//! Regenerates Figure 8: the §4.2 static load-balancing ablation — FPGA
//! latency with nnz-grouped schedule tables vs natural row order,
//! normalized to the no-LB case.
//!
//!     cargo bench --bench fig8_load_balancing

use nysx::bench::tables::*;

fn main() {
    let cfg = EvalConfig::default();
    let evals = evaluate_all(&cfg);
    println!("{}", render_fig8(&evals));
}
