//! Regenerates Table 7: throughput, power and energy efficiency per
//! platform (CPU/GPU analytic models, FPGA cycle+power model), with DPP.
//!
//!     cargo bench --bench table7_energy

use nysx::bench::tables::*;

fn main() {
    let cfg = EvalConfig::default();
    let evals = evaluate_all(&cfg);
    println!("{}", render_table7(&evals));
}
