//! Regenerates Table 8: model-parameter memory (Table 2 accounting) with
//! and without DPP landmark reduction.
//!
//!     cargo bench --bench table8_memory

use nysx::bench::tables::*;

fn main() {
    let cfg = EvalConfig::default();
    let evals = evaluate_all(&cfg);
    println!("{}", render_table8(&evals));
}
