//! Regenerates Table 3: estimated ZCU104 resource utilization of the
//! §6.1 design point (4 PEs, 16 FP32 MAC lanes, 512-deep stream FIFO)
//! with the deployed NCI1 model's on-chip buffer inventory.
//!
//!     cargo bench --bench table3_resources

use nysx::bench::tables::*;

fn main() {
    let cfg = EvalConfig::default();
    let evals = evaluate_all(&cfg);
    println!("{}", render_table3(&evals));
}
