//! Regenerates Table 6 + Figure 6: end-to-end latency per graph on
//! CPU/GPU (platform models) and FPGA (cycle model), with and without
//! DPP landmark reduction. Uses the shared cached evaluation driver.
//!
//!     cargo bench --bench table6_latency    [NYSX_SCALE=0.25 for quick runs]

use nysx::bench::tables::*;

fn main() {
    let cfg = EvalConfig::default();
    let evals = evaluate_all(&cfg);
    println!("{}", render_table6(&evals));
    println!("{}", render_fig6(&evals));
}
