//! Regenerates Figure 7: classification accuracy of GraphHD / NysHD /
//! NysX across the eight TUDatasets, plus (with --ablation via
//! NYSX_ABLATION=1) the equal-budget Uniform@s_dpp ablation that isolates
//! the DPP diversity effect from the landmark-count effect.
//!
//!     cargo bench --bench fig7_accuracy

use nysx::bench::tables::*;

fn main() {
    let cfg = EvalConfig {
        ablation: std::env::var("NYSX_ABLATION").is_ok(),
        ..EvalConfig::default()
    };
    let evals = evaluate_all(&cfg);
    println!("{}", render_fig7(&evals));
}
