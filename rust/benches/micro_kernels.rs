//! Micro-benchmarks of the L3 hot-path kernels (in-repo harness; no
//! criterion in the vendored crate set): scheduled SpMV vs plain CSR vs
//! dense, MPH lookup vs hashmap vs binary search, the NEE projection, the
//! full optimized inference, and the MPH γ ablation.
//!
//!     cargo bench --bench micro_kernels

use std::time::Duration;

use nysx::bench::harness::{bench, black_box, print_results};
use nysx::graph::tudataset::spec_by_name;
use nysx::infer::NysxEngine;
use nysx::kernel::node_codes;
use nysx::model::train::train;
use nysx::model::ModelConfig;
use nysx::mph::{code_key, Mph, MphLookup};
use nysx::sparse::{SchedulePolicy, ScheduleTable};
use nysx::util::rng::Xoshiro256;

fn main() {
    let budget = Duration::from_millis(300);
    let mut results = Vec::new();

    // --- a trained model + a representative query graph ---
    let spec = spec_by_name("NCI1").unwrap();
    let (ds, _s_uni, s_dpp) = spec.generate_scaled(42, 0.15);
    let cfg = ModelConfig {
        hops: spec.hops,
        hv_dim: 10_000,
        num_landmarks: s_dpp.min(ds.train.len()),
        ..ModelConfig::default()
    };
    eprintln!("training NCI1@0.15 model for the micro benches...");
    let model = train(&ds, &cfg);
    let graph = &ds.train[0].0;

    // --- SpMV variants on the largest landmark-histogram operand ---
    let h = model
        .landmark_hists
        .iter()
        .max_by_key(|h| h.nnz())
        .unwrap();
    let x: Vec<f64> = (0..h.cols).map(|i| (i % 7) as f64).collect();
    let mut y = vec![0.0f64; h.rows];
    let lb = ScheduleTable::build(h, 4, SchedulePolicy::NnzGrouped);
    results.push(bench("spmv/csr-plain", budget, || {
        h.spmv_into(black_box(&x), black_box(&mut y));
    }));
    results.push(bench("spmv/scheduled-lb", budget, || {
        lb.run_spmv(h, black_box(&x), black_box(&mut y));
    }));
    let dense = h.to_dense();
    let mut yd = vec![0.0f64; h.rows];
    results.push(bench("spmv/dense-matvec", budget, || {
        yd.copy_from_slice(&dense.matvec(black_box(&x)));
    }));

    // --- codebook lookup: MPH vs hashmap vs binary search ---
    let cb = model
        .codebooks
        .iter()
        .max_by_key(|c| c.len())
        .unwrap();
    let lookup = model
        .lookups
        .iter()
        .max_by_key(|l| l.mph.num_keys())
        .unwrap();
    let codes = node_codes(graph, &model.lsh).concat();
    results.push(bench("lookup/mph-o1", budget, || {
        let mut acc = 0u32;
        for &c in &codes {
            if let Some(i) = lookup.get(code_key(c)) {
                acc = acc.wrapping_add(i);
            }
        }
        black_box(acc);
    }));
    results.push(bench("lookup/hashmap", budget, || {
        let mut acc = 0u32;
        for &c in &codes {
            if let Some(i) = cb.index_of(c) {
                acc = acc.wrapping_add(i);
            }
        }
        black_box(acc);
    }));
    results.push(bench("lookup/binary-search", budget, || {
        let mut acc = 0usize;
        for &c in &codes {
            if let Ok(i) = cb.codes.binary_search(&c) {
                acc = acc.wrapping_add(i);
            }
        }
        black_box(acc);
    }));

    // --- NEE projection (the paper's dominant kernel) ---
    let c_vec: Vec<f64> = (0..model.s()).map(|i| (i % 11) as f64).collect();
    let mut hv = vec![0.0f64; model.d()];
    results.push(bench("nee/project-f32-rowmajor", budget, || {
        model
            .projection
            .project_into(black_box(&c_vec), black_box(&mut hv));
    }));

    // --- whole optimized inference ---
    let mut engine = NysxEngine::new(&model);
    results.push(bench("infer/optimized-e2e", budget, || {
        black_box(engine.infer(black_box(graph)).predicted);
    }));

    print_results(&results);

    // --- MPH γ ablation (paper §5.2.2 sizing trade-off) ---
    let mut rng = Xoshiro256::seed_from_u64(1);
    let keys: Vec<u64> = (0..20_000).map(|_| rng.next_u64()).collect::<std::collections::HashSet<_>>().into_iter().collect();
    let values: Vec<u32> = (0..keys.len() as u32).collect();
    println!("\nMPH gamma ablation ({} keys):", keys.len());
    println!("{:>6} {:>10} {:>8} {:>14}", "gamma", "bits/key", "levels", "mean probes");
    for gamma in [1.1f64, 1.25, 1.5, 2.0, 3.0] {
        let mph = Mph::build(&keys, gamma);
        let st = mph.stats(&keys);
        let _lk = MphLookup::build(&keys, &values, gamma);
        println!(
            "{gamma:>6} {:>10.2} {:>8} {:>14.2}",
            st.bits_per_key, st.levels, st.expected_probes
        );
    }
}
