//! Micro-benchmarks of the L3 hot-path kernels (in-repo harness; no
//! criterion in the vendored crate set): packed-vs-i8 hypervector
//! kernels, scheduled SpMV vs plain CSR vs dense, MPH lookup vs hashmap
//! vs binary search, the NEE projection (f64 and fused packed), the full
//! optimized inference, and the MPH γ ablation.
//!
//!     cargo bench --bench micro_kernels
//!
//! Smoke mode (for CI, no `cargo bench` needed — any way of running the
//! bench binary works, e.g. `NYSX_BENCH_SMOKE=1 cargo bench --bench
//! micro_kernels` or executing the built binary directly): set
//! `NYSX_BENCH_SMOKE=1` to shrink measurement budgets and the trained
//! model so the whole suite — including the packed-vs-i8 comparison —
//! compiles and completes in a few seconds.

use std::time::Duration;

use nysx::bench::harness::{bench, black_box, print_results, BenchResult};
use nysx::exec::Pool;
use nysx::graph::tudataset::spec_by_name;
use nysx::hdc::simd;
use nysx::hdc::{
    bundle, packed_bundle, Hypervector, PackedAccumulator, PackedBatch, PackedHypervector,
    PopcountBackend,
};
use nysx::infer::NysxEngine;
use nysx::kernel::node_codes;
use nysx::model::train::train;
use nysx::model::ModelConfig;
use nysx::mph::{code_key, Mph, MphLookup};
use nysx::sparse::{Csr, SchedulePolicy, ScheduleTable};
use nysx::util::rng::Xoshiro256;

fn smoke_mode() -> bool {
    std::env::var("NYSX_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// Mean-time ratio of two named results (old/new > 1 means `new` wins).
fn speedup(results: &[BenchResult], old: &str, new: &str) -> Option<(String, f64)> {
    let find = |n: &str| results.iter().find(|r| r.name == n);
    let (o, n) = (find(old)?, find(new)?);
    Some((format!("{old} → {new}"), o.mean_ns / n.mean_ns))
}

/// Median-time (p50) ratio — the thread-scaling table reports medians so
/// one slow outlier sample cannot fake or hide a speedup.
fn speedup_p50(results: &[BenchResult], old: &str, new: &str) -> Option<f64> {
    let find = |n: &str| results.iter().find(|r| r.name == n);
    Some(find(old)?.p50_ns / find(new)?.p50_ns)
}

fn main() {
    let smoke = smoke_mode();
    let budget = if smoke {
        Duration::from_millis(8)
    } else {
        Duration::from_millis(300)
    };
    // Warm the process-wide exec pool ONCE before any timing loop: the
    // engine benches below dispatch on it, and its first run pays
    // worker spawn/wake costs that must never pollute reported medians.
    nysx::exec::global().warm_up();
    let mut results = Vec::new();

    // --- packed vs i8 hypervector kernels at the paper's d = 10^4 ---
    let d = 10_000;
    let mut rng = Xoshiro256::seed_from_u64(7);
    let a8 = Hypervector::random(d, &mut rng);
    let b8 = Hypervector::random(d, &mut rng);
    let (pa, pb) = (a8.pack(), b8.pack());
    results.push(bench("hv/dot-i8", budget, || {
        black_box(a8.dot(black_box(&b8)));
    }));
    results.push(bench("hv/dot-packed", budget, || {
        black_box(pa.dot(black_box(&pb)));
    }));
    results.push(bench("hv/hamming-i8", budget, || {
        black_box(a8.hamming(black_box(&b8)));
    }));
    results.push(bench("hv/hamming-packed", budget, || {
        black_box(pa.hamming(black_box(&pb)));
    }));
    results.push(bench("hv/bind-i8", budget, || {
        black_box(a8.bind(black_box(&b8)));
    }));
    results.push(bench("hv/bind-packed", budget, || {
        black_box(pa.bind(black_box(&pb)));
    }));
    results.push(bench("hv/permute-i8", budget, || {
        black_box(a8.permute(black_box(12_345)));
    }));
    results.push(bench("hv/permute-packed", budget, || {
        black_box(pa.permute(black_box(12_345)));
    }));
    let members8: Vec<Hypervector> = (0..16).map(|_| Hypervector::random(d, &mut rng)).collect();
    let member_refs8: Vec<&Hypervector> = members8.iter().collect();
    let members_p: Vec<PackedHypervector> = members8.iter().map(|h| h.pack()).collect();
    let member_refs_p: Vec<&PackedHypervector> = members_p.iter().collect();
    results.push(bench("hv/bundle16-i8", budget, || {
        black_box(bundle(black_box(&member_refs8)));
    }));
    results.push(bench("hv/bundle16-packed", budget, || {
        black_box(packed_bundle(black_box(&member_refs_p)));
    }));

    // --- a trained model + a representative query graph ---
    let (ds, cfg) = if smoke {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, s_uni, _) = spec.generate_scaled(42, 0.15);
        let cfg = ModelConfig {
            hops: 2,
            hv_dim: 1000,
            num_landmarks: s_uni.min(8),
            ..ModelConfig::default()
        };
        (ds, cfg)
    } else {
        let spec = spec_by_name("NCI1").unwrap();
        let (ds, _s_uni, s_dpp) = spec.generate_scaled(42, 0.15);
        let cfg = ModelConfig {
            hops: spec.hops,
            hv_dim: 10_000,
            num_landmarks: s_dpp.min(ds.train.len()),
            ..ModelConfig::default()
        };
        (ds, cfg)
    };
    eprintln!(
        "training {}@0.15 model for the micro benches{}...",
        ds.name,
        if smoke { " (smoke mode)" } else { "" }
    );
    let model = train(&ds, &cfg);
    let graph = &ds.train[0].0;

    // --- SpMV variants on the largest landmark-histogram operand ---
    let h = model
        .landmark_hists
        .iter()
        .max_by_key(|h| h.nnz())
        .unwrap();
    let x: Vec<f64> = (0..h.cols).map(|i| (i % 7) as f64).collect();
    let mut y = vec![0.0f64; h.rows];
    let lb = ScheduleTable::build(h, 4, SchedulePolicy::NnzGrouped);
    results.push(bench("spmv/csr-plain", budget, || {
        h.spmv_into(black_box(&x), black_box(&mut y));
    }));
    results.push(bench("spmv/scheduled-lb", budget, || {
        lb.run_spmv(h, black_box(&x), black_box(&mut y));
    }));
    let dense = h.to_dense();
    let mut yd = vec![0.0f64; h.rows];
    results.push(bench("spmv/dense-matvec", budget, || {
        yd.copy_from_slice(&dense.matvec(black_box(&x)));
    }));

    // --- codebook lookup: MPH vs hashmap vs binary search ---
    let cb = model
        .codebooks
        .iter()
        .max_by_key(|c| c.len())
        .unwrap();
    let lookup = model
        .lookups
        .iter()
        .max_by_key(|l| l.mph.num_keys())
        .unwrap();
    let codes = node_codes(graph, &model.lsh).concat();
    results.push(bench("lookup/mph-o1", budget, || {
        let mut acc = 0u32;
        for &c in &codes {
            if let Some(i) = lookup.get(code_key(c)) {
                acc = acc.wrapping_add(i);
            }
        }
        black_box(acc);
    }));
    results.push(bench("lookup/hashmap", budget, || {
        let mut acc = 0u32;
        for &c in &codes {
            if let Some(i) = cb.index_of(c) {
                acc = acc.wrapping_add(i);
            }
        }
        black_box(acc);
    }));
    results.push(bench("lookup/binary-search", budget, || {
        let mut acc = 0usize;
        for &c in &codes {
            if let Ok(i) = cb.codes.binary_search(&c) {
                acc = acc.wrapping_add(i);
            }
        }
        black_box(acc);
    }));

    // --- NEE projection (the paper's dominant kernel): f64 path vs the
    // fused project-bipolarize-pack hot path ---
    let c_vec: Vec<f64> = (0..model.s()).map(|i| (i % 11) as f64).collect();
    let mut hv = vec![0.0f64; model.d()];
    results.push(bench("nee/project-f32-rowmajor", budget, || {
        model
            .projection
            .project_into(black_box(&c_vec), black_box(&mut hv));
    }));
    let mut packed_hv = PackedHypervector::zeros(model.d());
    results.push(bench("nee/project-pack-fused", budget, || {
        model
            .projection
            .project_pack_into(black_box(&c_vec), black_box(&mut packed_hv));
    }));

    // --- SCE: prototype matching, i8 vs packed ---
    let q8 = packed_hv.unpack();
    let i8_protos = model.reference_prototypes();
    results.push(bench("sce/classify-i8", budget, || {
        black_box(i8_protos.classify(black_box(&q8)));
    }));
    results.push(bench("sce/classify-packed", budget, || {
        black_box(model.packed_prototypes.classify(black_box(&packed_hv)));
    }));

    // --- per-backend SIMD kernels: every compiled-in backend vs the
    // scalar oracle on the same operands (raw xor_popcount at d=10^4 and
    // the full SCE classify). Runs in smoke mode too, so CI reports the
    // comparison — and asserts bit-equality — on its own hardware. ---
    let backends = simd::available();
    let want_pop = simd::scalar().xor_popcount(pa.words(), pb.words());
    for be in &backends {
        assert_eq!(
            be.xor_popcount(pa.words(), pb.words()),
            want_pop,
            "backend {} diverges from scalar",
            be.name()
        );
        results.push(bench(&format!("backend/{}/xor-popcount", be.name()), budget, || {
            black_box(be.xor_popcount(black_box(pa.words()), black_box(pb.words())));
        }));
        results.push(bench(&format!("backend/{}/sce-classify", be.name()), budget, || {
            black_box(model.packed_prototypes.classify_with(*be, black_box(&packed_hv)));
        }));
    }

    // --- SCE batch-major: W queries per dispatch, single-query loop vs
    // the blocked C×W matcher (one pass over G per batch). Runs in smoke
    // mode too so CI covers the batched-vs-single comparison. ---
    let w_batch = if smoke { 8 } else { 32 };
    let mut qrng = Xoshiro256::seed_from_u64(11);
    let batch_queries: Vec<PackedHypervector> = (0..w_batch)
        .map(|_| PackedHypervector::random(model.d(), &mut qrng))
        .collect();
    let mut batch = PackedBatch::new(model.d());
    for q in &batch_queries {
        batch.push(q);
    }
    let single_name = format!("sce/batch{w_batch}-single-loop");
    let blocked_name = format!("sce/batch{w_batch}-blocked");
    results.push(bench(&single_name, budget, || {
        let mut acc = 0usize;
        for q in &batch_queries {
            acc = acc.wrapping_add(model.packed_prototypes.classify(black_box(q)));
        }
        black_box(acc);
    }));
    let mut batch_scores = Vec::new();
    let mut batch_preds = Vec::new();
    results.push(bench(&blocked_name, budget, || {
        model.packed_prototypes.classify_batch_into(
            black_box(&batch),
            &mut batch_scores,
            &mut batch_preds,
        );
        black_box(batch_preds.len());
    }));

    // --- exec thread scaling: the pool-parallel kernels at 1/2/4
    // threads on identical operands. Each pool is warmed up once before
    // its first timed loop (satellite of the pool-spawn-cost bugfix);
    // smoke mode runs the same code and asserts bit-equality only —
    // shared CI runners make timing ratios meaningless there. ---
    let scale_pools: Vec<Pool> = [1usize, 2, 4].iter().map(|&t| Pool::new(t)).collect();
    for pool in &scale_pools {
        pool.warm_up();
    }
    let be = simd::active();
    // Blocked C×W scoring at the paper's d: a synthetic C=16 prototype
    // set × W queries (the serving shape the acceptance bar measures).
    let exec_classes = 16usize;
    let exec_w = if smoke { 8 } else { 64 };
    let mut erng = Xoshiro256::seed_from_u64(29);
    let exec_protos = {
        let mut acc = PackedAccumulator::new(exec_classes, model.d());
        for i in 0..3 * exec_classes {
            acc.add(i % exec_classes, &PackedHypervector::random(model.d(), &mut erng));
        }
        acc.finalize()
    };
    let mut exec_batch = PackedBatch::new(model.d());
    for _ in 0..exec_w {
        exec_batch.push(&PackedHypervector::random(model.d(), &mut erng));
    }
    let mut want_scores = vec![0i64; exec_classes * exec_w];
    exec_protos.scores_batch_into_with(be, &exec_batch, &mut want_scores);
    let mut exec_out = vec![0i64; exec_classes * exec_w];
    for pool in &scale_pools {
        let t = pool.threads();
        exec_protos.scores_batch_into_pool(pool, be, &exec_batch, &mut exec_out);
        assert_eq!(
            exec_out, want_scores,
            "exec C×W scores diverge at {t} threads"
        );
        results.push(bench(
            &format!("exec/sce-c{exec_classes}xw{exec_w}/t{t}"),
            budget,
            || {
                exec_protos.scores_batch_into_pool(pool, be, black_box(&exec_batch), &mut exec_out);
                black_box(exec_out[0]);
            },
        ));
    }
    // Fused NEE project-bipolarize-pack across word ranges.
    let mut want_pack = PackedHypervector::zeros(model.d());
    model.projection.project_pack_into(&c_vec, &mut want_pack);
    for pool in &scale_pools {
        let t = pool.threads();
        let mut out = PackedHypervector::zeros(model.d());
        model.projection.project_pack_into_with_pool(pool, &c_vec, &mut out);
        assert_eq!(out, want_pack, "exec NEE pack diverges at {t} threads");
        results.push(bench(&format!("exec/nee-pack/t{t}"), budget, || {
            model
                .projection
                .project_pack_into_with_pool(pool, black_box(&c_vec), &mut out);
            black_box(out.dim());
        }));
    }
    // Scheduled SpMV over an operand big enough to feed several lanes.
    let spmv_n = if smoke { 192 } else { 1536 };
    let mut srng = Xoshiro256::seed_from_u64(31);
    let mut triplets = Vec::new();
    for r in 0..spmv_n {
        for c in 0..spmv_n {
            if srng.bernoulli(0.04) {
                triplets.push((r, c, srng.normal()));
            }
        }
    }
    let spmv_csr = Csr::from_triplets(spmv_n, spmv_n, triplets);
    let spmv_sched = ScheduleTable::build(&spmv_csr, 16, SchedulePolicy::NnzGrouped);
    let spmv_x: Vec<f64> = (0..spmv_n).map(|i| (i % 13) as f64).collect();
    let mut spmv_want = vec![0.0f64; spmv_n];
    spmv_sched.run_spmv(&spmv_csr, &spmv_x, &mut spmv_want);
    let mut spmv_y = vec![0.0f64; spmv_n];
    for pool in &scale_pools {
        let t = pool.threads();
        spmv_sched.run_spmv_with_pool(pool, &spmv_csr, &spmv_x, &mut spmv_y);
        assert_eq!(spmv_y, spmv_want, "exec SpMV diverges at {t} threads");
        results.push(bench(&format!("exec/spmv-lb-n{spmv_n}/t{t}"), budget, || {
            spmv_sched.run_spmv_with_pool(pool, black_box(&spmv_csr), &spmv_x, &mut spmv_y);
            black_box(spmv_y[0]);
        }));
    }

    // --- whole optimized inference ---
    let mut engine = NysxEngine::new(&model);
    results.push(bench("infer/optimized-e2e", budget, || {
        black_box(engine.infer(black_box(graph)).predicted);
    }));

    print_results(&results);

    println!("\npacked vs i8 speedups (mean-time ratio, d={d}):");
    for (old, new) in [
        ("hv/dot-i8", "hv/dot-packed"),
        ("hv/hamming-i8", "hv/hamming-packed"),
        ("hv/bind-i8", "hv/bind-packed"),
        ("hv/permute-i8", "hv/permute-packed"),
        ("hv/bundle16-i8", "hv/bundle16-packed"),
        ("sce/classify-i8", "sce/classify-packed"),
    ] {
        if let Some((label, ratio)) = speedup(&results, old, new) {
            println!("  {label:<44} {ratio:6.1}x");
        }
    }

    println!("\nbatched vs single-query SCE (mean-time ratio per batch, W={w_batch}):");
    if let Some((label, ratio)) = speedup(&results, &single_name, &blocked_name) {
        println!("  {label:<44} {ratio:6.2}x");
    }

    println!(
        "\nSIMD backends vs scalar (mean-time ratio; active dispatch: {}):",
        simd::active().name()
    );
    if backends.len() == 1 {
        println!("  (scalar only — no SIMD backend available on this host)");
    }
    for be in &backends {
        if be.name() == "scalar" {
            continue;
        }
        for kernel in ["xor-popcount", "sce-classify"] {
            let old = format!("backend/scalar/{kernel}");
            let new = format!("backend/{}/{kernel}", be.name());
            if let Some((label, ratio)) = speedup(&results, &old, &new) {
                println!("  {label:<44} {ratio:6.2}x");
            }
        }
    }

    println!(
        "\nexec thread scaling (p50-time ratio vs 1 thread; pools pre-warmed{}):",
        if smoke { "; smoke mode — ratios indicative only, equality asserted" } else { "" }
    );
    println!(
        "{:>28} {:>8} {:>8} {:>8}",
        "kernel", "t=1", "t=2", "t=4"
    );
    for kernel in [
        format!("exec/sce-c{exec_classes}xw{exec_w}"),
        "exec/nee-pack".to_string(),
        format!("exec/spmv-lb-n{spmv_n}"),
    ] {
        let base = format!("{kernel}/t1");
        let r2 = speedup_p50(&results, &base, &format!("{kernel}/t2")).unwrap_or(f64::NAN);
        let r4 = speedup_p50(&results, &base, &format!("{kernel}/t4")).unwrap_or(f64::NAN);
        println!("{kernel:>28} {:>7.2}x {r2:>7.2}x {r4:>7.2}x", 1.0);
    }

    // --- MPH γ ablation (paper §5.2.2 sizing trade-off) ---
    let n_keys = if smoke { 2_000 } else { 20_000 };
    let mut rng = Xoshiro256::seed_from_u64(1);
    let keys: Vec<u64> = (0..n_keys)
        .map(|_| rng.next_u64())
        .collect::<std::collections::HashSet<_>>()
        .into_iter()
        .collect();
    let values: Vec<u32> = (0..keys.len() as u32).collect();
    println!("\nMPH gamma ablation ({} keys):", keys.len());
    println!("{:>6} {:>10} {:>8} {:>14}", "gamma", "bits/key", "levels", "mean probes");
    for gamma in [1.1f64, 1.25, 1.5, 2.0, 3.0] {
        let mph = Mph::build(&keys, gamma);
        let st = mph.stats(&keys);
        let _lk = MphLookup::build(&keys, &values, gamma);
        println!(
            "{gamma:>6} {:>10.2} {:>8} {:>14.2}",
            st.bits_per_key, st.levels, st.expected_probes
        );
    }
}
