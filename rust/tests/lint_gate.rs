//! The lint gate: `nysx lint` over this crate's own `src/` and `tests/`
//! must report **zero findings** (DESIGN.md §8). Every invariant the
//! analyzer checks — SAFETY-annotated `unsafe`, a panic-free serving
//! set, hash-order/clock/RNG-free kernels, total float orderings,
//! confined thread spawns — is thereby pinned at its current state: a
//! regression fails this test (and the CI lint leg) with the exact
//! file:line, and the only way past is a justified per-site pragma.

use std::path::PathBuf;

use nysx::analysis::{lint_crate, rules, SCHEMA};
use nysx::util::json::Json;

fn crate_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// The tree is clean: zero findings over the whole crate.
#[test]
fn tree_has_zero_findings() {
    let report = lint_crate(&crate_root()).expect("lint runs");
    assert!(
        report.findings.is_empty(),
        "lint findings in the tree:\n{}",
        report.render_text()
    );
    assert!(
        report.files_scanned > 50,
        "suspiciously small scan ({} files) — did the walk break?",
        report.files_scanned
    );
}

/// Every suppression in force carries a written justification, and the
/// inventory is small: waiving an invariant is the exception, not a
/// budget. If this count grows, each new site was consciously argued.
#[test]
fn pragma_inventory_is_justified_and_bounded() {
    let report = lint_crate(&crate_root()).expect("lint runs");
    for p in &report.pragmas {
        assert!(
            !p.justification.trim().is_empty(),
            "{}:{} allow({}) lacks a justification",
            p.file,
            p.line,
            p.rule
        );
        assert!(
            rules::RULES.contains(&p.rule.as_str())
                || nysx::analysis::RACE_RULES.contains(&p.rule.as_str()),
            "{}:{} allows unknown rule {:?}",
            p.file,
            p.line,
            p.rule
        );
    }
    assert!(
        report.pragmas.len() <= 8,
        "pragma inventory grew to {} sites — is the invariant still an invariant?\n{}",
        report.pragmas.len(),
        report.render_text()
    );
}

/// The artifact pipeline end to end on the real tree: write validates
/// (schema tag, count consistency) and lands a parseable document whose
/// per-rule keys cover every rule.
#[test]
fn artifact_round_trips_on_the_real_tree() {
    let report = lint_crate(&crate_root()).expect("lint runs");
    let dir = std::env::temp_dir().join(format!("nysx-lint-gate-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join("LINT_REPORT.json");
    report.write(&path).expect("artifact validates and writes");
    let text = std::fs::read_to_string(&path).expect("artifact readable");
    let doc = Json::parse(&text).expect("artifact parses");
    assert_eq!(doc.get("schema").and_then(Json::as_str), Some(SCHEMA));
    assert_eq!(
        doc.get("total_findings").and_then(Json::as_usize),
        Some(report.findings.len())
    );
    assert_eq!(
        doc.get("files_scanned").and_then(Json::as_usize),
        Some(report.files_scanned)
    );
    for rule in rules::RULES {
        assert!(
            doc.get("rules").and_then(|r| r.get(rule)).is_some(),
            "artifact missing rules.{rule}"
        );
    }
    assert_eq!(
        doc.get("pragmas").and_then(Json::as_arr).map(<[Json]>::len),
        Some(report.pragmas.len())
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The gate actually bites: a planted violation in a scratch crate is
/// found at the right file and line, and the same scratch tree passes
/// once the violation carries a justified pragma.
#[test]
fn gate_detects_and_pragma_clears_a_planted_violation() {
    let dir = std::env::temp_dir().join(format!("nysx-lint-plant-{}", std::process::id()));
    let api = dir.join("src").join("api");
    std::fs::create_dir_all(&api).expect("temp tree");
    let bad = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
    std::fs::write(api.join("mod.rs"), bad).expect("write");
    let report = lint_crate(&dir).expect("lint runs");
    assert_eq!(report.findings.len(), 1, "{}", report.render_text());
    assert_eq!(report.findings[0].rule, rules::RULE_NO_PANIC);
    assert_eq!(report.findings[0].file, "src/api/mod.rs");
    assert_eq!(report.findings[0].line, 1);

    let fixed = format!("// nysx-lint: allow(no-panic-in-serving): scratch fixture\n{bad}");
    std::fs::write(api.join("mod.rs"), fixed).expect("write");
    let report = lint_crate(&dir).expect("lint runs");
    assert!(report.findings.is_empty(), "{}", report.render_text());
    assert_eq!(report.pragmas.len(), 1);
    std::fs::remove_dir_all(&dir).ok();
}

/// The determinism rule covers the succinct codecs: a planted `HashMap`
/// in a scratch `src/succinct/` file is a finding (the rank/select,
/// Elias–Fano and MPH structures must be bit-reproducible — hash-order
/// iteration anywhere in their build paths would break the cross-format
/// and cross-thread differential pins), while the same code in a
/// non-kernel path is not.
/// The timing-confinement rule keeps raw clock reads behind the
/// `obs::clock` seam: a planted `Instant::now()` in a scratch
/// `src/infer/` file is a finding (and *only* a timing finding — infer/
/// is outside the determinism kernel set), the identical code inside
/// `src/bench/` is allowed, and a justified pragma clears the planted
/// site.
#[test]
fn gate_confines_raw_clock_reads() {
    let dir = std::env::temp_dir().join(format!("nysx-lint-timing-{}", std::process::id()));
    let infer = dir.join("src").join("infer");
    let bench = dir.join("src").join("bench");
    std::fs::create_dir_all(&infer).expect("temp tree");
    std::fs::create_dir_all(&bench).expect("temp tree");
    let bad = "pub fn f() -> std::time::Instant { std::time::Instant::now() }\n";
    std::fs::write(infer.join("hot.rs"), bad).expect("write");
    std::fs::write(bench.join("mod.rs"), bad).expect("write");
    let report = lint_crate(&dir).expect("lint runs");
    assert_eq!(report.findings.len(), 1, "{}", report.render_text());
    assert_eq!(report.findings[0].rule, rules::RULE_TIMING);
    assert_eq!(report.findings[0].file, "src/infer/hot.rs");
    assert_eq!(report.findings[0].line, 1);

    let fixed =
        format!("// nysx-lint: allow(timing-confinement): scratch fixture, not a hot path\n{bad}");
    std::fs::write(infer.join("hot.rs"), fixed).expect("write");
    let report = lint_crate(&dir).expect("lint runs");
    assert!(report.findings.is_empty(), "{}", report.render_text());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn gate_covers_succinct_determinism() {
    let dir = std::env::temp_dir().join(format!("nysx-lint-succinct-{}", std::process::id()));
    let succinct = dir.join("src").join("succinct");
    let bench = dir.join("src").join("bench");
    std::fs::create_dir_all(&succinct).expect("temp tree");
    std::fs::create_dir_all(&bench).expect("temp tree");
    let bad = "pub fn f() { let m: std::collections::HashMap<u64, u32> = Default::default(); drop(m); }\n";
    std::fs::write(succinct.join("phast.rs"), bad).expect("write");
    std::fs::write(bench.join("mod.rs"), bad).expect("write");
    let report = lint_crate(&dir).expect("lint runs");
    assert_eq!(report.findings.len(), 1, "{}", report.render_text());
    assert_eq!(report.findings[0].rule, rules::RULE_DETERMINISM);
    assert_eq!(report.findings[0].file, "src/succinct/phast.rs");
    std::fs::remove_dir_all(&dir).ok();
}
