//! Cross-layer integration tests: the packed-engine serving path against
//! the i8 reference oracle, model-file round-trips through disk, the
//! full train→serve story, and — when built with the `xla-runtime`
//! feature AND `make artifacts` has run — rust (L3) executing the
//! jax-exported HLO artifacts (L2, containing the L1 Pallas kernel)
//! through PJRT.
//!
//! The XLA tests are skipped with a message when the manifest is missing,
//! so `cargo test` works before the first artifact build; without the
//! `xla-runtime` feature they are not compiled at all (the `xla` crate is
//! not in the vendored set).

use std::sync::Arc;

use nysx::graph::tudataset::spec_by_name;
use nysx::infer::{infer_reference, NysxEngine};
use nysx::model::train::train;
use nysx::model::ModelConfig;
use nysx::nystrom::LandmarkStrategy;

/// A model whose shapes fit the default test-scale encode artifact
/// (n=64, f=16, hops=3, bmax=512, s=48, d=2048, classes=4).
fn artifact_compatible_model() -> (nysx::graph::GraphDataset, nysx::model::NysHdcModel) {
    let spec = spec_by_name("NCI1").unwrap();
    // Tiny scale: graphs ~30 nodes < 64, f fixed by spec... NCI1 has f=37
    // which exceeds the artifact's f=16, so build a custom dataset from
    // MUTAG (f=7) padded? The artifact requires f == 16 exactly; instead
    // synthesize with a 16-label alphabet via ENZYMES-like spec below.
    let _ = spec;
    let mut custom = *spec_by_name("MUTAG").unwrap();
    custom.num_labels = 16;
    custom.hops = 3;
    custom.num_train = 60;
    custom.num_test = 16;
    let ds = custom.generate(123);
    let cfg = ModelConfig {
        hops: 3,
        hv_dim: 2048,
        num_landmarks: 24,
        strategy: LandmarkStrategy::Uniform,
        lsh_width: 1.0,
        ..ModelConfig::default()
    };
    let model = train(&ds, &cfg);
    (ds, model)
}

/// End-to-end differential test for the bit-packed engine: train a small
/// MUTAG-spec model (d off a word boundary so the tail word is live) and
/// assert the packed pipeline's predictions AND hypervectors are
/// bit-identical to the verbatim-Algorithm-1 i8 reference on every
/// train/test graph.
#[test]
fn packed_engine_matches_i8_reference_end_to_end() {
    let spec = spec_by_name("MUTAG").unwrap();
    let (ds, _, _) = spec.generate_scaled(17, 0.25);
    let cfg = ModelConfig {
        hops: 3,
        hv_dim: 1000, // 15 full words + a 40-bit tail word
        num_landmarks: 12,
        ..ModelConfig::default()
    };
    let model = train(&ds, &cfg);
    let mut engine = NysxEngine::new(&model);
    for (g, _) in ds.train.iter().chain(ds.test.iter()) {
        let packed = engine.infer(g);
        let (want_pred, want_hv) = infer_reference(&model, g);
        assert_eq!(packed.predicted, want_pred, "prediction mismatch");
        assert_eq!(packed.hv.unpack(), want_hv, "HV mismatch (unpacked)");
        assert_eq!(packed.hv, want_hv.pack(), "HV mismatch (packed)");
        // The packed prototypes must agree with the i8 prototypes on the
        // full score vector, not just the argmax.
        assert_eq!(
            model.packed_prototypes.scores(&packed.hv),
            model.reference_prototypes().scores(&want_hv),
            "score vector mismatch"
        );
    }
}

#[test]
fn model_file_roundtrip_via_disk() {
    let (ds, model) = artifact_compatible_model();
    let dir = std::env::temp_dir().join(format!("nysx-test-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("model.nysx");
    nysx::model::io::save_file(&model, &path).unwrap();
    let back = nysx::model::io::load_file(&path).unwrap();
    assert_eq!(back.packed_prototypes, model.packed_prototypes);
    let mut e1 = NysxEngine::new(&model);
    let mut e2 = NysxEngine::new(&back);
    for (g, _) in ds.test.iter().take(8) {
        assert_eq!(e1.infer(g).predicted, e2.infer(g).predicted);
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn train_serve_end_to_end() {
    // The full L3 story: train, serve through the coordinator, verify
    // accuracy matches offline evaluation.
    let (ds, model) = artifact_compatible_model();
    let offline_acc =
        nysx::model::train::evaluate(&model, &ds.test).expect("non-empty test split");
    let model = Arc::new(model);
    let mut server = nysx::coordinator::Server::start(
        model,
        nysx::coordinator::ServerConfig {
            workers: 3,
            ..Default::default()
        },
    );
    for (g, _) in ds.test.iter() {
        server.submit(g.clone()).unwrap();
    }
    let responses = server.shutdown();
    assert_eq!(responses.len(), ds.test.len());
    let correct = responses
        .iter()
        .filter(|r| r.predicted == ds.test[r.id as usize].1)
        .count();
    let served_acc = correct as f64 / ds.test.len() as f64;
    assert!((served_acc - offline_acc).abs() < 1e-9, "serving changed accuracy");
}

/// The `nysx::api` facade end to end: builder → train → evaluate →
/// serve, with the coordinator-backed classifier agreeing with the owned
/// packed engine on every round-tripped query.
#[test]
fn api_facade_end_to_end() {
    use nysx::api::{Classifier, Pipeline};
    let mut trained = Pipeline::for_dataset("MUTAG")
        .expect("MUTAG exists")
        .scale(0.2)
        .hops(3)
        .hv_dim(500)
        .seed(3)
        .train()
        .expect("small training run");
    let acc = trained.evaluate().expect("non-empty test split");
    let chance = 1.0 / trained.dataset().num_classes as f64;
    assert!(acc > chance, "facade accuracy {acc} at or below chance");
    let mut served = trained.serve(Default::default()).expect("default serving config");
    let (ds, engine) = trained.parts();
    for (g, _) in ds.test.iter().take(6) {
        assert_eq!(
            served.classify(g).expect("serving transport"),
            engine.infer(g).predicted,
            "served prediction != owned engine"
        );
    }
    served.shutdown();
}

#[cfg(feature = "xla-runtime")]
mod xla_tests {
    use super::artifact_compatible_model;
    use std::path::Path;

    use nysx::infer::{infer_reference, NysxEngine};
    use nysx::runtime::{Manifest, PjrtRuntime, XlaEncoder, XlaNee};

    fn artifacts_dir() -> Option<&'static Path> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            Some(Box::leak(dir.into_boxed_path()))
        } else {
            eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
            None
        }
    }

    #[test]
    fn xla_nee_matches_native_projection() {
        let Some(dir) = artifacts_dir() else { return };
        let manifest = Manifest::load(dir).expect("manifest loads");
        let (_ds, model) = artifact_compatible_model();
        let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
        let nee = XlaNee::new(&rt, &manifest, &model).expect("NEE artifact");

        // Random kernel vectors through both paths.
        let mut rng = nysx::util::rng::Xoshiro256::seed_from_u64(5);
        for _ in 0..5 {
            let c: Vec<f64> = (0..model.s()).map(|_| rng.uniform(0.0, 50.0)).collect();
            let xla_hv = nee.project_sign(&c).expect("xla exec");
            let y = model.projection.project(&c);
            let native_hv = nysx::hdc::Hypervector::from_real(&y);
            assert_eq!(xla_hv.len(), model.d());
            // f32-vs-f64 accumulation can flip signs only at |y| ≈ ulp scale.
            let mismatches = xla_hv
                .iter()
                .zip(&native_hv.data)
                .filter(|(&x, &n)| (x as i8) != n)
                .count();
            assert!(
                (mismatches as f64) < 0.005 * model.d() as f64,
                "{mismatches}/{} HV sign mismatches",
                model.d()
            );
        }
    }

    #[test]
    fn xla_full_encoder_matches_rust_reference() {
        let Some(dir) = artifacts_dir() else { return };
        let manifest = Manifest::load(dir).expect("manifest loads");
        let (ds, model) = artifact_compatible_model();
        let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
        let encoder = XlaEncoder::new(&rt, &manifest, &model).expect("encode artifact");

        let mut engine = NysxEngine::new(&model);
        let mut agree = 0usize;
        let mut total = 0usize;
        for (g, _) in ds.test.iter() {
            if !encoder.fits(g) {
                continue;
            }
            total += 1;
            let (xla_pred, xla_scores, xla_hv) = encoder.encode_classify(g).expect("xla exec");
            let (rust_pred, rust_hv) = infer_reference(&model, g);
            let opt = engine.infer(g);
            assert_eq!(opt.predicted, rust_pred, "rust paths disagree");
            // HVs agree except at fp32 sign-boundary coordinates.
            let mismatches = xla_hv
                .iter()
                .zip(&rust_hv.data)
                .filter(|(&x, &n)| (x as i8) != n)
                .count();
            assert!(
                (mismatches as f64) < 0.01 * model.d() as f64,
                "{mismatches} HV mismatches"
            );
            assert_eq!(xla_scores.len(), encoder.classes_art);
            if xla_pred == rust_pred {
                agree += 1;
            }
        }
        assert!(total >= 10, "too few test graphs fit the artifact ({total})");
        assert!(
            agree as f64 >= 0.9 * total as f64,
            "XLA vs rust predictions agree on only {agree}/{total}"
        );
    }
}
