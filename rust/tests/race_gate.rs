//! The race gate: `nysx race` over this crate's own `src/` and `tests/`
//! must report **zero findings** (DESIGN.md §9). The concurrency
//! invariants the analyzer pins — raw-pointer dispatch confined to
//! `exec/parallel.rs` and always paired with `validate_disjoint`,
//! constant range lists sorted+disjoint, coordinator locks taken in the
//! declared order — are thereby frozen at their current state: a
//! regression fails this test (and the CI race leg) with the exact
//! file:line, and the only way past is a justified per-site pragma.

use std::path::PathBuf;

use nysx::analysis::race::{self, RULE_CONST_OVERLAP, RULE_LOCK_ORDER, RULE_RAW_CONFINEMENT};
use nysx::analysis::{race_crate, RACE_RULES};
use nysx::util::json::Json;

fn crate_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// A scratch crate root under the system temp dir, torn down on drop.
fn scratch_tree(tag: &str, rel: &str, text: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("nysx-race-{tag}-{}", std::process::id()));
    let path = dir.join(rel);
    std::fs::create_dir_all(path.parent().expect("rel has a parent")).expect("temp tree");
    std::fs::write(&path, text).expect("write fixture");
    dir
}

/// The tree is clean: zero race findings over the whole crate.
#[test]
fn tree_has_zero_race_findings() {
    let report = race_crate(&crate_root()).expect("race check runs");
    assert!(
        report.findings.is_empty(),
        "race findings in the tree:\n{}",
        report.render_text()
    );
    assert!(
        report.files_scanned > 50,
        "suspiciously small scan ({} files) — did the walk break?",
        report.files_scanned
    );
}

/// The artifact pipeline end to end on the real tree: write validates
/// (schema tag, count consistency) and lands a parseable
/// `CONCURRENCY_REPORT.json` whose per-rule keys cover every race rule.
#[test]
fn artifact_round_trips_on_the_real_tree() {
    let report = race_crate(&crate_root()).expect("race check runs");
    let dir = std::env::temp_dir().join(format!("nysx-race-gate-{}", std::process::id()));
    std::fs::create_dir_all(&dir).expect("temp dir");
    let path = dir.join("CONCURRENCY_REPORT.json");
    report.write(&path).expect("artifact validates and writes");
    let text = std::fs::read_to_string(&path).expect("artifact readable");
    let doc = Json::parse(&text).expect("artifact parses");
    assert_eq!(doc.get("schema").and_then(Json::as_str), Some(race::SCHEMA));
    assert_eq!(
        doc.get("total_findings").and_then(Json::as_usize),
        Some(report.findings.len())
    );
    assert_eq!(
        doc.get("files_scanned").and_then(Json::as_usize),
        Some(report.files_scanned)
    );
    for rule in RACE_RULES {
        assert!(
            doc.get("rules").and_then(|r| r.get(rule)).is_some(),
            "artifact missing rules.{rule}"
        );
    }
    assert_eq!(
        doc.get("pragmas").and_then(Json::as_arr).map(<[Json]>::len),
        Some(report.pragmas.len())
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// The gate bites on data races by construction: a constant range list
/// with overlapping intervals is found at the right file and line, and
/// the same tree passes once the site carries a justified pragma.
#[test]
fn gate_detects_planted_overlap_and_pragma_clears_it() {
    let bad = "pub fn f(data: &mut [u8]) { dispatch(data, &[0..6, 5..10]); }\n";
    let dir = scratch_tree("overlap", "src/kernel/sched.rs", bad);
    let report = race_crate(&dir).expect("race check runs");
    assert_eq!(report.findings.len(), 1, "{}", report.render_text());
    assert_eq!(report.findings[0].rule, RULE_CONST_OVERLAP);
    assert_eq!(report.findings[0].file, "src/kernel/sched.rs");
    assert_eq!(report.findings[0].line, 1);

    let fixed = format!(
        "// nysx-lint: allow(race-const-overlap): scratch fixture, ranges are read-only\n{bad}"
    );
    std::fs::write(dir.join("src/kernel/sched.rs"), fixed).expect("write");
    let report = race_crate(&dir).expect("race check runs");
    assert!(report.findings.is_empty(), "{}", report.render_text());
    assert_eq!(report.pragmas.len(), 1);
    assert_eq!(report.pragmas[0].rule, RULE_CONST_OVERLAP);
    std::fs::remove_dir_all(&dir).ok();
}

/// The gate bites on deadlocks by construction: acquiring the metrics
/// registry lock and then the batcher queue lock inverts the declared
/// order and is flagged at the second acquisition.
#[test]
fn gate_detects_planted_lock_order_inversion() {
    let bad = concat!(
        "fn drain(&self) {\n",
        "    let m = lock_or_poison(&self.inner);\n",
        "    let q = lock_or_poison(&self.state);\n",
        "    drop((m, q));\n",
        "}\n",
    );
    let dir = scratch_tree("lockord", "src/coordinator/batcher.rs", bad);
    let report = race_crate(&dir).expect("race check runs");
    assert_eq!(report.findings.len(), 1, "{}", report.render_text());
    assert_eq!(report.findings[0].rule, RULE_LOCK_ORDER);
    assert_eq!(report.findings[0].file, "src/coordinator/batcher.rs");
    assert_eq!(report.findings[0].line, 3);
    assert!(
        report.findings[0].message.contains("inversion"),
        "{}",
        report.findings[0].message
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Raw-pointer dispatch anywhere but `exec/parallel.rs` is confined out
/// of existence: a planted `SendPtr` in a kernel file is flagged.
#[test]
fn gate_confines_raw_dispatch_to_parallel_rs() {
    let bad = "pub fn push(base: *mut u8) { let p = SendPtr(base); drop(p); }\n";
    let dir = scratch_tree("rawconf", "src/kernel/fast.rs", bad);
    let report = race_crate(&dir).expect("race check runs");
    assert_eq!(report.findings.len(), 1, "{}", report.render_text());
    assert_eq!(report.findings[0].rule, RULE_RAW_CONFINEMENT);
    assert_eq!(report.findings[0].file, "src/kernel/fast.rs");
    assert_eq!(report.findings[0].line, 1);
    std::fs::remove_dir_all(&dir).ok();
}
