//! The `nysx::exec` cross-kernel differential suite: every kernel the
//! data-parallel runtime drives — fused NEE projection, blocked C×W SCE
//! matching, schedule-table SpMV, Gram assembly, and whole-model
//! training with per-lane bundle accumulators — must be **bit-identical
//! at thread counts {1, 2, 7}** to the sequential path, and
//! (transitively, through the packed engine's own differential suite)
//! to the i8 oracle. Dims deliberately straddle the 64-bit word
//! boundary (63/64/65) so tail-word handling is live in every parallel
//! split.
//!
//! Thread count must be a pure throughput knob: these tests are what
//! make `NYSX_THREADS=1` vs `NYSX_THREADS=4` CI legs equivalent by
//! construction, not by luck.

use nysx::exec::{self, Pool};
use nysx::graph::tudataset::spec_by_name;
use nysx::graph::Graph;
use nysx::hdc::{simd, PackedAccumulator, PackedBatch, PackedHypervector};
use nysx::infer::{infer_reference, NysxEngine};
use nysx::kernel::{gram_from_signatures_with_pool, signatures_with_pool, LshParams};
use nysx::linalg::Mat;
use nysx::model::train::train_with_pool;
use nysx::model::ModelConfig;
use nysx::nystrom::NystromProjection;
use nysx::sparse::{Csr, SchedulePolicy, ScheduleTable};
use nysx::util::rng::Xoshiro256;

const THREAD_COUNTS: [usize; 3] = [1, 2, 7];
const BOUNDARY_DIMS: [usize; 3] = [63, 64, 65];

fn pools() -> Vec<Pool> {
    THREAD_COUNTS.iter().map(|&t| Pool::new(t)).collect()
}

fn random_psd(n: usize, rank: usize, rng: &mut Xoshiro256) -> Mat {
    let a = Mat::randn(n, rank, rng);
    a.matmul(&a.transpose())
}

fn random_csr(rows: usize, cols: usize, p: f64, rng: &mut Xoshiro256) -> Csr {
    let mut triplets = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            if rng.bernoulli(p) {
                triplets.push((r, c, rng.normal()));
            }
        }
    }
    Csr::from_triplets(rows, cols, triplets)
}

/// NEE: parallel projection build and fused project-bipolarize-pack are
/// bit-identical across thread counts at word-boundary dims, and the
/// packed bits equal the sign of the f64 projection (the i8 oracle's
/// input).
#[test]
fn nee_projection_parallel_equals_sequential_and_oracle() {
    let pools = pools();
    for &d in &BOUNDARY_DIMS {
        let build = |pool: &Pool| {
            let mut rng = Xoshiro256::seed_from_u64(3);
            let hz = random_psd(7, 5, &mut rng);
            NystromProjection::build_with_pool(pool, &hz, d, &mut rng)
        };
        let want = build(&pools[0]);
        for pool in &pools {
            let got = build(pool);
            assert_eq!(got.data, want.data, "P_nys drift d={d} t={}", pool.threads());
            let mut qrng = Xoshiro256::seed_from_u64(11);
            for _ in 0..4 {
                let c: Vec<f64> = (0..want.s).map(|_| qrng.normal()).collect();
                let mut packed = PackedHypervector::zeros(d);
                got.project_pack_into_with_pool(pool, &c, &mut packed);
                // Sequential fused path.
                let mut seq = PackedHypervector::zeros(d);
                want.project_pack_into(&c, &mut seq);
                assert_eq!(packed, seq, "fused pack drift d={d} t={}", pool.threads());
                // i8-oracle route: sign of the f64 projection, packed.
                let oracle = nysx::hdc::Hypervector::from_real(&want.project(&c)).pack();
                assert_eq!(packed, oracle, "pack != sign(project) d={d}");
            }
        }
    }
}

/// SCE: blocked C×W batch scoring and class-block single-query scoring
/// across thread counts equal the sequential matcher AND the i8 oracle
/// prototypes, at boundary dims.
#[test]
fn sce_matching_parallel_equals_sequential_and_oracle() {
    let pools = pools();
    let be = simd::active();
    let mut rng = Xoshiro256::seed_from_u64(5);
    for &d in &BOUNDARY_DIMS {
        let classes = 4;
        let mut packed_acc = PackedAccumulator::new(classes, d);
        let mut i8_acc = nysx::hdc::PrototypeAccumulator::new(classes, d);
        for i in 0..17 {
            let hv = nysx::hdc::Hypervector::random(d, &mut rng);
            packed_acc.add(i % classes, &hv.pack());
            i8_acc.add(i % classes, &hv);
        }
        let protos = packed_acc.finalize();
        let oracle = i8_acc.finalize();
        let queries: Vec<nysx::hdc::Hypervector> = (0..9)
            .map(|_| nysx::hdc::Hypervector::random(d, &mut rng))
            .collect();
        let mut batch = PackedBatch::new(d);
        for q in &queries {
            batch.push(&q.pack());
        }
        let mut want = vec![0i64; classes * queries.len()];
        protos.scores_batch_into_with(be, &batch, &mut want);
        for pool in &pools {
            let t = pool.threads();
            let mut got = vec![0i64; classes * queries.len()];
            protos.scores_batch_into_pool(pool, be, &batch, &mut got);
            assert_eq!(got, want, "batch scores drift d={d} t={t}");
            for (qi, q) in queries.iter().enumerate() {
                let qp = q.pack();
                let row = &got[qi * classes..(qi + 1) * classes];
                assert_eq!(row, oracle.scores(q).as_slice(), "scores != i8 oracle d={d}");
                assert_eq!(
                    protos.scores_pool(pool, be, &qp).as_slice(),
                    row,
                    "class-block scores drift d={d} t={t}"
                );
                assert_eq!(
                    protos.classify_pool(pool, be, &qp),
                    oracle.classify(q),
                    "classify drift d={d} t={t}"
                );
            }
        }
    }
}

/// SpMV: the schedule-table row groups are a partition for every policy
/// (the §4.2 permutation property), and the pool-parallel scheduled
/// SpMV is bit-identical to plain CSR SpMV across thread counts, PE
/// widths, and policies.
#[test]
fn scheduled_spmv_parallel_equals_plain_for_every_policy() {
    let pools = pools();
    let mut rng = Xoshiro256::seed_from_u64(13);
    for trial in 0..6 {
        let rows = 5 + 17 * trial;
        let cols = 3 + 11 * trial;
        let csr = random_csr(rows, cols, 0.3, &mut rng);
        let x: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
        let want = csr.spmv(&x);
        for pes in [1usize, 4, 7] {
            for policy in [SchedulePolicy::NnzGrouped, SchedulePolicy::RowOrder] {
                // Partitioner property: groups partition the rows.
                let groups = exec::nnz_row_groups(&csr, pes, policy);
                let mut seen = vec![false; rows];
                for g in &groups {
                    for &r in g {
                        assert!(!seen[r as usize], "row {r} twice ({policy:?})");
                        seen[r as usize] = true;
                    }
                }
                assert!(seen.iter().all(|&s| s), "missing rows ({policy:?})");

                let sched = ScheduleTable::build(&csr, pes, policy);
                for pool in &pools {
                    let mut got = vec![0.0f64; rows];
                    sched.run_spmv_with_pool(pool, &csr, &x, &mut got);
                    assert_eq!(
                        got,
                        want,
                        "SpMV drift rows={rows} pes={pes} {policy:?} t={}",
                        pool.threads()
                    );
                }
            }
        }
    }
}

/// Gram: parallel signatures + triangle-partitioned kernel walk are
/// bit-identical across thread counts and the matrix stays symmetric.
#[test]
fn gram_parallel_equals_sequential() {
    let pools = pools();
    let mut rng = Xoshiro256::seed_from_u64(19);
    let spec = spec_by_name("MUTAG").unwrap();
    let (ds, _, _) = spec.generate_scaled(23, 0.15);
    let graphs: Vec<&Graph> = ds.train.iter().take(14).map(|(g, _)| g).collect();
    let lsh = LshParams::sample(2, ds.feature_dim, 1.0, &mut rng);
    let want_sigs = signatures_with_pool(&pools[0], &graphs, &lsh);
    let want = gram_from_signatures_with_pool(&pools[0], &want_sigs);
    for pool in &pools {
        let sigs = signatures_with_pool(pool, &graphs, &lsh);
        let k = gram_from_signatures_with_pool(pool, &sigs);
        assert_eq!(k.data, want.data, "gram drift t={}", pool.threads());
        for i in 0..k.rows {
            for j in 0..k.cols {
                assert_eq!(k[(i, j)], k[(j, i)], "asymmetry at ({i},{j})");
            }
        }
    }
}

/// Finalize: the class-parallel threshold walk (`finalize_with_pool`)
/// is bit-identical to the sequential finalize across thread counts and
/// boundary dims — including classes that received zero samples.
#[test]
fn finalize_parallel_equals_sequential() {
    let pools = pools();
    let mut rng = Xoshiro256::seed_from_u64(23);
    for &d in &BOUNDARY_DIMS {
        let classes = 7;
        let mut acc = PackedAccumulator::new(classes, d);
        for i in 0..33 {
            let hv = nysx::hdc::Hypervector::random(d, &mut rng);
            // Classes 4..7 stay empty: the n == 0 all-(+1) path is live.
            acc.add(i % 4, &hv.pack());
        }
        let want = acc.clone().finalize();
        for pool in &pools {
            let got = acc.clone().finalize_with_pool(pool);
            assert_eq!(got, want, "finalize drift d={d} t={}", pool.threads());
        }
    }
}

/// One representative output per parallel-dispatch shape: contiguous
/// ranges (NEE projection), scatter writes (scheduled SpMV), and
/// class-parallel map (finalize).
fn kernel_outputs(pool: &Pool) -> (Vec<f32>, Vec<f64>, nysx::hdc::PackedPrototypes) {
    let mut rng = Xoshiro256::seed_from_u64(31);
    let hz = random_psd(7, 5, &mut rng);
    let proj = NystromProjection::build_with_pool(pool, &hz, 65, &mut rng);
    let csr = random_csr(40, 30, 0.3, &mut rng);
    let x: Vec<f64> = (0..30).map(|_| rng.normal()).collect();
    let sched = ScheduleTable::build(&csr, 4, SchedulePolicy::NnzGrouped);
    let mut y = vec![0.0f64; 40];
    sched.run_spmv_with_pool(pool, &csr, &x, &mut y);
    let mut acc = PackedAccumulator::new(5, 65);
    for i in 0..21 {
        let hv = nysx::hdc::Hypervector::random(65, &mut rng);
        acc.add(i % 5, &hv.pack());
    }
    (proj.data, y, acc.finalize_with_pool(pool))
}

/// The shadow checker plus seeded schedule perturbation
/// (`NYSX_EXEC_CHECK=1` semantics, forced on for this thread) must not
/// change a single bit: part execution *order* is permuted per lane and
/// per seed, every write claim is recorded and checked, and the outputs
/// still equal the unperturbed, unchecked baseline at every thread
/// count — the dynamic half of the §9 acceptance pin.
#[test]
fn perturbed_schedules_with_shadow_check_stay_bit_identical() {
    use nysx::exec::check;
    let pools = pools();
    let baseline = {
        let _seed = check::force_perturb_seed(0);
        kernel_outputs(&pools[0])
    };
    for seed in [1u64, 2, 3] {
        let _check = check::force_enabled(true);
        let _seed = check::force_perturb_seed(seed);
        for pool in &pools {
            let got = kernel_outputs(pool);
            assert_eq!(
                got,
                baseline,
                "kernel drift under perturbation seed={seed} t={}",
                pool.threads()
            );
        }
    }
}

/// Training + the batched classify path end to end: models trained at
/// 1/2/7 threads are identical, and every engine's single AND batched
/// predictions (and packed HVs) match each other and the i8 oracle —
/// the acceptance pin behind the NYSX_THREADS=1 vs =4 CI legs.
#[test]
fn train_and_batched_classify_bit_identical_across_thread_counts() {
    let pools = pools();
    let spec = spec_by_name("MUTAG").unwrap();
    let (ds, _, _) = spec.generate_scaled(29, 0.2);
    let cfg = ModelConfig {
        hops: 2,
        hv_dim: 500, // off a word boundary: live tail word everywhere
        num_landmarks: 8,
        ..ModelConfig::default()
    };
    let want_model = train_with_pool(&ds, &cfg, &pools[0]);
    let graphs: Vec<&Graph> = ds.test.iter().map(|(g, _)| g).collect();
    let oracle: Vec<(usize, nysx::hdc::Hypervector)> = graphs
        .iter()
        .map(|g| infer_reference(&want_model, g))
        .collect();
    for pool in &pools {
        let t = pool.threads();
        let model = train_with_pool(&ds, &cfg, pool);
        assert_eq!(
            model.packed_prototypes, want_model.packed_prototypes,
            "trained prototypes drift at t={t}"
        );
        assert_eq!(
            model.landmark_indices, want_model.landmark_indices,
            "landmark drift at t={t}"
        );
        let mut engine = NysxEngine::with_pool(&model, std::sync::Arc::new(Pool::new(t)));
        let batched = engine.infer_batch(&graphs);
        for (qi, res) in batched.iter().enumerate() {
            let (want_pred, want_hv) = &oracle[qi];
            assert_eq!(res.predicted, *want_pred, "batched pred != i8 oracle t={t}");
            assert_eq!(res.hv, want_hv.pack(), "batched HV != i8 oracle t={t}");
            let single = engine.infer(graphs[qi]);
            assert_eq!(single.predicted, *want_pred, "single pred drift t={t}");
            assert_eq!(single.hv, res.hv, "single vs batched HV drift t={t}");
        }
    }
}
