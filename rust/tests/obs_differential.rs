//! Obs differential gate: classification outputs are bit-identical with
//! observability recording on or off, at any pool width (DESIGN.md
//! §11). Instrumentation only ever *writes* metric atomics — this test
//! pins the "recording never feeds back into computation" contract end
//! to end through the public pipeline facade, across pools {1, 2, 7},
//! for both the single-graph and the batched inference paths, down to
//! the packed query hypervector words.
//!
//! One `#[test]` on purpose: the enable flag is process-global, and two
//! tests toggling it concurrently inside this binary would race. (Other
//! integration binaries run in their own processes and never see it.)

use nysx::api::Pipeline;
use nysx::graph::Graph;

/// Per test graph: (single predicted, single hv words, batch predicted,
/// batch hv words).
type Fingerprint = Vec<(usize, Vec<u64>, usize, Vec<u64>)>;

fn run(threads: usize, obs_on: bool) -> Fingerprint {
    nysx::obs::set_enabled(obs_on);
    let mut pipeline = Pipeline::for_dataset("MUTAG")
        .expect("known dataset")
        .scale(0.25)
        .hv_dim(1000)
        .seed(91)
        .threads(threads)
        .train()
        .expect("training succeeds");
    let graphs: Vec<Graph> = pipeline
        .dataset()
        .test
        .iter()
        .map(|(g, _)| g.clone())
        .collect();
    let refs: Vec<&Graph> = graphs.iter().collect();
    let batched = pipeline.infer_batch(&refs);
    graphs
        .iter()
        .zip(batched)
        .map(|(g, b)| {
            let s = pipeline.infer(g);
            (
                s.predicted,
                s.hv.words().to_vec(),
                b.predicted,
                b.hv.words().to_vec(),
            )
        })
        .collect()
}

#[test]
fn outputs_bit_identical_with_obs_on_or_off_across_pools() {
    let mut baseline: Option<Fingerprint> = None;
    for threads in [1usize, 2, 7] {
        for obs_on in [false, true] {
            let fp = run(threads, obs_on);
            assert!(!fp.is_empty(), "test split must be non-empty");
            match &baseline {
                None => baseline = Some(fp),
                Some(b) => assert_eq!(
                    b, &fp,
                    "outputs diverged at threads={threads} obs_on={obs_on}"
                ),
            }
        }
    }

    // The enabled runs were not vacuous: every pipeline stage span
    // recorded at least once (train_finalize during train(), the rest
    // on the inference paths).
    let snap = nysx::obs::Snapshot::capture();
    for stage in nysx::obs::STAGES {
        let name = format!("stage.{stage}");
        let hist = snap
            .histograms
            .iter()
            .find(|h| h.name == name)
            .unwrap_or_else(|| panic!("snapshot missing {name}"));
        assert!(hist.count > 0, "{name} never recorded while obs was on");
    }
    nysx::obs::set_enabled(false);
}
