//! Integration suite for the sharded serving tier: the consistent-hash
//! front router plus N independent coordinators must be *invisible* to
//! correctness. Two properties anchor it:
//!
//! 1. **Bit-identical classification** across shard counts {1, 2, 4}
//!    and against the in-process packed engine — shard count, like
//!    thread count, is a pure throughput knob.
//! 2. **Zero loss under faults**: stopping a shard mid-load reroutes
//!    new traffic to survivors, every already-accepted request is still
//!    answered (the drained shard finishes its queue before joining),
//!    and the books always balance: sent == answered + rejected, with
//!    rejections only ever surfacing as typed [`SubmitError`] variants.

use std::collections::{HashMap, HashSet};
use std::time::Duration;

use nysx::api::{Classifier, NysxError, Pipeline, TrainedPipeline};
use nysx::coordinator::{BatcherConfig, ServerConfig, ShardedConfig, SubmitError};
use nysx::graph::Graph;

/// A small-but-real pipeline: scaled-down MUTAG, word-boundary-straddling
/// hv dim, single exec thread so the suite stays fast under `cargo test`.
fn trained() -> TrainedPipeline {
    Pipeline::for_dataset("MUTAG")
        .expect("dataset spec")
        .scale(0.25)
        .seed(42)
        .hv_dim(1000)
        .threads(1)
        .train()
        .expect("training")
}

fn tier_config(shards: usize, max_outstanding: usize, batch_size: usize) -> ShardedConfig {
    ShardedConfig {
        shards,
        max_outstanding,
        per_shard: ServerConfig {
            workers: 1,
            batcher: BatcherConfig {
                batch_size,
                // Short deadline: tests drain often, and nothing here
                // depends on batches actually filling.
                max_wait: Duration::from_millis(2),
                ..Default::default()
            },
            ..Default::default()
        },
    }
}

/// Property 1: the served predictions at shard counts {1, 2, 4} are all
/// bit-identical to the in-process packed engine, single AND batch path.
#[test]
fn classifications_bit_identical_across_shard_counts() {
    let mut trained = trained();
    let graphs: Vec<Graph> = trained
        .dataset()
        .test
        .iter()
        .map(|(g, _)| g.clone())
        .collect();
    let refs: Vec<&Graph> = graphs.iter().collect();
    let want: Vec<usize> = trained
        .infer_batch(&refs)
        .into_iter()
        .map(|r| r.predicted)
        .collect();
    assert!(!want.is_empty(), "empty test split would vacuously pass");

    for shards in [1usize, 2, 4] {
        let mut tier = trained
            .serve_sharded(tier_config(shards, 256, 4))
            .expect("tier start");
        assert_eq!(tier.num_shards(), shards);
        let got = tier.classify_batch(&refs).expect("served batch");
        assert_eq!(
            got, want,
            "predictions diverged from in-process engine at {shards} shards"
        );
        // Single-request path rides the same router; spot-check a prefix.
        for (&g, &w) in refs.iter().zip(&want).take(16) {
            assert_eq!(tier.classify(g).expect("served single"), w);
        }
        // Every request answered: replicated prototypes mean any shard
        // may serve any graph, but none may be silently dropped.
        let served: usize = (0..shards).map(|s| tier.shard_metrics(s).requests).sum();
        assert!(
            served >= refs.len(),
            "shards answered {served} < {} submitted",
            refs.len()
        );
        tier.shutdown();
    }
}

/// Property 2: stop one shard in the middle of a replay. New traffic
/// reroutes to the survivors, everything accepted before the stop is
/// still answered, predictions stay bit-identical, and the accounting
/// identity sent == answered + rejected holds with rejected == 0 (no
/// request in this replay is ever shed — the cap is generous).
#[test]
fn stopping_a_shard_mid_load_loses_nothing() {
    let mut trained = trained();
    let ds_len = trained.dataset().test.len();
    let plan: Vec<usize> = (0..80).map(|i| i % ds_len).collect();
    let expected: Vec<usize> = {
        let graphs: Vec<Graph> = trained
            .dataset()
            .test
            .iter()
            .map(|(g, _)| g.clone())
            .collect();
        let refs: Vec<&Graph> = plan.iter().map(|&i| &graphs[i]).collect();
        trained
            .infer_batch(&refs)
            .into_iter()
            .map(|r| r.predicted)
            .collect()
    };

    let mut tier = trained
        .serve_sharded(tier_config(3, 256, 2))
        .expect("tier start");
    let mut want_of: HashMap<u64, usize> = HashMap::new();
    let mut sent = 0usize;
    let mut answered = Vec::new();
    for (k, (&idx, &want)) in plan.iter().zip(&expected).enumerate() {
        if k == plan.len() / 2 {
            tier.stop_shard(1);
            assert_eq!(tier.live_shards(), 2, "one shard should be gone");
            // Idempotent: stopping again (or an already-dead slot) is a
            // quiet no-op, not a panic or a double-join.
            tier.stop_shard(1);
            assert_eq!(tier.live_shards(), 2);
        }
        let mut graph = trained.dataset().test[idx].0.clone();
        loop {
            match tier.submit(graph) {
                Ok(id) => {
                    want_of.insert(id, want);
                    sent += 1;
                    break;
                }
                Err(SubmitError::Backpressure(g)) => {
                    // Typed shed signal with the graph handed back; free
                    // a slot and retry rather than dropping the request.
                    graph = g;
                    if let Some(r) = tier.recv() {
                        answered.push(r);
                    }
                }
                Err(SubmitError::Closed(_)) => {
                    panic!("tier closed with {} live shards", tier.live_shards())
                }
            }
        }
    }
    answered.extend(tier.drain());

    // Books: every accepted request came back exactly once, including
    // the ones queued on shard 1 when it was stopped.
    assert_eq!(sent, plan.len());
    assert_eq!(
        answered.len(),
        sent,
        "lost {} responses across the shard stop",
        sent - answered.len()
    );
    let mut seen = HashSet::new();
    for r in &answered {
        assert!(seen.insert(r.id), "duplicate response {}", r.id);
        assert_eq!(
            Some(&r.predicted),
            want_of.get(&r.id),
            "prediction diverged for request {}",
            r.id
        );
    }

    // Survivors carried the post-stop traffic.
    assert!(tier.shard_metrics(0).requests + tier.shard_metrics(2).requests > 0);
    tier.shutdown();
}

/// The typed failure surface end to end: a tiny admission cap trips
/// `Backpressure` deterministically (outstanding only decrements on
/// recv, so worker speed cannot race the assertion), and a fully
/// stopped tier returns `Closed` — both hand the graph back untouched,
/// and the books still balance when sheds are counted as rejections.
#[test]
fn backpressure_and_closed_are_typed_and_lossless() {
    let mut trained = trained();
    let graph = trained.dataset().test[0].0.clone();
    let nodes = graph.num_nodes();
    // batch_size > cap so admission, not the queue, is the binding
    // constraint; a long deadline keeps the batcher out of the picture.
    let mut tier = trained
        .serve_sharded(ShardedConfig {
            shards: 2,
            max_outstanding: 2,
            per_shard: ServerConfig {
                workers: 1,
                batcher: BatcherConfig {
                    batch_size: 8,
                    max_wait: Duration::from_millis(10),
                    ..Default::default()
                },
                ..Default::default()
            },
        })
        .expect("tier start");

    let mut sent = 0usize;
    let mut rejected = 0usize;
    for _ in 0..2 {
        tier.submit(graph.clone()).expect("under the cap");
        sent += 1;
    }
    match tier.submit(graph.clone()) {
        Err(SubmitError::Backpressure(g)) => {
            rejected += 1;
            assert_eq!(g.num_nodes(), nodes, "backpressure must return the graph intact");
        }
        other => panic!("expected Backpressure at the cap, got {other:?}"),
    }
    let answered = tier.drain().len();

    // Stop everything: the tier is now typed-Closed, and submissions
    // keep getting their graph back (callers can fail over losslessly).
    tier.stop_shard(0);
    tier.stop_shard(1);
    assert_eq!(tier.live_shards(), 0);
    match tier.submit(graph.clone()) {
        Err(SubmitError::Closed(g)) => {
            rejected += 1;
            assert_eq!(g.num_nodes(), nodes, "closed must return the graph intact");
        }
        other => panic!("expected Closed on an empty ring, got {other:?}"),
    }
    // The accounting identity: every submission either entered the tier
    // and was answered, or was handed back as a typed rejection — none
    // vanished.
    assert_eq!(rejected, 2, "one Backpressure + one Closed");
    assert_eq!(sent, answered, "every accepted request must be answered");

    // NysxError conversion keeps the typed story at the api layer,
    // distinguishing retryable sheds from terminal closure.
    let bp: NysxError = SubmitError::Backpressure(graph.clone()).into();
    assert!(bp.is_retryable());
    let err: NysxError = SubmitError::Closed(graph).into();
    assert!(matches!(err, NysxError::Closed) && !err.is_retryable());
    tier.shutdown();
}
