//! Synthetic TUDataset suite.
//!
//! We have no network access, so the eight TUDataset benchmarks
//! (Table 4 of the paper) are replaced by class-conditional synthetic
//! generators matched to the published statistics: #train/#test, average
//! nodes/edges, class count and node-label alphabet size. See DESIGN.md §2
//! for why this preserves the behaviours the paper measures.
//!
//! Class signal design:
//! * **Label signal** — each (class, mode) pair tilts the Zipf-like node
//!   label distribution toward a class-specific subset of the alphabet.
//!   Propagation-kernel methods (NysHD/NysX) see this; GraphHD (topology
//!   only) does not.
//! * **Structure signal** — classes differ in triangle bias / extra-edge
//!   density. All methods can see this.
//! * **Intra-class modes** — each class is a mixture of sub-modes with
//!   skewed priors. Uniform landmark sampling over-represents the heavy
//!   mode; DPP selection covers the tail modes, which is exactly the
//!   redundancy-vs-diversity effect §4.1 of the paper exploits.
//!
//! MUTAG and COX2 are configured structure-dominant (weak label signal),
//! reproducing the paper's observation that GraphHD is slightly better on
//! those two datasets.

use super::generators::tree_plus_random_hub;
use super::{Graph, GraphDataset};
use crate::util::rng::Xoshiro256;

/// Static description of one synthetic TU dataset.
#[derive(Debug, Clone, Copy)]
pub struct TuSpec {
    pub name: &'static str,
    pub description: &'static str,
    pub num_train: usize,
    pub num_test: usize,
    pub avg_nodes: f64,
    pub avg_edges: f64,
    pub num_classes: usize,
    /// Node-label alphabet size (= feature dim f).
    pub num_labels: usize,
    /// Propagation hops H used for this dataset (structure-dominant sets
    /// use deeper propagation).
    pub hops: usize,
    /// Strength of the class-conditional label tilt (0 = labels carry no
    /// class signal).
    pub label_signal: f64,
    /// Strength of the class-conditional structure (triangle bias) signal.
    pub struct_signal: f64,
    /// Number of intra-class modes (>=1).
    pub modes: usize,
    /// Landmark count used by the uniform (NysHD) baseline.
    pub s_uniform: usize,
    /// Landmark count after hybrid Uniform+DPP reduction (NysX); the
    /// reduction ratio follows the paper's Table 8.
    pub s_dpp: usize,
}

/// The eight benchmark specs (Table 4 statistics; landmark counts sized so
/// that P_nys memory matches Table 8 at d=10000/FP32).
pub const TU_SPECS: [TuSpec; 8] = [
    TuSpec {
        name: "ENZYMES",
        description: "Protein graphs",
        num_train: 480,
        num_test: 120,
        avg_nodes: 33.0,
        avg_edges: 62.0,
        num_classes: 6,
        num_labels: 3,
        hops: 3,
        label_signal: 3.0,
        struct_signal: 0.5,
        modes: 2,
        s_uniform: 420,
        s_dpp: 290,
    },
    TuSpec {
        name: "NCI1",
        description: "Chemical compounds",
        num_train: 3288,
        num_test: 822,
        avg_nodes: 30.0,
        avg_edges: 32.0,
        num_classes: 2,
        num_labels: 37,
        hops: 4,
        label_signal: 2.5,
        struct_signal: 0.3,
        modes: 4,
        s_uniform: 328,
        s_dpp: 206,
    },
    TuSpec {
        name: "DD",
        description: "Protein structures",
        num_train: 943,
        num_test: 235,
        avg_nodes: 284.0,
        avg_edges: 716.0,
        num_classes: 2,
        num_labels: 89,
        hops: 4,
        label_signal: 2.0,
        struct_signal: 0.4,
        modes: 4,
        s_uniform: 327,
        s_dpp: 239,
    },
    TuSpec {
        name: "BZR",
        description: "Drug activity graphs",
        num_train: 324,
        num_test: 81,
        avg_nodes: 36.0,
        avg_edges: 38.0,
        num_classes: 2,
        num_labels: 10,
        hops: 4,
        label_signal: 2.2,
        struct_signal: 0.3,
        modes: 4,
        s_uniform: 308,
        s_dpp: 184,
    },
    TuSpec {
        name: "MUTAG",
        description: "Mutagenicity prediction",
        num_train: 150,
        num_test: 38,
        avg_nodes: 18.0,
        avg_edges: 20.0,
        num_classes: 2,
        num_labels: 7,
        hops: 6,
        // Structure-dominant: labels nearly uninformative so the
        // topology-only GraphHD baseline can edge ahead (paper §6.6.3).
        label_signal: 0.4,
        struct_signal: 1.0,
        modes: 2,
        s_uniform: 148,
        s_dpp: 91,
    },
    TuSpec {
        name: "COX2",
        description: "Drug activity graphs",
        num_train: 373,
        num_test: 94,
        avg_nodes: 41.0,
        avg_edges: 43.0,
        num_classes: 2,
        num_labels: 8,
        hops: 6,
        // Structure-dominant like MUTAG.
        label_signal: 0.4,
        struct_signal: 1.0,
        modes: 2,
        s_uniform: 327,
        s_dpp: 201,
    },
    TuSpec {
        name: "NCI109",
        description: "Chemical compounds",
        num_train: 3301,
        num_test: 826,
        avg_nodes: 30.0,
        avg_edges: 32.0,
        num_classes: 2,
        num_labels: 38,
        hops: 4,
        label_signal: 2.5,
        struct_signal: 0.3,
        modes: 4,
        s_uniform: 327,
        s_dpp: 183,
    },
    TuSpec {
        name: "Mutagenicity",
        description: "Mutagenicity prediction",
        num_train: 3469,
        num_test: 868,
        avg_nodes: 30.0,
        avg_edges: 31.0,
        num_classes: 2,
        num_labels: 14,
        hops: 4,
        label_signal: 2.3,
        struct_signal: 0.3,
        modes: 4,
        s_uniform: 310,
        s_dpp: 187,
    },
];

/// Look up a spec by (case-insensitive) name.
pub fn spec_by_name(name: &str) -> Option<&'static TuSpec> {
    TU_SPECS
        .iter()
        .find(|s| s.name.eq_ignore_ascii_case(name))
}

impl TuSpec {
    /// Per-(class, mode) node-label weights: Zipf base tilted toward a
    /// (class, mode)-specific congruence subset of the alphabet.
    fn label_weights(&self, class: usize, mode: usize) -> Vec<f64> {
        let f = self.num_labels;
        let stride = self.num_classes * self.modes;
        let phase = class * self.modes + mode;
        (0..f)
            .map(|l| {
                let base = 1.0 / (1.0 + l as f64).sqrt();
                let boost = if stride > 0 && l % stride.min(f) == phase % stride.min(f) {
                    1.0 + self.label_signal
                } else {
                    1.0
                };
                base * boost
            })
            .collect()
    }

    /// Class-conditional triangle bias in [0, 0.95].
    fn triangle_bias(&self, class: usize) -> f64 {
        let denom = (self.num_classes - 1).max(1) as f64;
        (0.08 + self.struct_signal * 0.6 * class as f64 / denom).min(0.95)
    }

    /// Skewed mode prior: heavy head, light tail (drives landmark
    /// redundancy under uniform sampling).
    fn mode_weights(&self) -> Vec<f64> {
        (0..self.modes).map(|m| 1.0 / (1.0 + 3.0 * m as f64)).collect()
    }

    /// Sample one graph of the given class.
    pub fn sample_graph(&self, class: usize, rng: &mut Xoshiro256) -> Graph {
        // Log-normal node count around avg_nodes (mean-corrected).
        let sigma: f64 = if self.avg_nodes > 100.0 { 0.45 } else { 0.3 };
        let scale = self.avg_nodes / (sigma * sigma / 2.0).exp();
        let n = ((scale * (sigma * rng.normal()).exp()).round() as usize).max(6);
        // Extra edges beyond the spanning tree, scaled with n. Class tilts
        // the density slightly (part of the structure signal).
        let extra_per_node =
            (self.avg_edges - self.avg_nodes + 1.0).max(0.0) / self.avg_nodes;
        let class_density = 1.0
            + self.struct_signal * 0.35 * (class as f64 / (self.num_classes - 1).max(1) as f64 - 0.5);
        let extra = ((extra_per_node * n as f64 * class_density)
            + rng.normal() * 0.6)
            .round()
            .max(0.0) as usize;
        let mode = rng.weighted_choice(&self.mode_weights());
        let weights = self.label_weights(class, mode);
        // Structure signal part 2: higher classes form hubs (degree-
        // proportional extra edges) — the signal PageRank-rank encodings
        // (GraphHD) are sharpest at.
        let denom = (self.num_classes - 1).max(1) as f64;
        let hub_bias = (self.struct_signal * 0.75 * class as f64 / denom).min(0.9);
        let edges = tree_plus_random_hub(n, extra, self.triangle_bias(class), hub_bias, rng);
        let labels: Vec<usize> = (0..n).map(|_| rng.weighted_choice(&weights)).collect();
        Graph::from_edges(n, &edges, &labels, self.num_labels)
    }

    /// Generate the full train/test dataset. Class priors are skewed
    /// (65/35 for binary) so uniform landmark sampling exhibits the
    /// redundancy the paper's DPP selection removes.
    pub fn generate(&self, seed: u64) -> GraphDataset {
        let mut rng = Xoshiro256::seed_from_u64(seed ^ fxhash(self.name));
        let class_weights: Vec<f64> = (0..self.num_classes)
            .map(|c| 1.0 / (1.0 + 0.55 * c as f64))
            .collect();
        let gen_split = |count: usize, rng: &mut Xoshiro256| {
            let mut out = Vec::with_capacity(count);
            for _ in 0..count {
                let class = rng.weighted_choice(&class_weights);
                out.push((self.sample_graph(class, rng), class));
            }
            out
        };
        let train = gen_split(self.num_train, &mut rng);
        let test = gen_split(self.num_test, &mut rng);
        GraphDataset {
            name: self.name.to_string(),
            train,
            test,
            num_classes: self.num_classes,
            feature_dim: self.num_labels,
        }
    }

    /// Generate a scaled-down variant (for fast tests / CI): counts are
    /// multiplied by `scale`, landmark budgets shrink proportionally.
    pub fn generate_scaled(&self, seed: u64, scale: f64) -> (GraphDataset, usize, usize) {
        let mut spec = *self;
        spec.num_train = ((self.num_train as f64 * scale).round() as usize).max(4 * self.num_classes);
        spec.num_test = ((self.num_test as f64 * scale).round() as usize).max(2 * self.num_classes);
        let s_uni = ((self.s_uniform as f64 * scale).round() as usize)
            .clamp(self.num_classes + 2, spec.num_train);
        let s_dpp = ((self.s_dpp as f64 * scale).round() as usize)
            .clamp(self.num_classes + 1, s_uni);
        (spec.generate(seed), s_uni, s_dpp)
    }
}

/// Tiny FNV-style string hash for per-dataset seed derivation.
fn fxhash(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn specs_cover_eight_datasets() {
        assert_eq!(TU_SPECS.len(), 8);
        assert!(spec_by_name("mutag").is_some());
        assert!(spec_by_name("Mutagenicity").is_some());
        assert!(spec_by_name("nope").is_none());
    }

    #[test]
    fn landmark_budgets_valid() {
        for spec in &TU_SPECS {
            assert!(spec.s_uniform <= spec.num_train, "{}", spec.name);
            assert!(spec.s_dpp < spec.s_uniform, "{}", spec.name);
            assert!(spec.s_dpp > 0);
        }
    }

    #[test]
    fn generated_stats_match_table4() {
        // Use the two smallest datasets for speed; check node/edge averages
        // within 20% of Table 4 and exact counts.
        for name in ["MUTAG", "BZR"] {
            let spec = spec_by_name(name).unwrap();
            let ds = spec.generate(7);
            let st = ds.stats();
            assert_eq!(st.num_train, spec.num_train);
            assert_eq!(st.num_test, spec.num_test);
            assert!(
                (st.avg_nodes - spec.avg_nodes).abs() / spec.avg_nodes < 0.2,
                "{name}: avg_nodes {} vs {}",
                st.avg_nodes,
                spec.avg_nodes
            );
            assert!(
                (st.avg_edges - spec.avg_edges).abs() / spec.avg_edges < 0.25,
                "{name}: avg_edges {} vs {}",
                st.avg_edges,
                spec.avg_edges
            );
            assert_eq!(st.num_classes, spec.num_classes);
            assert_eq!(st.feature_dim, spec.num_labels);
        }
    }

    #[test]
    fn deterministic_generation() {
        let spec = spec_by_name("MUTAG").unwrap();
        let a = spec.generate(3);
        let b = spec.generate(3);
        assert_eq!(a.train[0].1, b.train[0].1);
        assert_eq!(a.train[0].0.adj, b.train[0].0.adj);
        let c = spec.generate(4);
        // Different seed ⇒ (almost surely) different first graph.
        assert!(a.train[0].0.adj != c.train[0].0.adj || a.train[1].0.adj != c.train[1].0.adj);
    }

    #[test]
    fn all_classes_present() {
        let spec = spec_by_name("ENZYMES").unwrap();
        let (ds, _, _) = spec.generate_scaled(11, 0.25);
        let mut seen = vec![false; ds.num_classes];
        for (_, y) in ds.train.iter().chain(ds.test.iter()) {
            seen[*y] = true;
        }
        assert!(seen.iter().all(|&s| s), "scaled ENZYMES missing a class");
    }

    #[test]
    fn label_distribution_differs_between_classes() {
        let spec = spec_by_name("NCI1").unwrap();
        let mut rng = Xoshiro256::seed_from_u64(5);
        let hist = |class: usize, rng: &mut Xoshiro256| -> Vec<f64> {
            let mut h = vec![0.0; spec.num_labels];
            for _ in 0..40 {
                let g = spec.sample_graph(class, rng);
                for i in 0..g.num_nodes() {
                    for l in 0..spec.num_labels {
                        h[l] += g.features[(i, l)];
                    }
                }
            }
            let total: f64 = h.iter().sum();
            h.iter().map(|x| x / total).collect()
        };
        let h0 = hist(0, &mut rng);
        let h1 = hist(1, &mut rng);
        let l1: f64 = h0.iter().zip(&h1).map(|(a, b)| (a - b).abs()).sum();
        assert!(l1 > 0.05, "classes indistinguishable by labels: l1={l1}");
    }
}
