//! Random graph generators used by the synthetic TUDataset suite and by
//! property tests: connected tree-plus-random-edges graphs (matches the
//! node/edge statistics of small molecule/protein graphs), Erdős–Rényi,
//! and preferential attachment.

use super::Graph;
use crate::util::rng::Xoshiro256;

/// A connected random graph: uniform spanning tree (n-1 edges) plus
/// `extra` random non-duplicate edges. Two structural knobs drive the
/// class-conditional generators: `triangle_bias` closes wedges
/// (clustering), `hub_bias` attaches extras degree-proportionally
/// (hub formation — the signal PageRank-based GraphHD is sharpest at).
pub fn tree_plus_random_hub(
    n: usize,
    extra: usize,
    triangle_bias: f64,
    hub_bias: f64,
    rng: &mut Xoshiro256,
) -> Vec<(usize, usize)> {
    assert!(n >= 1);
    let mut edges: Vec<(usize, usize)> = Vec::with_capacity(n - 1 + extra);
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut exists = std::collections::HashSet::new();
    let push = |edges: &mut Vec<(usize, usize)>,
                    adj: &mut Vec<Vec<usize>>,
                    exists: &mut std::collections::HashSet<(usize, usize)>,
                    u: usize,
                    v: usize|
     -> bool {
        if u == v {
            return false;
        }
        let key = (u.min(v), u.max(v));
        if !exists.insert(key) {
            return false;
        }
        edges.push(key);
        adj[u].push(v);
        adj[v].push(u);
        true
    };

    // Random attachment tree: node i attaches to a uniform previous node.
    for i in 1..n {
        let j = rng.gen_range(i);
        push(&mut edges, &mut adj, &mut exists, i, j);
    }

    // Degree-proportional endpoint sampling for hub formation.
    let mut endpoints: Vec<usize> = Vec::new();
    for &(u, v) in &edges {
        endpoints.push(u);
        endpoints.push(v);
    }

    let mut added = 0usize;
    let mut attempts = 0usize;
    while added < extra && attempts < extra * 20 + 100 {
        attempts += 1;
        let (u, v) = if rng.bernoulli(hub_bias) && !endpoints.is_empty() {
            // Hub attachment: one endpoint degree-proportional, the other
            // uniform.
            (endpoints[rng.gen_range(endpoints.len())], rng.gen_range(n))
        } else if rng.bernoulli(triangle_bias) && n >= 3 {
            // Close a wedge: pick a node with >= 2 neighbors, join two of
            // its neighbors.
            let c = rng.gen_range(n);
            if adj[c].len() < 2 {
                continue;
            }
            let a = adj[c][rng.gen_range(adj[c].len())];
            let b = adj[c][rng.gen_range(adj[c].len())];
            (a, b)
        } else {
            (rng.gen_range(n), rng.gen_range(n))
        };
        if push(&mut edges, &mut adj, &mut exists, u, v) {
            endpoints.push(u);
            endpoints.push(v);
            added += 1;
        }
    }
    edges
}

/// Back-compat wrapper without hub bias.
pub fn tree_plus_random(
    n: usize,
    extra: usize,
    triangle_bias: f64,
    rng: &mut Xoshiro256,
) -> Vec<(usize, usize)> {
    tree_plus_random_hub(n, extra, triangle_bias, 0.0, rng)
}

/// Erdős–Rényi G(n, p).
pub fn erdos_renyi(n: usize, p: f64, rng: &mut Xoshiro256) -> Vec<(usize, usize)> {
    let mut edges = Vec::new();
    for u in 0..n {
        for v in (u + 1)..n {
            if rng.bernoulli(p) {
                edges.push((u, v));
            }
        }
    }
    edges
}

/// Barabási–Albert preferential attachment with `m` edges per new node.
pub fn preferential_attachment(n: usize, m: usize, rng: &mut Xoshiro256) -> Vec<(usize, usize)> {
    assert!(m >= 1 && n > m);
    let mut edges = Vec::new();
    // Repeated-endpoint list implements degree-proportional sampling.
    let mut endpoints: Vec<usize> = Vec::new();
    // Seed clique of m+1 nodes.
    for u in 0..=m {
        for v in (u + 1)..=m {
            edges.push((u, v));
            endpoints.push(u);
            endpoints.push(v);
        }
    }
    for new in (m + 1)..n {
        let mut targets = std::collections::HashSet::new();
        while targets.len() < m {
            let t = endpoints[rng.gen_range(endpoints.len())];
            if t != new {
                targets.insert(t);
            }
        }
        for t in targets {
            edges.push((new, t));
            endpoints.push(new);
            endpoints.push(t);
        }
    }
    edges
}

/// Random graph with node labels drawn from class-conditional weights.
pub fn labeled_graph(
    n: usize,
    extra_edges: usize,
    triangle_bias: f64,
    label_weights: &[f64],
    rng: &mut Xoshiro256,
) -> Graph {
    let edges = tree_plus_random(n, extra_edges, triangle_bias, rng);
    let labels: Vec<usize> = (0..n).map(|_| rng.weighted_choice(label_weights)).collect();
    Graph::from_edges(n, &edges, &labels, label_weights.len())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_connected(n: usize, edges: &[(usize, usize)]) -> bool {
        let mut adj = vec![Vec::new(); n];
        for &(u, v) in edges {
            adj[u].push(v);
            adj[v].push(u);
        }
        let mut seen = vec![false; n];
        let mut stack = vec![0usize];
        seen[0] = true;
        while let Some(u) = stack.pop() {
            for &v in &adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    stack.push(v);
                }
            }
        }
        seen.into_iter().all(|s| s)
    }

    #[test]
    fn tree_plus_random_connected_and_sized() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for _ in 0..20 {
            let n = 2 + rng.gen_range(60);
            let extra = rng.gen_range(n);
            let edges = tree_plus_random(n, extra, 0.3, &mut rng);
            assert!(is_connected(n, &edges), "n={n}");
            assert!(edges.len() >= n - 1);
            assert!(edges.len() <= n - 1 + extra);
            // No duplicates or self loops.
            let set: std::collections::HashSet<_> = edges.iter().collect();
            assert_eq!(set.len(), edges.len());
            assert!(edges.iter().all(|&(u, v)| u != v));
        }
    }

    #[test]
    fn triangle_bias_raises_clustering() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let count_triangles = |n: usize, edges: &[(usize, usize)]| -> usize {
            let mut a = vec![vec![false; n]; n];
            for &(u, v) in edges {
                a[u][v] = true;
                a[v][u] = true;
            }
            let mut t = 0;
            for i in 0..n {
                for j in (i + 1)..n {
                    if !a[i][j] {
                        continue;
                    }
                    for k in (j + 1)..n {
                        if a[i][k] && a[j][k] {
                            t += 1;
                        }
                    }
                }
            }
            t
        };
        let n = 40;
        let mut tri_hi = 0usize;
        let mut tri_lo = 0usize;
        for _ in 0..10 {
            tri_hi += count_triangles(n, &tree_plus_random(n, 30, 0.9, &mut rng));
            tri_lo += count_triangles(n, &tree_plus_random(n, 30, 0.0, &mut rng));
        }
        assert!(tri_hi > tri_lo, "bias should create triangles: {tri_hi} vs {tri_lo}");
    }

    #[test]
    fn erdos_renyi_edge_count() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let n = 100;
        let p = 0.1;
        let edges = erdos_renyi(n, p, &mut rng);
        let expect = (n * (n - 1) / 2) as f64 * p;
        assert!((edges.len() as f64 - expect).abs() < expect * 0.25);
    }

    #[test]
    fn preferential_attachment_properties() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let n = 100;
        let m = 3;
        let edges = preferential_attachment(n, m, &mut rng);
        assert!(is_connected(n, &edges));
        // m*(m+1)/2 seed + (n-m-1)*m attachment edges
        assert_eq!(edges.len(), m * (m + 1) / 2 + (n - m - 1) * m);
        // Hub formation: max degree should clearly exceed m.
        let mut deg = vec![0usize; n];
        for &(u, v) in &edges {
            deg[u] += 1;
            deg[v] += 1;
        }
        assert!(*deg.iter().max().unwrap() > 2 * m);
    }

    #[test]
    fn labeled_graph_respects_alphabet() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let g = labeled_graph(30, 10, 0.2, &[0.5, 0.25, 0.25], &mut rng);
        assert_eq!(g.feature_dim(), 3);
        assert_eq!(g.num_nodes(), 30);
    }
}
