//! Graph substrate: the labeled-graph type consumed by the whole pipeline
//! plus random generators and the synthetic TUDataset suite.

pub mod generators;
pub mod tudataset;

use crate::linalg::dense::Mat;
use crate::sparse::Csr;

/// An undirected graph with one-hot node-label features, matching the
/// paper's input `(A_x ∈ {0,1}^{N×N}, F_x ∈ R^{N×f})`.
#[derive(Debug, Clone)]
pub struct Graph {
    /// Symmetric 0/1 adjacency in CSR.
    pub adj: Csr,
    /// N×f node features (one-hot node labels for TU-style datasets).
    pub features: Mat,
}

impl Graph {
    /// Build from an edge list (undirected; both directions stored) and
    /// per-node label ids in [0, f).
    pub fn from_edges(num_nodes: usize, edges: &[(usize, usize)], labels: &[usize], f: usize) -> Self {
        assert_eq!(labels.len(), num_nodes);
        let mut triplets = Vec::with_capacity(edges.len() * 2);
        for &(u, v) in edges {
            assert!(u < num_nodes && v < num_nodes, "edge out of range");
            if u == v {
                continue; // no self loops in TU graphs
            }
            triplets.push((u, v, 1.0));
            triplets.push((v, u, 1.0));
        }
        // from_triplets sums duplicates; clamp back to 0/1.
        let mut adj = Csr::from_triplets(num_nodes, num_nodes, triplets);
        for v in &mut adj.val {
            *v = 1.0;
        }
        let mut features = Mat::zeros(num_nodes, f);
        for (i, &l) in labels.iter().enumerate() {
            assert!(l < f, "label {l} out of range (f={f})");
            features[(i, l)] = 1.0;
        }
        Self { adj, features }
    }

    #[inline]
    pub fn num_nodes(&self) -> usize {
        self.adj.rows
    }

    /// Undirected edge count (nnz / 2).
    #[inline]
    pub fn num_edges(&self) -> usize {
        self.adj.nnz() / 2
    }

    #[inline]
    pub fn feature_dim(&self) -> usize {
        self.features.cols
    }

    /// Degree of node v.
    pub fn degree(&self, v: usize) -> usize {
        self.adj.row_nnz(v)
    }

    /// Bytes for the query inputs per Table 2 (dense A_x at b_A bits +
    /// dense F_x at b_F bits).
    pub fn input_bytes(&self, b_a_bits: usize, b_f_bits: usize) -> usize {
        let n = self.num_nodes();
        (n * n * b_a_bits + n * self.feature_dim() * b_f_bits) / 8
    }

    /// Structural fingerprint: a 64-bit FNV-1a hash over the graph's
    /// shape, topology (CSR row pointers + column indices) and nonzero
    /// feature entries. Equal graphs hash equal on every platform (pure
    /// integer arithmetic; floats enter via `to_bits`), which is what the
    /// sharded front end needs for consistent request routing — the same
    /// graph always lands on the same shard regardless of submit order.
    pub fn fingerprint(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        #[inline]
        fn fnv1a(h: u64, x: u64) -> u64 {
            (h ^ x).wrapping_mul(PRIME)
        }
        let mut h = fnv1a(OFFSET, self.num_nodes() as u64);
        h = fnv1a(h, self.feature_dim() as u64);
        // Offset *values* feed the hash, so the representation behind
        // RowOffsets (plain vs Elias-Fano) can never move a graph to a
        // different shard.
        for p in self.adj.offsets().iter() {
            h = fnv1a(h, p as u64);
        }
        for &c in &self.adj.col_idx {
            h = fnv1a(h, c as u64);
        }
        // One-hot features are sparse; hash (flat index, bits) of the
        // nonzeros so dimension padding with zeros still distinguishes
        // via the feature_dim fold above.
        for (i, &x) in self.features.data.iter().enumerate() {
            if x != 0.0 {
                h = fnv1a(h, i as u64);
                h = fnv1a(h, x.to_bits());
            }
        }
        h
    }
}

/// A labeled train/test split for graph classification.
#[derive(Debug, Clone)]
pub struct GraphDataset {
    pub name: String,
    pub train: Vec<(Graph, usize)>,
    pub test: Vec<(Graph, usize)>,
    pub num_classes: usize,
    pub feature_dim: usize,
}

impl GraphDataset {
    pub fn stats(&self) -> DatasetStats {
        let all = self.train.iter().chain(self.test.iter());
        let mut nodes = 0usize;
        let mut edges = 0usize;
        let mut count = 0usize;
        for (g, _) in all {
            nodes += g.num_nodes();
            edges += g.num_edges();
            count += 1;
        }
        DatasetStats {
            num_train: self.train.len(),
            num_test: self.test.len(),
            avg_nodes: nodes as f64 / count.max(1) as f64,
            avg_edges: edges as f64 / count.max(1) as f64,
            num_classes: self.num_classes,
            feature_dim: self.feature_dim,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DatasetStats {
    pub num_train: usize,
    pub num_test: usize,
    pub avg_nodes: f64,
    pub avg_edges: f64,
    pub num_classes: usize,
    pub feature_dim: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_edges_symmetric_no_self_loops() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 2), (1, 0)], &[0, 1, 1, 0], 2);
        assert_eq!(g.num_nodes(), 4);
        assert_eq!(g.num_edges(), 2); // (0,1) deduped, (2,2) dropped
        let d = g.adj.to_dense();
        for i in 0..4 {
            assert_eq!(d[(i, i)], 0.0);
            for j in 0..4 {
                assert_eq!(d[(i, j)], d[(j, i)]);
                assert!(d[(i, j)] == 0.0 || d[(i, j)] == 1.0);
            }
        }
        assert_eq!(g.degree(1), 2);
    }

    #[test]
    fn one_hot_features() {
        let g = Graph::from_edges(3, &[(0, 1)], &[2, 0, 1], 3);
        assert_eq!(g.features[(0, 2)], 1.0);
        assert_eq!(g.features[(1, 0)], 1.0);
        let row_sums: Vec<f64> = (0..3).map(|i| g.features.row(i).iter().sum()).collect();
        assert_eq!(row_sums, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn fingerprint_distinguishes_structure() {
        let g = Graph::from_edges(4, &[(0, 1), (1, 2)], &[0, 1, 1, 0], 2);
        assert_eq!(g.fingerprint(), g.clone().fingerprint(), "clone must hash equal");
        let extra_edge = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)], &[0, 1, 1, 0], 2);
        assert_ne!(g.fingerprint(), extra_edge.fingerprint());
        let relabel = Graph::from_edges(4, &[(0, 1), (1, 2)], &[1, 1, 1, 0], 2);
        assert_ne!(g.fingerprint(), relabel.fingerprint());
        // Same labels in a wider one-hot space is a different input.
        let wider = Graph::from_edges(4, &[(0, 1), (1, 2)], &[0, 1, 1, 0], 3);
        assert_ne!(g.fingerprint(), wider.fingerprint());
    }

    #[test]
    fn input_bytes_accounting() {
        let g = Graph::from_edges(10, &[(0, 1)], &vec![0; 10], 5);
        // 10*10*32 bits for A + 10*5*32 bits for F = 400+200 bytes
        assert_eq!(g.input_bytes(32, 32), 600);
    }
}
