//! The crate-wide error type: every fallible user-input boundary —
//! dataset lookup, model (de)serialization, configuration validation,
//! serving submission — reports a [`NysxError`] instead of panicking.
//!
//! Internal invariants (scratch-buffer sizing, bit-identity between the
//! packed and i8 paths, schedule-table consistency) remain `assert!`s:
//! violating them is a bug in this crate, not bad input.

use std::fmt;

use crate::coordinator::SubmitError;

/// Why an API call failed.
///
/// Constructed by [`crate::api::Pipeline`], [`crate::model::io`],
/// [`crate::coordinator::Server::try_start`], and the
/// [`crate::api::Classifier`] implementations.
#[derive(Debug)]
pub enum NysxError {
    /// A configuration value is invalid (zero hops, zero workers, a
    /// non-finite LSH width, more landmarks than training graphs, ...).
    Config(String),
    /// The requested dataset name matches no synthetic TUDataset spec.
    UnknownDataset {
        /// The name that failed to resolve.
        name: String,
        /// The names that would have resolved, for the error message.
        available: Vec<&'static str>,
    },
    /// A model artifact failed to decode: wrong magic, truncation, a
    /// corrupt length prefix, or an internal inconsistency. `offset` is
    /// the byte position in the stream where decoding stopped.
    ModelFormat {
        /// Bytes consumed from the stream before the failure.
        offset: u64,
        /// What the decoder was doing and why it gave up.
        detail: String,
    },
    /// A plain I/O failure outside the decoder (opening or creating the
    /// artifact file, writing the serialized bytes).
    Io(std::io::Error),
    /// The serving stack rejected a submission because every queue is at
    /// capacity. Retryable: drain a response and resubmit.
    Backpressure,
    /// The serving stack has shut down; resubmitting can never succeed.
    Closed,
}

impl NysxError {
    /// Shorthand for a [`NysxError::Config`] with a formatted message.
    pub fn config(msg: impl Into<String>) -> Self {
        NysxError::Config(msg.into())
    }

    /// True when retrying the same call later could succeed (currently
    /// only serving backpressure).
    pub fn is_retryable(&self) -> bool {
        matches!(self, NysxError::Backpressure)
    }
}

impl fmt::Display for NysxError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NysxError::Config(msg) => write!(f, "invalid configuration: {msg}"),
            NysxError::UnknownDataset { name, available } => write!(
                f,
                "unknown dataset {name:?} (available: {})",
                available.join(", ")
            ),
            NysxError::ModelFormat { offset, detail } => {
                write!(f, "model format error at byte {offset}: {detail}")
            }
            NysxError::Io(e) => write!(f, "i/o error: {e}"),
            NysxError::Backpressure => {
                write!(f, "serving backpressure: all queues at capacity (retryable)")
            }
            NysxError::Closed => write!(f, "serving stack is shut down"),
        }
    }
}

impl std::error::Error for NysxError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            NysxError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for NysxError {
    fn from(e: std::io::Error) -> Self {
        NysxError::Io(e)
    }
}

/// The serving submit error maps onto the API error by dropping the
/// returned graph: facade callers that want the graph back for a retry
/// loop use [`crate::coordinator::Server::submit`] directly.
impl From<SubmitError> for NysxError {
    fn from(e: SubmitError) -> Self {
        match e {
            SubmitError::Backpressure(_) => NysxError::Backpressure,
            SubmitError::Closed(_) => NysxError::Closed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_mentions_the_payload() {
        let e = NysxError::UnknownDataset {
            name: "NOPE".into(),
            available: vec!["MUTAG", "NCI1"],
        };
        let s = e.to_string();
        assert!(s.contains("NOPE") && s.contains("MUTAG"), "{s}");

        let e = NysxError::ModelFormat {
            offset: 1234,
            detail: "bad magic".into(),
        };
        let s = e.to_string();
        assert!(s.contains("1234") && s.contains("bad magic"), "{s}");
    }

    #[test]
    fn submit_error_conversion_preserves_retryability() {
        let g = crate::graph::Graph::from_edges(2, &[(0, 1)], &[0, 0], 1);
        let bp: NysxError = SubmitError::Backpressure(g.clone()).into();
        assert!(bp.is_retryable());
        let closed: NysxError = SubmitError::Closed(g).into();
        assert!(!closed.is_retryable());
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: NysxError = io.into();
        assert!(std::error::Error::source(&e).is_some());
        assert!(e.to_string().contains("gone"));
    }
}
