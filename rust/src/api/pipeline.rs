//! The typed pipeline builder and the owned handles it yields.
//!
//! [`Pipeline`] validates every piece of user input (dataset name,
//! dimensions, landmark budget vs training-set size) before any heavy
//! work; [`TrainedPipeline`] owns the trained model behind an
//! `Arc<NysHdcModel>` plus a ready packed engine, so callers get
//! `infer` / `infer_batch` / `evaluate` / `save` / `serve` without ever
//! touching the engine's borrow parameter; [`ServeHandle`] wraps the
//! running coordinator and doubles as the coordinator-backed
//! [`ServedClassifier`].

use std::collections::HashMap;
use std::path::Path;
use std::sync::Arc;

use super::error::NysxError;
use super::Classifier;
use crate::coordinator::shard::MAX_SHARDS;
use crate::coordinator::{
    MetricsSummary, Response, Server, ServerConfig, ShardedConfig, ShardedServer,
    SubmitBatchError, SubmitError,
};
use crate::exec::{self, Pool};
use crate::graph::tudataset::{spec_by_name, TuSpec, TU_SPECS};
use crate::graph::{Graph, GraphDataset};
use crate::infer::{InferenceResult, NysxEngine};
use crate::model::{io as model_io, ModelConfig, NysHdcModel};
use crate::nystrom::LandmarkStrategy;

/// Scale is consumed by `generate_scaled` as a multiplier on split
/// sizes; anything non-finite or non-positive is meaningless, and an
/// absurdly large value would saturate the split arithmetic and abort
/// on allocation — cap it like every other knob (paper scale is 1.0;
/// 100x the paper's largest dataset is already ~350k graphs).
fn check_scale(scale: f64) -> Result<(), NysxError> {
    if scale.is_finite() && scale > 0.0 && scale <= 100.0 {
        Ok(())
    } else {
        Err(NysxError::Config(format!(
            "scale must be in (0, 100], got {scale}"
        )))
    }
}

/// A loaded artifact must match the dataset the pipeline evaluates on.
fn check_dataset_match(model: &NysHdcModel, expected: &str, path: &Path) -> Result<(), NysxError> {
    if model.dataset_name.eq_ignore_ascii_case(expected) {
        Ok(())
    } else {
        Err(NysxError::Config(format!(
            "model at {} was trained on {:?}, pipeline is for {expected:?}",
            path.display(),
            model.dataset_name
        )))
    }
}

/// Builder for a training (or model-loading) run on one synthetic
/// TUDataset. Construct with [`Pipeline::for_dataset`]; finish with
/// [`Pipeline::train`] or [`Pipeline::load`].
#[derive(Debug, Clone)]
pub struct Pipeline {
    spec: &'static TuSpec,
    scale: f64,
    seed: u64,
    hv_dim: usize,
    hops: Option<usize>,
    strategy: LandmarkStrategy,
    num_landmarks: Option<usize>,
    threads: Option<usize>,
    shards: Option<usize>,
}

impl Pipeline {
    /// Start a pipeline on a named dataset. The name is the first
    /// user-input boundary: an unknown name is a typed
    /// [`NysxError::UnknownDataset`] listing what would have matched.
    pub fn for_dataset(name: &str) -> Result<Self, NysxError> {
        let spec = spec_by_name(name).ok_or_else(|| NysxError::UnknownDataset {
            name: name.to_string(),
            available: TU_SPECS.iter().map(|s| s.name).collect(),
        })?;
        Ok(Self {
            spec,
            scale: 1.0,
            seed: 42,
            hv_dim: 10_000,
            hops: None,
            strategy: LandmarkStrategy::HybridDpp { pool_factor: 2 },
            num_landmarks: None,
            threads: None,
            shards: None,
        })
    }

    /// Dataset scale factor (1.0 = paper-size splits).
    pub fn scale(mut self, scale: f64) -> Self {
        self.scale = scale;
        self
    }

    /// Master seed for dataset generation and training.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// HV dimensionality d (default: the paper's 10^4).
    pub fn hv_dim(mut self, d: usize) -> Self {
        self.hv_dim = d;
        self
    }

    /// Propagation hops H (default: the dataset spec's value).
    pub fn hops(mut self, hops: usize) -> Self {
        self.hops = Some(hops);
        self
    }

    /// Landmark selection strategy. Unless [`Pipeline::num_landmarks`]
    /// overrides it, the budget follows the strategy: `Uniform` uses the
    /// spec's NysHD budget `s_uniform`, DPP strategies the reduced
    /// `s_dpp`.
    pub fn landmarks(mut self, strategy: LandmarkStrategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Explicit landmark count s, overriding the strategy default.
    pub fn num_landmarks(mut self, s: usize) -> Self {
        self.num_landmarks = Some(s);
        self
    }

    /// Exec-pool thread count for this pipeline: training, the owned
    /// engine, and every classifier it hands out run their
    /// data-parallel kernels on a dedicated [`exec::Pool`] of `n`
    /// threads instead of the process-wide pool (`--threads` /
    /// `NYSX_THREADS`). A pure throughput knob — models, predictions
    /// and scores are bit-identical at any thread count. `n = 0` is a
    /// typed config error at `train()`/`load()` time.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n);
        self
    }

    /// Default shard count for [`TrainedPipeline::serve_sharded`]: a
    /// `ShardedConfig` whose `shards` is 0 inherits this value. Like
    /// `threads`, a pure deployment knob — classification results are
    /// bit-identical at any shard count, since every shard replicates
    /// the same model. `n = 0` (or beyond the shard cap) is a typed
    /// config error at `train()`/`load()` time.
    pub fn shards(mut self, n: usize) -> Self {
        self.shards = Some(n);
        self
    }

    /// Validate the builder's default shard count (1 when unset).
    fn resolve_shards(&self) -> Result<usize, NysxError> {
        match self.shards {
            None => Ok(1),
            Some(n) if n >= 1 && n <= MAX_SHARDS => Ok(n),
            Some(n) => Err(NysxError::Config(format!(
                "shards must be in 1..={MAX_SHARDS}, got {n}"
            ))),
        }
    }

    /// Resolve the exec pool this pipeline (and its `TrainedPipeline`)
    /// runs on, validating an explicit thread count.
    fn resolve_pool(&self) -> Result<Arc<Pool>, NysxError> {
        match self.threads {
            None => Ok(exec::global()),
            Some(n) if n >= 1 && n <= exec::MAX_THREADS => Ok(Arc::new(Pool::new(n))),
            Some(n) => Err(NysxError::Config(format!(
                "threads must be in 1..={}, got {n}",
                exec::MAX_THREADS
            ))),
        }
    }

    /// Generate the dataset and the validated [`ModelConfig`].
    fn materialize(&self) -> Result<(GraphDataset, ModelConfig), NysxError> {
        check_scale(self.scale)?;
        let (ds, s_uni, s_dpp) = self.spec.generate_scaled(self.seed, self.scale);
        let num_landmarks = self.num_landmarks.unwrap_or_else(|| match self.strategy {
            LandmarkStrategy::Uniform => s_uni,
            _ => s_dpp,
        });
        let cfg = ModelConfig {
            hops: self.hops.unwrap_or(self.spec.hops),
            hv_dim: self.hv_dim,
            num_landmarks,
            strategy: self.strategy,
            seed: self.seed,
            ..ModelConfig::default()
        };
        cfg.validate()?;
        if cfg.num_landmarks > ds.train.len() {
            return Err(NysxError::Config(format!(
                "num_landmarks = {} exceeds the {}-graph training split of {} at scale {}",
                cfg.num_landmarks,
                ds.train.len(),
                self.spec.name,
                self.scale
            )));
        }
        Ok((ds, cfg))
    }

    /// Train a model on the generated dataset.
    pub fn train(self) -> Result<TrainedPipeline, NysxError> {
        let pool = self.resolve_pool()?;
        let shards = self.resolve_shards()?;
        let (ds, cfg) = self.materialize()?;
        let model = Arc::new(crate::model::train::train_with_pool(&ds, &cfg, &pool));
        Ok(TrainedPipeline::from_parts(model, ds, pool, shards))
    }

    /// Load a model artifact instead of training. The builder's dataset
    /// spec, seed and scale still generate the split that
    /// [`TrainedPipeline::evaluate`] scores against; the artifact itself
    /// defines the model hyper-parameters (the builder's `hv_dim` /
    /// `landmarks` settings do not apply). Loading an artifact trained on
    /// a different dataset is a typed error.
    pub fn load(self, path: &Path) -> Result<TrainedPipeline, NysxError> {
        let pool = self.resolve_pool()?;
        let shards = self.resolve_shards()?;
        check_scale(self.scale)?;
        let model = model_io::load_file(path)?;
        check_dataset_match(&model, self.spec.name, path)?;
        let (ds, _, _) = self.spec.generate_scaled(self.seed, self.scale);
        Ok(TrainedPipeline::from_parts(Arc::new(model), ds, pool, shards))
    }
}

/// A trained model plus its dataset and a ready packed engine, fully
/// owned — the facade's working handle.
pub struct TrainedPipeline {
    model: Arc<NysHdcModel>,
    dataset: GraphDataset,
    engine: NysxEngine,
    /// The exec pool every engine/classifier of this pipeline runs on
    /// (dedicated when built with [`Pipeline::threads`], otherwise the
    /// process-wide pool).
    pool: Arc<Pool>,
    /// Default shard count for [`Self::serve_sharded`] (from
    /// [`Pipeline::shards`], 1 when unset).
    default_shards: usize,
}

impl TrainedPipeline {
    fn from_parts(
        model: Arc<NysHdcModel>,
        dataset: GraphDataset,
        pool: Arc<Pool>,
        default_shards: usize,
    ) -> Self {
        let engine = NysxEngine::with_pool(model.clone(), pool.clone());
        Self {
            model,
            dataset,
            engine,
            pool,
            default_shards,
        }
    }

    /// The trained model (shareable: `serve` and extra classifiers clone
    /// this `Arc`).
    pub fn model(&self) -> &Arc<NysHdcModel> {
        &self.model
    }

    /// The generated dataset this pipeline trained (or evaluates) on.
    pub fn dataset(&self) -> &GraphDataset {
        &self.dataset
    }

    /// Split borrows for loops that read the dataset while inferring:
    /// `let (ds, engine) = pipeline.parts();` hands out the dataset and
    /// the engine disjointly, so iterating `ds.test` while calling
    /// `engine.infer` borrow-checks.
    pub fn parts(&mut self) -> (&GraphDataset, &mut NysxEngine) {
        (&self.dataset, &mut self.engine)
    }

    /// Full Algorithm 1 on one graph through the owned packed engine.
    pub fn infer(&mut self, graph: &Graph) -> InferenceResult {
        self.engine.infer(graph)
    }

    /// Batched Algorithm 1 (one blocked C×W SCE dispatch per call).
    pub fn infer_batch(&mut self, graphs: &[&Graph]) -> Vec<InferenceResult> {
        self.engine.infer_batch(graphs)
    }

    /// Accuracy on the dataset's test split (`None` if it is empty).
    pub fn evaluate(&mut self) -> Option<f64> {
        // The owned engine cannot fail transport-wise; collapse Result.
        super::accuracy(&mut self.engine, &self.dataset.test).unwrap_or(None)
    }

    /// Accuracy on an arbitrary labeled split.
    pub fn evaluate_split(&mut self, split: &[(Graph, usize)]) -> Option<f64> {
        super::accuracy(&mut self.engine, split).unwrap_or(None)
    }

    /// Persist the model artifact (current v3 format: Elias–Fano
    /// codebook and row-offset sections, DESIGN.md §10). [`Pipeline::load`]
    /// reads v1, v2 and v3 artifacts alike.
    pub fn save(&self, path: &Path) -> Result<(), NysxError> {
        model_io::save_file(&self.model, path).map_err(NysxError::Io)
    }

    /// The model's resident-memory accounting (paper Table 2 terms:
    /// codebooks, histograms dense and CSR, projection, prototypes) —
    /// the per-model view behind `bench memory`'s measured artifact.
    pub fn memory_report(&self) -> crate::model::MemoryReport {
        self.model.memory_report()
    }

    /// Start the serving coordinator over this model. The workers'
    /// engines run on this pipeline's exec pool, so
    /// [`Pipeline::threads`] bounds the serving path too.
    pub fn serve(&self, cfg: ServerConfig) -> Result<ServeHandle, NysxError> {
        Ok(ServeHandle {
            server: Server::try_start_with_pool(self.model.clone(), cfg, self.pool.clone())?,
            pending: HashMap::new(),
        })
    }

    /// Start the SHARDED serving tier over this model: N independent
    /// shards behind a consistent-hash front router with per-shard
    /// admission control (see `coordinator::sharded`). A `cfg.shards` of
    /// 0 inherits the builder's [`Pipeline::shards`] default. Each shard
    /// gets its own exec pool sized like this pipeline's, so
    /// [`Pipeline::threads`] bounds the per-shard parallelism.
    pub fn serve_sharded(&self, mut cfg: ShardedConfig) -> Result<ShardedServeHandle, NysxError> {
        if cfg.shards == 0 {
            cfg.shards = self.default_shards;
        }
        if cfg.shards > MAX_SHARDS {
            return Err(NysxError::Config(format!(
                "shards must be in 1..={MAX_SHARDS}, got {}",
                cfg.shards
            )));
        }
        let threads = self.pool.threads();
        let pools = (0..cfg.shards)
            .map(|_| Arc::new(Pool::new(threads)))
            .collect();
        Ok(ShardedServeHandle {
            server: ShardedServer::try_start_with_pools(self.model.clone(), cfg, pools)?,
            pending: HashMap::new(),
        })
    }

    /// Load a saved artifact against THIS pipeline's dataset — no
    /// dataset regeneration, unlike [`Pipeline::load`]. The go-to for
    /// save/reload verification and A/B comparisons on one split.
    pub fn reload(&self, path: &Path) -> Result<TrainedPipeline, NysxError> {
        let model = model_io::load_file(path)?;
        check_dataset_match(&model, &self.dataset.name, path)?;
        Ok(TrainedPipeline::from_parts(
            Arc::new(model),
            self.dataset.clone(),
            self.pool.clone(),
            self.default_shards,
        ))
    }

    /// A fresh owned packed-engine classifier over this model (for
    /// side-by-side sweeps; the pipeline keeps its own engine). Shares
    /// this pipeline's exec pool.
    pub fn classifier(&self) -> NysxEngine {
        NysxEngine::with_pool(self.model.clone(), self.pool.clone())
    }

    /// The verbatim i8 Algorithm-1 oracle over this model.
    pub fn reference_classifier(&self) -> super::ReferenceClassifier<Arc<NysHdcModel>> {
        super::ReferenceClassifier(self.model.clone())
    }
}

/// A running serving stack. Exposes the raw submit/recv surface for
/// replay loops, and implements [`Classifier`] — a blocking
/// submit-then-await round trip per query — which makes it the
/// coordinator-backed [`ServedClassifier`] of the differential suites.
pub struct ServeHandle {
    server: Server,
    /// Responses received while waiting for a different request id
    /// (worker completion order is not submission order).
    pending: HashMap<u64, usize>,
}

/// The coordinator-backed [`Classifier`]: every `classify` call crosses
/// the real router → batch queue → worker path.
pub type ServedClassifier = ServeHandle;

impl ServeHandle {
    /// Submit a query graph (non-blocking; see
    /// [`Server::submit`] for the backpressure contract).
    // The Err hands the graph back by design; see Server::submit.
    #[allow(clippy::result_large_err)]
    pub fn submit(&mut self, graph: Graph) -> Result<u64, SubmitError> {
        self.server.submit(graph)
    }

    /// Blocking receive of one response.
    pub fn recv(&mut self) -> Option<Response> {
        self.server.recv()
    }

    /// Drain all outstanding responses.
    pub fn drain(&mut self) -> Vec<Response> {
        self.server.drain()
    }

    /// Serving metrics snapshot.
    pub fn metrics(&self) -> MetricsSummary {
        self.server.metrics.summary()
    }

    /// Drain, close the queues and join the workers.
    pub fn shutdown(self) -> Vec<Response> {
        self.server.shutdown()
    }

    /// Submit, absorbing backpressure by receiving (and buffering)
    /// responses until a slot frees up.
    fn submit_blocking(&mut self, mut graph: Graph) -> Result<u64, NysxError> {
        loop {
            match self.server.submit(graph) {
                Ok(id) => return Ok(id),
                Err(SubmitError::Backpressure(g)) => {
                    graph = g;
                    self.absorb_backpressure()?;
                }
                Err(SubmitError::Closed(_)) => return Err(NysxError::Closed),
            }
        }
    }

    /// Submit a whole chunk as ONE batch-major unit
    /// ([`Server::submit_batch`]), absorbing backpressure like
    /// [`Self::submit_blocking`].
    fn submit_batch_blocking(&mut self, mut graphs: Vec<Graph>) -> Result<Vec<u64>, NysxError> {
        loop {
            match self.server.submit_batch(graphs) {
                Ok(ids) => return Ok(ids),
                Err(SubmitBatchError::Backpressure(gs)) => {
                    graphs = gs;
                    self.absorb_backpressure()?;
                }
                Err(SubmitBatchError::Closed(_)) => return Err(NysxError::Closed),
            }
        }
    }

    /// Free queue space by receiving (and buffering) one response.
    fn absorb_backpressure(&mut self) -> Result<(), NysxError> {
        match self.server.recv() {
            Some(resp) => {
                self.pending.insert(resp.id, resp.predicted);
                Ok(())
            }
            // Nothing outstanding to drain yet the queues are full:
            // retrying can never succeed, so this must NOT be the
            // retryable Backpressure error.
            None => Err(NysxError::config(
                "serving queues are full with zero responses outstanding — \
                 queue capacity too small to make progress",
            )),
        }
    }

    /// Wait for a specific request id, buffering other responses.
    fn await_response(&mut self, id: u64) -> Result<usize, NysxError> {
        loop {
            if let Some(predicted) = self.pending.remove(&id) {
                return Ok(predicted);
            }
            match self.server.recv() {
                Some(resp) => {
                    self.pending.insert(resp.id, resp.predicted);
                }
                None => return Err(NysxError::Closed),
            }
        }
    }
}

impl Classifier for ServeHandle {
    fn name(&self) -> &'static str {
        "nysx-served"
    }

    fn classify(&mut self, graph: &Graph) -> Result<usize, NysxError> {
        let id = self.submit_blocking(graph.clone())?;
        self.await_response(id)
    }

    /// Batch-major end to end: the queries are chunked to the server's
    /// configured `batch_size` and each chunk is submitted as ONE
    /// atomic group to a single worker queue ([`Server::submit_batch`]),
    /// so the worker pops it whole and runs one blocked C×W SCE dispatch
    /// per chunk — instead of scattering the batch one request at a
    /// time across workers and hoping the batcher reassembles it.
    fn classify_batch(&mut self, graphs: &[&Graph]) -> Result<Vec<usize>, NysxError> {
        // Chunk to the dispatch width, but never beyond the queue
        // capacity — a chunk larger than the queue could NEVER enqueue
        // atomically, turning every batched call into a dead loop while
        // single submits still worked.
        let chunk = self
            .server
            .batch_size()
            .max(1)
            .min(self.server.queue_capacity().max(1));
        let mut ids = Vec::with_capacity(graphs.len());
        for group in graphs.chunks(chunk) {
            let owned: Vec<Graph> = group.iter().map(|g| (*g).clone()).collect();
            ids.extend(self.submit_batch_blocking(owned)?);
        }
        ids.into_iter().map(|id| self.await_response(id)).collect()
    }
}

/// A running sharded serving tier ([`TrainedPipeline::serve_sharded`]).
/// Mirrors [`ServeHandle`]'s surface — raw submit/recv for replay loops
/// plus a blocking [`Classifier`] impl — and adds the shard-level
/// controls: [`Self::stop_shard`] for fault injection / topology
/// changes and per-shard metrics.
pub struct ShardedServeHandle {
    server: ShardedServer,
    /// Responses received while waiting for a different request id.
    pending: HashMap<u64, usize>,
}

impl ShardedServeHandle {
    /// Submit a query graph through the consistent-hash front router
    /// (non-blocking; see [`ShardedServer::submit`] for the
    /// backpressure / reroute contract).
    // The Err hands the graph back by design; see Server::submit.
    #[allow(clippy::result_large_err)]
    pub fn submit(&mut self, graph: Graph) -> Result<u64, SubmitError> {
        self.server.submit(graph)
    }

    /// Blocking receive of one response from any shard.
    pub fn recv(&mut self) -> Option<Response> {
        self.server.recv()
    }

    /// Non-blocking receive (open-loop load generators poll this).
    pub fn try_recv(&mut self) -> Option<Response> {
        self.server.try_recv()
    }

    /// Drain all outstanding responses.
    pub fn drain(&mut self) -> Vec<Response> {
        self.server.drain()
    }

    /// Total shard slots (including stopped ones).
    pub fn num_shards(&self) -> usize {
        self.server.num_shards()
    }

    /// Shards still accepting work.
    pub fn live_shards(&self) -> usize {
        self.server.live_shards()
    }

    /// Tear down one shard mid-load (fault injection): queued work still
    /// completes and subsequent submits reroute consistently.
    pub fn stop_shard(&mut self, shard: usize) {
        self.server.stop_shard(shard)
    }

    /// Metrics snapshot for one shard (valid even after `stop_shard`).
    pub fn shard_metrics(&self, shard: usize) -> MetricsSummary {
        self.server.shard_metrics(shard).summary()
    }

    /// Graceful drain-then-stop across every live shard; zero loss.
    pub fn shutdown(self) -> Vec<Response> {
        self.server.shutdown()
    }

    /// Submit, absorbing backpressure (admission cap or queue-full) by
    /// receiving and buffering responses until a slot frees up.
    fn submit_blocking(&mut self, mut graph: Graph) -> Result<u64, NysxError> {
        loop {
            match self.server.submit(graph) {
                Ok(id) => return Ok(id),
                Err(SubmitError::Backpressure(g)) => {
                    graph = g;
                    self.absorb_backpressure()?;
                }
                Err(SubmitError::Closed(_)) => return Err(NysxError::Closed),
            }
        }
    }

    /// Submit a whole chunk as one batch-major unit, absorbing
    /// backpressure like [`Self::submit_blocking`].
    fn submit_batch_blocking(&mut self, mut graphs: Vec<Graph>) -> Result<Vec<u64>, NysxError> {
        loop {
            match self.server.submit_batch(graphs) {
                Ok(ids) => return Ok(ids),
                Err(SubmitBatchError::Backpressure(gs)) => {
                    graphs = gs;
                    self.absorb_backpressure()?;
                }
                Err(SubmitBatchError::Closed(_)) => return Err(NysxError::Closed),
            }
        }
    }

    /// Free an admission/queue slot by receiving one response.
    fn absorb_backpressure(&mut self) -> Result<(), NysxError> {
        match self.server.recv() {
            Some(resp) => {
                self.pending.insert(resp.id, resp.predicted);
                Ok(())
            }
            // Backpressure with zero responses outstanding: no retry can
            // ever succeed — a dead configuration, not a transient.
            None => Err(NysxError::config(
                "sharded tier backpressured with zero responses outstanding — \
                 admission cap or queue capacity too small to make progress",
            )),
        }
    }

    /// Wait for a specific request id, buffering other responses.
    fn await_response(&mut self, id: u64) -> Result<usize, NysxError> {
        loop {
            if let Some(predicted) = self.pending.remove(&id) {
                return Ok(predicted);
            }
            match self.server.recv() {
                Some(resp) => {
                    self.pending.insert(resp.id, resp.predicted);
                }
                None => return Err(NysxError::Closed),
            }
        }
    }
}

impl Classifier for ShardedServeHandle {
    fn name(&self) -> &'static str {
        "nysx-sharded"
    }

    fn classify(&mut self, graph: &Graph) -> Result<usize, NysxError> {
        let id = self.submit_blocking(graph.clone())?;
        self.await_response(id)
    }

    /// Batch-major through the front router: chunks are clamped to the
    /// dispatch width AND to both progress ceilings — queue capacity and
    /// the per-shard admission cap — so every atomic group can
    /// eventually be admitted (a chunk above either ceiling would
    /// dead-loop, like the capacity case on [`ServeHandle`]).
    fn classify_batch(&mut self, graphs: &[&Graph]) -> Result<Vec<usize>, NysxError> {
        let chunk = self
            .server
            .batch_size()
            .max(1)
            .min(self.server.queue_capacity().max(1))
            .min(self.server.max_outstanding());
        let mut ids = Vec::with_capacity(graphs.len());
        for group in graphs.chunks(chunk) {
            let owned: Vec<Graph> = group.iter().map(|g| (*g).clone()).collect();
            ids.extend(self.submit_batch_blocking(owned)?);
        }
        ids.into_iter().map(|id| self.await_response(id)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::BatcherConfig;

    fn small_pipeline() -> Pipeline {
        Pipeline::for_dataset("MUTAG")
            .expect("MUTAG exists")
            .scale(0.2)
            // Shallower than the MUTAG spec's H=6 and off a 64 boundary:
            // fast tests with the packed tail word live.
            .hops(3)
            .hv_dim(500)
            .seed(11)
    }

    #[test]
    fn unknown_dataset_is_typed_and_lists_alternatives() {
        match Pipeline::for_dataset("NOT_A_DATASET") {
            Err(NysxError::UnknownDataset { name, available }) => {
                assert_eq!(name, "NOT_A_DATASET");
                assert!(available.contains(&"MUTAG"));
            }
            other => panic!("want UnknownDataset, got {other:?}"),
        }
        // Case-insensitive resolution still works.
        assert!(Pipeline::for_dataset("mutag").is_ok());
    }

    #[test]
    fn invalid_builder_inputs_are_config_errors() {
        for (what, result) in [
            ("hv_dim 0", small_pipeline().hv_dim(0).train()),
            ("hops 0", small_pipeline().hops(0).train()),
            ("scale NaN", small_pipeline().scale(f64::NAN).train()),
            ("scale -1", small_pipeline().scale(-1.0).train()),
            ("scale 1e30", small_pipeline().scale(1e30).train()),
            (
                "s > train split",
                small_pipeline().num_landmarks(1_000_000).train(),
            ),
            ("threads 0", small_pipeline().threads(0).train()),
            (
                "threads absurd",
                small_pipeline().threads(1_000_000).train(),
            ),
        ] {
            match result {
                Err(NysxError::Config(_)) => {}
                Ok(_) => panic!("{what}: invalid input trained anyway"),
                Err(other) => panic!("{what}: want Config, got {other:?}"),
            }
        }
    }

    #[test]
    fn train_evaluate_infer_roundtrip() {
        let mut p = small_pipeline().train().expect("small training run");
        let acc = p.evaluate().expect("test split is non-empty");
        let chance = 1.0 / p.dataset().num_classes as f64;
        assert!(acc > chance, "facade accuracy {acc} at or below chance");
        assert_eq!(
            Some(acc),
            crate::model::train::evaluate(p.model(), &p.dataset().test),
            "facade evaluate != model::train::evaluate"
        );
        // infer / infer_batch agree with a fresh classifier; parts()
        // splits the borrows so the loop reads the dataset while the
        // engine infers.
        let mut fresh = p.classifier();
        let (ds, engine) = p.parts();
        let graphs: Vec<&Graph> = ds.test.iter().map(|(g, _)| g).collect();
        let batched: Vec<usize> = engine
            .infer_batch(&graphs)
            .iter()
            .map(|r| r.predicted)
            .collect();
        for (g, want) in graphs.iter().zip(&batched) {
            assert_eq!(engine.infer(g).predicted, *want);
            assert_eq!(fresh.classify(g).expect("in-process"), *want);
        }
        assert_eq!(p.evaluate_split(&[]), None);
    }

    /// The facade-level exec pin: pipelines built at different thread
    /// counts train bit-identical models and classify identically — the
    /// `threads` knob is pure throughput.
    #[test]
    fn threads_knob_never_changes_results() {
        let mut one = small_pipeline().threads(1).train().expect("train @1");
        let mut four = small_pipeline().threads(4).train().expect("train @4");
        assert_eq!(
            one.model().packed_prototypes, four.model().packed_prototypes,
            "prototypes depend on thread count"
        );
        assert_eq!(
            one.model().projection.data, four.model().projection.data,
            "P_nys depends on thread count"
        );
        assert_eq!(one.evaluate(), four.evaluate(), "accuracy drift");
        let test: Vec<Graph> = four.dataset().test.iter().map(|(g, _)| g.clone()).collect();
        let graphs: Vec<&Graph> = test.iter().collect();
        let want: Vec<usize> = one.infer_batch(&graphs).iter().map(|r| r.predicted).collect();
        let got: Vec<usize> = four.infer_batch(&graphs).iter().map(|r| r.predicted).collect();
        assert_eq!(got, want, "batched predictions depend on thread count");
    }

    #[test]
    fn save_then_load_preserves_predictions() {
        let dir = std::env::temp_dir().join(format!("nysx-api-{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("temp dir");
        let path = dir.join("facade.nysx");
        let mut trained = small_pipeline().train().expect("train");
        trained.save(&path).expect("save");
        let mut loaded = small_pipeline().load(&path).expect("load");
        let (ds, engine) = trained.parts();
        for (g, _) in ds.test.iter().take(8) {
            assert_eq!(engine.infer(g).hv, loaded.infer(g).hv, "roundtrip drift");
        }
        // reload() (dataset reuse, no regeneration) agrees with load().
        let mut reloaded = trained.reload(&path).expect("reload");
        let (lds, lengine) = loaded.parts();
        for (g, _) in lds.test.iter().take(4) {
            assert_eq!(lengine.infer(g).hv, reloaded.infer(g).hv, "reload != load");
        }
        // Loading under the wrong dataset spec is a typed error.
        match Pipeline::for_dataset("NCI1").expect("NCI1 exists").load(&path) {
            Err(NysxError::Config(msg)) => {
                assert!(msg.contains("MUTAG"), "{msg}");
            }
            other => panic!("want Config, got {other:?}"),
        }
        // A missing file is Io, not ModelFormat.
        match small_pipeline().load(&dir.join("absent.nysx")) {
            Err(NysxError::Io(_)) => {}
            other => panic!("want Io, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// The serving-level packed-vs-i8 equivalence, driven through the
    /// [`Classifier`] trait on every side: the coordinator-backed
    /// classifier must agree with the in-process packed engine and the
    /// i8 oracle on every test graph, including through the batched
    /// dispatch path.
    #[test]
    fn served_classifier_matches_in_process_backends() {
        let p = small_pipeline().train().expect("train");
        let graphs: Vec<&Graph> = p.dataset.test.iter().map(|(g, _)| g).collect();
        let mut engine = p.classifier();
        let mut oracle = p.reference_classifier();
        let want = engine.classify_batch(&graphs).expect("in-process");
        assert_eq!(
            want,
            oracle.classify_batch(&graphs).expect("in-process"),
            "packed engine != i8 oracle"
        );

        let mut served = p
            .serve(ServerConfig {
                workers: 3,
                batcher: BatcherConfig {
                    batch_size: 3,
                    max_wait: std::time::Duration::from_millis(2),
                    ..Default::default()
                },
                ..Default::default()
            })
            .expect("serve");
        let got = served.classify_batch(&graphs).expect("serving transport");
        assert_eq!(got, want, "served predictions diverge from the engine");
        // Single-query round trips too.
        for (g, want) in graphs.iter().take(5).zip(&want) {
            assert_eq!(served.classify(g).expect("serving transport"), *want);
        }
        served.shutdown();
    }

    /// Regression (chunking vs capacity): a dispatch width larger than
    /// the queue capacity must not dead-loop batched classification —
    /// chunks are clamped to the capacity so every atomic group can
    /// enqueue, and predictions still match the in-process engine.
    #[test]
    fn classify_batch_survives_batch_size_beyond_capacity() {
        let p = small_pipeline().train().expect("train");
        let graphs: Vec<&Graph> = p.dataset.test.iter().take(6).map(|(g, _)| g).collect();
        let mut engine = p.classifier();
        let want = engine.classify_batch(&graphs).expect("in-process");
        let mut served = p
            .serve(ServerConfig {
                workers: 1,
                batcher: BatcherConfig {
                    batch_size: 64, // far beyond...
                    capacity: 2,    // ...the queue capacity
                    max_wait: std::time::Duration::from_millis(1),
                },
                ..Default::default()
            })
            .expect("serve");
        let got = served
            .classify_batch(&graphs)
            .expect("chunked batches must make progress");
        assert_eq!(got, want, "capacity-clamped chunks changed predictions");
        served.shutdown();
    }

    /// The sharded tier through the facade: `serve_sharded` inherits the
    /// builder's shard default, classifies bit-identically to the
    /// in-process engine through the consistent-hash front router, and
    /// invalid shard counts are typed config errors.
    #[test]
    fn sharded_served_classifier_matches_in_process() {
        let p = small_pipeline()
            .threads(1)
            .shards(2)
            .train()
            .expect("train");
        let graphs: Vec<&Graph> = p.dataset.test.iter().map(|(g, _)| g).collect();
        let mut engine = p.classifier();
        let want = engine.classify_batch(&graphs).expect("in-process");

        // shards: 0 inherits the builder's default (2).
        let mut sharded = p
            .serve_sharded(ShardedConfig {
                shards: 0,
                per_shard: ServerConfig {
                    workers: 2,
                    batcher: BatcherConfig {
                        batch_size: 3,
                        max_wait: std::time::Duration::from_millis(2),
                        ..Default::default()
                    },
                    ..Default::default()
                },
                ..Default::default()
            })
            .expect("serve_sharded");
        assert_eq!(sharded.num_shards(), 2, "shards: 0 must inherit the builder default");
        assert_eq!(sharded.live_shards(), 2);
        let got = sharded.classify_batch(&graphs).expect("sharded transport");
        assert_eq!(got, want, "sharded predictions diverge from the engine");
        for (g, want) in graphs.iter().take(5).zip(&want) {
            assert_eq!(sharded.classify(g).expect("sharded transport"), *want);
        }
        for shard in 0..2 {
            assert!(
                sharded.shard_metrics(shard).requests > 0,
                "shard {shard} served nothing — front router not spreading"
            );
        }
        sharded.shutdown();

        // Builder-level validation: shards(0) is a typed config error.
        match small_pipeline().shards(0).train() {
            Err(NysxError::Config(_)) => {}
            other => panic!(
                "want Config for zero shards, got {:?}",
                other.err().map(|e| e.to_string())
            ),
        }
    }

    /// A tiny per-shard admission cap must not dead-loop batched
    /// classification through the sharded handle — chunks clamp to the
    /// cap as well as the queue capacity.
    #[test]
    fn sharded_classify_batch_survives_tiny_admission_cap() {
        let p = small_pipeline().threads(1).train().expect("train");
        let graphs: Vec<&Graph> = p.dataset.test.iter().take(6).map(|(g, _)| g).collect();
        let mut engine = p.classifier();
        let want = engine.classify_batch(&graphs).expect("in-process");
        let mut sharded = p
            .serve_sharded(ShardedConfig {
                shards: 2,
                max_outstanding: 1, // far below the dispatch width
                per_shard: ServerConfig {
                    workers: 1,
                    batcher: BatcherConfig {
                        batch_size: 64,
                        capacity: 2,
                        max_wait: std::time::Duration::from_millis(1),
                    },
                    ..Default::default()
                },
            })
            .expect("serve_sharded");
        let got = sharded
            .classify_batch(&graphs)
            .expect("cap-clamped chunks must make progress");
        assert_eq!(got, want, "cap-clamped chunks changed predictions");
        sharded.shutdown();
    }

    /// Serving errors surface as typed `NysxError`s through the trait.
    #[test]
    fn served_classifier_errors_are_typed() {
        let p = small_pipeline().train().expect("train");
        match p.serve(ServerConfig {
            workers: 0,
            ..Default::default()
        }) {
            Err(NysxError::Config(_)) => {}
            other => panic!(
                "want Config for zero workers, got {:?}",
                other.err().map(|e| e.to_string())
            ),
        }
    }
}
