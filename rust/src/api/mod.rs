//! The crate's front door: typed errors, the pipeline builder, and the
//! backend-agnostic [`Classifier`] trait.
//!
//! Everything a consumer needs sits behind three names:
//!
//! * [`NysxError`] — the crate-wide error type. Every user-input boundary
//!   (dataset lookup, model files, configuration, serving submission)
//!   returns it instead of panicking.
//! * [`Pipeline`] / [`TrainedPipeline`] — the builder chain
//!   `Pipeline::for_dataset("MUTAG")?.hv_dim(10_000).seed(42).train()?`
//!   yielding an owned handle with `infer`, `infer_batch`, `evaluate`,
//!   `save`, and `serve` — no `'m` borrow to juggle. `.threads(n)` pins
//!   the pipeline to a dedicated [`crate::exec`] pool (default: the
//!   process-wide pool, sized by `--threads` / `NYSX_THREADS`); thread
//!   count is pure throughput — results are bit-identical at any value.
//!   `.shards(n)` sets the default width for
//!   [`TrainedPipeline::serve_sharded`], the multi-shard serving tier
//!   behind a consistent-hash front router ([`ShardedServeHandle`]) —
//!   like threads, shard count never changes classifications.
//! * [`Classifier`] — one interface over every backend: the packed
//!   [`NysxEngine`], the verbatim i8 Algorithm-1 oracle
//!   ([`ReferenceClassifier`]), the GraphHD / NysHD baselines, and the
//!   coordinator-backed [`ServedClassifier`]. The paper's Fig. 7 / Table
//!   4 comparisons (and this repo's bench tables and differential suite)
//!   drive all of them through this trait, so every number in a
//!   head-to-head table comes from the same dispatch path.
//!
//! ```no_run
//! use nysx::api::{Classifier, Pipeline};
//! use nysx::nystrom::LandmarkStrategy;
//!
//! # fn main() -> Result<(), nysx::api::NysxError> {
//! let mut pipeline = Pipeline::for_dataset("MUTAG")?
//!     .hv_dim(10_000)
//!     .landmarks(LandmarkStrategy::HybridDpp { pool_factor: 2 })
//!     .seed(42)
//!     .train()?;
//! let accuracy = pipeline.evaluate();
//! let mut serving = pipeline.serve(Default::default())?;
//! let (graph, _) = &pipeline.dataset().test[0];
//! let predicted = serving.classify(graph)?;
//! # let _ = (accuracy, predicted);
//! # Ok(())
//! # }
//! ```

pub mod error;
pub mod pipeline;

pub use error::NysxError;
pub use pipeline::{
    Pipeline, ServeHandle, ServedClassifier, ShardedServeHandle, TrainedPipeline,
};

use std::borrow::Borrow;

use crate::baselines::GraphHdModel;
use crate::graph::Graph;
use crate::infer::{infer_reference, NysxEngine};
use crate::model::NysHdcModel;

/// A graph classification backend.
///
/// `&mut self` because most backends keep reusable scratch (the packed
/// engine) or per-call state (the serving round trip); stateless
/// backends simply ignore the mutability. Errors only arise from
/// backends with a fallible transport (serving); in-process backends
/// always return `Ok`.
pub trait Classifier {
    /// Short stable name for report rows ("nysx", "graphhd", ...).
    fn name(&self) -> &'static str;

    /// Classify one graph.
    fn classify(&mut self, graph: &Graph) -> Result<usize, NysxError>;

    /// Classify a batch. Backends with a real batch path (blocked C×W
    /// matching, batched serving dispatch) override this; the default
    /// loops over [`Classifier::classify`].
    fn classify_batch(&mut self, graphs: &[&Graph]) -> Result<Vec<usize>, NysxError> {
        graphs.iter().map(|g| self.classify(g)).collect()
    }
}

/// Forward through mutable references so call sites can build
/// `[&mut dyn Classifier]` sweeps over backends they still own.
impl<C: Classifier + ?Sized> Classifier for &mut C {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn classify(&mut self, graph: &Graph) -> Result<usize, NysxError> {
        (**self).classify(graph)
    }

    fn classify_batch(&mut self, graphs: &[&Graph]) -> Result<Vec<usize>, NysxError> {
        (**self).classify_batch(graphs)
    }
}

/// The optimized packed engine is the production classifier: single
/// queries ride the fused project-bipolarize-pack + popcount SCE,
/// batches the blocked C×W matcher.
impl<M: Borrow<NysHdcModel>> Classifier for NysxEngine<M> {
    fn name(&self) -> &'static str {
        "nysx"
    }

    fn classify(&mut self, graph: &Graph) -> Result<usize, NysxError> {
        Ok(self.infer(graph).predicted)
    }

    fn classify_batch(&mut self, graphs: &[&Graph]) -> Result<Vec<usize>, NysxError> {
        Ok(self
            .infer_batch(graphs)
            .into_iter()
            .map(|r| r.predicted)
            .collect())
    }
}

/// The verbatim i8 Algorithm-1 oracle behind the [`Classifier`]
/// interface, so differential suites can drive "reference vs optimized"
/// through one dispatch path.
pub struct ReferenceClassifier<M: Borrow<NysHdcModel>>(pub M);

impl<M: Borrow<NysHdcModel>> Classifier for ReferenceClassifier<M> {
    fn name(&self) -> &'static str {
        "nysx-i8-reference"
    }

    fn classify(&mut self, graph: &Graph) -> Result<usize, NysxError> {
        Ok(infer_reference(self.0.borrow(), graph).0)
    }
}

/// The topology-only GraphHD baseline (packed encode + popcount match).
impl Classifier for GraphHdModel {
    fn name(&self) -> &'static str {
        "graphhd"
    }

    fn classify(&mut self, graph: &Graph) -> Result<usize, NysxError> {
        Ok(GraphHdModel::classify(self, graph))
    }
}

/// Prometheus text-exposition rendering of the process-wide
/// observability registry (`nysx::obs`): every counter, gauge, stage
/// histogram, and exec-lane site, in one deterministic snapshot. The
/// facade entry point for scrape endpoints and the `nysx profile
/// --prom-out` writer. Meaningful numbers require obs to be on
/// (`nysx::obs::set_enabled(true)` or `NYSX_OBS` for the CLI) — with it
/// off the catalog renders with zero values.
pub fn snapshot_prometheus() -> String {
    crate::obs::Snapshot::capture().prometheus()
}

/// Accuracy of any [`Classifier`] over a labeled split, batched through
/// [`Classifier::classify_batch`]. `Ok(None)` on an empty split;
/// transport errors (serving backends) propagate.
pub fn accuracy(
    classifier: &mut dyn Classifier,
    split: &[(Graph, usize)],
) -> Result<Option<f64>, NysxError> {
    if split.is_empty() {
        return Ok(None);
    }
    const BATCH: usize = 64;
    let mut correct = 0usize;
    for chunk in split.chunks(BATCH) {
        let graphs: Vec<&Graph> = chunk.iter().map(|(g, _)| g).collect();
        let preds = classifier.classify_batch(&graphs)?;
        correct += preds
            .iter()
            .zip(chunk)
            .filter(|(p, (_, y))| **p == *y)
            .count();
    }
    Ok(Some(correct as f64 / split.len() as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::train_graphhd;
    use crate::graph::tudataset::spec_by_name;
    use crate::model::train::train;
    use crate::model::ModelConfig;

    fn trained() -> (crate::graph::GraphDataset, NysHdcModel) {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(91, 0.25);
        let cfg = ModelConfig {
            hops: 3,
            // Off a 64 boundary: tail words live through the trait too.
            hv_dim: 1000,
            num_landmarks: 10,
            ..ModelConfig::default()
        };
        let model = train(&ds, &cfg);
        (ds, model)
    }

    /// The inference equivalence property driven through the trait: the
    /// packed engine and the i8 oracle must agree graph by graph AND
    /// batch by batch when both are behind `dyn Classifier`.
    #[test]
    fn packed_vs_i8_equivalence_through_the_trait() {
        let (ds, model) = trained();
        let mut engine = NysxEngine::new(&model);
        let mut oracle = ReferenceClassifier(&model);
        let backends: [&mut dyn Classifier; 2] = [&mut engine, &mut oracle];
        let mut all_preds: Vec<Vec<usize>> = Vec::new();
        for backend in backends {
            let graphs: Vec<&Graph> = ds.test.iter().map(|(g, _)| g).collect();
            let batched = backend.classify_batch(&graphs).expect("in-process backend");
            let singles: Vec<usize> = graphs
                .iter()
                .map(|g| backend.classify(g).expect("in-process backend"))
                .collect();
            assert_eq!(batched, singles, "{}: batch != single", backend.name());
            all_preds.push(batched);
        }
        assert_eq!(
            all_preds[0], all_preds[1],
            "packed engine != i8 oracle through the Classifier trait"
        );
    }

    /// Baselines ride the same interface; accuracy() must agree with the
    /// backend-specific evaluation helpers bit for bit.
    #[test]
    fn accuracy_matches_backend_specific_evaluators() {
        let (ds, model) = trained();
        let mut engine = NysxEngine::new(&model);
        assert_eq!(
            accuracy(&mut engine, &ds.test).unwrap(),
            crate::model::train::evaluate(&model, &ds.test),
            "trait-driven accuracy != evaluate()"
        );

        let ghd = train_graphhd(&ds, 512, 7);
        let want = crate::baselines::evaluate_graphhd(&ghd, &ds.test);
        let mut ghd = ghd;
        assert_eq!(
            accuracy(&mut ghd, &ds.test).unwrap(),
            Some(want),
            "trait-driven GraphHD accuracy != evaluate_graphhd()"
        );

        assert_eq!(accuracy(&mut engine, &[]).unwrap(), None);
    }

    /// The facade's Prometheus snapshot renders the full obs catalog —
    /// every pipeline stage histogram appears under its sanitized name
    /// regardless of whether obs is enabled.
    #[test]
    fn prometheus_facade_renders_the_catalog() {
        let text = snapshot_prometheus();
        for stage in crate::obs::STAGES {
            let metric = format!("nysx_stage_{stage}");
            assert!(
                text.contains(&metric),
                "prometheus text missing {metric}"
            );
        }
        assert!(text.contains("nysx_infer_requests"));
    }
}
