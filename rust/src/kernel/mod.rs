//! Propagation-kernel substrate: LSH code generation, codebooks and
//! histograms, and the graph propagation kernel (paper §2.1.3, §5.2.1).

pub mod histogram;
pub mod lsh;
pub mod propagation;

pub use histogram::{histogram, raw_dot, raw_histogram, Codebook};
pub use lsh::{node_codes, node_codes_reference, schedule_op_counts, LshParams};
pub use propagation::{
    gram_from_signatures, gram_from_signatures_with_pool, gram_matrix, gram_matrix_with_pool,
    normalize_gram, signatures_with_pool, GraphSignature,
};
