//! Hop-wise code histograms and the codebooks (vocabularies) they are
//! binned through (paper §2.1.3).

use std::collections::{BTreeMap, HashMap};

/// A hop-specific codebook `B^(t)`: the set of integer codes observed in
/// the landmark graphs at that hop, with a canonical (sorted) index per
/// code — the histogram bin layout shared by training and inference.
#[derive(Debug, Clone, PartialEq)]
pub struct Codebook {
    /// Sorted distinct codes.
    pub codes: Vec<i64>,
    // nysx-lint: allow(determinism): lookup-only oracle (the "naive dictionary" the MPHE replaces); never iterated, so hash order cannot reach an output
    index: HashMap<i64, u32>,
}

impl Codebook {
    /// Build from any iterator of observed codes.
    pub fn build<I: IntoIterator<Item = i64>>(codes: I) -> Self {
        let mut distinct: Vec<i64> = codes.into_iter().collect();
        distinct.sort_unstable();
        distinct.dedup();
        let index = distinct
            .iter()
            .enumerate()
            .map(|(i, &c)| (c, i as u32))
            .collect();
        Self {
            codes: distinct,
            index,
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.codes.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    /// HashMap-based lookup (the "naive dictionary search" the MPHE
    /// replaces; kept as the functional oracle).
    #[inline]
    pub fn index_of(&self, code: i64) -> Option<u32> {
        self.index.get(&code).copied()
    }

    /// Bytes per Table 2: each entry stores the code (i64) and its index
    /// (u32).
    pub fn bytes(&self) -> usize {
        self.len() * (8 + 4)
    }
}

/// Dense histogram of codes binned through a codebook; codes absent from
/// the codebook are skipped (Alg. 1 lines 6-8).
pub fn histogram(codes: &[i64], codebook: &Codebook) -> Vec<u32> {
    let mut h = vec![0u32; codebook.len()];
    for &c in codes {
        if let Some(j) = codebook.index_of(c) {
            h[j as usize] += 1;
        }
    }
    h
}

/// Raw (codebook-free) histogram: code -> count. Used during training and
/// by the propagation-kernel Gram computation, where the vocabulary is
/// defined by the graphs themselves. A `BTreeMap` on purpose: [`raw_dot`]
/// iterates it while summing f64 terms, and only a sorted map gives the
/// same summation order on every run (HashMap iteration order varies with
/// the per-process hash seed, which made gram matrices differ across runs
/// in the last few ulps).
pub fn raw_histogram(codes: &[i64]) -> BTreeMap<i64, u32> {
    let mut h = BTreeMap::new();
    for &c in codes {
        *h.entry(c).or_insert(0) += 1;
    }
    h
}

/// Dot product of two raw histograms (the per-hop term of the propagation
/// kernel). Iteration is in sorted code order, so the floating-point sum
/// has a fixed association — bit-identical across runs, thread counts and
/// which-operand-is-smaller.
pub fn raw_dot(a: &BTreeMap<i64, u32>, b: &BTreeMap<i64, u32>) -> f64 {
    // Iterate the smaller map; sorted order makes the term order (and
    // therefore the f64 sum) independent of which operand that is.
    let (small, large) = if a.len() <= b.len() { (a, b) } else { (b, a) };
    small
        .iter()
        .filter_map(|(c, &x)| large.get(c).map(|&y| x as f64 * y as f64))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codebook_sorted_dedup() {
        let cb = Codebook::build(vec![5, -2, 5, 0, -2]);
        assert_eq!(cb.codes, vec![-2, 0, 5]);
        assert_eq!(cb.index_of(-2), Some(0));
        assert_eq!(cb.index_of(5), Some(2));
        assert_eq!(cb.index_of(7), None);
        assert_eq!(cb.bytes(), 3 * 12);
    }

    #[test]
    fn histogram_counts_and_skips() {
        let cb = Codebook::build(vec![1, 2, 3]);
        let h = histogram(&[1, 1, 3, 99, -5], &cb);
        assert_eq!(h, vec![2, 0, 1]);
        // total counted = nodes with in-vocabulary codes
        assert_eq!(h.iter().sum::<u32>(), 3);
    }

    #[test]
    fn raw_dot_symmetric_and_correct() {
        let a = raw_histogram(&[1, 1, 2, 7]);
        let b = raw_histogram(&[1, 2, 2, 2]);
        assert_eq!(raw_dot(&a, &b), raw_dot(&b, &a));
        // 1: 2*1 + 2: 1*3 = 5
        assert_eq!(raw_dot(&a, &b), 5.0);
        let empty = raw_histogram(&[]);
        assert_eq!(raw_dot(&a, &empty), 0.0);
    }

    /// Consistency: binning through a codebook built from the same codes
    /// preserves all counts.
    #[test]
    fn dense_matches_raw_when_in_vocab() {
        let codes = vec![4, 4, -1, 0, 4, -1];
        let cb = Codebook::build(codes.clone());
        let dense = histogram(&codes, &cb);
        let raw = raw_histogram(&codes);
        for (j, &code) in cb.codes.iter().enumerate() {
            assert_eq!(dense[j], raw[&code]);
        }
        assert_eq!(dense.iter().sum::<u32>() as usize, codes.len());
    }
}
