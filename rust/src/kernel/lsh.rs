//! Locality-sensitive hashing for the propagation kernel (paper §2.1.3):
//! per-hop random projection `u^(t)`, offset `b^(t)`, shared width `w`,
//! and the two equivalent code-generation schedules:
//!
//! * the *baseline* `M^(t) = A^t F`, `c = ⌊(M u + b)/w⌋` which stores the
//!   full N×f feature matrix per hop, and
//! * the paper's §5.2.1 *restructured chain* `c ← F u` then `c ← A c`
//!   per hop, which keeps only an N-vector and cuts the op count from
//!   `HNf + (H-1) f·nnz(A)` to `HNf + H(H-1)/2·nnz(A)`.

use crate::graph::Graph;
use crate::util::rng::Xoshiro256;

/// Per-hop LSH parameters `{(u^(t), b^(t))}` with shared width `w`.
#[derive(Debug, Clone, PartialEq)]
pub struct LshParams {
    /// hops × f projection vectors.
    pub u: Vec<Vec<f64>>,
    /// hops offsets.
    pub b: Vec<f64>,
    /// Shared quantization width w > 0.
    pub w: f64,
}

impl LshParams {
    /// Sample parameters: u ~ N(0, I), b ~ U[0, w).
    pub fn sample(hops: usize, f: usize, w: f64, rng: &mut Xoshiro256) -> Self {
        assert!(w > 0.0);
        Self {
            u: (0..hops)
                .map(|_| (0..f).map(|_| rng.normal()).collect())
                .collect(),
            b: (0..hops).map(|_| rng.uniform(0.0, w)).collect(),
            w,
        }
    }

    pub fn hops(&self) -> usize {
        self.u.len()
    }

    pub fn feature_dim(&self) -> usize {
        self.u.first().map(|u| u.len()).unwrap_or(0)
    }

    /// Quantize one projected value to its integer code.
    #[inline]
    pub fn quantize(&self, proj: f64, hop: usize) -> i64 {
        ((proj + self.b[hop]) / self.w).floor() as i64
    }
}

/// Baseline code generation: materializes `M^(t) = A^t F` (N×f per hop).
/// Kept as the oracle for the equivalence property test and for op-count
/// comparisons; the production path is [`node_codes`].
pub fn node_codes_reference(graph: &Graph, lsh: &LshParams) -> Vec<Vec<i64>> {
    let n = graph.num_nodes();
    let mut m = graph.features.clone();
    let mut out = Vec::with_capacity(lsh.hops());
    for t in 0..lsh.hops() {
        let proj = m.matvec(&lsh.u[t]);
        out.push((0..n).map(|i| lsh.quantize(proj[i], t)).collect());
        if t + 1 < lsh.hops() {
            m = graph.adj.spmm(&m);
        }
    }
    out
}

/// Restructured chain (paper §5.2.1): per hop t compute `F u^(t)` then
/// apply `A` t times, so only N-vectors are live. Exactly computes
/// `A^t F u^(t)`.
pub fn node_codes(graph: &Graph, lsh: &LshParams) -> Vec<Vec<i64>> {
    let n = graph.num_nodes();
    let mut out = Vec::with_capacity(lsh.hops());
    let mut scratch = vec![0.0; n];
    for t in 0..lsh.hops() {
        // c = F u^(t)
        let mut c = graph.features.matvec(&lsh.u[t]);
        // c = A^t c
        for _ in 0..t {
            graph.adj.spmv_into(&c, &mut scratch);
            std::mem::swap(&mut c, &mut scratch);
        }
        out.push(c.iter().map(|&p| lsh.quantize(p, t)).collect());
    }
    out
}

/// Operation counts of both schedules (paper §5.2.1's complexity claim),
/// returned as (baseline_ops, restructured_ops).
pub fn schedule_op_counts(n: usize, f: usize, nnz: usize, hops: usize) -> (u64, u64) {
    let h = hops as u64;
    let (n, f, nnz) = (n as u64, f as u64, nnz as u64);
    let baseline = h * n * f + h.saturating_sub(1) * f * nnz;
    let restructured = h * n * f + h * h.saturating_sub(1) / 2 * nnz;
    (baseline, restructured)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::labeled_graph;

    fn sample_graph(rng: &mut Xoshiro256) -> Graph {
        let n = 5 + rng.gen_range(40);
        labeled_graph(n, rng.gen_range(n), 0.3, &[0.4, 0.3, 0.2, 0.1], rng)
    }

    /// Property (paper §5.2.1): the restructured chain computes the same
    /// codes as the baseline for every hop.
    #[test]
    fn chain_equals_baseline() {
        let mut rng = Xoshiro256::seed_from_u64(42);
        for _ in 0..25 {
            let g = sample_graph(&mut rng);
            let lsh = LshParams::sample(4, g.feature_dim(), 1.0, &mut rng);
            let a = node_codes_reference(&g, &lsh);
            let b = node_codes(&g, &lsh);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn codes_shift_with_offset() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let g = sample_graph(&mut rng);
        let mut lsh = LshParams::sample(1, g.feature_dim(), 1.0, &mut rng);
        let before = node_codes(&g, &lsh);
        lsh.b[0] += 1.0; // exactly one bin
        let after = node_codes(&g, &lsh);
        for (x, y) in before[0].iter().zip(&after[0]) {
            assert_eq!(x + 1, *y);
        }
    }

    #[test]
    fn width_controls_granularity() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let g = sample_graph(&mut rng);
        let fine = LshParams::sample(1, g.feature_dim(), 0.1, &mut rng);
        let mut coarse = fine.clone();
        coarse.w = 100.0;
        coarse.b = vec![0.0];
        let fine_codes = node_codes(&g, &fine);
        let coarse_codes = node_codes(&g, &coarse);
        let distinct = |v: &Vec<i64>| v.iter().collect::<std::collections::HashSet<_>>().len();
        assert!(distinct(&fine_codes[0]) >= distinct(&coarse_codes[0]));
    }

    /// The §5.2.1 claim: restructuring wins when f > H/2.
    #[test]
    fn op_count_claim() {
        let (base, restr) = schedule_op_counts(100, 50, 400, 4);
        assert!(restr < base, "restructured {restr} vs baseline {base}");
        // Degenerate single-hop case: identical (no propagation at all).
        let (b1, r1) = schedule_op_counts(100, 50, 400, 1);
        assert_eq!(b1, r1);
    }

    #[test]
    fn hop_zero_ignores_adjacency() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let labels = [0usize, 1, 2, 0];
        let g1 = Graph::from_edges(4, &[(0, 1), (1, 2)], &labels, 3);
        let g2 = Graph::from_edges(4, &[(0, 3), (2, 3)], &labels, 3);
        let lsh = LshParams::sample(1, 3, 1.0, &mut rng);
        assert_eq!(node_codes(&g1, &lsh), node_codes(&g2, &lsh));
    }
}
