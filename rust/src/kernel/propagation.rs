//! The propagation kernel for graphs (Neumann et al. [41]; paper §2.1.3):
//! `K(G_X, G_Z) = Σ_t h_X^(t)ᵀ h_Z^(t)` over LSH-binned histograms of
//! iteratively propagated node features. Used (a) to build the DPP
//! similarity kernel for landmark selection (§4.1) and (b) as the kernel
//! the Nyström method approximates.

use std::collections::BTreeMap;

use super::histogram::{raw_dot, raw_histogram};
use super::lsh::{node_codes, LshParams};
use crate::exec::{self, Pool};
use crate::graph::Graph;
use crate::linalg::Mat;

/// Per-hop raw histograms of one graph — the graph's signature under a
/// fixed set of LSH parameters. Sorted maps so [`GraphSignature::kernel`]
/// sums its f64 terms in code order — identical on every run (see
/// [`raw_histogram`]).
#[derive(Debug, Clone)]
pub struct GraphSignature {
    pub hists: Vec<BTreeMap<i64, u32>>,
}

impl GraphSignature {
    pub fn compute(graph: &Graph, lsh: &LshParams) -> Self {
        let codes = node_codes(graph, lsh);
        Self {
            hists: codes.iter().map(|c| raw_histogram(c)).collect(),
        }
    }

    /// Propagation-kernel value against another signature.
    pub fn kernel(&self, other: &GraphSignature) -> f64 {
        self.hists
            .iter()
            .zip(&other.hists)
            .map(|(a, b)| raw_dot(a, b))
            .sum()
    }
}

/// Full Gram matrix `K[i][j] = K(G_i, G_j)` over a graph set. O(n²) pairs
/// but signatures are computed once (O(n)).
pub fn gram_matrix(graphs: &[&Graph], lsh: &LshParams) -> Mat {
    gram_matrix_with_pool(&exec::global(), graphs, lsh)
}

/// [`gram_matrix`] across an explicit exec pool: signatures and the
/// pairwise kernel walk both run data-parallel (bit-identical at any
/// thread count).
pub fn gram_matrix_with_pool(pool: &Pool, graphs: &[&Graph], lsh: &LshParams) -> Mat {
    let sigs = signatures_with_pool(pool, graphs, lsh);
    gram_from_signatures_with_pool(pool, &sigs)
}

/// Per-graph signatures across an exec pool, returned in graph order:
/// each lane computes a contiguous block of graphs; no shared state.
pub fn signatures_with_pool(
    pool: &Pool,
    graphs: &[&Graph],
    lsh: &LshParams,
) -> Vec<GraphSignature> {
    let ranges = exec::even_ranges(graphs.len(), pool.threads());
    exec::map_parts(pool, ranges.len(), |block| {
        ranges[block]
            .clone()
            .map(|i| GraphSignature::compute(graphs[i], lsh))
            .collect::<Vec<_>>()
    })
    .into_iter()
    .flatten()
    .collect()
}

/// Gram matrix from precomputed signatures.
pub fn gram_from_signatures(sigs: &[GraphSignature]) -> Mat {
    gram_from_signatures_with_pool(&exec::global(), sigs)
}

/// [`gram_from_signatures`] across an explicit exec pool. The upper
/// triangle is split into triangle-balanced contiguous row ranges
/// ([`exec::triangle_ranges`], row `i` costs `n - i` kernel
/// evaluations); each lane fills its own rows, then the lower triangle
/// is mirrored sequentially. Every `K[i][j]` is computed by exactly one
/// lane with the same kernel sum, so the matrix is bit-identical at any
/// thread count.
pub fn gram_from_signatures_with_pool(pool: &Pool, sigs: &[GraphSignature]) -> Mat {
    let n = sigs.len();
    let mut k = Mat::zeros(n, n);
    if n == 0 {
        return k;
    }
    let row_ranges = exec::triangle_ranges(n, pool.threads());
    let elem_ranges: Vec<std::ops::Range<usize>> =
        row_ranges.iter().map(|r| r.start * n..r.end * n).collect();
    exec::for_each_range_mut(pool, &mut k.data, &elem_ranges, |block, part| {
        for (local, i) in row_ranges[block].clone().enumerate() {
            let row = &mut part[local * n..(local + 1) * n];
            for (j, slot) in row.iter_mut().enumerate().skip(i) {
                *slot = sigs[i].kernel(&sigs[j]);
            }
        }
    });
    for i in 0..n {
        for j in 0..i {
            k[(i, j)] = k[(j, i)];
        }
    }
    k
}

/// Normalized kernel k̂(x,z) = k(x,z)/sqrt(k(x,x)k(z,z)) — used for the
/// DPP L-kernel so determinants are scale-free.
pub fn normalize_gram(k: &Mat) -> Mat {
    let n = k.rows;
    let mut out = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            let denom = (k[(i, i)] * k[(j, j)]).sqrt();
            out[(i, j)] = if denom > 0.0 { k[(i, j)] / denom } else { 0.0 };
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::labeled_graph;
    use crate::linalg::sym_eigen;
    use crate::util::rng::Xoshiro256;

    fn graphs(n: usize, rng: &mut Xoshiro256) -> Vec<Graph> {
        (0..n)
            .map(|_| {
                let nodes = 6 + rng.gen_range(25);
                labeled_graph(nodes, rng.gen_range(nodes), 0.2, &[0.5, 0.3, 0.2], rng)
            })
            .collect()
    }

    #[test]
    fn gram_symmetric_psd() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let gs = graphs(12, &mut rng);
        let refs: Vec<&Graph> = gs.iter().collect();
        let lsh = LshParams::sample(3, 3, 1.0, &mut rng);
        let k = gram_matrix(&refs, &lsh);
        // Symmetric
        assert!(k.max_abs_diff(&k.transpose()) < 1e-12);
        // PSD: all eigenvalues >= -tol (histogram dot products are inner
        // products in the histogram feature space).
        let e = sym_eigen(&k);
        for &l in &e.values {
            assert!(l > -1e-8 * k.fro_norm(), "negative eigenvalue {l}");
        }
    }

    /// The exec contract on the propagation kernel: signatures and Gram
    /// matrices are bit-identical at thread counts {1, 2, 7}.
    #[test]
    fn parallel_gram_bit_identical_across_thread_counts() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let gs = graphs(17, &mut rng);
        let refs: Vec<&Graph> = gs.iter().collect();
        let lsh = LshParams::sample(3, 3, 1.0, &mut rng);
        let oracle_pool = crate::exec::Pool::new(1);
        let want_sigs = signatures_with_pool(&oracle_pool, &refs, &lsh);
        let want = gram_from_signatures_with_pool(&oracle_pool, &want_sigs);
        // Single-thread pool result equals the hand-rolled sequential walk.
        let mut seq = Mat::zeros(17, 17);
        for i in 0..17 {
            for j in i..17 {
                let v = want_sigs[i].kernel(&want_sigs[j]);
                seq[(i, j)] = v;
                seq[(j, i)] = v;
            }
        }
        assert_eq!(want.data, seq.data, "pool=1 gram != sequential walk");
        for threads in [2usize, 7] {
            let pool = crate::exec::Pool::new(threads);
            let sigs = signatures_with_pool(&pool, &refs, &lsh);
            assert_eq!(sigs.len(), want_sigs.len());
            for (a, b) in sigs.iter().zip(&want_sigs) {
                assert_eq!(a.hists, b.hists, "signature drift at threads={threads}");
            }
            let k = gram_from_signatures_with_pool(&pool, &sigs);
            assert_eq!(k.data, want.data, "gram drift at threads={threads}");
        }
        // Plain entry points (global pool) agree too.
        assert_eq!(gram_matrix(&refs, &lsh).data, want.data);
        assert_eq!(gram_from_signatures(&want_sigs).data, want.data);
        // Degenerate empty set.
        let empty = gram_from_signatures_with_pool(&oracle_pool, &[]);
        assert_eq!(empty.rows, 0);
    }

    #[test]
    fn self_similarity_dominates() {
        // Cauchy-Schwarz: K(x,z) <= sqrt(K(x,x) K(z,z)).
        let mut rng = Xoshiro256::seed_from_u64(2);
        let gs = graphs(8, &mut rng);
        let lsh = LshParams::sample(2, 3, 1.0, &mut rng);
        let sigs: Vec<GraphSignature> = gs
            .iter()
            .map(|g| GraphSignature::compute(g, &lsh))
            .collect();
        for i in 0..gs.len() {
            for j in 0..gs.len() {
                let kij = sigs[i].kernel(&sigs[j]);
                let bound = (sigs[i].kernel(&sigs[i]) * sigs[j].kernel(&sigs[j])).sqrt();
                assert!(kij <= bound + 1e-9);
            }
        }
    }

    #[test]
    fn identical_graphs_max_normalized_similarity() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let gs = graphs(3, &mut rng);
        let refs: Vec<&Graph> = vec![&gs[0], &gs[0], &gs[1]];
        let lsh = LshParams::sample(2, 3, 1.0, &mut rng);
        let k = normalize_gram(&gram_matrix(&refs, &lsh));
        assert!((k[(0, 1)] - 1.0).abs() < 1e-12, "duplicate graphs should have sim 1");
        assert!(k[(0, 2)] < 1.0);
        for i in 0..3 {
            assert!((k[(i, i)] - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn kernel_counts_node_pairs_at_hop0() {
        // Hop-0 kernel of two graphs with identical label multisets equals
        // sum over codes of count products; with every node the same
        // label, K = n1 * n2.
        let mut rng = Xoshiro256::seed_from_u64(4);
        let g1 = Graph::from_edges(4, &[(0, 1), (1, 2), (2, 3)], &[0; 4], 2);
        let g2 = Graph::from_edges(6, &[(0, 1), (1, 2), (2, 3), (3, 4), (4, 5)], &[0; 6], 2);
        let lsh = LshParams::sample(1, 2, 1.0, &mut rng);
        let sig1 = GraphSignature::compute(&g1, &lsh);
        let sig2 = GraphSignature::compute(&g2, &lsh);
        assert_eq!(sig1.kernel(&sig2), 24.0);
    }
}
