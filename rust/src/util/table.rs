//! ASCII table formatter used by every bench/example that regenerates a
//! paper table or figure. Produces aligned, monospace tables like:
//!
//! ```text
//! Dataset       | CPU   | GPU   | FPGA
//! --------------+-------+-------+------
//! DD            | 7.47  | 3.00  | 1.80
//! ```

#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str) -> Self {
        Self {
            title: title.to_string(),
            ..Default::default()
        }
    }

    pub fn header<S: AsRef<str>>(mut self, cols: &[S]) -> Self {
        self.header = cols.iter().map(|s| s.as_ref().to_string()).collect();
        self
    }

    pub fn row<S: AsRef<str>>(&mut self, cells: &[S]) -> &mut Self {
        self.rows
            .push(cells.iter().map(|s| s.as_ref().to_string()).collect());
        self
    }

    pub fn render(&self) -> String {
        let ncols = self
            .header
            .len()
            .max(self.rows.iter().map(|r| r.len()).max().unwrap_or(0));
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncols {
                let cell = cells.get(i).map(|s| s.as_str()).unwrap_or("");
                if i > 0 {
                    line.push_str(" | ");
                }
                line.push_str(&format!("{:<width$}", cell, width = widths[i]));
            }
            line.trim_end().to_string()
        };
        if !self.header.is_empty() {
            out.push_str(&fmt_row(&self.header));
            out.push('\n');
            let mut sep = String::new();
            for (i, w) in widths.iter().enumerate() {
                if i > 0 {
                    sep.push_str("-+-");
                }
                sep.push_str(&"-".repeat(*w));
            }
            out.push_str(&sep);
            out.push('\n');
        }
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(&["Dataset", "ms"]);
        t.row(&["DD", "7.47"]);
        t.row(&["ENZYMES-long", "0.6"]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("Dataset      | ms"));
        assert!(s.contains("DD           | 7.47"));
    }

    #[test]
    fn handles_ragged_rows() {
        let mut t = Table::new("").header(&["a", "b", "c"]);
        t.row(&["1"]);
        let s = t.render();
        assert!(s.contains("1"));
    }
}
