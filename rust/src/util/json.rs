//! Minimal JSON value type with emitter and parser (no `serde` in the
//! vendored crate set). Used for `artifacts/manifest.json`, experiment
//! reports and coordinator metrics dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so emitted documents are
/// deterministic (stable key order) — important for golden-file tests.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(x: T) -> Json {
        Json::Num(x.into())
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: s.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(JsonError::new(p.pos, "trailing characters"));
        }
        Ok(v)
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl JsonError {
    fn new(pos: usize, msg: &str) -> Self {
        Self {
            pos,
            msg: msg.to_string(),
        }
    }
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{item}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(JsonError::new(self.pos, "unexpected character"))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(JsonError::new(self.pos, "bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(JsonError::new(self.pos, "expected value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(JsonError::new(self.pos, "unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(JsonError::new(self.pos, "bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| JsonError::new(self.pos, "bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| JsonError::new(self.pos, "bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(JsonError::new(self.pos, "bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar. `rest` is non-empty
                    // (peek returned Some), but stay total anyway: a
                    // malformed document must never panic the emitter's
                    // round-trip validation path.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| JsonError::new(self.pos, "invalid utf8"))?;
                    let c = rest
                        .chars()
                        .next()
                        .ok_or_else(|| JsonError::new(self.pos, "unterminated string"))?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        // The consumed range is ASCII digits/signs/dots by construction,
        // but a typed error beats relying on that invariant here.
        let s = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| JsonError::new(start, "bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::new(start, "bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(JsonError::new(self.pos, "expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(JsonError::new(self.pos, "expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let doc = Json::obj(vec![
            ("name", Json::str("nysx")),
            ("d", Json::num(10000.0)),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
            ("arr", Json::arr(vec![Json::num(1.0), Json::num(2.5)])),
        ]);
        let text = doc.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(doc, back);
    }

    #[test]
    fn parse_whitespace_and_nesting() {
        let v = Json::parse(" { \"a\" : [ 1 , { \"b\" : \"x\\ny\" } ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[0].as_f64(), Some(1.0));
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[1]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x\ny")
        );
    }

    #[test]
    fn parse_numbers() {
        for (s, expect) in [
            ("0", 0.0),
            ("-3", -3.0),
            ("3.25", 3.25),
            ("1e3", 1000.0),
            ("-2.5e-2", -0.025),
        ] {
            assert_eq!(Json::parse(s).unwrap().as_f64(), Some(expect), "{s}");
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn escapes_emitted_and_parsed() {
        let doc = Json::str("line1\nline2\t\"q\" \\ \u{1}");
        let text = doc.to_string();
        assert_eq!(Json::parse(&text).unwrap(), doc);
    }
}
