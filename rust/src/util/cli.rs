//! Tiny command-line flag parser (no `clap` in the vendored crate set).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments, which covers every binary in this repo.

use std::collections::HashMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    flags: HashMap<String, String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Self {
        let mut flags = HashMap::new();
        let mut positional = Vec::new();
        let mut iter = items.into_iter().peekable();
        while let Some(item) = iter.next() {
            if let Some(rest) = item.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    flags.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    flags.insert(rest.to_string(), v);
                } else {
                    flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                positional.push(item);
            }
        }
        Self { flags, positional }
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} must be an integer, got {s:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} must be an integer, got {s:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{key} must be a number, got {s:?}")))
            .unwrap_or(default)
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true" | "1" | "yes"))
    }

    // Fallible getters: binaries surface malformed flag values as typed
    // errors instead of panicking (the panicking `get_*` variants above
    // remain for contexts where aborting is the right behavior).

    fn try_get<T: std::str::FromStr>(
        &self,
        key: &str,
        default: T,
        kind: &str,
    ) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| format!("--{key} must be {kind}, got {s:?}")),
        }
    }

    pub fn try_usize(&self, key: &str, default: usize) -> Result<usize, String> {
        self.try_get(key, default, "an integer")
    }

    pub fn try_u64(&self, key: &str, default: u64) -> Result<u64, String> {
        self.try_get(key, default, "an integer")
    }

    pub fn try_f64(&self, key: &str, default: f64) -> Result<f64, String> {
        self.try_get(key, default, "a number")
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn flag_forms() {
        // Note: a bare `--flag` greedily consumes a following non-flag
        // token as its value, so positionals go first (or use --flag=v).
        let a = parse("run --dataset MUTAG --seed=7 --verbose");
        assert_eq!(a.get("dataset"), Some("MUTAG"));
        assert_eq!(a.get_u64("seed", 0), 7);
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positional(), &["run".to_string()]);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_usize("pes", 4), 4);
        assert_eq!(a.get_f64("w", 1.0), 1.0);
        assert!(!a.get_bool("dpp"));
    }

    #[test]
    fn boolean_flag_before_flag() {
        let a = parse("--dpp --dataset DD");
        assert!(a.get_bool("dpp"));
        assert_eq!(a.get("dataset"), Some("DD"));
    }

    #[test]
    fn try_getters_report_instead_of_panicking() {
        let a = parse("--workers four --scale 0.5");
        let err = a.try_usize("workers", 4).expect_err("non-numeric");
        assert!(err.contains("workers") && err.contains("four"), "{err}");
        assert_eq!(a.try_f64("scale", 1.0), Ok(0.5));
        assert_eq!(a.try_usize("absent", 7), Ok(7));
        assert_eq!(a.try_u64("absent", 9), Ok(9));
    }
}
