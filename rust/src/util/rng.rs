//! Deterministic PRNG suite built from scratch (no `rand` crate available).
//!
//! [`SplitMix64`] seeds [`Xoshiro256`] (xoshiro256**), which provides the
//! uniform/normal/choice primitives used across training, landmark
//! sampling, synthetic dataset generation and the property-test framework.
//! Everything in the repo that draws randomness takes an explicit `&mut
//! Xoshiro256` so experiments are reproducible from a single seed.

/// SplitMix64: tiny, high-quality stream used to expand a `u64` seed into
/// the 256-bit xoshiro state (the construction recommended by the xoshiro
/// authors).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256**: the repo-wide PRNG. Fast, 2^256-1 period, passes BigCrush.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
    /// Cached second normal variate from Box-Muller.
    gauss_spare: Option<f64>,
}

impl Xoshiro256 {
    /// Seed from a single u64 via SplitMix64 expansion.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Derive an independent stream (for per-worker / per-dataset rngs).
    pub fn fork(&mut self) -> Self {
        Self::seed_from_u64(self.next_u64())
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn gen_range(&mut self, n: usize) -> usize {
        assert!(n > 0, "gen_range(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Bernoulli trial.
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (uniform without
    /// replacement). O(n) selection-sampling when k is large, rejection
    /// when tiny.
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "choose_k: k={k} > n={n}");
        if k == 0 {
            return Vec::new();
        }
        if k * 4 <= n {
            // Rejection via a sorted set is fine for sparse draws.
            let mut seen = std::collections::HashSet::with_capacity(k * 2);
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let i = self.gen_range(n);
                if seen.insert(i) {
                    out.push(i);
                }
            }
            out
        } else {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted_choice(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted_choice: all-zero weights");
        let mut target = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Poisson sample (Knuth for small lambda, normal approx for large).
    pub fn poisson(&mut self, lambda: f64) -> usize {
        assert!(lambda >= 0.0);
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0usize;
            let mut p = 1.0;
            loop {
                p *= self.next_f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let z = self.normal_ms(lambda, lambda.sqrt());
            z.max(0.0).round() as usize
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // First outputs for seed 0 (reference values from the SplitMix64
        // reference implementation).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
    }

    #[test]
    fn deterministic_and_fork_independent() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        assert_eq!(a.next_u64(), b.next_u64());
        let mut f = a.fork();
        assert_ne!(a.next_u64(), f.next_u64());
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn gen_range_unbiased_small() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let mut counts = [0usize; 5];
        let n = 50_000;
        for _ in 0..n {
            counts[rng.gen_range(5)] += 1;
        }
        for &c in &counts {
            let expect = n / 5;
            assert!((c as i64 - expect as i64).abs() < (expect as i64) / 10);
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let n = 200_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn choose_k_distinct_and_covering() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        for &(n, k) in &[(10usize, 3usize), (100, 90), (5, 5), (1000, 1)] {
            let sel = rng.choose_k(n, k);
            assert_eq!(sel.len(), k);
            let set: std::collections::HashSet<_> = sel.iter().collect();
            assert_eq!(set.len(), k, "duplicates in choose_k({n},{k})");
            assert!(sel.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::seed_from_u64(11);
        let mut v: Vec<usize> = (0..57).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..57).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut rng = Xoshiro256::seed_from_u64(13);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.weighted_choice(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio={ratio}");
    }

    #[test]
    fn poisson_mean() {
        let mut rng = Xoshiro256::seed_from_u64(17);
        for &lambda in &[0.5, 4.0, 60.0] {
            let n = 20_000;
            let sum: usize = (0..n).map(|_| rng.poisson(lambda)).sum();
            let mean = sum as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.07,
                "lambda={lambda} mean={mean}"
            );
        }
    }
}
