//! Foundation utilities built in-repo (the vendored crate set has no
//! `rand`, `serde`, or `clap`): PRNG, JSON, CLI parsing and table
//! formatting.

pub mod cli;
pub mod json;
pub mod rng;
pub mod table;

/// Format a byte count as a human-readable MB string (paper reports MB).
pub fn fmt_mb(bytes: usize) -> String {
    format!("{:.2}", bytes as f64 / (1024.0 * 1024.0))
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (nearest-rank on a sorted copy), p in [0,100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((stddev(&xs) - 1.118033988749895).abs() < 1e-9);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 3.0); // nearest-rank rounds up at .5
    }

    #[test]
    fn fmt_mb_works() {
        assert_eq!(fmt_mb(1024 * 1024), "1.00");
        assert_eq!(fmt_mb(1536 * 1024), "1.50");
    }
}
