//! `nysx` CLI — the L3 leader entrypoint, built on the [`nysx::api`]
//! facade: every user-input failure (unknown dataset, bad flag value,
//! corrupt model file, invalid serving config) is a typed
//! [`NysxError`] printed to stderr with exit code 2 — never a panic.
//!
//! Subcommands:
//!   train   --dataset MUTAG [--dpp] [--out model.nysx] [--scale 1.0]
//!   infer   --model model.nysx --dataset MUTAG [--count 32]
//!   serve   --dataset MUTAG [--workers 4] [--requests 500] [--batch 1]
//!           [--shards N] [--dpp]            # N > 1: sharded tier
//!   eval    [--scale 1.0] [--ablation]      # all tables & figures
//!   bench serving [--shards 1,2,4] [--qps 100,300,1000] [--out BENCH_SERVING.json]
//!   bench memory  [--datasets MUTAG,BZR] [--out BENCH_MEMORY.json]
//!   profile infer|serving [--out PROFILE.json] [--prom-out PROM.txt]
//!   lint    [--root DIR] [--json] [--out LINT_REPORT.json]   # exit 2 on findings
//!   race    [--root DIR] [--json] [--out CONCURRENCY_REPORT.json]  # exit 2 on findings
//!   roofline
//!
//! Every subcommand accepts `--threads N` to size the `nysx::exec`
//! data-parallel pool (default: the `NYSX_THREADS` environment variable,
//! then the machine's available parallelism). Thread count is a pure
//! throughput knob — results are bit-identical at any value.
//!
//! Observability (`nysx::obs`) is ON by default in the CLI; `NYSX_OBS=0`
//! turns it off. Either way classifications are bit-identical — the
//! stage spans and lane counters observe, never steer.
//!
//! Positional command first, then flags (the tiny parser is greedy).

use std::path::Path;

use nysx::api::{NysxError, Pipeline, TrainedPipeline};
use nysx::bench::tables::{
    evaluate_all, render_fig6, render_fig7, render_fig8, render_roofline, render_table3,
    render_table4, render_table6, render_table7, render_table8, EvalConfig,
};
use nysx::coordinator::{BatcherConfig, ServerConfig, SubmitError};
use nysx::graph::tudataset::TU_SPECS;
use nysx::nystrom::LandmarkStrategy;
use nysx::util::cli::Args;

fn main() {
    // CLI convention: observability defaults ON (the library defaults
    // off); NYSX_OBS=0 disables it. Must run before any span executes.
    nysx::obs::init_from_env();
    let args = Args::from_env();
    // Size the exec pool before anything touches it: `--threads N`
    // beats NYSX_THREADS beats available parallelism. An explicit 0 (or
    // garbage) is a typed error like every other flag — only an absent
    // flag falls through to the env/hardware default.
    if args.get("threads").is_some() {
        if let Err(e) = args
            .try_usize("threads", 0)
            .and_then(nysx::exec::configure_threads)
        {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    }
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    let result = match cmd {
        "train" => cmd_train(&args),
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "eval" => cmd_eval(&args),
        "bench" => cmd_bench(&args),
        "profile" => cmd_profile(&args),
        "lint" => cmd_lint(&args),
        "race" => cmd_race(&args),
        "roofline" => {
            println!("{}", render_roofline());
            Ok(())
        }
        _ => {
            println!(
                "nysx — Nyström-HDC graph classification (NysX reproduction)\n\n\
                 USAGE: nysx <train|infer|serve|eval|bench|profile|lint|race|roofline> [flags]\n\
                 common flags: --threads N (exec pool size; default NYSX_THREADS or all cores)\n\
                 datasets: {}",
                TU_SPECS.iter().map(|s| s.name).collect::<Vec<_>>().join(", ")
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
}

/// Map a malformed flag value onto the crate error type.
fn flag_err(msg: String) -> NysxError {
    NysxError::Config(msg)
}

/// Build the pipeline every subcommand shares from the CLI flags.
fn pipeline_from_args(args: &Args) -> Result<Pipeline, NysxError> {
    let name = args.get_or("dataset", "MUTAG");
    let strategy = if args.get_bool("dpp") {
        LandmarkStrategy::HybridDpp { pool_factor: 2 }
    } else {
        LandmarkStrategy::Uniform
    };
    Ok(Pipeline::for_dataset(name)?
        .scale(args.try_f64("scale", 1.0).map_err(flag_err)?)
        .seed(args.try_u64("seed", 42).map_err(flag_err)?)
        .hv_dim(args.try_usize("d", 10_000).map_err(flag_err)?)
        .landmarks(strategy))
}

fn report_accuracy(trained: &mut TrainedPipeline) {
    match trained.evaluate() {
        Some(acc) => println!("test accuracy: {:.2}%", 100.0 * acc),
        None => println!("test accuracy: n/a (empty test split)"),
    }
}

fn cmd_train(args: &Args) -> Result<(), NysxError> {
    let pipeline = pipeline_from_args(args)?;
    eprintln!(
        "generating {} and training...",
        args.get_or("dataset", "MUTAG")
    );
    let t0 = nysx::obs::clock::now_ns();
    let mut trained = pipeline.train()?;
    let model = trained.model();
    eprintln!(
        "trained on {} ({} train graphs, s={}, {:?}) in {:.1}s incl. dataset generation",
        trained.dataset().name,
        trained.dataset().train.len(),
        model.s(),
        model.config.strategy,
        nysx::obs::clock::elapsed_ns(t0) as f64 / 1e9
    );
    report_accuracy(&mut trained);
    let mem = trained.model().memory_report();
    println!(
        "model memory: {:.2} MB dense / {:.2} MB deployed (P_nys {:.0}%)",
        mem.total_dense() as f64 / 1048576.0,
        mem.total_deployed() as f64 / 1048576.0,
        100.0 * mem.p_nys_fraction()
    );
    if let Some(path) = args.get("out") {
        trained.save(Path::new(path))?;
        println!("saved to {path}");
    }
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<(), NysxError> {
    let pipeline = pipeline_from_args(args)?;
    let mut trained = if let Some(path) = args.get("model") {
        pipeline.load(Path::new(path))?
    } else {
        eprintln!("no --model given; training one now");
        pipeline.train()?
    };
    let accel = nysx::sim::AcceleratorConfig::zcu104();
    let power = nysx::sim::PowerModel::default();
    let (ds, engine) = trained.parts();
    let count = args
        .try_usize("count", 32)
        .map_err(flag_err)?
        .min(ds.test.len());
    let mut correct = 0;
    for (g, y) in ds.test.iter().take(count) {
        let t0 = nysx::obs::clock::now_ns();
        let res = engine.infer(g);
        let host_us = nysx::obs::clock::elapsed_ns(t0) as f64 / 1e3;
        let b = nysx::sim::simulate(&res.trace, &accel, nysx::sim::SimOptions::default());
        let e = power.energy(&b, &accel);
        if res.predicted == *y {
            correct += 1;
        }
        println!(
            "graph N={:<4} pred={} truth={} host={:.0}µs fpga={:.3}ms {:.2}mJ",
            g.num_nodes(),
            res.predicted,
            y,
            host_us,
            e.time_ms,
            e.energy_mj
        );
    }
    if count == 0 {
        // Guard the division: `--count 0` or an empty test split would
        // otherwise print "NaN%".
        println!("no graphs evaluated (empty test split or --count 0)");
    } else {
        println!(
            "accuracy on {count} graphs: {:.1}%",
            100.0 * correct as f64 / count as f64
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<(), NysxError> {
    let workers = args.try_usize("workers", 4).map_err(flag_err)?;
    let requests = args.try_usize("requests", 500).map_err(flag_err)?;
    // Batch-major dispatch: each worker pops up to --batch requests and
    // runs them as ONE blocked C×W SCE pass (1 = the paper's real-time
    // edge mode; >1 amortizes prototype traffic across the batch).
    let batch = args.try_usize("batch", 1).map_err(flag_err)?.max(1);
    // --shards N > 1 serves through the sharded tier (consistent-hash
    // front router + per-shard admission control); 1 is the classic
    // single-server coordinator. Predictions are identical either way.
    let shards = args.try_usize("shards", 1).map_err(flag_err)?;
    eprintln!("training model for serving...");
    let trained = pipeline_from_args(args)?.train()?;
    let server_cfg = ServerConfig {
        workers,
        batcher: BatcherConfig {
            batch_size: batch,
            ..Default::default()
        },
        ..Default::default()
    };
    let ds = trained.dataset();
    let mut rng = nysx::util::rng::Xoshiro256::seed_from_u64(7);

    if shards > 1 {
        let mut tier = trained.serve_sharded(nysx::coordinator::ShardedConfig {
            shards,
            max_outstanding: args.try_usize("max-outstanding", 1024).map_err(flag_err)?,
            per_shard: server_cfg,
        })?;
        for _ in 0..requests {
            let (g, _) = &ds.test[rng.gen_range(ds.test.len())];
            let mut graph = g.clone();
            loop {
                match tier.submit(graph) {
                    Ok(_) => break,
                    Err(SubmitError::Backpressure(g)) => {
                        graph = g;
                        tier.recv(); // free a slot, then retry
                    }
                    Err(e @ SubmitError::Closed(_)) => return Err(e.into()),
                }
            }
        }
        tier.drain();
        println!(
            "served {requests} requests across {shards} shards ({workers} workers each, batch size {batch})"
        );
        for shard in 0..shards {
            let s = tier.shard_metrics(shard);
            println!(
                "  shard {shard}: {} reqs, host p50={:.0}µs p99={:.0}µs p999={:.0}µs, queue p99={:.0}µs, {:.0} req/s",
                s.requests,
                s.host_us.p50,
                s.host_us.p99,
                s.host_us.p999,
                s.queue_us.p99,
                s.host_throughput_rps,
            );
        }
        tier.shutdown();
        return Ok(());
    }

    let mut server = trained.serve(server_cfg)?;
    for _ in 0..requests {
        let (g, _) = &ds.test[rng.gen_range(ds.test.len())];
        let mut graph = g.clone();
        loop {
            match server.submit(graph) {
                Ok(_) => break,
                Err(SubmitError::Backpressure(g)) => {
                    graph = g;
                    server.recv(); // free a slot, then retry
                }
                Err(e @ SubmitError::Closed(_)) => return Err(e.into()),
            }
        }
    }
    server.drain();
    let s = server.metrics();
    println!(
        "served {} requests on {workers} workers (batch size {batch}, exec pool {} threads)\n  host latency  p50={:.0}µs p95={:.0}µs p99={:.0}µs\n  queue wait    p50={:.0}µs p99={:.0}µs\n  sim FPGA      mean={:.3}ms p99={:.3}ms\n  host throughput {:.0} req/s; simulated energy {:.1} mJ total\n  per-worker {:?}",
        s.requests,
        nysx::exec::global().threads(),
        s.host_us.p50,
        s.host_us.p95,
        s.host_us.p99,
        s.queue_us.p50,
        s.queue_us.p99,
        s.fpga_ms.mean,
        s.fpga_ms.p99,
        s.host_throughput_rps,
        s.total_fpga_mj,
        s.per_worker
    );
    server.shutdown();
    Ok(())
}

/// `bench <target>` — currently only the serving load harness.
fn cmd_bench(args: &Args) -> Result<(), NysxError> {
    match args.positional().get(1).map(|s| s.as_str()) {
        Some("serving") => cmd_bench_serving(args),
        Some("memory") => cmd_bench_memory(args),
        other => Err(NysxError::Config(format!(
            "unknown bench target {:?}; available: serving, memory",
            other.unwrap_or("<none>")
        ))),
    }
}

/// Parse a comma-separated flag value ("1,2,4") into numbers.
fn parse_list<T: std::str::FromStr>(s: &str, flag: &str) -> Result<Vec<T>, NysxError> {
    s.split(',')
        .map(|item| {
            item.trim().parse::<T>().map_err(|_| {
                NysxError::Config(format!(
                    "--{flag} must be a comma-separated list of numbers, got {s:?}"
                ))
            })
        })
        .collect()
}

/// The serving load harness: closed- and open-loop sweeps per shard
/// count, artifact to `--out` (default BENCH_SERVING.json). Smoke mode
/// (`NYSX_BENCH_SMOKE=1`) shrinks every knob's default for CI.
fn cmd_bench_serving(args: &Args) -> Result<(), NysxError> {
    use nysx::bench::serving::{self, ServingBenchConfig};
    let mut cfg = ServingBenchConfig::from_env();
    if let Some(name) = args.get("dataset") {
        cfg.dataset = name.to_string();
    }
    cfg.scale = args.try_f64("scale", cfg.scale).map_err(flag_err)?;
    cfg.seed = args.try_u64("seed", cfg.seed).map_err(flag_err)?;
    cfg.hv_dim = args.try_usize("d", cfg.hv_dim).map_err(flag_err)?;
    if let Some(list) = args.get("shards") {
        cfg.shard_counts = parse_list(list, "shards")?;
    }
    if let Some(list) = args.get("qps") {
        cfg.qps_points = parse_list(list, "qps")?;
    }
    cfg.requests_per_point = args
        .try_usize("requests", cfg.requests_per_point)
        .map_err(flag_err)?;
    cfg.closed_loop_requests = args
        .try_usize("closed-requests", cfg.closed_loop_requests)
        .map_err(flag_err)?;
    cfg.closed_loop_clients = args
        .try_usize("clients", cfg.closed_loop_clients)
        .map_err(flag_err)?;
    cfg.workers_per_shard = args
        .try_usize("workers", cfg.workers_per_shard)
        .map_err(flag_err)?;
    cfg.batch_size = args.try_usize("batch", cfg.batch_size).map_err(flag_err)?.max(1);
    cfg.max_outstanding = args
        .try_usize("max-outstanding", cfg.max_outstanding)
        .map_err(flag_err)?;
    let out = args.get_or("out", "BENCH_SERVING.json").to_string();

    eprintln!(
        "serving load harness on {}: shards {:?}, qps {:?}{}",
        cfg.dataset,
        cfg.shard_counts,
        cfg.qps_points,
        if serving::smoke_mode() { " (smoke)" } else { "" }
    );
    let report = serving::run(&cfg)?;
    for run in &report.runs {
        let c = &run.closed_loop;
        println!(
            "shards={}: closed loop ({} clients) {:.0} req/s, latency p50={:.2}ms p99={:.2}ms p999={:.2}ms",
            run.shards,
            cfg.closed_loop_clients,
            c.achieved_qps,
            c.latency_ms.p50,
            c.latency_ms.p99,
            c.latency_ms.p999,
        );
        for (qps, st) in &run.open_loop {
            println!(
                "  offered {qps:.0} qps -> achieved {:.0} ({} answered, {} shed), p50={:.2}ms p99={:.2}ms p999={:.2}ms",
                st.achieved_qps,
                st.answered,
                st.rejected,
                st.latency_ms.p50,
                st.latency_ms.p99,
                st.latency_ms.p999,
            );
        }
    }
    report.write(Path::new(&out))?;
    println!("wrote {out}");
    Ok(())
}

/// The memory-footprint harness (DESIGN.md §10): per TUDataset config,
/// phast-vs-legacy MPH bits/key, v3-vs-v2 artifact bytes, and
/// Elias–Fano-vs-plain CSR offsets, plus one large synthetic graph;
/// artifact to `--out` (default BENCH_MEMORY.json). Smoke mode
/// (`NYSX_BENCH_SMOKE=1`) shrinks the sweep for CI.
fn cmd_bench_memory(args: &Args) -> Result<(), NysxError> {
    use nysx::bench::memory::{self, MemoryBenchConfig};
    use nysx::bench::serving::smoke_mode;
    let mut cfg = MemoryBenchConfig::from_env();
    if let Some(list) = args.get("datasets") {
        cfg.datasets = list.split(',').map(|s| s.trim().to_string()).collect();
    }
    cfg.scale = args.try_f64("scale", cfg.scale).map_err(flag_err)?;
    cfg.seed = args.try_u64("seed", cfg.seed).map_err(flag_err)?;
    cfg.hv_dim = args.try_usize("d", cfg.hv_dim).map_err(flag_err)?;
    cfg.hops = args.try_usize("hops", cfg.hops).map_err(flag_err)?;
    cfg.synthetic_nodes = args
        .try_usize("synthetic-nodes", cfg.synthetic_nodes)
        .map_err(flag_err)?;
    let out = args.get_or("out", "BENCH_MEMORY.json").to_string();

    eprintln!(
        "memory footprint harness: {:?} + {}-node synthetic{}",
        cfg.datasets,
        cfg.synthetic_nodes,
        if smoke_mode() { " (smoke)" } else { "" }
    );
    let report = memory::run(&cfg)?;
    for d in &report.datasets {
        println!(
            "{}: mph {:.2} vs {:.2} bits/key (phast vs legacy), model {} vs {} bytes (v3 vs v2), offsets {} vs {} bytes (EF vs plain)",
            d.dataset,
            d.phast_bits_per_key,
            d.legacy_bits_per_key,
            d.model_bytes_v3,
            d.model_bytes_v2,
            d.csr_offsets_ef_bytes,
            d.csr_offsets_plain_bytes,
        );
    }
    let s = &report.synthetic;
    println!(
        "synthetic ({} nodes, {} edges): mph {:.2} vs {:.2} bits/key, offsets {} vs {} bytes (EF vs plain)",
        s.nodes,
        s.edges,
        s.phast_bits_per_key,
        s.legacy_bits_per_key,
        s.csr_offsets_ef_bytes,
        s.csr_offsets_plain_bytes,
    );
    println!(
        "headline: phast {:.2} bits/key vs legacy {:.2} bits/key over {} keys total",
        report.phast_bits_per_key,
        report.legacy_bits_per_key,
        report.datasets.iter().map(|d| d.num_keys).sum::<usize>() + s.num_keys,
    );
    report.write(Path::new(&out))?;
    println!("wrote {out}");
    Ok(())
}

/// `profile <infer|serving>` — run the obs-instrumented profiling
/// harness (DESIGN.md §11) and write the `nysx-obs/v1` artifact to
/// `--out` (default PROFILE.json), optionally a Prometheus text
/// exposition to `--prom-out`. Forces obs ON regardless of `NYSX_OBS`
/// (profiling with the meters off would be an empty artifact). Smoke
/// mode (`NYSX_BENCH_SMOKE=1`) shrinks the run for CI.
fn cmd_profile(args: &Args) -> Result<(), NysxError> {
    use nysx::bench::profile::{self, ProfileConfig};
    let kind = args.positional().get(1).map(|s| s.as_str());
    let mut cfg = ProfileConfig::from_env();
    if let Some(name) = args.get("dataset") {
        cfg.dataset = name.to_string();
    }
    cfg.scale = args.try_f64("scale", cfg.scale).map_err(flag_err)?;
    cfg.seed = args.try_u64("seed", cfg.seed).map_err(flag_err)?;
    cfg.hv_dim = args.try_usize("d", cfg.hv_dim).map_err(flag_err)?;
    cfg.repeats = args.try_usize("repeats", cfg.repeats).map_err(flag_err)?;
    cfg.shards = args.try_usize("shards", cfg.shards).map_err(flag_err)?;
    cfg.requests = args.try_usize("requests", cfg.requests).map_err(flag_err)?;
    cfg.workers_per_shard = args
        .try_usize("workers", cfg.workers_per_shard)
        .map_err(flag_err)?;
    cfg.batch_size = args.try_usize("batch", cfg.batch_size).map_err(flag_err)?.max(1);
    if args.get("threads").is_some() {
        cfg.threads = Some(args.try_usize("threads", 0).map_err(flag_err)?);
    }
    let out = args.get_or("out", "PROFILE.json").to_string();

    let report = match kind {
        Some("infer") => profile::profile_infer(&cfg)?,
        Some("serving") => profile::profile_serving(&cfg)?,
        other => {
            return Err(NysxError::Config(format!(
                "unknown profile kind {:?}; available: infer, serving",
                other.unwrap_or("<none>")
            )))
        }
    };
    for stage in nysx::obs::STAGES {
        let name = format!("stage.{stage}");
        if let Some(h) = report.snapshot.histograms.iter().find(|h| h.name == name) {
            println!(
                "stage {stage:<15} count={:<8} mean={:.1}µs p50~{:.1}µs p99~{:.1}µs",
                h.count,
                h.mean_ns() / 1e3,
                h.percentile_ns(50.0) as f64 / 1e3,
                h.percentile_ns(99.0) as f64 / 1e3,
            );
        }
    }
    for lane in &report.snapshot.lanes {
        println!(
            "lanes {:<22} runs={:<6} lanes={} imbalance={:.2}",
            lane.name,
            lane.runs,
            lane.lanes,
            lane.imbalance(),
        );
    }
    report.write(Path::new(&out))?;
    println!("wrote {out}");
    if let Some(prom) = args.get("prom-out") {
        std::fs::write(prom, nysx::api::snapshot_prometheus()).map_err(NysxError::Io)?;
        println!("wrote {prom}");
    }
    Ok(())
}

/// `lint` — run the invariant analyzer (DESIGN.md §8) over a crate root
/// (default: the current directory, i.e. run it from `rust/`). Prints
/// the text report (or the `nysx-lint/v1` JSON document with `--json`),
/// optionally writes the validated artifact to `--out`, and exits 2 —
/// through the standard typed-error path — iff there are findings.
fn cmd_lint(args: &Args) -> Result<(), NysxError> {
    let root = args.get_or("root", ".").to_string();
    let report = nysx::analysis::lint_crate(Path::new(&root))?;
    if args.get_bool("json") {
        println!("{}", report.to_json());
    } else {
        print!("{}", report.render_text());
    }
    if let Some(out) = args.get("out") {
        report.write(Path::new(out))?;
        eprintln!("wrote {out}");
    }
    if report.findings.is_empty() {
        Ok(())
    } else {
        Err(NysxError::Config(format!(
            "{} lint finding(s)",
            report.findings.len()
        )))
    }
}

fn cmd_race(args: &Args) -> Result<(), NysxError> {
    let root = args.get_or("root", ".").to_string();
    let report = nysx::analysis::race_crate(Path::new(&root))?;
    if args.get_bool("json") {
        println!("{}", report.to_json());
    } else {
        print!("{}", report.render_text());
    }
    if let Some(out) = args.get("out") {
        report.write(Path::new(out))?;
        eprintln!("wrote {out}");
    }
    if report.findings.is_empty() {
        Ok(())
    } else {
        Err(NysxError::Config(format!(
            "{} race finding(s)",
            report.findings.len()
        )))
    }
}

fn cmd_eval(args: &Args) -> Result<(), NysxError> {
    let cfg = EvalConfig {
        scale: args
            .try_f64("scale", EvalConfig::default().scale)
            .map_err(flag_err)?,
        seed: args.try_u64("seed", 42).map_err(flag_err)?,
        hv_dim: args.try_usize("d", 10_000).map_err(flag_err)?,
        ablation: args.get_bool("ablation"),
    };
    let evals = evaluate_all(&cfg);
    for section in [
        render_table4(&evals),
        render_table3(&evals),
        render_table6(&evals),
        render_fig6(&evals),
        render_table7(&evals),
        render_fig7(&evals),
        render_table8(&evals),
        render_fig8(&evals),
        render_roofline(),
    ] {
        println!("{section}");
    }
    Ok(())
}
