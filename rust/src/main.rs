//! `nysx` CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   train   --dataset MUTAG [--dpp] [--out model.nysx] [--scale 1.0]
//!   infer   --model model.nysx --dataset MUTAG [--count 32]
//!   serve   --dataset MUTAG [--workers 4] [--requests 500] [--batch 1] [--dpp]
//!   eval    [--scale 1.0] [--ablation]      # all tables & figures
//!   roofline
//!
//! Positional command first, then flags (the tiny parser is greedy).

use std::sync::Arc;

use nysx::bench::tables::{
    evaluate_all, render_fig6, render_fig7, render_fig8, render_roofline, render_table3,
    render_table4, render_table6, render_table7, render_table8, EvalConfig,
};
use nysx::coordinator::{BatcherConfig, Server, ServerConfig, SubmitError};
use nysx::graph::tudataset::{spec_by_name, TU_SPECS};
use nysx::model::train::{evaluate, train};
use nysx::model::ModelConfig;
use nysx::nystrom::LandmarkStrategy;
use nysx::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional().first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "eval" => cmd_eval(&args),
        "roofline" => println!("{}", render_roofline()),
        _ => {
            println!(
                "nysx — Nyström-HDC graph classification (NysX reproduction)\n\n\
                 USAGE: nysx <train|infer|serve|eval|roofline> [flags]\n\
                 datasets: {}",
                TU_SPECS.iter().map(|s| s.name).collect::<Vec<_>>().join(", ")
            );
        }
    }
}

fn dataset_and_config(args: &Args) -> (nysx::graph::GraphDataset, ModelConfig) {
    let name = args.get_or("dataset", "MUTAG");
    let spec = spec_by_name(name).unwrap_or_else(|| panic!("unknown dataset {name}"));
    let scale = args.get_f64("scale", 1.0);
    let seed = args.get_u64("seed", 42);
    let (ds, s_uni, s_dpp) = spec.generate_scaled(seed, scale);
    let dpp = args.get_bool("dpp");
    let cfg = ModelConfig {
        hops: spec.hops,
        hv_dim: args.get_usize("d", 10_000),
        num_landmarks: if dpp { s_dpp } else { s_uni },
        strategy: if dpp {
            LandmarkStrategy::HybridDpp { pool_factor: 2 }
        } else {
            LandmarkStrategy::Uniform
        },
        seed,
        ..ModelConfig::default()
    };
    (ds, cfg)
}

fn cmd_train(args: &Args) {
    let (ds, cfg) = dataset_and_config(args);
    eprintln!(
        "training on {} ({} train graphs, s={}, {:?})",
        ds.name,
        ds.train.len(),
        cfg.num_landmarks,
        cfg.strategy
    );
    let t0 = std::time::Instant::now();
    let model = train(&ds, &cfg);
    eprintln!("trained in {:.1}s", t0.elapsed().as_secs_f64());
    println!("test accuracy: {:.2}%", 100.0 * evaluate(&model, &ds.test));
    let mem = model.memory_report();
    println!(
        "model memory: {:.2} MB dense / {:.2} MB deployed (P_nys {:.0}%)",
        mem.total_dense() as f64 / 1048576.0,
        mem.total_deployed() as f64 / 1048576.0,
        100.0 * mem.p_nys_fraction()
    );
    if let Some(path) = args.get("out") {
        nysx::model::io::save_file(&model, std::path::Path::new(path)).expect("save model");
        println!("saved to {path}");
    }
}

fn cmd_infer(args: &Args) {
    let (ds, cfg) = dataset_and_config(args);
    let model = if let Some(path) = args.get("model") {
        nysx::model::io::load_file(std::path::Path::new(path)).expect("load model")
    } else {
        eprintln!("no --model given; training one now");
        train(&ds, &cfg)
    };
    let count = args.get_usize("count", 32).min(ds.test.len());
    let mut engine = nysx::infer::NysxEngine::new(&model);
    let accel = nysx::sim::AcceleratorConfig::zcu104();
    let power = nysx::sim::PowerModel::default();
    let mut correct = 0;
    for (g, y) in ds.test.iter().take(count) {
        let t0 = std::time::Instant::now();
        let res = engine.infer(g);
        let host_us = t0.elapsed().as_secs_f64() * 1e6;
        let b = nysx::sim::simulate(&res.trace, &accel, nysx::sim::SimOptions::default());
        let e = power.energy(&b, &accel);
        if res.predicted == *y {
            correct += 1;
        }
        println!(
            "graph N={:<4} pred={} truth={} host={:.0}µs fpga={:.3}ms {:.2}mJ",
            g.num_nodes(),
            res.predicted,
            y,
            host_us,
            e.time_ms,
            e.energy_mj
        );
    }
    if count == 0 {
        // Guard the division: `--count 0` or an empty test split would
        // otherwise print "NaN%".
        println!("no graphs evaluated (empty test split or --count 0)");
    } else {
        println!(
            "accuracy on {count} graphs: {:.1}%",
            100.0 * correct as f64 / count as f64
        );
    }
}

fn cmd_serve(args: &Args) {
    let (ds, cfg) = dataset_and_config(args);
    eprintln!("training model for serving...");
    let model = Arc::new(train(&ds, &cfg));
    let workers = args.get_usize("workers", 4);
    let requests = args.get_usize("requests", 500);
    // Batch-major dispatch: each worker pops up to --batch requests and
    // runs them as ONE blocked C×W SCE pass (1 = the paper's real-time
    // edge mode; >1 amortizes prototype traffic across the batch).
    let batch = args.get_usize("batch", 1).max(1);
    let mut server = Server::start(
        model,
        ServerConfig {
            workers,
            batcher: BatcherConfig {
                batch_size: batch,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let mut rng = nysx::util::rng::Xoshiro256::seed_from_u64(7);
    for _ in 0..requests {
        let (g, _) = &ds.test[rng.gen_range(ds.test.len())];
        loop {
            match server.submit(g.clone()) {
                Ok(_) => break,
                Err(SubmitError::Backpressure(_)) => {
                    server.recv(); // free a slot, then retry
                }
                Err(SubmitError::Closed(_)) => {
                    unreachable!("server closed mid-replay")
                }
            }
        }
    }
    server.drain();
    let s = server.metrics.summary();
    println!(
        "served {} requests on {workers} workers (batch size {batch})\n  host latency  p50={:.0}µs p95={:.0}µs p99={:.0}µs\n  queue wait    p50={:.0}µs p99={:.0}µs\n  sim FPGA      mean={:.3}ms p99={:.3}ms\n  host throughput {:.0} req/s; simulated energy {:.1} mJ total\n  per-worker {:?}",
        s.requests,
        s.host_us.p50,
        s.host_us.p95,
        s.host_us.p99,
        s.queue_us.p50,
        s.queue_us.p99,
        s.fpga_ms.mean,
        s.fpga_ms.p99,
        s.host_throughput_rps,
        s.total_fpga_mj,
        s.per_worker
    );
    server.shutdown();
}

fn cmd_eval(args: &Args) {
    let cfg = EvalConfig {
        scale: args.get_f64("scale", EvalConfig::default().scale),
        seed: args.get_u64("seed", 42),
        hv_dim: args.get_usize("d", 10_000),
        ablation: args.get_bool("ablation"),
    };
    let evals = evaluate_all(&cfg);
    for section in [
        render_table4(&evals),
        render_table3(&evals),
        render_table6(&evals),
        render_fig6(&evals),
        render_table7(&evals),
        render_fig7(&evals),
        render_table8(&evals),
        render_fig8(&evals),
        render_roofline(),
    ] {
        println!("{section}");
    }
}
