//! Cycle-approximate model of the NysX accelerator (paper §5): six engine
//! cycle models driven by real per-inference work traces, composed along
//! the Fig-5 compute flow, with power/energy, resource-utilization and
//! roofline models. This is the hardware substitute for the ZCU104 — see
//! DESIGN.md §4 at the repository root.

pub mod accelerator;
pub mod config;
pub mod engines;
pub mod power;
pub mod resources;
pub mod roofline;

pub use accelerator::{latency_ms, simulate, CycleBreakdown, SimOptions};
pub use config::AcceleratorConfig;
pub use power::{EnergyReport, PowerModel};
pub use resources::{estimate as estimate_resources, ResourceReport};
pub use roofline::{analyze, machine_balance, nee_point, Bound, RooflinePoint};
