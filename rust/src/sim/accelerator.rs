//! Composition of the six engine cycle models along the paper's Fig-5
//! compute flow: per hop LSHU → MPHE → HUE → KSE (sequential, with
//! MPHE/HUE pipelined behind LSHU), then NEE → SCE once.

use super::config::AcceleratorConfig;
use super::engines::{hue, kse, lshu, mphe, nee, sce};
use crate::infer::InferTrace;

/// Per-engine cycle breakdown of one inference.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct CycleBreakdown {
    pub lshu: u64,
    pub mphe: u64,
    pub hue: u64,
    pub kse: u64,
    pub nee: u64,
    pub sce: u64,
}

impl CycleBreakdown {
    pub fn total(&self) -> u64 {
        self.lshu + self.mphe + self.hue + self.kse + self.nee + self.sce
    }

    /// Fraction of total cycles spent in the NEE (the paper's ">90% of
    /// inference time" profiling claim is about wall time on *their*
    /// datasets; ours lands in the Fig 8 / Table 7 renderings — see
    /// DESIGN.md §4).
    pub fn nee_fraction(&self) -> f64 {
        self.nee as f64 / self.total().max(1) as f64
    }
}

/// Ablation/configuration switches for a simulation run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimOptions {
    /// §4.2 static load balancing on (LSHU + KSE schedules).
    pub load_balanced: bool,
    /// MPHE on; false = naive binary-search dictionary lookups.
    pub mph_lookup: bool,
    /// Streaming NEE on; false = narrow unstreamed reads.
    pub streamed_nee: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        Self {
            load_balanced: true,
            mph_lookup: true,
            streamed_nee: true,
        }
    }
}

/// Simulate one inference from its work trace.
pub fn simulate(trace: &InferTrace, cfg: &AcceleratorConfig, opts: SimOptions) -> CycleBreakdown {
    let mut b = CycleBreakdown {
        lshu: lshu::cycles(trace, cfg, opts.load_balanced),
        ..Default::default()
    };
    for hop in &trace.hops {
        if opts.mph_lookup {
            b.mphe += mphe::cycles(hop, cfg);
        } else {
            b.mphe += mphe::cycles_naive(hop);
        }
        b.hue += hue::cycles(hop, cfg);
        b.kse += kse::cycles(hop, opts.load_balanced);
    }
    b.nee = if opts.streamed_nee {
        nee::cycles(trace.d, trace.s, cfg)
    } else {
        nee::cycles_unstreamed(trace.d, trace.s, cfg)
    };
    b.sce = sce::cycles(trace.num_classes, trace.d, cfg);
    b
}

/// End-to-end latency in milliseconds.
pub fn latency_ms(trace: &InferTrace, cfg: &AcceleratorConfig, opts: SimOptions) -> f64 {
    cfg.cycles_to_ms(simulate(trace, cfg, opts).total())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::tudataset::spec_by_name;
    use crate::infer::NysxEngine;
    use crate::model::train::train;
    use crate::model::ModelConfig;

    fn traced() -> InferTrace {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(41, 0.25);
        let cfg = ModelConfig {
            hops: 3,
            hv_dim: 4096,
            num_landmarks: 16,
            ..ModelConfig::default()
        };
        let model = train(&ds, &cfg);
        let mut engine = NysxEngine::new(&model);
        engine.infer(&ds.test[0].0).trace
    }

    #[test]
    fn optimizations_monotone() {
        let trace = traced();
        let cfg = AcceleratorConfig::zcu104();
        let full = simulate(&trace, &cfg, SimOptions::default()).total();
        for (name, opts) in [
            (
                "no-lb",
                SimOptions {
                    load_balanced: false,
                    ..SimOptions::default()
                },
            ),
            (
                "no-mph",
                SimOptions {
                    mph_lookup: false,
                    ..SimOptions::default()
                },
            ),
            (
                "no-stream",
                SimOptions {
                    streamed_nee: false,
                    ..SimOptions::default()
                },
            ),
        ] {
            let degraded = simulate(&trace, &cfg, opts).total();
            assert!(
                degraded >= full,
                "{name}: disabling an optimization should not speed things up ({degraded} < {full})"
            );
        }
    }

    #[test]
    fn nee_dominates_for_large_d() {
        let mut trace = traced();
        trace.d = 10_000;
        trace.s = 300;
        let cfg = AcceleratorConfig::zcu104();
        let b = simulate(&trace, &cfg, SimOptions::default());
        assert!(
            b.nee_fraction() > 0.5,
            "NEE should dominate: {:?}",
            b
        );
    }

    #[test]
    fn latency_scale_realistic() {
        // Paper Table 6: FPGA latencies are 0.3–1.8 ms. Our MUTAG-scaled
        // trace with d=10000, s≈150 should land sub-2ms.
        let mut trace = traced();
        trace.d = 10_000;
        trace.s = 148;
        let cfg = AcceleratorConfig::zcu104();
        let ms = latency_ms(&trace, &cfg, SimOptions::default());
        assert!(ms > 0.05 && ms < 3.0, "latency {ms} ms out of paper range");
    }
}
