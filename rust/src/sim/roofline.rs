//! Roofline model (paper §5.2.5, Williams et al. [60]): arithmetic
//! intensity vs machine balance for the NEE projection, and the attainable
//! performance it implies.

use super::config::AcceleratorConfig;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bound {
    MemoryBound,
    ComputeBound,
}

/// One point on the roofline.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RooflinePoint {
    /// Arithmetic intensity in ops/byte.
    pub ai: f64,
    /// Peak compute of the design point in GOPS.
    pub peak_gops: f64,
    /// Sustained memory bandwidth in GB/s.
    pub sustained_bw_gbps: f64,
    /// Machine balance in ops/byte.
    pub machine_balance: f64,
    /// Attainable performance = min(peak, AI × BW) in GOPS.
    pub attainable_gops: f64,
    pub bound: Bound,
}

/// Peak MAC throughput of the NEE in GOPS (2 ops per MAC per cycle).
pub fn peak_gops(cfg: &AcceleratorConfig) -> f64 {
    2.0 * cfg.nee_lanes as f64 * cfg.freq_hz / 1e9
}

/// Machine balance (ops/byte) of the design point.
pub fn machine_balance(cfg: &AcceleratorConfig) -> f64 {
    peak_gops(cfg) / (cfg.ddr_bandwidth_gbps * cfg.ddr_efficiency)
}

/// Classify an arbitrary kernel by arithmetic intensity.
pub fn analyze(cfg: &AcceleratorConfig, ai: f64) -> RooflinePoint {
    let peak = peak_gops(cfg);
    let bw = cfg.ddr_bandwidth_gbps * cfg.ddr_efficiency;
    let attainable = peak.min(ai * bw);
    RooflinePoint {
        ai,
        peak_gops: peak,
        sustained_bw_gbps: bw,
        machine_balance: machine_balance(cfg),
        attainable_gops: attainable,
        bound: if ai < machine_balance(cfg) {
            Bound::MemoryBound
        } else {
            Bound::ComputeBound
        },
    }
}

/// The NEE projection's point: 2 ops per streamed operand.
pub fn nee_point(cfg: &AcceleratorConfig) -> RooflinePoint {
    let ai = 2.0 / (cfg.operand_bits as f64 / 8.0);
    analyze(cfg, ai)
}

/// Measured-efficiency helper: achieved GOPS of an NEE run.
pub fn achieved_gops(d: usize, s: usize, cycles: u64, cfg: &AcceleratorConfig) -> f64 {
    let ops = 2.0 * d as f64 * s as f64;
    let seconds = cycles as f64 / cfg.freq_hz;
    ops / seconds / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engines::nee;

    #[test]
    fn paper_design_point() {
        // The paper's illustration uses 32 lanes: 19.2 GOPS peak, 17.3
        // GB/s sustained, balance ≈ 1.11 ops/byte, AI = 0.5 → memory
        // bound.
        let mut cfg = AcceleratorConfig::zcu104();
        cfg.nee_lanes = 32;
        let p = nee_point(&cfg);
        assert!((p.peak_gops - 19.2).abs() < 1e-9);
        assert!((p.sustained_bw_gbps - 17.28).abs() < 0.01);
        assert!((p.machine_balance - 1.111).abs() < 0.01);
        assert!((p.ai - 0.5).abs() < 1e-12);
        assert_eq!(p.bound, Bound::MemoryBound);
        // Attainable = 0.5 * 17.28 = 8.64 GOPS.
        assert!((p.attainable_gops - 8.64).abs() < 0.01);
    }

    #[test]
    fn simulated_nee_tracks_roofline() {
        // The cycle model's achieved GOPS must approach (and not exceed)
        // the roofline's attainable GOPS.
        let cfg = AcceleratorConfig::zcu104();
        let (d, s) = (10_000, 300);
        let cycles = nee::cycles(d, s, &cfg);
        let achieved = achieved_gops(d, s, cycles, &cfg);
        let p = nee_point(&cfg);
        assert!(achieved <= p.attainable_gops + 1e-9);
        assert!(
            achieved > 0.95 * p.attainable_gops,
            "streaming should sustain ≥95% of roofline: {achieved} vs {}",
            p.attainable_gops
        );
    }

    #[test]
    fn crossover_with_lane_sweep() {
        // With very few lanes the kernel becomes compute bound.
        let mut cfg = AcceleratorConfig::zcu104();
        cfg.nee_lanes = 2;
        assert_eq!(nee_point(&cfg).bound, Bound::ComputeBound);
        cfg.nee_lanes = 64;
        assert_eq!(nee_point(&cfg).bound, Bound::MemoryBound);
    }
}
