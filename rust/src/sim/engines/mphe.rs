//! MPHE cycle model (paper §5.2.2 / Fig 3): pipelined, banked minimal-
//! perfect-hash lookups issuing ~1 per cycle; extra level probes stall the
//! pipeline one cycle each.

use crate::infer::HopTrace;
use crate::sim::config::AcceleratorConfig;

/// Cycles for one hop's code→index lookups.
///
/// The pipeline issues one lookup per cycle in steady state; each lookup
/// costs `probes` level-table accesses, of which the first overlaps with
/// issue. Level tables and rank vectors are banked, so concurrent PEs do
/// not serialize; the codebook-verification read adds one pipelined stage
/// (absorbed into the pipeline depth).
pub fn cycles(hop: &HopTrace, cfg: &AcceleratorConfig) -> u64 {
    if hop.lookups == 0 {
        return 0;
    }
    // Steady-state issue: max(lookups, total probes) — rehash probes
    // beyond the first stall the queue.
    let issue = hop.mph_probes.max(hop.lookups);
    issue + cfg.mphe_pipeline_depth
}

/// Naive dictionary-search alternative (the baseline MPHE replaces):
/// binary search over |B| entries, log2|B| BRAM reads per lookup, no
/// pipelining across lookups (dependent address chain).
pub fn cycles_naive(hop: &HopTrace) -> u64 {
    let log_b = (hop.hist_bins.max(2) as f64).log2().ceil() as u64;
    hop.lookups * log_b
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hop(lookups: u64, probes: u64, bins: usize) -> HopTrace {
        HopTrace {
            lookups,
            mph_probes: probes,
            vocab_hits: lookups,
            hist_bins: bins,
            ..HopTrace::default()
        }
    }

    #[test]
    fn pipelined_vs_naive() {
        let cfg = AcceleratorConfig::zcu104();
        let h = hop(1000, 1300, 4096);
        let mph = cycles(&h, &cfg);
        assert_eq!(mph, 1300 + 8);
        let naive = cycles_naive(&h);
        assert_eq!(naive, 1000 * 12);
        assert!(mph * 3 < naive, "MPHE should be far cheaper");
    }

    #[test]
    fn zero_lookups_zero_cycles() {
        let cfg = AcceleratorConfig::zcu104();
        assert_eq!(cycles(&hop(0, 0, 16), &cfg), 0);
    }
}
