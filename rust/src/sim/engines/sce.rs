//! SCE cycle model (paper §5.2.6): dense `s = G h` over bipolar operands
//! (adds/subs, no DSPs needed), one block of rows per PE, then a
//! sequential argmax.

use crate::sim::config::AcceleratorConfig;

/// Cycles for prototype matching + argmax.
///
/// Bipolar dot products are add/sub trees; each PE covers a block of
/// prototype rows, consuming `simd` HV elements per cycle (wide BRAM
/// word). Argmax is C sequential compares.
pub fn cycles(num_classes: usize, d: usize, cfg: &AcceleratorConfig) -> u64 {
    // 64 bipolar elements per cycle per PE (512-bit BRAM word of i8).
    let simd = (cfg.axi_width_bits / 8) as u64;
    let per_pe_rows = (num_classes as u64).div_ceil(cfg.pes as u64);
    let mac = per_pe_rows * (d as u64).div_ceil(simd);
    mac + num_classes as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_fraction_of_total() {
        let cfg = AcceleratorConfig::zcu104();
        let c = cycles(6, 10_000, &cfg);
        // 2 rows per PE * ceil(10000/64)=157 + 6 = 320
        assert_eq!(c, 2 * 157 + 6);
        // vs NEE at s=300: ~208k cycles — SCE is noise (paper Table 1).
        assert!(c < 1000);
    }
}
