//! KSE cycle model (paper §5.2.4): scheduled SpMV of the query histogram
//! against the CSR landmark histogram matrix `H^(t)`.

use crate::infer::HopTrace;

/// Cycles for one hop's landmark-similarity SpMV. The schedule table's
/// per-iteration max-row cost is computed on the *actual* trained `H^(t)`
/// during inference tracing, so this is a direct read-out.
pub fn cycles(hop: &HopTrace, load_balanced: bool) -> u64 {
    let fill = 4u64; // schedule fetch + row_ptr read pipeline fill
    if load_balanced {
        hop.kse_cycles_lb + fill
    } else {
        hop.kse_cycles_nolb + fill
    }
}

/// Dense alternative (what CPU/GPU baselines do): s×|B| MACs over `pes`
/// lanes, ignoring sparsity.
pub fn cycles_dense(hop: &HopTrace, s: usize, pes: usize) -> u64 {
    (s as u64 * hop.hist_bins as u64).div_ceil(pes as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lb_le_nolb_le_dense() {
        let hop = HopTrace {
            kse_cycles_lb: 500,
            kse_cycles_nolb: 800,
            kse_nnz: 1900,
            hist_bins: 1000,
            ..HopTrace::default()
        };
        let lb = cycles(&hop, true);
        let nolb = cycles(&hop, false);
        let dense = cycles_dense(&hop, 64, 4);
        assert!(lb < nolb);
        assert!(nolb < dense, "sparse ({nolb}) must beat dense ({dense})");
    }
}
