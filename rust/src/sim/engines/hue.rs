//! HUE cycle model (paper §5.2.3): each PE increments a private histogram
//! copy (conflict-free), then local copies merge into the hop-global
//! histogram via a reduction.

use crate::infer::HopTrace;
use crate::sim::config::AcceleratorConfig;

/// Cycles for one hop's histogram updates + merge.
///
/// Updates: `vocab_hits` increments spread over `pes` private copies
/// (1 increment/cycle each). Merge: the `pes` local copies reduce through
/// an adder tree, one bin per cycle over |B^(t)| bins.
pub fn cycles(hop: &HopTrace, cfg: &AcceleratorConfig) -> u64 {
    let updates = hop.vocab_hits.div_ceil(cfg.pes as u64);
    let merge = hop.hist_bins as u64;
    updates + merge
}

/// Contended single-copy alternative: concurrent increments to one banked
/// histogram serialize on conflicts; model as one update per cycle total
/// (the paper's "contention-prone" baseline).
pub fn cycles_contended(hop: &HopTrace) -> u64 {
    hop.vocab_hits
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn private_copies_beat_contended() {
        let cfg = AcceleratorConfig::zcu104();
        let hop = HopTrace {
            vocab_hits: 1000,
            hist_bins: 100,
            ..HopTrace::default()
        };
        let c = cycles(&hop, &cfg);
        assert_eq!(c, 250 + 100);
        assert!(c < cycles_contended(&hop));
    }

    #[test]
    fn merge_dominates_small_graphs() {
        let cfg = AcceleratorConfig::zcu104();
        let hop = HopTrace {
            vocab_hits: 8,
            hist_bins: 512,
            ..HopTrace::default()
        };
        assert_eq!(cycles(&hop, &cfg), 2 + 512);
    }
}
