//! Per-engine cycle models. Each engine consumes the relevant slice of an
//! [`crate::infer::InferTrace`] (real per-graph work counts) plus the
//! design point, and returns its cycle cost. The composition lives in
//! [`crate::sim::accelerator`].

pub mod hue;
pub mod kse;
pub mod lshu;
pub mod mphe;
pub mod nee;
pub mod sce;
