//! NEE cycle model (paper §5.2.5 / Fig 4): the DDR-streamed Nyström
//! projection `h = sign(P_nys C)` — the memory-bound stage that dominates
//! end-to-end latency.

use crate::sim::config::AcceleratorConfig;

/// Cycle cost of streaming a d×s FP32 projection.
///
/// * **Memory stream**: `d·s·4` bytes at the sustained DDR rate
///   (contiguous 512-bit bursts, multiple outstanding reads).
/// * **Compute**: `d·s` MACs over `nee_lanes` (one lane per operand in a
///   beat), with `sign()` fused into the accumulator drain.
/// * The deep FIFO decouples the two, so steady-state cost is the max of
///   the streams, plus the first-beat DRAM latency to fill the pipe.
pub fn cycles(d: usize, s: usize, cfg: &AcceleratorConfig) -> u64 {
    if d == 0 || s == 0 {
        return 0;
    }
    let elems = d as u64 * s as u64;
    let bytes = elems * (cfg.operand_bits as u64 / 8);
    let mem = (bytes as f64 / cfg.ddr_bytes_per_cycle()).ceil() as u64;
    let compute = elems.div_ceil(cfg.nee_lanes as u64);
    mem.max(compute) + cfg.ddr_latency_cycles
}

/// True iff this design point is memory-bound for the projection
/// (arithmetic intensity below machine balance — paper's roofline
/// conclusion).
pub fn is_memory_bound(cfg: &AcceleratorConfig) -> bool {
    // AI = 2 flops / operand_bytes; machine balance = peak flops/cycle
    // over bytes/cycle.
    let ai = 2.0 / (cfg.operand_bits as f64 / 8.0);
    let peak_flops_per_cycle = 2.0 * cfg.nee_lanes as f64;
    let balance = peak_flops_per_cycle / cfg.ddr_bytes_per_cycle();
    ai < balance
}

/// Non-streamed alternative: issue-limited narrow reads (one operand per
/// request, no burst, latency partially pipelined at 4 outstanding).
pub fn cycles_unstreamed(d: usize, s: usize, cfg: &AcceleratorConfig) -> u64 {
    let elems = d as u64 * s as u64;
    // Each read beats out one operand-width word; effective bandwidth
    // collapses to operand_bits/axi_width of the streamed rate.
    let shrink = cfg.axi_width_bits as u64 / cfg.operand_bits as u64;
    let bytes = elems * (cfg.operand_bits as u64 / 8);
    let mem = (bytes as f64 * shrink as f64 / cfg.ddr_bytes_per_cycle()).ceil() as u64;
    mem + cfg.ddr_latency_cycles
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zcu104_is_memory_bound() {
        // Paper §5.2.5: AI = 0.5 < machine balance ≈ 1.11 at 32 lanes; at
        // our 16 lanes balance = 32/57.6 ≈ 0.56 — still memory-bound.
        assert!(is_memory_bound(&AcceleratorConfig::zcu104()));
    }

    #[test]
    fn memory_bound_cycle_count() {
        let cfg = AcceleratorConfig::zcu104();
        let d = 10_000;
        let s = 300;
        let c = cycles(d, s, &cfg);
        // 12 MB / 57.6 B-per-cycle ≈ 208334 cycles + latency
        let mem = (d as f64 * s as f64 * 4.0 / 57.6).ceil() as u64;
        assert_eq!(c, mem + cfg.ddr_latency_cycles);
        // Compute stream is lighter: d*s/16 < mem
        assert!((d as u64 * s as u64) / 16 < mem);
    }

    #[test]
    fn streaming_wins_big() {
        let cfg = AcceleratorConfig::zcu104();
        let streamed = cycles(10_000, 300, &cfg);
        let naive = cycles_unstreamed(10_000, 300, &cfg);
        assert!(
            naive > streamed * 10,
            "expected ~16x from burst widening: {naive} vs {streamed}"
        );
    }

    #[test]
    fn compute_bound_when_lanes_scarce() {
        let mut cfg = AcceleratorConfig::zcu104();
        cfg.nee_lanes = 2;
        assert!(!is_memory_bound(&cfg));
        let c = cycles(1000, 100, &cfg);
        assert_eq!(c, (1000 * 100) / 2 + cfg.ddr_latency_cycles);
    }
}
