//! LSHU cycle model (paper §5.2.1 / Fig 2): a DenseMV unit for `c = F u`
//! plus a scheduled SpMV unit for the hop-wise `c ← A c` applications.

use crate::infer::InferTrace;
use crate::sim::config::AcceleratorConfig;

/// Cycles for all hops of LSH code generation.
///
/// * DenseMV: `N×f` MACs spread over `pes` PEs, once per hop (the
///   restructured chain recomputes `F u^(t)` per hop with fresh `u`).
/// * SpMV: one scheduled pass over `A` per chain application; the
///   schedule already encodes load (im)balance, so its cycle count is the
///   per-iteration max row cost summed over iterations.
/// * Floor/quantize is fused into the MAC drain (1 cycle/element,
///   pipelined — absorbed into the DenseMV term).
pub fn cycles(trace: &InferTrace, cfg: &AcceleratorConfig, load_balanced: bool) -> u64 {
    let hops = trace.hops.len() as u64;
    let dense_mv = hops * (trace.n as u64 * trace.f as u64).div_ceil(cfg.pes as u64);
    let per_apply = if load_balanced {
        trace.a_spmv_cycles_lb
    } else {
        trace.a_spmv_cycles_nolb
    };
    // Per-application pipeline fill (schedule fetch + CSR row_ptr read).
    let fill = 4u64;
    dense_mv + trace.a_spmv_applications * (per_apply + fill)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::infer::HopTrace;

    fn trace() -> InferTrace {
        InferTrace {
            n: 100,
            f: 10,
            nnz_a: 400,
            a_spmv_cycles_lb: 110,
            a_spmv_cycles_nolb: 200,
            a_spmv_applications: 3,
            hops: vec![HopTrace::default(); 3],
            s: 32,
            d: 1024,
            num_classes: 2,
        }
    }

    #[test]
    fn dense_and_sparse_terms() {
        let cfg = AcceleratorConfig::zcu104();
        let lb = cycles(&trace(), &cfg, true);
        // dense: 3 * ceil(1000/4)=750; sparse: 3*(110+4)=342
        assert_eq!(lb, 750 + 342);
        let nolb = cycles(&trace(), &cfg, false);
        assert!(nolb > lb);
        assert_eq!(nolb, 750 + 3 * 204);
    }
}
