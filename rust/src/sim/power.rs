//! FPGA power/energy model. Energy per inference integrates per-engine
//! dynamic power over each engine's active time plus device static power
//! over the whole inference — reproducing the paper's Table 7 metric
//! (mJ/graph) and its reported 0.70–0.86 W average device power.

use super::accelerator::CycleBreakdown;
use super::config::AcceleratorConfig;

/// Dynamic power per engine while active, plus device static power.
/// Values are calibrated to land ZCU104 post-implementation reports in
/// the paper's 0.7–0.9 W band: static PL power dominates; the NEE's DDR
/// interface + 16 FP32 MACs are the largest dynamic contributor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PowerModel {
    /// Static (leakage + clocking) watts, always on.
    pub static_w: f64,
    pub lshu_w: f64,
    pub mphe_w: f64,
    pub hue_w: f64,
    pub kse_w: f64,
    /// NEE MAC array + stream FIFO.
    pub nee_w: f64,
    /// DDR controller + PHY activity while streaming.
    pub ddr_w: f64,
    pub sce_w: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        Self {
            static_w: 0.62,
            lshu_w: 0.11,
            mphe_w: 0.05,
            hue_w: 0.04,
            kse_w: 0.09,
            nee_w: 0.14,
            ddr_w: 0.18,
            sce_w: 0.06,
        }
    }
}

/// Energy/power report for one inference.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EnergyReport {
    /// Total energy in millijoules.
    pub energy_mj: f64,
    /// Average device power in watts over the inference.
    pub avg_power_w: f64,
    /// End-to-end time in ms.
    pub time_ms: f64,
}

impl PowerModel {
    /// Integrate energy over a cycle breakdown.
    pub fn energy(&self, b: &CycleBreakdown, cfg: &AcceleratorConfig) -> EnergyReport {
        let t = |cycles: u64| cycles as f64 / cfg.freq_hz; // seconds
        let total_s = t(b.total());
        let dynamic_j = self.lshu_w * t(b.lshu)
            + self.mphe_w * t(b.mphe)
            + self.hue_w * t(b.hue)
            + self.kse_w * t(b.kse)
            + (self.nee_w + self.ddr_w) * t(b.nee)
            + self.sce_w * t(b.sce);
        let energy_j = self.static_w * total_s + dynamic_j;
        EnergyReport {
            energy_mj: energy_j * 1e3,
            avg_power_w: if total_s > 0.0 { energy_j / total_s } else { 0.0 },
            time_ms: total_s * 1e3,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_in_paper_band() {
        // An NEE-dominated breakdown (the common case) must land in the
        // paper's 0.70–0.90 W window.
        let b = CycleBreakdown {
            lshu: 5_000,
            mphe: 1_000,
            hue: 1_000,
            kse: 8_000,
            nee: 200_000,
            sce: 400,
        };
        let cfg = AcceleratorConfig::zcu104();
        let rep = PowerModel::default().energy(&b, &cfg);
        assert!(
            rep.avg_power_w > 0.68 && rep.avg_power_w < 0.95,
            "power {} W outside ZCU104 band",
            rep.avg_power_w
        );
        // Energy consistency: E = P * t.
        assert!((rep.energy_mj - rep.avg_power_w * rep.time_ms).abs() < 1e-9);
    }

    #[test]
    fn idle_engines_cost_only_static() {
        let b = CycleBreakdown {
            nee: 100_000,
            ..Default::default()
        };
        let cfg = AcceleratorConfig::zcu104();
        let pm = PowerModel::default();
        let rep = pm.energy(&b, &cfg);
        let expect_w = pm.static_w + pm.nee_w + pm.ddr_w;
        assert!((rep.avg_power_w - expect_w).abs() < 1e-9);
    }
}
