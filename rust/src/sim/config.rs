//! Accelerator design point: the ZCU104 configuration of §6.1 plus the
//! knobs the ablation benches sweep (PE counts, lane counts, FIFO depth).
//!
//! Do not confuse these knobs with the host's [`crate::exec`] pool
//! (`--threads` / `NYSX_THREADS` / `Pipeline::threads`): `pes` and
//! `nee_lanes` describe the **modeled FPGA** and change simulated
//! cycles/energy, while the exec thread count only changes host
//! wall-clock — simulated results and classifications are bit-identical
//! at any exec pool size (DESIGN.md §6).

/// Device + design-point parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AcceleratorConfig {
    /// Fabric clock (paper: 300 MHz achieved).
    pub freq_hz: f64,
    /// Theoretical DDR4 bandwidth (ZCU104 PL-DDR4: 19.2 GB/s).
    pub ddr_bandwidth_gbps: f64,
    /// Sustained fraction of theoretical BW (paper: ~90% with contiguous
    /// 512-bit bursts).
    pub ddr_efficiency: f64,
    /// DRAM round-trip latency in fabric cycles (first-beat latency the
    /// stream FIFO hides after fill).
    pub ddr_latency_cycles: u64,
    /// AXI/memory-port width in bits (512 per §6.1).
    pub axi_width_bits: usize,
    /// PEs in LSHU/KSE/HUE (paper instantiates 4).
    pub pes: usize,
    /// MAC lanes in the NEE (one per FP32 in a 512-bit beat: 16).
    pub nee_lanes: usize,
    /// Stream FIFO depth in beats (paper: 512).
    pub fifo_depth: usize,
    /// Operand precision in bits streamed from DDR (FP32).
    pub operand_bits: usize,
    /// MPHE pipeline depth (hash + probe + rank + verify stages).
    pub mphe_pipeline_depth: u64,
    /// On-chip BRAM capacity in bytes (ZCU104: 4.5 MB).
    pub bram_bytes: usize,
}

impl Default for AcceleratorConfig {
    fn default() -> Self {
        Self::zcu104()
    }
}

impl AcceleratorConfig {
    /// The paper's ZCU104 design point.
    pub fn zcu104() -> Self {
        Self {
            freq_hz: 300e6,
            ddr_bandwidth_gbps: 19.2,
            ddr_efficiency: 0.90,
            ddr_latency_cycles: 120,
            axi_width_bits: 512,
            pes: 4,
            nee_lanes: 16,
            fifo_depth: 512,
            operand_bits: 32,
            mphe_pipeline_depth: 8,
            bram_bytes: 4_500_000,
        }
    }

    /// Sustained DDR bytes per fabric cycle.
    pub fn ddr_bytes_per_cycle(&self) -> f64 {
        self.ddr_bandwidth_gbps * 1e9 * self.ddr_efficiency / self.freq_hz
    }

    /// Operands delivered per 512-bit beat (the paper's y/x unpacking).
    pub fn operands_per_beat(&self) -> usize {
        self.axi_width_bits / self.operand_bits
    }

    /// Convert cycles to milliseconds.
    pub fn cycles_to_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / self.freq_hz * 1e3
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zcu104_constants() {
        let c = AcceleratorConfig::zcu104();
        // 19.2 GB/s * 0.9 / 300 MHz = 57.6 bytes/cycle
        assert!((c.ddr_bytes_per_cycle() - 57.6).abs() < 1e-9);
        assert_eq!(c.operands_per_beat(), 16);
        assert!((c.cycles_to_ms(300_000) - 1.0).abs() < 1e-12);
    }
}
