//! FPGA resource estimation (reproduces Table 3). LUT/FF/DSP counts are
//! composed from per-engine primitive costs at the §6.1 design point;
//! BRAM is derived from the actual on-chip buffer inventory of a trained
//! model. Constants follow typical Vitis HLS FP32 operator costs on
//! UltraScale+ (fmul ≈ 3 DSP, fadd ≈ 2 DSP, ~450 LUT / ~600 FF per MAC
//! lane) plus AXI SmartConnect overhead [1].

use super::config::AcceleratorConfig;
use crate::model::MemoryReport;

/// ZCU104 device budgets (Table 3 "Available" column).
pub const ZCU104_LUT: usize = 230_400;
pub const ZCU104_FF: usize = 460_800;
pub const ZCU104_BRAM18: usize = 624;
pub const ZCU104_DSP: usize = 1_728;
pub const ZCU104_URAM: usize = 96;

/// Estimated utilization of one design point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ResourceReport {
    pub lut: usize,
    pub ff: usize,
    pub bram18: usize,
    pub dsp: usize,
    pub uram: usize,
}

impl ResourceReport {
    pub fn utilization(&self) -> [(&'static str, usize, usize, f64); 5] {
        [
            ("LUT", self.lut, ZCU104_LUT, self.lut as f64 / ZCU104_LUT as f64),
            ("FF", self.ff, ZCU104_FF, self.ff as f64 / ZCU104_FF as f64),
            (
                "BRAM (18K)",
                self.bram18,
                ZCU104_BRAM18,
                self.bram18 as f64 / ZCU104_BRAM18 as f64,
            ),
            ("DSP", self.dsp, ZCU104_DSP, self.dsp as f64 / ZCU104_DSP as f64),
            ("URAM", self.uram, ZCU104_URAM, self.uram as f64 / ZCU104_URAM as f64),
        ]
    }

    pub fn fits(&self) -> bool {
        self.lut <= ZCU104_LUT
            && self.ff <= ZCU104_FF
            && self.bram18 <= ZCU104_BRAM18
            && self.dsp <= ZCU104_DSP
            && self.uram <= ZCU104_URAM
    }
}

// Per-primitive costs (Vitis HLS FP32 on UltraScale+; see module docs).
const DSP_PER_FP32_MAC: usize = 5; // 3 (fmul) + 2 (fadd)
const LUT_PER_FP32_MAC: usize = 450;
const FF_PER_FP32_MAC: usize = 640;

/// 18Kb BRAM blocks for `bytes` of storage (2,304 bytes per block, ≥1
/// block per physically separate bank).
fn bram_blocks(bytes: usize, banks: usize) -> usize {
    let per_bank = bytes.div_ceil(banks.max(1));
    banks.max(1) * per_bank.div_ceil(2_304)
}

/// Estimate the design's resource utilization. The logic estimate is a
/// static function of the design point; the BRAM estimate additionally
/// needs the deployed model's on-chip buffer sizes.
pub fn estimate(cfg: &AcceleratorConfig, mem: &MemoryReport, max_hist_bins: usize) -> ResourceReport {
    let pes = cfg.pes;
    let lanes = cfg.nee_lanes;

    // --- DSP ---
    let nee_dsp = lanes * DSP_PER_FP32_MAC;
    let lshu_dsp = pes * DSP_PER_FP32_MAC + pes * 3; // MACs + 1/w quantize fmul
    let kse_dsp = pes * DSP_PER_FP32_MAC;
    let mphe_dsp = 8; // xorshift rehash 64-bit constant multiplier
    let misc_dsp = 16; // similarity scaling, argmax tie-break datapath
    let dsp = nee_dsp + lshu_dsp + kse_dsp + mphe_dsp + misc_dsp;

    // --- LUT / FF ---
    let mac_lut = (lanes + 2 * pes) * LUT_PER_FP32_MAC;
    let mac_ff = (lanes + 2 * pes) * FF_PER_FP32_MAC;
    let lut = mac_lut
        + 6_200          // MPHE: 4 hash engines + rank/popcount units
        + 2_600          // HUE adder trees
        + 4_800          // SCE bipolar add trees (64-wide)
        + 7_400          // bank conflict resolvers + schedule fetch logic
        + 13_500         // AXI SmartConnect + DDR4 stream interface [1]
        + 9_000          // control FSMs, CSRs, top-level plumbing
        + cfg.fifo_depth / 8; // FIFO pointers/flags scale with depth
    let ff = mac_ff
        + 8_200
        + 3_400
        + 5_600
        + 9_800
        + 21_000
        + 12_000
        + cfg.fifo_depth / 4;

    // --- BRAM ---
    // Stream FIFO: depth × beat-width bits.
    let fifo_bytes = cfg.fifo_depth * cfg.axi_width_bits / 8;
    let mut bram = bram_blocks(fifo_bytes, lanes.min(8));
    // Query histograms: pes private copies + merged, banked per PE.
    bram += bram_blocks((pes + 1) * max_hist_bins * 4, pes + 1);
    // Landmark hists (CSR), codebook stores, MPH level tables + ranks,
    // schedule tables, prototypes — all banked across PEs.
    bram += bram_blocks(mem.hists_csr, pes);
    bram += bram_blocks(mem.codebooks, pes);
    bram += bram_blocks(mem.mph, pes);
    bram += bram_blocks(mem.schedules, pes);
    bram += bram_blocks(mem.prototypes, 2);
    // C vector + output HV staging (cyclically partitioned).
    bram += bram_blocks(4 * 1024, 4) + bram_blocks(16 * 1024, 4);

    ResourceReport {
        lut,
        ff,
        bram18: bram,
        dsp,
        uram: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn typical_mem() -> MemoryReport {
        // Representative trained model (MUTAG-scale): small CSR hists,
        // codebooks of a few thousand entries, MPH ≈ 3 bits/key.
        MemoryReport {
            codebooks: 60_000,
            hists_dense: 2_000_000,
            hists_csr: 220_000,
            p_nys: 12_000_000,
            prototypes: 20_000,
            mph: 12_000,
            schedules: 6_000,
        }
    }

    #[test]
    fn near_table3_at_paper_design_point() {
        let cfg = AcceleratorConfig::zcu104();
        let r = estimate(&cfg, &typical_mem(), 4_096);
        // Paper Table 3: LUT 71,900; FF 87,800; BRAM 329; DSP 156.
        assert!(
            (r.lut as f64 - 71_900.0).abs() / 71_900.0 < 0.25,
            "LUT {} vs 71900",
            r.lut
        );
        assert!(
            (r.ff as f64 - 87_800.0).abs() / 87_800.0 < 0.25,
            "FF {} vs 87800",
            r.ff
        );
        assert!(
            (r.dsp as f64 - 156.0).abs() / 156.0 < 0.25,
            "DSP {} vs 156",
            r.dsp
        );
        assert!(
            (r.bram18 as f64 - 329.0).abs() / 329.0 < 0.5,
            "BRAM {} vs 329",
            r.bram18
        );
        assert_eq!(r.uram, 0);
        assert!(r.fits());
    }

    #[test]
    fn scaling_with_lanes() {
        let mut cfg = AcceleratorConfig::zcu104();
        let base = estimate(&cfg, &typical_mem(), 4_096);
        cfg.nee_lanes = 32;
        let wide = estimate(&cfg, &typical_mem(), 4_096);
        assert!(wide.dsp > base.dsp);
        assert!(wide.lut > base.lut);
    }
}
