//! The exec shadow checker — dynamic validation of the partition
//! invariants that `exec/parallel.rs` otherwise takes on proof
//! (DESIGN.md §9).
//!
//! Every unsafe dispatch in the runtime rests on one claim: within a
//! parallel region, no element is written by more than one part. The
//! static side (`validate_disjoint`, the partitioner property tests,
//! `nysx race`) proves it for contiguous ranges; [`ScatterMut`] writes
//! are only a `# Safety` contract. Under `NYSX_EXEC_CHECK=1` this module
//! turns that contract into a checked one: every parallel region opens
//! an **epoch** in a process-wide claim table, every part's write
//! interval (or scattered index) is recorded as a claim against that
//! epoch, and two claims that touch the same element abort with a typed
//! [`ClaimViolation`] report *before* the aliasing write happens. A
//! claim arriving after its region retired is a [`cross-epoch
//! leak`](ClaimViolation::CrossEpochLeak) — a write outlives the borrow
//! that justified it.
//!
//! Claims are keyed by **part**, not by thread: two parts writing one
//! element are flagged even when a small pool happens to run them
//! sequentially on one lane, because that overlap makes the output
//! depend on the schedule — the exact bug class the bit-identical
//! contract bans. This is why the checker catches schedule-dependent
//! races at *any* thread count, including 1.
//!
//! # Schedule perturbation
//!
//! The same env gate carries a seeded schedule-perturbation harness:
//! with `NYSX_EXEC_SEED=<nonzero>` (or [`force_perturb_seed`] in tests),
//! [`Pool::run`] executes each lane's parts in a seeded permutation of
//! their static order instead of ascending. Results must not move — the
//! differential suites assert bit-identity across seeds, which
//! empirically pins the claim that part execution order is immaterial.
//!
//! # Cost when off
//!
//! Everything is behind [`enabled`] / [`perturb_seed`], each one cached
//! env read plus a thread-local test override — a branch per region (not
//! per element) on the hot paths.
//!
//! [`ScatterMut`]: super::ScatterMut
//! [`Pool::run`]: super::Pool::run

use std::cell::Cell;
use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Mutex, OnceLock};

/// Env var enabling the shadow checker (`1` = on).
pub const ENV_CHECK: &str = "NYSX_EXEC_CHECK";
/// Env var carrying the schedule-perturbation seed (nonzero = on).
pub const ENV_SEED: &str = "NYSX_EXEC_SEED";

thread_local! {
    /// Per-thread test override for [`enabled`]: `None` defers to the
    /// environment. Thread-local so concurrently running tests cannot
    /// perturb each other through a process global.
    static FORCED_CHECK: Cell<Option<bool>> = const { Cell::new(None) };
    /// Per-thread test override for [`perturb_seed`] (`Some(0)` forces
    /// perturbation *off* even when `NYSX_EXEC_SEED` is set).
    static FORCED_SEED: Cell<Option<u64>> = const { Cell::new(None) };
    /// The part index currently executing on this thread (claims from
    /// [`ScatterMut`](super::ScatterMut) writes are attributed to it);
    /// [`CALLER_PART`] outside any pool part.
    static CURRENT_PART: Cell<usize> = const { Cell::new(CALLER_PART) };
}

/// Claim owner for writes issued outside any pool part (single-threaded
/// setup code touching a buffer before/after a region).
pub const CALLER_PART: usize = usize::MAX;

fn env_enabled() -> bool {
    static CACHE: OnceLock<bool> = OnceLock::new();
    *CACHE.get_or_init(|| std::env::var(ENV_CHECK).as_deref() == Ok("1"))
}

fn env_seed() -> u64 {
    static CACHE: OnceLock<u64> = OnceLock::new();
    *CACHE.get_or_init(|| {
        std::env::var(ENV_SEED)
            .ok()
            .and_then(|v| v.trim().parse::<u64>().ok())
            .unwrap_or(0)
    })
}

/// Is shadow checking live on this thread? (`NYSX_EXEC_CHECK=1`, or a
/// [`force_enabled`] guard in scope.)
#[inline]
pub fn enabled() -> bool {
    FORCED_CHECK.with(|c| c.get()).unwrap_or_else(env_enabled)
}

/// The active schedule-perturbation seed (0 = off): a [`force_perturb_seed`]
/// guard on this thread wins, then `NYSX_EXEC_SEED`.
#[inline]
pub fn perturb_seed() -> u64 {
    FORCED_SEED.with(|c| c.get()).unwrap_or_else(env_seed)
}

/// RAII override of [`enabled`] for the current thread; restores the
/// previous override on drop (including during unwinding, which is what
/// `#[should_panic]` probes rely on).
pub struct CheckGuard {
    prev: Option<bool>,
}

impl Drop for CheckGuard {
    fn drop(&mut self) {
        FORCED_CHECK.with(|c| c.set(self.prev));
    }
}

/// Force [`enabled`] on or off for this thread until the guard drops.
#[must_use]
pub fn force_enabled(on: bool) -> CheckGuard {
    let prev = FORCED_CHECK.with(|c| c.replace(Some(on)));
    CheckGuard { prev }
}

/// RAII override of [`perturb_seed`] for the current thread.
pub struct PerturbGuard {
    prev: Option<u64>,
}

impl Drop for PerturbGuard {
    fn drop(&mut self) {
        FORCED_SEED.with(|c| c.set(self.prev));
    }
}

/// Force the perturbation seed for this thread until the guard drops
/// (0 forces perturbation off, shadowing `NYSX_EXEC_SEED`).
#[must_use]
pub fn force_perturb_seed(seed: u64) -> PerturbGuard {
    let prev = FORCED_SEED.with(|c| c.replace(Some(seed)));
    PerturbGuard { prev }
}

/// Attribute claims on this thread to part `p` until the guard drops
/// (the pool wraps every part invocation in one when checking is on).
#[must_use]
pub fn enter_part(p: usize) -> PartGuard {
    let prev = CURRENT_PART.with(|c| c.replace(p));
    PartGuard { prev }
}

/// The part claims on this thread are currently attributed to.
#[inline]
pub fn current_part() -> usize {
    CURRENT_PART.with(|c| c.get())
}

/// Restores the previous part attribution on drop (panic-safe, so a
/// panicking part cannot misattribute later claims on a pooled thread).
pub struct PartGuard {
    prev: usize,
}

impl Drop for PartGuard {
    fn drop(&mut self) {
        CURRENT_PART.with(|c| c.set(self.prev));
    }
}

/// A detected violation of the write-disjointness contract — the typed
/// report the checker aborts with.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ClaimViolation {
    /// Two parts claimed intersecting write intervals inside one epoch.
    OverlappingClaim {
        epoch: u64,
        /// The earlier claim: (part, start, end).
        held: (usize, usize, usize),
        /// The incoming claim: (part, start, end).
        incoming: (usize, usize, usize),
    },
    /// A claim arrived for an epoch that already retired — a write
    /// outliving the parallel region that justified it.
    CrossEpochLeak { epoch: u64, part: usize, index: usize },
}

impl fmt::Display for ClaimViolation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let part_name = |p: usize| {
            if p == CALLER_PART {
                "caller".to_string()
            } else {
                format!("part {p}")
            }
        };
        match self {
            ClaimViolation::OverlappingClaim { epoch, held, incoming } => write!(
                f,
                "overlapping write claim in epoch {epoch}: {} claims {}..{} but {} already \
                 claims {}..{} — parts must write disjoint elements",
                part_name(incoming.0),
                incoming.1,
                incoming.2,
                part_name(held.0),
                held.1,
                held.2,
            ),
            ClaimViolation::CrossEpochLeak { epoch, part, index } => write!(
                f,
                "cross-epoch claim leak: {} wrote index {index} against retired epoch {epoch} \
                 — the write outlived its parallel region",
                part_name(*part),
            ),
        }
    }
}

/// Claims held by one live region: the contiguous intervals recorded up
/// front by `for_each_range_mut`, plus scattered per-index claims from
/// `ScatterMut` writes.
#[derive(Debug, Default)]
struct RegionClaims {
    /// (start, end, part), in claim order.
    ranges: Vec<(usize, usize, usize)>,
    /// index → owning part.
    indices: BTreeMap<usize, usize>,
}

#[derive(Debug)]
struct TableState {
    next_epoch: u64,
    live: BTreeMap<u64, RegionClaims>,
}

static TABLE: Mutex<TableState> = Mutex::new(TableState {
    next_epoch: 1,
    live: BTreeMap::new(),
});

fn table() -> std::sync::MutexGuard<'static, TableState> {
    // A panic while holding the lock is impossible (no user code runs
    // under it), but stay poison-proof like the coordinator locks.
    TABLE.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// A live parallel region in the claim table; claims are validated
/// against its epoch, and dropping it retires the epoch (claims against
/// it afterwards are cross-epoch leaks).
#[derive(Debug)]
pub struct Region {
    epoch: u64,
}

impl Region {
    #[inline]
    pub fn epoch(&self) -> u64 {
        self.epoch
    }
}

impl Drop for Region {
    fn drop(&mut self) {
        table().live.remove(&self.epoch);
    }
}

/// Open a new epoch for one parallel region over one buffer.
pub fn begin_region() -> Region {
    let mut t = table();
    let epoch = t.next_epoch;
    t.next_epoch += 1;
    t.live.insert(epoch, RegionClaims::default());
    Region { epoch }
}

/// Record `part`'s claim to the write interval `start..end` in `epoch`.
/// Empty intervals claim nothing. Errors on intersection with any other
/// claim in the epoch, or if the epoch already retired.
pub fn claim_range(
    epoch: u64,
    part: usize,
    start: usize,
    end: usize,
) -> Result<(), ClaimViolation> {
    if start >= end {
        return Ok(());
    }
    let mut t = table();
    let Some(region) = t.live.get_mut(&epoch) else {
        return Err(ClaimViolation::CrossEpochLeak { epoch, part, index: start });
    };
    for &(s, e, p) in &region.ranges {
        if start < e && s < end {
            return Err(ClaimViolation::OverlappingClaim {
                epoch,
                held: (p, s, e),
                incoming: (part, start, end),
            });
        }
    }
    if let Some((&i, &p)) = region.indices.range(start..end).next() {
        return Err(ClaimViolation::OverlappingClaim {
            epoch,
            held: (p, i, i + 1),
            incoming: (part, start, end),
        });
    }
    region.ranges.push((start, end, part));
    Ok(())
}

/// Record `part`'s claim to the single element `index` in `epoch` (a
/// `ScatterMut` write). Re-claiming an element the *same* part already
/// owns is fine (write-then-update patterns); a different owner is an
/// overlap, and a retired epoch is a leak.
pub fn claim_index(epoch: u64, part: usize, index: usize) -> Result<(), ClaimViolation> {
    let mut t = table();
    let Some(region) = t.live.get_mut(&epoch) else {
        return Err(ClaimViolation::CrossEpochLeak { epoch, part, index });
    };
    for &(s, e, p) in &region.ranges {
        if s <= index && index < e && p != part {
            return Err(ClaimViolation::OverlappingClaim {
                epoch,
                held: (p, s, e),
                incoming: (part, index, index + 1),
            });
        }
    }
    match region.indices.get(&index) {
        Some(&p) if p != part => Err(ClaimViolation::OverlappingClaim {
            epoch,
            held: (p, index, index + 1),
            incoming: (part, index, index + 1),
        }),
        Some(_) => Ok(()),
        None => {
            region.indices.insert(index, part);
            Ok(())
        }
    }
}

/// Abort with the typed report — the checker's failure mode. A data race
/// about to happen is not a degradable condition; the panic carries the
/// full [`ClaimViolation`] rendering for the test/CI log.
#[cold]
pub fn abort(v: ClaimViolation) -> ! {
    panic!("exec check: {v}")
}

/// Seeded Fisher–Yates permutation of one lane's part list (xorshift64,
/// fully deterministic across platforms): the schedule-perturbation
/// harness. Seeds differ per lane so lanes do not share an order.
pub fn permute_parts(seed: u64, lane: usize, parts: &mut [usize]) {
    let mut s = seed ^ (lane as u64).wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    if s == 0 {
        s = 0x2545_F491_4F6C_DD1D;
    }
    for i in (1..parts.len()).rev() {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        let j = (s % (i as u64 + 1)) as usize;
        parts.swap(i, j);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlapping_range_claims_are_typed_errors() {
        let region = begin_region();
        claim_range(region.epoch(), 0, 0, 6).expect("first claim");
        let err = claim_range(region.epoch(), 1, 5, 10).expect_err("overlap");
        assert_eq!(
            err,
            ClaimViolation::OverlappingClaim {
                epoch: region.epoch(),
                held: (0, 0, 6),
                incoming: (1, 5, 10),
            }
        );
        assert!(err.to_string().contains("overlapping write claim"), "{err}");
        // Disjoint claims are fine, in any order.
        claim_range(region.epoch(), 2, 6, 9).expect("disjoint");
        claim_range(region.epoch(), 3, 20, 25).expect("disjoint");
        claim_range(region.epoch(), 4, 10, 20).expect("disjoint, out of order");
    }

    #[test]
    fn empty_range_claims_nothing() {
        let region = begin_region();
        claim_range(region.epoch(), 0, 5, 5).expect("empty");
        claim_range(region.epoch(), 1, 0, 10).expect("whole buffer still free");
    }

    #[test]
    fn index_claims_conflict_only_across_parts() {
        let region = begin_region();
        claim_index(region.epoch(), 3, 7).expect("first write");
        claim_index(region.epoch(), 3, 7).expect("same part re-writes (write+update)");
        let err = claim_index(region.epoch(), 4, 7).expect_err("cross-part overlap");
        assert!(matches!(err, ClaimViolation::OverlappingClaim { .. }), "{err:?}");
        // Index claims also collide with range claims of other parts.
        claim_range(region.epoch(), 0, 100, 110).expect("range");
        let err = claim_index(region.epoch(), 1, 105).expect_err("index inside range");
        assert!(matches!(err, ClaimViolation::OverlappingClaim { .. }), "{err:?}");
        claim_index(region.epoch(), 0, 105).expect("owning part may scatter into its range");
        let err = claim_range(region.epoch(), 5, 6, 9).expect_err("range over index 7");
        assert!(matches!(err, ClaimViolation::OverlappingClaim { .. }), "{err:?}");
    }

    #[test]
    fn retired_epoch_is_a_cross_epoch_leak() {
        let region = begin_region();
        let epoch = region.epoch();
        claim_index(epoch, 0, 3).expect("live");
        drop(region);
        let err = claim_index(epoch, 0, 4).expect_err("epoch retired");
        assert_eq!(err, ClaimViolation::CrossEpochLeak { epoch, part: 0, index: 4 });
        assert!(err.to_string().contains("cross-epoch claim leak"), "{err}");
        let err = claim_range(epoch, 1, 0, 2).expect_err("range against retired epoch");
        assert!(matches!(err, ClaimViolation::CrossEpochLeak { .. }), "{err:?}");
    }

    #[test]
    fn regions_are_independent_epochs() {
        let a = begin_region();
        let b = begin_region();
        assert_ne!(a.epoch(), b.epoch());
        // The same interval may be claimed once per region.
        claim_range(a.epoch(), 0, 0, 10).expect("region a");
        claim_range(b.epoch(), 0, 0, 10).expect("region b");
    }

    #[test]
    fn guards_are_nestable_and_restore() {
        assert_eq!(current_part(), CALLER_PART);
        {
            let _outer = enter_part(2);
            assert_eq!(current_part(), 2);
            {
                let _inner = enter_part(5);
                assert_eq!(current_part(), 5);
            }
            assert_eq!(current_part(), 2);
        }
        assert_eq!(current_part(), CALLER_PART);

        let ambient = enabled();
        {
            let _on = force_enabled(true);
            assert!(enabled());
            {
                let _off = force_enabled(false);
                assert!(!enabled());
            }
            assert!(enabled());
        }
        assert_eq!(enabled(), ambient);

        let ambient = perturb_seed();
        {
            let _g = force_perturb_seed(9);
            assert_eq!(perturb_seed(), 9);
        }
        assert_eq!(perturb_seed(), ambient);
    }

    #[test]
    fn permute_parts_is_a_deterministic_permutation() {
        let base: Vec<usize> = (0..23).map(|p| p * 2).collect();
        let mut a = base.clone();
        let mut b = base.clone();
        permute_parts(7, 1, &mut a);
        permute_parts(7, 1, &mut b);
        assert_eq!(a, b, "same seed+lane → same order");
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, base, "still a permutation");
        let mut c = base.clone();
        permute_parts(8, 1, &mut c);
        assert_ne!(a, c, "different seed → different order (23! ≫ collisions)");
        let mut d = base.clone();
        permute_parts(7, 2, &mut d);
        assert_ne!(a, d, "different lane → different order");
        // Degenerate sizes survive.
        let mut empty: [usize; 0] = [];
        permute_parts(7, 0, &mut empty);
        let mut one = [4usize];
        permute_parts(7, 0, &mut one);
        assert_eq!(one, [4]);
    }
}
