//! The scoped worker pool — the software analogue of the paper's PE
//! array.
//!
//! # Shape
//!
//! A [`Pool`] of `T` threads consists of `T - 1` parked worker threads
//! plus the calling thread, which always executes lane 0 of every
//! [`Pool::run`] — so `Pool::new(1)` spawns nothing and every `run` is a
//! plain sequential loop (the oracle configuration the differential
//! suite pins every other thread count against).
//!
//! # Scoped dispatch
//!
//! [`Pool::run`] takes `&(dyn Fn(usize) + Sync)` over *borrowed* data —
//! no `'static` bound — and does not return until every lane has
//! finished (a completion latch is waited on even if a lane panics), so
//! the closure and everything it borrows provably outlives all worker
//! use. That is the entire safety argument for the one lifetime
//! transmute in this module.
//!
//! # Static assignment, not work stealing
//!
//! `run(parts, f)` assigns part `p` to lane `p % lanes` — decided before
//! anything is dispatched, exactly like the paper's §4.2 iteration-wise
//! schedule tables and unlike a work-stealing runtime. Which lane (OS
//! thread) executes a part can never influence results anyway: callers
//! make every part's writes disjoint and every reduction fixed-order, so
//! outputs are bit-identical at any thread count. Load balance comes
//! from the partitioners in [`super::partition`] sizing the parts
//! evenly (by rows, classes, or nnz) up front.
//!
//! # Process-wide pool
//!
//! [`global`] lazily builds one shared pool sized by (in priority
//! order) [`configure_threads`] (the `--threads` CLI flag), the
//! `NYSX_THREADS` environment variable, or
//! `std::thread::available_parallelism()`. Dedicated pools
//! ([`Pool::new`]) serve tests, benches, and
//! `Pipeline::threads(n)`-scoped runs.
//!
//! # Nesting
//!
//! A `run` issued from inside a pool lane (any pool's) executes inline
//! and sequentially on that lane — parallel kernels can therefore call
//! other parallel kernels without deadlock or oversubscription, and the
//! inner kernel's results are unchanged because every kernel is
//! bit-identical at any lane count, including one.

use std::cell::Cell;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

use super::check;

thread_local! {
    /// True while this thread is executing a pool lane (worker threads
    /// always; the caller thread during its inline lane 0).
    static IN_POOL_LANE: Cell<bool> = const { Cell::new(false) };
}

/// Completion latch for one `run`: counts outstanding worker lanes and
/// remembers whether any of them panicked.
struct Latch {
    state: Mutex<LatchState>,
    cv: Condvar,
}

struct LatchState {
    remaining: usize,
    panicked: bool,
}

impl Latch {
    fn new(remaining: usize) -> Self {
        Self {
            state: Mutex::new(LatchState {
                remaining,
                panicked: false,
            }),
            cv: Condvar::new(),
        }
    }

    fn lane_done(&self, panicked: bool) {
        let mut st = self.state.lock().unwrap();
        st.remaining -= 1;
        st.panicked |= panicked;
        if st.remaining == 0 {
            drop(st);
            self.cv.notify_all();
        }
    }

    /// Block until every worker lane finished; report whether any panicked.
    fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.remaining > 0 {
            st = self.cv.wait(st).unwrap();
        }
        st.panicked
    }
}

/// One dispatched lane of a `run`.
struct Job {
    /// The erased lane closure. SAFETY: points at a stack closure in the
    /// dispatching `run`, which waits on `latch` before returning (or
    /// unwinding), so the reference is live for the job's whole life.
    task: &'static (dyn Fn(usize) + Sync),
    lane: usize,
    latch: Arc<Latch>,
}

/// Waits for the latch on drop — including during unwinding — so `run`
/// can never leave a worker holding a reference into a dead stack frame.
struct WaitGuard<'a> {
    latch: &'a Latch,
}

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        self.latch.wait();
    }
}

/// Invoke one part, attributing shadow-checker claims to it while it
/// runs (a guard, so a panicking part cannot misattribute later claims
/// on a pooled thread). When checking is off this is a plain call.
#[inline]
fn call_part(f: &(dyn Fn(usize) + Sync), p: usize) {
    if check::enabled() {
        let _part = check::enter_part(p);
        f(p);
    } else {
        f(p);
    }
}

/// Run one lane's share of a `parts`-sized job: parts `lane, lane +
/// lanes, …` in ascending order — or, under an active schedule
/// perturbation seed (`NYSX_EXEC_SEED` / a test guard), in a seeded
/// permutation of that list. Results may not depend on the order:
/// every caller makes part writes disjoint and reductions fixed-order,
/// and the differential suites pin bit-identity across seeds.
/// Number of parts lane `lane` executes out of `parts` across `lanes`
/// lanes under the static `p % lanes` assignment.
#[inline]
fn lane_parts(parts: usize, lane: usize, lanes: usize) -> u64 {
    if lane >= parts {
        0
    } else {
        ((parts - lane - 1) / lanes + 1) as u64
    }
}

fn run_lane(f: &(dyn Fn(usize) + Sync), lane: usize, lanes: usize, parts: usize, perturb: u64) {
    if perturb == 0 {
        let mut p = lane;
        while p < parts {
            call_part(f, p);
            p += lanes;
        }
    } else {
        let mut order: Vec<usize> = (lane..parts).step_by(lanes).collect();
        check::permute_parts(perturb, lane, &mut order);
        for p in order {
            call_part(f, p);
        }
    }
}

fn worker_loop(rx: std::sync::mpsc::Receiver<Job>) {
    IN_POOL_LANE.with(|c| c.set(true));
    while let Ok(job) = rx.recv() {
        let result = catch_unwind(AssertUnwindSafe(|| (job.task)(job.lane)));
        job.latch.lane_done(result.is_err());
    }
}

/// A fixed-size scoped worker pool (see the module docs).
pub struct Pool {
    threads: usize,
    /// One channel per spawned worker (`threads - 1` of them): lane `l`
    /// of a run goes to worker `l - 1`, a static assignment with no
    /// shared dequeue contention.
    senders: Vec<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl Pool {
    /// Pool with exactly `threads` lanes (clamped to at least 1). The
    /// `threads - 1` workers spawn eagerly; `Pool::new(1)` spawns
    /// nothing and runs everything inline.
    pub fn new(threads: usize) -> Self {
        let threads = threads.max(1);
        let mut senders = Vec::with_capacity(threads - 1);
        let mut handles = Vec::with_capacity(threads - 1);
        for i in 1..threads {
            let (tx, rx) = channel::<Job>();
            let handle = std::thread::Builder::new()
                .name(format!("nysx-exec-{i}"))
                .spawn(move || worker_loop(rx))
                .expect("spawn exec worker");
            senders.push(tx);
            handles.push(handle);
        }
        Self {
            threads,
            senders,
            handles,
        }
    }

    /// Total lanes (spawned workers + the calling thread).
    #[inline]
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Dispatch one trivial run so worker wake-up paths (stacks, channel
    /// buffers, futexes) are warm before anything is timed. Benches call
    /// this once per pool so first-run spawn/wake cost never pollutes
    /// reported medians.
    pub fn warm_up(&self) {
        self.run(self.threads, &|_| {});
    }

    /// Execute `f(p)` for every `p in 0..parts`, each part exactly once,
    /// across at most `threads` lanes: lane `l` runs parts `l, l+lanes,
    /// l+2·lanes, …` in increasing order. Lane 0 runs on the caller.
    /// Returns only after every part has finished.
    ///
    /// With one lane (single-thread pool, one part, or a nested call
    /// from inside a pool lane) this is exactly `for p in 0..parts {
    /// f(p) }` — the sequential oracle.
    ///
    /// Panics in any lane propagate to the caller after all lanes
    /// finish (a worker-lane panic surfaces as a `"exec worker lane
    /// panicked"` panic; a caller-lane panic resumes as itself).
    pub fn run(&self, parts: usize, f: &(dyn Fn(usize) + Sync)) {
        self.run_inner(None, parts, f);
    }

    /// [`Pool::run`] with a labeled observability site: while obs is
    /// enabled, each lane's whole part-loop is wrapped in ONE
    /// `obs::clock` pair and its busy time + part count recorded into
    /// `site` (plus one run/lane-count mark per dispatch) — that's how
    /// per-site load-imbalance ratios land in `PROFILE.json`. While
    /// obs is disabled this is exactly [`Pool::run`]: the site resolves
    /// to `None` before any clock or atomic is touched. Recording can
    /// never influence the schedule or results.
    pub fn run_labeled(
        &self,
        site: &'static crate::obs::LaneSite,
        parts: usize,
        f: &(dyn Fn(usize) + Sync),
    ) {
        self.run_inner(Some(site), parts, f);
    }

    fn run_inner(
        &self,
        site: Option<&'static crate::obs::LaneSite>,
        parts: usize,
        f: &(dyn Fn(usize) + Sync),
    ) {
        if parts == 0 {
            return;
        }
        let site = if crate::obs::enabled() { site } else { None };
        // Read the perturbation seed once, on the caller, so every lane
        // of this run (worker threads included) permutes against the
        // same seed even when it came from a caller-thread test guard.
        let perturb = check::perturb_seed();
        let lanes = parts.min(self.threads);
        if lanes <= 1 || IN_POOL_LANE.with(|c| c.get()) {
            match site {
                Some(site) => {
                    site.record_run(1);
                    let t0 = crate::obs::clock::now_ns();
                    run_lane(f, 0, 1, parts, perturb);
                    site.record_lane(0, crate::obs::clock::elapsed_ns(t0), parts as u64);
                }
                None => run_lane(f, 0, 1, parts, perturb),
            }
            return;
        }

        if let Some(site) = site {
            site.record_run(lanes);
        }
        let lane_fn = move |lane: usize| match site {
            Some(site) => {
                let t0 = crate::obs::clock::now_ns();
                run_lane(f, lane, lanes, parts, perturb);
                site.record_lane(
                    lane,
                    crate::obs::clock::elapsed_ns(t0),
                    lane_parts(parts, lane, lanes),
                );
            }
            None => run_lane(f, lane, lanes, parts, perturb),
        };
        let task: &(dyn Fn(usize) + Sync) = &lane_fn;
        // SAFETY: `WaitGuard` (dropped below, on the normal path AND on
        // unwind) blocks until every worker counted down the latch, and
        // workers count down only after their last use of `task` — so
        // the borrow outlives all uses despite the erased lifetime.
        let task: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(task)
        };

        let latch = Arc::new(Latch::new(lanes - 1));
        // A worker's channel can only be closed if its thread died (it
        // never exits while the pool holds the sender). Losing a lane
        // must not lose its parts or hang the latch: count the lane
        // done and run its share inline on the caller after lane 0, so
        // the exactly-once contract survives even that degraded state.
        let mut orphaned: Vec<usize> = Vec::new();
        for lane in 1..lanes {
            let job = Job {
                task,
                lane,
                latch: latch.clone(),
            };
            if self.senders[lane - 1].send(job).is_err() {
                latch.lane_done(false);
                orphaned.push(lane);
            }
        }

        let guard = WaitGuard { latch: &latch };
        // The caller's lane counts as a pool lane too: nested plain
        // entry points inside `f` must execute inline.
        let was_in_lane = IN_POOL_LANE.with(|c| c.replace(true));
        let lane0 = catch_unwind(AssertUnwindSafe(|| {
            lane_fn(0);
            for &lane in &orphaned {
                lane_fn(lane);
            }
        }));
        IN_POOL_LANE.with(|c| c.set(was_in_lane));
        drop(guard); // blocks until all worker lanes are done

        if let Err(payload) = lane0 {
            resume_unwind(payload);
        }
        if latch.wait() {
            panic!("exec worker lane panicked");
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        // Closing the channels ends the worker loops; join for a clean
        // teardown (dedicated pools die with their Pipeline/engine).
        self.senders.clear();
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("threads", &self.threads).finish()
    }
}

/// Thread count requested via [`configure_threads`] before the global
/// pool first initializes (0 = unset).
static REQUESTED_THREADS: AtomicUsize = AtomicUsize::new(0);

static GLOBAL: OnceLock<Arc<Pool>> = OnceLock::new();

/// Upper bound on configurable thread counts — same plausibility cap
/// spirit as `ServerConfig.workers`.
pub const MAX_THREADS: usize = 4096;

/// Interpret an `NYSX_THREADS` value: a positive integer wins; unset,
/// empty, zero, or garbage fall back to `default`.
fn threads_from_env(value: Option<&str>, default: usize) -> usize {
    value
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n > 0 && n <= MAX_THREADS)
        .unwrap_or(default)
}

fn default_threads() -> usize {
    let requested = REQUESTED_THREADS.load(Ordering::Relaxed);
    if requested > 0 {
        return requested;
    }
    let hw = std::thread::available_parallelism()
        .map(std::num::NonZeroUsize::get)
        .unwrap_or(1);
    let env = std::env::var("NYSX_THREADS").ok();
    let resolved = threads_from_env(env.as_deref(), hw);
    // An invalid value falling back to all cores is bit-identical by
    // design, so nothing downstream would ever reveal the typo — warn.
    if let Some(v) = env.as_deref() {
        let valid = v
            .trim()
            .parse::<usize>()
            .ok()
            .filter(|&n| n > 0 && n <= MAX_THREADS)
            .is_some();
        if !v.trim().is_empty() && !valid {
            eprintln!(
                "warning: ignoring invalid NYSX_THREADS={v:?} (want 1..={MAX_THREADS}); \
                 using {hw} threads"
            );
        }
    }
    resolved
}

/// Pin the global pool's size (the `--threads` CLI override). Must run
/// before anything touches [`global`]; afterwards it only succeeds if it
/// agrees with the already-running pool.
pub fn configure_threads(threads: usize) -> Result<(), String> {
    if threads == 0 || threads > MAX_THREADS {
        return Err(format!(
            "thread count must be in 1..={MAX_THREADS}, got {threads}"
        ));
    }
    if let Some(pool) = GLOBAL.get() {
        if pool.threads() == threads {
            return Ok(());
        }
        return Err(format!(
            "exec pool already running with {} threads; --threads {} must be set before first use",
            pool.threads(),
            threads
        ));
    }
    REQUESTED_THREADS.store(threads, Ordering::Relaxed);
    Ok(())
}

/// The process-wide pool, built once at first use (see the module docs
/// for the sizing rule). Plain kernel entry points dispatch here; the
/// `*_with_pool` variants take an explicit pool for tests, benches, and
/// `Pipeline::threads(n)`.
pub fn global() -> Arc<Pool> {
    GLOBAL
        .get_or_init(|| Arc::new(Pool::new(default_threads())))
        .clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn every_part_runs_exactly_once_at_any_thread_count() {
        for threads in [1usize, 2, 3, 7] {
            let pool = Pool::new(threads);
            for parts in [0usize, 1, 2, 7, 64, 129] {
                let hits: Vec<AtomicUsize> =
                    (0..parts).map(|_| AtomicUsize::new(0)).collect();
                pool.run(parts, &|p| {
                    hits[p].fetch_add(1, Ordering::Relaxed);
                });
                for (p, h) in hits.iter().enumerate() {
                    assert_eq!(
                        h.load(Ordering::Relaxed),
                        1,
                        "part {p} ran a wrong number of times (threads={threads}, parts={parts})"
                    );
                }
            }
        }
    }

    #[test]
    fn borrowed_data_is_visible_and_writes_complete_before_return() {
        let pool = Pool::new(4);
        let input: Vec<u64> = (0..1000).collect();
        let sum = AtomicU64::new(0);
        pool.run(8, &|p| {
            let chunk: u64 = input[p * 125..(p + 1) * 125].iter().sum();
            sum.fetch_add(chunk, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 1000 * 999 / 2);
    }

    #[test]
    fn single_thread_pool_is_strictly_sequential_in_order() {
        // Pin the perturbation off: this test asserts the *schedule*,
        // which an ambient NYSX_EXEC_SEED would legitimately permute.
        let _seed = check::force_perturb_seed(0);
        let pool = Pool::new(1);
        let order = Mutex::new(Vec::new());
        pool.run(5, &|p| order.lock().unwrap().push(p));
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn perturbed_schedules_still_run_every_part_exactly_once() {
        for seed in [1u64, 0xDEAD_BEEF_u64] {
            let _seed = check::force_perturb_seed(seed);
            for threads in [1usize, 3] {
                let pool = Pool::new(threads);
                let hits: Vec<AtomicUsize> =
                    (0..13).map(|_| AtomicUsize::new(0)).collect();
                pool.run(13, &|p| {
                    hits[p].fetch_add(1, Ordering::Relaxed);
                });
                for (p, h) in hits.iter().enumerate() {
                    assert_eq!(
                        h.load(Ordering::Relaxed),
                        1,
                        "part {p} (seed={seed}, threads={threads})"
                    );
                }
            }
        }
    }

    #[test]
    fn perturbed_single_lane_schedule_is_a_permutation_not_identity() {
        let _seed = check::force_perturb_seed(0x5EED);
        let pool = Pool::new(1);
        let order = Mutex::new(Vec::new());
        pool.run(16, &|p| order.lock().unwrap().push(p));
        let got = order.lock().unwrap().clone();
        let mut sorted = got.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..16).collect::<Vec<_>>(), "must cover all parts");
        assert_ne!(got, sorted, "seeded schedule should actually permute");
    }

    #[test]
    fn nested_run_executes_inline_without_deadlock() {
        let pool = Pool::new(3);
        let inner_hits = AtomicUsize::new(0);
        pool.run(3, &|_| {
            // A nested dispatch from inside a lane must not wait on
            // workers that may all be busy with outer lanes.
            pool.run(4, &|_| {
                inner_hits.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(inner_hits.load(Ordering::Relaxed), 12);
    }

    #[test]
    fn worker_lane_panic_propagates_after_completion() {
        let pool = Pool::new(4);
        let survivors = AtomicUsize::new(0);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, &|p| {
                if p == 5 {
                    panic!("boom");
                }
                survivors.fetch_add(1, Ordering::Relaxed);
            });
        }));
        assert!(result.is_err(), "lane panic must propagate");
        // Every non-panicking part still ran (no lost work, no deadlock),
        // and the pool stays usable afterwards.
        assert_eq!(survivors.load(Ordering::Relaxed), 7);
        let after = AtomicUsize::new(0);
        pool.run(4, &|_| {
            after.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(after.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn concurrent_runs_from_multiple_callers() {
        let pool = Arc::new(Pool::new(3));
        let total = Arc::new(AtomicUsize::new(0));
        let mut callers = Vec::new();
        for _ in 0..4 {
            let pool = pool.clone();
            let total = total.clone();
            callers.push(std::thread::spawn(move || {
                for _ in 0..20 {
                    pool.run(5, &|_| {
                        total.fetch_add(1, Ordering::Relaxed);
                    });
                }
            }));
        }
        for c in callers {
            c.join().unwrap();
        }
        assert_eq!(total.load(Ordering::Relaxed), 4 * 20 * 5);
    }

    #[test]
    fn env_threads_parsing() {
        assert_eq!(threads_from_env(None, 6), 6);
        assert_eq!(threads_from_env(Some(""), 6), 6);
        assert_eq!(threads_from_env(Some("0"), 6), 6);
        assert_eq!(threads_from_env(Some("lots"), 6), 6);
        assert_eq!(threads_from_env(Some("4"), 6), 4);
        assert_eq!(threads_from_env(Some(" 12 "), 6), 12);
        assert_eq!(threads_from_env(Some("999999999"), 6), 6, "beyond cap");
    }

    #[test]
    fn configure_rejects_zero_and_absurd_counts() {
        assert!(configure_threads(0).is_err());
        assert!(configure_threads(MAX_THREADS + 1).is_err());
    }

    #[test]
    fn global_pool_is_shared_and_stable() {
        let a = global();
        let b = global();
        assert_eq!(a.threads(), b.threads());
        assert!(a.threads() >= 1);
        // Re-configuring to the running size is a no-op Ok; to a
        // different size a descriptive error.
        assert!(configure_threads(a.threads()).is_ok());
        let other = if a.threads() == 1 { 2 } else { a.threads() + 1 };
        assert!(configure_threads(other).is_err());
    }

    #[test]
    fn warm_up_runs() {
        let pool = Pool::new(2);
        pool.warm_up(); // must not hang or panic
        pool.warm_up(); // idempotent
    }

    #[test]
    fn lane_parts_partition_sums_to_parts() {
        for parts in [0usize, 1, 2, 5, 7, 64, 129] {
            for lanes in [1usize, 2, 3, 7, 16] {
                let total: u64 = (0..lanes).map(|l| lane_parts(parts, l, lanes)).sum();
                assert_eq!(total, parts as u64, "parts={parts} lanes={lanes}");
                // Matches the static p % lanes assignment exactly.
                for lane in 0..lanes {
                    let want = (lane..parts).step_by(lanes).count() as u64;
                    assert_eq!(lane_parts(parts, lane, lanes), want);
                }
            }
        }
    }

    /// `run_labeled` records per-lane busy time and part counts while
    /// obs is enabled, is a plain `run` while disabled, and never
    /// changes which parts execute.
    #[test]
    fn labeled_runs_record_lane_utilization() {
        static SITE: crate::obs::LaneSite = crate::obs::LaneSite::new("test.pool_site");
        let _serial = crate::obs::test_toggle_lock();
        let pool = Pool::new(3);

        crate::obs::set_enabled(false);
        let hits = AtomicUsize::new(0);
        pool.run_labeled(&SITE, 6, &|_| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), 6);
        assert_eq!(SITE.snapshot().runs, 0, "disabled obs must record nothing");

        crate::obs::set_enabled(true);
        pool.run_labeled(&SITE, 7, &|_| {
            // Make busy time visibly nonzero on every lane.
            std::thread::sleep(std::time::Duration::from_micros(200));
        });
        crate::obs::set_enabled(false);

        let snap = SITE.snapshot();
        assert_eq!(snap.runs, 1);
        assert_eq!(snap.lanes, 3);
        assert_eq!(snap.parts, vec![3, 2, 2], "7 parts over 3 lanes, p % lanes");
        assert!(snap.busy_ns.iter().all(|&b| b > 0), "{:?}", snap.busy_ns);
        let imb = snap.imbalance();
        assert!((1.0..=3.0).contains(&imb), "imbalance {imb} out of range");

        // Sequential path (1 part) records lane 0 only.
        SITE.reset();
        crate::obs::set_enabled(true);
        pool.run_labeled(&SITE, 1, &|_| {});
        crate::obs::set_enabled(false);
        let seq = SITE.snapshot();
        assert_eq!((seq.runs, seq.lanes), (1, 1));
        assert_eq!(seq.parts, vec![1]);
    }
}
