//! Static partitioners — every split is decided before anything is
//! dispatched, mirroring the paper's §4.2 offline schedule construction
//! rather than runtime work stealing.
//!
//! Three shapes cover the pipeline's hot kernels:
//!
//! * [`even_ranges`] — contiguous equal-size ranges, for work that is
//!   uniform per element: dense d×s NEE projection rows / packed words,
//!   query blocks of the C×W batch matcher, graphs of a training split.
//! * [`class_blocks`] — [`even_ranges`] under its SCE name: class-block
//!   partitions of prototype matching, each block a contiguous run of
//!   the scores vector.
//! * [`nnz_row_groups`] — nnz-balanced sparse row groups built **from
//!   the paper's own [`ScheduleTable`]**: PE column `j` of an
//!   `nnz`-grouped schedule collects rows of near-mean weight per
//!   iteration, so the column's row set is a balanced share of the
//!   total nnz. [`triangle_ranges`] is the analogous cost-balanced
//!   split for upper-triangular Gram walks (row `i` costs `n - i`).

use std::ops::Range;

use crate::sparse::{Csr, SchedulePolicy, ScheduleTable};

/// Split `0..n` into at most `parts` contiguous ranges whose lengths
/// differ by at most one, in index order. Empty iff `n == 0` or
/// `parts == 0`; never returns an empty range.
pub fn even_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 || parts == 0 {
        return Vec::new();
    }
    let parts = parts.min(n);
    let base = n / parts;
    let extra = n % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    for i in 0..parts {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    debug_assert_eq!(start, n);
    out
}

/// Class-block partition of `0..classes` for prototype matching — each
/// block is a contiguous run of the per-class scores vector, so the SCE
/// lanes write disjoint slices.
pub fn class_blocks(classes: usize, parts: usize) -> Vec<Range<usize>> {
    even_ranges(classes, parts)
}

/// Cost-balanced contiguous row ranges for an upper-triangular walk
/// where row `i` does `n - i` units of work (Gram matrices, pairwise
/// kernels). Ranges cover `0..n` exactly; early ranges are shorter.
pub fn triangle_ranges(n: usize, parts: usize) -> Vec<Range<usize>> {
    if n == 0 || parts == 0 {
        return Vec::new();
    }
    let parts = parts.min(n);
    let total = (n as u64) * (n as u64 + 1) / 2;
    let mut out: Vec<Range<usize>> = Vec::with_capacity(parts);
    let mut start = 0usize;
    let mut acc = 0u64;
    for p in 0..parts {
        if start >= n {
            break;
        }
        let target = total * (p as u64 + 1) / parts as u64;
        let mut end = start;
        while end < n && (acc < target || end == start) {
            acc += (n - end) as u64;
            end += 1;
        }
        out.push(start..end);
        start = end;
    }
    // Numerical-target slack can leave a tail; give it to the last range.
    if start < n {
        out.last_mut().expect("parts >= 1").end = n;
    }
    out
}

/// nnz-balanced row groups for sparse kernels, built by reusing the
/// §4.2 schedule: construct a [`ScheduleTable`] with `parts` PEs under
/// `policy` and collect each PE column's assigned rows. Under
/// [`SchedulePolicy::NnzGrouped`] every iteration assigns rows of
/// similar nonzero count to all PEs, so each group's total nnz
/// approaches `nnz / parts`; [`SchedulePolicy::RowOrder`] yields the
/// strided no-LB baseline. The groups always form an exact partition of
/// the rows (the schedule is a permutation), which is what makes
/// scatter-writing `y[r]` from different lanes sound.
///
/// This is the *materialized* form of the partition, for offline
/// consumers and the property suite; the hot
/// [`ScheduleTable::run_spmv_with_pool`] realizes the **same** split
/// allocation-free by handing each lane a contiguous block of PE
/// columns and walking the table in place.
pub fn nnz_row_groups(csr: &Csr, parts: usize, policy: SchedulePolicy) -> Vec<Vec<u32>> {
    if csr.rows == 0 || parts == 0 {
        return Vec::new();
    }
    let parts = parts.min(csr.rows);
    let sched = ScheduleTable::build(csr, parts, policy);
    let mut groups: Vec<Vec<u32>> = vec![Vec::new(); parts];
    for it in 0..sched.iterations {
        for (pe, group) in groups.iter_mut().enumerate() {
            if let Some(r) = sched.row_for(it, pe) {
                group.push(r);
            }
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::testing::{forall, PropConfig};
    use crate::util::rng::Xoshiro256;

    fn covers_exactly(ranges: &[Range<usize>], n: usize) {
        let mut next = 0usize;
        for r in ranges {
            assert_eq!(r.start, next, "gap or overlap at {}", r.start);
            assert!(r.end > r.start, "empty range");
            next = r.end;
        }
        assert_eq!(next, n, "ranges do not cover 0..{n}");
    }

    #[test]
    fn even_ranges_cover_and_balance() {
        forall("even-ranges", PropConfig::default(), |rng, size| {
            let n = rng.gen_range(8 * size.max(1) + 1);
            let parts = 1 + rng.gen_range(9);
            let ranges = even_ranges(n, parts);
            if n == 0 {
                crate::prop_assert!(ranges.is_empty(), "n=0 must yield no ranges");
                return Ok(());
            }
            covers_exactly(&ranges, n);
            crate::prop_assert!(ranges.len() == parts.min(n), "range count");
            let lens: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
            let (lo, hi) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            crate::prop_assert!(hi - lo <= 1, "uneven: {lens:?}");
            Ok(())
        });
    }

    #[test]
    fn triangle_ranges_cover_and_balance_cost() {
        forall("triangle-ranges", PropConfig::default(), |rng, size| {
            let n = 1 + rng.gen_range(8 * size.max(1));
            let parts = 1 + rng.gen_range(7);
            let ranges = triangle_ranges(n, parts);
            covers_exactly(&ranges, n);
            // Cost balance: no range exceeds the ideal share by more
            // than one row's maximum cost (n units).
            let cost = |r: &Range<usize>| -> u64 {
                r.clone().map(|i| (n - i) as u64).sum()
            };
            let total: u64 = (n as u64) * (n as u64 + 1) / 2;
            let ideal = total / ranges.len() as u64;
            for r in &ranges {
                crate::prop_assert!(
                    cost(r) <= ideal + n as u64,
                    "range {r:?} cost {} vs ideal {ideal} (n={n}, parts={parts})",
                    cost(r)
                );
            }
            Ok(())
        });
    }

    /// THE SchedulePolicy × partitioner forall: for every policy and
    /// every part count, the schedule-derived row groups are an exact
    /// partition of the rows, and under nnz-grouping the per-group nnz
    /// shares are balanced to within one iteration's max row weight.
    #[test]
    fn row_groups_partition_rows_under_every_policy() {
        forall("row-groups-partition", PropConfig::default(), |rng, size| {
            let rows = 1 + rng.gen_range(10 * size.max(1));
            let cols = 1 + rng.gen_range(40);
            let mut m = Mat::zeros(rows, cols);
            for i in 0..rows {
                for j in 0..cols {
                    if rng.bernoulli(0.25) {
                        m[(i, j)] = rng.normal();
                    }
                }
            }
            let csr = Csr::from_dense(&m, 0.0);
            let parts = 1 + rng.gen_range(8);
            for policy in [SchedulePolicy::NnzGrouped, SchedulePolicy::RowOrder] {
                let groups = nnz_row_groups(&csr, parts, policy);
                crate::prop_assert!(
                    groups.len() == parts.min(rows),
                    "{policy:?}: group count"
                );
                let mut seen = vec![false; rows];
                for group in &groups {
                    for &r in group {
                        crate::prop_assert!(
                            !seen[r as usize],
                            "{policy:?}: row {r} in two groups"
                        );
                        seen[r as usize] = true;
                    }
                }
                crate::prop_assert!(
                    seen.iter().all(|&s| s),
                    "{policy:?}: rows missing from groups"
                );
                if policy == SchedulePolicy::NnzGrouped {
                    let nnz_of = |g: &Vec<u32>| -> u64 {
                        g.iter().map(|&r| csr.row_nnz(r as usize) as u64).sum()
                    };
                    let shares: Vec<u64> = groups.iter().map(nnz_of).collect();
                    let max_row = (0..rows).map(|r| csr.row_nnz(r)).max().unwrap_or(0) as u64;
                    let (lo, hi) = (
                        *shares.iter().min().unwrap(),
                        *shares.iter().max().unwrap(),
                    );
                    let iterations = rows.div_ceil(parts.min(rows)) as u64;
                    crate::prop_assert!(
                        hi - lo <= max_row * iterations.min(2) + max_row,
                        "nnz shares skewed: {shares:?} (max row {max_row})"
                    );
                }
            }
            Ok(())
        });
    }

    #[test]
    fn degenerate_inputs() {
        assert!(even_ranges(0, 4).is_empty());
        assert!(even_ranges(5, 0).is_empty());
        assert_eq!(even_ranges(3, 8).len(), 3, "never more parts than items");
        assert_eq!(class_blocks(10, 3), even_ranges(10, 3));
        assert!(triangle_ranges(0, 4).is_empty());
        assert_eq!(triangle_ranges(1, 4), vec![0..1]);
        let empty = Csr::from_triplets(0, 3, vec![]);
        assert!(nnz_row_groups(&empty, 4, SchedulePolicy::NnzGrouped).is_empty());
    }
}
