//! `nysx::exec` — the dependency-free data-parallel runtime: the
//! software analogue of the paper's PE array with §4.2 static load
//! balancing (see `DESIGN.md` §6).
//!
//! The accelerator gets its throughput from arrays of identical engines
//! fed by *statically* balanced work assignments: an offline schedule
//! decides, before execution, which row every PE processes in every
//! iteration. This subsystem reproduces that execution model on host
//! threads:
//!
//! * [`pool`] — a scoped worker pool (std threads + channels, nothing
//!   vendored): [`Pool::run`] shares borrowed slices without `'static`
//!   bounds and returns only when every lane is done. The process-wide
//!   [`global`] pool is sized by `--threads` / `NYSX_THREADS` /
//!   available parallelism.
//! * [`partition`] — static partitioners: even contiguous ranges for
//!   dense work (NEE projection rows, C×W query blocks, class blocks),
//!   [`ScheduleTable`]-derived nnz-balanced row groups for SpMV, and
//!   triangle-balanced ranges for Gram walks. Splits are decided before
//!   dispatch, like the paper's schedule tables — never stolen at
//!   runtime.
//! * [`parallel`] — deterministic helpers ([`for_each_range_mut`],
//!   [`map_parts`], [`map_reduce`], [`ScatterMut`]) that only hand
//!   lanes disjoint writes and fold reductions in fixed part order.
//! * [`check`] — the shadow-state overlap checker (`NYSX_EXEC_CHECK=1`)
//!   and seeded schedule-perturbation harness (`NYSX_EXEC_SEED`):
//!   per-part write claims in an epoch-tagged claim table, typed abort
//!   on overlap or cross-epoch leak, zero cost when off (see
//!   `DESIGN.md` §9).
//!
//! # The determinism contract
//!
//! Every kernel threaded through this runtime is **bit-identical at any
//! thread count** — the differential suite pins parallel == sequential
//! == i8-oracle for each of them across thread counts and word-boundary
//! dims. Thread count is a pure throughput knob, exactly as PE count is
//! for the accelerator.
//!
//! [`ScheduleTable`]: crate::sparse::ScheduleTable

pub mod check;
pub mod parallel;
pub mod partition;
pub mod pool;

pub use parallel::{
    for_each_range_mut, for_each_range_mut_labeled, map_parts, map_reduce, ScatterMut,
};
pub use partition::{class_blocks, even_ranges, nnz_row_groups, triangle_ranges};
pub use pool::{configure_threads, global, Pool, MAX_THREADS};

/// Minimum dense multiply-accumulate count (d×s for the NEE projection)
/// before the plain kernel entry points dispatch to the global pool —
/// below it, lane wake-up costs more than the work. Explicit
/// `*_with_pool` calls always partition regardless.
pub const PAR_MIN_MACS: usize = 1 << 16;

/// Minimum popcount word count (C·W·⌈d/64⌉ for the blocked matcher)
/// before the plain matching entry points go parallel.
pub const PAR_MIN_WORDS: usize = 1 << 14;

/// Minimum sparse nonzero count before a scheduled SpMV goes parallel.
pub const PAR_MIN_NNZ: usize = 1 << 13;

/// THE dispatch gate shared by every auto-parallel entry point: fan out
/// on `pool` only when it has more than one lane AND the kernel carries
/// at least `min_work` units (one of the `PAR_MIN_*` thresholds above).
/// Centralized so the plain `hdc` entry points and the engine's batch
/// tail can never drift apart on when they parallelize.
#[inline]
pub fn worth_parallelizing(pool: &Pool, work: usize, min_work: usize) -> bool {
    pool.threads() > 1 && work >= min_work
}
