//! Deterministic data-parallel helpers over a [`Pool`].
//!
//! Everything here upholds one contract: **results are bit-identical at
//! any thread count**. The helpers only hand lanes *disjoint* mutable
//! data (validated contiguous ranges, or scatter targets whose index
//! sets the caller proves disjoint), and every reduction folds per-part
//! results in fixed part order — never completion order. There are no
//! atomics on result paths and no floating-point combination whose
//! grouping depends on scheduling.

use std::marker::PhantomData;
use std::ops::Range;

use super::check;
use super::pool::Pool;

/// Raw-pointer wrapper so a base address can be captured by a `Sync`
/// closure; all aliasing discipline lives in the helpers below.
struct SendPtr<T>(*mut T);

// SAFETY: SendPtr is only ever constructed over a `&mut [T]` borrow held
// by the caller for the whole parallel region, and the only code that
// dereferences it (`for_each_range_mut`) hands each lane a validated
// disjoint range — so cross-thread access never aliases and `T: Send`
// suffices for both bounds.
unsafe impl<T: Send> Send for SendPtr<T> {}
// SAFETY: as above — shared `&SendPtr` access only reads the pointer
// value; element access is partitioned per lane.
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Validate that `ranges` are sorted, pairwise disjoint and inside
/// `len` — the precondition that makes handing them out as `&mut`
/// slices across lanes sound.
fn validate_disjoint(ranges: &[Range<usize>], len: usize) {
    let mut prev_end = 0usize;
    for r in ranges {
        assert!(
            r.start >= prev_end && r.start <= r.end && r.end <= len,
            "partition ranges must be sorted, disjoint and in-bounds \
             (range {}..{} against len {len})",
            r.start,
            r.end
        );
        prev_end = r.end;
    }
}

/// Run `f(part, &mut data[ranges[part]])` for every part, parts
/// distributed over the pool's lanes. `ranges` must be sorted, disjoint
/// and in-bounds (asserted), which is exactly what every partitioner in
/// [`super::partition`] produces.
pub fn for_each_range_mut<T, F>(pool: &Pool, data: &mut [T], ranges: &[Range<usize>], f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    for_each_range_mut_inner(pool, None, data, ranges, f)
}

/// [`for_each_range_mut`] through [`Pool::run_labeled`]: identical
/// semantics, plus per-lane busy-time/part accounting into the obs
/// `site` while observability is enabled (a no-op otherwise).
pub fn for_each_range_mut_labeled<T, F>(
    pool: &Pool,
    site: &'static crate::obs::LaneSite,
    data: &mut [T],
    ranges: &[Range<usize>],
    f: F,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    for_each_range_mut_inner(pool, Some(site), data, ranges, f)
}

fn for_each_range_mut_inner<T, F>(
    pool: &Pool,
    site: Option<&'static crate::obs::LaneSite>,
    data: &mut [T],
    ranges: &[Range<usize>],
    f: F,
) where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    // Shadow-claim pass (NYSX_EXEC_CHECK=1, DESIGN.md §9): every part's
    // write interval is recorded in the epoch-tagged claim table up
    // front, so an overlap aborts with the typed report before any
    // aliasing write can happen — checked independently of (and ahead
    // of) the static assertion below.
    let _region = if check::enabled() {
        let region = check::begin_region();
        for (part, r) in ranges.iter().enumerate() {
            if let Err(v) = check::claim_range(region.epoch(), part, r.start, r.end) {
                check::abort(v);
            }
        }
        Some(region)
    } else {
        None
    };
    validate_disjoint(ranges, data.len());
    let base = SendPtr(data.as_mut_ptr());
    let body = |part: usize| {
        let r = &ranges[part];
        // SAFETY: ranges are validated disjoint and in-bounds, and the
        // pool runs each part index exactly once — so no two lanes ever
        // hold slices over the same elements.
        let slice = unsafe { std::slice::from_raw_parts_mut(base.0.add(r.start), r.end - r.start) };
        f(part, slice);
    };
    match site {
        Some(site) => pool.run_labeled(site, ranges.len(), &body),
        None => pool.run(ranges.len(), &body),
    }
}

/// Map every part index to a value, returned **in part order** (not
/// completion order) — the deterministic fan-out primitive.
pub fn map_parts<R, F>(pool: &Pool, parts: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let mut out: Vec<Option<R>> = Vec::new();
    out.resize_with(parts, || None);
    let ranges: Vec<Range<usize>> = (0..parts).map(|i| i..i + 1).collect();
    for_each_range_mut(pool, &mut out, &ranges, |part, slot| {
        slot[0] = Some(f(part));
    });
    out.into_iter()
        .map(|r| r.expect("pool ran every part exactly once"))
        .collect()
}

/// Map every part, then fold the results **left to right in part
/// order** — a fixed reduction tree, so the combined value is identical
/// at any thread count even for non-associative combines (floating
/// point, first-wins argmax). `None` iff `parts == 0`.
pub fn map_reduce<R, F, G>(pool: &Pool, parts: usize, map: F, reduce: G) -> Option<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
    G: FnMut(R, R) -> R,
{
    map_parts(pool, parts, map).into_iter().reduce(reduce)
}

/// Scattered disjoint writes into one buffer — for kernels whose
/// per-lane output rows are a *non-contiguous* partition (the §4.2
/// schedule's nnz-balanced row groups). Bounds are always checked; the
/// disjointness of the index sets is the caller's obligation, which is
/// why the write methods are `unsafe`.
pub struct ScatterMut<'a, T> {
    ptr: *mut T,
    len: usize,
    /// Live claim-table region while shadow checking (`None` when off):
    /// every `write`/`update` records an index claim attributed to the
    /// executing part, and dropping the handle retires the epoch — a
    /// write after that is a cross-epoch leak (DESIGN.md §9).
    check: Option<check::Region>,
    _borrow: PhantomData<&'a mut [T]>,
}

// SAFETY: ScatterMut exclusively borrows its slice for 'a (PhantomData
// keeps the borrow alive), and its only element accessors are the
// `unsafe fn write`/`update` below, whose contract makes lanes touch
// disjoint index sets — so sending or sharing the handle across threads
// is sound whenever `T: Send`.
unsafe impl<T: Send> Send for ScatterMut<'_, T> {}
// SAFETY: as above — the disjointness contract of `write`/`update` is
// what shared references rely on.
unsafe impl<T: Send> Sync for ScatterMut<'_, T> {}

impl<'a, T> ScatterMut<'a, T> {
    pub fn new(data: &'a mut [T]) -> Self {
        Self {
            ptr: data.as_mut_ptr(),
            len: data.len(),
            check: check::enabled().then(check::begin_region),
            _borrow: PhantomData,
        }
    }

    /// Record the shadow claim for element `i` (no-op when checking is
    /// off); aborts with the typed report on a cross-part overlap.
    #[inline]
    fn claim(&self, i: usize) {
        if let Some(region) = &self.check {
            if let Err(v) = check::claim_index(region.epoch(), check::current_part(), i) {
                check::abort(v);
            }
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Overwrite element `i`.
    ///
    /// # Safety
    ///
    /// Within one parallel region, no index may be touched by more than
    /// one lane (bounds are checked here; disjointness is not).
    #[inline]
    pub unsafe fn write(&self, i: usize, value: T) {
        assert!(i < self.len, "scatter write out of bounds: {i} >= {}", self.len);
        self.claim(i);
        // SAFETY: `i` is in bounds (asserted above); exclusivity of the
        // slot is the caller's `# Safety` obligation.
        unsafe { *self.ptr.add(i) = value };
    }

    /// Read-modify-write element `i` (e.g. `+=` accumulation into rows
    /// this lane owns).
    ///
    /// # Safety
    ///
    /// Same contract as [`Self::write`].
    #[inline]
    pub unsafe fn update(&self, i: usize, f: impl FnOnce(&mut T)) {
        assert!(i < self.len, "scatter update out of bounds: {i} >= {}", self.len);
        self.claim(i);
        // SAFETY: `i` is in bounds (asserted above); exclusivity of the
        // slot is the caller's `# Safety` obligation.
        f(unsafe { &mut *self.ptr.add(i) });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_are_filled_disjointly_and_completely() {
        for threads in [1usize, 2, 7] {
            let pool = Pool::new(threads);
            let mut data = vec![0u32; 100];
            let ranges = super::super::partition::even_ranges(100, 7);
            for_each_range_mut(&pool, &mut data, &ranges, |part, slice| {
                for x in slice.iter_mut() {
                    *x = part as u32 + 1;
                }
            });
            assert!(data.iter().all(|&x| x != 0), "uncovered element");
            // Part boundaries match the partition exactly.
            for (part, r) in ranges.iter().enumerate() {
                assert!(data[r.clone()].iter().all(|&x| x == part as u32 + 1));
            }
        }
    }

    #[test]
    fn labeled_variant_fills_identically_and_accounts_parts() {
        static SITE: crate::obs::LaneSite = crate::obs::LaneSite::new("test.parallel_site");
        let _serial = crate::obs::test_toggle_lock();
        crate::obs::set_enabled(true);
        let pool = Pool::new(3);
        let mut labeled = vec![0u32; 100];
        let mut plain = vec![0u32; 100];
        let ranges = super::super::partition::even_ranges(100, 7);
        let fill = |part: usize, slice: &mut [u32]| {
            for x in slice.iter_mut() {
                *x = part as u32 + 1;
            }
        };
        for_each_range_mut_labeled(&pool, &SITE, &mut labeled, &ranges, fill);
        crate::obs::set_enabled(false);
        for_each_range_mut(&pool, &mut plain, &ranges, fill);
        assert_eq!(labeled, plain, "labeling must not change results");
        let snap = SITE.snapshot();
        assert_eq!(snap.runs, 1);
        assert_eq!(snap.parts.iter().sum::<u64>(), 7, "7 ranges dispatched");
    }

    #[test]
    fn overlapping_ranges_rejected() {
        // With shadow checking off, the static `validate_disjoint`
        // assertion fires; under NYSX_EXEC_CHECK=1 the claim table gets
        // there first with its typed report. Either way the call must
        // abort before any write.
        let pool = Pool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut data = vec![0u8; 10];
            for_each_range_mut(&pool, &mut data, &[0..6, 5..10], |_, _| {});
        }));
        let payload = result.expect_err("overlap must abort");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_default();
        assert!(
            msg.contains("sorted, disjoint") || msg.contains("overlapping write claim"),
            "unexpected panic message: {msg}"
        );
    }

    #[test]
    #[should_panic(expected = "overlapping write claim")]
    fn exec_check_catches_overlapping_for_each_range_mut() {
        // The shadow checker (forced on for this thread) sees the
        // deliberately overlapping partition at claim time and aborts
        // with the typed report — ahead of the static assertion.
        let _check = check::force_enabled(true);
        let pool = Pool::new(2);
        let mut data = vec![0u8; 10];
        for_each_range_mut(&pool, &mut data, &[0..6, 5..10], |_, _| {});
    }

    #[test]
    #[should_panic(expected = "overlapping write claim")]
    fn exec_check_catches_cross_part_scatter_overlap() {
        // Two parts scatter-write the same element. A 1-thread pool runs
        // them sequentially on this thread (no UB is ever executed), yet
        // the claim table still flags the overlap, because claims are
        // keyed by part — the output would depend on part order, which
        // the bit-identity contract bans.
        let _check = check::force_enabled(true);
        let pool = Pool::new(1);
        let mut data = vec![0u64; 8];
        let scatter = ScatterMut::new(&mut data);
        pool.run(2, &|p| {
            // SAFETY: parts write disjoint elements only for p == 0; the
            // deliberate p == 1 collision on index 0 is what the shadow
            // checker must catch before the write happens (and the pool
            // is single-threaded, so no concurrent aliasing occurs).
            unsafe { scatter.write(0, p as u64) };
        });
    }

    #[test]
    fn exec_check_passes_disjoint_work_and_retires_epochs() {
        let _check = check::force_enabled(true);
        let pool = Pool::new(1);
        for _ in 0..3 {
            let mut data = vec![0u32; 40];
            let ranges = super::super::partition::even_ranges(40, 7);
            for_each_range_mut(&pool, &mut data, &ranges, |part, slice| {
                for x in slice.iter_mut() {
                    *x = part as u32 + 1;
                }
            });
            assert!(data.iter().all(|&x| x != 0));
            let scatter = ScatterMut::new(&mut data);
            pool.run(4, &|p| {
                let mut i = p;
                while i < 40 {
                    // SAFETY: strided sets with distinct residues are
                    // disjoint.
                    unsafe { scatter.write(i, p as u32) };
                    i += 4;
                }
            });
        }
    }

    #[test]
    #[should_panic(expected = "sorted, disjoint")]
    fn out_of_bounds_ranges_rejected() {
        let pool = Pool::new(2);
        let mut data = vec![0u8; 10];
        for_each_range_mut(&pool, &mut data, &[0..5, 5..11], |_, _| {});
    }

    #[test]
    fn map_parts_preserves_part_order() {
        for threads in [1usize, 2, 7] {
            let pool = Pool::new(threads);
            let got = map_parts(&pool, 23, |p| p * p);
            let want: Vec<usize> = (0..23).map(|p| p * p).collect();
            assert_eq!(got, want, "threads={threads}");
        }
        assert!(map_parts(&Pool::new(3), 0, |p| p).is_empty());
    }

    #[test]
    fn map_reduce_fixed_fold_order() {
        // A deliberately non-commutative combine: string concatenation
        // exposes any completion-order dependence immediately.
        for threads in [1usize, 2, 7] {
            let pool = Pool::new(threads);
            let got = map_reduce(&pool, 9, |p| p.to_string(), |a, b| a + &b);
            assert_eq!(got.as_deref(), Some("012345678"), "threads={threads}");
        }
        assert_eq!(map_reduce(&Pool::new(2), 0, |p| p, |a, b| a + b), None);
    }

    #[test]
    fn scatter_disjoint_interleaved_writes() {
        for threads in [1usize, 2, 7] {
            let pool = Pool::new(threads);
            let mut data = vec![0u64; 64];
            {
                let scatter = ScatterMut::new(&mut data);
                // Lane p owns the strided index set {p, p+4, p+8, ...}.
                pool.run(4, &|p| {
                    let mut i = p;
                    while i < 64 {
                        // SAFETY: strided sets with distinct residues are
                        // disjoint.
                        unsafe { scatter.write(i, (p as u64 + 1) * 1000 + i as u64) };
                        unsafe { scatter.update(i, |v| *v += 1) };
                        i += 4;
                    }
                });
            }
            for (i, &v) in data.iter().enumerate() {
                assert_eq!(v, ((i % 4) as u64 + 1) * 1000 + i as u64 + 1, "index {i}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn scatter_bounds_checked() {
        let mut data = vec![0u8; 4];
        let scatter = ScatterMut::new(&mut data);
        // SAFETY: single-threaded, no aliasing; the point is that the
        // bounds assert fires before the out-of-bounds write happens.
        unsafe { scatter.write(4, 1) };
    }
}
