//! Nyström projection construction (paper §2.1.2): from the landmark
//! kernel `H_Z = QΛQ^T`, build `P_nys = P_rp Λ^{-1/2} Q^T ∈ R^{d×s}` which
//! maps a kernel-similarity vector `C(x)` straight to HV space.
//!
//! `P_nys` is stored row-major in `f32` — the precision the accelerator
//! streams from DDR (16 FP32 values per 512-bit AXI beat, §6.1).

use crate::exec::{self, Pool};
use crate::linalg::{sym_eigen, Mat, SymEigen};
use crate::util::rng::Xoshiro256;

/// The d×s Nyström projection matrix in streaming (f32, row-major) layout.
#[derive(Debug, Clone)]
pub struct NystromProjection {
    pub d: usize,
    pub s: usize,
    /// Row-major d×s f32 — one row per HV dimension.
    pub data: Vec<f32>,
    /// Effective rank of H_Z after the rcond cutoff (diagnostics).
    pub rank: usize,
}

impl NystromProjection {
    /// Build from the landmark kernel `h_z` (s×s PSD) with HV dimension
    /// `d`. `P_rp` entries are i.i.d. N(0,1) random-hyperplane directions.
    pub fn build(h_z: &Mat, d: usize, rng: &mut Xoshiro256) -> Self {
        Self::build_with_pool(&exec::global(), h_z, d, rng)
    }

    /// [`Self::build`] on an explicit exec pool. The RNG is consumed in
    /// exactly the sequential order (all of `P_rp`, row-major, before
    /// any matmul work), so the built matrix is bit-identical at any
    /// thread count; only the d×s² multiply runs across the pool's
    /// lanes, over disjoint row ranges. With a single lane the build
    /// streams `P_rp` row by row instead — same bits, no d×s f64
    /// staging buffer.
    pub fn build_with_pool(pool: &Pool, h_z: &Mat, d: usize, rng: &mut Xoshiro256) -> Self {
        let s = h_z.rows;
        assert_eq!(h_z.rows, h_z.cols);
        let eig: SymEigen = sym_eigen(h_z);
        let rcond = 1e-10;
        let w = eig.whitening(rcond); // s×s: Λ^{-1/2} Q^T (rank-truncated)
        let lmax = eig.values.first().copied().unwrap_or(0.0).max(0.0);
        let rank = eig.values.iter().filter(|&&l| l > rcond * lmax).count();
        let mut data = vec![0.0f32; d * s];
        if pool.threads() <= 1 {
            // Single lane: build row-by-row to avoid materializing P_rp.
            let mut p_row = vec![0.0f64; s];
            for r in 0..d {
                for x in p_row.iter_mut() {
                    *x = rng.normal();
                }
                let out = &mut data[r * s..(r + 1) * s];
                row_times_w(&p_row, &w, out);
            }
            return Self { d, s, data, rank };
        }
        // Stage 1 (sequential): draw P_rp row-major — the same RNG draw
        // order as the row-by-row build, so models don't depend on the
        // host's thread count.
        let mut p_rp = vec![0.0f64; d * s];
        for x in p_rp.iter_mut() {
            *x = rng.normal();
        }
        // Stage 2 (parallel): P_nys = P_rp @ W over disjoint row ranges;
        // each output row's dot products are computed in the same order
        // as the sequential build — bit-identical sums.
        let row_ranges = exec::even_ranges(d, pool.threads());
        let elem_ranges: Vec<std::ops::Range<usize>> =
            row_ranges.iter().map(|r| r.start * s..r.end * s).collect();
        let w = &w;
        let p_rp = &p_rp;
        exec::for_each_range_mut(pool, &mut data, &elem_ranges, |block, part| {
            for (local, r) in row_ranges[block].clone().enumerate() {
                row_times_w(&p_rp[r * s..(r + 1) * s], w, &mut part[local * s..(local + 1) * s]);
            }
        });
        Self { d, s, data, rank }
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.s..(r + 1) * self.s]
    }

    /// y = P_nys @ c (f32 accumulation in f64 — matches the accelerator's
    /// wide accumulators).
    pub fn project(&self, c: &[f64]) -> Vec<f64> {
        assert_eq!(c.len(), self.s);
        let mut y = vec![0.0f64; self.d];
        self.project_into(c, &mut y);
        y
    }

    /// Run `f` with the C vector converted to f32. One conversion of the
    /// small C vector per call (s ≤ a few hundred) beats d×s per-element
    /// converts of the matrix stream; the stack buffer keeps the common
    /// case allocation-free.
    #[inline]
    fn with_c32<R>(&self, c: &[f64], f: impl FnOnce(&[f32]) -> R) -> R {
        debug_assert_eq!(c.len(), self.s);
        if self.s <= 1024 {
            let mut stack = [0.0f32; 1024];
            for (dst, &src) in stack[..self.s].iter_mut().zip(c.iter()) {
                *dst = src as f32;
            }
            f(&stack[..self.s])
        } else {
            // Rare oversized case (s > 1024): one allocation per call.
            let heap: Vec<f32> = c.iter().map(|&x| x as f32).collect();
            f(&heap)
        }
    }

    /// One output coordinate: `y[r] = P_nys[r, :] · c32` in four
    /// independent f32 lanes (auto-vectorizes) — the single accumulation
    /// kernel shared by every projection entry point, so the f64 path,
    /// the fused packed path and (transitively) reference/optimized
    /// inference all see bit-identical sums.
    #[inline]
    fn row_dot(&self, r: usize, c32: &[f32]) -> f32 {
        let row = self.row(r);
        let mut acc = [0.0f32; 4];
        let chunks = self.s / 4;
        for k in 0..chunks {
            let base = k * 4;
            acc[0] += row[base] * c32[base];
            acc[1] += row[base + 1] * c32[base + 1];
            acc[2] += row[base + 2] * c32[base + 2];
            acc[3] += row[base + 3] * c32[base + 3];
        }
        let mut tail = 0.0f32;
        for k in chunks * 4..self.s {
            tail += row[k] * c32[k];
        }
        (acc[0] + acc[1]) + (acc[2] + acc[3]) + tail
    }

    /// Allocation-free projection for the hot path.
    ///
    /// Perf (§Perf L3): C is converted to f32 once per call and the dot
    /// products run in four independent f32 lanes (auto-vectorizes),
    /// instead of converting every streamed P element to f64 — this
    /// matches the accelerator (FP32 MAC lanes) and the L2 jax graph
    /// (f32 matmul), and both rust inference paths share this function so
    /// reference/optimized equality is preserved.
    #[inline]
    pub fn project_into(&self, c: &[f64], y: &mut [f64]) {
        debug_assert_eq!(y.len(), self.d);
        self.with_c32(c, |c32| {
            for (r, yr) in y.iter_mut().enumerate() {
                *yr = self.row_dot(r, c32) as f64;
            }
        });
    }

    /// Fused project-bipolarize-pack: `out = pack(sign(P_nys c))` with no
    /// f64 `y` or i8 HV ever materialized — the NEE→SCE hot path. The
    /// per-row sum is the same f32 [`Self::row_dot`] used by
    /// [`Self::project_into`], and `x < 0.0` over f32 agrees exactly with
    /// the sign of the widened f64 (widening is value-preserving), so the
    /// resulting bits equal `Hypervector::from_real(&self.project(c)).pack()`
    /// bit-for-bit.
    pub fn project_pack_into(&self, c: &[f64], out: &mut crate::hdc::PackedHypervector) {
        assert_eq!(out.dim(), self.d);
        self.project_pack_words(c, out.words_mut());
    }

    /// [`Self::project_pack_into`] across an exec pool: the packed words
    /// are split into contiguous even ranges ([`exec::even_ranges`]) and
    /// each lane packs its own words — disjoint `u64` writes, each word's
    /// 64 row dots computed exactly as in the sequential path, so the
    /// result is bit-identical at any thread count.
    pub fn project_pack_into_with_pool(
        &self,
        pool: &Pool,
        c: &[f64],
        out: &mut crate::hdc::PackedHypervector,
    ) {
        assert_eq!(out.dim(), self.d);
        self.project_pack_words_with_pool(pool, c, out.words_mut());
    }

    /// Word-level core of [`Self::project_pack_into_with_pool`], shared
    /// with the batch producers that pack straight into
    /// [`crate::hdc::PackedBatch`] slots.
    pub(crate) fn project_pack_words_with_pool(&self, pool: &Pool, c: &[f64], words: &mut [u64]) {
        assert_eq!(words.len(), crate::hdc::packed::words_for(self.d));
        if pool.threads() <= 1 || words.len() <= 1 {
            return self.project_pack_words(c, words);
        }
        self.with_c32(c, |c32| {
            let ranges = exec::even_ranges(words.len(), pool.threads());
            exec::for_each_range_mut(pool, words, &ranges, |block, part| {
                let start_word = ranges[block].start;
                for (local, w) in part.iter_mut().enumerate() {
                    let wi = start_word + local;
                    let base = wi * 64;
                    let top = (base + 64).min(self.d);
                    let mut bits = 0u64;
                    for r in base..top {
                        if self.row_dot(r, c32) < 0.0 {
                            bits |= 1 << (r - base);
                        }
                    }
                    *w = bits;
                }
            });
        });
    }

    /// Word-level core of [`Self::project_pack_into`], shared with batch
    /// producers that pack straight into a [`crate::hdc::PackedBatch`]
    /// slot. `words` must be exactly `words_for(d)` long; tail bits are
    /// written zero (bits at and above `d` are never set).
    pub(crate) fn project_pack_words(&self, c: &[f64], words: &mut [u64]) {
        assert_eq!(words.len(), crate::hdc::packed::words_for(self.d));
        self.with_c32(c, |c32| {
            for (wi, w) in words.iter_mut().enumerate() {
                let base = wi * 64;
                let top = (base + 64).min(self.d);
                let mut bits = 0u64;
                for r in base..top {
                    if self.row_dot(r, c32) < 0.0 {
                        bits |= 1 << (r - base);
                    }
                }
                *w = bits;
            }
        });
    }

    /// Bytes at the streaming precision (Table 2's dominant `ds·b_P`).
    pub fn bytes(&self) -> usize {
        self.d * self.s * 4
    }
}

/// One output row of the projection build: `out = p_row @ W` (`W` is
/// s×s) — the single dot-product kernel shared by the streaming and the
/// staged parallel build, so both produce bit-identical sums.
fn row_times_w(p_row: &[f64], w: &Mat, out: &mut [f32]) {
    for (j, o) in out.iter_mut().enumerate() {
        let mut acc = 0.0f64;
        for (k, &p) in p_row.iter().enumerate() {
            acc += p * w[(k, j)];
        }
        *o = acc as f32;
    }
}

/// Exact Nyström kernel approximation `Ĝ = C H_Z^+ C^T` for validation:
/// given cross-kernel rows `c_i = K(x_i, ·landmarks·)`, approximate
/// `K(x_i, x_j)`. Used by tests to verify the whole construction.
pub fn nystrom_gram_approx(c: &Mat, h_z: &Mat) -> Mat {
    let eig = sym_eigen(h_z);
    let pinv = eig.pseudo_inverse(1e-10);
    c.matmul(&pinv).matmul(&c.transpose())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::cosine;

    fn random_psd(n: usize, rank: usize, rng: &mut Xoshiro256) -> Mat {
        let a = Mat::randn(n, rank, rng);
        a.matmul(&a.transpose())
    }

    #[test]
    fn exact_when_landmarks_are_all_points() {
        // With Z = X, Ĝ = K K^+ K = K.
        let mut rng = Xoshiro256::seed_from_u64(1);
        let k = random_psd(10, 10, &mut rng);
        let approx = nystrom_gram_approx(&k, &k);
        assert!(
            approx.max_abs_diff(&k) < 1e-6 * (1.0 + k.fro_norm()),
            "err {}",
            approx.max_abs_diff(&k)
        );
    }

    #[test]
    fn exact_for_low_rank_kernels() {
        // K has rank 3; any 5 landmarks spanning the range reconstruct K
        // exactly. Build K = B B^T with B 12×3, landmarks = first 5 rows.
        let mut rng = Xoshiro256::seed_from_u64(2);
        let b = Mat::randn(12, 3, &mut rng);
        let k = b.matmul(&b.transpose());
        let s = 5;
        // C = K[:, :s]; H_Z = K[:s, :s]
        let mut c = Mat::zeros(12, s);
        let mut hz = Mat::zeros(s, s);
        for i in 0..12 {
            for j in 0..s {
                c[(i, j)] = k[(i, j)];
            }
        }
        for i in 0..s {
            for j in 0..s {
                hz[(i, j)] = k[(i, j)];
            }
        }
        let approx = nystrom_gram_approx(&c, &hz);
        assert!(
            approx.max_abs_diff(&k) < 1e-6 * (1.0 + k.fro_norm()),
            "err {}",
            approx.max_abs_diff(&k)
        );
    }

    #[test]
    fn projection_shape_and_rank() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let hz = random_psd(8, 4, &mut rng);
        let p = NystromProjection::build(&hz, 64, &mut rng);
        assert_eq!(p.d, 64);
        assert_eq!(p.s, 8);
        assert_eq!(p.data.len(), 64 * 8);
        assert_eq!(p.rank, 4);
        assert_eq!(p.bytes(), 64 * 8 * 4);
    }

    /// The point of the construction: angles between projected embeddings
    /// approximate kernel similarity. For two kernel-similar points the
    /// Nyström HV embeddings must be closer than for dissimilar points.
    #[test]
    fn projection_preserves_kernel_geometry() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        // Feature-space points: x0 ≈ x1, x2 far away; linear kernel.
        let pts = Mat::from_rows(vec![
            vec![1.0, 0.0, 0.2],
            vec![0.95, 0.05, 0.25],
            vec![-0.1, 1.0, -0.8],
            vec![0.8, 0.1, 0.1],
            vec![0.0, 0.9, -0.6],
        ]);
        let k = pts.matmul(&pts.transpose());
        // Landmarks = all 5 points.
        let p = NystromProjection::build(&k, 8192, &mut rng);
        // C(x_i) = K[:, i] (kernel vector vs landmarks).
        let emb = |i: usize| -> Vec<f64> {
            let c: Vec<f64> = (0..5).map(|j| k[(i, j)]).collect();
            p.project(&c)
        };
        let e0 = emb(0);
        let e1 = emb(1);
        let e2 = emb(2);
        let close = cosine(&e0, &e1);
        let far = cosine(&e0, &e2);
        assert!(
            close > far + 0.1,
            "kernel geometry lost: close={close} far={far}"
        );
    }

    #[test]
    fn project_pack_matches_project_sign() {
        let mut rng = Xoshiro256::seed_from_u64(6);
        let hz = random_psd(6, 6, &mut rng);
        // d=100 exercises the non-multiple-of-64 tail word.
        let p = NystromProjection::build(&hz, 100, &mut rng);
        let mut packed = crate::hdc::PackedHypervector::zeros(100);
        for _ in 0..10 {
            let c: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
            p.project_pack_into(&c, &mut packed);
            let want = crate::hdc::Hypervector::from_real(&p.project(&c)).pack();
            assert_eq!(packed, want);
        }
    }

    /// The exec contract on the NEE: the projection matrix AND the fused
    /// project-bipolarize-pack output are bit-identical at any thread
    /// count, including across word-boundary dims.
    #[test]
    fn parallel_build_and_pack_bit_identical_across_thread_counts() {
        let pools: Vec<crate::exec::Pool> = [1usize, 2, 7]
            .iter()
            .map(|&t| crate::exec::Pool::new(t))
            .collect();
        for &d in &[63usize, 64, 65, 300] {
            let build_at = |pool: &crate::exec::Pool| {
                let mut rng = Xoshiro256::seed_from_u64(41);
                let hz = random_psd(6, 5, &mut rng);
                NystromProjection::build_with_pool(pool, &hz, d, &mut rng)
            };
            let want = build_at(&pools[0]); // single-thread oracle
            for pool in &pools[1..] {
                let got = build_at(pool);
                assert_eq!(got.data, want.data, "build drifted at d={d}");
                assert_eq!(got.rank, want.rank);
            }
            // The plain entry point (global pool) agrees too.
            let mut rng = Xoshiro256::seed_from_u64(41);
            let hz = random_psd(6, 5, &mut rng);
            let plain = NystromProjection::build(&hz, d, &mut rng);
            assert_eq!(plain.data, want.data, "global-pool build drifted at d={d}");

            let mut qrng = Xoshiro256::seed_from_u64(7);
            for _ in 0..5 {
                let c: Vec<f64> = (0..want.s).map(|_| qrng.normal()).collect();
                let mut seq = crate::hdc::PackedHypervector::zeros(d);
                want.project_pack_into(&c, &mut seq);
                for pool in &pools {
                    let mut par = crate::hdc::PackedHypervector::zeros(d);
                    want.project_pack_into_with_pool(pool, &c, &mut par);
                    assert_eq!(
                        par,
                        seq,
                        "project-pack drifted at d={d}, threads={}",
                        pool.threads()
                    );
                }
            }
        }
    }

    #[test]
    fn project_into_matches_project() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let hz = random_psd(6, 6, &mut rng);
        let p = NystromProjection::build(&hz, 32, &mut rng);
        let c: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let a = p.project(&c);
        let mut b = vec![0.0; 32];
        p.project_into(&c, &mut b);
        assert_eq!(a, b);
    }
}
