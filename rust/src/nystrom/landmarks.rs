//! Landmark selection (paper §4.1): uniform sampling (the NysHD baseline),
//! greedy MAP determinantal-point-process selection, and the paper's
//! hybrid Uniform+DPP strategy (Algorithm 2).

use crate::exec::{self, Pool};
use crate::graph::Graph;
use crate::kernel::{
    gram_from_signatures_with_pool, normalize_gram, signatures_with_pool, LshParams,
};
use crate::linalg::Mat;
use crate::util::rng::Xoshiro256;

/// Landmark selection strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LandmarkStrategy {
    /// Uniform sampling without replacement (NysHD baseline [64]).
    Uniform,
    /// Algorithm 2: uniform candidate pool, then greedy MAP DPP over the
    /// normalized propagation kernel. `pool_factor` bounds the pool at
    /// `pool_factor × s` candidates to keep the O(|C|² ) kernel and the
    /// O(s²|C|) greedy selection tractable.
    HybridDpp { pool_factor: usize },
    /// Pure DPP over the entire training set (the "impractical" upper
    /// bound the paper discusses; exposed for the ablation bench).
    FullDpp,
}

/// Select `s` landmark indices from `graphs` under `strategy`.
/// Returns indices into `graphs`.
pub fn select_landmarks(
    graphs: &[&Graph],
    s: usize,
    strategy: LandmarkStrategy,
    lsh: &LshParams,
    rng: &mut Xoshiro256,
) -> Vec<usize> {
    select_landmarks_with_pool(&exec::global(), graphs, s, strategy, lsh, rng)
}

/// [`select_landmarks`] on an explicit exec pool. The RNG draws (pool
/// sampling, uniform picks) stay strictly sequential on the caller;
/// only the pool's O(|C|²) propagation-kernel matrix runs across exec
/// lanes — selections are bit-identical at any thread count.
pub fn select_landmarks_with_pool(
    exec_pool: &Pool,
    graphs: &[&Graph],
    s: usize,
    strategy: LandmarkStrategy,
    lsh: &LshParams,
    rng: &mut Xoshiro256,
) -> Vec<usize> {
    let n = graphs.len();
    assert!(s <= n, "cannot select {s} landmarks from {n} graphs");
    match strategy {
        LandmarkStrategy::Uniform => rng.choose_k(n, s),
        LandmarkStrategy::HybridDpp { pool_factor } => {
            // Step 1: uniform candidate pool C ⊂ G.
            let pool_size = (pool_factor.max(1) * s).min(n);
            let pool = rng.choose_k(n, pool_size);
            // Steps 2-3: propagation-kernel similarity over the pool, DPP.
            dpp_over_pool(exec_pool, graphs, &pool, s, lsh)
        }
        LandmarkStrategy::FullDpp => {
            let pool: Vec<usize> = (0..n).collect();
            dpp_over_pool(exec_pool, graphs, &pool, s, lsh)
        }
    }
}

fn dpp_over_pool(
    exec_pool: &Pool,
    graphs: &[&Graph],
    pool: &[usize],
    s: usize,
    lsh: &LshParams,
) -> Vec<usize> {
    let candidates: Vec<&Graph> = pool.iter().map(|&i| graphs[i]).collect();
    let sigs = signatures_with_pool(exec_pool, &candidates, lsh);
    let k = normalize_gram(&gram_from_signatures_with_pool(exec_pool, &sigs));
    let chosen = greedy_dpp_map(&k, s);
    chosen.into_iter().map(|i| pool[i]).collect()
}

/// Gain threshold below which the kernel's numerical rank counts as
/// exhausted. Must dominate the stabilizing `ridge` (1e-9) plus the
/// cancellation noise of O(1) Cholesky updates, while sitting far below
/// any meaningful conditional gain on a normalized kernel (diag ≈ 1).
const GAIN_EPS: f64 = 1e-6;

/// Greedy MAP inference for a k-DPP: iteratively add the item with the
/// largest conditional determinant gain (Chen et al.'s fast greedy MAP,
/// O(s²·n) via incremental Cholesky). The kernel must be PSD; a small
/// ridge keeps the algorithm stable when items are near-duplicates.
///
/// When the best remaining gain falls below [`GAIN_EPS`] the kernel's
/// rank is exhausted: continuing would divide the Cholesky update by
/// `≈ √ridge` and drive the remaining picks with noise-amplified
/// garbage. Instead the greedy loop stops and the remaining slots are
/// filled uniformly (fixed-seed RNG, deterministic for a given `n`) from
/// the unselected pool, keeping the "exactly `s` distinct indices"
/// contract.
pub fn greedy_dpp_map(kernel: &Mat, s: usize) -> Vec<usize> {
    greedy_dpp_map_with_gains(kernel, s).0
}

/// [`greedy_dpp_map`] plus the conditional gain of each *greedy* pick
/// (`gains.len() < s` means the tail of the selection came from the
/// uniform rank-exhaustion fallback). Exposed for diagnostics and the
/// rank-deficiency regression tests.
pub fn greedy_dpp_map_with_gains(kernel: &Mat, s: usize) -> (Vec<usize>, Vec<f64>) {
    let n = kernel.rows;
    assert_eq!(kernel.rows, kernel.cols);
    assert!(s <= n);
    let ridge = 1e-9;
    // d2[i] = marginal gain (squared Cholesky diagonal) of item i.
    let mut d2: Vec<f64> = (0..n).map(|i| kernel[(i, i)] + ridge).collect();
    // cis[t][i] = t-th Cholesky row for candidate i.
    let mut cis: Vec<Vec<f64>> = Vec::with_capacity(s);
    let mut selected: Vec<usize> = Vec::with_capacity(s);
    let mut gains: Vec<f64> = Vec::with_capacity(s);
    let mut in_set = vec![false; n];

    for _ in 0..s {
        // argmax over unselected candidates.
        let mut best = usize::MAX;
        let mut best_gain = f64::NEG_INFINITY;
        for i in 0..n {
            if !in_set[i] && d2[i] > best_gain {
                best_gain = d2[i];
                best = i;
            }
        }
        if best == usize::MAX || best_gain <= GAIN_EPS {
            break; // rank exhausted — fall back to the uniform fill below
        }
        let j = best;
        let dj = best_gain.sqrt();
        // e_i = (K[j][i] - <c_j, c_i>) / d_j for all i.
        let mut e = vec![0.0f64; n];
        for i in 0..n {
            if in_set[i] {
                continue;
            }
            let mut dotp = 0.0;
            for row in &cis {
                dotp += row[j] * row[i];
            }
            e[i] = (kernel[(j, i)] - dotp) / dj;
        }
        for i in 0..n {
            if !in_set[i] {
                d2[i] -= e[i] * e[i];
                if d2[i] < 0.0 {
                    d2[i] = 0.0;
                }
            }
        }
        cis.push(e);
        in_set[j] = true;
        selected.push(j);
        gains.push(best_gain);
    }

    // Rank exhausted before `s` picks: beyond the kernel's span every
    // remaining item adds (numerically) zero determinant, so any subset
    // is as good as any other — fill uniformly, deterministically.
    if selected.len() < s {
        let mut pool: Vec<usize> = (0..n).filter(|&i| !in_set[i]).collect();
        let mut rng = Xoshiro256::seed_from_u64(0x5EED_D1CE ^ n as u64);
        while selected.len() < s {
            let k = rng.gen_range(pool.len());
            selected.push(pool.swap_remove(k));
        }
    }
    (selected, gains)
}

/// Diversity diagnostic: mean pairwise normalized-kernel similarity of a
/// selected subset (lower = more diverse). Used by tests and the
/// DPP-vs-uniform ablation.
pub fn mean_pairwise_similarity(kernel: &Mat, subset: &[usize]) -> f64 {
    if subset.len() < 2 {
        return 0.0;
    }
    let mut total = 0.0;
    let mut count = 0usize;
    for (a, &i) in subset.iter().enumerate() {
        for &j in subset.iter().skip(a + 1) {
            let denom = (kernel[(i, i)] * kernel[(j, j)]).sqrt();
            total += if denom > 0.0 { kernel[(i, j)] / denom } else { 0.0 };
            count += 1;
        }
    }
    total / count as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::generators::labeled_graph;
    use crate::kernel::{gram_from_signatures, GraphSignature};
    use crate::linalg::sym_eigen;

    #[test]
    fn greedy_dpp_avoids_duplicates() {
        // Kernel with items 0,1 identical and 2 orthogonal: picking {0,2}
        // or {1,2} has det 1; {0,1} has det 0. Greedy must not pick the
        // duplicate pair.
        let k = Mat::from_rows(vec![
            vec![1.0, 1.0, 0.0],
            vec![1.0, 1.0, 0.0],
            vec![0.0, 0.0, 1.0],
        ]);
        let sel = greedy_dpp_map(&k, 2);
        assert_eq!(sel.len(), 2);
        let has = |i: usize| sel.contains(&i);
        assert!(has(2), "must include the orthogonal item: {sel:?}");
        assert!(!(has(0) && has(1)), "picked both duplicates: {sel:?}");
    }

    #[test]
    fn greedy_dpp_block_diverse() {
        // Two tight clusters (within-sim 0.95) of 5 items each; selecting
        // 2 must take one from each cluster.
        let n = 10;
        let mut k = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                let same_cluster = (i < 5) == (j < 5);
                k[(i, j)] = if i == j {
                    1.0
                } else if same_cluster {
                    0.95
                } else {
                    0.05
                };
            }
        }
        let sel = greedy_dpp_map(&k, 2);
        let c0 = sel.iter().filter(|&&i| i < 5).count();
        assert_eq!(c0, 1, "one per cluster expected: {sel:?}");
    }

    /// Regression (degenerate-gain blow-up): on a rank-deficient kernel
    /// with `s > rank`, the old code divided by `√(d2.max(1e-300)) ≈
    /// 1e-150` once the rank was exhausted and filled the remaining
    /// slots with noise-driven garbage. Now the greedy loop stops at the
    /// gain epsilon and the tail comes from a deterministic uniform fill.
    #[test]
    fn rank_deficient_kernel_falls_back_to_uniform_fill() {
        // Two blocks of four exact duplicates → kernel rank 2, s = 6.
        let n = 8;
        let mut k = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                k[(i, j)] = if (i < 4) == (j < 4) { 1.0 } else { 0.0 };
            }
        }
        let (sel, gains) = greedy_dpp_map_with_gains(&k, 6);
        // Contract: exactly s distinct, in-range indices.
        assert_eq!(sel.len(), 6);
        let set: std::collections::HashSet<_> = sel.iter().collect();
        assert_eq!(set.len(), 6, "duplicate indices: {sel:?}");
        assert!(sel.iter().all(|&i| i < n));
        // Exactly rank-many greedy picks, all finite and meaningful; the
        // rest came from the uniform fill, not from garbage gains.
        assert_eq!(gains.len(), 2, "gains {gains:?}");
        assert!(gains.iter().all(|g| g.is_finite() && *g > GAIN_EPS));
        // The two greedy picks straddle the duplicate blocks.
        assert_ne!(sel[0] < 4, sel[1] < 4, "greedy picks {sel:?}");
        // Deterministic, and the plain entry point agrees.
        assert_eq!(greedy_dpp_map(&k, 6), sel);
        // s = n still returns everything exactly once.
        let all = greedy_dpp_map(&k, n);
        let all_set: std::collections::HashSet<_> = all.iter().collect();
        assert_eq!(all_set.len(), n);
        // All-zero kernel: pure uniform fill, contract intact.
        let zero = Mat::zeros(5, 5);
        let (zsel, zgains) = greedy_dpp_map_with_gains(&zero, 4);
        assert_eq!(zsel.len(), 4);
        assert!(zgains.is_empty(), "zero kernel has no real gains: {zgains:?}");
        let zset: std::collections::HashSet<_> = zsel.iter().collect();
        assert_eq!(zset.len(), 4);
    }

    #[test]
    fn dpp_subset_more_diverse_than_uniform() {
        // Property: on a clustered graph population, hybrid DPP landmarks
        // have lower mean pairwise similarity than uniform landmarks.
        let mut rng = Xoshiro256::seed_from_u64(7);
        // Population: 80% from one label regime, 20% from another.
        let graphs: Vec<Graph> = (0..60)
            .map(|i| {
                let w: &[f64] = if i % 5 == 0 {
                    &[0.05, 0.05, 0.9]
                } else {
                    &[0.9, 0.05, 0.05]
                };
                labeled_graph(12 + rng.gen_range(8), 6, 0.2, w, &mut rng)
            })
            .collect();
        let refs: Vec<&Graph> = graphs.iter().collect();
        let lsh = LshParams::sample(2, 3, 1.0, &mut rng);
        let sigs: Vec<GraphSignature> = refs
            .iter()
            .map(|g| GraphSignature::compute(g, &lsh))
            .collect();
        let k = normalize_gram(&gram_from_signatures(&sigs));

        let s = 8;
        let mut uni_sims = Vec::new();
        for _ in 0..10 {
            let uni = rng.choose_k(refs.len(), s);
            uni_sims.push(mean_pairwise_similarity(&k, &uni));
        }
        let uni_mean = crate::util::mean(&uni_sims);
        let dpp = select_landmarks(
            &refs,
            s,
            LandmarkStrategy::FullDpp,
            &lsh,
            &mut rng,
        );
        let dpp_sim = mean_pairwise_similarity(&k, &dpp);
        assert!(
            dpp_sim < uni_mean,
            "DPP sim {dpp_sim} not below uniform mean {uni_mean}"
        );
    }

    #[test]
    fn hybrid_selects_requested_count_and_valid_indices() {
        let mut rng = Xoshiro256::seed_from_u64(8);
        let graphs: Vec<Graph> = (0..30)
            .map(|_| labeled_graph(10, 5, 0.2, &[0.5, 0.5], &mut rng))
            .collect();
        let refs: Vec<&Graph> = graphs.iter().collect();
        let lsh = LshParams::sample(2, 2, 1.0, &mut rng);
        for strategy in [
            LandmarkStrategy::Uniform,
            LandmarkStrategy::HybridDpp { pool_factor: 2 },
            LandmarkStrategy::FullDpp,
        ] {
            let sel = select_landmarks(&refs, 10, strategy, &lsh, &mut rng);
            assert_eq!(sel.len(), 10, "{strategy:?}");
            let set: std::collections::HashSet<_> = sel.iter().collect();
            assert_eq!(set.len(), 10, "duplicates under {strategy:?}");
            assert!(sel.iter().all(|&i| i < 30));
        }
    }

    #[test]
    fn greedy_map_matches_det_objective_small() {
        // Exhaustive check on a random 6-item PSD kernel: greedy's chosen
        // 3-subset has log-det within the top-3 of all subsets (greedy is
        // near-optimal, not optimal; this guards against regressions).
        let mut rng = Xoshiro256::seed_from_u64(9);
        let a = Mat::randn(6, 4, &mut rng);
        let k = a.matmul(&a.transpose());
        let sel = greedy_dpp_map(&k, 3);
        let logdet = |idx: &[usize]| -> f64 {
            let mut sub = Mat::zeros(idx.len(), idx.len());
            for (ai, &i) in idx.iter().enumerate() {
                for (aj, &j) in idx.iter().enumerate() {
                    sub[(ai, aj)] = k[(i, j)];
                }
            }
            sym_eigen(&sub).log_det(1e-12)
        };
        let greedy_val = logdet(&sel);
        let mut all_vals = Vec::new();
        for i in 0..6 {
            for j in (i + 1)..6 {
                for l in (j + 1)..6 {
                    all_vals.push(logdet(&[i, j, l]));
                }
            }
        }
        all_vals.sort_by(|a, b| b.total_cmp(a));
        assert!(
            greedy_val >= all_vals[2] - 1e-9,
            "greedy {greedy_val} below top-3 {:?}",
            &all_vals[..3]
        );
    }
}
