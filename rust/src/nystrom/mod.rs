//! Nyström substrate: landmark selection (uniform / hybrid-DPP / full-DPP)
//! and construction of the `P_nys` projection matrix.

pub mod landmarks;
pub mod projection;

pub use landmarks::{
    greedy_dpp_map, greedy_dpp_map_with_gains, mean_pairwise_similarity, select_landmarks,
    select_landmarks_with_pool, LandmarkStrategy,
};
pub use projection::{nystrom_gram_approx, NystromProjection};
