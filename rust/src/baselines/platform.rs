//! Analytic CPU/GPU latency + energy models (the paper's baseline
//! platforms, Table 5). We have neither the Ryzen 5 5625U nor the RTX
//! A4000, so batch-1 PyTorch inference is modeled as a sequence of
//! framework ops — each costing `max(flops/effective_rate,
//! bytes/effective_bw)` plus a per-op dispatch overhead — with the
//! complexity expressions of Table 1 supplying the per-op flops/bytes.
//! Effective rates are calibrated once against the paper's reported CPU
//! latencies (see DESIGN.md §4, "Platform-model calibration"); the
//! quantities we then *reproduce* are the cross-platform ratios.
//!
//! Baselines run **dense** kernels (the paper notes NysHD "does not
//! exploit the sparsity in adjacency and histogram matrices"), and the
//! codebook lookup stage is host-side dictionary work — on the GPU this
//! forces a device↔host round trip per hop.

use crate::model::NysHdcModel;

/// Effective-throughput description of a baseline platform.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlatformSpec {
    pub name: &'static str,
    /// Effective dense FP32 throughput for batch-1 tensor ops (GFLOP/s).
    pub dense_gflops: f64,
    /// Effective memory bandwidth for streaming tensor ops (GB/s).
    pub mem_bw_gbps: f64,
    /// Per-framework-op dispatch overhead (µs).
    pub op_overhead_us: f64,
    /// Host dictionary lookup cost per key (ns).
    pub lookup_ns: f64,
    /// Per-hop host sync cost (µs) — device↔host code transfer for the
    /// codebook stage (0 for CPU).
    pub hop_sync_us: f64,
    /// Fixed per-inference cost (µs): input staging, final sync.
    pub fixed_us: f64,
    /// Average device power during inference (W), as measured in Table 7.
    pub power_w: f64,
}

/// AMD Ryzen 5 5625U (6C/12T) running PyTorch 2.4, batch size 1.
pub const CPU_RYZEN_5625U: PlatformSpec = PlatformSpec {
    name: "CPU (Ryzen 5 5625U)",
    dense_gflops: 30.0,
    mem_bw_gbps: 12.0,
    op_overhead_us: 100.0,
    lookup_ns: 150.0,
    hop_sync_us: 0.0,
    fixed_us: 120.0,
    power_w: 24.9,
};

/// NVIDIA RTX A4000 (PyTorch + CUDA 12.1), batch size 1, parameters
/// resident in device memory.
pub const GPU_RTX_A4000: PlatformSpec = PlatformSpec {
    name: "GPU (RTX A4000)",
    dense_gflops: 2_000.0,
    mem_bw_gbps: 300.0,
    op_overhead_us: 70.0,
    lookup_ns: 150.0, // dictionary stage still runs on the host
    hop_sync_us: 350.0,
    fixed_us: 250.0,
    power_w: 60.5,
};

/// Average per-inference workload parameters for one (model, dataset)
/// pair — the inputs to Table 1's complexity expressions.
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    pub n: f64,
    pub f: f64,
    pub hops: usize,
    /// |B^(t)| per hop.
    pub hist_bins: Vec<f64>,
    pub s: f64,
    pub d: f64,
    pub classes: f64,
}

impl Workload {
    /// Derive from a trained model and dataset-average graph statistics.
    pub fn from_model(model: &NysHdcModel, avg_nodes: f64) -> Self {
        Self {
            n: avg_nodes,
            f: model.feature_dim as f64,
            hops: model.hops(),
            hist_bins: model.codebooks.iter().map(|c| c.len() as f64).collect(),
            s: model.s() as f64,
            d: model.d() as f64,
            classes: model.num_classes as f64,
        }
    }
}

/// One modeled framework op.
fn op_time_s(spec: &PlatformSpec, flops: f64, bytes: f64) -> f64 {
    let compute = flops / (spec.dense_gflops * 1e9);
    let memory = bytes / (spec.mem_bw_gbps * 1e9);
    compute.max(memory) + spec.op_overhead_us * 1e-6
}

/// Estimated end-to-end batch-1 latency in milliseconds.
pub fn estimate_latency_ms(spec: &PlatformSpec, w: &Workload) -> f64 {
    let mut t = spec.fixed_us * 1e-6;
    for hop in 0..w.hops {
        // Feature propagation M ← A M (dense, hops-1 times: skipped at
        // hop 0).
        if hop > 0 {
            t += op_time_s(
                spec,
                2.0 * w.n * w.n * w.f,
                4.0 * (w.n * w.n + 2.0 * w.n * w.f),
            );
        }
        // LSH projection (M u + b)/w.
        t += op_time_s(spec, 2.0 * w.n * w.f, 4.0 * w.n * (w.f + 1.0));
        // Floor to integer codes.
        t += op_time_s(spec, w.n, 8.0 * w.n);
        // Host-side codebook dictionary lookups (+ device sync on GPU).
        t += w.n * spec.lookup_ns * 1e-9 + spec.op_overhead_us * 1e-6 + spec.hop_sync_us * 1e-6;
        // Histogram scatter-add.
        t += op_time_s(spec, w.n, 8.0 * w.n);
        // Landmark similarity: DENSE s×|B| matvec.
        let bins = w.hist_bins.get(hop).copied().unwrap_or(0.0);
        t += op_time_s(spec, 2.0 * w.s * bins, 4.0 * w.s * bins);
        // Accumulate C += v.
        t += op_time_s(spec, w.s, 8.0 * w.s);
    }
    // Nyström projection y = P_nys C (memory bound: d×s stream).
    t += op_time_s(spec, 2.0 * w.s * w.d, 4.0 * w.s * w.d);
    // sign(y).
    t += op_time_s(spec, w.d, 8.0 * w.d);
    // Prototype matching + argmax.
    t += op_time_s(spec, 2.0 * w.classes * w.d, w.classes * w.d);
    t += op_time_s(spec, w.classes, 8.0 * w.classes);
    t * 1e3
}

/// Energy per inference in millijoules.
pub fn estimate_energy_mj(spec: &PlatformSpec, w: &Workload) -> f64 {
    spec.power_w * estimate_latency_ms(spec, w)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nci1_like() -> Workload {
        Workload {
            n: 30.0,
            f: 37.0,
            hops: 4,
            hist_bins: vec![500.0, 700.0, 900.0, 1100.0],
            s: 328.0,
            d: 10_000.0,
            classes: 2.0,
        }
    }

    fn dd_like() -> Workload {
        Workload {
            n: 284.0,
            f: 89.0,
            hops: 4,
            hist_bins: vec![3000.0, 5000.0, 6000.0, 7000.0],
            s: 327.0,
            d: 10_000.0,
            classes: 2.0,
        }
    }

    #[test]
    fn cpu_latencies_in_paper_band() {
        // Paper Table 6 CPU column spans 2.85–7.47 ms; the calibrated
        // model must land small-molecule datasets at a few ms and DD
        // higher than NCI1.
        let nci1 = estimate_latency_ms(&CPU_RYZEN_5625U, &nci1_like());
        let dd = estimate_latency_ms(&CPU_RYZEN_5625U, &dd_like());
        assert!(nci1 > 1.5 && nci1 < 9.0, "NCI1 CPU {nci1} ms");
        assert!(dd > nci1, "DD ({dd}) must exceed NCI1 ({nci1})");
        assert!(dd < 15.0, "DD CPU {dd} ms");
    }

    #[test]
    fn gpu_wins_on_compute_heavy_loses_on_hop_heavy() {
        // DD (big dense propagation): GPU < CPU.
        let dd_cpu = estimate_latency_ms(&CPU_RYZEN_5625U, &dd_like());
        let dd_gpu = estimate_latency_ms(&GPU_RTX_A4000, &dd_like());
        assert!(dd_gpu < dd_cpu, "GPU should win on DD: {dd_gpu} vs {dd_cpu}");
        // Hop-heavy tiny graphs (MUTAG-like, 6 hops): GPU ≥ CPU (the
        // paper's MUTAG/COX2 anomaly).
        let mutag = Workload {
            n: 18.0,
            f: 7.0,
            hops: 6,
            hist_bins: vec![80.0; 6],
            s: 148.0,
            d: 10_000.0,
            classes: 2.0,
        };
        let mutag_cpu = estimate_latency_ms(&CPU_RYZEN_5625U, &mutag);
        let mutag_gpu = estimate_latency_ms(&GPU_RTX_A4000, &mutag);
        assert!(
            mutag_gpu > mutag_cpu * 0.95,
            "GPU should not clearly win hop-heavy tiny graphs: {mutag_gpu} vs {mutag_cpu}"
        );
    }

    #[test]
    fn dpp_reduction_cuts_latency() {
        let mut w = nci1_like();
        let before = estimate_latency_ms(&CPU_RYZEN_5625U, &w);
        w.s *= 0.63;
        let after = estimate_latency_ms(&CPU_RYZEN_5625U, &w);
        assert!(after < before);
    }

    #[test]
    fn energy_is_power_times_time() {
        let w = nci1_like();
        let t = estimate_latency_ms(&CPU_RYZEN_5625U, &w);
        let e = estimate_energy_mj(&CPU_RYZEN_5625U, &w);
        assert!((e - t * 24.9).abs() < 1e-9);
    }
}
