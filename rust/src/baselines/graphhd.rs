//! GraphHD baseline (Nunes et al. [43]): the first HDC graph classifier.
//! Encodes *topology only* — node identity comes from PageRank-centrality
//! rank, edges are bound node-HV pairs, the graph HV bundles all edges.
//! Node labels/attributes are ignored, which is exactly the expressiveness
//! gap NysHD/NysX close (paper §7).
//!
//! The deployed path runs fully on [`PackedHypervector`]s so baseline
//! benches compare like-for-like with the packed NysX engine: edge
//! binding is a word-wise XOR into a reusable scratch HV, edge bundling
//! goes through the bit-sliced [`PackedAccumulator`] counters, and
//! classification is popcount matching against [`PackedPrototypes`] —
//! both of which dispatch through the same runtime-selected SIMD backend
//! ([`crate::hdc::simd`]) as the NysX engine, so a backend win shows up
//! identically on the baseline side of every comparison. The i8 path
//! ([`GraphHdModel::encode_reference`], `prototypes`) is retained as the
//! oracle; the tests pin the two bit-identical.
//!
//! Node ranking is *total and deterministic*: centralities are compared
//! with `f64::total_cmp` (no NaN panic) and exact ties break by node id,
//! so regular graphs — where every node has identical centrality — encode
//! reproducibly.

use crate::graph::{Graph, GraphDataset};
use crate::hdc::{
    Hypervector, PackedAccumulator, PackedHypervector, PackedPrototypes, PrototypeAccumulator,
};
use crate::util::rng::Xoshiro256;

/// GraphHD model: a codebook of rank-HVs plus class prototypes, in both
/// the deployed packed representation and the i8 oracle one.
#[derive(Debug, Clone)]
pub struct GraphHdModel {
    /// HV per centrality rank slot (rank r of a node indexes slot
    /// min(r, slots-1)) — i8 oracle representation.
    pub rank_hvs: Vec<Hypervector>,
    /// The same codebook packed to sign bits (deployed representation;
    /// bit-identical to `rank_hvs`).
    pub rank_hvs_packed: Vec<PackedHypervector>,
    /// i8 oracle prototypes.
    pub prototypes: crate::hdc::ClassPrototypes,
    /// Packed prototypes (deployed; bit-identical to `prototypes`).
    pub packed_prototypes: PackedPrototypes,
    pub dim: usize,
}

/// PageRank with damping 0.85, fixed iterations (sufficient for graphs of
/// a few hundred nodes).
pub fn pagerank(graph: &Graph, iters: usize) -> Vec<f64> {
    let n = graph.num_nodes();
    if n == 0 {
        return vec![];
    }
    let d = 0.85;
    let mut pr = vec![1.0 / n as f64; n];
    let mut next = vec![0.0f64; n];
    let degrees: Vec<f64> = (0..n).map(|v| graph.degree(v) as f64).collect();
    for _ in 0..iters {
        next.iter_mut().for_each(|x| *x = (1.0 - d) / n as f64);
        for v in 0..n {
            if degrees[v] == 0.0 {
                // Dangling mass spreads uniformly.
                let share = d * pr[v] / n as f64;
                next.iter_mut().for_each(|x| *x += share);
                continue;
            }
            let share = d * pr[v] / degrees[v];
            for k in graph.adj.row_range(v) {
                next[graph.adj.col_idx[k] as usize] += share;
            }
        }
        std::mem::swap(&mut pr, &mut next);
    }
    pr
}

impl GraphHdModel {
    /// Rank-slot assignment shared by the packed and i8 encoders: nodes
    /// sorted by descending PageRank under `total_cmp` (total over every
    /// f64, NaN included), exact ties broken by ascending node id — the
    /// encoding is deterministic even on regular graphs where all
    /// centralities coincide.
    fn rank_slots(&self, graph: &Graph) -> Vec<usize> {
        let n = graph.num_nodes();
        let pr = pagerank(graph, 30);
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| pr[b].total_cmp(&pr[a]).then(a.cmp(&b)));
        let mut slot_of = vec![0usize; n];
        for (rank, &v) in order.iter().enumerate() {
            slot_of[v] = rank.min(self.rank_hvs_packed.len() - 1);
        }
        slot_of
    }

    /// Encode one graph on the deployed packed path: nodes get rank-slot
    /// HVs by descending PageRank; each edge contributes
    /// `bind(hv_u, hv_v)` (word-wise XOR); the graph HV bundles all edges
    /// through the bit-sliced accumulator. Bit-identical to
    /// [`Self::encode_reference`] packed.
    pub fn encode(&self, graph: &Graph) -> PackedHypervector {
        let n = graph.num_nodes();
        let slot_of = self.rank_slots(graph);
        let mut acc = PackedAccumulator::new(1, self.dim);
        let mut edge_hv = PackedHypervector::zeros(self.dim);
        let mut any_edge = false;
        for u in 0..n {
            for k in graph.adj.row_range(u) {
                let v = graph.adj.col_idx[k] as usize;
                if v <= u {
                    continue; // undirected: each edge once
                }
                any_edge = true;
                self.rank_hvs_packed[slot_of[u]]
                    .bind_into(&self.rank_hvs_packed[slot_of[v]], &mut edge_hv);
                acc.add(0, &edge_hv);
            }
        }
        if !any_edge {
            // Degenerate edgeless graph: bundle node HVs instead.
            for v in 0..n {
                acc.add(0, &self.rank_hvs_packed[slot_of[v]]);
            }
        }
        acc.finalize().prototypes.pop().expect("one bundle class")
    }

    /// The i8 oracle encoder (verbatim element-wise sums + sign), kept
    /// for differential testing against [`Self::encode`].
    pub fn encode_reference(&self, graph: &Graph) -> Hypervector {
        let n = graph.num_nodes();
        let slot_of = self.rank_slots(graph);
        let mut acc = vec![0i64; self.dim];
        let mut any_edge = false;
        for u in 0..n {
            for k in graph.adj.row_range(u) {
                let v = graph.adj.col_idx[k] as usize;
                if v <= u {
                    continue; // undirected: each edge once
                }
                any_edge = true;
                let hu = &self.rank_hvs[slot_of[u]];
                let hv = &self.rank_hvs[slot_of[v]];
                for ((a, &x), &y) in acc.iter_mut().zip(&hu.data).zip(&hv.data) {
                    *a += (x * y) as i64;
                }
            }
        }
        if !any_edge {
            // Degenerate edgeless graph: bundle node HVs instead.
            for v in 0..n {
                for (a, &x) in acc.iter_mut().zip(&self.rank_hvs[slot_of[v]].data) {
                    *a += x as i64;
                }
            }
        }
        Hypervector {
            data: acc.iter().map(|&v| if v < 0 { -1 } else { 1 }).collect(),
        }
    }

    /// Deployed classification: packed encode + popcount prototype
    /// matching.
    pub fn classify(&self, graph: &Graph) -> usize {
        self.packed_prototypes.classify(&self.encode(graph))
    }
}

/// Train GraphHD on a dataset (packed end to end; the i8 oracle views are
/// derived losslessly from the packed training state).
pub fn train_graphhd(dataset: &GraphDataset, dim: usize, seed: u64) -> GraphHdModel {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let max_nodes = dataset
        .train
        .iter()
        .chain(dataset.test.iter())
        .map(|(g, _)| g.num_nodes())
        .max()
        .unwrap_or(1);
    // Draw the codebook in the i8 representation (keeps the RNG stream —
    // and therefore every trained model — identical to the pre-packed
    // implementation), then pack losslessly.
    let rank_hvs: Vec<Hypervector> = (0..max_nodes)
        .map(|_| Hypervector::random(dim, &mut rng))
        .collect();
    let rank_hvs_packed: Vec<PackedHypervector> = rank_hvs.iter().map(|h| h.pack()).collect();
    let mut model = GraphHdModel {
        rank_hvs,
        rank_hvs_packed,
        prototypes: PrototypeAccumulator::new(dataset.num_classes, dim).finalize(),
        packed_prototypes: PackedAccumulator::new(dataset.num_classes, dim).finalize(),
        dim,
    };
    let mut acc = PackedAccumulator::new(dataset.num_classes, dim);
    for (g, y) in &dataset.train {
        acc.add(*y, &model.encode(g));
    }
    model.packed_prototypes = acc.finalize();
    model.prototypes = model.packed_prototypes.to_reference();
    model
}

/// Test-set accuracy on the deployed packed path.
pub fn evaluate_graphhd(model: &GraphHdModel, split: &[(Graph, usize)]) -> f64 {
    if split.is_empty() {
        return 0.0;
    }
    let correct = split
        .iter()
        .filter(|(g, y)| model.classify(g) == *y)
        .count();
    correct as f64 / split.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::tudataset::spec_by_name;

    #[test]
    fn pagerank_sums_to_one_and_ranks_hubs() {
        // Star graph: center must dominate.
        let edges: Vec<(usize, usize)> = (1..6).map(|i| (0, i)).collect();
        let g = Graph::from_edges(6, &edges, &[0; 6], 1);
        let pr = pagerank(&g, 50);
        let total: f64 = pr.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "sum {total}");
        for leaf in 1..6 {
            assert!(pr[0] > pr[leaf]);
        }
    }

    #[test]
    fn beats_chance_on_structural_dataset() {
        // MUTAG is configured structure-dominant, so the topology-only
        // baseline must be clearly above chance there.
        // Full-size MUTAG (the scaled split has only ~23 test graphs,
        // too noisy for a threshold assertion).
        let spec = spec_by_name("MUTAG").unwrap();
        let ds = spec.generate(51);
        let model = train_graphhd(&ds, 4096, 9);
        let acc = evaluate_graphhd(&model, &ds.test);
        let majority = {
            let mut counts = vec![0usize; ds.num_classes];
            for (_, y) in &ds.test {
                counts[*y] += 1;
            }
            *counts.iter().max().unwrap() as f64 / ds.test.len() as f64
        };
        assert!(
            acc > 0.5 && acc > majority - 0.15,
            "GraphHD accuracy {acc} too low on MUTAG (majority {majority})"
        );
    }

    #[test]
    fn encode_deterministic() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(52, 0.2);
        let model = train_graphhd(&ds, 1024, 3);
        let g = &ds.test[0].0;
        assert_eq!(model.encode(g), model.encode(g));
        assert_eq!(model.encode_reference(g), model.encode_reference(g));
    }

    /// The packed encoder/classifier is bit-identical to the i8 oracle on
    /// real (structure-rich) graphs, prototypes included.
    #[test]
    fn packed_path_matches_i8_oracle() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(53, 0.2);
        // Off a 64 boundary so the tail word is live.
        let model = train_graphhd(&ds, 1000, 5);
        assert_eq!(
            model.packed_prototypes,
            PackedPrototypes::from_reference(&model.prototypes),
            "prototype representations diverged"
        );
        for (g, _) in ds.test.iter().take(8) {
            let packed = model.encode(g);
            let oracle = model.encode_reference(g);
            assert_eq!(packed, oracle.pack(), "encode != packed oracle");
            assert_eq!(
                model.classify(g),
                model.prototypes.classify(&oracle),
                "classification diverged from i8 oracle"
            );
        }
    }

    /// Regression (total ordering): on a regular graph every node has the
    /// same centrality, so ranking is pure tie-breaking. The encoder must
    /// not panic, must be deterministic, and must agree with the oracle.
    #[test]
    fn tie_heavy_regular_graph_encodes_deterministically() {
        // 8-cycle: every node has degree 2 and identical PageRank.
        let n = 8;
        let edges: Vec<(usize, usize)> = (0..n).map(|i| (i, (i + 1) % n)).collect();
        let g = Graph::from_edges(n, &edges, &[0; 8], 1);
        let pr = pagerank(&g, 30);
        for v in 1..n {
            assert!(
                (pr[v] - pr[0]).abs() < 1e-12,
                "cycle graph should have uniform centrality"
            );
        }
        let ds = GraphDataset {
            name: "cycle".to_string(),
            train: vec![(g.clone(), 0)],
            test: vec![(g.clone(), 0)],
            num_classes: 1,
            feature_dim: 1,
        };
        let model = train_graphhd(&ds, 257, 11);
        let a = model.encode(&g);
        let b = model.encode(&g);
        assert_eq!(a, b, "tie-heavy encoding must be deterministic");
        assert_eq!(a, model.encode_reference(&g).pack(), "packed != oracle on ties");
        // With uniform centrality the tie-break is node id: node v must
        // occupy rank slot v exactly.
        let slots = model.rank_slots(&g);
        assert_eq!(slots, (0..n).collect::<Vec<_>>(), "id tie-break violated");
    }

    /// Edgeless and empty graphs take the bundling fallback on both paths.
    #[test]
    fn degenerate_graphs_agree_with_oracle() {
        let edgeless = Graph::from_edges(5, &[], &[0; 5], 1);
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(54, 0.15);
        let model = train_graphhd(&ds, 130, 7);
        let packed = model.encode(&edgeless);
        assert_eq!(packed, model.encode_reference(&edgeless).pack());
        assert_eq!(
            model.packed_prototypes.classify(&packed),
            model.prototypes.classify(&model.encode_reference(&edgeless))
        );
    }
}
