//! GraphHD baseline (Nunes et al. [43]): the first HDC graph classifier.
//! Encodes *topology only* — node identity comes from PageRank-centrality
//! rank, edges are bound node-HV pairs, the graph HV bundles all edges.
//! Node labels/attributes are ignored, which is exactly the expressiveness
//! gap NysHD/NysX close (paper §7).

use crate::graph::{Graph, GraphDataset};
use crate::hdc::{Hypervector, PrototypeAccumulator};
use crate::util::rng::Xoshiro256;

/// GraphHD model: a codebook of rank-HVs plus class prototypes.
#[derive(Debug, Clone)]
pub struct GraphHdModel {
    /// HV per centrality rank slot (rank r of a node indexes slot
    /// min(r, slots-1)).
    pub rank_hvs: Vec<Hypervector>,
    pub prototypes: crate::hdc::ClassPrototypes,
    pub dim: usize,
}

/// PageRank with damping 0.85, fixed iterations (sufficient for graphs of
/// a few hundred nodes).
pub fn pagerank(graph: &Graph, iters: usize) -> Vec<f64> {
    let n = graph.num_nodes();
    if n == 0 {
        return vec![];
    }
    let d = 0.85;
    let mut pr = vec![1.0 / n as f64; n];
    let mut next = vec![0.0f64; n];
    let degrees: Vec<f64> = (0..n).map(|v| graph.degree(v) as f64).collect();
    for _ in 0..iters {
        next.iter_mut().for_each(|x| *x = (1.0 - d) / n as f64);
        for v in 0..n {
            if degrees[v] == 0.0 {
                // Dangling mass spreads uniformly.
                let share = d * pr[v] / n as f64;
                next.iter_mut().for_each(|x| *x += share);
                continue;
            }
            let share = d * pr[v] / degrees[v];
            for k in graph.adj.row_ptr[v]..graph.adj.row_ptr[v + 1] {
                next[graph.adj.col_idx[k] as usize] += share;
            }
        }
        std::mem::swap(&mut pr, &mut next);
    }
    pr
}

impl GraphHdModel {
    /// Encode one graph: nodes get rank-slot HVs by descending PageRank;
    /// each edge contributes bind(hv_u, hv_v); the graph HV bundles edges.
    pub fn encode(&self, graph: &Graph) -> Hypervector {
        let n = graph.num_nodes();
        let pr = pagerank(graph, 30);
        // Rank nodes by centrality (descending).
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| pr[b].partial_cmp(&pr[a]).unwrap());
        let mut slot_of = vec![0usize; n];
        for (rank, &v) in order.iter().enumerate() {
            slot_of[v] = rank.min(self.rank_hvs.len() - 1);
        }
        let mut acc = vec![0i64; self.dim];
        let mut any_edge = false;
        for u in 0..n {
            for k in graph.adj.row_ptr[u]..graph.adj.row_ptr[u + 1] {
                let v = graph.adj.col_idx[k] as usize;
                if v <= u {
                    continue; // undirected: each edge once
                }
                any_edge = true;
                let hu = &self.rank_hvs[slot_of[u]];
                let hv = &self.rank_hvs[slot_of[v]];
                for ((a, &x), &y) in acc.iter_mut().zip(&hu.data).zip(&hv.data) {
                    *a += (x * y) as i64;
                }
            }
        }
        if !any_edge {
            // Degenerate edgeless graph: bundle node HVs instead.
            for v in 0..n {
                for (a, &x) in acc.iter_mut().zip(&self.rank_hvs[slot_of[v]].data) {
                    *a += x as i64;
                }
            }
        }
        Hypervector {
            data: acc.iter().map(|&v| if v < 0 { -1 } else { 1 }).collect(),
        }
    }
}

/// Train GraphHD on a dataset.
pub fn train_graphhd(dataset: &GraphDataset, dim: usize, seed: u64) -> GraphHdModel {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let max_nodes = dataset
        .train
        .iter()
        .chain(dataset.test.iter())
        .map(|(g, _)| g.num_nodes())
        .max()
        .unwrap_or(1);
    let rank_hvs: Vec<Hypervector> = (0..max_nodes)
        .map(|_| Hypervector::random(dim, &mut rng))
        .collect();
    let mut model = GraphHdModel {
        rank_hvs,
        prototypes: PrototypeAccumulator::new(dataset.num_classes, dim).finalize(),
        dim,
    };
    let mut acc = PrototypeAccumulator::new(dataset.num_classes, dim);
    for (g, y) in &dataset.train {
        acc.add(*y, &model.encode(g));
    }
    model.prototypes = acc.finalize();
    model
}

/// Test-set accuracy.
pub fn evaluate_graphhd(model: &GraphHdModel, split: &[(Graph, usize)]) -> f64 {
    if split.is_empty() {
        return 0.0;
    }
    let correct = split
        .iter()
        .filter(|(g, y)| model.prototypes.classify(&model.encode(g)) == *y)
        .count();
    correct as f64 / split.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::tudataset::spec_by_name;

    #[test]
    fn pagerank_sums_to_one_and_ranks_hubs() {
        // Star graph: center must dominate.
        let edges: Vec<(usize, usize)> = (1..6).map(|i| (0, i)).collect();
        let g = Graph::from_edges(6, &edges, &[0; 6], 1);
        let pr = pagerank(&g, 50);
        let total: f64 = pr.iter().sum();
        assert!((total - 1.0).abs() < 1e-9, "sum {total}");
        for leaf in 1..6 {
            assert!(pr[0] > pr[leaf]);
        }
    }

    #[test]
    fn beats_chance_on_structural_dataset() {
        // MUTAG is configured structure-dominant, so the topology-only
        // baseline must be clearly above chance there.
        // Full-size MUTAG (the scaled split has only ~23 test graphs,
        // too noisy for a threshold assertion).
        let spec = spec_by_name("MUTAG").unwrap();
        let ds = spec.generate(51);
        let model = train_graphhd(&ds, 4096, 9);
        let acc = evaluate_graphhd(&model, &ds.test);
        let majority = {
            let mut counts = vec![0usize; ds.num_classes];
            for (_, y) in &ds.test {
                counts[*y] += 1;
            }
            *counts.iter().max().unwrap() as f64 / ds.test.len() as f64
        };
        assert!(
            acc > 0.5 && acc > majority - 0.15,
            "GraphHD accuracy {acc} too low on MUTAG (majority {majority})"
        );
    }

    #[test]
    fn encode_deterministic() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(52, 0.2);
        let model = train_graphhd(&ds, 1024, 3);
        let g = &ds.test[0].0;
        assert_eq!(model.encode(g), model.encode(g));
    }
}
