//! Baselines: the GraphHD and NysHD algorithmic baselines of Fig 7 and
//! the analytic CPU/GPU platform models of Tables 6-7.

pub mod graphhd;
pub mod nyshd;
pub mod platform;

pub use graphhd::{evaluate_graphhd, pagerank, train_graphhd, GraphHdModel};
pub use nyshd::{train_nyshd, train_nysx};
pub use platform::{
    estimate_energy_mj, estimate_latency_ms, PlatformSpec, Workload, CPU_RYZEN_5625U,
    GPU_RTX_A4000,
};
