//! NysHD baseline (Zhao et al. [64]): Nyström-HDC with *uniform* landmark
//! sampling and dense execution — algorithmically our model with
//! `LandmarkStrategy::Uniform` at the unreduced landmark budget. The
//! paper's NysX differs by (a) hybrid Uniform+DPP selection at a smaller
//! `s` and (b) the hardware pipeline (sparsity, MPH, streaming).

use crate::graph::GraphDataset;
use crate::model::{train::train, ModelConfig, NysHdcModel};
use crate::nystrom::LandmarkStrategy;

/// Train the NysHD configuration (uniform landmarks).
pub fn train_nyshd(dataset: &GraphDataset, s: usize, base: &ModelConfig) -> NysHdcModel {
    let cfg = ModelConfig {
        num_landmarks: s,
        strategy: LandmarkStrategy::Uniform,
        ..base.clone()
    };
    train(dataset, &cfg)
}

/// Train the NysX configuration (hybrid Uniform+DPP at reduced s).
pub fn train_nysx(dataset: &GraphDataset, s: usize, base: &ModelConfig) -> NysHdcModel {
    let cfg = ModelConfig {
        num_landmarks: s,
        strategy: LandmarkStrategy::HybridDpp { pool_factor: 2 },
        ..base.clone()
    };
    train(dataset, &cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::tudataset::spec_by_name;
    use crate::model::train::evaluate;

    #[test]
    fn both_configs_train_and_classify() {
        let spec = spec_by_name("BZR").unwrap();
        let (ds, s_uni, s_dpp) = spec.generate_scaled(61, 0.25);
        let base = ModelConfig {
            hops: 3,
            hv_dim: 2048,
            ..ModelConfig::default()
        };
        let nyshd = train_nyshd(&ds, s_uni, &base);
        let nysx = train_nysx(&ds, s_dpp, &base);
        assert!(nysx.s() < nyshd.s(), "NysX must use fewer landmarks");
        let chance = 1.0 / ds.num_classes as f64;
        assert!(evaluate(&nyshd, &ds.test).expect("non-empty split") > chance);
        assert!(evaluate(&nysx, &ds.test).expect("non-empty split") > chance);
        // Memory reduction follows directly from s.
        let m_uni = nyshd.memory_report().total_dense();
        let m_dpp = nysx.memory_report().total_dense();
        assert!(m_dpp < m_uni, "DPP must shrink the model: {m_dpp} vs {m_uni}");
    }
}
