//! Criterion-style micro-benchmark harness (no `criterion` in the
//! vendored crate set): warmup, adaptive iteration count targeting a
//! fixed measurement budget, mean/std/min/p50 reporting.

use std::time::{Duration, Instant};

use crate::util::{mean, percentile, stddev};

/// One benchmark's measurements.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: usize,
    pub iters_per_sample: u64,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
    pub p50_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
    pub fn throughput_per_s(&self) -> f64 {
        1e9 / self.mean_ns
    }
}

/// Benchmark a closure: ~`budget` of measurement after warmup, split into
/// `samples` batches. Returns per-call statistics.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup + calibration: estimate per-call cost.
    let cal_start = Instant::now();
    let mut cal_iters = 0u64;
    while cal_start.elapsed() < budget.div_f64(10.0).max(Duration::from_millis(5)) {
        f();
        cal_iters += 1;
    }
    let per_call = cal_start.elapsed().as_nanos() as f64 / cal_iters as f64;

    let samples = 20usize;
    let per_sample_ns = budget.as_nanos() as f64 / samples as f64;
    let iters = ((per_sample_ns / per_call).ceil() as u64).max(1);

    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        times.push(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    BenchResult {
        name: name.to_string(),
        samples,
        iters_per_sample: iters,
        mean_ns: mean(&times),
        std_ns: stddev(&times),
        min_ns: times.iter().cloned().fold(f64::INFINITY, f64::min),
        p50_ns: percentile(&times, 50.0),
    }
}

/// Pretty-print a batch of results.
pub fn print_results(results: &[BenchResult]) {
    let mut table = crate::util::table::Table::new("microbenchmarks")
        .header(&["bench", "mean", "p50", "min", "±std", "iters"]);
    for r in results {
        let fmt = |ns: f64| {
            if ns >= 1e6 {
                format!("{:.3} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.2} µs", ns / 1e3)
            } else {
                format!("{ns:.0} ns")
            }
        };
        table.row(&[
            r.name.clone(),
            fmt(r.mean_ns),
            fmt(r.p50_ns),
            fmt(r.min_ns),
            fmt(r.std_ns),
            format!("{}x{}", r.samples, r.iters_per_sample),
        ]);
    }
    table.print();
}

/// Prevent the optimizer from discarding a value (stable-rust black box).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_plausible() {
        let mut acc = 0u64;
        let r = bench("noop-ish", Duration::from_millis(50), || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.mean_ns > 0.0);
        assert!(r.mean_ns < 1e6, "a wrapping add should not take 1ms");
        assert!(r.min_ns <= r.mean_ns + 1e-9);
    }

    #[test]
    fn relative_ordering_detected() {
        let cheap = bench("cheap", Duration::from_millis(40), || {
            black_box((0..10u64).sum::<u64>());
        });
        let pricey = bench("pricey", Duration::from_millis(40), || {
            black_box((0..10_000u64).sum::<u64>());
        });
        assert!(pricey.mean_ns > cheap.mean_ns);
    }
}
