//! Memory-footprint benchmark: the measured side of the succinct layer
//! (DESIGN.md §10). For each TUDataset config it trains a model and
//! reports, head to head,
//!
//! * **MPH bits/key** — the bucketed phast engine vs the legacy BBHash
//!   cascade, built over the *same* codebook key sets (both engines
//!   count payload bytes through [`MphEngine::bits_per_key`], so the
//!   comparison is apples to apples);
//! * **model artifact bytes** — the v3 writer (Elias–Fano codebook and
//!   row-offset sections) vs the retained v2 writer, on the same
//!   trained model;
//! * **CSR row-offset bytes** — plain `(rows+1) × 8` vs the Elias–Fano
//!   encoding, summed over the model's landmark histograms.
//!
//! One large synthetic graph (preferential attachment, so the degree
//! distribution is adversarially skewed rather than uniform) probes the
//! same structures at a scale no TUDataset config reaches, and its
//! sequential key set anchors the pooled **headline bits/key**: total
//! MPH payload bits across every key set divided by total keys — the
//! honest version of the per-structure average, since tiny codebooks
//! carry fixed overhead that a per-set mean would hide.
//!
//! Emits `BENCH_MEMORY.json` (schema [`SCHEMA`]), round-trip-validated
//! before it lands on disk, exactly like `BENCH_SERVING.json`.
//! Smoke mode (`NYSX_BENCH_SMOKE=1`): two datasets and a 20k-node
//! synthetic graph, seconds end to end, same code paths.

use crate::api::NysxError;
use crate::bench::serving::smoke_mode;
use crate::graph::generators::preferential_attachment;
use crate::graph::tudataset::spec_by_name;
use crate::model::train::train;
use crate::model::{io as model_io, ModelConfig};
use crate::mph::{code_key, Mph, MphEngine};
use crate::sparse::Csr;
use crate::succinct::{EliasFano, PhastMph};
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;

/// Schema tag stamped into every artifact this module writes.
pub const SCHEMA: &str = "nysx-bench-memory/v1";

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct MemoryBenchConfig {
    /// TUDataset configs to measure (each trains one model).
    pub datasets: Vec<String>,
    pub scale: f64,
    pub seed: u64,
    pub hv_dim: usize,
    pub hops: usize,
    /// Node count of the synthetic preferential-attachment graph.
    pub synthetic_nodes: usize,
    /// Edges attached per new node (≈ half the average degree).
    pub synthetic_attach: usize,
}

impl Default for MemoryBenchConfig {
    fn default() -> Self {
        Self {
            datasets: crate::graph::tudataset::TU_SPECS
                .iter()
                .map(|s| s.name.to_string())
                .collect(),
            scale: 0.5,
            seed: 42,
            hv_dim: 2048,
            hops: 3,
            synthetic_nodes: 200_000,
            synthetic_attach: 4,
        }
    }
}

impl MemoryBenchConfig {
    /// The CI smoke sweep: two datasets at test scale, same code paths.
    pub fn smoke() -> Self {
        Self {
            datasets: vec!["MUTAG".to_string(), "BZR".to_string()],
            scale: 0.15,
            hv_dim: 500,
            synthetic_nodes: 20_000,
            ..Self::default()
        }
    }

    /// `smoke()` when `NYSX_BENCH_SMOKE` is set, full sweep otherwise.
    pub fn from_env() -> Self {
        if smoke_mode() {
            Self::smoke()
        } else {
            Self::default()
        }
    }
}

/// Running totals for the pooled headline: payload bits over keys,
/// accumulated across every key set both engines were built on.
#[derive(Debug, Clone, Copy, Default)]
struct Pooled {
    phast_bits: u64,
    legacy_bits: u64,
    keys: u64,
}

impl Pooled {
    /// Build both engines over one key set and fold its payload in.
    fn add_key_set(&mut self, keys: &[u64], gamma: f64) {
        let phast = MphEngine::Phast(PhastMph::build(keys));
        let legacy = MphEngine::Legacy(Mph::build(keys, gamma));
        self.phast_bits += phast.bytes() as u64 * 8;
        self.legacy_bits += legacy.bytes() as u64 * 8;
        self.keys += keys.len() as u64;
    }

    fn fold(&mut self, other: Pooled) {
        self.phast_bits += other.phast_bits;
        self.legacy_bits += other.legacy_bits;
        self.keys += other.keys;
    }

    fn phast_bits_per_key(&self) -> f64 {
        if self.keys == 0 {
            0.0
        } else {
            self.phast_bits as f64 / self.keys as f64
        }
    }

    fn legacy_bits_per_key(&self) -> f64 {
        if self.keys == 0 {
            0.0
        } else {
            self.legacy_bits as f64 / self.keys as f64
        }
    }
}

/// Measurements for one trained TUDataset config.
#[derive(Debug, Clone)]
pub struct DatasetMemory {
    pub dataset: String,
    /// Total codebook keys across hops (the MPH denominators).
    pub num_keys: usize,
    /// Pooled over this model's per-hop codebook key sets.
    pub phast_bits_per_key: f64,
    pub legacy_bits_per_key: f64,
    /// Serialized artifact bytes: retained v2 writer vs the v3 default.
    pub model_bytes_v2: usize,
    pub model_bytes_v3: usize,
    /// Landmark-histogram row offsets: plain usize array vs Elias–Fano.
    pub csr_offsets_plain_bytes: usize,
    pub csr_offsets_ef_bytes: usize,
}

impl DatasetMemory {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::str(self.dataset.as_str())),
            ("num_keys", Json::num(self.num_keys as f64)),
            ("phast_bits_per_key", Json::num(self.phast_bits_per_key)),
            ("legacy_bits_per_key", Json::num(self.legacy_bits_per_key)),
            ("model_bytes_v2", Json::num(self.model_bytes_v2 as f64)),
            ("model_bytes_v3", Json::num(self.model_bytes_v3 as f64)),
            (
                "csr_offsets_plain_bytes",
                Json::num(self.csr_offsets_plain_bytes as f64),
            ),
            (
                "csr_offsets_ef_bytes",
                Json::num(self.csr_offsets_ef_bytes as f64),
            ),
        ])
    }
}

/// Measurements on the large synthetic graph.
#[derive(Debug, Clone)]
pub struct SyntheticMemory {
    pub nodes: usize,
    pub edges: usize,
    /// Sequential LSH-shaped key set of `nodes` keys.
    pub num_keys: usize,
    pub phast_bits_per_key: f64,
    pub legacy_bits_per_key: f64,
    pub csr_offsets_plain_bytes: usize,
    pub csr_offsets_ef_bytes: usize,
}

impl SyntheticMemory {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("nodes", Json::num(self.nodes as f64)),
            ("edges", Json::num(self.edges as f64)),
            ("num_keys", Json::num(self.num_keys as f64)),
            ("phast_bits_per_key", Json::num(self.phast_bits_per_key)),
            ("legacy_bits_per_key", Json::num(self.legacy_bits_per_key)),
            (
                "csr_offsets_plain_bytes",
                Json::num(self.csr_offsets_plain_bytes as f64),
            ),
            (
                "csr_offsets_ef_bytes",
                Json::num(self.csr_offsets_ef_bytes as f64),
            ),
        ])
    }
}

/// The whole harness run — serialize with [`MemoryBenchReport::to_json`].
#[derive(Debug, Clone)]
pub struct MemoryBenchReport {
    pub config: MemoryBenchConfig,
    pub smoke: bool,
    pub datasets: Vec<DatasetMemory>,
    pub synthetic: SyntheticMemory,
    /// Pooled across every key set measured (datasets + synthetic).
    pub phast_bits_per_key: f64,
    pub legacy_bits_per_key: f64,
}

impl MemoryBenchReport {
    /// The `BENCH_MEMORY.json` document (schema documented in
    /// DESIGN.md §10).
    pub fn to_json(&self) -> Json {
        let c = &self.config;
        Json::obj(vec![
            ("schema", Json::str(SCHEMA)),
            ("bench", Json::str("memory")),
            ("smoke", Json::Bool(self.smoke)),
            (
                "config",
                Json::obj(vec![
                    (
                        "datasets",
                        Json::arr(c.datasets.iter().map(|d| Json::str(d.as_str()))),
                    ),
                    ("scale", Json::num(c.scale)),
                    ("seed", Json::num(c.seed as f64)),
                    ("hv_dim", Json::num(c.hv_dim as f64)),
                    ("hops", Json::num(c.hops as f64)),
                    ("synthetic_nodes", Json::num(c.synthetic_nodes as f64)),
                    ("synthetic_attach", Json::num(c.synthetic_attach as f64)),
                ]),
            ),
            (
                "headline",
                Json::obj(vec![
                    ("phast_bits_per_key", Json::num(self.phast_bits_per_key)),
                    ("legacy_bits_per_key", Json::num(self.legacy_bits_per_key)),
                ]),
            ),
            (
                "datasets",
                Json::arr(self.datasets.iter().map(DatasetMemory::to_json)),
            ),
            ("synthetic", self.synthetic.to_json()),
        ])
    }

    /// Emit, round-trip-validate, and write the artifact. The parse-back
    /// check guarantees no ill-formed artifact ever lands on disk.
    pub fn write(&self, path: &std::path::Path) -> Result<(), NysxError> {
        let doc = self.to_json();
        let text = doc.to_string();
        let back = Json::parse(&text).map_err(|e| {
            NysxError::Config(format!("emitted BENCH_MEMORY.json does not parse: {e}"))
        })?;
        if back != doc {
            return Err(NysxError::config(
                "BENCH_MEMORY.json round-trip drift: parsed document != emitted document",
            ));
        }
        std::fs::write(path, text + "\n").map_err(NysxError::Io)
    }
}

/// Plain row-offset footprint: the in-memory `usize` array the
/// Elias–Fano representation replaces.
fn plain_offset_bytes(rows: usize) -> usize {
    (rows + 1) * std::mem::size_of::<usize>()
}

/// Serialize through a writer into a counted buffer.
fn serialized_bytes(
    write: impl FnOnce(&mut Vec<u8>) -> std::io::Result<()>,
    what: &str,
) -> Result<usize, NysxError> {
    let mut buf = Vec::new();
    write(&mut buf).map_err(|e| NysxError::Config(format!("serializing {what} failed: {e}")))?;
    Ok(buf.len())
}

fn measure_dataset(
    name: &str,
    cfg: &MemoryBenchConfig,
    pooled: &mut Pooled,
) -> Result<DatasetMemory, NysxError> {
    let spec = spec_by_name(name)
        .ok_or_else(|| NysxError::Config(format!("unknown dataset {name:?}")))?;
    let (ds, _, s_dpp) = spec.generate_scaled(cfg.seed, cfg.scale);
    let model_cfg = ModelConfig {
        hops: cfg.hops,
        hv_dim: cfg.hv_dim,
        num_landmarks: s_dpp.min(ds.train.len()).max(4),
        seed: cfg.seed,
        ..ModelConfig::default()
    };
    let model = train(&ds, &model_cfg);

    // Both MPH engines over every per-hop codebook key set.
    let mut keys_total = 0usize;
    let mut local = Pooled::default();
    for cb in &model.codebooks {
        let keys: Vec<u64> = cb.codes.iter().map(|&c| code_key(c)).collect();
        keys_total += keys.len();
        local.add_key_set(&keys, model.config.mph_gamma);
    }
    pooled.fold(local);

    // Both artifact writers on the same trained model.
    let v2 = serialized_bytes(|buf| model_io::save_v2(&model, buf), "v2 model")?;
    let v3 = serialized_bytes(|buf| model_io::save(&model, buf), "v3 model")?;

    // Row-offset footprint across the landmark histograms.
    let mut plain = 0usize;
    let mut ef = 0usize;
    for h in &model.landmark_hists {
        plain += plain_offset_bytes(h.rows);
        let offsets: Vec<u64> = h.offsets().iter().map(|p| p as u64).collect();
        ef += EliasFano::from_sorted(&offsets).bytes();
    }

    Ok(DatasetMemory {
        dataset: name.to_string(),
        num_keys: keys_total,
        phast_bits_per_key: local.phast_bits_per_key(),
        legacy_bits_per_key: local.legacy_bits_per_key(),
        model_bytes_v2: v2,
        model_bytes_v3: v3,
        csr_offsets_plain_bytes: plain,
        csr_offsets_ef_bytes: ef,
    })
}

fn measure_synthetic(
    cfg: &MemoryBenchConfig,
    pooled: &mut Pooled,
) -> Result<SyntheticMemory, NysxError> {
    let n = cfg.synthetic_nodes.max(2);
    let mut rng = Xoshiro256::seed_from_u64(cfg.seed ^ 0x53594E54); // "SYNT"
    let edges = preferential_attachment(n, cfg.synthetic_attach.max(1), &mut rng);
    let mut triplets = Vec::with_capacity(edges.len() * 2);
    for &(u, v) in &edges {
        triplets.push((u, v, 1.0));
        triplets.push((v, u, 1.0));
    }
    let adj = Csr::from_triplets(n, n, triplets);

    let plain = plain_offset_bytes(adj.rows);
    let offsets: Vec<u64> = adj.offsets().iter().map(|p| p as u64).collect();
    let ef = EliasFano::from_sorted(&offsets).bytes();

    // Sequential LSH-shaped keys at a scale no TUDataset codebook
    // reaches — where the phast fixed overhead has fully amortized.
    let keys: Vec<u64> = (0..n as i64).map(code_key).collect();
    let mut local = Pooled::default();
    local.add_key_set(&keys, ModelConfig::default().mph_gamma);
    pooled.fold(local);

    Ok(SyntheticMemory {
        nodes: n,
        edges: edges.len(),
        num_keys: keys.len(),
        phast_bits_per_key: local.phast_bits_per_key(),
        legacy_bits_per_key: local.legacy_bits_per_key(),
        csr_offsets_plain_bytes: plain,
        csr_offsets_ef_bytes: ef,
    })
}

/// Run the whole harness: one trained model per dataset config, then
/// the synthetic graph, then the pooled headline.
pub fn run(cfg: &MemoryBenchConfig) -> Result<MemoryBenchReport, NysxError> {
    if cfg.datasets.is_empty() {
        return Err(NysxError::config("memory bench needs at least one dataset"));
    }
    let mut pooled = Pooled::default();
    let mut datasets = Vec::with_capacity(cfg.datasets.len());
    for name in &cfg.datasets {
        datasets.push(measure_dataset(name, cfg, &mut pooled)?);
    }
    let synthetic = measure_synthetic(cfg, &mut pooled)?;
    Ok(MemoryBenchReport {
        config: cfg.clone(),
        smoke: smoke_mode(),
        datasets,
        synthetic,
        phast_bits_per_key: pooled.phast_bits_per_key(),
        legacy_bits_per_key: pooled.legacy_bits_per_key(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The harness end to end at smoke scale: every dataset's v3
    /// artifact beats v2, the succinct MPH beats the cascade pooled and
    /// per structure at amortized scale, Elias–Fano beats the plain
    /// offsets on the big graph, and the artifact round-trips with the
    /// schema intact.
    #[test]
    fn smoke_run_measures_and_emits_valid_json() {
        let cfg = MemoryBenchConfig {
            datasets: vec!["MUTAG".to_string()],
            synthetic_nodes: 20_000,
            ..MemoryBenchConfig::smoke()
        };
        let report = run(&cfg).expect("smoke harness run");
        assert_eq!(report.datasets.len(), 1);
        for d in &report.datasets {
            assert!(d.num_keys > 0, "{} trained with empty codebooks", d.dataset);
            assert!(
                d.model_bytes_v3 < d.model_bytes_v2,
                "{}: v3 {} >= v2 {}",
                d.dataset,
                d.model_bytes_v3,
                d.model_bytes_v2
            );
            assert!(d.phast_bits_per_key > 0.0 && d.legacy_bits_per_key > 0.0);
        }
        let s = &report.synthetic;
        assert_eq!(s.nodes, 20_000);
        assert!(s.edges > s.nodes, "preferential attachment too sparse");
        assert!(
            s.csr_offsets_ef_bytes < s.csr_offsets_plain_bytes,
            "EF offsets {} >= plain {}",
            s.csr_offsets_ef_bytes,
            s.csr_offsets_plain_bytes
        );
        assert!(
            s.phast_bits_per_key < 3.0,
            "synthetic phast {} bits/key",
            s.phast_bits_per_key
        );
        // The headline the CI leg gates on.
        assert!(
            report.phast_bits_per_key < report.legacy_bits_per_key,
            "pooled phast {} >= legacy {}",
            report.phast_bits_per_key,
            report.legacy_bits_per_key
        );
        assert!(
            report.phast_bits_per_key < 3.0,
            "pooled headline {} bits/key",
            report.phast_bits_per_key
        );

        let doc = report.to_json();
        let text = doc.to_string();
        let back = Json::parse(&text).expect("artifact parses");
        assert_eq!(back, doc, "JSON round-trip drift");
        assert_eq!(back.get("schema").and_then(Json::as_str), Some(SCHEMA));
        let headline = back.get("headline").expect("headline object");
        let phast = headline
            .get("phast_bits_per_key")
            .and_then(Json::as_f64)
            .expect("headline.phast_bits_per_key");
        let legacy = headline
            .get("legacy_bits_per_key")
            .and_then(Json::as_f64)
            .expect("headline.legacy_bits_per_key");
        assert!(phast < legacy);
        let first = &back.get("datasets").unwrap().as_arr().unwrap()[0];
        for key in [
            "model_bytes_v2",
            "model_bytes_v3",
            "csr_offsets_plain_bytes",
            "csr_offsets_ef_bytes",
        ] {
            assert!(
                first.get(key).and_then(Json::as_usize).is_some(),
                "dataset entry missing {key}"
            );
        }
    }

    /// write() lands a parseable file on disk and unknown datasets are a
    /// typed error, not a panic.
    #[test]
    fn write_emits_parseable_artifact_and_bad_dataset_is_typed() {
        let cfg = MemoryBenchConfig {
            datasets: vec!["MUTAG".to_string()],
            synthetic_nodes: 2_000,
            ..MemoryBenchConfig::smoke()
        };
        let report = run(&cfg).expect("smoke run");
        let dir = std::env::temp_dir().join(format!("nysx-bench-mem-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_MEMORY.json");
        report.write(&path).expect("write");
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(&text).expect("file parses");
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(SCHEMA));
        std::fs::remove_dir_all(&dir).ok();

        let bad = MemoryBenchConfig {
            datasets: vec!["NOT_A_DATASET".to_string()],
            ..MemoryBenchConfig::smoke()
        };
        let err = run(&bad).err().expect("unknown dataset must be rejected");
        assert!(matches!(err, NysxError::Config(_)), "{err}");
    }
}
