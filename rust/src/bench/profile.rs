//! Observability profiling harness behind `nysx profile`: run the
//! training + inference pipeline (or the sharded serving tier) with
//! `nysx::obs` enabled, then emit the merged metric snapshot as the
//! machine-readable `PROFILE.json` artifact (schema [`SCHEMA`]),
//! optionally alongside a Prometheus text exposition.
//!
//! Two profile kinds:
//!
//! * **infer** — trains a pipeline (the `train_finalize` stage span),
//!   sweeps the test split through both the single-query and batched
//!   engine paths (the `featurize` / `spmv` / `mph_lookup` /
//!   `nee_project` / `sce_match` stage spans), then runs the §4.2
//!   load-balance comparison: the SAME synthetic skewed operand through
//!   the nnz-grouped scheduled SpMV (`spmv.nnz_row_groups` lane site)
//!   and a naive even-rows partition (`spmv.even_ranges`). The two
//!   arms' per-lane busy times land side by side in the artifact, so
//!   the imbalance ratio the paper's static LB removes is measurable
//!   from `PROFILE.json` alone.
//! * **serving** — drives a closed-window load through the sharded
//!   tier (queue/batch/shard-route spans, admission-shed counter) and
//!   attaches the per-shard [`MetricsSummary`] rollups.
//!
//! Smoke mode (`NYSX_BENCH_SMOKE=1`) shrinks both to CI scale, same
//! code paths. Like every `BENCH_*.json`, the artifact is parse-back
//! validated before it touches disk.

use crate::api::{NysxError, Pipeline, TrainedPipeline};
use crate::coordinator::{
    BatcherConfig, MetricsSummary, ServerConfig, ShardedConfig, SubmitError,
};
use crate::graph::Graph;
use crate::obs;
use crate::sparse::{Csr, SchedulePolicy, ScheduleTable};
use crate::util::json::Json;
use crate::util::rng::Xoshiro256;

use super::serving::smoke_mode;

/// Schema tag stamped into every `PROFILE.json`.
pub const SCHEMA: &str = "nysx-obs/v1";

/// Profiling harness configuration (shared by both kinds; each reads
/// the fields it needs).
#[derive(Debug, Clone)]
pub struct ProfileConfig {
    pub dataset: String,
    pub scale: f64,
    pub seed: u64,
    pub hv_dim: usize,
    /// Exec threads (None = global pool sizing). The SpMV comparison
    /// always uses at least 2 lanes — imbalance needs company.
    pub threads: Option<usize>,
    /// Inference passes over the test split (profile infer).
    pub repeats: usize,
    /// Rows of the synthetic skewed operand for the SpMV comparison.
    pub spmv_rows: usize,
    /// Heavy-row nonzero count of the synthetic operand (light rows get
    /// a handful) — the skew the §4.2 schedule flattens.
    pub spmv_heavy_nnz: usize,
    /// SpMV passes per comparison arm.
    pub spmv_passes: usize,
    /// Shards of the serving profile.
    pub shards: usize,
    /// Total requests the serving profile answers.
    pub requests: usize,
    pub workers_per_shard: usize,
    pub batch_size: usize,
    /// Per-shard admission cap.
    pub max_outstanding: usize,
}

impl Default for ProfileConfig {
    fn default() -> Self {
        Self {
            dataset: "MUTAG".to_string(),
            scale: 1.0,
            seed: 42,
            hv_dim: 2048,
            threads: None,
            repeats: 3,
            spmv_rows: 4096,
            spmv_heavy_nnz: 256,
            spmv_passes: 8,
            shards: 2,
            requests: 400,
            workers_per_shard: 2,
            batch_size: 4,
            max_outstanding: 256,
        }
    }
}

impl ProfileConfig {
    /// The CI smoke profile: seconds end to end, same code paths.
    pub fn smoke() -> Self {
        Self {
            scale: 0.2,
            hv_dim: 500,
            threads: Some(2),
            repeats: 1,
            spmv_rows: 512,
            spmv_heavy_nnz: 96,
            spmv_passes: 2,
            shards: 2,
            requests: 40,
            workers_per_shard: 1,
            batch_size: 2,
            max_outstanding: 64,
            ..Self::default()
        }
    }

    /// `smoke()` when `NYSX_BENCH_SMOKE` is set, full profile otherwise.
    pub fn from_env() -> Self {
        if smoke_mode() {
            Self::smoke()
        } else {
            Self::default()
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::str(self.dataset.as_str())),
            ("scale", Json::num(self.scale)),
            ("seed", Json::num(self.seed as f64)),
            ("hv_dim", Json::num(self.hv_dim as f64)),
            (
                "threads",
                match self.threads {
                    Some(n) => Json::num(n as f64),
                    None => Json::Null,
                },
            ),
            ("repeats", Json::num(self.repeats as f64)),
            ("spmv_rows", Json::num(self.spmv_rows as f64)),
            ("spmv_heavy_nnz", Json::num(self.spmv_heavy_nnz as f64)),
            ("spmv_passes", Json::num(self.spmv_passes as f64)),
            ("shards", Json::num(self.shards as f64)),
            ("requests", Json::num(self.requests as f64)),
            (
                "workers_per_shard",
                Json::num(self.workers_per_shard as f64),
            ),
            ("batch_size", Json::num(self.batch_size as f64)),
            ("max_outstanding", Json::num(self.max_outstanding as f64)),
        ])
    }
}

/// A finished profile run: the merged obs snapshot plus (for serving)
/// the per-shard coordinator rollups. Serialize with
/// [`ProfileReport::to_json`]; persist with [`ProfileReport::write`].
#[derive(Debug, Clone)]
pub struct ProfileReport {
    /// "infer" or "serving".
    pub kind: &'static str,
    pub smoke: bool,
    pub config: ProfileConfig,
    pub snapshot: obs::Snapshot,
    /// Per-shard [`MetricsSummary`] rollups, shard order (serving only).
    pub shard_rollups: Vec<MetricsSummary>,
}

impl ProfileReport {
    /// The `PROFILE.json` document (schema documented in DESIGN.md §11).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(SCHEMA)),
            ("kind", Json::str(self.kind)),
            ("smoke", Json::Bool(self.smoke)),
            ("config", self.config.to_json()),
            ("stages", self.stages_json()),
            ("snapshot", self.snapshot.to_json()),
            (
                "shards",
                Json::arr(self.shard_rollups.iter().map(shard_rollup_json)),
            ),
        ])
    }

    /// Convenience view: the six pipeline stages in catalog order with
    /// their headline numbers, so consumers don't have to dig through
    /// the full snapshot for the common question.
    fn stages_json(&self) -> Json {
        Json::arr(obs::STAGES.iter().map(|stage| {
            let name = format!("stage.{stage}");
            let hist = self
                .snapshot
                .histograms
                .iter()
                .find(|h| h.name == name)
                .expect("every pipeline stage is in the catalog");
            Json::obj(vec![
                ("name", Json::str(*stage)),
                ("count", Json::num(hist.count as f64)),
                ("sum_ns", Json::num(hist.sum_ns as f64)),
                ("mean_ns", Json::num(hist.mean_ns())),
                ("p50_ns", Json::num(hist.percentile_ns(50.0) as f64)),
                ("p99_ns", Json::num(hist.percentile_ns(99.0) as f64)),
            ])
        }))
    }

    /// Emit, round-trip-validate, and write the artifact. The parse-back
    /// check guarantees no ill-formed artifact ever lands on disk.
    pub fn write(&self, path: &std::path::Path) -> Result<(), NysxError> {
        let doc = self.to_json();
        let text = doc.to_string();
        let back = Json::parse(&text).map_err(|e| {
            NysxError::Config(format!("emitted PROFILE.json does not parse: {e}"))
        })?;
        if back != doc {
            return Err(NysxError::config(
                "PROFILE.json round-trip drift: parsed document != emitted document",
            ));
        }
        std::fs::write(path, text + "\n").map_err(NysxError::Io)
    }
}

fn shard_rollup_json(s: &MetricsSummary) -> Json {
    Json::obj(vec![
        ("requests", Json::num(s.requests as f64)),
        ("misattributed", Json::num(s.misattributed as f64)),
        (
            "per_worker",
            Json::arr(s.per_worker.iter().map(|&n| Json::num(n as f64))),
        ),
        ("host_throughput_rps", Json::num(s.host_throughput_rps)),
        (
            "host_us",
            Json::obj(vec![
                ("mean", Json::num(s.host_us.mean)),
                ("p50", Json::num(s.host_us.p50)),
                ("p99", Json::num(s.host_us.p99)),
                ("min", Json::num(s.host_us.min)),
                ("max", Json::num(s.host_us.max)),
            ]),
        ),
        (
            "queue_us",
            Json::obj(vec![
                ("mean", Json::num(s.queue_us.mean)),
                ("p50", Json::num(s.queue_us.p50)),
                ("p99", Json::num(s.queue_us.p99)),
                ("min", Json::num(s.queue_us.min)),
                ("max", Json::num(s.queue_us.max)),
            ]),
        ),
    ])
}

fn trained_pipeline(cfg: &ProfileConfig) -> Result<TrainedPipeline, NysxError> {
    let mut builder = Pipeline::for_dataset(&cfg.dataset)?
        .scale(cfg.scale)
        .seed(cfg.seed)
        .hv_dim(cfg.hv_dim);
    if let Some(n) = cfg.threads {
        builder = builder.threads(n);
    }
    builder.train()
}

/// The inference profile: training + full test-split sweeps (single and
/// batched) + the scheduled-vs-even SpMV lane comparison, all under a
/// freshly reset obs registry.
pub fn profile_infer(cfg: &ProfileConfig) -> Result<ProfileReport, NysxError> {
    obs::set_enabled(true);
    obs::registry().reset_all();
    obs::metrics::EXEC_THREADS.set(
        cfg.threads
            .unwrap_or_else(|| crate::exec::global().threads()) as u64,
    );
    let mut pipeline = trained_pipeline(cfg)?;
    let graphs: Vec<Graph> = pipeline
        .dataset()
        .test
        .iter()
        .map(|(g, _)| g.clone())
        .collect();
    if graphs.is_empty() {
        return Err(NysxError::config("profile needs a non-empty test split"));
    }
    for _ in 0..cfg.repeats.max(1) {
        for g in &graphs {
            let _ = pipeline.infer(g);
        }
        let refs: Vec<&Graph> = graphs.iter().collect();
        let _ = pipeline.infer_batch(&refs);
    }
    spmv_lane_comparison(cfg);
    Ok(ProfileReport {
        kind: "infer",
        smoke: smoke_mode(),
        config: cfg.clone(),
        snapshot: obs::Snapshot::capture(),
        shard_rollups: Vec::new(),
    })
}

/// The serving profile: a closed admission window over the sharded tier
/// until `cfg.requests` responses have been collected.
pub fn profile_serving(cfg: &ProfileConfig) -> Result<ProfileReport, NysxError> {
    obs::set_enabled(true);
    obs::registry().reset_all();
    obs::metrics::EXEC_THREADS.set(
        cfg.threads
            .unwrap_or_else(|| crate::exec::global().threads()) as u64,
    );
    let pipeline = trained_pipeline(cfg)?;
    let graphs: Vec<Graph> = pipeline
        .dataset()
        .test
        .iter()
        .map(|(g, _)| g.clone())
        .collect();
    if graphs.is_empty() {
        return Err(NysxError::config("profile needs a non-empty test split"));
    }
    let mut tier = pipeline.serve_sharded(ShardedConfig {
        shards: cfg.shards,
        max_outstanding: cfg.max_outstanding,
        per_shard: ServerConfig {
            workers: cfg.workers_per_shard,
            batcher: BatcherConfig {
                batch_size: cfg.batch_size,
                max_wait: std::time::Duration::from_micros(200),
                ..Default::default()
            },
            ..Default::default()
        },
    })?;
    // Keep a bounded window in flight: enough outstanding work to form
    // real batches, never more than the tier's admission cap.
    let window = (cfg.batch_size * cfg.shards * 4)
        .clamp(1, cfg.max_outstanding);
    let total = cfg.requests.max(1);
    let (mut submitted, mut answered, mut next) = (0usize, 0usize, 0usize);
    while answered < total {
        while submitted < total && submitted - answered < window {
            let g = graphs[next % graphs.len()].clone();
            next += 1;
            match tier.submit(g) {
                Ok(_) => submitted += 1,
                Err(SubmitError::Backpressure(_)) => break,
                Err(SubmitError::Closed(_)) => {
                    return Err(NysxError::Closed);
                }
            }
        }
        if tier.recv().is_some() {
            answered += 1;
        }
    }
    let shard_rollups: Vec<MetricsSummary> =
        (0..cfg.shards).map(|s| tier.shard_metrics(s)).collect();
    tier.shutdown();
    Ok(ProfileReport {
        kind: "serving",
        smoke: smoke_mode(),
        config: cfg.clone(),
        snapshot: obs::Snapshot::capture(),
        shard_rollups,
    })
}

/// The §4.2 comparison the lane sites exist for: run the SAME skewed
/// operand through the nnz-grouped scheduled SpMV and through a naive
/// even-rows partition, so `spmv.nnz_row_groups` vs `spmv.even_ranges`
/// per-lane busy times (and their imbalance ratios) land side by side
/// in the snapshot. The two arms must produce bit-identical results —
/// scheduling only permutes work.
fn spmv_lane_comparison(cfg: &ProfileConfig) {
    let csr = skewed_csr(cfg.spmv_rows.max(8), cfg.spmv_heavy_nnz.max(2), cfg.seed);
    let threads = cfg
        .threads
        .unwrap_or_else(|| crate::exec::global().threads())
        .max(2);
    let pool = crate::exec::Pool::new(threads);
    let x: Vec<f64> = (0..csr.cols).map(|j| 1.0 + (j % 7) as f64).collect();
    let passes = cfg.spmv_passes.max(1);

    // Arm 1: the paper's static LB schedule (lane site is inside
    // `run_spmv_with_pool`).
    let table = ScheduleTable::build(&csr, threads * 8, SchedulePolicy::NnzGrouped);
    let mut y_scheduled = vec![0.0; csr.rows];
    for _ in 0..passes {
        table.run_spmv_with_pool(&pool, &csr, &x, &mut y_scheduled);
    }

    // Arm 2: naive even contiguous row ranges — the "no LB" baseline the
    // schedule beats on skewed operands.
    let ranges = crate::exec::even_ranges(csr.rows, pool.threads());
    let mut y_even = vec![0.0; csr.rows];
    for _ in 0..passes {
        crate::exec::for_each_range_mut_labeled(
            &pool,
            &obs::lanes::SITE_SPMV_EVEN,
            &mut y_even,
            &ranges,
            |block, part| {
                for (local, r) in ranges[block].clone().enumerate() {
                    let mut acc = 0.0;
                    for k in csr.row_range(r) {
                        acc += csr.val[k] * x[csr.col_idx[k] as usize];
                    }
                    part[local] = acc;
                }
            },
        );
    }
    assert_eq!(
        y_scheduled, y_even,
        "scheduled and even-ranges SpMV must agree bit-for-bit"
    );
}

/// A deterministic skewed operand: the first eighth of the rows are
/// heavy (`heavy_nnz` nonzeros each), the rest carry 1–4 — the row-nnz
/// distribution where even contiguous ranges concentrate nearly all
/// work in the lane owning the heavy block.
fn skewed_csr(rows: usize, heavy_nnz: usize, seed: u64) -> Csr {
    let cols = rows;
    let mut rng = Xoshiro256::seed_from_u64(seed ^ 0x5b3c_9d1e);
    let mut triplets = Vec::new();
    for r in 0..rows {
        let nnz = if r < rows / 8 {
            heavy_nnz.min(cols)
        } else {
            1 + r % 4
        };
        for i in 0..nnz {
            // Spread columns deterministically; duplicate (r, c) pairs
            // stay as separate nnz entries, so every row keeps exactly
            // `nnz` stored values and the skew is exact.
            let c = (r * 31 + i * 97 + rng.gen_range(7)) % cols;
            triplets.push((r, c, 1.0 + (i % 5) as f64));
        }
    }
    Csr::from_triplets(rows, cols, triplets)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The infer profile at smoke scale covers every pipeline stage and
    /// both SpMV comparison arms, and its artifact round-trips with the
    /// schema intact.
    #[test]
    fn infer_profile_covers_stages_and_lane_sites() {
        let _guard = crate::obs::test_toggle_lock();
        let cfg = ProfileConfig::smoke();
        let report = profile_infer(&cfg).expect("smoke profile runs");
        crate::obs::set_enabled(false);
        for stage in obs::STAGES {
            let name = format!("stage.{stage}");
            let hist = report
                .snapshot
                .histograms
                .iter()
                .find(|h| h.name == name)
                .expect("stage histogram in snapshot");
            assert!(hist.count > 0, "stage {stage} recorded nothing");
        }
        for site in ["spmv.nnz_row_groups", "spmv.even_ranges"] {
            let lane = report
                .snapshot
                .lanes
                .iter()
                .find(|l| l.name == site)
                .expect("lane site in snapshot");
            assert!(lane.runs > 0, "lane site {site} never ran");
            assert!(lane.imbalance() >= 1.0, "{site}: imbalance below 1");
        }

        let doc = report.to_json();
        let back = Json::parse(&doc.to_string()).expect("artifact parses");
        assert_eq!(back, doc, "JSON round-trip drift");
        assert_eq!(back.get("schema").and_then(Json::as_str), Some(SCHEMA));
        assert_eq!(back.get("kind").and_then(Json::as_str), Some("infer"));
        let stages = back.get("stages").and_then(Json::as_arr).expect("stages");
        assert_eq!(stages.len(), obs::STAGES.len());
    }

    /// The serving profile at smoke scale answers every request, rolls
    /// up per-shard metrics with zero misattribution, and emits a valid
    /// artifact.
    #[test]
    fn serving_profile_rolls_up_shards() {
        let _guard = crate::obs::test_toggle_lock();
        let cfg = ProfileConfig::smoke();
        let report = profile_serving(&cfg).expect("smoke profile runs");
        crate::obs::set_enabled(false);
        assert_eq!(report.kind, "serving");
        assert_eq!(report.shard_rollups.len(), cfg.shards);
        let answered: usize = report.shard_rollups.iter().map(|s| s.requests).sum();
        assert_eq!(answered, cfg.requests, "every request must be answered");
        for (i, s) in report.shard_rollups.iter().enumerate() {
            assert_eq!(s.misattributed, 0, "shard {i} misattributed samples");
        }
        let (_, serve_requests) = report
            .snapshot
            .counters
            .iter()
            .find(|(n, _)| *n == "serve.requests")
            .expect("serve.requests counter");
        // >= not ==: the registry is process-global, so concurrent tests
        // exercising the serving path while obs is on add to it too.
        assert!(*serve_requests as usize >= cfg.requests);

        let doc = report.to_json();
        let back = Json::parse(&doc.to_string()).expect("artifact parses");
        assert_eq!(back, doc, "JSON round-trip drift");
        let shards = back.get("shards").and_then(Json::as_arr).expect("shards");
        assert_eq!(shards.len(), cfg.shards);
    }

    /// The skewed operand really is skewed, and both SpMV arms agree.
    #[test]
    fn skewed_operand_has_heavy_head() {
        let csr = skewed_csr(256, 64, 9);
        let head: usize = (0..32).map(|r| csr.row_nnz(r)).sum();
        let tail: usize = (32..256).map(|r| csr.row_nnz(r)).sum();
        assert!(
            head > tail / 2,
            "head rows must dominate: head {head} vs tail {tail}"
        );
    }
}
