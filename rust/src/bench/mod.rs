//! Benchmark support: the criterion-style timing harness and the shared
//! evaluation driver that regenerates every table and figure of the
//! paper.

pub mod harness;
pub mod memory;
pub mod profile;
pub mod serving;
pub mod tables;

pub use harness::{bench, black_box, print_results, BenchResult};
pub use memory::{MemoryBenchConfig, MemoryBenchReport};
pub use profile::{profile_infer, profile_serving, ProfileConfig, ProfileReport};
pub use serving::{ServingBenchConfig, ServingBenchReport};
pub use tables::{evaluate_all, evaluate_dataset, evaluate_dataset_cached, DatasetEval, EvalConfig};
