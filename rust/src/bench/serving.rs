//! Serving load harness: closed- and open-loop load generation against
//! the sharded serving tier, sweeping offered QPS against measured
//! p50/p99/p999 end-to-end latency per shard count, and emitting the
//! machine-readable `BENCH_SERVING.json` artifact — the first entry in
//! the repo's benchmark-artifact convention (every `BENCH_*.json`
//! carries a `schema` tag and is valid input to `Json::parse`).
//!
//! Two load modes per shard count:
//!
//! * **Closed loop** — a fixed number of logical clients, each with one
//!   request in flight; a response immediately triggers the next submit.
//!   Measures the tier's maximum sustained throughput and the latency it
//!   costs.
//! * **Open loop** — arrivals on a fixed wall-clock schedule (offered
//!   QPS), independent of completions. Requests the tier cannot admit
//!   are shed (typed `Backpressure`) and counted as rejected. This is
//!   the honest tail-latency probe: unlike closed loop, slow responses
//!   do not throttle the arrival rate.
//!
//! Accounting invariant, asserted after every stage: **sent == answered
//! + rejected** — no silently lost requests, under load or shedding.
//!
//! Smoke mode (`NYSX_BENCH_SMOKE=1`): shrink the sweep so CI can assert
//! the artifact exists and is well-formed in seconds.

use std::collections::HashMap;
use std::time::{Duration, Instant};

use crate::api::{NysxError, Pipeline, ShardedServeHandle};
use crate::coordinator::{
    BatcherConfig, LatencyStats, ServerConfig, ShardedConfig, SubmitError,
};
use crate::graph::Graph;
use crate::util::json::Json;

/// Schema tag stamped into every artifact this module writes.
pub const SCHEMA: &str = "nysx-bench-serving/v1";

/// `NYSX_BENCH_SMOKE` truthiness, shared convention with the
/// micro-kernel bench binary.
pub fn smoke_mode() -> bool {
    std::env::var("NYSX_BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0")
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct ServingBenchConfig {
    pub dataset: String,
    pub scale: f64,
    pub seed: u64,
    pub hv_dim: usize,
    /// Exec threads per shard pool (None = global pool sizing).
    pub threads: Option<usize>,
    /// Shard counts to sweep (the paper-repro default is {1, 2, 4}).
    pub shard_counts: Vec<usize>,
    /// Offered-QPS points for the open-loop sweep.
    pub qps_points: Vec<f64>,
    /// Arrivals per open-loop sweep point.
    pub requests_per_point: usize,
    /// Total requests of the closed-loop stage.
    pub closed_loop_requests: usize,
    /// Concurrent logical clients of the closed-loop stage.
    pub closed_loop_clients: usize,
    pub workers_per_shard: usize,
    pub batch_size: usize,
    /// Per-shard admission cap (typed Backpressure beyond it).
    pub max_outstanding: usize,
}

impl Default for ServingBenchConfig {
    fn default() -> Self {
        Self {
            dataset: "MUTAG".to_string(),
            scale: 1.0,
            seed: 42,
            hv_dim: 2048,
            threads: None,
            shard_counts: vec![1, 2, 4],
            qps_points: vec![100.0, 300.0, 1000.0, 3000.0],
            requests_per_point: 2000,
            closed_loop_requests: 2000,
            closed_loop_clients: 16,
            workers_per_shard: 2,
            batch_size: 4,
            max_outstanding: 256,
        }
    }
}

impl ServingBenchConfig {
    /// The CI smoke sweep: seconds end to end, same code paths.
    pub fn smoke() -> Self {
        Self {
            scale: 0.2,
            hv_dim: 500,
            threads: Some(1),
            shard_counts: vec![1, 2],
            qps_points: vec![200.0],
            requests_per_point: 40,
            closed_loop_requests: 40,
            closed_loop_clients: 4,
            workers_per_shard: 1,
            batch_size: 2,
            max_outstanding: 64,
            ..Self::default()
        }
    }

    /// `smoke()` when `NYSX_BENCH_SMOKE` is set, full sweep otherwise.
    pub fn from_env() -> Self {
        if smoke_mode() {
            Self::smoke()
        } else {
            Self::default()
        }
    }
}

/// Measurements of one load stage (closed loop, or one open-loop point).
#[derive(Debug, Clone)]
pub struct StageResult {
    pub sent: usize,
    pub answered: usize,
    pub rejected: usize,
    pub wall_s: f64,
    /// Answered requests per wall second.
    pub achieved_qps: f64,
    /// End-to-end latency (submit → response receipt), milliseconds.
    pub latency_ms: LatencyStats,
}

impl StageResult {
    fn from_samples(
        sent: usize,
        rejected: usize,
        wall: Duration,
        latencies_ms: &[f64],
    ) -> Result<Self, NysxError> {
        let answered = latencies_ms.len();
        // The load generator's books must balance exactly; anything else
        // means the tier lost or duplicated a response.
        if sent != answered + rejected {
            return Err(NysxError::Config(format!(
                "serving bench accounting broken: sent {sent} != answered {answered} + rejected {rejected}"
            )));
        }
        let wall_s = wall.as_secs_f64();
        Ok(Self {
            sent,
            answered,
            rejected,
            wall_s,
            achieved_qps: if wall_s > 0.0 {
                answered as f64 / wall_s
            } else {
                0.0
            },
            latency_ms: LatencyStats::from_samples(latencies_ms),
        })
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("sent", Json::num(self.sent as f64)),
            ("answered", Json::num(self.answered as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("wall_s", Json::num(self.wall_s)),
            ("achieved_qps", Json::num(self.achieved_qps)),
            (
                "latency_ms",
                Json::obj(vec![
                    ("mean", Json::num(self.latency_ms.mean)),
                    ("p50", Json::num(self.latency_ms.p50)),
                    ("p99", Json::num(self.latency_ms.p99)),
                    ("p999", Json::num(self.latency_ms.p999)),
                    ("min", Json::num(self.latency_ms.min)),
                    ("max", Json::num(self.latency_ms.max)),
                ]),
            ),
        ])
    }
}

/// All stages for one shard count.
#[derive(Debug, Clone)]
pub struct ShardRun {
    pub shards: usize,
    pub closed_loop: StageResult,
    /// One entry per `qps_points` value, in sweep order.
    pub open_loop: Vec<(f64, StageResult)>,
}

/// The whole harness run — serialize with [`ServingBenchReport::to_json`].
#[derive(Debug, Clone)]
pub struct ServingBenchReport {
    pub config: ServingBenchConfig,
    pub smoke: bool,
    pub runs: Vec<ShardRun>,
}

impl ServingBenchReport {
    /// The `BENCH_SERVING.json` document (schema documented in
    /// DESIGN.md §7).
    pub fn to_json(&self) -> Json {
        let c = &self.config;
        Json::obj(vec![
            ("schema", Json::str(SCHEMA)),
            ("bench", Json::str("serving")),
            ("dataset", Json::str(c.dataset.as_str())),
            ("scale", Json::num(c.scale)),
            ("seed", Json::num(c.seed as f64)),
            ("smoke", Json::Bool(self.smoke)),
            (
                "config",
                Json::obj(vec![
                    ("hv_dim", Json::num(c.hv_dim as f64)),
                    (
                        "shard_counts",
                        Json::arr(c.shard_counts.iter().map(|&s| Json::num(s as f64))),
                    ),
                    (
                        "qps_points",
                        Json::arr(c.qps_points.iter().map(|&q| Json::num(q))),
                    ),
                    ("workers_per_shard", Json::num(c.workers_per_shard as f64)),
                    ("batch_size", Json::num(c.batch_size as f64)),
                    ("max_outstanding", Json::num(c.max_outstanding as f64)),
                    (
                        "requests_per_point",
                        Json::num(c.requests_per_point as f64),
                    ),
                    (
                        "closed_loop_requests",
                        Json::num(c.closed_loop_requests as f64),
                    ),
                    (
                        "closed_loop_clients",
                        Json::num(c.closed_loop_clients as f64),
                    ),
                ]),
            ),
            (
                "runs",
                Json::arr(self.runs.iter().map(|run| {
                    Json::obj(vec![
                        ("shards", Json::num(run.shards as f64)),
                        ("closed_loop", run.closed_loop.to_json()),
                        (
                            "open_loop",
                            Json::arr(run.open_loop.iter().map(|(qps, stage)| {
                                let mut obj = stage.to_json();
                                if let Json::Obj(map) = &mut obj {
                                    map.insert("offered_qps".to_string(), Json::num(*qps));
                                }
                                obj
                            })),
                        ),
                    ])
                })),
            ),
        ])
    }

    /// Emit, round-trip-validate, and write the artifact. The parse-back
    /// check guarantees no ill-formed artifact ever lands on disk.
    pub fn write(&self, path: &std::path::Path) -> Result<(), NysxError> {
        let doc = self.to_json();
        let text = doc.to_string();
        let back = Json::parse(&text).map_err(|e| {
            NysxError::Config(format!("emitted BENCH_SERVING.json does not parse: {e}"))
        })?;
        if back != doc {
            return Err(NysxError::config(
                "BENCH_SERVING.json round-trip drift: parsed document != emitted document",
            ));
        }
        std::fs::write(path, text + "\n").map_err(NysxError::Io)
    }
}

/// The closed-loop stage: keep `clients` requests in flight until
/// `total` have been answered.
fn closed_loop(
    tier: &mut ShardedServeHandle,
    graphs: &[Graph],
    clients: usize,
    total: usize,
) -> Result<StageResult, NysxError> {
    let mut submitted_at: HashMap<u64, Instant> = HashMap::new();
    let mut latencies_ms = Vec::with_capacity(total);
    let mut sent = 0usize;
    let mut rejected = 0usize;
    let mut next_graph = 0usize;
    let start = Instant::now();
    while latencies_ms.len() + rejected < total {
        // Top up to the client count (or the remaining budget).
        while submitted_at.len() < clients && sent < total {
            let g = graphs[next_graph % graphs.len()].clone();
            next_graph += 1;
            let now = Instant::now();
            match tier.submit(g) {
                Ok(id) => {
                    submitted_at.insert(id, now);
                    sent += 1;
                }
                Err(SubmitError::Backpressure(_)) => {
                    // Closed loop sized within the admission cap should
                    // never shed; count it if a config makes it happen.
                    sent += 1;
                    rejected += 1;
                }
                Err(SubmitError::Closed(_)) => {
                    return Err(NysxError::Closed);
                }
            }
        }
        if submitted_at.is_empty() {
            break; // everything shed — books still balance below
        }
        match tier.recv() {
            Some(resp) => {
                let at = submitted_at.remove(&resp.id).ok_or_else(|| {
                    NysxError::Config(format!("response for unknown request id {}", resp.id))
                })?;
                latencies_ms.push(at.elapsed().as_secs_f64() * 1e3);
            }
            None => return Err(NysxError::Closed),
        }
    }
    StageResult::from_samples(sent, rejected, start.elapsed(), &latencies_ms)
}

/// One open-loop point: `total` arrivals on a fixed `qps` schedule;
/// arrivals the tier cannot admit are shed and counted.
fn open_loop(
    tier: &mut ShardedServeHandle,
    graphs: &[Graph],
    qps: f64,
    total: usize,
) -> Result<StageResult, NysxError> {
    let period = Duration::from_secs_f64(1.0 / qps.max(1e-9));
    let mut submitted_at: HashMap<u64, Instant> = HashMap::new();
    let mut latencies_ms = Vec::with_capacity(total);
    let mut rejected = 0usize;
    let start = Instant::now();
    for i in 0..total {
        // The arrival clock is absolute (start + i·period): a stalled
        // tier does not slow the offered load — that's the difference
        // between open and closed loop.
        let due = start + period.mul_f64(i as f64);
        loop {
            let now = Instant::now();
            if now >= due {
                break;
            }
            // Poll completions between arrivals instead of sleeping the
            // whole gap, so response timestamps stay tight.
            if let Some(resp) = tier.try_recv() {
                if let Some(at) = submitted_at.remove(&resp.id) {
                    latencies_ms.push(at.elapsed().as_secs_f64() * 1e3);
                }
            } else {
                std::thread::sleep((due - now).min(Duration::from_micros(200)));
            }
        }
        let g = graphs[i % graphs.len()].clone();
        let now = Instant::now();
        match tier.submit(g) {
            Ok(id) => {
                submitted_at.insert(id, now);
            }
            Err(SubmitError::Backpressure(_)) => rejected += 1,
            Err(SubmitError::Closed(_)) => return Err(NysxError::Closed),
        }
    }
    // Collect the stragglers.
    for resp in tier.drain() {
        if let Some(at) = submitted_at.remove(&resp.id) {
            latencies_ms.push(at.elapsed().as_secs_f64() * 1e3);
        }
    }
    if !submitted_at.is_empty() {
        return Err(NysxError::Config(format!(
            "{} accepted requests never answered",
            submitted_at.len()
        )));
    }
    StageResult::from_samples(total, rejected, start.elapsed(), &latencies_ms)
}

/// Run the whole harness: train once, then per shard count run the
/// closed-loop stage and the open-loop QPS sweep on a fresh tier.
pub fn run(cfg: &ServingBenchConfig) -> Result<ServingBenchReport, NysxError> {
    let mut builder = Pipeline::for_dataset(&cfg.dataset)?
        .scale(cfg.scale)
        .seed(cfg.seed)
        .hv_dim(cfg.hv_dim);
    if let Some(n) = cfg.threads {
        builder = builder.threads(n);
    }
    let pipeline = builder.train()?;
    let graphs: Vec<Graph> = pipeline
        .dataset()
        .test
        .iter()
        .map(|(g, _)| g.clone())
        .collect();
    if graphs.is_empty() {
        return Err(NysxError::config("serving bench needs a non-empty test split"));
    }

    let mut runs = Vec::with_capacity(cfg.shard_counts.len());
    for &shards in &cfg.shard_counts {
        let serve_cfg = || ShardedConfig {
            shards,
            max_outstanding: cfg.max_outstanding,
            per_shard: ServerConfig {
                workers: cfg.workers_per_shard,
                batcher: BatcherConfig {
                    batch_size: cfg.batch_size,
                    max_wait: Duration::from_micros(200),
                    ..Default::default()
                },
                ..Default::default()
            },
        };

        // Fresh tier per stage so one stage's backlog never pollutes the
        // next stage's latency samples.
        let mut tier = pipeline.serve_sharded(serve_cfg())?;
        let closed = closed_loop(
            &mut tier,
            &graphs,
            cfg.closed_loop_clients,
            cfg.closed_loop_requests,
        )?;
        tier.shutdown();

        let mut points = Vec::with_capacity(cfg.qps_points.len());
        for &qps in &cfg.qps_points {
            let mut tier = pipeline.serve_sharded(serve_cfg())?;
            let stage = open_loop(&mut tier, &graphs, qps, cfg.requests_per_point)?;
            tier.shutdown();
            points.push((qps, stage));
        }

        runs.push(ShardRun {
            shards,
            closed_loop: closed,
            open_loop: points,
        });
    }

    Ok(ServingBenchReport {
        config: cfg.clone(),
        smoke: smoke_mode(),
        runs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The harness end to end at smoke scale: books balance in every
    /// stage, latency percentiles are ordered, and the emitted artifact
    /// round-trips through the JSON parser with the schema intact.
    #[test]
    fn smoke_run_balances_books_and_emits_valid_json() {
        let cfg = ServingBenchConfig {
            shard_counts: vec![1, 2],
            qps_points: vec![500.0],
            requests_per_point: 24,
            closed_loop_requests: 24,
            closed_loop_clients: 3,
            ..ServingBenchConfig::smoke()
        };
        let report = run(&cfg).expect("smoke harness run");
        assert_eq!(report.runs.len(), 2);
        for run in &report.runs {
            for (label, stage) in std::iter::once(("closed", &run.closed_loop))
                .chain(run.open_loop.iter().map(|(_, s)| ("open", s)))
            {
                assert_eq!(
                    stage.sent,
                    stage.answered + stage.rejected,
                    "{label} loop accounting broken at {} shards",
                    run.shards
                );
                assert!(stage.answered > 0, "{label} loop answered nothing");
                let l = &stage.latency_ms;
                assert!(
                    l.p50 <= l.p99 && l.p99 <= l.p999 && l.p999 <= l.max,
                    "{label} loop percentiles out of order"
                );
                assert!(l.p50 > 0.0, "{label} loop zero latency is implausible");
            }
        }

        let doc = report.to_json();
        let text = doc.to_string();
        let back = Json::parse(&text).expect("artifact parses");
        assert_eq!(back, doc, "JSON round-trip drift");
        assert_eq!(back.get("schema").and_then(Json::as_str), Some(SCHEMA));
        assert_eq!(
            back.get("runs").and_then(Json::as_arr).map(<[Json]>::len),
            Some(2)
        );
        let first = &back.get("runs").unwrap().as_arr().unwrap()[0];
        assert_eq!(first.get("shards").and_then(Json::as_usize), Some(1));
        let point = &first.get("open_loop").unwrap().as_arr().unwrap()[0];
        assert_eq!(
            point.get("offered_qps").and_then(Json::as_f64),
            Some(500.0)
        );
        for key in ["p50", "p99", "p999", "min"] {
            assert!(
                point
                    .get("latency_ms")
                    .and_then(|l| l.get(key))
                    .and_then(Json::as_f64)
                    .is_some(),
                "open-loop point missing latency_ms.{key}"
            );
        }
    }

    /// write() refuses nothing on a good report and lands a parseable
    /// file on disk.
    #[test]
    fn write_emits_parseable_artifact() {
        let report = ServingBenchReport {
            config: ServingBenchConfig::smoke(),
            smoke: true,
            runs: vec![ShardRun {
                shards: 1,
                closed_loop: StageResult::from_samples(
                    3,
                    1,
                    Duration::from_millis(10),
                    &[1.0, 2.0],
                )
                .unwrap(),
                open_loop: vec![(
                    100.0,
                    StageResult::from_samples(2, 0, Duration::from_millis(5), &[0.5, 0.7])
                        .unwrap(),
                )],
            }],
        };
        let dir = std::env::temp_dir().join(format!("nysx-bench-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_SERVING.json");
        report.write(&path).expect("write");
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(&text).expect("file parses");
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(SCHEMA));
        std::fs::remove_dir_all(&dir).ok();

        // Broken books are a typed error, not a silent artifact.
        let err = StageResult::from_samples(5, 1, Duration::from_millis(1), &[1.0])
            .err()
            .expect("5 != 1 + 1 must be rejected");
        assert!(matches!(err, NysxError::Config(_)), "{err}");
    }
}
