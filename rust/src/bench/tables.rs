//! The evaluation driver shared by `examples/full_evaluation` and every
//! paper-table bench: trains GraphHD / NysHD / NysX on each synthetic
//! TUDataset, runs the platform models and the FPGA cycle model, and
//! renders Tables 3/4/6/7/8 and Figures 6/7/8.
//!
//! Results are cached as JSON under `results/cache/` keyed by
//! (scale, seed, hv_dim) so the seven `cargo bench` targets don't retrain
//! eight datasets each.

use std::path::PathBuf;

use crate::api::{accuracy, Classifier};
use crate::baselines::{
    estimate_latency_ms, train_graphhd, train_nyshd, train_nysx, Workload, CPU_RYZEN_5625U,
    GPU_RTX_A4000,
};
use crate::graph::tudataset::{TuSpec, TU_SPECS};
use crate::graph::GraphDataset;
use crate::infer::NysxEngine;
use crate::model::{ModelConfig, NysHdcModel};
use crate::sim::{
    estimate_resources, simulate, AcceleratorConfig, PowerModel, SimOptions,
};
use crate::util::json::Json;
use crate::util::table::Table;

/// Evaluation configuration.
#[derive(Debug, Clone, Copy)]
pub struct EvalConfig {
    /// Dataset scale factor (1.0 = paper-size datasets).
    pub scale: f64,
    pub seed: u64,
    /// HV dimensionality d (paper: 10^4).
    pub hv_dim: usize,
    /// Also train the equal-budget Uniform@s_dpp ablation.
    pub ablation: bool,
}

impl Default for EvalConfig {
    fn default() -> Self {
        Self {
            scale: std::env::var("NYSX_SCALE")
                .ok()
                .and_then(|s| s.parse().ok())
                .unwrap_or(1.0),
            seed: 42,
            hv_dim: 10_000,
            ablation: false,
        }
    }
}

/// All measured quantities for one dataset (flat & JSON-cacheable).
#[derive(Debug, Clone, Default)]
pub struct DatasetEval {
    pub name: String,
    // Table 4
    pub num_train: usize,
    pub num_test: usize,
    pub avg_nodes: f64,
    pub avg_edges: f64,
    pub classes: usize,
    pub feature_dim: usize,
    pub hops: usize,
    pub s_uniform: usize,
    pub s_dpp: usize,
    // Fig 7
    pub acc_graphhd: f64,
    pub acc_nyshd: f64,
    pub acc_nysx: f64,
    /// Uniform sampling at the reduced budget (ablation; NaN if skipped).
    pub acc_uniform_at_sdpp: f64,
    // Table 6 (ms)
    pub cpu_ms: f64,
    pub cpu_dpp_ms: f64,
    pub gpu_ms: f64,
    pub gpu_dpp_ms: f64,
    pub fpga_ms: f64,
    pub fpga_dpp_ms: f64,
    // Fig 8
    pub fpga_dpp_nolb_ms: f64,
    pub fpga_sparse_lb_ms: f64,
    pub fpga_sparse_nolb_ms: f64,
    // Table 7
    pub fpga_power_w: f64,
    pub fpga_dpp_mj: f64,
    pub fpga_mj: f64,
    pub nee_fraction: f64,
    // Table 8 (MB, dense Table-2 accounting)
    pub mem_no_dpp_mb: f64,
    pub mem_dpp_mb: f64,
    // Table 3 inputs (from the deployed NysX model)
    pub mem_codebooks: usize,
    pub mem_hists_csr: usize,
    pub mem_mph: usize,
    pub mem_schedules: usize,
    pub mem_protos: usize,
    pub max_hist_bins: usize,
}

const FIELDS_F64: &[&str] = &[
    "avg_nodes",
    "avg_edges",
    "acc_graphhd",
    "acc_nyshd",
    "acc_nysx",
    "acc_uniform_at_sdpp",
    "cpu_ms",
    "cpu_dpp_ms",
    "gpu_ms",
    "gpu_dpp_ms",
    "fpga_ms",
    "fpga_dpp_ms",
    "fpga_dpp_nolb_ms",
    "fpga_sparse_lb_ms",
    "fpga_sparse_nolb_ms",
    "fpga_power_w",
    "fpga_dpp_mj",
    "fpga_mj",
    "nee_fraction",
    "mem_no_dpp_mb",
    "mem_dpp_mb",
];

const FIELDS_USIZE: &[&str] = &[
    "num_train",
    "num_test",
    "classes",
    "feature_dim",
    "hops",
    "s_uniform",
    "s_dpp",
    "mem_codebooks",
    "mem_hists_csr",
    "mem_mph",
    "mem_schedules",
    "mem_protos",
    "max_hist_bins",
];

impl DatasetEval {
    fn get_f64(&self, key: &str) -> f64 {
        match key {
            "avg_nodes" => self.avg_nodes,
            "avg_edges" => self.avg_edges,
            "acc_graphhd" => self.acc_graphhd,
            "acc_nyshd" => self.acc_nyshd,
            "acc_nysx" => self.acc_nysx,
            "acc_uniform_at_sdpp" => self.acc_uniform_at_sdpp,
            "cpu_ms" => self.cpu_ms,
            "cpu_dpp_ms" => self.cpu_dpp_ms,
            "gpu_ms" => self.gpu_ms,
            "gpu_dpp_ms" => self.gpu_dpp_ms,
            "fpga_ms" => self.fpga_ms,
            "fpga_dpp_ms" => self.fpga_dpp_ms,
            "fpga_dpp_nolb_ms" => self.fpga_dpp_nolb_ms,
            "fpga_sparse_lb_ms" => self.fpga_sparse_lb_ms,
            "fpga_sparse_nolb_ms" => self.fpga_sparse_nolb_ms,
            "fpga_power_w" => self.fpga_power_w,
            "fpga_dpp_mj" => self.fpga_dpp_mj,
            "fpga_mj" => self.fpga_mj,
            "nee_fraction" => self.nee_fraction,
            "mem_no_dpp_mb" => self.mem_no_dpp_mb,
            "mem_dpp_mb" => self.mem_dpp_mb,
            _ => panic!("unknown f64 field {key}"),
        }
    }

    fn set_f64(&mut self, key: &str, v: f64) {
        match key {
            "avg_nodes" => self.avg_nodes = v,
            "avg_edges" => self.avg_edges = v,
            "acc_graphhd" => self.acc_graphhd = v,
            "acc_nyshd" => self.acc_nyshd = v,
            "acc_nysx" => self.acc_nysx = v,
            "acc_uniform_at_sdpp" => self.acc_uniform_at_sdpp = v,
            "cpu_ms" => self.cpu_ms = v,
            "cpu_dpp_ms" => self.cpu_dpp_ms = v,
            "gpu_ms" => self.gpu_ms = v,
            "gpu_dpp_ms" => self.gpu_dpp_ms = v,
            "fpga_ms" => self.fpga_ms = v,
            "fpga_dpp_ms" => self.fpga_dpp_ms = v,
            "fpga_dpp_nolb_ms" => self.fpga_dpp_nolb_ms = v,
            "fpga_sparse_lb_ms" => self.fpga_sparse_lb_ms = v,
            "fpga_sparse_nolb_ms" => self.fpga_sparse_nolb_ms = v,
            "fpga_power_w" => self.fpga_power_w = v,
            "fpga_dpp_mj" => self.fpga_dpp_mj = v,
            "fpga_mj" => self.fpga_mj = v,
            "nee_fraction" => self.nee_fraction = v,
            "mem_no_dpp_mb" => self.mem_no_dpp_mb = v,
            "mem_dpp_mb" => self.mem_dpp_mb = v,
            _ => panic!("unknown f64 field {key}"),
        }
    }

    fn get_usize(&self, key: &str) -> usize {
        match key {
            "num_train" => self.num_train,
            "num_test" => self.num_test,
            "classes" => self.classes,
            "feature_dim" => self.feature_dim,
            "hops" => self.hops,
            "s_uniform" => self.s_uniform,
            "s_dpp" => self.s_dpp,
            "mem_codebooks" => self.mem_codebooks,
            "mem_hists_csr" => self.mem_hists_csr,
            "mem_mph" => self.mem_mph,
            "mem_schedules" => self.mem_schedules,
            "mem_protos" => self.mem_protos,
            "max_hist_bins" => self.max_hist_bins,
            _ => panic!("unknown usize field {key}"),
        }
    }

    fn set_usize(&mut self, key: &str, v: usize) {
        match key {
            "num_train" => self.num_train = v,
            "num_test" => self.num_test = v,
            "classes" => self.classes = v,
            "feature_dim" => self.feature_dim = v,
            "hops" => self.hops = v,
            "s_uniform" => self.s_uniform = v,
            "s_dpp" => self.s_dpp = v,
            "mem_codebooks" => self.mem_codebooks = v,
            "mem_hists_csr" => self.mem_hists_csr = v,
            "mem_mph" => self.mem_mph = v,
            "mem_schedules" => self.mem_schedules = v,
            "mem_protos" => self.mem_protos = v,
            "max_hist_bins" => self.max_hist_bins = v,
            _ => panic!("unknown usize field {key}"),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("name", Json::str(self.name.clone()))];
        for &k in FIELDS_F64 {
            let v = self.get_f64(k);
            pairs.push((k, if v.is_nan() { Json::Null } else { Json::num(v) }));
        }
        for &k in FIELDS_USIZE {
            pairs.push((k, Json::num(self.get_usize(k) as f64)));
        }
        Json::obj(pairs)
    }

    pub fn from_json(doc: &Json) -> Option<Self> {
        let mut e = DatasetEval {
            name: doc.get("name")?.as_str()?.to_string(),
            ..Default::default()
        };
        for &k in FIELDS_F64 {
            match doc.get(k) {
                Some(Json::Null) | None => e.set_f64(k, f64::NAN),
                Some(v) => e.set_f64(k, v.as_f64()?),
            }
        }
        for &k in FIELDS_USIZE {
            e.set_usize(k, doc.get(k)?.as_usize()?);
        }
        Some(e)
    }
}

fn cache_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("results/cache")
}

fn cache_key(spec: &TuSpec, cfg: &EvalConfig) -> PathBuf {
    cache_dir().join(format!(
        "{}_s{}_seed{}_d{}.json",
        spec.name,
        (cfg.scale * 100.0).round() as usize,
        cfg.seed,
        cfg.hv_dim
    ))
}

/// Mean simulated FPGA latency/energy/power over (a sample of) the test
/// split, plus the no-LB ablation and NEE fraction.
struct SplitSim {
    ms: f64,
    mj: f64,
    watts: f64,
    nolb_ms: f64,
    nee_frac: f64,
    /// LB-affected (LSHU + KSE) stage time under the §4.2 schedule.
    sparse_lb_ms: f64,
    /// ... and under natural row order.
    sparse_nolb_ms: f64,
}

fn simulate_split(
    model: &NysHdcModel,
    ds: &GraphDataset,
    accel: &AcceleratorConfig,
    power: &PowerModel,
) -> SplitSim {
    let mut engine = NysxEngine::new(model);
    let sample: Vec<&crate::graph::Graph> = ds.test.iter().take(120).map(|(g, _)| g).collect();
    let mut ms = Vec::new();
    let mut mj = Vec::new();
    let mut watts = Vec::new();
    let mut nolb_ms = Vec::new();
    let mut nee_frac = Vec::new();
    let mut sparse_lb = Vec::new();
    let mut sparse_nolb = Vec::new();
    // Batch-major sweep: both the NysHD and NysX rows go through the
    // blocked C×W packed dispatch (one SCE pass per chunk) instead of
    // 120 single-query sweeps — traces are bit-identical to infer().
    let mut traces = Vec::with_capacity(sample.len());
    for chunk in sample.chunks(32) {
        traces.extend(engine.infer_batch(chunk).into_iter().map(|r| r.trace));
    }
    for trace in traces {
        let lb = simulate(&trace, accel, SimOptions::default());
        let nolb = simulate(
            &trace,
            accel,
            SimOptions {
                load_balanced: false,
                ..SimOptions::default()
            },
        );
        let e = power.energy(&lb, accel);
        ms.push(e.time_ms);
        mj.push(e.energy_mj);
        watts.push(e.avg_power_w);
        nolb_ms.push(accel.cycles_to_ms(nolb.total()));
        nee_frac.push(lb.nee_fraction());
        sparse_lb.push(accel.cycles_to_ms(lb.lshu + lb.kse));
        sparse_nolb.push(accel.cycles_to_ms(nolb.lshu + nolb.kse));
    }
    SplitSim {
        ms: crate::util::mean(&ms),
        mj: crate::util::mean(&mj),
        watts: crate::util::mean(&watts),
        nolb_ms: crate::util::mean(&nolb_ms),
        nee_frac: crate::util::mean(&nee_frac),
        sparse_lb_ms: crate::util::mean(&sparse_lb),
        sparse_nolb_ms: crate::util::mean(&sparse_nolb),
    }
}

/// Train + evaluate one dataset (no cache).
pub fn evaluate_dataset(spec: &TuSpec, cfg: &EvalConfig) -> DatasetEval {
    let (ds, s_uni, s_dpp) = spec.generate_scaled(cfg.seed, cfg.scale);
    let stats = ds.stats();
    let base = ModelConfig {
        hops: spec.hops,
        hv_dim: cfg.hv_dim,
        seed: cfg.seed ^ 0x5eed,
        ..ModelConfig::default()
    };

    eprintln!("[{}] training NysHD (uniform, s={s_uni})", spec.name);
    let nyshd = train_nyshd(&ds, s_uni, &base);
    eprintln!("[{}] training NysX (hybrid DPP, s={s_dpp})", spec.name);
    let nysx = train_nysx(&ds, s_dpp, &base);
    eprintln!("[{}] training GraphHD", spec.name);
    let mut ghd = train_graphhd(&ds, cfg.hv_dim, cfg.seed ^ 0x6ead);

    // The Fig. 7 / Table 4 head-to-head: every backend — NysX, NysHD
    // (both packed engines) and GraphHD — is scored through the SAME
    // `dyn Classifier` dispatch path, so the comparison can never drift
    // because one row took a different evaluation code path. In-process
    // backends are infallible; a skipped row renders as NaN.
    let mut nysx_engine = NysxEngine::new(&nysx);
    let mut nyshd_engine = NysxEngine::new(&nyshd);
    let mut acc_nysx = f64::NAN;
    let mut acc_nyshd = f64::NAN;
    let mut acc_graphhd = f64::NAN;
    let sweep: [(&mut dyn Classifier, &mut f64); 3] = [
        (&mut nysx_engine, &mut acc_nysx),
        (&mut nyshd_engine, &mut acc_nyshd),
        (&mut ghd, &mut acc_graphhd),
    ];
    for (classifier, out) in sweep {
        *out = accuracy(classifier, &ds.test)
            .ok()
            .flatten()
            .unwrap_or(f64::NAN);
    }
    let acc_uniform_at_sdpp = if cfg.ablation {
        let mut ablated = NysxEngine::new(train_nyshd(&ds, s_dpp, &base));
        accuracy(&mut ablated, &ds.test)
            .ok()
            .flatten()
            .unwrap_or(f64::NAN)
    } else {
        f64::NAN
    };

    // Platform models (Table 1 complexity × Table 5 constants).
    let w_uni = Workload::from_model(&nyshd, stats.avg_nodes);
    let w_dpp = Workload::from_model(&nysx, stats.avg_nodes);
    let cpu_ms = estimate_latency_ms(&CPU_RYZEN_5625U, &w_uni);
    let cpu_dpp_ms = estimate_latency_ms(&CPU_RYZEN_5625U, &w_dpp);
    let gpu_ms = estimate_latency_ms(&GPU_RTX_A4000, &w_uni);
    let gpu_dpp_ms = estimate_latency_ms(&GPU_RTX_A4000, &w_dpp);

    // FPGA cycle model over real traces.
    let accel = AcceleratorConfig::zcu104();
    let power = PowerModel::default();
    let sim_uni = simulate_split(&nyshd, &ds, &accel, &power);
    let sim_dpp = simulate_split(&nysx, &ds, &accel, &power);

    let mem_uni = nyshd.memory_report();
    let mem_dpp = nysx.memory_report();
    let mb = |b: usize| b as f64 / (1024.0 * 1024.0);

    DatasetEval {
        name: spec.name.to_string(),
        num_train: stats.num_train,
        num_test: stats.num_test,
        avg_nodes: stats.avg_nodes,
        avg_edges: stats.avg_edges,
        classes: stats.num_classes,
        feature_dim: stats.feature_dim,
        hops: spec.hops,
        s_uniform: s_uni,
        s_dpp,
        acc_graphhd,
        acc_nyshd,
        acc_nysx,
        acc_uniform_at_sdpp,
        cpu_ms,
        cpu_dpp_ms,
        gpu_ms,
        gpu_dpp_ms,
        fpga_ms: sim_uni.ms,
        fpga_dpp_ms: sim_dpp.ms,
        fpga_dpp_nolb_ms: sim_dpp.nolb_ms,
        fpga_sparse_lb_ms: sim_dpp.sparse_lb_ms,
        fpga_sparse_nolb_ms: sim_dpp.sparse_nolb_ms,
        fpga_power_w: sim_dpp.watts,
        fpga_dpp_mj: sim_dpp.mj,
        fpga_mj: sim_uni.mj,
        nee_fraction: sim_dpp.nee_frac,
        mem_no_dpp_mb: mb(mem_uni.total_dense()),
        mem_dpp_mb: mb(mem_dpp.total_dense()),
        mem_codebooks: mem_dpp.codebooks,
        mem_hists_csr: mem_dpp.hists_csr,
        mem_mph: mem_dpp.mph,
        mem_schedules: mem_dpp.schedules,
        mem_protos: mem_dpp.prototypes,
        max_hist_bins: nysx.codebooks.iter().map(|c| c.len()).max().unwrap_or(0),
    }
}

/// Evaluate one dataset with JSON caching.
pub fn evaluate_dataset_cached(spec: &TuSpec, cfg: &EvalConfig) -> DatasetEval {
    let path = cache_key(spec, cfg);
    if let Ok(text) = std::fs::read_to_string(&path) {
        if let Ok(doc) = Json::parse(&text) {
            if let Some(eval) = DatasetEval::from_json(&doc) {
                // Ablation results must be present if requested.
                if !cfg.ablation || !eval.acc_uniform_at_sdpp.is_nan() {
                    return eval;
                }
            }
        }
    }
    let eval = evaluate_dataset(spec, cfg);
    std::fs::create_dir_all(cache_dir()).ok();
    std::fs::write(&path, eval.to_json().to_string()).ok();
    eval
}

/// Evaluate all eight datasets (cached).
pub fn evaluate_all(cfg: &EvalConfig) -> Vec<DatasetEval> {
    TU_SPECS
        .iter()
        .map(|spec| {
            eprintln!("== evaluating {} (scale {}) ==", spec.name, cfg.scale);
            evaluate_dataset_cached(spec, cfg)
        })
        .collect()
}

// ------------------------------------------------------------ renderers --

pub fn render_table4(evals: &[DatasetEval]) -> String {
    let mut t = Table::new("Table 4: Summary of Graph Classification Datasets (synthetic)")
        .header(&["Task", "#Train", "#Test", "Avg.Nodes", "Avg.Edges", "Classes", "f", "H"]);
    for e in evals {
        t.row(&[
            e.name.clone(),
            e.num_train.to_string(),
            e.num_test.to_string(),
            format!("{:.0}", e.avg_nodes),
            format!("{:.0}", e.avg_edges),
            e.classes.to_string(),
            e.feature_dim.to_string(),
            e.hops.to_string(),
        ]);
    }
    t.render()
}

pub fn render_table6(evals: &[DatasetEval]) -> String {
    let mut t = Table::new("Table 6: End-to-end latency (ms) per graph; speedup vs CPU (no DPP)")
        .header(&["Dataset", "CPU", "CPU+DPP", "GPU", "GPU+DPP", "FPGA", "FPGA+DPP"]);
    let cell = |ms: f64, base: f64| format!("{:.2} ({:.2}x)", ms, base / ms);
    for e in evals {
        t.row(&[
            e.name.clone(),
            cell(e.cpu_ms, e.cpu_ms),
            cell(e.cpu_dpp_ms, e.cpu_ms),
            cell(e.gpu_ms, e.cpu_ms),
            cell(e.gpu_dpp_ms, e.cpu_ms),
            cell(e.fpga_ms, e.cpu_ms),
            cell(e.fpga_dpp_ms, e.cpu_ms),
        ]);
    }
    let mean_speedup_cpu =
        crate::util::mean(&evals.iter().map(|e| e.cpu_ms / e.fpga_dpp_ms).collect::<Vec<_>>());
    let mean_speedup_gpu =
        crate::util::mean(&evals.iter().map(|e| e.gpu_ms / e.fpga_dpp_ms).collect::<Vec<_>>());
    format!(
        "{}\nMean FPGA+DPP speedup: {:.2}x vs CPU (paper: 6.85x), {:.2}x vs GPU (paper: 4.32x)\n",
        t.render(),
        mean_speedup_cpu,
        mean_speedup_gpu
    )
}

pub fn render_fig6(evals: &[DatasetEval]) -> String {
    let mut t = Table::new("Figure 6: Speedup over CPU baseline (no DPP)").header(&[
        "Dataset", "CPU+DPP", "GPU", "GPU+DPP", "FPGA", "FPGA+DPP",
    ]);
    for e in evals {
        let sp = |ms: f64| format!("{:.2}x", e.cpu_ms / ms);
        t.row(&[
            e.name.clone(),
            sp(e.cpu_dpp_ms),
            sp(e.gpu_ms),
            sp(e.gpu_dpp_ms),
            sp(e.fpga_ms),
            sp(e.fpga_dpp_ms),
        ]);
    }
    t.render()
}

pub fn render_table7(evals: &[DatasetEval]) -> String {
    let mut t = Table::new("Table 7: Throughput, power, energy efficiency (with DPP)").header(&[
        "Dataset",
        "Device",
        "Thru (g/s)",
        "Power (W)",
        "mJ/graph",
        "vs FPGA",
    ]);
    for e in evals {
        let fpga_mj = e.fpga_dpp_mj;
        let rows: [(&str, f64, f64); 3] = [
            ("CPU", e.cpu_dpp_ms, CPU_RYZEN_5625U.power_w),
            ("GPU", e.gpu_dpp_ms, GPU_RTX_A4000.power_w),
            ("FPGA", e.fpga_dpp_ms, e.fpga_power_w),
        ];
        for (dev, ms, w) in rows {
            let mj = w * ms;
            let mj = if dev == "FPGA" { fpga_mj } else { mj };
            t.row(&[
                e.name.clone(),
                dev.to_string(),
                format!("{:.0}", 1000.0 / ms),
                format!("{:.2}", w),
                format!("{:.2}", mj),
                format!("({:.0}x)", mj / fpga_mj),
            ]);
        }
    }
    let cpu_ratio = crate::util::mean(
        &evals
            .iter()
            .map(|e| CPU_RYZEN_5625U.power_w * e.cpu_dpp_ms / e.fpga_dpp_mj)
            .collect::<Vec<_>>(),
    );
    let gpu_ratio = crate::util::mean(
        &evals
            .iter()
            .map(|e| GPU_RTX_A4000.power_w * e.gpu_dpp_ms / e.fpga_dpp_mj)
            .collect::<Vec<_>>(),
    );
    format!(
        "{}\nMean energy ratio: {:.0}x vs CPU (paper: 169x), {:.0}x vs GPU (paper: 314x)\n",
        t.render(),
        cpu_ratio,
        gpu_ratio
    )
}

pub fn render_fig7(evals: &[DatasetEval]) -> String {
    let ablation = evals.iter().any(|e| !e.acc_uniform_at_sdpp.is_nan());
    let mut header = vec!["Dataset", "GraphHD", "NysHD", "NysX (ours)"];
    if ablation {
        header.push("Uniform@s_dpp");
    }
    let mut t = Table::new("Figure 7: Classification accuracy (%)").header(&header);
    for e in evals {
        let mut row = vec![
            e.name.clone(),
            format!("{:.1}", 100.0 * e.acc_graphhd),
            format!("{:.1}", 100.0 * e.acc_nyshd),
            format!("{:.1}", 100.0 * e.acc_nysx),
        ];
        if ablation {
            row.push(if e.acc_uniform_at_sdpp.is_nan() {
                "-".to_string()
            } else {
                format!("{:.1}", 100.0 * e.acc_uniform_at_sdpp)
            });
        }
        t.row(&row);
    }
    let delta = crate::util::mean(
        &evals
            .iter()
            .map(|e| 100.0 * (e.acc_nysx - e.acc_nyshd))
            .collect::<Vec<_>>(),
    );
    format!(
        "{}\nMean NysX - NysHD accuracy delta: {delta:+.1} pp (paper: +3.4 pp)\n",
        t.render()
    )
}

pub fn render_table8(evals: &[DatasetEval]) -> String {
    let mut t = Table::new("Table 8: Model parameter memory with and without DPP").header(&[
        "Dataset",
        "Memory w/o DPP (MB)",
        "Memory w/ DPP (MB)",
        "Reduction",
    ]);
    for e in evals {
        t.row(&[
            e.name.clone(),
            format!("{:.2}", e.mem_no_dpp_mb),
            format!("{:.2}", e.mem_dpp_mb),
            format!(
                "{:.1}%",
                100.0 * (1.0 - e.mem_dpp_mb / e.mem_no_dpp_mb)
            ),
        ]);
    }
    let mean_red = crate::util::mean(
        &evals
            .iter()
            .map(|e| 100.0 * (1.0 - e.mem_dpp_mb / e.mem_no_dpp_mb))
            .collect::<Vec<_>>(),
    );
    format!(
        "{}\nMean memory reduction: {mean_red:.1}% (paper: 37% avg)\n",
        t.render()
    )
}

pub fn render_fig8(evals: &[DatasetEval]) -> String {
    // The §4.2 schedule only touches the SpMV engines (LSHU + KSE); the
    // paper's Fig 8 normalizes the SpMV-stage latency to the no-LB case.
    // We report both the stage-level speedup (the honest measure of the
    // optimization) and the end-to-end effect, which our NEE-dominated
    // breakdown dilutes (see DESIGN.md §4, "Known deviations").
    let mut t = Table::new("Figure 8: Static load balancing speedup in SpMV stages (LSHU/KSE)")
        .header(&[
            "Dataset",
            "SpMV no-LB (ms)",
            "SpMV LB (ms)",
            "Stage speedup",
            "End-to-end",
        ]);
    for e in evals {
        t.row(&[
            e.name.clone(),
            format!("{:.4}", e.fpga_sparse_nolb_ms),
            format!("{:.4}", e.fpga_sparse_lb_ms),
            format!("{:.2}x", e.fpga_sparse_nolb_ms / e.fpga_sparse_lb_ms),
            format!("{:.3}x", e.fpga_dpp_nolb_ms / e.fpga_dpp_ms),
        ]);
    }
    let mean_sp = crate::util::mean(
        &evals
            .iter()
            .map(|e| e.fpga_sparse_nolb_ms / e.fpga_sparse_lb_ms)
            .collect::<Vec<_>>(),
    );
    format!(
        "{}\nMean SpMV-stage LB speedup: {mean_sp:.2}x (paper: 1.19x mean, 1.13-1.24x)\n",
        t.render()
    )
}

pub fn render_table3(evals: &[DatasetEval]) -> String {
    // Use the NCI1 deployment (or the first eval) as the representative
    // on-chip inventory, matching the paper's single design point.
    let rep = evals
        .iter()
        .find(|e| e.name == "NCI1")
        .or_else(|| evals.first())
        .expect("need at least one eval");
    let mem = crate::model::MemoryReport {
        codebooks: rep.mem_codebooks,
        hists_dense: 0,
        hists_csr: rep.mem_hists_csr,
        p_nys: 0, // streamed from DDR, not on-chip
        prototypes: rep.mem_protos,
        mph: rep.mem_mph,
        schedules: rep.mem_schedules,
    };
    let cfg = AcceleratorConfig::zcu104();
    let r = estimate_resources(&cfg, &mem, rep.max_hist_bins);
    let mut t = Table::new("Table 3: Resource utilization (estimated; paper values in parens)")
        .header(&["Resource", "Used", "Available", "Utilization", "Paper"]);
    let paper = [("LUT", 71_900), ("FF", 87_800), ("BRAM (18K)", 329), ("DSP", 156), ("URAM", 0)];
    for ((name, used, avail, frac), (_, pval)) in r.utilization().iter().zip(paper.iter()) {
        t.row(&[
            name.to_string(),
            used.to_string(),
            avail.to_string(),
            format!("{:.0}%", 100.0 * frac),
            pval.to_string(),
        ]);
    }
    t.render()
}

/// §5.2.5 roofline rendering.
pub fn render_roofline() -> String {
    let mut out = String::new();
    let mut t = Table::new("Roofline analysis of the NEE projection (§5.2.5)").header(&[
        "Lanes", "Peak GOPS", "BW (GB/s)", "Balance", "AI", "Attainable", "Bound",
    ]);
    for lanes in [2usize, 8, 16, 32, 64] {
        let mut cfg = AcceleratorConfig::zcu104();
        cfg.nee_lanes = lanes;
        let p = crate::sim::nee_point(&cfg);
        t.row(&[
            lanes.to_string(),
            format!("{:.1}", p.peak_gops),
            format!("{:.1}", p.sustained_bw_gbps),
            format!("{:.2}", p.machine_balance),
            format!("{:.2}", p.ai),
            format!("{:.2}", p.attainable_gops),
            format!("{:?}", p.bound),
        ]);
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip() {
        let mut e = DatasetEval {
            name: "X".into(),
            cpu_ms: 1.5,
            s_dpp: 7,
            acc_uniform_at_sdpp: f64::NAN,
            ..Default::default()
        };
        e.avg_nodes = 33.3;
        let back = DatasetEval::from_json(&Json::parse(&e.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.name, "X");
        assert_eq!(back.cpu_ms, 1.5);
        assert_eq!(back.s_dpp, 7);
        assert!(back.acc_uniform_at_sdpp.is_nan());
        assert_eq!(back.avg_nodes, 33.3);
    }

    #[test]
    fn small_scale_eval_smoke() {
        // One tiny dataset end to end through the whole driver.
        let spec = crate::graph::tudataset::spec_by_name("MUTAG").unwrap();
        let cfg = EvalConfig {
            scale: 0.15,
            seed: 9,
            hv_dim: 1024,
            ablation: true,
        };
        let e = evaluate_dataset(spec, &cfg);
        assert!(e.acc_nysx > 0.3);
        assert!(e.fpga_dpp_ms > 0.0);
        assert!(e.fpga_dpp_nolb_ms >= e.fpga_dpp_ms * 0.99);
        assert!(e.mem_dpp_mb < e.mem_no_dpp_mb);
        assert!(!e.acc_uniform_at_sdpp.is_nan());
        // Renderers don't panic and mention the dataset.
        let evals = vec![e];
        for s in [
            render_table4(&evals),
            render_table6(&evals),
            render_fig6(&evals),
            render_table7(&evals),
            render_fig7(&evals),
            render_table8(&evals),
            render_fig8(&evals),
            render_table3(&evals),
            render_roofline(),
        ] {
            assert!(!s.is_empty());
        }
    }
}
