//! CSR sparse matrices — the storage format the accelerator uses for the
//! adjacency matrix `A` and the landmark histogram matrices `H^(t)`
//! (paper §5.2.1, §5.2.4).
//!
//! Row offsets live behind [`RowOffsets`]: plain `Vec<usize>` for small
//! matrices (free indexing on the hot SpMV paths), Elias–Fano
//! (DESIGN.md §10) once the offset array is large enough that its
//! ≈2-bits-per-entry encoding beats 64-bit words by an order of
//! magnitude. The representation is chosen deterministically from the
//! shape at construction and is *never observable*: `PartialEq`, SpMV,
//! fingerprints and serialization all compare/use logical offset values.

use std::ops::Range;

use crate::linalg::dense::Mat;
use crate::succinct::EliasFano;

/// Offset arrays below this many entries always stay plain: the whole
/// array is smaller than the codec's fixed directory overhead, and
/// small-graph SpMV is the latency path.
const EF_MIN_OFFSETS: usize = 1024;

/// The row-offset array of a CSR matrix (`len == rows + 1`, monotone,
/// starts at 0): uncompressed or Elias–Fano coded.
#[derive(Debug, Clone)]
pub enum RowOffsets {
    Plain(Vec<usize>),
    EliasFano(EliasFano),
}

impl RowOffsets {
    /// Deterministic representation choice: Elias–Fano when the array is
    /// large enough to clear `EF_MIN_OFFSETS` *and* the encoding
    /// actually wins (it always should; the byte check keeps the rule
    /// honest for adversarial shapes). Density is what decides the
    /// margin — low nnz/row means ≈2 bits/offset vs a full word.
    pub fn auto(row_ptr: Vec<usize>) -> Self {
        if row_ptr.len() >= EF_MIN_OFFSETS {
            let ef = EliasFano::from_sorted(&row_ptr.iter().map(|&p| p as u64).collect::<Vec<u64>>());
            if ef.bytes() < row_ptr.len() * std::mem::size_of::<usize>() {
                return RowOffsets::EliasFano(ef);
            }
        }
        RowOffsets::Plain(row_ptr)
    }

    #[inline]
    pub fn len(&self) -> usize {
        match self {
            RowOffsets::Plain(p) => p.len(),
            RowOffsets::EliasFano(ef) => ef.len(),
        }
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The i-th offset (O(1) in both representations).
    #[inline]
    pub fn get(&self, i: usize) -> usize {
        match self {
            RowOffsets::Plain(p) => p[i],
            RowOffsets::EliasFano(ef) => ef.get(i) as usize,
        }
    }

    /// Logical values in order (sequential decode, not per-index gets).
    pub fn iter(&self) -> Box<dyn Iterator<Item = usize> + '_> {
        match self {
            RowOffsets::Plain(p) => Box::new(p.iter().copied()),
            RowOffsets::EliasFano(ef) => Box::new(ef.iter().map(|v| v as usize)),
        }
    }

    pub fn to_vec(&self) -> Vec<usize> {
        self.iter().collect()
    }

    /// Heap bytes of the chosen representation.
    pub fn bytes(&self) -> usize {
        match self {
            RowOffsets::Plain(p) => p.len() * std::mem::size_of::<usize>(),
            RowOffsets::EliasFano(ef) => ef.bytes(),
        }
    }

    pub fn is_compressed(&self) -> bool {
        matches!(self, RowOffsets::EliasFano(_))
    }
}

/// Equality is logical — the same offsets in different representations
/// compare equal, so a compressed matrix round-trips through any format
/// version without disturbing model/graph comparisons.
impl PartialEq for RowOffsets {
    fn eq(&self, other: &Self) -> bool {
        self.len() == other.len() && self.iter().eq(other.iter())
    }
}

/// Compressed sparse row matrix over `f64` values.
#[derive(Debug, Clone)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// len rows+1, representation-polymorphic (see [`RowOffsets`]).
    offsets: RowOffsets,
    pub col_idx: Vec<u32>,
    pub val: Vec<f64>,
}

impl PartialEq for Csr {
    fn eq(&self, other: &Self) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self.offsets == other.offsets
            && self.col_idx == other.col_idx
            && self.val == other.val
    }
}

impl Csr {
    /// Build from (row, col, value) triplets; duplicates are summed.
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        mut triplets: Vec<(usize, usize, f64)>,
    ) -> Self {
        triplets.retain(|&(_, _, v)| v != 0.0);
        triplets.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut row_ptr = vec![0usize; rows + 1];
        let mut col_idx = Vec::with_capacity(triplets.len());
        let mut val: Vec<f64> = Vec::with_capacity(triplets.len());
        for (r, c, v) in triplets {
            assert!(r < rows && c < cols, "triplet out of range");
            col_idx.push(c as u32);
            val.push(v);
            row_ptr[r + 1] += 1;
        }
        for i in 0..rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        // Merge duplicates within each row (entries are sorted).
        let mut m_row_ptr = vec![0usize; rows + 1];
        let mut m_col = Vec::with_capacity(col_idx.len());
        let mut m_val = Vec::with_capacity(val.len());
        for r in 0..rows {
            let (start, end) = (row_ptr[r], row_ptr[r + 1]);
            let mut i = start;
            while i < end {
                let c = col_idx[i];
                let mut acc = val[i];
                let mut j = i + 1;
                while j < end && col_idx[j] == c {
                    acc += val[j];
                    j += 1;
                }
                if acc != 0.0 {
                    m_col.push(c);
                    m_val.push(acc);
                }
                i = j;
            }
            m_row_ptr[r + 1] = m_col.len();
        }
        Self::from_parts(rows, cols, m_row_ptr, m_col, m_val)
    }

    /// Assemble from already-validated CSR arrays (the model/artifact
    /// load path — shape checks live with the caller's format errors).
    /// The offset representation is re-chosen here, so every load lands
    /// on the same canonical form regardless of source format version.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<u32>,
        val: Vec<f64>,
    ) -> Self {
        debug_assert_eq!(row_ptr.len(), rows + 1);
        debug_assert_eq!(col_idx.len(), val.len());
        Self {
            rows,
            cols,
            offsets: RowOffsets::auto(row_ptr),
            col_idx,
            val,
        }
    }

    /// Build from a dense matrix, dropping entries with |x| <= tol.
    pub fn from_dense(m: &Mat, tol: f64) -> Self {
        let mut triplets = Vec::new();
        for i in 0..m.rows {
            for j in 0..m.cols {
                let v = m[(i, j)];
                if v.abs() > tol {
                    triplets.push((i, j, v));
                }
            }
        }
        Self::from_triplets(m.rows, m.cols, triplets)
    }

    /// The row-offset array (for memory accounting and serialization).
    #[inline]
    pub fn offsets(&self) -> &RowOffsets {
        &self.offsets
    }

    /// Start of row `r`'s entries in `col_idx`/`val`.
    #[inline]
    pub fn row_start(&self, r: usize) -> usize {
        self.offsets.get(r)
    }

    /// `col_idx`/`val` index range of row `r`.
    #[inline]
    pub fn row_range(&self, r: usize) -> Range<usize> {
        self.offsets.get(r)..self.offsets.get(r + 1)
    }

    /// The same matrix with Elias–Fano offsets regardless of size
    /// (differential tests and memory benches; `auto` stays the
    /// production rule).
    pub fn with_compressed_offsets(mut self) -> Self {
        let ptr: Vec<u64> = self.offsets.iter().map(|p| p as u64).collect();
        self.offsets = RowOffsets::EliasFano(EliasFano::from_sorted(&ptr));
        self
    }

    /// The same matrix with plain `Vec<usize>` offsets.
    pub fn with_plain_offsets(mut self) -> Self {
        self.offsets = RowOffsets::Plain(self.offsets.to_vec());
        self
    }

    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for k in self.row_range(r) {
                m[(r, self.col_idx[k] as usize)] = self.val[k];
            }
        }
        m
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.val.len()
    }

    #[inline]
    pub fn row_nnz(&self, r: usize) -> usize {
        let range = self.row_range(r);
        range.end - range.start
    }

    /// Average per-row density φ (paper Tables 1-2 use this).
    pub fn avg_row_density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// y = A x
    pub fn spmv(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len(), "spmv shape mismatch");
        let mut y = vec![0.0; self.rows];
        self.spmv_into(x, &mut y);
        y
    }

    /// y = A x into a caller-provided buffer (hot-path, allocation-free).
    /// Specialized per offset representation: plain offsets index
    /// directly; Elias–Fano offsets decode sequentially (one pass, no
    /// per-row selects). Accumulation order is identical either way, so
    /// results are bit-identical across representations.
    #[inline]
    pub fn spmv_into(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(self.cols, x.len());
        debug_assert_eq!(self.rows, y.len());
        match &self.offsets {
            RowOffsets::Plain(ptr) => {
                for r in 0..self.rows {
                    let mut acc = 0.0;
                    for k in ptr[r]..ptr[r + 1] {
                        acc += self.val[k] * x[self.col_idx[k] as usize];
                    }
                    y[r] = acc;
                }
            }
            RowOffsets::EliasFano(ef) => {
                let mut bounds = ef.iter();
                let mut start = bounds.next().unwrap_or(0) as usize;
                for r in 0..self.rows {
                    let end = bounds.next().map_or(start, |e| e as usize);
                    let mut acc = 0.0;
                    for k in start..end {
                        acc += self.val[k] * x[self.col_idx[k] as usize];
                    }
                    y[r] = acc;
                    start = end;
                }
            }
        }
    }

    /// Dense product A (rows×cols) @ B (cols×k) -> rows×k.
    pub fn spmm(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "spmm shape mismatch");
        let mut out = Mat::zeros(self.rows, b.cols);
        for r in 0..self.rows {
            for k in self.row_range(r) {
                let c = self.col_idx[k] as usize;
                let v = self.val[k];
                let b_row = b.row(c);
                let out_row = out.row_mut(r);
                for (o, &x) in out_row.iter_mut().zip(b_row.iter()) {
                    *o += v * x;
                }
            }
        }
        out
    }

    /// Per-row nnz histogram spread statistics (drives Fig 8 analysis).
    pub fn row_nnz_stats(&self) -> RowNnzStats {
        let nnzs: Vec<usize> = (0..self.rows).map(|r| self.row_nnz(r)).collect();
        let max = nnzs.iter().copied().max().unwrap_or(0);
        let min = nnzs.iter().copied().min().unwrap_or(0);
        let mean = if self.rows > 0 {
            self.nnz() as f64 / self.rows as f64
        } else {
            0.0
        };
        let var = if self.rows > 0 {
            nnzs.iter()
                .map(|&x| (x as f64 - mean) * (x as f64 - mean))
                .sum::<f64>()
                / self.rows as f64
        } else {
            0.0
        };
        RowNnzStats {
            min,
            max,
            mean,
            std: var.sqrt(),
        }
    }

    /// Bytes to store this matrix in CSR with the given value bit-width
    /// (row_ptr as u32, col_idx as u32) — used by the memory accounting.
    pub fn csr_bytes(&self, value_bits: usize) -> usize {
        4 * (self.rows + 1) + 4 * self.nnz() + (value_bits / 8) * self.nnz()
    }

    /// Actual in-memory bytes of the offset+index+value arrays under the
    /// *current* offset representation (the memory bench's ground truth,
    /// vs the idealized u32 accounting of [`Self::csr_bytes`]).
    pub fn heap_bytes(&self) -> usize {
        self.offsets.bytes() + self.col_idx.len() * 4 + self.val.len() * 8
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RowNnzStats {
    pub min: usize,
    pub max: usize,
    pub mean: f64,
    pub std: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn random_sparse(rows: usize, cols: usize, p: f64, rng: &mut Xoshiro256) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                if rng.bernoulli(p) {
                    m[(i, j)] = rng.normal();
                }
            }
        }
        m
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let m = random_sparse(13, 9, 0.3, &mut rng);
        let csr = Csr::from_dense(&m, 0.0);
        assert!(csr.to_dense().max_abs_diff(&m) < 1e-15);
    }

    #[test]
    fn spmv_matches_dense_property() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        for trial in 0..20 {
            let rows = 1 + rng.gen_range(40);
            let cols = 1 + rng.gen_range(40);
            let p = rng.uniform(0.0, 0.5);
            let m = random_sparse(rows, cols, p, &mut rng);
            let csr = Csr::from_dense(&m, 0.0);
            let x: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
            let want = m.matvec(&x);
            let got = csr.spmv(&x);
            for (a, b) in want.iter().zip(&got) {
                assert!((a - b).abs() < 1e-10, "trial {trial}");
            }
        }
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let m = random_sparse(10, 8, 0.25, &mut rng);
        let b = Mat::randn(8, 5, &mut rng);
        let csr = Csr::from_dense(&m, 0.0);
        assert!(csr.spmm(&b).max_abs_diff(&m.matmul(&b)) < 1e-10);
    }

    #[test]
    fn triplets_sum_duplicates() {
        let csr = Csr::from_triplets(2, 2, vec![(0, 1, 1.0), (0, 1, 2.0), (1, 0, -1.0)]);
        assert_eq!(csr.nnz(), 2);
        let d = csr.to_dense();
        assert_eq!(d[(0, 1)], 3.0);
        assert_eq!(d[(1, 0)], -1.0);
    }

    #[test]
    fn duplicate_cancellation_dropped() {
        let csr = Csr::from_triplets(1, 2, vec![(0, 0, 1.0), (0, 0, -1.0)]);
        assert_eq!(csr.nnz(), 0);
    }

    #[test]
    fn empty_rows_ok() {
        let csr = Csr::from_triplets(4, 4, vec![(2, 3, 5.0)]);
        assert_eq!(csr.row_nnz(0), 0);
        assert_eq!(csr.row_nnz(2), 1);
        let y = csr.spmv(&[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(y, vec![0.0, 0.0, 5.0, 0.0]);
    }

    #[test]
    fn stats_and_bytes() {
        let csr = Csr::from_triplets(3, 3, vec![(0, 0, 1.0), (0, 1, 1.0), (1, 2, 1.0)]);
        let s = csr.row_nnz_stats();
        assert_eq!(s.max, 2);
        assert_eq!(s.min, 0);
        assert!((s.mean - 1.0).abs() < 1e-12);
        assert_eq!(csr.csr_bytes(32), 4 * 4 + 4 * 3 + 4 * 3);
        assert!((csr.avg_row_density() - 3.0 / 9.0).abs() < 1e-12);
    }

    fn random_triplets(
        rows: usize,
        cols: usize,
        per_row: usize,
        rng: &mut Xoshiro256,
    ) -> Vec<(usize, usize, f64)> {
        let mut t = Vec::new();
        for r in 0..rows {
            for _ in 0..rng.gen_range(per_row + 1) {
                t.push((r, rng.gen_range(cols), rng.normal()));
            }
        }
        t
    }

    /// Large sparse matrices auto-select Elias–Fano offsets; small ones
    /// stay plain; the choice never leaks into logical equality.
    #[test]
    fn offset_representation_auto_selection() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let small = Csr::from_triplets(20, 20, random_triplets(20, 20, 3, &mut rng));
        assert!(!small.offsets().is_compressed(), "small matrix must stay plain");

        let rows = 4000;
        let big = Csr::from_triplets(rows, 64, random_triplets(rows, 64, 4, &mut rng));
        assert!(big.offsets().is_compressed(), "large matrix must compress");
        assert!(
            big.offsets().bytes() * 4 < (rows + 1) * 8,
            "EF offsets {} bytes not winning over plain {}",
            big.offsets().bytes(),
            (rows + 1) * 8
        );

        let plain = big.clone().with_plain_offsets();
        assert_eq!(plain, big, "representation must not affect equality");
        assert_eq!(plain.offsets().to_vec(), big.offsets().to_vec());
    }

    /// Differential: SpMV and every row accessor agree bit-for-bit
    /// between plain and Elias–Fano offsets on the same matrix.
    #[test]
    fn ef_vs_plain_spmv_bit_identical() {
        let mut rng = Xoshiro256::seed_from_u64(8);
        for trial in 0..10 {
            let rows = 1 + rng.gen_range(300);
            let cols = 1 + rng.gen_range(80);
            let base = Csr::from_triplets(rows, cols, random_triplets(rows, cols, 5, &mut rng));
            let ef = base.clone().with_compressed_offsets();
            let plain = base.clone().with_plain_offsets();
            let x: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
            let ye = ef.spmv(&x);
            let yp = plain.spmv(&x);
            assert!(
                ye.iter().zip(&yp).all(|(a, b)| a.to_bits() == b.to_bits()),
                "spmv differs between representations (trial {trial})"
            );
            for r in 0..rows {
                assert_eq!(ef.row_range(r), plain.row_range(r), "trial {trial} row {r}");
            }
            assert_eq!(ef.to_dense(), plain.to_dense());
        }
    }
}
