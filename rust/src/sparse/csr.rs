//! CSR sparse matrices — the storage format the accelerator uses for the
//! adjacency matrix `A` and the landmark histogram matrices `H^(t)`
//! (paper §5.2.1, §5.2.4).

use crate::linalg::dense::Mat;

/// Compressed sparse row matrix over `f64` values.
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    /// len rows+1
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
    pub val: Vec<f64>,
}

impl Csr {
    /// Build from (row, col, value) triplets; duplicates are summed.
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        mut triplets: Vec<(usize, usize, f64)>,
    ) -> Self {
        triplets.retain(|&(_, _, v)| v != 0.0);
        triplets.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut row_ptr = vec![0usize; rows + 1];
        let mut col_idx = Vec::with_capacity(triplets.len());
        let mut val: Vec<f64> = Vec::with_capacity(triplets.len());
        for (r, c, v) in triplets {
            assert!(r < rows && c < cols, "triplet out of range");
            if !col_idx.is_empty()
                && row_ptr[r + 1] > 0
                && *col_idx.last().unwrap() == c as u32
                && row_ptr[rows] == 0
            {
                // handled below via merge pass; keep simple: push all then merge
            }
            let _ = v;
            col_idx.push(c as u32);
            val.push(v);
            row_ptr[r + 1] += 1;
        }
        for i in 0..rows {
            row_ptr[i + 1] += row_ptr[i];
        }
        // Merge duplicates within each row (entries are sorted).
        let mut m_row_ptr = vec![0usize; rows + 1];
        let mut m_col = Vec::with_capacity(col_idx.len());
        let mut m_val = Vec::with_capacity(val.len());
        for r in 0..rows {
            let (start, end) = (row_ptr[r], row_ptr[r + 1]);
            let mut i = start;
            while i < end {
                let c = col_idx[i];
                let mut acc = val[i];
                let mut j = i + 1;
                while j < end && col_idx[j] == c {
                    acc += val[j];
                    j += 1;
                }
                if acc != 0.0 {
                    m_col.push(c);
                    m_val.push(acc);
                }
                i = j;
            }
            m_row_ptr[r + 1] = m_col.len();
        }
        Self {
            rows,
            cols,
            row_ptr: m_row_ptr,
            col_idx: m_col,
            val: m_val,
        }
    }

    /// Build from a dense matrix, dropping entries with |x| <= tol.
    pub fn from_dense(m: &Mat, tol: f64) -> Self {
        let mut triplets = Vec::new();
        for i in 0..m.rows {
            for j in 0..m.cols {
                let v = m[(i, j)];
                if v.abs() > tol {
                    triplets.push((i, j, v));
                }
            }
        }
        Self::from_triplets(m.rows, m.cols, triplets)
    }

    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            for k in self.row_ptr[r]..self.row_ptr[r + 1] {
                m[(r, self.col_idx[k] as usize)] = self.val[k];
            }
        }
        m
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.val.len()
    }

    #[inline]
    pub fn row_nnz(&self, r: usize) -> usize {
        self.row_ptr[r + 1] - self.row_ptr[r]
    }

    /// Average per-row density φ (paper Tables 1-2 use this).
    pub fn avg_row_density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 0.0;
        }
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// y = A x
    pub fn spmv(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len(), "spmv shape mismatch");
        let mut y = vec![0.0; self.rows];
        self.spmv_into(x, &mut y);
        y
    }

    /// y = A x into a caller-provided buffer (hot-path, allocation-free).
    #[inline]
    pub fn spmv_into(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(self.cols, x.len());
        debug_assert_eq!(self.rows, y.len());
        for r in 0..self.rows {
            let mut acc = 0.0;
            let start = self.row_ptr[r];
            let end = self.row_ptr[r + 1];
            for k in start..end {
                // SAFETY-free fast path: indices are validated at build.
                acc += self.val[k] * x[self.col_idx[k] as usize];
            }
            y[r] = acc;
        }
    }

    /// Dense product A (rows×cols) @ B (cols×k) -> rows×k.
    pub fn spmm(&self, b: &Mat) -> Mat {
        assert_eq!(self.cols, b.rows, "spmm shape mismatch");
        let mut out = Mat::zeros(self.rows, b.cols);
        for r in 0..self.rows {
            for k in self.row_ptr[r]..self.row_ptr[r + 1] {
                let c = self.col_idx[k] as usize;
                let v = self.val[k];
                let b_row = b.row(c);
                let out_row = out.row_mut(r);
                for (o, &x) in out_row.iter_mut().zip(b_row.iter()) {
                    *o += v * x;
                }
            }
        }
        out
    }

    /// Per-row nnz histogram spread statistics (drives Fig 8 analysis).
    pub fn row_nnz_stats(&self) -> RowNnzStats {
        let nnzs: Vec<usize> = (0..self.rows).map(|r| self.row_nnz(r)).collect();
        let max = nnzs.iter().copied().max().unwrap_or(0);
        let min = nnzs.iter().copied().min().unwrap_or(0);
        let mean = if self.rows > 0 {
            self.nnz() as f64 / self.rows as f64
        } else {
            0.0
        };
        let var = if self.rows > 0 {
            nnzs.iter()
                .map(|&x| (x as f64 - mean) * (x as f64 - mean))
                .sum::<f64>()
                / self.rows as f64
        } else {
            0.0
        };
        RowNnzStats {
            min,
            max,
            mean,
            std: var.sqrt(),
        }
    }

    /// Bytes to store this matrix in CSR with the given value bit-width
    /// (row_ptr as u32, col_idx as u32) — used by the memory accounting.
    pub fn csr_bytes(&self, value_bits: usize) -> usize {
        4 * (self.rows + 1) + 4 * self.nnz() + (value_bits / 8) * self.nnz()
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RowNnzStats {
    pub min: usize,
    pub max: usize,
    pub mean: f64,
    pub std: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn random_sparse(rows: usize, cols: usize, p: f64, rng: &mut Xoshiro256) -> Mat {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                if rng.bernoulli(p) {
                    m[(i, j)] = rng.normal();
                }
            }
        }
        m
    }

    #[test]
    fn dense_roundtrip() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let m = random_sparse(13, 9, 0.3, &mut rng);
        let csr = Csr::from_dense(&m, 0.0);
        assert!(csr.to_dense().max_abs_diff(&m) < 1e-15);
    }

    #[test]
    fn spmv_matches_dense_property() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        for trial in 0..20 {
            let rows = 1 + rng.gen_range(40);
            let cols = 1 + rng.gen_range(40);
            let p = rng.uniform(0.0, 0.5);
            let m = random_sparse(rows, cols, p, &mut rng);
            let csr = Csr::from_dense(&m, 0.0);
            let x: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
            let want = m.matvec(&x);
            let got = csr.spmv(&x);
            for (a, b) in want.iter().zip(&got) {
                assert!((a - b).abs() < 1e-10, "trial {trial}");
            }
        }
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let m = random_sparse(10, 8, 0.25, &mut rng);
        let b = Mat::randn(8, 5, &mut rng);
        let csr = Csr::from_dense(&m, 0.0);
        assert!(csr.spmm(&b).max_abs_diff(&m.matmul(&b)) < 1e-10);
    }

    #[test]
    fn triplets_sum_duplicates() {
        let csr = Csr::from_triplets(2, 2, vec![(0, 1, 1.0), (0, 1, 2.0), (1, 0, -1.0)]);
        assert_eq!(csr.nnz(), 2);
        let d = csr.to_dense();
        assert_eq!(d[(0, 1)], 3.0);
        assert_eq!(d[(1, 0)], -1.0);
    }

    #[test]
    fn duplicate_cancellation_dropped() {
        let csr = Csr::from_triplets(1, 2, vec![(0, 0, 1.0), (0, 0, -1.0)]);
        assert_eq!(csr.nnz(), 0);
    }

    #[test]
    fn empty_rows_ok() {
        let csr = Csr::from_triplets(4, 4, vec![(2, 3, 5.0)]);
        assert_eq!(csr.row_nnz(0), 0);
        assert_eq!(csr.row_nnz(2), 1);
        let y = csr.spmv(&[1.0, 1.0, 1.0, 1.0]);
        assert_eq!(y, vec![0.0, 0.0, 5.0, 0.0]);
    }

    #[test]
    fn stats_and_bytes() {
        let csr = Csr::from_triplets(3, 3, vec![(0, 0, 1.0), (0, 1, 1.0), (1, 2, 1.0)]);
        let s = csr.row_nnz_stats();
        assert_eq!(s.max, 2);
        assert_eq!(s.min, 0);
        assert!((s.mean - 1.0).abs() < 1e-12);
        assert_eq!(csr.csr_bytes(32), 4 * 4 + 4 * 3 + 4 * 3);
        assert!((csr.avg_row_density() - 3.0 / 9.0).abs() < 1e-12);
    }
}
