//! Static iteration-wise load balancing for SpMV (paper §4.2).
//!
//! Given `N` rows and `P` PEs, computation proceeds in `ceil(N/P)`
//! iterations; a precomputed `iterations × P` schedule table assigns one
//! row to each PE per iteration. Rows are bucketed by nonzero count and
//! allocated in increasing-nnz order, so every iteration processes rows of
//! similar weight — the per-iteration cycle cost (max nnz across the P
//! rows) approaches the mean instead of being dominated by stragglers.

use super::csr::Csr;

/// Sentinel for "no row assigned" slots in the last iteration when
/// `N % P != 0` (the paper pads; idle PE contributes zero work).
pub const NO_ROW: u32 = u32::MAX;

/// Precomputed schedule table: `table[i * pes + j]` = row assigned to PE
/// `j` in iteration `i`, or [`NO_ROW`].
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduleTable {
    pub pes: usize,
    pub iterations: usize,
    pub table: Vec<u32>,
}

/// Row-assignment policy — the paper's nnz-grouped policy plus the
/// ablation alternatives benchmarked in Fig 8.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// Paper §4.2: bucket rows by nnz, allocate P-at-a-time in increasing
    /// nnz order.
    NnzGrouped,
    /// Natural row order (the "no LB" baseline): iteration i takes rows
    /// [i*P, (i+1)*P).
    RowOrder,
}

impl ScheduleTable {
    /// Offline construction (O(N)) from a sparse operand's row-nnz counts.
    pub fn build(csr: &Csr, pes: usize, policy: SchedulePolicy) -> Self {
        assert!(pes > 0);
        let n = csr.rows;
        let iterations = n.div_ceil(pes);
        let order: Vec<u32> = match policy {
            SchedulePolicy::RowOrder => (0..n as u32).collect(),
            SchedulePolicy::NnzGrouped => {
                // Bucket rows by nnz (counting sort — preserves CSR-order
                // within a bucket, matching the paper's "traverse buckets
                // in increasing order of nnz").
                let max_nnz = (0..n).map(|r| csr.row_nnz(r)).max().unwrap_or(0);
                let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); max_nnz + 1];
                for r in 0..n {
                    buckets[csr.row_nnz(r)].push(r as u32);
                }
                buckets.into_iter().flatten().collect()
            }
        };
        let mut table = vec![NO_ROW; iterations * pes];
        for (slot, &row) in order.iter().enumerate() {
            // slot = iteration * pes + pe, filled P rows at a time.
            table[slot] = row;
        }
        Self {
            pes,
            iterations,
            table,
        }
    }

    /// Row assigned to `pe` in `iteration` (None when padded-idle).
    #[inline]
    pub fn row_for(&self, iteration: usize, pe: usize) -> Option<u32> {
        let r = self.table[iteration * self.pes + pe];
        (r != NO_ROW).then_some(r)
    }

    /// Cycle cost model for one SpMV pass under this schedule: each
    /// iteration costs `max(nnz of assigned rows)` MAC cycles (all PEs
    /// wait on the slowest; this is the quantity §4.2 minimizes). Returns
    /// (balanced_cycles, total_nnz).
    pub fn spmv_cycles(&self, csr: &Csr) -> (u64, u64) {
        let mut cycles = 0u64;
        let mut total = 0u64;
        for it in 0..self.iterations {
            let mut max_nnz = 0usize;
            for pe in 0..self.pes {
                if let Some(r) = self.row_for(it, pe) {
                    let nnz = csr.row_nnz(r as usize);
                    total += nnz as u64;
                    max_nnz = max_nnz.max(nnz);
                }
            }
            cycles += max_nnz as u64;
        }
        (cycles, total)
    }

    /// PE utilization in [0,1]: useful MACs / (cycles × PEs). The paper's
    /// Fig 8 speedups are the ratio of no-LB to LB cycles.
    pub fn utilization(&self, csr: &Csr) -> f64 {
        let (cycles, total) = self.spmv_cycles(csr);
        if cycles == 0 {
            return 1.0;
        }
        total as f64 / (cycles as f64 * self.pes as f64)
    }

    /// On-chip bytes for the table (u32 entries, banked along columns).
    pub fn table_bytes(&self) -> usize {
        self.table.len() * 4
    }

    /// SpMV y = A x executed PE-by-PE exactly as the accelerator would,
    /// verifying that scheduling is a pure permutation of work
    /// (used by tests and the functional model).
    pub fn run_spmv(&self, csr: &Csr, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(csr.cols, x.len());
        debug_assert_eq!(csr.rows, y.len());
        for it in 0..self.iterations {
            for pe in 0..self.pes {
                if let Some(r) = self.row_for(it, pe) {
                    let r = r as usize;
                    let mut acc = 0.0;
                    for k in csr.row_range(r) {
                        acc += csr.val[k] * x[csr.col_idx[k] as usize];
                    }
                    y[r] = acc;
                }
            }
        }
    }

    /// [`Self::run_spmv`] across an exec pool: the PE columns are split
    /// into contiguous blocks (the schedule already balanced nnz across
    /// PEs per iteration, so a block of columns is a balanced share of
    /// the matrix) and each lane walks its block through every
    /// iteration. The schedule assigns each row to exactly one
    /// (iteration, PE) slot, so lanes scatter-write disjoint `y[r]`
    /// entries, each computed with the identical per-row loop —
    /// bit-identical to the sequential walk at any thread count.
    pub fn run_spmv_with_pool(
        &self,
        pool: &crate::exec::Pool,
        csr: &Csr,
        x: &[f64],
        y: &mut [f64],
    ) {
        debug_assert_eq!(csr.cols, x.len());
        debug_assert_eq!(csr.rows, y.len());
        let lanes = pool.threads().min(self.pes);
        if lanes <= 1 {
            return self.run_spmv(csr, x, y);
        }
        let pe_blocks = crate::exec::even_ranges(self.pes, lanes);
        let scatter = crate::exec::ScatterMut::new(y);
        // Labeled obs site: per-lane busy time of the nnz-grouped
        // schedule lands in PROFILE.json as "spmv.nnz_row_groups" (the
        // §4.2 load-balance comparison arm; a no-op while obs is off).
        pool.run_labeled(&crate::obs::lanes::SITE_SPMV_SCHEDULED, pe_blocks.len(), &|block| {
            for it in 0..self.iterations {
                for pe in pe_blocks[block].clone() {
                    if let Some(r) = self.row_for(it, pe) {
                        let r = r as usize;
                        let mut acc = 0.0;
                        for k in csr.row_range(r) {
                            acc += csr.val[k] * x[csr.col_idx[k] as usize];
                        }
                        // SAFETY: the schedule is a permutation of rows
                        // (each row in exactly one slot) and PE blocks
                        // are disjoint, so no two lanes write one row.
                        unsafe { scatter.write(r, acc) };
                    }
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::dense::Mat;
    use crate::util::rng::Xoshiro256;

    fn random_csr(rows: usize, cols: usize, p: f64, rng: &mut Xoshiro256) -> Csr {
        let mut m = Mat::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                if rng.bernoulli(p) {
                    m[(i, j)] = rng.normal();
                }
            }
        }
        Csr::from_dense(&m, 0.0)
    }

    /// Property: a schedule assigns every row exactly once.
    #[test]
    fn schedule_is_permutation() {
        let mut rng = Xoshiro256::seed_from_u64(10);
        for _ in 0..25 {
            let rows = 1 + rng.gen_range(100);
            let pes = 1 + rng.gen_range(8);
            let csr = random_csr(rows, 20, rng.uniform(0.0, 0.6), &mut rng);
            for policy in [SchedulePolicy::NnzGrouped, SchedulePolicy::RowOrder] {
                let sched = ScheduleTable::build(&csr, pes, policy);
                let mut seen = vec![false; rows];
                for it in 0..sched.iterations {
                    for pe in 0..pes {
                        if let Some(r) = sched.row_for(it, pe) {
                            assert!(!seen[r as usize], "row {r} assigned twice");
                            seen[r as usize] = true;
                        }
                    }
                }
                assert!(seen.iter().all(|&s| s), "rows missing from schedule");
            }
        }
    }

    /// Property: scheduled SpMV is bit-identical to plain CSR SpMV.
    #[test]
    fn scheduled_spmv_exact() {
        let mut rng = Xoshiro256::seed_from_u64(11);
        for _ in 0..15 {
            let rows = 1 + rng.gen_range(60);
            let cols = 1 + rng.gen_range(60);
            let csr = random_csr(rows, cols, 0.3, &mut rng);
            let x: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
            let want = csr.spmv(&x);
            let sched = ScheduleTable::build(&csr, 4, SchedulePolicy::NnzGrouped);
            let mut got = vec![0.0; rows];
            sched.run_spmv(&csr, &x, &mut got);
            assert_eq!(want, got); // bit-identical: same per-row fp order
        }
    }

    /// Property: the pool-parallel scheduled SpMV is bit-identical to
    /// the sequential scheduled SpMV (and so to plain CSR SpMV) for
    /// every policy, PE count and thread count.
    #[test]
    fn pool_spmv_bit_identical_across_thread_counts() {
        let pools: Vec<crate::exec::Pool> =
            [1usize, 2, 7].iter().map(|&t| crate::exec::Pool::new(t)).collect();
        let mut rng = Xoshiro256::seed_from_u64(21);
        for _ in 0..10 {
            let rows = 1 + rng.gen_range(80);
            let cols = 1 + rng.gen_range(50);
            let csr = random_csr(rows, cols, rng.uniform(0.05, 0.5), &mut rng);
            let x: Vec<f64> = (0..cols).map(|_| rng.normal()).collect();
            for pes in [1usize, 3, 4, 9] {
                for policy in [SchedulePolicy::NnzGrouped, SchedulePolicy::RowOrder] {
                    let sched = ScheduleTable::build(&csr, pes, policy);
                    let mut want = vec![0.0; rows];
                    sched.run_spmv(&csr, &x, &mut want);
                    for pool in &pools {
                        let mut got = vec![0.0; rows];
                        sched.run_spmv_with_pool(pool, &csr, &x, &mut got);
                        assert_eq!(
                            got,
                            want,
                            "pool SpMV drift: pes={pes}, {policy:?}, threads={}",
                            pool.threads()
                        );
                    }
                }
            }
        }
    }

    /// Property: nnz-grouped never costs more cycles than row-order, and
    /// strictly helps on a skewed matrix.
    #[test]
    fn balanced_no_worse_and_helps_on_skew() {
        let mut rng = Xoshiro256::seed_from_u64(12);
        for _ in 0..20 {
            let rows = 8 + rng.gen_range(120);
            let csr = random_csr(rows, 64, rng.uniform(0.05, 0.4), &mut rng);
            let lb = ScheduleTable::build(&csr, 4, SchedulePolicy::NnzGrouped);
            let nolb = ScheduleTable::build(&csr, 4, SchedulePolicy::RowOrder);
            let (c_lb, _) = lb.spmv_cycles(&csr);
            let (c_no, _) = nolb.spmv_cycles(&csr);
            assert!(c_lb <= c_no, "LB worse: {c_lb} > {c_no}");
        }

        // Heavily skewed: one dense row per group of empty rows.
        let mut triplets = Vec::new();
        for r in (0..64).step_by(4) {
            for c in 0..64 {
                triplets.push((r, c, 1.0));
            }
        }
        let skew = Csr::from_triplets(64, 64, triplets);
        let lb = ScheduleTable::build(&skew, 4, SchedulePolicy::NnzGrouped);
        let nolb = ScheduleTable::build(&skew, 4, SchedulePolicy::RowOrder);
        let (c_lb, _) = lb.spmv_cycles(&skew);
        let (c_no, _) = nolb.spmv_cycles(&skew);
        // Row-order puts one dense row in every iteration (16 iterations x
        // 64 cycles); grouping packs the 16 dense rows into 4 iterations.
        assert!(c_lb * 3 < c_no, "expected big win on skew: {c_lb} vs {c_no}");
        assert!(lb.utilization(&skew) > nolb.utilization(&skew));
    }

    #[test]
    fn empty_matrix() {
        let csr = Csr::from_triplets(0, 5, vec![]);
        let sched = ScheduleTable::build(&csr, 4, SchedulePolicy::NnzGrouped);
        assert_eq!(sched.iterations, 0);
        assert_eq!(sched.spmv_cycles(&csr), (0, 0));
        assert_eq!(sched.utilization(&csr), 1.0);
    }

    #[test]
    fn padding_slots_idle() {
        let csr = Csr::from_triplets(5, 5, vec![(0, 0, 1.0), (4, 4, 1.0)]);
        let sched = ScheduleTable::build(&csr, 4, SchedulePolicy::NnzGrouped);
        assert_eq!(sched.iterations, 2);
        let assigned: usize = (0..sched.iterations)
            .map(|it| (0..4).filter(|&pe| sched.row_for(it, pe).is_some()).count())
            .sum();
        assert_eq!(assigned, 5);
        assert_eq!(sched.table_bytes(), 2 * 4 * 4);
    }
}
