//! Sparse-matrix substrate: CSR storage/SpMV and the paper's §4.2 static
//! load-balancing schedule tables.

pub mod csr;
pub mod schedule;

pub use csr::{Csr, RowNnzStats, RowOffsets};
pub use schedule::{SchedulePolicy, ScheduleTable, NO_ROW};
