//! Algorithm 1 implemented verbatim — the correctness oracle for the
//! optimized engine. Materializes the full feature matrix `M^(t)` per hop
//! (the baseline the paper's §5.2.1 restructuring replaces) and uses
//! hashmap codebook lookups (the naive dictionary search the MPHE
//! replaces).

use crate::graph::Graph;
use crate::hdc::Hypervector;
use crate::model::NysHdcModel;

/// End-to-end Algorithm 1: returns (predicted class, query HV).
pub fn infer_reference(model: &NysHdcModel, graph: &Graph) -> (usize, Hypervector) {
    let n = graph.num_nodes();
    let s = model.s();
    // line 1: M ← F_x
    let mut m = graph.features.clone();
    // line 2: C ← 0
    let mut c_sim = vec![0.0f64; s];

    for t in 0..model.hops() {
        // line 4: c ← ⌊(M u^(t) + b^(t) 1_N)/w⌋
        let proj = m.matvec(&model.lsh.u[t]);
        let codes: Vec<i64> = (0..n).map(|i| model.lsh.quantize(proj[i], t)).collect();
        // lines 5-8: histogram through B^(t), skipping absent codes
        let cb = &model.codebooks[t];
        let mut hist = vec![0.0f64; cb.len()];
        for &code in &codes {
            if let Some(j) = cb.index_of(code) {
                hist[j as usize] += 1.0;
            }
        }
        // line 9: v^(t) = H^(t) h^(t)
        let h = &model.landmark_hists[t];
        for r in 0..h.rows {
            let mut acc = 0.0;
            for k in h.row_range(r) {
                acc += h.val[k] * hist[h.col_idx[k] as usize];
            }
            // line 10: C ← C + v^(t)
            c_sim[r] += acc;
        }
        // lines 11-12: propagate M ← A_x M
        if t + 1 < model.hops() {
            m = graph.adj.spmm(&m);
        }
    }

    // line 13: y = P_nys C; h = sign(y)
    let y = model.projection.project(&c_sim);
    let hv = Hypervector::from_real(&y);
    // line 14: argmax over class prototypes (i8 oracle view, unpacked
    // on demand — the model stores only the packed representation)
    let predicted = model.reference_prototypes().classify(&hv);
    (predicted, hv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::tudataset::spec_by_name;
    use crate::model::train::{encode_hv, train};
    use crate::model::ModelConfig;

    #[test]
    fn reference_matches_training_encoder() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(21, 0.25);
        let cfg = ModelConfig {
            hops: 3,
            hv_dim: 1024,
            num_landmarks: 12,
            ..ModelConfig::default()
        };
        let model = train(&ds, &cfg);
        for (g, _) in ds.test.iter().take(10) {
            let (_, hv) = infer_reference(&model, g);
            assert_eq!(hv, encode_hv(&model, g));
        }
    }
}
