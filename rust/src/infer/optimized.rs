//! The optimized NysX inference pipeline — the functional model of the
//! accelerator (paper §5):
//!
//! * LSHU: restructured `A^k F u` vector chain with the §4.2 scheduled
//!   SpMV;
//! * MPHE: O(1) minimal-perfect-hash codebook lookups with verification;
//! * HUE: histogram accumulation;
//! * KSE: scheduled SpMV against the CSR landmark histograms;
//! * NEE: f32 streaming projection with fused bipolarize-and-pack — the
//!   query HV is produced directly as sign bits
//!   ([`crate::hdc::PackedHypervector`]), no i8 (or f64 y) ever hits the
//!   hot path;
//! * SCE: popcount prototype matching against the packed prototypes +
//!   argmax (bit-identical to the i8 reference, which
//!   [`crate::infer::reference`] keeps serving as the oracle). The
//!   popcount inner kernels dispatch through the process-wide
//!   [`crate::hdc::simd`] backend (scalar/AVX2/NEON, selected once at
//!   startup; `NYSX_FORCE_SCALAR=1` pins the scalar oracle), so the same
//!   engine runs wide SIMD popcount where the host supports it without
//!   any change in results.
//!
//! All scratch buffers live in [`NysxEngine`], so the per-request hot path
//! is allocation-free. Every inference also produces an [`InferTrace`] —
//! the per-stage work counts (real nnz, real MPH probe counts, real
//! histogram sizes) that drive the cycle-accurate accelerator model in
//! [`crate::sim`].
//!
//! # Batch-major serving path
//!
//! [`NysxEngine::infer_batch`] runs W queries through one engine with a
//! single scratch set: the per-graph stages (LSHU/MPHE/HUE/KSE) reuse the
//! same buffers request after request, each kernel vector is
//! project-bipolarize-packed straight into a slot of the engine's
//! [`crate::hdc::PackedBatch`], and the SCE runs **once** for the whole
//! batch via the blocked C×W popcount matcher
//! ([`crate::hdc::PackedPrototypes::classify_batch_into`]) instead of W
//! independent prototype sweeps. [`NysxEngine::classify_kernel_vectors`]
//! exposes the same NEE+SCE tail for callers that already hold kernel
//! vectors. Both are bit-identical to the single-query path (and so to
//! the i8 oracle), which the tests below enforce.

use std::borrow::Borrow;
use std::sync::Arc;

use crate::exec::{self, Pool};
use crate::graph::Graph;
use crate::hdc::packed::words_for;
use crate::hdc::{simd, PackedBatch, PackedHypervector};
use crate::model::NysHdcModel;
use crate::mph::code_key;
use crate::sparse::{SchedulePolicy, ScheduleTable};

/// Per-hop work counts observed during one inference.
#[derive(Debug, Clone, Default)]
pub struct HopTrace {
    /// Codebook lookups issued (= N).
    pub lookups: u64,
    /// Total MPH level probes across those lookups.
    pub mph_probes: u64,
    /// Lookups that hit the vocabulary (histogram updates).
    pub vocab_hits: u64,
    /// |B^(t)| — histogram length.
    pub hist_bins: usize,
    /// nnz(H^(t)).
    pub kse_nnz: u64,
    /// KSE SpMV cycles under the §4.2 schedule.
    pub kse_cycles_lb: u64,
    /// KSE SpMV cycles under natural row order (no LB).
    pub kse_cycles_nolb: u64,
}

/// Whole-inference work counts.
#[derive(Debug, Clone, Default)]
pub struct InferTrace {
    pub n: usize,
    pub f: usize,
    pub nnz_a: u64,
    /// Cycles for ONE application of A under the LB schedule.
    pub a_spmv_cycles_lb: u64,
    /// ... and under natural row order.
    pub a_spmv_cycles_nolb: u64,
    /// Number of A-applications in the restructured chain = H(H-1)/2.
    pub a_spmv_applications: u64,
    pub hops: Vec<HopTrace>,
    pub s: usize,
    pub d: usize,
    pub num_classes: usize,
}

/// Result of one inference.
#[derive(Debug, Clone)]
pub struct InferenceResult {
    pub predicted: usize,
    /// The query HV as the SCE saw it: bit-packed sign bits. Call
    /// `.unpack()` for the i8 view (lossless).
    pub hv: PackedHypervector,
    pub trace: InferTrace,
}

/// Reusable inference engine bound to a trained model.
///
/// Generic over the model *handle* `M`: borrow-based construction
/// (`NysxEngine::new(&model)`) keeps the zero-copy shape the workers and
/// benches use, while `NysxEngine::new(Arc<NysHdcModel>)` yields a fully
/// owned engine — the form [`crate::api::TrainedPipeline`] hands out so
/// facade callers never juggle a borrow lifetime.
pub struct NysxEngine<M: Borrow<NysHdcModel> = Arc<NysHdcModel>> {
    model: M,
    /// The exec pool driving the engine's data-parallel kernels (NEE
    /// projection word ranges, blocked C×W SCE query blocks, big-graph
    /// scheduled SpMV). Defaults to [`exec::global`]; every result is
    /// bit-identical at any pool size.
    pool: Arc<Pool>,
    /// No-LB schedules for the KSE ablation (built once).
    kse_nolb: Vec<ScheduleTable>,
    // --- scratch (hot path is allocation-free) ---
    c_sim: Vec<f64>,
    hv: PackedHypervector,
    proj: Vec<f64>,
    proj_scratch: Vec<f64>,
    codes: Vec<i64>,
    hist: Vec<f64>,
    // --- batch scratch (one set, reused across batches) ---
    batch: PackedBatch,
    batch_scores: Vec<i64>,
    batch_preds: Vec<usize>,
    /// W kernel vectors staged back-to-back (s values each) so the
    /// batched NEE can project-pack every query in parallel.
    c_sims_flat: Vec<f64>,
}

impl<M: Borrow<NysHdcModel>> NysxEngine<M> {
    pub fn new(model: M) -> Self {
        Self::with_pool(model, exec::global())
    }

    /// [`Self::new`] on an explicit exec pool (the form
    /// [`crate::api::Pipeline::threads`] hands out).
    pub fn with_pool(model: M, pool: Arc<Pool>) -> Self {
        let (kse_nolb, c_sim, hv, hist, batch) = {
            let m: &NysHdcModel = model.borrow();
            let max_bins = m.codebooks.iter().map(|cb| cb.len()).max().unwrap_or(0);
            let kse_nolb = m
                .landmark_hists
                .iter()
                .map(|h| ScheduleTable::build(h, m.config.pes, SchedulePolicy::RowOrder))
                .collect();
            (
                kse_nolb,
                vec![0.0; m.s()],
                PackedHypervector::zeros(m.d()),
                vec![0.0; max_bins],
                PackedBatch::new(m.d()),
            )
        };
        Self {
            model,
            pool,
            kse_nolb,
            c_sim,
            hv,
            proj: Vec::new(),
            proj_scratch: Vec::new(),
            codes: Vec::new(),
            hist,
            batch,
            batch_scores: Vec::new(),
            batch_preds: Vec::new(),
            c_sims_flat: Vec::new(),
        }
    }

    /// The trained model this engine serves.
    pub fn model(&self) -> &NysHdcModel {
        self.model.borrow()
    }

    /// The exec pool this engine dispatches on.
    pub fn pool(&self) -> &Arc<Pool> {
        &self.pool
    }

    /// Alg. 1 lines 1-12: compute the kernel-similarity vector C(x) and
    /// the work trace. Returns a borrow of the internal C buffer.
    pub fn kernel_vector(&mut self, graph: &Graph) -> (&[f64], InferTrace) {
        // Destructure to split the borrows: the model handle is read-only
        // while every scratch buffer is mutated.
        let Self {
            model,
            pool,
            kse_nolb,
            c_sim,
            proj,
            proj_scratch,
            codes,
            hist,
            ..
        } = self;
        let model: &NysHdcModel = (*model).borrow();
        let n = graph.num_nodes();
        let hops = model.hops();
        c_sim.iter_mut().for_each(|v| *v = 0.0);
        proj.resize(n, 0.0);
        proj_scratch.resize(n, 0.0);
        codes.resize(n, 0);

        // Per-query adjacency schedule (O(N) offline-style construction —
        // the paper builds it when the CSR operand is loaded).
        let a_lb = ScheduleTable::build(&graph.adj, model.config.pes, SchedulePolicy::NnzGrouped);
        let a_nolb = ScheduleTable::build(&graph.adj, model.config.pes, SchedulePolicy::RowOrder);
        let (a_cycles_lb, _) = a_lb.spmv_cycles(&graph.adj);
        let (a_cycles_nolb, _) = a_nolb.spmv_cycles(&graph.adj);

        let mut trace = InferTrace {
            n,
            f: graph.feature_dim(),
            nnz_a: graph.adj.nnz() as u64,
            a_spmv_cycles_lb: a_cycles_lb,
            a_spmv_cycles_nolb: a_cycles_nolb,
            a_spmv_applications: (hops * (hops.saturating_sub(1)) / 2) as u64,
            hops: Vec::with_capacity(hops),
            s: model.s(),
            d: model.d(),
            num_classes: model.num_classes,
        };

        for t in 0..hops {
            // LSHU: c = F u^(t), then t scheduled applications of A.
            // Obs stage spans per hop: the guards record elapsed ns into
            // the stage histograms on scope exit, and are inert (no
            // clock read) while obs is disabled.
            {
                let _stage = crate::obs::span(&crate::obs::metrics::STAGE_FEATURIZE);
                for (i, p) in proj.iter_mut().enumerate() {
                    let mut acc = 0.0;
                    let row = graph.features.row(i);
                    for (x, u) in row.iter().zip(&model.lsh.u[t]) {
                        acc += x * u;
                    }
                    *p = acc;
                }
            }
            {
                let _stage = crate::obs::span(&crate::obs::metrics::STAGE_SPMV);
                for _ in 0..t {
                    // Edge graphs are small; only big adjacency operands are
                    // worth the pool's lane wake-up (bit-identical either way
                    // — the schedule row groups partition y disjointly).
                    if graph.adj.nnz() >= exec::PAR_MIN_NNZ {
                        a_lb.run_spmv_with_pool(pool, &graph.adj, proj, proj_scratch);
                    } else {
                        a_lb.run_spmv(&graph.adj, proj, proj_scratch);
                    }
                    std::mem::swap(proj, proj_scratch);
                }
            }
            for (c, &p) in codes.iter_mut().zip(proj.iter()) {
                *c = model.lsh.quantize(p, t);
            }

            // MPHE + HUE: verified O(1) lookups, histogram accumulation.
            let cb_len = model.codebooks[t].len();
            let hist = &mut hist[..cb_len];
            hist.iter_mut().for_each(|v| *v = 0.0);
            let lookup = &model.lookups[t];
            let mut probes = 0u64;
            let mut hits = 0u64;
            {
                let _stage = crate::obs::span(&crate::obs::metrics::STAGE_MPH_LOOKUP);
                for &code in codes.iter() {
                    let (idx, p) = lookup.get_with_probes(code_key(code));
                    probes += p as u64;
                    if let Some(j) = idx {
                        hist[j as usize] += 1.0;
                        hits += 1;
                    }
                }
            }

            // KSE: v^(t) = H^(t) h^(t) via the static LB schedule,
            // accumulated into C (same "spmv" obs stage as the A-chain:
            // both are scheduled SpMV passes).
            let _stage = crate::obs::span(&crate::obs::metrics::STAGE_SPMV);
            let h = &model.landmark_hists[t];
            let sched = &model.kse_schedules[t];
            for it in 0..sched.iterations {
                for pe in 0..sched.pes {
                    if let Some(r) = sched.row_for(it, pe) {
                        let r = r as usize;
                        let mut acc = 0.0;
                        for k in h.row_range(r) {
                            acc += h.val[k] * hist[h.col_idx[k] as usize];
                        }
                        c_sim[r] += acc;
                    }
                }
            }
            drop(_stage);

            let (kse_lb, _) = sched.spmv_cycles(h);
            let (kse_cycles_nolb, _) = kse_nolb[t].spmv_cycles(h);
            trace.hops.push(HopTrace {
                lookups: n as u64,
                mph_probes: probes,
                vocab_hits: hits,
                hist_bins: cb_len,
                kse_nnz: h.nnz() as u64,
                kse_cycles_lb: kse_lb,
                kse_cycles_nolb,
            });
        }
        (c_sim.as_slice(), trace)
    }

    /// NEE + SCE from a kernel vector: fused project-bipolarize-pack into
    /// the reusable packed scratch HV, then popcount-classify against the
    /// packed prototypes. Zero i8 materialization; bit-identical to the
    /// i8 reference path.
    pub fn classify_kernel_vector(&mut self, c_sim: &[f64]) -> (usize, PackedHypervector) {
        let Self { model, pool, hv, .. } = self;
        let model: &NysHdcModel = (*model).borrow();
        // The d×s projection dominates single-query NEE+SCE time; split
        // its packed words across the pool's lanes when the matrix is
        // big enough to amortize the dispatch (same bits either way).
        {
            let _stage = crate::obs::span(&crate::obs::metrics::STAGE_NEE_PROJECT);
            if exec::worth_parallelizing(pool, model.d() * model.s(), exec::PAR_MIN_MACS) {
                model.projection.project_pack_into_with_pool(pool, c_sim, hv);
            } else {
                model.projection.project_pack_into(c_sim, hv);
            }
        }
        // SCE: class-block parallel matching once the C×d prototype
        // sweep itself is big enough, the streaming sequential argmax
        // otherwise — identical scores and first-max tie rule either
        // way.
        let sce_work = model.packed_prototypes.num_classes() * words_for(model.d());
        let _stage = crate::obs::span(&crate::obs::metrics::STAGE_SCE_MATCH);
        let predicted = if exec::worth_parallelizing(pool, sce_work, exec::PAR_MIN_WORDS) {
            model.packed_prototypes.classify_pool(pool, simd::active(), hv)
        } else {
            model.packed_prototypes.classify(hv)
        };
        drop(_stage);
        (predicted, hv.clone())
    }

    /// NEE + SCE for a whole batch of kernel vectors: each C(x) is
    /// project-bipolarize-packed into a slot of the engine's reusable
    /// [`PackedBatch`], then ONE blocked C×W popcount matching call
    /// classifies every query. Per query this is bit-identical to
    /// [`Self::classify_kernel_vector`].
    pub fn classify_kernel_vectors(
        &mut self,
        c_sims: &[Vec<f64>],
    ) -> Vec<(usize, PackedHypervector)> {
        let Self {
            model,
            pool,
            batch,
            batch_scores,
            batch_preds,
            c_sims_flat,
            ..
        } = self;
        let model: &NysHdcModel = (*model).borrow();
        // Stage the kernel vectors flat so the shared NEE+SCE tail can
        // fan out over contiguous per-query slices.
        let mut c_flat = std::mem::take(c_sims_flat);
        c_flat.clear();
        for c in c_sims {
            c_flat.extend_from_slice(c);
        }
        nee_sce_batch(model, pool, &c_flat, c_sims.len(), batch, batch_scores, batch_preds);
        *c_sims_flat = c_flat;
        (0..c_sims.len())
            .map(|qi| (batch_preds[qi], batch.get(qi)))
            .collect()
    }

    /// Batched Algorithm 1: the per-graph stages run back-to-back on one
    /// scratch set, the SCE runs once for the whole batch (blocked C×W
    /// matching). Results are bit-identical to calling [`Self::infer`] on
    /// each graph in order, traces included.
    pub fn infer_batch(&mut self, graphs: &[&Graph]) -> Vec<InferenceResult> {
        if crate::obs::enabled() {
            crate::obs::metrics::INFER_REQUESTS.inc();
            crate::obs::metrics::INFER_GRAPHS.add(graphs.len() as u64);
        }
        let mut traces = Vec::with_capacity(graphs.len());
        // Stage 1 (sequential, one scratch set): the per-graph front half
        // (LSHU/MPHE/HUE/KSE), staging each kernel vector into the flat
        // batch buffer.
        let mut c_flat = std::mem::take(&mut self.c_sims_flat);
        c_flat.clear();
        for &g in graphs {
            let (c, trace) = self.kernel_vector(g);
            c_flat.extend_from_slice(c);
            traces.push(trace);
        }
        // Stage 2+3: the shared NEE+SCE tail — fused project-pack into
        // disjoint batch slots, then ONE blocked C×W SCE, both across
        // the pool when the work clears the PAR_MIN_* thresholds.
        // Bit-identical to per-graph infer() at any thread count.
        let Self {
            model,
            pool,
            batch,
            batch_scores,
            batch_preds,
            ..
        } = self;
        let model: &NysHdcModel = (*model).borrow();
        nee_sce_batch(model, pool, &c_flat, graphs.len(), batch, batch_scores, batch_preds);
        let results = traces
            .into_iter()
            .enumerate()
            .map(|(qi, trace)| InferenceResult {
                predicted: batch_preds[qi],
                hv: batch.get(qi),
                trace,
            })
            .collect();
        self.c_sims_flat = c_flat;
        results
    }

    /// Full Algorithm 1.
    pub fn infer(&mut self, graph: &Graph) -> InferenceResult {
        if crate::obs::enabled() {
            crate::obs::metrics::INFER_REQUESTS.inc();
            crate::obs::metrics::INFER_GRAPHS.inc();
        }
        let (_, trace) = self.kernel_vector(graph);
        // Split borrows: take c_sim out temporarily to satisfy the borrow
        // checker without cloning on the hot path.
        let c_sim = std::mem::take(&mut self.c_sim);
        let (predicted, hv) = self.classify_kernel_vector(&c_sim);
        self.c_sim = c_sim;
        InferenceResult {
            predicted,
            hv,
            trace,
        }
    }
}

/// The shared batched NEE+SCE tail: project-bipolarize-pack `W`
/// kernel vectors (stored flat, `s` values each) into disjoint
/// [`PackedBatch`] slots, then run ONE blocked C×W SCE pass into
/// `scores`/`preds`. Both stages fan out over the exec pool only when
/// the work clears the matching `exec::PAR_MIN_*` threshold — the same
/// gate rule as the plain `hdc` entry points — and are bit-identical
/// either way. Single source of truth for `classify_kernel_vectors`
/// and `infer_batch` so their dispatch behavior can never diverge.
#[allow(clippy::too_many_arguments)]
fn nee_sce_batch(
    model: &NysHdcModel,
    pool: &Pool,
    c_flat: &[f64],
    w: usize,
    batch: &mut PackedBatch,
    scores: &mut Vec<i64>,
    preds: &mut Vec<usize>,
) {
    let s = model.s();
    debug_assert_eq!(c_flat.len(), w * s, "flat kernel-vector buffer shape");
    batch.clear();
    for _ in 0..w {
        batch.push_zeroed();
    }
    let wph = batch.words_per_hv();
    {
        let _stage = crate::obs::span(&crate::obs::metrics::STAGE_NEE_PROJECT);
        if exec::worth_parallelizing(pool, w * model.d() * s, exec::PAR_MIN_MACS) {
            let q_ranges = exec::even_ranges(w, pool.threads());
            let word_ranges: Vec<std::ops::Range<usize>> =
                q_ranges.iter().map(|r| r.start * wph..r.end * wph).collect();
            exec::for_each_range_mut_labeled(
                pool,
                &crate::obs::lanes::SITE_NEE_BATCH,
                batch.all_words_mut(),
                &word_ranges,
                |block, part| {
                    for (local, q) in q_ranges[block].clone().enumerate() {
                        model.projection.project_pack_words(
                            &c_flat[q * s..(q + 1) * s],
                            &mut part[local * wph..(local + 1) * wph],
                        );
                    }
                },
            );
        } else {
            for q in 0..w {
                model
                    .projection
                    .project_pack_words(&c_flat[q * s..(q + 1) * s], batch.query_words_mut(q));
            }
        }
    }
    let sce_work = model.packed_prototypes.num_classes() * w * wph;
    let _stage = crate::obs::span(&crate::obs::metrics::STAGE_SCE_MATCH);
    if exec::worth_parallelizing(pool, sce_work, exec::PAR_MIN_WORDS) {
        model
            .packed_prototypes
            .classify_batch_into_pool(pool, simd::active(), batch, scores, preds);
    } else {
        model
            .packed_prototypes
            .classify_batch_into_with(simd::active(), batch, scores, preds);
    }
    drop(_stage);
}

impl InferTrace {
    /// Total MPH probes across hops (MPHE cycle driver).
    pub fn total_probes(&self) -> u64 {
        self.hops.iter().map(|h| h.mph_probes).sum()
    }

    /// Total vocabulary hits (HUE update driver).
    pub fn total_hits(&self) -> u64 {
        self.hops.iter().map(|h| h.vocab_hits).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::tudataset::spec_by_name;
    use crate::infer::reference::infer_reference;
    use crate::model::train::train;
    use crate::model::ModelConfig;

    fn trained() -> (crate::graph::GraphDataset, NysHdcModel) {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(31, 0.3);
        let cfg = ModelConfig {
            hops: 3,
            // Off a 64 boundary so the packed tail word is exercised on
            // every inference.
            hv_dim: 1000,
            num_landmarks: 14,
            ..ModelConfig::default()
        };
        let model = train(&ds, &cfg);
        (ds, model)
    }

    /// THE core equivalence property: the optimized pipeline (vector
    /// chain + MPH + scheduled SpMV + fused f32 project-bipolarize-pack +
    /// popcount SCE) produces bit-identical HVs and predictions to the
    /// verbatim i8 Algorithm 1.
    #[test]
    fn optimized_equals_reference() {
        let (ds, model) = trained();
        let mut engine = NysxEngine::new(&model);
        for (g, _) in ds.test.iter() {
            let opt = engine.infer(g);
            let (want_pred, want_hv) = infer_reference(&model, g);
            assert_eq!(opt.hv, want_hv.pack(), "packed HV mismatch");
            assert_eq!(opt.hv.unpack(), want_hv, "unpacked HV mismatch");
            assert_eq!(opt.predicted, want_pred, "prediction mismatch");
        }
    }

    #[test]
    fn trace_counts_sane() {
        let (ds, model) = trained();
        let mut engine = NysxEngine::new(&model);
        let g = &ds.test[0].0;
        let res = engine.infer(g);
        let tr = &res.trace;
        assert_eq!(tr.n, g.num_nodes());
        assert_eq!(tr.hops.len(), 3);
        assert_eq!(tr.a_spmv_applications, 3); // 0+1+2
        for hop in &tr.hops {
            assert_eq!(hop.lookups, g.num_nodes() as u64);
            assert!(hop.vocab_hits <= hop.lookups);
            // Every lookup needs at least one probe.
            assert!(hop.mph_probes >= hop.lookups);
            assert!(hop.kse_cycles_lb <= hop.kse_cycles_nolb);
            assert!(hop.kse_cycles_lb as f64 >= hop.kse_nnz as f64 / model.config.pes as f64);
        }
        assert!(tr.a_spmv_cycles_lb <= tr.a_spmv_cycles_nolb);
    }

    #[test]
    fn engine_reusable_across_requests() {
        // Same engine, interleaved graphs of different sizes: results must
        // match fresh-engine runs (scratch reuse must not leak state).
        let (ds, model) = trained();
        let mut engine = NysxEngine::new(&model);
        let order = [0usize, 5, 1, 5, 0];
        for &i in &order {
            let g = &ds.test[i].0;
            let res = engine.infer(g);
            let mut fresh = NysxEngine::new(&model);
            let fresh_res = fresh.infer(g);
            assert_eq!(res.hv, fresh_res.hv);
            assert_eq!(res.predicted, fresh_res.predicted);
        }
    }

    #[test]
    fn staged_api_matches_full() {
        let (ds, model) = trained();
        let mut engine = NysxEngine::new(&model);
        let g = &ds.test[2].0;
        let full = engine.infer(g);
        let (c, _) = engine.kernel_vector(g);
        let c = c.to_vec();
        let (pred, hv) = engine.classify_kernel_vector(&c);
        assert_eq!(pred, full.predicted);
        assert_eq!(hv, full.hv);
    }

    /// The batched pipeline is bit-identical to per-graph [`NysxEngine::infer`]
    /// — predictions, packed HVs, and traces — across batch widths,
    /// including interleaving batched and single calls on one engine.
    #[test]
    fn batch_inference_bit_identical_to_single() {
        let (ds, model) = trained();
        let mut engine = NysxEngine::new(&model);
        let graphs: Vec<&crate::graph::Graph> = ds.test.iter().map(|(g, _)| g).collect();
        let singles: Vec<InferenceResult> = graphs.iter().map(|&g| engine.infer(g)).collect();

        // Whole split as one batch.
        let batched = engine.infer_batch(&graphs);
        assert_eq!(batched.len(), singles.len());
        for (b, s) in batched.iter().zip(&singles) {
            assert_eq!(b.predicted, s.predicted, "prediction drift in batch");
            assert_eq!(b.hv, s.hv, "packed HV drift in batch");
            assert_eq!(b.trace.n, s.trace.n);
            assert_eq!(b.trace.total_probes(), s.trace.total_probes());
            assert_eq!(b.trace.total_hits(), s.trace.total_hits());
        }

        // Varying widths interleaved with single calls: scratch reuse must
        // not leak state in either direction.
        let mid = graphs.len() / 2;
        let first = engine.infer_batch(&graphs[..mid]);
        let lone = engine.infer(graphs[mid]);
        let rest = engine.infer_batch(&graphs[mid + 1..]);
        assert_eq!(lone.predicted, singles[mid].predicted);
        assert_eq!(lone.hv, singles[mid].hv);
        for (b, s) in first.iter().zip(&singles[..mid]) {
            assert_eq!(b.predicted, s.predicted);
            assert_eq!(b.hv, s.hv);
        }
        for (b, s) in rest.iter().zip(&singles[mid + 1..]) {
            assert_eq!(b.predicted, s.predicted);
            assert_eq!(b.hv, s.hv);
        }

        // Degenerate widths.
        assert!(engine.infer_batch(&[]).is_empty());
        let one = engine.infer_batch(&graphs[..1]);
        assert_eq!(one[0].predicted, singles[0].predicted);
        assert_eq!(one[0].hv, singles[0].hv);
    }

    #[test]
    fn batch_kernel_vector_api_matches_staged_single() {
        let (ds, model) = trained();
        let mut engine = NysxEngine::new(&model);
        let c_sims: Vec<Vec<f64>> = ds
            .test
            .iter()
            .take(6)
            .map(|(g, _)| {
                let (c, _) = engine.kernel_vector(g);
                c.to_vec()
            })
            .collect();
        let batch_out = engine.classify_kernel_vectors(&c_sims);
        assert_eq!(batch_out.len(), c_sims.len());
        for (c, (pred, hv)) in c_sims.iter().zip(&batch_out) {
            let (want_pred, want_hv) = engine.classify_kernel_vector(c);
            assert_eq!(*pred, want_pred);
            assert_eq!(*hv, want_hv);
        }
        assert!(engine.classify_kernel_vectors(&[]).is_empty());
    }
}
