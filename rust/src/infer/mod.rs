//! Inference paths: the literal Algorithm-1 reference and the optimized
//! NysX pipeline (restructured LSH chain, MPH lookups, statically
//! load-balanced SpMV) that doubles as the accelerator's functional model.

pub mod optimized;
pub mod reference;

pub use optimized::{HopTrace, InferTrace, InferenceResult, NysxEngine};
pub use reference::infer_reference;
