//! Per-site exec-lane utilization: busy time and parts executed per
//! lane of a labeled `Pool::run` site, merged into a load-imbalance
//! ratio at snapshot time.
//!
//! This is what makes the paper's §4.2 static-load-balancing claim
//! *observable*: the scheduled SpMV (`spmv.nnz_row_groups`, nnz-grouped
//! PE blocks) and the naive contiguous partitioning
//! (`spmv.even_ranges`) are both labeled sites, so one profile run
//! shows the imbalance ratio (max-lane busy / mean-lane busy) of each
//! side by side in `PROFILE.json`.
//!
//! Recording is a handful of relaxed atomic adds per lane per run —
//! the pool wraps each lane's whole part-loop in ONE clock pair, so
//! the overhead is independent of part count.

use std::sync::atomic::{AtomicU64, Ordering};

/// Lane slots tracked per site. `exec::MAX_THREADS` is 4096, but lanes
/// beyond this many fold onto slot `lane % MAX_LANES` — utilization
/// stays conservative instead of the table growing 32 KiB per site.
pub const MAX_LANES: usize = 64;

/// Lane accounting for one labeled `Pool::run` call site.
pub struct LaneSite {
    name: &'static str,
    busy_ns: [AtomicU64; MAX_LANES],
    parts: [AtomicU64; MAX_LANES],
    runs: AtomicU64,
    /// High-water mark of lanes used by any single run.
    lanes_hwm: AtomicU64,
}

impl LaneSite {
    pub const fn new(name: &'static str) -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const Z: AtomicU64 = AtomicU64::new(0);
        Self {
            name,
            busy_ns: [Z; MAX_LANES],
            parts: [Z; MAX_LANES],
            runs: AtomicU64::new(0),
            lanes_hwm: AtomicU64::new(0),
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Record one lane's contribution to one run.
    #[inline]
    pub fn record_lane(&self, lane: usize, busy_ns: u64, parts: u64) {
        let slot = lane % MAX_LANES;
        self.busy_ns[slot].fetch_add(busy_ns, Ordering::Relaxed);
        self.parts[slot].fetch_add(parts, Ordering::Relaxed);
    }

    /// Record that one run dispatched across `lanes` lanes.
    #[inline]
    pub fn record_run(&self, lanes: usize) {
        self.runs.fetch_add(1, Ordering::Relaxed);
        self.lanes_hwm.fetch_max(lanes as u64, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> LaneSiteSnapshot {
        let lanes = (self.lanes_hwm.load(Ordering::Relaxed) as usize).min(MAX_LANES);
        let busy_ns: Vec<u64> = self.busy_ns[..lanes]
            .iter()
            .map(|a| a.load(Ordering::Relaxed))
            .collect();
        let parts: Vec<u64> = self.parts[..lanes]
            .iter()
            .map(|a| a.load(Ordering::Relaxed))
            .collect();
        LaneSiteSnapshot {
            name: self.name,
            runs: self.runs.load(Ordering::Relaxed),
            lanes,
            busy_ns,
            parts,
        }
    }

    pub fn reset(&self) {
        for a in &self.busy_ns {
            a.store(0, Ordering::Relaxed);
        }
        for a in &self.parts {
            a.store(0, Ordering::Relaxed);
        }
        self.runs.store(0, Ordering::Relaxed);
        self.lanes_hwm.store(0, Ordering::Relaxed);
    }
}

/// Immutable view of a [`LaneSite`], with the derived imbalance ratio.
#[derive(Debug, Clone, PartialEq)]
pub struct LaneSiteSnapshot {
    pub name: &'static str,
    pub runs: u64,
    /// Lanes observed (high-water mark across runs).
    pub lanes: usize,
    /// Cumulative busy nanoseconds per lane, `lanes` entries.
    pub busy_ns: Vec<u64>,
    /// Cumulative parts executed per lane, `lanes` entries.
    pub parts: Vec<u64>,
}

impl LaneSiteSnapshot {
    /// Load-imbalance ratio: max-lane busy / mean-lane busy over the
    /// observed lanes. 1.0 is perfect balance; `lanes as f64` is the
    /// worst case (all work on one lane). 0.0 when nothing ran.
    pub fn imbalance(&self) -> f64 {
        if self.lanes == 0 {
            return 0.0;
        }
        let total: u64 = self.busy_ns.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let max = *self.busy_ns.iter().max().expect("lanes > 0") as f64;
        let mean = total as f64 / self.lanes as f64;
        max / mean
    }
}

// The labeled call sites. Adding a site = a static here + its row in
// `SITES` + passing it to `Pool::run_labeled` at the call site.

/// Scheduled SpMV: §4.2 nnz-grouped PE blocks (`sparse::schedule`).
pub static SITE_SPMV_SCHEDULED: LaneSite = LaneSite::new("spmv.nnz_row_groups");
/// Naive contiguous row partitioning of the same SpMV (profile harness
/// comparison arm).
pub static SITE_SPMV_EVEN: LaneSite = LaneSite::new("spmv.even_ranges");
/// Batched NEE projection word-ranges (`infer::optimized::nee_sce_batch`).
pub static SITE_NEE_BATCH: LaneSite = LaneSite::new("nee.batch_project");

/// Every labeled site, in stable export order.
pub static SITES: [&LaneSite; 3] = [&SITE_SPMV_SCHEDULED, &SITE_SPMV_EVEN, &SITE_NEE_BATCH];

/// Zero every site (called from `Registry::reset_all`).
pub fn reset_all() {
    for site in SITES {
        site.reset();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn imbalance_ratio_from_recorded_lanes() {
        let site = LaneSite::new("test.site");
        assert_eq!(site.snapshot().imbalance(), 0.0);

        // Perfectly balanced run across 4 lanes.
        site.record_run(4);
        for lane in 0..4 {
            site.record_lane(lane, 1_000, 8);
        }
        let snap = site.snapshot();
        assert_eq!(snap.lanes, 4);
        assert_eq!(snap.runs, 1);
        assert_eq!(snap.busy_ns, vec![1_000; 4]);
        assert_eq!(snap.parts, vec![8; 4]);
        assert!((snap.imbalance() - 1.0).abs() < 1e-12, "{}", snap.imbalance());

        // Pile extra work on lane 0: ratio rises toward `lanes`.
        site.record_run(4);
        site.record_lane(0, 5_000, 8);
        let skewed = site.snapshot();
        assert_eq!(skewed.runs, 2);
        // busy = [6000, 1000, 1000, 1000]; mean = 2250; max/mean = 2.666…
        assert!(
            (skewed.imbalance() - 6_000.0 / 2_250.0).abs() < 1e-12,
            "{}",
            skewed.imbalance()
        );
        assert!(skewed.imbalance() <= 4.0);

        site.reset();
        assert_eq!(site.snapshot().lanes, 0);
    }

    #[test]
    fn lanes_beyond_the_table_fold_conservatively() {
        let site = LaneSite::new("test.fold");
        site.record_run(MAX_LANES + 2);
        site.record_lane(MAX_LANES + 1, 10, 1); // folds onto slot 1
        let snap = site.snapshot();
        assert_eq!(snap.lanes, MAX_LANES);
        assert_eq!(snap.busy_ns[1], 10);
    }
}
