//! The single wall-clock seam for observability.
//!
//! Every timing read outside `coordinator/` and `bench/` flows through
//! this module, so the `timing-confinement` lint rule can confine the
//! raw `Instant::now` / `SystemTime` tokens to three directories and the
//! determinism contract stays mechanically checkable: kernels never see
//! a clock, they only ever *are seen by* one.
//!
//! Time is exposed as nanoseconds since a lazily-pinned process epoch
//! (`u64` is ~584 years of nanoseconds — no overflow in practice), so
//! call sites work in plain integers and no `Instant` values leak into
//! instrumented code.

use std::sync::OnceLock;
use std::time::Instant;

fn epoch() -> &'static Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    EPOCH.get_or_init(Instant::now)
}

/// Monotonic nanoseconds since the process obs epoch (first clock read).
#[inline]
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// Nanoseconds elapsed since a `now_ns()` reading. Saturating, so a
/// stale or crossed reading can never underflow into a huge duration.
#[inline]
pub fn elapsed_ns(start_ns: u64) -> u64 {
    now_ns().saturating_sub(start_ns)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_and_saturating() {
        let a = now_ns();
        let b = now_ns();
        assert!(b >= a, "clock went backwards: {a} -> {b}");
        assert_eq!(elapsed_ns(u64::MAX), 0, "elapsed_ns must saturate");
        // A real spin shows up as nonzero elapsed time eventually.
        let t0 = now_ns();
        let mut x = 0u64;
        for i in 0..100_000u64 {
            x = x.wrapping_add(i * i);
        }
        std::hint::black_box(x);
        let dt = elapsed_ns(t0);
        assert!(dt < u64::MAX / 2, "elapsed {dt} implausible");
    }
}
