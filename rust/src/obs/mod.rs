//! `nysx::obs` — dependency-free observability: stage-level tracing
//! spans, lock-free counters/gauges/log2-latency-histograms, per-site
//! exec-lane utilization, and export to `PROFILE.json` / Prometheus
//! text exposition. DESIGN.md §11 documents the metric catalog,
//! histogram layout and overhead budget.
//!
//! # The enable switch
//!
//! Observability is a process-global `AtomicBool`, **off by default
//! for library use** and turned **on by the CLI** unless `NYSX_OBS=0`
//! ([`init_from_env`]). Disabled paths are a single relaxed load plus
//! a branch — no clock read, no atomics, no allocation — and by
//! construction recording never feeds back into computation, so
//! outputs are bit-identical with obs on, off, or toggled mid-run, at
//! any thread count (`tests/obs_differential.rs` pins this across
//! pools {1, 2, 7}).
//!
//! # The clock seam
//!
//! All timing flows through [`clock`] — the one module outside
//! `coordinator/` and `bench/` allowed to touch `Instant` (the
//! `timing-confinement` lint rule enforces exactly that set), so the
//! kernel determinism contract stays mechanically checkable.
//!
//! # Usage
//!
//! ```
//! // Scoped stage timer (records on drop; no-op while disabled):
//! {
//!     let _s = nysx::obs::span(&nysx::obs::metrics::STAGE_SPMV);
//!     // ... the A-chain ...
//! }
//! // Or by catalog name, macro-style:
//! nysx::span!("stage.nee_project");
//! let snap = nysx::obs::Snapshot::capture();
//! assert!(snap.histograms.iter().any(|h| h.name == "stage.spmv"));
//! ```

pub mod clock;
pub mod export;
pub mod lanes;
pub mod metrics;

pub use export::Snapshot;
pub use lanes::{LaneSite, LaneSiteSnapshot};
pub use metrics::{registry, Counter, Gauge, Histogram, HistogramSnapshot, Registry, STAGES};

use std::sync::atomic::{AtomicBool, Ordering};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is observability recording on? One relaxed load — every
/// instrumentation site branches on this and does nothing while off.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Flip recording on or off. Safe at any time from any thread:
/// recording only ever *writes* metric atomics, never influences
/// computation, so toggling cannot change outputs.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// CLI initialization: on unless `NYSX_OBS=0` (or empty). Library
/// consumers who want recording call [`set_enabled`] themselves —
/// the default for embedded use stays off.
pub fn init_from_env() {
    let on = match std::env::var("NYSX_OBS") {
        Ok(v) => !(v.is_empty() || v == "0"),
        Err(_) => true,
    };
    set_enabled(on);
}

/// Serializes unit tests that toggle the process-global enable flag —
/// two toggling tests racing in one test binary would see each other's
/// state. (Integration tests run in their own processes and don't need
/// it.)
#[cfg(test)]
pub(crate) fn test_toggle_lock() -> std::sync::MutexGuard<'static, ()> {
    static M: std::sync::OnceLock<std::sync::Mutex<()>> = std::sync::OnceLock::new();
    M.get_or_init(|| std::sync::Mutex::new(()))
        .lock()
        .unwrap_or_else(|poison| poison.into_inner())
}

/// A scoped stage timer: created by [`span`] / [`span_named`], records
/// elapsed nanoseconds into its histogram when dropped. While obs is
/// disabled the guard is inert (no clock read on either end).
pub struct SpanGuard {
    hist: Option<&'static Histogram>,
    start_ns: u64,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(h) = self.hist {
            h.record_ns(clock::elapsed_ns(self.start_ns));
        }
    }
}

/// Open a scoped timer on a catalog histogram (the zero-lookup form —
/// instrumented pipeline stages reference their static directly).
#[inline]
pub fn span(hist: &'static Histogram) -> SpanGuard {
    if enabled() {
        SpanGuard {
            hist: Some(hist),
            start_ns: clock::now_ns(),
        }
    } else {
        SpanGuard {
            hist: None,
            start_ns: 0,
        }
    }
}

/// Open a scoped timer by catalog name (`"stage.spmv"`,
/// `"serve.batch"`, …). Unknown names yield an inert guard — a typo
/// can't panic a serving path. Backs the [`crate::span!`] macro.
pub fn span_named(name: &str) -> SpanGuard {
    if !enabled() {
        return SpanGuard {
            hist: None,
            start_ns: 0,
        };
    }
    match metrics::registry().histogram(name) {
        Some(h) => SpanGuard {
            hist: Some(h),
            start_ns: clock::now_ns(),
        },
        None => SpanGuard {
            hist: None,
            start_ns: 0,
        },
    }
}

/// `span!("stage.nee_project")` — scoped stage timer bound to the
/// enclosing block: records into the named catalog histogram when the
/// block exits, a no-op while obs is disabled.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        let _nysx_obs_span = $crate::obs::span_named($name);
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The enable toggle, span recording, and the inert-guard paths.
    /// (Single test so the process-global toggle isn't raced by a
    /// sibling test in this module; other test modules never disable.)
    #[test]
    fn spans_record_only_while_enabled() {
        let _serial = test_toggle_lock();
        let before = metrics::STAGE_TRAIN_FINALIZE.snapshot().count;

        set_enabled(false);
        {
            let _g = span(&metrics::STAGE_TRAIN_FINALIZE);
            let _n = span_named("stage.train_finalize");
        }
        assert_eq!(
            metrics::STAGE_TRAIN_FINALIZE.snapshot().count,
            before,
            "disabled spans must record nothing"
        );

        set_enabled(true);
        assert!(enabled());
        {
            let _g = span(&metrics::STAGE_TRAIN_FINALIZE);
            let _n = span_named("stage.train_finalize");
            let _typo = span_named("stage.no_such_stage"); // inert, no panic
            crate::span!("stage.train_finalize");
        }
        let after = metrics::STAGE_TRAIN_FINALIZE.snapshot().count;
        assert_eq!(after, before + 3, "three live spans must have recorded");
        set_enabled(false);
    }

    #[test]
    fn init_from_env_respects_nysx_obs() {
        // Can't mutate the process env safely under parallel tests;
        // exercise the parse contract through a local mirror of it.
        let parse = |v: Option<&str>| match v {
            Some(v) => !(v.is_empty() || v == "0"),
            None => true,
        };
        assert!(parse(None), "CLI default is on");
        assert!(!parse(Some("0")));
        assert!(!parse(Some("")));
        assert!(parse(Some("1")));
        assert!(parse(Some("yes")));
    }
}
