//! Lock-free metric primitives and the process-wide registry.
//!
//! Everything here is a `static` built from `AtomicU64`s: recording is
//! wait-free (one or two relaxed RMWs), there is no registration step,
//! no allocation, and no lock anywhere on a hot path. The catalog of
//! metric names is fixed at compile time — snapshots iterate a constant
//! table in a deterministic order, which keeps `PROFILE.json` stable
//! across runs of the same workload shape.
//!
//! Latency histograms use a fixed 64-bucket power-of-two layout: bucket
//! `i` covers `[2^i, 2^(i+1))` nanoseconds (bucket 0 also absorbs 0).
//! That spans 1 ns to ~584 years in 64 buckets with ≤ 2× relative
//! error — plenty for stage attribution, and it makes the merge a plain
//! element-wise sum. Counts are striped across [`HIST_SHARDS`]
//! cache-line-separated shards selected by a stable per-thread index,
//! so concurrent lanes don't serialize on one cache line; shards merge
//! at snapshot time.
//!
//! Recording never reads the clock itself — callers time through
//! [`super::clock`] (the lint-confined seam) and hand finished
//! durations in.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

/// Number of buckets in the power-of-two histogram layout (one per
/// possible `u64` leading-zero count).
pub const HIST_BUCKETS: usize = 64;

/// Concurrency stripes per histogram. Threads are assigned a stable
/// stripe round-robin; 8 stripes cover the pool sizes this crate runs
/// at without bloating the static footprint.
pub const HIST_SHARDS: usize = 8;

/// The six pipeline stages every profile artifact must cover, in
/// pipeline order. Histogram names are `"stage.<name>"`.
pub const STAGES: [&str; 6] = [
    "featurize",
    "mph_lookup",
    "spmv",
    "nee_project",
    "sce_match",
    "train_finalize",
];

/// Bucket index of a nanosecond value: `floor(log2(v))`, with 0 mapped
/// to bucket 0. Bucket `i >= 1` covers `[2^i, 2^(i+1))`.
#[inline]
pub fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        63 - v.leading_zeros() as usize
    }
}

/// Inclusive lower bound of a bucket (the percentile estimate returned
/// for any rank landing in it).
#[inline]
pub fn bucket_lower_bound(bucket: usize) -> u64 {
    if bucket == 0 {
        0
    } else {
        1u64 << bucket
    }
}

/// Stable per-thread stripe index: assigned round-robin from a global
/// counter on first use, so a thread always hits the same stripe and
/// two pool lanes rarely share one.
fn stripe_index() -> usize {
    use std::cell::Cell;
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static STRIPE: Cell<usize> = const { Cell::new(usize::MAX) };
    }
    STRIPE.with(|c| {
        let mut i = c.get();
        if i == usize::MAX {
            i = NEXT.fetch_add(1, Ordering::Relaxed) % HIST_SHARDS;
            c.set(i);
        }
        i
    })
}

/// A monotonically increasing event counter.
pub struct Counter {
    name: &'static str,
    value: AtomicU64,
}

impl Counter {
    pub const fn new(name: &'static str) -> Self {
        Self {
            name,
            value: AtomicU64::new(0),
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.value.store(0, Ordering::Relaxed);
    }
}

/// A last-write-wins gauge (configuration facts: thread count, shard
/// count, batch width).
pub struct Gauge {
    name: &'static str,
    value: AtomicU64,
}

impl Gauge {
    pub const fn new(name: &'static str) -> Self {
        Self {
            name,
            value: AtomicU64::new(0),
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    #[inline]
    pub fn set(&self, v: u64) {
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.set(0);
    }
}

/// One stripe of a histogram, padded out so stripes land on distinct
/// cache lines (64 buckets × 8 B = 512 B per stripe already guarantees
/// separation of the bucket arrays; the sum rides along).
struct HistStripe {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum_ns: AtomicU64,
}

impl HistStripe {
    const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const Z: AtomicU64 = AtomicU64::new(0);
        Self {
            buckets: [Z; HIST_BUCKETS],
            sum_ns: AtomicU64::new(0),
        }
    }
}

/// A log2-bucketed latency histogram (layout documented in the module
/// docs). `record_ns` is wait-free; `snapshot` merges the stripes.
pub struct Histogram {
    name: &'static str,
    stripes: [HistStripe; HIST_SHARDS],
}

impl Histogram {
    pub const fn new(name: &'static str) -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const S: HistStripe = HistStripe::new();
        Self {
            name,
            stripes: [S; HIST_SHARDS],
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Record one duration (nanoseconds).
    #[inline]
    pub fn record_ns(&self, v: u64) {
        let s = &self.stripes[stripe_index()];
        s.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        s.sum_ns.fetch_add(v, Ordering::Relaxed);
    }

    /// Merge all stripes into one immutable view.
    pub fn snapshot(&self) -> HistogramSnapshot {
        let mut buckets = [0u64; HIST_BUCKETS];
        let mut sum_ns = 0u64;
        for stripe in &self.stripes {
            for (b, a) in buckets.iter_mut().zip(stripe.buckets.iter()) {
                *b += a.load(Ordering::Relaxed);
            }
            sum_ns = sum_ns.wrapping_add(stripe.sum_ns.load(Ordering::Relaxed));
        }
        let count = buckets.iter().sum();
        HistogramSnapshot {
            name: self.name,
            buckets,
            count,
            sum_ns,
        }
    }

    pub fn reset(&self) {
        for stripe in &self.stripes {
            for a in &stripe.buckets {
                a.store(0, Ordering::Relaxed);
            }
            stripe.sum_ns.store(0, Ordering::Relaxed);
        }
    }
}

/// Merged, immutable view of a [`Histogram`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistogramSnapshot {
    pub name: &'static str,
    pub buckets: [u64; HIST_BUCKETS],
    pub count: u64,
    pub sum_ns: u64,
}

impl HistogramSnapshot {
    /// Nearest-rank percentile estimate: the inclusive lower bound of
    /// the bucket holding the sample of rank `round(p/100 · (n-1))` —
    /// the same rank formula as `coordinator::LatencyStats`, so the
    /// estimate is guaranteed to land in the SAME bucket as the exact
    /// sorted-vector answer (`estimate <= exact < 2·max(estimate, 1)`).
    /// Returns 0 for an empty histogram.
    pub fn percentile_ns(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * (self.count - 1) as f64).round() as u64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum > rank {
                return bucket_lower_bound(i);
            }
        }
        bucket_lower_bound(HIST_BUCKETS - 1)
    }

    /// Lower bound of the highest occupied bucket (0 if empty).
    pub fn max_bucket_lower_ns(&self) -> u64 {
        match self.buckets.iter().rposition(|&c| c > 0) {
            Some(i) => bucket_lower_bound(i),
            None => 0,
        }
    }

    /// Mean in nanoseconds (exact: true sum over count).
    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }
}

// ---------------------------------------------------------------------
// The fixed catalog. Adding a metric = adding a static AND its row in
// the registry arrays below; `Registry::histogram` and the export layer
// pick it up from there.
// ---------------------------------------------------------------------

pub static STAGE_FEATURIZE: Histogram = Histogram::new("stage.featurize");
pub static STAGE_MPH_LOOKUP: Histogram = Histogram::new("stage.mph_lookup");
pub static STAGE_SPMV: Histogram = Histogram::new("stage.spmv");
pub static STAGE_NEE_PROJECT: Histogram = Histogram::new("stage.nee_project");
pub static STAGE_SCE_MATCH: Histogram = Histogram::new("stage.sce_match");
pub static STAGE_TRAIN_FINALIZE: Histogram = Histogram::new("stage.train_finalize");
pub static SERVE_QUEUE: Histogram = Histogram::new("serve.queue");
pub static SERVE_BATCH: Histogram = Histogram::new("serve.batch");
pub static SERVE_SHARD_ROUTE: Histogram = Histogram::new("serve.shard_route");

pub static INFER_REQUESTS: Counter = Counter::new("infer.requests");
pub static INFER_GRAPHS: Counter = Counter::new("infer.graphs");
pub static SERVE_REQUESTS: Counter = Counter::new("serve.requests");
pub static SERVE_BATCHES: Counter = Counter::new("serve.batches");
pub static SERVE_ADMISSION_SHED: Counter = Counter::new("serve.admission_shed");
pub static SERVE_MISATTRIBUTED: Counter = Counter::new("serve.misattributed");

pub static EXEC_THREADS: Gauge = Gauge::new("exec.threads");
pub static SERVE_SHARDS: Gauge = Gauge::new("serve.shards");

/// The process-wide metric catalog: every counter, gauge and histogram,
/// in stable export order.
pub struct Registry {
    pub counters: &'static [&'static Counter],
    pub gauges: &'static [&'static Gauge],
    pub histograms: &'static [&'static Histogram],
}

static REGISTRY: Registry = Registry {
    counters: &[
        &INFER_REQUESTS,
        &INFER_GRAPHS,
        &SERVE_REQUESTS,
        &SERVE_BATCHES,
        &SERVE_ADMISSION_SHED,
        &SERVE_MISATTRIBUTED,
    ],
    gauges: &[&EXEC_THREADS, &SERVE_SHARDS],
    histograms: &[
        &STAGE_FEATURIZE,
        &STAGE_MPH_LOOKUP,
        &STAGE_SPMV,
        &STAGE_NEE_PROJECT,
        &STAGE_SCE_MATCH,
        &STAGE_TRAIN_FINALIZE,
        &SERVE_QUEUE,
        &SERVE_BATCH,
        &SERVE_SHARD_ROUTE,
    ],
};

/// The process-wide registry.
pub fn registry() -> &'static Registry {
    &REGISTRY
}

impl Registry {
    /// Look a histogram up by its catalog name (the `span!` macro path).
    pub fn histogram(&self, name: &str) -> Option<&'static Histogram> {
        self.histograms.iter().find(|h| h.name() == name).copied()
    }

    /// Zero every metric (profiling harness between warmup and the
    /// measured section; tests).
    pub fn reset_all(&self) {
        for c in self.counters {
            c.reset();
        }
        for g in self.gauges {
            g.reset();
        }
        for h in self.histograms {
            h.reset();
        }
        super::lanes::reset_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_layout_boundaries() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(2), 1);
        assert_eq!(bucket_of(3), 1);
        assert_eq!(bucket_of(4), 2);
        assert_eq!(bucket_of(u64::MAX), 63);
        for i in 1..HIST_BUCKETS {
            let lo = bucket_lower_bound(i);
            // Exactly at the boundary lands in bucket i, one below in i-1.
            assert_eq!(bucket_of(lo), i);
            assert_eq!(bucket_of(lo - 1), i - 1);
        }
    }

    /// Exact sorted-vector percentile at the same nearest-rank formula
    /// as `coordinator::LatencyStats::from_samples`.
    fn ref_percentile(samples: &[u64], p: f64) -> u64 {
        if samples.is_empty() {
            return 0;
        }
        let mut xs = samples.to_vec();
        xs.sort_unstable();
        let idx = ((p / 100.0) * (xs.len() - 1) as f64).round() as usize;
        xs[idx.min(xs.len() - 1)]
    }

    fn check_against_reference(name: &str, samples: &[u64]) {
        let h = Histogram::new("test.property");
        for &s in samples {
            h.record_ns(s);
        }
        let snap = h.snapshot();
        assert_eq!(snap.count, samples.len() as u64, "{name}: count");
        assert_eq!(
            snap.sum_ns,
            samples.iter().copied().fold(0u64, u64::wrapping_add),
            "{name}: sum"
        );
        for p in [50.0, 99.0, 99.9] {
            let est = snap.percentile_ns(p);
            let exact = ref_percentile(samples, p);
            assert_eq!(
                bucket_of(est),
                bucket_of(exact),
                "{name}: p{p} estimate {est} not in the exact answer's bucket ({exact})"
            );
            assert!(est <= exact, "{name}: p{p} estimate {est} > exact {exact}");
            let within_2x = est.max(1).checked_mul(2).map_or(true, |ub| exact < ub);
            assert!(within_2x, "{name}: p{p} exact {exact} >= 2x estimate {est}");
        }
        // Every recorded sample lives in the bucket the layout says.
        let mut want = [0u64; HIST_BUCKETS];
        for &s in samples {
            want[bucket_of(s)] += 1;
        }
        assert_eq!(snap.buckets, want, "{name}: bucket contents");
    }

    /// Satellite property test: on the adversarial fixtures from
    /// `coordinator/metrics.rs` (converted µs → ns), the histogram's
    /// p50/p99/p999 estimates land in the same power-of-two bucket as
    /// the exact sorted-vector reference.
    #[test]
    fn percentiles_within_one_bucket_of_exact_reference() {
        // Empty series.
        check_against_reference("empty", &[]);
        let empty = Histogram::new("test.empty").snapshot();
        assert_eq!(
            (empty.count, empty.percentile_ns(50.0), empty.max_bucket_lower_ns()),
            (0, 0, 0)
        );

        // Single sample (42.5 µs).
        check_against_reference("single", &[42_500]);

        // Duplicate-heavy: 980×1.0 µs + 20×100.0 µs.
        let mut dup: Vec<u64> = vec![1_000; 980];
        dup.extend(std::iter::repeat(100_000).take(20));
        check_against_reference("duplicate-heavy", &dup);

        // Out-of-order uniform ramp: i/7.0 µs for i in 0..1000, reversed.
        let mut ramp: Vec<u64> = (0..1000u64)
            .map(|i| (i as f64 / 7.0 * 1000.0) as u64)
            .collect();
        ramp.reverse();
        check_against_reference("out-of-order-ramp", &ramp);

        // Tail-separated: 499×1.0 µs + one 1000.0 µs outlier.
        let mut tail: Vec<u64> = vec![1_000; 499];
        tail.push(1_000_000);
        check_against_reference("tail-separated", &tail);

        // Boundary adversary: exact powers of two and their neighbors.
        let mut pow: Vec<u64> = Vec::new();
        for b in [1u64, 2, 4, 1 << 10, 1 << 20, 1 << 40] {
            pow.extend([b - 1, b, b + 1]);
        }
        pow.push(0);
        pow.push(u64::MAX);
        check_against_reference("power-of-two-boundaries", &pow);
    }

    #[test]
    fn histogram_merges_stripes_from_many_threads() {
        static H: Histogram = Histogram::new("test.threads");
        let threads: Vec<_> = (0..8)
            .map(|t| {
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        H.record_ns(t * 1_000 + i);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let snap = H.snapshot();
        assert_eq!(snap.count, 8_000);
        let want_sum: u64 = (0..8u64)
            .flat_map(|t| (0..1000u64).map(move |i| t * 1_000 + i))
            .sum();
        assert_eq!(snap.sum_ns, want_sum);
        H.reset();
        assert_eq!(H.snapshot().count, 0);
    }

    #[test]
    fn counters_gauges_and_catalog_lookup() {
        let c = Counter::new("test.counter");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        c.reset();
        assert_eq!(c.get(), 0);

        let g = Gauge::new("test.gauge");
        g.set(7);
        g.set(3);
        assert_eq!(g.get(), 3);

        let reg = registry();
        for stage in STAGES {
            let name = format!("stage.{stage}");
            assert!(
                reg.histogram(&name).is_some(),
                "stage histogram {name} missing from the catalog"
            );
        }
        assert!(reg.histogram("no.such.metric").is_none());
        // Catalog names are unique (export keys collide otherwise).
        let mut names: Vec<&str> = reg
            .histograms
            .iter()
            .map(|h| h.name())
            .chain(reg.counters.iter().map(|c| c.name()))
            .chain(reg.gauges.iter().map(|g| g.name()))
            .collect();
        let n = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), n, "duplicate metric names in the catalog");
    }
}
