//! Snapshot + export: one coherent view of every metric, serialized to
//! the `PROFILE.json` building blocks and to Prometheus text
//! exposition format.
//!
//! A [`Snapshot`] is a point-in-time merge of the whole registry
//! (counters, gauges, histograms, labeled lane sites). The JSON shape
//! here is the reusable core — `bench::profile` wraps it with run
//! configuration and the `nysx-obs/v1` schema tag, and round-trip
//! validates before anything lands on disk.

use crate::util::json::Json;

use super::lanes::{self, LaneSiteSnapshot};
use super::metrics::{self, HistogramSnapshot};

/// Point-in-time merge of the process-wide registry.
#[derive(Debug, Clone)]
pub struct Snapshot {
    /// Nanoseconds since the obs clock epoch at capture time — the
    /// wall-clock bound for lane busy-time sanity checks
    /// (`sum(busy_ns) <= wall_ns × lanes`).
    pub wall_ns: u64,
    pub counters: Vec<(&'static str, u64)>,
    pub gauges: Vec<(&'static str, u64)>,
    pub histograms: Vec<HistogramSnapshot>,
    pub lanes: Vec<LaneSiteSnapshot>,
}

impl Snapshot {
    /// Capture the current state of every registered metric.
    pub fn capture() -> Self {
        let reg = metrics::registry();
        Self {
            wall_ns: super::clock::now_ns(),
            counters: reg.counters.iter().map(|c| (c.name(), c.get())).collect(),
            gauges: reg.gauges.iter().map(|g| (g.name(), g.get())).collect(),
            histograms: reg.histograms.iter().map(|h| h.snapshot()).collect(),
            lanes: lanes::SITES.iter().map(|s| s.snapshot()).collect(),
        }
    }

    /// The snapshot body shared by every profile artifact (stable key
    /// order via `Json`'s BTreeMap objects).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("wall_ns", Json::num(self.wall_ns as f64)),
            (
                "counters",
                Json::Obj(
                    self.counters
                        .iter()
                        .map(|(k, v)| (k.to_string(), Json::num(*v as f64)))
                        .collect(),
                ),
            ),
            (
                "gauges",
                Json::Obj(
                    self.gauges
                        .iter()
                        .map(|(k, v)| (k.to_string(), Json::num(*v as f64)))
                        .collect(),
                ),
            ),
            (
                "histograms",
                Json::Obj(
                    self.histograms
                        .iter()
                        .map(|h| (h.name.to_string(), hist_json(h)))
                        .collect(),
                ),
            ),
            (
                "lanes",
                Json::Obj(
                    self.lanes
                        .iter()
                        .map(|l| (l.name.to_string(), lane_json(l)))
                        .collect(),
                ),
            ),
        ])
    }

    /// Prometheus text exposition (the `--prom-out` /
    /// `api::snapshot_prometheus` surface). Histograms emit cumulative
    /// `_bucket{le=...}` series up to the highest occupied bucket, then
    /// `+Inf`, `_sum` and `_count`, per the exposition format.
    pub fn prometheus(&self) -> String {
        let mut out = String::new();
        for (name, v) in &self.counters {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} counter\n{n} {v}\n"));
        }
        for (name, v) in &self.gauges {
            let n = prom_name(name);
            out.push_str(&format!("# TYPE {n} gauge\n{n} {v}\n"));
        }
        for h in &self.histograms {
            let n = format!("{}_ns", prom_name(h.name));
            out.push_str(&format!("# TYPE {n} histogram\n"));
            let top = h.buckets.iter().rposition(|&c| c > 0);
            let mut cum = 0u64;
            if let Some(top) = top {
                for (i, &c) in h.buckets.iter().enumerate().take(top + 1) {
                    cum += c;
                    // Upper bound of bucket i is 2^(i+1) - 1 inclusive;
                    // Prometheus `le` is inclusive, so that's the label.
                    let le = if i + 1 >= 64 {
                        u64::MAX
                    } else {
                        (1u64 << (i + 1)) - 1
                    };
                    out.push_str(&format!("{n}_bucket{{le=\"{le}\"}} {cum}\n"));
                }
            }
            out.push_str(&format!("{n}_bucket{{le=\"+Inf\"}} {}\n", h.count));
            out.push_str(&format!("{n}_sum {}\n", h.sum_ns));
            out.push_str(&format!("{n}_count {}\n", h.count));
        }
        for l in &self.lanes {
            let n = prom_name(l.name);
            out.push_str(&format!(
                "# TYPE {n}_lane_busy_ns counter\n# TYPE {n}_imbalance gauge\n"
            ));
            for (lane, busy) in l.busy_ns.iter().enumerate() {
                out.push_str(&format!("{n}_lane_busy_ns{{lane=\"{lane}\"}} {busy}\n"));
            }
            out.push_str(&format!("{n}_imbalance {}\n", l.imbalance()));
        }
        out
    }
}

fn hist_json(h: &HistogramSnapshot) -> Json {
    Json::obj(vec![
        ("count", Json::num(h.count as f64)),
        ("sum_ns", Json::num(h.sum_ns as f64)),
        ("mean_ns", Json::num(h.mean_ns())),
        ("p50_ns", Json::num(h.percentile_ns(50.0) as f64)),
        ("p99_ns", Json::num(h.percentile_ns(99.0) as f64)),
        ("p999_ns", Json::num(h.percentile_ns(99.9) as f64)),
        ("max_bucket_lower_ns", Json::num(h.max_bucket_lower_ns() as f64)),
        (
            "buckets",
            Json::arr(h.buckets.iter().map(|&c| Json::num(c as f64))),
        ),
    ])
}

fn lane_json(l: &LaneSiteSnapshot) -> Json {
    Json::obj(vec![
        ("runs", Json::num(l.runs as f64)),
        ("lanes", Json::num(l.lanes as f64)),
        (
            "busy_ns",
            Json::arr(l.busy_ns.iter().map(|&b| Json::num(b as f64))),
        ),
        (
            "parts",
            Json::arr(l.parts.iter().map(|&p| Json::num(p as f64))),
        ),
        ("imbalance", Json::num(l.imbalance())),
    ])
}

/// Metric-name sanitizer for the Prometheus exposition format:
/// `[a-zA-Z0-9_:]` stays, everything else (the catalog's `.`) becomes
/// `_`, and the whole thing gets the `nysx_` namespace prefix.
fn prom_name(name: &str) -> String {
    let body: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == ':' { c } else { '_' })
        .collect();
    format!("nysx_{body}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_json_round_trips_and_covers_the_catalog() {
        metrics::STAGE_SPMV.record_ns(1_234);
        metrics::INFER_REQUESTS.inc();
        lanes::SITE_SPMV_SCHEDULED.record_run(2);
        lanes::SITE_SPMV_SCHEDULED.record_lane(0, 500, 3);
        lanes::SITE_SPMV_SCHEDULED.record_lane(1, 700, 3);

        let snap = Snapshot::capture();
        let doc = snap.to_json();
        let text = doc.to_string();
        let back = Json::parse(&text).expect("snapshot JSON must parse");
        assert_eq!(back, doc, "snapshot JSON round-trip drift");

        // Every stage histogram is present under histograms.stage.<name>.
        let hists = doc.get("histograms").expect("histograms key");
        for stage in metrics::STAGES {
            assert!(
                hists.get(&format!("stage.{stage}")).is_some(),
                "stage.{stage} missing from snapshot JSON"
            );
        }
        let spmv = hists.get("stage.spmv").unwrap();
        assert!(spmv.get("count").unwrap().as_f64().unwrap() >= 1.0);
        let lanes_obj = doc.get("lanes").expect("lanes key");
        let sched = lanes_obj.get("spmv.nnz_row_groups").expect("scheduled site");
        assert!(sched.get("imbalance").unwrap().as_f64().unwrap() >= 1.0);
    }

    #[test]
    fn prometheus_exposition_shape() {
        metrics::STAGE_SCE_MATCH.record_ns(5);
        let text = Snapshot::capture().prometheus();
        assert!(text.contains("# TYPE nysx_stage_sce_match_ns histogram"));
        assert!(text.contains("nysx_stage_sce_match_ns_count"));
        assert!(text.contains("nysx_stage_sce_match_ns_bucket{le=\"+Inf\"}"));
        assert!(text.contains("# TYPE nysx_serve_shards gauge"));
        assert!(text.contains("# TYPE nysx_infer_requests counter"));
        // Dots sanitized, every line is name<space>value or a comment.
        for line in text.lines() {
            assert!(
                line.starts_with("# ") || line.split(' ').count() == 2,
                "malformed exposition line: {line:?}"
            );
        }
    }
}
