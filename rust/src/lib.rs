//! # NysX — Nyström-HDC graph classification, reproduced end-to-end
//!
//! A three-layer Rust + JAX + Pallas reproduction of *"NysX: An Accurate
//! and Energy-Efficient FPGA Accelerator for Hyperdimensional Graph
//! Classification at the Edge"*:
//!
//! * **L3 (this crate)** — the serving coordinator, the full training and
//!   inference pipelines, every algorithmic substrate (propagation kernel,
//!   DPP landmark selection, minimal-perfect-hash lookup, load-balanced
//!   SpMV), and a cycle-approximate model of the paper's six-engine FPGA
//!   accelerator.
//! * **L2 (python/compile/model.py)** — the same inference graph in JAX,
//!   AOT-lowered to HLO text consumed by [`runtime`].
//! * **L1 (python/compile/kernels/)** — the Nyström-encoding hot spot as a
//!   Pallas kernel fused into the L2 graph.
//!
//! Start at [`api`] — the typed front door: `Pipeline` builds and trains
//! (or loads) a model, `TrainedPipeline` owns it together with a packed
//! engine, and the `Classifier` trait drives any backend (optimized
//! engine, i8 oracle, GraphHD/NysHD baselines, the live serving stack)
//! through one interface. `DESIGN.md` at the repository root holds the
//! system inventory and the paper-vs-measured record.

pub mod analysis;
pub mod api;
pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod exec;
pub mod graph;
pub mod infer;
pub mod model;
pub mod hdc;
pub mod kernel;
pub mod linalg;
pub mod mph;
pub mod nystrom;
pub mod obs;
pub mod runtime;
pub mod sim;
pub mod succinct;
pub mod testing;
pub mod sparse;
pub mod util;
