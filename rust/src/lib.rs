//! # NysX — Nyström-HDC graph classification, reproduced end-to-end
//!
//! A three-layer Rust + JAX + Pallas reproduction of *"NysX: An Accurate
//! and Energy-Efficient FPGA Accelerator for Hyperdimensional Graph
//! Classification at the Edge"*:
//!
//! * **L3 (this crate)** — the serving coordinator, the full training and
//!   inference pipelines, every algorithmic substrate (propagation kernel,
//!   DPP landmark selection, minimal-perfect-hash lookup, load-balanced
//!   SpMV), and a cycle-approximate model of the paper's six-engine FPGA
//!   accelerator.
//! * **L2 (python/compile/model.py)** — the same inference graph in JAX,
//!   AOT-lowered to HLO text consumed by [`runtime`].
//! * **L1 (python/compile/kernels/)** — the Nyström-encoding hot spot as a
//!   Pallas kernel fused into the L2 graph.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod baselines;
pub mod bench;
pub mod coordinator;
pub mod graph;
pub mod infer;
pub mod model;
pub mod hdc;
pub mod kernel;
pub mod linalg;
pub mod mph;
pub mod nystrom;
pub mod runtime;
pub mod sim;
pub mod testing;
pub mod sparse;
pub mod util;
