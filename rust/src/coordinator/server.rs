//! The serving leader: spawns the worker pool, owns the router and the
//! response fan-in, exposes submit/drain/shutdown.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use super::batcher::{BatchQueue, BatcherConfig, PushError};
use super::metrics::MetricsRegistry;
use super::router::{Router, RoutingPolicy};
use super::worker::worker_loop;
use super::{Request, Response};
use crate::graph::Graph;
use crate::model::NysHdcModel;
use crate::sim::{AcceleratorConfig, PowerModel};

/// Why a submission was rejected. Mirrors [`PushError`] at the serving
/// API surface: `Backpressure` is retryable (drain a response, resubmit),
/// `Closed` is terminal (the stack is shutting down — resubmitting can
/// never succeed). Both hand the query graph back.
#[derive(Debug)]
pub enum SubmitError {
    /// Worker queue at capacity — retry after draining.
    Backpressure(Graph),
    /// Serving stack shut down — give up.
    Closed(Graph),
}

impl SubmitError {
    /// Take the rejected query graph back, whatever the reason.
    pub fn into_graph(self) -> Graph {
        match self {
            SubmitError::Backpressure(g) | SubmitError::Closed(g) => g,
        }
    }

    pub fn is_closed(&self) -> bool {
        matches!(self, SubmitError::Closed(_))
    }
}

/// Why a whole-batch submission was rejected (see [`Server::submit_batch`]):
/// the entire batch comes back — group submission is all-or-nothing.
#[derive(Debug)]
pub enum SubmitBatchError {
    /// The chosen worker queue cannot take the batch right now — retry
    /// after draining responses.
    Backpressure(Vec<Graph>),
    /// Serving stack shut down — give up.
    Closed(Vec<Graph>),
}

impl SubmitBatchError {
    /// Take the rejected batch back, whatever the reason.
    pub fn into_graphs(self) -> Vec<Graph> {
        match self {
            SubmitBatchError::Backpressure(gs) | SubmitBatchError::Closed(gs) => gs,
        }
    }

    pub fn is_closed(&self) -> bool {
        matches!(self, SubmitBatchError::Closed(_))
    }
}

/// Server configuration.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub workers: usize,
    pub routing: RoutingPolicy,
    pub batcher: BatcherConfig,
    pub accel: AcceleratorConfig,
    pub power: PowerModel,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            routing: RoutingPolicy::SizeAware,
            batcher: BatcherConfig::default(),
            accel: AcceleratorConfig::zcu104(),
            power: PowerModel::default(),
        }
    }
}

/// A running server.
pub struct Server {
    router: Arc<Router>,
    workers: Vec<JoinHandle<()>>,
    /// `Some` when this server owns its own response fan-in (standalone
    /// mode). `None` in shard mode — workers then send into a sink shared
    /// across shards, and the [`super::ShardedServer`] front end drains it.
    responses: Option<Receiver<Response>>,
    _response_tx: Sender<Response>,
    pub metrics: Arc<MetricsRegistry>,
    next_id: u64,
    /// Request-id step between consecutive submissions. 1 standalone;
    /// the shard count in shard mode, where shard `i` issues the strided
    /// sequence `i, i+S, i+2S, …` — globally unique without coordination,
    /// and the front end recovers the owning shard as `id % S`.
    id_stride: u64,
    outstanding: usize,
    /// The batcher's dispatch width (callers chunk batch submissions to
    /// this so each group pops as one blocked SCE dispatch).
    batch_size: usize,
    /// Per-worker queue capacity (the hard ceiling on one atomic
    /// `submit_batch`).
    queue_capacity: usize,
}

impl Server {
    /// Validate the configuration and spawn the worker pool. This is the
    /// user-input boundary: a zero worker count or a zero batch size is
    /// a typed [`crate::api::NysxError::Config`] error, not an assert.
    /// (A zero-capacity queue stays legal — it makes every submit
    /// immediate backpressure, which the tests rely on.)
    pub fn try_start(
        model: Arc<NysHdcModel>,
        cfg: ServerConfig,
    ) -> Result<Self, crate::api::NysxError> {
        Self::try_start_with_pool(model, cfg, crate::exec::global())
    }

    /// [`Self::try_start`] with an explicit exec pool for the workers'
    /// engines — how [`crate::api::TrainedPipeline::serve`] propagates
    /// its `Pipeline::threads(n)` pool onto the serving path.
    pub fn try_start_with_pool(
        model: Arc<NysHdcModel>,
        cfg: ServerConfig,
        exec_pool: Arc<crate::exec::Pool>,
    ) -> Result<Self, crate::api::NysxError> {
        Self::validate(&cfg)?;
        let (tx, rx) = channel();
        Self::spawn(model, cfg, exec_pool, tx, Some(rx), 0, 1)
    }

    /// Start one shard of a [`super::ShardedServer`]: workers send their
    /// responses into the shared `sink` instead of a private channel, and
    /// request ids come from the strided sequence `id_base, id_base +
    /// id_stride, …` so they are globally unique across shards without
    /// coordination. [`Server::recv`]/[`Server::drain`] return nothing in
    /// this mode — the front end owns the fan-in.
    pub fn try_start_shard(
        model: Arc<NysHdcModel>,
        cfg: ServerConfig,
        exec_pool: Arc<crate::exec::Pool>,
        sink: Sender<Response>,
        id_base: u64,
        id_stride: u64,
    ) -> Result<Self, crate::api::NysxError> {
        use crate::api::NysxError;
        Self::validate(&cfg)?;
        if id_stride == 0 {
            return Err(NysxError::config("shard id_stride must be > 0"));
        }
        Self::spawn(model, cfg, exec_pool, sink, None, id_base, id_stride)
    }

    /// The shared user-input boundary for every constructor.
    fn validate(cfg: &ServerConfig) -> Result<(), crate::api::NysxError> {
        use crate::api::NysxError;
        if cfg.workers == 0 {
            return Err(NysxError::config("ServerConfig.workers must be > 0"));
        }
        if cfg.workers > 4096 {
            return Err(NysxError::Config(format!(
                "ServerConfig.workers = {} is beyond any plausible host",
                cfg.workers
            )));
        }
        if cfg.batcher.batch_size == 0 {
            return Err(NysxError::config("BatcherConfig.batch_size must be > 0"));
        }
        Ok(())
    }

    /// [`Self::try_start`] for infallible configs; panics on invalid
    /// ones. Prefer `try_start` (or the [`crate::api::TrainedPipeline::serve`]
    /// facade) anywhere the config comes from user input.
    pub fn start(model: Arc<NysHdcModel>, cfg: ServerConfig) -> Self {
        match Self::try_start(model, cfg) {
            Ok(server) => server,
            // nysx-lint: allow(no-panic-in-serving): documented panicking convenience wrapper; fallible callers use try_start
            Err(e) => panic!("{e}"),
        }
    }

    /// Spawn the (already validated) worker pool, wiring responses into
    /// `tx` (private channel standalone, shared sink in shard mode). OS
    /// thread exhaustion is a typed [`crate::api::NysxError::Io`]: the
    /// queues close and every already-spawned worker drains and joins
    /// before the error surfaces, so a partial pool never leaks.
    fn spawn(
        model: Arc<NysHdcModel>,
        cfg: ServerConfig,
        exec_pool: Arc<crate::exec::Pool>,
        tx: Sender<Response>,
        rx: Option<Receiver<Response>>,
        id_base: u64,
        id_stride: u64,
    ) -> Result<Self, crate::api::NysxError> {
        let queues: Vec<Arc<BatchQueue>> = (0..cfg.workers)
            .map(|_| Arc::new(BatchQueue::new(cfg.batcher)))
            .collect();
        let router = Arc::new(Router::new(queues.clone(), cfg.routing));
        let metrics = Arc::new(MetricsRegistry::new(cfg.workers));
        let mut workers = Vec::with_capacity(cfg.workers);
        for (i, queue) in queues.iter().enumerate() {
            let model = model.clone();
            let queue = queue.clone();
            let tx = tx.clone();
            let accel = cfg.accel;
            let power = cfg.power;
            let exec_pool = exec_pool.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("nysx-worker-{i}"))
                .spawn(move || worker_loop(i, model, queue, accel, power, tx, exec_pool));
            match spawned {
                Ok(handle) => workers.push(handle),
                Err(e) => {
                    router.close_all();
                    for w in workers {
                        let _ = w.join();
                    }
                    return Err(crate::api::NysxError::Io(e));
                }
            }
        }
        Ok(Self {
            router,
            workers,
            responses: rx,
            _response_tx: tx,
            metrics,
            next_id: id_base,
            id_stride,
            outstanding: 0,
            batch_size: cfg.batcher.batch_size,
            queue_capacity: cfg.batcher.capacity,
        })
    }

    /// The configured per-dispatch batch width (1 = edge mode).
    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// The configured per-worker queue capacity — batch submitters must
    /// chunk below this or `submit_batch` can never succeed.
    pub fn queue_capacity(&self) -> usize {
        self.queue_capacity
    }

    /// Submit a query graph; returns its request id, or a [`SubmitError`]
    /// handing the graph back — [`SubmitError::Backpressure`] is worth
    /// retrying after draining a response, [`SubmitError::Closed`] is not.
    // The Err variant hands the query graph back by design (no clone on
    // the backpressure path).
    #[allow(clippy::result_large_err)]
    pub fn submit(&mut self, graph: Graph) -> Result<u64, SubmitError> {
        let id = self.next_id;
        let req = Request {
            id,
            graph,
            submitted: Instant::now(),
        };
        match self.router.route(req) {
            Ok(_worker) => {
                self.next_id += self.id_stride;
                self.outstanding += 1;
                Ok(id)
            }
            Err(PushError::Full(req)) => Err(SubmitError::Backpressure(req.graph)),
            Err(PushError::Closed(req)) => Err(SubmitError::Closed(req.graph)),
        }
    }

    /// Submit a whole batch of query graphs as ONE unit: the router
    /// picks a single worker and the batch enqueues atomically on its
    /// queue, so the worker's next `pop_batch` hands the group (bounded
    /// by the batcher's `batch_size`) to one blocked C×W dispatch —
    /// batch-major end to end, instead of scattering the queries across
    /// workers one `submit` at a time. Returns the request ids in
    /// submission order, or hands the whole batch back.
    // The Err hands every graph back by design, like submit().
    #[allow(clippy::result_large_err)]
    pub fn submit_batch(&mut self, graphs: Vec<Graph>) -> Result<Vec<u64>, SubmitBatchError> {
        if graphs.is_empty() {
            return Ok(Vec::new());
        }
        let submitted = Instant::now();
        let count = graphs.len() as u64;
        let reqs: Vec<Request> = graphs
            .into_iter()
            .enumerate()
            .map(|(i, graph)| Request {
                id: self.next_id + i as u64 * self.id_stride,
                graph,
                submitted,
            })
            .collect();
        match self.router.route_batch(reqs) {
            Ok(_worker) => {
                let ids: Vec<u64> = (0..count)
                    .map(|k| self.next_id + k * self.id_stride)
                    .collect();
                self.next_id += count * self.id_stride;
                self.outstanding += ids.len();
                Ok(ids)
            }
            Err(e) => {
                let graphs: Vec<Graph> = e.requests.into_iter().map(|r| r.graph).collect();
                if e.closed {
                    Err(SubmitBatchError::Closed(graphs))
                } else {
                    Err(SubmitBatchError::Backpressure(graphs))
                }
            }
        }
    }

    /// Blocking receive of one response (records metrics). Always `None`
    /// in shard mode — the [`super::ShardedServer`] front end owns the
    /// shared fan-in and records per-shard metrics itself.
    pub fn recv(&mut self) -> Option<Response> {
        if self.outstanding == 0 {
            return None;
        }
        let responses = self.responses.as_ref()?;
        match responses.recv() {
            Ok(resp) => {
                self.outstanding -= 1;
                self.metrics.record(
                    resp.worker,
                    resp.host_us,
                    resp.queue_us,
                    resp.fpga_ms,
                    resp.fpga_mj,
                );
                Some(resp)
            }
            Err(_) => None,
        }
    }

    /// Drain all outstanding responses.
    pub fn drain(&mut self) -> Vec<Response> {
        let mut out = Vec::with_capacity(self.outstanding);
        while self.outstanding > 0 {
            match self.recv() {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }

    /// Close queues and join workers.
    pub fn shutdown(mut self) -> Vec<Response> {
        let rest = self.drain();
        self.close_and_join();
        rest
    }

    /// Close queues and join workers WITHOUT draining responses — the
    /// shard-mode teardown, where the front end owns the response
    /// receiver and has already drained (graceful) or will account for
    /// the in-flight responses itself (fault injection). Closing lets
    /// workers finish every request already queued before they exit, so
    /// nothing in flight is lost; the finished responses are buffered in
    /// the shared channel for the front end to collect.
    pub fn close_and_join(&mut self) {
        self.router.close_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }

    /// Requests submitted to this server that it has not seen answered.
    /// In shard mode the front end does the answering, so this is the
    /// count of ids this shard has issued (the front end keeps the real
    /// outstanding books).
    pub fn outstanding(&self) -> usize {
        self.outstanding
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::tudataset::spec_by_name;
    use crate::infer::NysxEngine;
    use crate::model::train::train;
    use crate::model::ModelConfig;
    use crate::testing::{forall, PropConfig};

    fn small_model() -> (crate::graph::GraphDataset, Arc<NysHdcModel>) {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(81, 0.2);
        let model = Arc::new(train(
            &ds,
            &ModelConfig {
                hops: 2,
                // Off a word boundary: the packed tail word is live in
                // every worker inference below.
                hv_dim: 500,
                num_landmarks: 8,
                ..ModelConfig::default()
            },
        ));
        (ds, model)
    }

    /// The coordinator's end-to-end invariant: every submitted request is
    /// answered exactly once, with the same prediction as the
    /// single-threaded oracle, regardless of worker count / routing
    /// policy. The workers run the bit-packed engine, so the oracle here
    /// is deliberately the *i8* verbatim-Algorithm-1 reference — this
    /// property doubles as the serving-level packed-vs-i8 regression
    /// test. A fast sanity pass first confirms the packed engine agrees
    /// with that oracle single-threaded, so any failure inside the
    /// property isolates to the coordinator.
    #[test]
    fn serving_matches_single_threaded() {
        let (ds, model) = small_model();
        let mut packed_engine = NysxEngine::new(&*model);
        let want: Vec<usize> = ds
            .test
            .iter()
            .map(|(g, _)| {
                let (oracle_pred, oracle_hv) = crate::infer::infer_reference(&model, g);
                let packed = packed_engine.infer(g);
                assert_eq!(packed.predicted, oracle_pred, "packed engine != i8 oracle");
                assert_eq!(packed.hv, oracle_hv.pack(), "packed HV != i8 oracle HV");
                oracle_pred
            })
            .collect();

        forall(
            "serving-equivalence",
            PropConfig {
                cases: 6,
                ..Default::default()
            },
            |rng, _size| {
                let workers = 1 + rng.gen_range(4);
                let policy = match rng.gen_range(3) {
                    0 => RoutingPolicy::RoundRobin,
                    1 => RoutingPolicy::LeastLoaded,
                    _ => RoutingPolicy::SizeAware,
                };
                // batch_size > 1 exercises the blocked batch-major SCE
                // dispatch in the workers; 1 is the paper's edge mode.
                let batch_size = 1 + rng.gen_range(4);
                let mut server = Server::start(
                    model.clone(),
                    ServerConfig {
                        workers,
                        routing: policy,
                        batcher: BatcherConfig {
                            batch_size,
                            max_wait: std::time::Duration::from_millis(2),
                            ..Default::default()
                        },
                        ..Default::default()
                    },
                );
                let mut id_to_graph = Vec::new();
                for (g, _) in ds.test.iter() {
                    let id = server.submit(g.clone()).expect("no backpressure expected");
                    id_to_graph.push(id);
                }
                let responses = server.shutdown();
                crate::prop_assert!(
                    responses.len() == ds.test.len(),
                    "{} responses for {} requests (workers={workers}, {policy:?})",
                    responses.len(),
                    ds.test.len()
                );
                let mut seen = std::collections::HashSet::new();
                for resp in &responses {
                    crate::prop_assert!(seen.insert(resp.id), "duplicate response id {}", resp.id);
                    crate::prop_assert!(
                        resp.predicted == want[resp.id as usize],
                        "prediction mismatch for request {}",
                        resp.id
                    );
                }
                Ok(())
            },
        );
    }

    /// The serving API must tell retryable backpressure apart from
    /// terminal shutdown — the caller's recovery differs.
    #[test]
    fn submit_distinguishes_backpressure_from_shutdown() {
        let (ds, model) = small_model();
        let g = ds.test[0].0.clone();
        // capacity 0: every push is immediate backpressure.
        let mut server = Server::start(
            model,
            ServerConfig {
                workers: 1,
                batcher: BatcherConfig {
                    capacity: 0,
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        match server.submit(g.clone()) {
            Err(e @ SubmitError::Backpressure(_)) => assert!(!e.is_closed()),
            other => panic!("want Backpressure, got {other:?}"),
        }
        // After close, the same submit is terminal — and the graph comes
        // back intact for the caller to reroute elsewhere.
        server.router.close_all();
        match server.submit(g.clone()) {
            Err(e @ SubmitError::Closed(_)) => {
                assert!(e.is_closed());
                let returned = e.into_graph();
                assert_eq!(returned.num_nodes(), g.num_nodes());
            }
            other => panic!("want Closed, got {other:?}"),
        }
        server.shutdown();
    }

    /// The `workers > 0` (and `batch_size > 0`) user-input boundary is a
    /// typed error, not an assert.
    #[test]
    fn try_start_rejects_bad_configs() {
        let (_, model) = small_model();
        let err = Server::try_start(
            model.clone(),
            ServerConfig {
                workers: 0,
                ..Default::default()
            },
        )
        .err()
        .expect("zero workers must be rejected");
        assert!(matches!(err, crate::api::NysxError::Config(_)), "{err}");
        let err = Server::try_start(
            model.clone(),
            ServerConfig {
                batcher: BatcherConfig {
                    batch_size: 0,
                    ..Default::default()
                },
                ..Default::default()
            },
        )
        .err()
        .expect("zero batch size must be rejected");
        assert!(matches!(err, crate::api::NysxError::Config(_)), "{err}");
        // A valid config still starts and shuts down cleanly.
        Server::try_start(model, ServerConfig::default())
            .expect("default config is valid")
            .shutdown();
    }

    /// The batch-major submit path: every batched request is answered
    /// exactly once with oracle predictions, the group actually shares
    /// worker dispatches (batch_size > 1 observed), and backpressure /
    /// shutdown hand the whole batch back.
    #[test]
    fn submit_batch_round_trips_and_batches_dispatch() {
        let (ds, model) = small_model();
        let mut server = Server::start(
            model.clone(),
            ServerConfig {
                workers: 2,
                batcher: BatcherConfig {
                    batch_size: 4,
                    max_wait: std::time::Duration::from_millis(2),
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        assert_eq!(server.batch_size(), 4);
        let graphs: Vec<_> = ds.test.iter().take(8).map(|(g, _)| g.clone()).collect();
        let want: Vec<usize> = graphs
            .iter()
            .map(|g| crate::infer::infer_reference(&model, g).0)
            .collect();
        let ids = server
            .submit_batch(graphs.clone())
            .expect("batch fits default capacity");
        assert_eq!(ids.len(), 8);
        assert_eq!(ids, (0..8).collect::<Vec<u64>>(), "ids in submission order");
        let responses = server.drain();
        assert_eq!(responses.len(), 8);
        let mut batched = 0usize;
        for resp in &responses {
            assert_eq!(
                resp.predicted, want[resp.id as usize],
                "batched prediction != oracle"
            );
            if resp.batch_size > 1 {
                batched += 1;
            }
        }
        assert!(
            batched >= 4,
            "a submit_batch group must share worker dispatches, saw {batched}/8 batched"
        );
        // Empty batch: no-op.
        assert!(server.submit_batch(Vec::new()).unwrap().is_empty());
        // After close: terminal, whole batch handed back.
        server.router.close_all();
        match server.submit_batch(graphs) {
            Err(e @ SubmitBatchError::Closed(_)) => {
                assert!(e.is_closed());
                assert_eq!(e.into_graphs().len(), 8);
            }
            other => panic!("want Closed, got {:?}", other.map(|ids| ids.len())),
        }
        server.shutdown();

        // Zero-capacity queues: retryable backpressure with the batch back.
        let mut tight = Server::start(
            model,
            ServerConfig {
                workers: 1,
                batcher: BatcherConfig {
                    capacity: 0,
                    ..Default::default()
                },
                ..Default::default()
            },
        );
        match tight.submit_batch(vec![ds.test[0].0.clone()]) {
            Err(e @ SubmitBatchError::Backpressure(_)) => {
                assert!(!e.is_closed());
                assert_eq!(e.into_graphs().len(), 1);
            }
            other => panic!("want Backpressure, got {:?}", other.map(|ids| ids.len())),
        }
        tight.shutdown();
    }

    #[test]
    fn metrics_populated() {
        let (ds, model) = small_model();
        let mut server = Server::start(model, ServerConfig::default());
        let count = ds.test.len().min(10);
        for (g, _) in ds.test.iter().take(count) {
            server.submit(g.clone()).unwrap();
        }
        let responses = server.drain();
        assert_eq!(responses.len(), count);
        let summary = server.metrics.summary();
        assert_eq!(summary.requests, count);
        assert!(summary.fpga_ms.mean > 0.0);
        assert!(summary.host_throughput_rps >= 0.0);
        server.shutdown();
    }
}
