//! Bounded batch queue: requests accumulate until `batch_size` are ready
//! or `max_wait` expires (edge mode: batch_size = 1, so every request is
//! dispatched immediately). Mutex + Condvar, no busy-waiting.
//!
//! The partial-batch deadline is anchored to the **oldest queued
//! request's** submission instant (`front().submitted + max_wait`), not
//! to when a popper happens to arrive — so a request's end-to-end queue
//! wait is bounded by `max_wait` plus scheduling slack even when the
//! consumer shows up late.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::{lock_or_poison, Request};

/// Batching policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatcherConfig {
    /// Maximum requests handed to a worker at once.
    pub batch_size: usize,
    /// Maximum time the first queued request may wait for batch-mates.
    pub max_wait: Duration,
    /// Queue capacity; `push` returns [`PushError::Full`] (retryable
    /// backpressure) beyond it.
    pub capacity: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            batch_size: 1, // paper's real-time edge mode
            max_wait: Duration::from_micros(200),
            capacity: 4096,
        }
    }
}

/// Why a push was rejected. The two cases demand different caller
/// behavior: `Full` is retryable backpressure (the queue is live but at
/// capacity — shed load or retry after draining a response), `Closed` is
/// terminal (the queue is shutting down and will never accept the
/// request). Both hand the request back.
#[derive(Debug)]
pub enum PushError {
    /// Queue at capacity — retryable.
    Full(Request),
    /// Queue closed — terminal.
    Closed(Request),
}

impl PushError {
    /// Take the rejected request back, whatever the reason.
    pub fn into_request(self) -> Request {
        match self {
            PushError::Full(req) | PushError::Closed(req) => req,
        }
    }

    pub fn is_closed(&self) -> bool {
        matches!(self, PushError::Closed(_))
    }
}

/// Why a whole-batch push was rejected: the entire batch is handed back
/// (group submission is all-or-nothing — a partial enqueue would tear
/// the batch apart across workers, defeating batch-major dispatch).
#[derive(Debug)]
pub struct PushManyError {
    /// Every request of the rejected batch, in submission order.
    pub requests: Vec<Request>,
    /// Terminal shutdown (`true`) vs retryable backpressure (`false`).
    pub closed: bool,
}

#[derive(Debug, Default)]
struct QueueState {
    items: VecDeque<Request>,
    closed: bool,
}

/// A thread-safe batch queue.
#[derive(Debug)]
pub struct BatchQueue {
    cfg: BatcherConfig,
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl BatchQueue {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self {
            cfg,
            state: Mutex::new(QueueState::default()),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request. The request is handed back inside a
    /// [`PushError`] that distinguishes retryable backpressure
    /// ([`PushError::Full`]) from terminal shutdown ([`PushError::Closed`]).
    // The Err variant carries the whole Request back by design: the
    // caller keeps ownership to retry or reroute without a clone.
    #[allow(clippy::result_large_err)]
    pub fn push(&self, req: Request) -> Result<(), PushError> {
        // A poisoned lock means a worker panicked mid-queue-operation;
        // the queue is unusable, which is exactly what Closed conveys.
        let Some(mut st) = lock_or_poison(&self.state) else {
            return Err(PushError::Closed(req));
        };
        if st.closed {
            return Err(PushError::Closed(req));
        }
        if st.items.len() >= self.cfg.capacity {
            return Err(PushError::Full(req));
        }
        st.items.push_back(req);
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Enqueue a whole batch atomically: all requests land contiguously
    /// under one lock acquisition (so a worker's `pop_batch` can hand
    /// them to ONE blocked C×W dispatch), or none do. Rejection hands
    /// the whole batch back inside a [`PushManyError`].
    pub fn push_many(&self, reqs: Vec<Request>) -> Result<(), PushManyError> {
        if reqs.is_empty() {
            return Ok(());
        }
        let Some(mut st) = lock_or_poison(&self.state) else {
            return Err(PushManyError {
                requests: reqs,
                closed: true,
            });
        };
        if st.closed {
            return Err(PushManyError {
                requests: reqs,
                closed: true,
            });
        }
        if st.items.len() + reqs.len() > self.cfg.capacity {
            return Err(PushManyError {
                requests: reqs,
                closed: false,
            });
        }
        st.items.extend(reqs);
        drop(st);
        self.cv.notify_all();
        Ok(())
    }

    /// Current depth (for least-loaded routing). A poisoned queue reads
    /// as empty — routers must not panic over a dead worker's lock.
    pub fn len(&self) -> usize {
        lock_or_poison(&self.state).map_or(0, |st| st.items.len())
    }

    pub fn is_empty(&self) -> bool {
        lock_or_poison(&self.state).is_none_or(|st| st.items.is_empty())
    }

    /// Blocking pop of the next batch. Returns None after close+drain —
    /// and on a poisoned lock, which a consumer must treat the same way
    /// (the queue state died with the thread that panicked under it).
    pub fn pop_batch(&self) -> Option<Vec<Request>> {
        let mut st = lock_or_poison(&self.state)?;
        loop {
            if st.items.is_empty() {
                if st.closed {
                    return None;
                }
                st = self.cv.wait(st).ok()?;
                continue;
            }
            // Have at least one; maybe wait for batch-mates. The deadline
            // is anchored to the *oldest queued request's* submission
            // instant, not the popper's arrival — a request that already
            // sat in the queue must not be granted a fresh max_wait, or
            // its end-to-end wait could approach 2x the budget. Re-read
            // the front each iteration: a rival popper may have drained
            // the queue, making a younger request the new anchor.
            while st.items.len() < self.cfg.batch_size && !st.closed {
                let deadline = match st.items.front() {
                    Some(oldest) => oldest.submitted + self.cfg.max_wait,
                    None => break, // drained by a rival popper
                };
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                st = self.cv.wait_timeout(st, deadline - now).ok()?.0;
            }
            if st.items.is_empty() {
                continue; // drained by a rival worker; go back to wait
            }
            let take = st.items.len().min(self.cfg.batch_size);
            let batch: Vec<Request> = st.items.drain(..take).collect();
            return Some(batch);
        }
    }

    /// Close the queue: pushes fail, poppers drain then get None. On a
    /// poisoned lock there is nothing to mark — every path already
    /// treats poison as closed — but waiters still get woken.
    pub fn close(&self) {
        if let Some(mut st) = lock_or_poison(&self.state) {
            st.closed = true;
        }
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use std::sync::Arc;

    fn req(id: u64) -> Request {
        Request {
            id,
            graph: Graph::from_edges(2, &[(0, 1)], &[0, 0], 1),
            submitted: Instant::now(),
        }
    }

    #[test]
    fn fifo_order_batch1() {
        let q = BatchQueue::new(BatcherConfig::default());
        for i in 0..5 {
            assert!(q.push(req(i)).is_ok());
        }
        for i in 0..5 {
            let b = q.pop_batch().unwrap();
            assert_eq!(b.len(), 1);
            assert_eq!(b[0].id, i);
        }
        q.close();
        assert!(q.pop_batch().is_none());
    }

    #[test]
    fn batches_form_up_to_size() {
        let q = BatchQueue::new(BatcherConfig {
            batch_size: 4,
            max_wait: Duration::from_millis(1),
            capacity: 100,
        });
        for i in 0..10 {
            q.push(req(i)).unwrap();
        }
        let b1 = q.pop_batch().unwrap();
        assert_eq!(b1.len(), 4);
        let b2 = q.pop_batch().unwrap();
        assert_eq!(b2.len(), 4);
        let b3 = q.pop_batch().unwrap();
        assert_eq!(b3.len(), 2); // max_wait expires, partial batch
    }

    #[test]
    fn backpressure_at_capacity() {
        let q = BatchQueue::new(BatcherConfig {
            batch_size: 1,
            max_wait: Duration::ZERO,
            capacity: 2,
        });
        assert!(q.push(req(0)).is_ok());
        assert!(q.push(req(1)).is_ok());
        assert!(q.push(req(2)).is_err(), "push beyond capacity must fail");
    }

    /// Backpressure and shutdown are different errors: the server retries
    /// the first and must treat the second as terminal.
    #[test]
    fn push_errors_distinguish_full_from_closed() {
        let q = BatchQueue::new(BatcherConfig {
            batch_size: 1,
            max_wait: Duration::ZERO,
            capacity: 1,
        });
        assert!(q.push(req(0)).is_ok());
        match q.push(req(1)) {
            Err(PushError::Full(r)) => {
                assert_eq!(r.id, 1, "Full must hand the request back");
            }
            other => panic!("want Full, got {other:?}"),
        }
        q.close();
        match q.push(req(2)) {
            Err(e @ PushError::Closed(_)) => {
                assert!(e.is_closed());
                assert_eq!(e.into_request().id, 2, "Closed must hand the request back");
            }
            other => panic!("want Closed, got {other:?}"),
        }
        // Closed wins over Full: the queue still holds req 0 (at capacity),
        // but shutdown is the terminal, more informative error.
        match q.push(req(3)) {
            Err(PushError::Closed(_)) => {}
            other => panic!("want Closed after close, got {other:?}"),
        }
    }

    /// Regression (batch-deadline anchoring): the partial-batch deadline
    /// is `oldest.submitted + max_wait`, not `popper arrival + max_wait`.
    /// A consumer that shows up late may only wait out the *remaining*
    /// budget, keeping the oldest request's end-to-end queue wait at
    /// max_wait plus scheduling slack. The pre-fix code granted a fresh
    /// max_wait from popper arrival (~2x end to end) and trips both
    /// assertions below.
    #[test]
    fn max_wait_anchored_to_oldest_request() {
        let max_wait = Duration::from_millis(200);
        let q = BatchQueue::new(BatcherConfig {
            batch_size: 8,
            max_wait,
            capacity: 100,
        });
        let submitted = Instant::now();
        q.push(req(0)).unwrap(); // req() stamps `submitted` with now
        // The consumer arrives after most of the wait budget is gone.
        std::thread::sleep(Duration::from_millis(120));
        let delayed_by = submitted.elapsed();
        let popper_arrived = Instant::now();
        let batch = q.pop_batch().unwrap();
        let popper_waited = popper_arrived.elapsed();
        let end_to_end = submitted.elapsed();
        assert_eq!(batch.len(), 1);

        let slack = Duration::from_millis(100);
        let remaining_budget = max_wait.saturating_sub(delayed_by);
        assert!(
            popper_waited <= remaining_budget + slack,
            "popper waited {popper_waited:?}, but only {remaining_budget:?} of the budget was left"
        );
        // max(delayed_by, max_wait) guards against oversleep on loaded
        // runners: if the consumer itself showed up past the deadline the
        // pop must return immediately.
        assert!(
            end_to_end <= max_wait.max(delayed_by) + slack,
            "oldest request queued for {end_to_end:?}, budget was {max_wait:?}"
        );
    }

    /// Regression (satellite to the anchoring fix above): the front
    /// anchor survives MIXED `push` / `push_many` traffic on one queue.
    /// A single request ages alone, then a group submission joins it
    /// behind the same popper — the deadline must stay pinned to the old
    /// single's submit instant, not re-anchor to the younger group's.
    /// Code that re-read the anchor from the newest arrival (or from the
    /// batch head of the push_many group) would grant a fresh max_wait
    /// here and trip the end-to-end bound.
    #[test]
    fn mixed_single_and_batch_submissions_keep_front_anchor() {
        // Wide deadline relative to the interleave point so an overslept
        // scheduler can't push the group submission past the anchor's
        // deadline (which would legitimately dispatch the single alone).
        let max_wait = Duration::from_millis(400);
        let q = Arc::new(BatchQueue::new(BatcherConfig {
            batch_size: 8,
            max_wait,
            capacity: 100,
        }));
        let submitted = Instant::now();
        q.push(req(0)).unwrap(); // the oldest request: the anchor
        // A popper blocks on the partial batch while the single ages.
        let popper = {
            let q = q.clone();
            std::thread::spawn(move || {
                let popped_at = Instant::now();
                let batch = q.pop_batch().unwrap();
                (batch, popped_at.elapsed())
            })
        };
        // Part of the budget elapses, then a group submission interleaves
        // onto the same queue (still short of batch_size).
        std::thread::sleep(Duration::from_millis(150));
        q.push_many(vec![req(1), req(2)]).unwrap();
        let (batch, popper_waited) = popper.join().unwrap();
        let end_to_end = submitted.elapsed();

        assert_eq!(
            batch.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2],
            "the aged single and the group must dispatch as one batch"
        );
        let slack = Duration::from_millis(100);
        assert!(
            end_to_end <= max_wait + slack,
            "front request queued {end_to_end:?} — deadline re-anchored to the \
             push_many group instead of staying on the aged single ({max_wait:?} budget)"
        );
        // The popper itself must not have waited past the anchor's budget.
        assert!(
            popper_waited <= max_wait + slack,
            "popper blocked {popper_waited:?}, budget was {max_wait:?}"
        );
    }

    /// push_many is atomic: a batch lands contiguously or not at all,
    /// backpressure vs shutdown is distinguished, and a subsequent
    /// pop_batch with a matching batch_size hands the group back whole.
    #[test]
    fn push_many_is_atomic_and_pops_as_one_group() {
        let q = BatchQueue::new(BatcherConfig {
            batch_size: 3,
            max_wait: Duration::from_millis(1),
            capacity: 4,
        });
        q.push_many(vec![req(0), req(1), req(2)]).expect("fits");
        // 3 queued + 2 > capacity 4: rejected whole, nothing enqueued.
        match q.push_many(vec![req(3), req(4)]) {
            Err(e) => {
                assert!(!e.closed, "capacity rejection is retryable");
                assert_eq!(e.requests.len(), 2, "whole batch handed back");
                assert_eq!(e.requests[0].id, 3);
            }
            Ok(()) => panic!("push_many beyond capacity must fail"),
        }
        assert_eq!(q.len(), 3, "rejected batch must not partially enqueue");
        // The accepted group pops as one contiguous batch.
        let batch = q.pop_batch().unwrap();
        assert_eq!(
            batch.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
        // Empty batch is a no-op Ok even at capacity.
        assert!(q.push_many(Vec::new()).is_ok());
        q.close();
        match q.push_many(vec![req(9)]) {
            Err(e) => assert!(e.closed, "closed queue is terminal"),
            Ok(()) => panic!("closed queue must reject"),
        }
    }

    #[test]
    fn close_rejects_and_drains() {
        let q = Arc::new(BatchQueue::new(BatcherConfig::default()));
        q.push(req(1)).unwrap();
        q.close();
        assert!(q.push(req(2)).is_err());
        assert_eq!(q.pop_batch().unwrap()[0].id, 1);
        assert!(q.pop_batch().is_none());
    }

    #[test]
    fn concurrent_producers_consumers_lose_nothing() {
        let q = Arc::new(BatchQueue::new(BatcherConfig {
            batch_size: 3,
            max_wait: Duration::from_micros(50),
            capacity: 10_000,
        }));
        let total = 300u64;
        let mut producers = Vec::new();
        for p in 0..3 {
            let q = q.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..total / 3 {
                    assert!(q.push(req(p * 1000 + i)).is_ok());
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..2 {
            let q = q.clone();
            consumers.push(std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(batch) = q.pop_batch() {
                    seen.extend(batch.into_iter().map(|r| r.id));
                }
                seen
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), total as usize, "requests lost or duplicated");
    }
}
