//! Bounded batch queue: requests accumulate until `batch_size` are ready
//! or `max_wait` expires (edge mode: batch_size = 1, so every request is
//! dispatched immediately). Mutex + Condvar, no busy-waiting.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use super::Request;

/// Batching policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatcherConfig {
    /// Maximum requests handed to a worker at once.
    pub batch_size: usize,
    /// Maximum time the first queued request may wait for batch-mates.
    pub max_wait: Duration,
    /// Queue capacity; `push` returns false (backpressure) beyond it.
    pub capacity: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self {
            batch_size: 1, // paper's real-time edge mode
            max_wait: Duration::from_micros(200),
            capacity: 4096,
        }
    }
}

#[derive(Debug, Default)]
struct QueueState {
    items: VecDeque<Request>,
    closed: bool,
}

/// A thread-safe batch queue.
#[derive(Debug)]
pub struct BatchQueue {
    cfg: BatcherConfig,
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl BatchQueue {
    pub fn new(cfg: BatcherConfig) -> Self {
        Self {
            cfg,
            state: Mutex::new(QueueState::default()),
            cv: Condvar::new(),
        }
    }

    /// Enqueue a request. On backpressure (full or closed) the request
    /// is handed back to the caller as `Err`.
    pub fn push(&self, req: Request) -> Result<(), Request> {
        let mut st = self.state.lock().unwrap();
        if st.closed || st.items.len() >= self.cfg.capacity {
            return Err(req);
        }
        st.items.push_back(req);
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Current depth (for least-loaded routing).
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Blocking pop of the next batch. Returns None after close+drain.
    pub fn pop_batch(&self) -> Option<Vec<Request>> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.items.is_empty() {
                if st.closed {
                    return None;
                }
                st = self.cv.wait(st).unwrap();
                continue;
            }
            // Have at least one; maybe wait for batch-mates.
            if st.items.len() < self.cfg.batch_size && !st.closed {
                let deadline = Instant::now() + self.cfg.max_wait;
                while st.items.len() < self.cfg.batch_size && !st.closed {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    let (guard, timeout) = self.cv.wait_timeout(st, deadline - now).unwrap();
                    st = guard;
                    if timeout.timed_out() {
                        break;
                    }
                }
                if st.items.is_empty() {
                    continue; // drained by a rival worker; go back to wait
                }
            }
            let take = st.items.len().min(self.cfg.batch_size);
            let batch: Vec<Request> = st.items.drain(..take).collect();
            return Some(batch);
        }
    }

    /// Close the queue: pushes fail, poppers drain then get None.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cv.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use std::sync::Arc;

    fn req(id: u64) -> Request {
        Request {
            id,
            graph: Graph::from_edges(2, &[(0, 1)], &[0, 0], 1),
            submitted: Instant::now(),
        }
    }

    #[test]
    fn fifo_order_batch1() {
        let q = BatchQueue::new(BatcherConfig::default());
        for i in 0..5 {
            assert!(q.push(req(i)).is_ok());
        }
        for i in 0..5 {
            let b = q.pop_batch().unwrap();
            assert_eq!(b.len(), 1);
            assert_eq!(b[0].id, i);
        }
        q.close();
        assert!(q.pop_batch().is_none());
    }

    #[test]
    fn batches_form_up_to_size() {
        let q = BatchQueue::new(BatcherConfig {
            batch_size: 4,
            max_wait: Duration::from_millis(1),
            capacity: 100,
        });
        for i in 0..10 {
            q.push(req(i)).unwrap();
        }
        let b1 = q.pop_batch().unwrap();
        assert_eq!(b1.len(), 4);
        let b2 = q.pop_batch().unwrap();
        assert_eq!(b2.len(), 4);
        let b3 = q.pop_batch().unwrap();
        assert_eq!(b3.len(), 2); // max_wait expires, partial batch
    }

    #[test]
    fn backpressure_at_capacity() {
        let q = BatchQueue::new(BatcherConfig {
            batch_size: 1,
            max_wait: Duration::ZERO,
            capacity: 2,
        });
        assert!(q.push(req(0)).is_ok());
        assert!(q.push(req(1)).is_ok());
        assert!(q.push(req(2)).is_err(), "push beyond capacity must fail");
    }

    #[test]
    fn close_rejects_and_drains() {
        let q = Arc::new(BatchQueue::new(BatcherConfig::default()));
        q.push(req(1)).unwrap();
        q.close();
        assert!(q.push(req(2)).is_err());
        assert_eq!(q.pop_batch().unwrap()[0].id, 1);
        assert!(q.pop_batch().is_none());
    }

    #[test]
    fn concurrent_producers_consumers_lose_nothing() {
        let q = Arc::new(BatchQueue::new(BatcherConfig {
            batch_size: 3,
            max_wait: Duration::from_micros(50),
            capacity: 10_000,
        }));
        let total = 300u64;
        let mut producers = Vec::new();
        for p in 0..3 {
            let q = q.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..total / 3 {
                    assert!(q.push(req(p * 1000 + i)).is_ok());
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..2 {
            let q = q.clone();
            consumers.push(std::thread::spawn(move || {
                let mut seen = Vec::new();
                while let Some(batch) = q.pop_batch() {
                    seen.extend(batch.into_iter().map(|r| r.id));
                }
                seen
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        q.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), total as usize, "requests lost or duplicated");
    }
}
