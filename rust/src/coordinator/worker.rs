//! Worker: owns a [`crate::infer::NysxEngine`] bound to the shared model,
//! drains its batch queue, runs the optimized pipeline, and emits
//! responses carrying host wall-clock time plus the cycle-model's
//! simulated FPGA latency/energy.
//!
//! A popped batch of W > 1 requests is dispatched as ONE
//! [`NysxEngine::infer_batch`] call — the per-graph stages share the
//! engine's scratch set and the SCE runs a single blocked C×W popcount
//! matching pass instead of W independent prototype sweeps. Per-request
//! latency metrics survive batching: `queue_us` is always measured from
//! each request's own submission instant, `host_us` becomes the amortized
//! per-request share of the batch wall time, and the simulated FPGA
//! latency/energy come from each request's own trace.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

use super::batcher::BatchQueue;
#[cfg(test)]
use super::Request;
use super::Response;
use crate::exec::Pool;
use crate::graph::Graph;
use crate::infer::NysxEngine;
use crate::model::NysHdcModel;
use crate::sim::{simulate, AcceleratorConfig, PowerModel, SimOptions};

/// Per-worker loop. Runs until the queue closes and drains. The
/// worker's engine dispatches its data-parallel kernels on `exec_pool`
/// — the server passes the pool its `TrainedPipeline` was built with,
/// so `Pipeline::threads(n)` bounds the serving path too.
pub fn worker_loop(
    worker_id: usize,
    model: Arc<NysHdcModel>,
    queue: Arc<BatchQueue>,
    accel: AcceleratorConfig,
    power: PowerModel,
    responses: Sender<Response>,
    exec_pool: Arc<Pool>,
) {
    // The engine takes the Arc itself: worker and engine share ownership
    // of the model for the thread's lifetime.
    let mut engine = NysxEngine::with_pool(model, exec_pool);
    let opts = SimOptions::default();
    while let Some(batch) = queue.pop_batch() {
        let batch_size = batch.len();
        let picked_up = Instant::now();
        let results = if batch_size == 1 {
            vec![engine.infer(&batch[0].graph)]
        } else {
            let graphs: Vec<&Graph> = batch.iter().map(|r| &r.graph).collect();
            engine.infer_batch(&graphs)
        };
        let host_us = picked_up.elapsed().as_secs_f64() * 1e6 / batch_size as f64;
        if crate::obs::enabled() {
            crate::obs::metrics::SERVE_BATCHES.inc();
            crate::obs::metrics::SERVE_REQUESTS.add(batch_size as u64);
            crate::obs::metrics::SERVE_BATCH
                .record_ns(picked_up.elapsed().as_nanos() as u64);
        }
        for (req, result) in batch.into_iter().zip(results) {
            let queue_us = (picked_up - req.submitted).as_secs_f64() * 1e6;
            if crate::obs::enabled() {
                crate::obs::metrics::SERVE_QUEUE.record_ns((queue_us * 1e3) as u64);
            }
            let breakdown = simulate(&result.trace, &accel, opts);
            let energy = power.energy(&breakdown, &accel);
            let resp = Response {
                id: req.id,
                predicted: result.predicted,
                host_us,
                queue_us,
                fpga_ms: energy.time_ms,
                fpga_mj: energy.energy_mj,
                worker: worker_id,
                batch_size,
            };
            if responses.send(resp).is_err() {
                return; // receiver dropped: shut down
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::graph::tudataset::spec_by_name;
    use crate::model::train::train;
    use crate::model::ModelConfig;
    use std::sync::mpsc;

    #[test]
    fn worker_processes_and_exits_on_close() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(71, 0.2);
        let model = Arc::new(train(
            &ds,
            &ModelConfig {
                hops: 2,
                hv_dim: 512,
                num_landmarks: 8,
                ..ModelConfig::default()
            },
        ));
        let queue = Arc::new(BatchQueue::new(BatcherConfig::default()));
        let (tx, rx) = mpsc::channel();
        let handle = {
            let (model, queue) = (model.clone(), queue.clone());
            std::thread::spawn(move || {
                worker_loop(
                    3,
                    model,
                    queue,
                    AcceleratorConfig::zcu104(),
                    PowerModel::default(),
                    tx,
                    crate::exec::global(),
                )
            })
        };
        for (i, (g, _)) in ds.test.iter().take(6).enumerate() {
            queue
                .push(Request {
                    id: i as u64,
                    graph: g.clone(),
                    submitted: Instant::now(),
                })
                .unwrap();
        }
        queue.close();
        handle.join().unwrap();
        let responses: Vec<Response> = rx.iter().collect();
        assert_eq!(responses.len(), 6);
        // Predictions must match a fresh single-threaded engine.
        let mut engine = NysxEngine::new(&*model);
        for resp in &responses {
            let want = engine.infer(&ds.test[resp.id as usize].0).predicted;
            assert_eq!(resp.predicted, want);
            assert_eq!(resp.worker, 3);
            assert_eq!(resp.batch_size, 1, "edge mode is batch-1");
            assert!(resp.fpga_ms > 0.0);
            assert!(resp.fpga_mj > 0.0);
        }
    }

    /// batch_size > 1 dispatches whole batches through the blocked SCE
    /// path; predictions, traces, and per-request metrics must match the
    /// single-query oracle.
    #[test]
    fn worker_batches_match_single_query_oracle() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(73, 0.2);
        let model = Arc::new(train(
            &ds,
            &ModelConfig {
                hops: 2,
                // Off a 64 boundary: live tail word in every batch slot.
                hv_dim: 500,
                num_landmarks: 8,
                ..ModelConfig::default()
            },
        ));
        let queue = Arc::new(BatchQueue::new(BatcherConfig {
            batch_size: 4,
            max_wait: std::time::Duration::from_millis(5),
            capacity: 100,
        }));
        let n = ds.test.len().min(10);
        // Fill and close BEFORE the worker starts: the pops are then
        // deterministic full batches (4, 4, n-8).
        for (i, (g, _)) in ds.test.iter().take(n).enumerate() {
            queue
                .push(Request {
                    id: i as u64,
                    graph: g.clone(),
                    submitted: Instant::now(),
                })
                .unwrap();
        }
        queue.close();
        let (tx, rx) = mpsc::channel();
        let handle = {
            let (model, queue) = (model.clone(), queue.clone());
            std::thread::spawn(move || {
                worker_loop(
                    0,
                    model,
                    queue,
                    AcceleratorConfig::zcu104(),
                    PowerModel::default(),
                    tx,
                    crate::exec::global(),
                )
            })
        };
        handle.join().unwrap();
        let responses: Vec<Response> = rx.iter().collect();
        assert_eq!(responses.len(), n);
        let mut engine = NysxEngine::new(&*model);
        let mut batched_requests = 0usize;
        for resp in &responses {
            let want = engine.infer(&ds.test[resp.id as usize].0).predicted;
            assert_eq!(resp.predicted, want, "batched prediction != oracle");
            assert!(resp.batch_size >= 1 && resp.batch_size <= 4);
            assert!(resp.queue_us >= 0.0);
            assert!(resp.host_us > 0.0);
            assert!(resp.fpga_ms > 0.0);
            if resp.batch_size > 1 {
                batched_requests += 1;
            }
        }
        // Everything except (at most) a final leftover batch of one must
        // have gone through the batched dispatch.
        assert!(
            batched_requests >= n - 1,
            "expected batched dispatches, saw {batched_requests} of {n} requests batched"
        );
    }
}
