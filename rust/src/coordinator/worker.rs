//! Worker: owns a [`crate::infer::NysxEngine`] bound to the shared model,
//! drains its batch queue, runs the optimized pipeline per request, and
//! emits responses carrying host wall-clock time plus the cycle-model's
//! simulated FPGA latency/energy.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

use super::batcher::BatchQueue;
#[cfg(test)]
use super::Request;
use super::Response;
use crate::infer::NysxEngine;
use crate::model::NysHdcModel;
use crate::sim::{simulate, AcceleratorConfig, PowerModel, SimOptions};

/// Per-worker loop. Runs until the queue closes and drains.
pub fn worker_loop(
    worker_id: usize,
    model: Arc<NysHdcModel>,
    queue: Arc<BatchQueue>,
    accel: AcceleratorConfig,
    power: PowerModel,
    responses: Sender<Response>,
) {
    let mut engine = NysxEngine::new(&model);
    let opts = SimOptions::default();
    while let Some(batch) = queue.pop_batch() {
        for req in batch {
            let picked_up = Instant::now();
            let queue_us = (picked_up - req.submitted).as_secs_f64() * 1e6;
            let result = engine.infer(&req.graph);
            let host_us = picked_up.elapsed().as_secs_f64() * 1e6;
            let breakdown = simulate(&result.trace, &accel, opts);
            let energy = power.energy(&breakdown, &accel);
            let resp = Response {
                id: req.id,
                predicted: result.predicted,
                host_us,
                queue_us,
                fpga_ms: energy.time_ms,
                fpga_mj: energy.energy_mj,
                worker: worker_id,
            };
            if responses.send(resp).is_err() {
                return; // receiver dropped: shut down
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::graph::tudataset::spec_by_name;
    use crate::model::train::train;
    use crate::model::ModelConfig;
    use std::sync::mpsc;

    #[test]
    fn worker_processes_and_exits_on_close() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(71, 0.2);
        let model = Arc::new(train(
            &ds,
            &ModelConfig {
                hops: 2,
                hv_dim: 512,
                num_landmarks: 8,
                ..ModelConfig::default()
            },
        ));
        let queue = Arc::new(BatchQueue::new(BatcherConfig::default()));
        let (tx, rx) = mpsc::channel();
        let handle = {
            let (model, queue) = (model.clone(), queue.clone());
            std::thread::spawn(move || {
                worker_loop(
                    3,
                    model,
                    queue,
                    AcceleratorConfig::zcu104(),
                    PowerModel::default(),
                    tx,
                )
            })
        };
        for (i, (g, _)) in ds.test.iter().take(6).enumerate() {
            queue
                .push(Request {
                    id: i as u64,
                    graph: g.clone(),
                    submitted: Instant::now(),
                })
                .unwrap();
        }
        queue.close();
        handle.join().unwrap();
        let responses: Vec<Response> = rx.iter().collect();
        assert_eq!(responses.len(), 6);
        // Predictions must match a fresh single-threaded engine.
        let mut engine = NysxEngine::new(&model);
        for resp in &responses {
            let want = engine.infer(&ds.test[resp.id as usize].0).predicted;
            assert_eq!(resp.predicted, want);
            assert_eq!(resp.worker, 3);
            assert!(resp.fpga_ms > 0.0);
            assert!(resp.fpga_mj > 0.0);
        }
    }
}
