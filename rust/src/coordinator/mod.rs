//! L3 serving coordinator: the edge-inference request path.
//!
//! The paper's deployment model is single-graph, real-time inference on a
//! resource-constrained device; the coordinator wraps the functional
//! accelerator model in a production-shaped serving loop — router →
//! per-worker batch queues → worker pool → response channel — built on
//! std threads + mpsc (no async runtime in the vendored crate set).
//!
//! Each response carries three timings: host wall-clock (this machine),
//! simulated FPGA latency (cycle model) and simulated FPGA energy, so the
//! serving examples and benches report the paper's metrics directly.
//!
//! For heavier traffic the tier scales out horizontally: a
//! [`ShardedServer`] front end owns N independent [`Server`] shards and a
//! consistent-hash front router ([`shard::ShardRing`]) with per-shard
//! admission control — see `sharded` for the topology and DESIGN.md §7.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;
pub mod shard;
pub mod sharded;
pub mod worker;

pub use batcher::{BatchQueue, BatcherConfig, PushError, PushManyError};
pub use metrics::{LatencyStats, MetricsRegistry, MetricsSummary};
pub use router::{Router, RoutingPolicy};
pub use server::{Server, ServerConfig, SubmitBatchError, SubmitError};
pub use shard::ShardRing;
pub use sharded::{ShardedConfig, ShardedServer};

use std::sync::{Mutex, MutexGuard};

use crate::graph::Graph;

/// Lock a mutex without ever panicking on poison: `None` means a worker
/// panicked while holding the lock, so the protected state can no longer
/// be trusted. Every serving-path caller maps `None` onto its closed /
/// degraded surface (a closed queue, an empty metrics rollup) instead of
/// cascading the panic — the no-panic-in-serving invariant (DESIGN.md §8).
pub(crate) fn lock_or_poison<T>(m: &Mutex<T>) -> Option<MutexGuard<'_, T>> {
    m.lock().ok()
}

/// A classification request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    pub graph: Graph,
    /// Submission timestamp.
    pub submitted: std::time::Instant,
}

/// A classification response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub predicted: usize,
    /// Host wall-clock inference time (µs) inside the worker. For a
    /// batched dispatch this is the request's amortized share of the
    /// batch (batch wall time / batch size) — the whole batch went
    /// through one blocked SCE call, so per-request attribution below
    /// that granularity does not exist.
    pub host_us: f64,
    /// Queueing delay before the worker picked the request up (µs),
    /// always measured from this request's own submission instant.
    pub queue_us: f64,
    /// Simulated FPGA latency (ms) from the cycle model.
    pub fpga_ms: f64,
    /// Simulated FPGA energy (mJ).
    pub fpga_mj: f64,
    /// Which worker served it.
    pub worker: usize,
    /// How many requests shared the dispatch that served this one (1 for
    /// edge mode).
    pub batch_size: usize,
}
