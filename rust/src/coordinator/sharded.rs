//! Multi-shard serving front end: N independent [`Server`] shards (each
//! with its own worker pool, engines and `nysx::exec` pool; prototype
//! memory replicated via the shared `Arc<NysHdcModel>`), a consistent-hash
//! front router ([`super::shard::ShardRing`]) mapping each query graph's
//! structural fingerprint to a shard, per-shard admission control that
//! sheds load with typed `Backpressure`, and graceful drain/shutdown that
//! completes every in-flight batch before workers exit.
//!
//! Determinism: sharding only changes WHERE a graph is classified, never
//! the arithmetic — every shard replicates the same model, so results are
//! bit-identical across shard counts (the differential test in
//! `tests/sharded_serving.rs` pins {1,2,4}).
//!
//! Response plumbing: all shards' workers send into ONE shared mpsc sink.
//! Shard `i` issues the strided request-id sequence `i, i+S, i+2S, …`
//! (`S` = shard count at start), so ids are globally unique without
//! coordination and the front end recovers the owning shard of any
//! response as `id % S` — no per-response shard tags, no forwarder
//! threads.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;

use super::metrics::MetricsRegistry;
use super::server::{Server, ServerConfig, SubmitBatchError, SubmitError};
use super::shard::{ShardRing, MAX_SHARDS};
use super::Response;
use crate::graph::Graph;
use crate::model::NysHdcModel;

/// Sharded front-end configuration.
#[derive(Debug, Clone)]
pub struct ShardedConfig {
    /// Number of shards (independent `Server` instances).
    pub shards: usize,
    /// Per-shard cap on in-flight requests. Submissions beyond it are
    /// shed with typed `Backpressure` BEFORE touching the shard's queues,
    /// bounding per-shard memory and queueing delay under overload.
    pub max_outstanding: usize,
    /// Configuration replicated to every shard.
    pub per_shard: ServerConfig,
}

impl Default for ShardedConfig {
    fn default() -> Self {
        Self {
            shards: 2,
            max_outstanding: 1024,
            per_shard: ServerConfig::default(),
        }
    }
}

/// A running sharded serving tier. See the module docs for the topology.
pub struct ShardedServer {
    /// Shard slot `i` holds shard `i`; `None` once stopped.
    slots: Vec<Option<Server>>,
    ring: ShardRing,
    responses: Receiver<Response>,
    _response_tx: Sender<Response>,
    /// Per-shard metrics registries, cloned out of the shards at start so
    /// they outlive [`ShardedServer::stop_shard`].
    metrics: Vec<Arc<MetricsRegistry>>,
    /// Per-shard in-flight counts (the admission-control books).
    outstanding: Vec<usize>,
    total_outstanding: usize,
    max_outstanding: usize,
    /// Request-id stride == shard count at start; `id % stride` is the
    /// owning shard of any response.
    stride: u64,
    batch_size: usize,
    queue_capacity: usize,
}

impl ShardedServer {
    /// Validate and start the tier; every shard gets its OWN exec pool
    /// sized like the global one, so shards never serialize on a shared
    /// work-stealing arena.
    pub fn try_start(
        model: Arc<NysHdcModel>,
        cfg: ShardedConfig,
    ) -> Result<Self, crate::api::NysxError> {
        let threads = crate::exec::global().threads();
        let pools = (0..cfg.shards)
            .map(|_| Arc::new(crate::exec::Pool::new(threads)))
            .collect();
        Self::try_start_with_pools(model, cfg, pools)
    }

    /// [`Self::try_start`] with explicit per-shard exec pools (one per
    /// shard, in shard order) — how the api facade propagates
    /// `Pipeline::threads(n)` sizing, and how tests bound thread counts.
    pub fn try_start_with_pools(
        model: Arc<NysHdcModel>,
        cfg: ShardedConfig,
        pools: Vec<Arc<crate::exec::Pool>>,
    ) -> Result<Self, crate::api::NysxError> {
        use crate::api::NysxError;
        if cfg.shards == 0 {
            return Err(NysxError::config("ShardedConfig.shards must be > 0"));
        }
        if cfg.shards > MAX_SHARDS {
            return Err(NysxError::Config(format!(
                "ShardedConfig.shards = {} exceeds the cap of {MAX_SHARDS}",
                cfg.shards
            )));
        }
        if cfg.max_outstanding == 0 {
            return Err(NysxError::config(
                "ShardedConfig.max_outstanding must be > 0 (0 would reject every submit)",
            ));
        }
        if pools.len() != cfg.shards {
            return Err(NysxError::Config(format!(
                "{} exec pools for {} shards",
                pools.len(),
                cfg.shards
            )));
        }
        let stride = cfg.shards as u64;
        let (tx, rx) = channel();
        let mut slots = Vec::with_capacity(cfg.shards);
        let mut metrics = Vec::with_capacity(cfg.shards);
        for (i, pool) in pools.into_iter().enumerate() {
            let shard = Server::try_start_shard(
                model.clone(),
                cfg.per_shard.clone(),
                pool,
                tx.clone(),
                i as u64,
                stride,
            )?;
            metrics.push(shard.metrics.clone());
            slots.push(Some(shard));
        }
        if crate::obs::enabled() {
            crate::obs::metrics::SERVE_SHARDS.set(cfg.shards as u64);
        }
        Ok(Self {
            slots,
            ring: ShardRing::new(cfg.shards),
            responses: rx,
            _response_tx: tx,
            metrics,
            outstanding: vec![0; cfg.shards],
            total_outstanding: 0,
            max_outstanding: cfg.max_outstanding,
            stride,
            batch_size: cfg.per_shard.batcher.batch_size,
            queue_capacity: cfg.per_shard.batcher.capacity,
        })
    }

    /// Total shard slots (including stopped ones).
    pub fn num_shards(&self) -> usize {
        self.slots.len()
    }

    /// Shards still accepting work.
    pub fn live_shards(&self) -> usize {
        self.ring.len()
    }

    /// The per-shard dispatch batch width (mirrors [`Server::batch_size`]).
    pub fn batch_size(&self) -> usize {
        self.batch_size
    }

    /// Per-worker queue capacity within each shard.
    pub fn queue_capacity(&self) -> usize {
        self.queue_capacity
    }

    /// The per-shard admission cap.
    pub fn max_outstanding(&self) -> usize {
        self.max_outstanding
    }

    /// Requests accepted and not yet collected via `recv`.
    pub fn outstanding(&self) -> usize {
        self.total_outstanding
    }

    /// Per-shard metrics registry (valid even after `stop_shard`).
    pub fn shard_metrics(&self, shard: usize) -> &Arc<MetricsRegistry> {
        &self.metrics[shard]
    }

    /// The shard the front router would pick for `graph` right now.
    pub fn route_of(&self, graph: &Graph) -> Option<usize> {
        if self.ring.is_empty() {
            None
        } else {
            Some(self.ring.shard_for(graph.fingerprint()))
        }
    }

    /// Submit one query graph. The front router hashes the graph's
    /// structural fingerprint onto the shard ring; admission control
    /// sheds with `Backpressure` if that shard is at its in-flight cap; a
    /// shard found closed (stopped underneath us) is dropped from the
    /// ring and the submit reroutes consistently. `Closed` only when no
    /// live shard remains.
    #[allow(clippy::result_large_err)]
    pub fn submit(&mut self, mut graph: Graph) -> Result<u64, SubmitError> {
        loop {
            if self.ring.is_empty() {
                return Err(SubmitError::Closed(graph));
            }
            let shard = {
                let _route = crate::obs::span(&crate::obs::metrics::SERVE_SHARD_ROUTE);
                self.ring.shard_for(graph.fingerprint())
            };
            if self.outstanding[shard] >= self.max_outstanding {
                if crate::obs::enabled() {
                    crate::obs::metrics::SERVE_ADMISSION_SHED.inc();
                }
                return Err(SubmitError::Backpressure(graph));
            }
            let server = match self.slots[shard].as_mut() {
                Some(s) => s,
                None => {
                    // Defensive: a stopped shard should already be off
                    // the ring; drop it and reroute.
                    self.ring.remove(shard as u32);
                    continue;
                }
            };
            match server.submit(graph) {
                Ok(id) => {
                    self.outstanding[shard] += 1;
                    self.total_outstanding += 1;
                    return Ok(id);
                }
                Err(SubmitError::Backpressure(g)) => {
                    return Err(SubmitError::Backpressure(g));
                }
                Err(SubmitError::Closed(g)) => {
                    self.ring.remove(shard as u32);
                    graph = g;
                }
            }
        }
    }

    /// Submit a batch as one unit, routed by the FIRST graph's
    /// fingerprint (a batch is one dispatch group; splitting it across
    /// shards would defeat batch-major execution). All-or-nothing like
    /// [`Server::submit_batch`]; admission control counts the whole
    /// batch against the shard's in-flight cap.
    #[allow(clippy::result_large_err)]
    pub fn submit_batch(&mut self, mut graphs: Vec<Graph>) -> Result<Vec<u64>, SubmitBatchError> {
        if graphs.is_empty() {
            return Ok(Vec::new());
        }
        loop {
            if self.ring.is_empty() {
                return Err(SubmitBatchError::Closed(graphs));
            }
            let shard = {
                let _route = crate::obs::span(&crate::obs::metrics::SERVE_SHARD_ROUTE);
                self.ring.shard_for(graphs[0].fingerprint())
            };
            if self.outstanding[shard] + graphs.len() > self.max_outstanding {
                if crate::obs::enabled() {
                    crate::obs::metrics::SERVE_ADMISSION_SHED.inc();
                }
                return Err(SubmitBatchError::Backpressure(graphs));
            }
            let server = match self.slots[shard].as_mut() {
                Some(s) => s,
                None => {
                    self.ring.remove(shard as u32);
                    continue;
                }
            };
            match server.submit_batch(graphs) {
                Ok(ids) => {
                    self.outstanding[shard] += ids.len();
                    self.total_outstanding += ids.len();
                    return Ok(ids);
                }
                Err(SubmitBatchError::Backpressure(gs)) => {
                    return Err(SubmitBatchError::Backpressure(gs));
                }
                Err(SubmitBatchError::Closed(gs)) => {
                    self.ring.remove(shard as u32);
                    graphs = gs;
                }
            }
        }
    }

    fn account(&mut self, resp: Response) -> Response {
        let shard = (resp.id % self.stride) as usize;
        self.outstanding[shard] -= 1;
        self.total_outstanding -= 1;
        self.metrics[shard].record(
            resp.worker,
            resp.host_us,
            resp.queue_us,
            resp.fpga_ms,
            resp.fpga_mj,
        );
        resp
    }

    /// Blocking receive of one response from any shard (records that
    /// shard's metrics). `None` once nothing is outstanding.
    pub fn recv(&mut self) -> Option<Response> {
        if self.total_outstanding == 0 {
            return None;
        }
        match self.responses.recv() {
            Ok(resp) => Some(self.account(resp)),
            Err(_) => None,
        }
    }

    /// Non-blocking receive — the open-loop load generator polls this
    /// between arrivals so response collection never stalls the arrival
    /// clock.
    pub fn try_recv(&mut self) -> Option<Response> {
        if self.total_outstanding == 0 {
            return None;
        }
        match self.responses.try_recv() {
            Ok(resp) => Some(self.account(resp)),
            Err(_) => None,
        }
    }

    /// Drain every outstanding response. Terminates even if shards were
    /// stopped mid-load: closing a shard's queues lets its workers finish
    /// all queued requests before exiting, so every accepted request has
    /// a response either buffered or on its way.
    pub fn drain(&mut self) -> Vec<Response> {
        let mut out = Vec::with_capacity(self.total_outstanding);
        while self.total_outstanding > 0 {
            match self.recv() {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out
    }

    /// Fault injection / planned topology change: tear down one shard
    /// mid-load. Its queued work still completes (workers drain queues on
    /// close) and stays collectable via `recv`; subsequent submits
    /// consistently reroute around the lost shard (only ~1/N of keys
    /// move). No-op if already stopped or out of range.
    pub fn stop_shard(&mut self, shard: usize) {
        if let Some(mut server) = self.slots.get_mut(shard).and_then(Option::take) {
            self.ring.remove(shard as u32);
            server.close_and_join();
        }
    }

    /// Graceful shutdown: drain every in-flight request to completion,
    /// THEN close queues and join workers shard by shard. Returns the
    /// drained responses — zero loss by construction.
    pub fn shutdown(mut self) -> Vec<Response> {
        let rest = self.drain();
        for slot in self.slots.iter_mut() {
            if let Some(server) = slot.as_mut() {
                server.close_and_join();
            }
        }
        rest
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::graph::tudataset::spec_by_name;
    use crate::model::train::train;
    use crate::model::ModelConfig;

    fn small_model() -> (crate::graph::GraphDataset, Arc<NysHdcModel>) {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(81, 0.2);
        let model = Arc::new(train(
            &ds,
            &ModelConfig {
                hops: 2,
                hv_dim: 500,
                num_landmarks: 8,
                ..ModelConfig::default()
            },
        ));
        (ds, model)
    }

    fn tiny_pools(n: usize) -> Vec<Arc<crate::exec::Pool>> {
        (0..n).map(|_| Arc::new(crate::exec::Pool::new(1))).collect()
    }

    #[test]
    fn try_start_rejects_bad_configs() {
        let (_, model) = small_model();
        for cfg in [
            ShardedConfig {
                shards: 0,
                ..Default::default()
            },
            ShardedConfig {
                shards: MAX_SHARDS + 1,
                ..Default::default()
            },
            ShardedConfig {
                max_outstanding: 0,
                ..Default::default()
            },
        ] {
            let shards = cfg.shards;
            let err = ShardedServer::try_start_with_pools(model.clone(), cfg, tiny_pools(shards))
                .err()
                .expect("bad config must be rejected");
            assert!(matches!(err, crate::api::NysxError::Config(_)), "{err}");
        }
        // Pool-count mismatch is a config error too.
        let err = ShardedServer::try_start_with_pools(
            model.clone(),
            ShardedConfig {
                shards: 2,
                ..Default::default()
            },
            tiny_pools(3),
        )
        .err()
        .expect("pool mismatch must be rejected");
        assert!(matches!(err, crate::api::NysxError::Config(_)), "{err}");
    }

    /// Admission control sheds with retryable Backpressure at the
    /// per-shard in-flight cap, before the request touches a queue.
    #[test]
    fn admission_cap_sheds_with_backpressure() {
        let (ds, model) = small_model();
        let mut tier = ShardedServer::try_start_with_pools(
            model,
            ShardedConfig {
                shards: 1,
                max_outstanding: 2,
                per_shard: ServerConfig {
                    workers: 1,
                    batcher: BatcherConfig {
                        // In-flight bookkeeping is front-end-side (a request
                        // counts until recv), so the cap trips regardless of
                        // how fast the worker drains the queue.
                        batch_size: 8,
                        max_wait: std::time::Duration::from_millis(10),
                        ..Default::default()
                    },
                    ..Default::default()
                },
            },
            tiny_pools(1),
        )
        .unwrap();
        let g = ds.test[0].0.clone();
        tier.submit(g.clone()).expect("below cap");
        tier.submit(g.clone()).expect("at cap boundary");
        match tier.submit(g.clone()) {
            Err(e @ SubmitError::Backpressure(_)) => assert!(!e.is_closed()),
            other => panic!("want Backpressure at the admission cap, got {other:?}"),
        }
        // A batch that would cross the cap is shed whole.
        match tier.submit_batch(vec![g.clone(), g.clone()]) {
            Err(e @ SubmitBatchError::Backpressure(_)) => {
                assert!(!e.is_closed());
                assert_eq!(e.into_graphs().len(), 2);
            }
            other => panic!("want batch Backpressure, got {:?}", other.map(|v| v.len())),
        }
        // Draining frees admission slots; the retry then succeeds.
        let freed = tier.drain();
        assert_eq!(freed.len(), 2, "both in-flight requests must complete");
        tier.submit(g).expect("cap freed after drain");
        assert_eq!(tier.shutdown().len(), 1);
    }

    /// Stopping every shard makes the tier terminally Closed, with the
    /// graph handed back intact.
    #[test]
    fn all_shards_stopped_is_closed() {
        let (ds, model) = small_model();
        let mut tier = ShardedServer::try_start_with_pools(
            model,
            ShardedConfig {
                shards: 2,
                ..Default::default()
            },
            tiny_pools(2),
        )
        .unwrap();
        assert_eq!(tier.num_shards(), 2);
        tier.stop_shard(0);
        tier.stop_shard(0); // idempotent
        assert_eq!(tier.live_shards(), 1);
        tier.stop_shard(1);
        assert_eq!(tier.live_shards(), 0);
        let g = ds.test[0].0.clone();
        match tier.submit(g.clone()) {
            Err(e @ SubmitError::Closed(_)) => {
                assert!(e.is_closed());
                assert_eq!(e.into_graph().num_nodes(), g.num_nodes());
            }
            other => panic!("want Closed with no live shards, got {other:?}"),
        }
        match tier.submit_batch(vec![g]) {
            Err(e @ SubmitBatchError::Closed(_)) => assert!(e.is_closed()),
            other => panic!("want batch Closed, got {:?}", other.map(|v| v.len())),
        }
        assert!(tier.shutdown().is_empty());
    }

    /// The front router is deterministic and stable: the same graph
    /// always routes to the same shard, and `route_of` agrees with where
    /// `submit` actually sends it (via the response's id residue).
    #[test]
    fn routing_is_deterministic_and_observable() {
        let (ds, model) = small_model();
        let mut tier = ShardedServer::try_start_with_pools(
            model,
            ShardedConfig {
                shards: 4,
                per_shard: ServerConfig {
                    workers: 1,
                    batcher: BatcherConfig {
                        max_wait: std::time::Duration::from_micros(50),
                        ..Default::default()
                    },
                    ..Default::default()
                },
                ..Default::default()
            },
            tiny_pools(4),
        )
        .unwrap();
        let mut expected = std::collections::HashMap::new();
        for (g, _) in ds.test.iter().take(12) {
            let want = tier.route_of(g).unwrap();
            assert_eq!(tier.route_of(g), Some(want), "routing must be stable");
            let id = tier.submit(g.clone()).unwrap();
            assert_eq!(
                (id % 4) as usize,
                want,
                "submit landed on a different shard than route_of"
            );
            expected.insert(id, want);
        }
        for resp in tier.shutdown() {
            assert_eq!(
                Some(&((resp.id % 4) as usize)),
                expected.get(&resp.id),
                "response id residue must identify the owning shard"
            );
        }
    }
}
