//! Consistent-hash shard ring: maps request keys onto shard ids so that
//! (a) keys spread near-uniformly across the live shards and (b) removing
//! a shard remaps ONLY the keys that lived on it — every other key keeps
//! its shard, so per-shard working sets (and any future per-shard caches)
//! survive topology changes instead of being reshuffled wholesale.
//!
//! Classic construction: every shard owns [`VNODES_PER_SHARD`] points on
//! a 2^64 ring, placed by a deterministic mix of (shard id, replica). A
//! key hashes to a ring position and is served by the first shard point
//! at or after it (wrapping). A shard's points depend only on its own id,
//! which is what makes removal minimal: surviving shards' points never
//! move, so only arcs previously owned by the removed shard change hands.

/// Ring points per shard. Load imbalance of consistent hashing shrinks
/// like 1/sqrt(vnodes); 256 points keeps the max/mean shard load within
/// a few percent at the shard counts this tier targets (≤ 256).
pub const VNODES_PER_SHARD: usize = 256;

/// Hard cap on shard count — far beyond any plausible host, like the
/// worker cap in [`super::Server`].
pub const MAX_SHARDS: usize = 256;

/// SplitMix64 finalizer: a cheap, well-mixed 64-bit permutation. Both
/// ring points and keys go through it, so callers may pass raw counters
/// or structured fingerprints as keys without worrying about clustering.
#[inline]
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Ring position of replica `r` of shard `s`. Depends only on (s, r):
/// the whole point of the construction.
#[inline]
fn point(shard: u32, replica: u32) -> u64 {
    mix64(((shard as u64) << 32) | replica as u64)
}

/// The consistent-hash ring over a set of live shard ids.
#[derive(Debug, Clone)]
pub struct ShardRing {
    /// (ring position, shard id), sorted by position.
    points: Vec<(u64, u32)>,
    /// Live shard ids, ascending.
    shards: Vec<u32>,
}

impl ShardRing {
    /// Ring over shards `0..num_shards`.
    pub fn new(num_shards: usize) -> Self {
        Self::with_shards((0..num_shards as u32).collect())
    }

    /// Ring over an explicit (possibly sparse) set of shard ids — how the
    /// front end rebuilds after [`ShardRing::remove`], and how the remap
    /// property test constructs the "one shard gone" topology directly.
    pub fn with_shards(mut shards: Vec<u32>) -> Self {
        shards.sort_unstable();
        shards.dedup();
        let mut points = Vec::with_capacity(shards.len() * VNODES_PER_SHARD);
        for &s in &shards {
            for r in 0..VNODES_PER_SHARD as u32 {
                points.push((point(s, r), s));
            }
        }
        points.sort_unstable();
        Self { points, shards }
    }

    /// Live shard ids, ascending.
    pub fn shards(&self) -> &[u32] {
        &self.shards
    }

    pub fn len(&self) -> usize {
        self.shards.len()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.is_empty()
    }

    pub fn contains(&self, shard: u32) -> bool {
        self.shards.binary_search(&shard).is_ok()
    }

    /// The shard serving `key`. Panics on an empty ring — callers check
    /// [`ShardRing::is_empty`] first (an empty tier is typed `Closed` at
    /// the serving surface, not a routing question).
    pub fn shard_for(&self, key: u64) -> usize {
        assert!(!self.points.is_empty(), "shard_for on an empty ring");
        let h = mix64(key);
        let i = self.points.partition_point(|&(p, _)| p < h);
        // Wrap past the last point back to the first (it's a ring).
        let (_, s) = self.points[i % self.points.len()];
        s as usize
    }

    /// Remove a shard (all its ring points at once). Every key previously
    /// served by another shard keeps its shard. No-op if absent.
    pub fn remove(&mut self, shard: u32) {
        if let Ok(i) = self.shards.binary_search(&shard) {
            self.shards.remove(i);
            self.points.retain(|&(_, s)| s != shard);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, PropConfig};

    #[test]
    fn ring_basics() {
        let ring = ShardRing::new(4);
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.shards(), &[0, 1, 2, 3]);
        assert!(ring.contains(2) && !ring.contains(4));
        // Deterministic: the same key always routes to the same shard.
        for key in 0..64u64 {
            assert_eq!(ring.shard_for(key), ring.shard_for(key));
            assert!(ring.shard_for(key) < 4);
        }
        // A single-shard ring routes everything to it.
        let one = ShardRing::new(1);
        for key in 0..64u64 {
            assert_eq!(one.shard_for(key), 0);
        }
    }

    #[test]
    fn remove_is_idempotent_and_empties() {
        let mut ring = ShardRing::new(2);
        ring.remove(0);
        ring.remove(0); // no-op
        assert_eq!(ring.shards(), &[1]);
        assert_eq!(ring.shard_for(123), 1);
        ring.remove(1);
        assert!(ring.is_empty());
    }

    #[test]
    #[should_panic(expected = "empty ring")]
    fn empty_ring_routing_panics() {
        ShardRing::with_shards(Vec::new()).shard_for(7);
    }

    /// Property (satellite): key distribution is near-uniform. With 256
    /// vnodes the arc-length coefficient of variation is ~1/16, so every
    /// shard's share of a large key population stays well inside
    /// [0.5, 1.6]× the fair share.
    #[test]
    fn keys_spread_near_uniformly() {
        forall("ring-uniform", PropConfig::default(), |rng, size| {
            let shards = 2 + rng.gen_range(7); // 2..=8
            let ring = ShardRing::new(shards);
            let keys = 4096 + size * 64;
            let mut per = vec![0usize; shards];
            for _ in 0..keys {
                per[ring.shard_for(rng.next_u64())] += 1;
            }
            let fair = keys as f64 / shards as f64;
            for (s, &count) in per.iter().enumerate() {
                let share = count as f64 / fair;
                crate::prop_assert!(
                    (0.5..=1.6).contains(&share),
                    "shard {s} holds {share:.2}x the fair share ({per:?})"
                );
            }
            Ok(())
        });
    }

    /// Property (satellite): removing one shard remaps ONLY its own keys.
    /// Exact for survivors (their ring points never move), and the moved
    /// fraction is ~1/N of all keys — no full reshuffle.
    #[test]
    fn removal_remaps_only_the_lost_shards_keys() {
        forall("ring-minimal-remap", PropConfig::default(), |rng, size| {
            let shards = 2 + rng.gen_range(7); // 2..=8
            let ring = ShardRing::new(shards);
            let gone = rng.gen_range(shards) as u32;
            let mut survivor = ring.clone();
            survivor.remove(gone);
            // Same topology built directly must agree with remove().
            let rebuilt = ShardRing::with_shards(
                (0..shards as u32).filter(|&s| s != gone).collect(),
            );
            let keys = 2048 + size * 64;
            let mut moved = 0usize;
            let mut on_gone = 0usize;
            for _ in 0..keys {
                let key = rng.next_u64();
                let before = ring.shard_for(key);
                let after = survivor.shard_for(key);
                crate::prop_assert!(
                    after == rebuilt.shard_for(key),
                    "remove() and with_shards() disagree on key {key:#x}"
                );
                crate::prop_assert!(
                    after != gone as usize,
                    "key {key:#x} routed to the removed shard {gone}"
                );
                if before == gone as usize {
                    on_gone += 1;
                    moved += 1; // its shard is gone; it must move
                } else {
                    crate::prop_assert!(
                        after == before,
                        "key {key:#x} moved {before} -> {after} though shard \
                         {before} survived (not a minimal remap)"
                    );
                }
            }
            // The moved set is exactly the removed shard's keys, and that
            // population is ~1/N of the total (generous statistical band).
            crate::prop_assert!(moved == on_gone, "moved {moved} != on_gone {on_gone}");
            let fair = keys as f64 / shards as f64;
            crate::prop_assert!(
                (moved as f64) < 2.0 * fair && (moved as f64) > 0.25 * fair,
                "removed shard owned {moved} of {keys} keys (fair {fair:.0}) — \
                 distribution looks broken"
            );
            Ok(())
        });
    }
}
