//! Serving metrics: latency distributions (host / queue / simulated
//! FPGA), throughput and energy accounting, aggregated across workers.

use std::sync::Mutex;
use std::time::Instant;

use super::lock_or_poison;
use crate::util::{mean, percentile, stddev};

/// Summary statistics over a latency series.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyStats {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    /// Extreme-tail percentile — the serving SLO the load harness sweeps
    /// (BENCH_SERVING.json reports p50/p99/p999 per offered-QPS point).
    pub p999: f64,
    /// Smallest sample (0.0 when the series is empty, matching the
    /// all-zero empty convention of the percentile fields).
    pub min: f64,
    pub max: f64,
}

impl LatencyStats {
    pub fn from_samples(xs: &[f64]) -> Self {
        // Fold from the infinities so genuinely-negative samples (clock
        // skew artifacts) surface instead of being clamped by a 0.0
        // seed; the empty series maps the infinities back to the 0.0
        // convention the consumers (and the empty-registry test) pin.
        let (min, max) = if xs.is_empty() {
            (0.0, 0.0)
        } else {
            (
                xs.iter().cloned().fold(f64::INFINITY, f64::min),
                xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
            )
        };
        Self {
            count: xs.len(),
            mean: mean(xs),
            std: stddev(xs),
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            p99: percentile(xs, 99.0),
            p999: percentile(xs, 99.9),
            min,
            max,
        }
    }
}

#[derive(Debug, Default)]
struct MetricsInner {
    host_us: Vec<f64>,
    queue_us: Vec<f64>,
    fpga_ms: Vec<f64>,
    fpga_mj: Vec<f64>,
    per_worker: Vec<usize>,
    /// Samples whose worker index fell outside `per_worker` — previously
    /// dropped silently, now counted so a mis-sized registry is visible
    /// in the rollup instead of quietly under-reporting a worker.
    misattributed: usize,
    started: Option<Instant>,
    finished: Option<Instant>,
}

/// Thread-safe metrics registry shared by all workers.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    inner: Mutex<MetricsInner>,
}

impl MetricsRegistry {
    pub fn new(workers: usize) -> Self {
        Self {
            inner: Mutex::new(MetricsInner {
                per_worker: vec![0; workers],
                ..Default::default()
            }),
        }
    }

    pub fn record(&self, worker: usize, host_us: f64, queue_us: f64, fpga_ms: f64, fpga_mj: f64) {
        // Metrics degrade gracefully under poison: dropping a sample is
        // strictly better than panicking the worker that reports it.
        let Some(mut m) = lock_or_poison(&self.inner) else {
            return;
        };
        let now = Instant::now();
        m.started.get_or_insert(now);
        m.finished = Some(now);
        m.host_us.push(host_us);
        m.queue_us.push(queue_us);
        m.fpga_ms.push(fpga_ms);
        m.fpga_mj.push(fpga_mj);
        if worker < m.per_worker.len() {
            m.per_worker[worker] += 1;
        } else {
            m.misattributed += 1;
            if crate::obs::enabled() {
                crate::obs::metrics::SERVE_MISATTRIBUTED.inc();
            }
        }
    }

    pub fn summary(&self) -> MetricsSummary {
        // Poisoned registry -> empty rollup (never a panic on the
        // observability path).
        let empty = MetricsInner::default();
        let guard = lock_or_poison(&self.inner);
        let m = guard.as_deref().unwrap_or(&empty);
        let wall_s = match (m.started, m.finished) {
            (Some(a), Some(b)) => (b - a).as_secs_f64(),
            _ => 0.0,
        };
        MetricsSummary {
            requests: m.host_us.len(),
            host_us: LatencyStats::from_samples(&m.host_us),
            queue_us: LatencyStats::from_samples(&m.queue_us),
            fpga_ms: LatencyStats::from_samples(&m.fpga_ms),
            total_fpga_mj: m.fpga_mj.iter().sum(),
            host_throughput_rps: if wall_s > 0.0 {
                m.host_us.len() as f64 / wall_s
            } else {
                0.0
            },
            per_worker: m.per_worker.clone(),
            misattributed: m.misattributed,
        }
    }
}

/// A point-in-time rollup.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSummary {
    pub requests: usize,
    pub host_us: LatencyStats,
    pub queue_us: LatencyStats,
    pub fpga_ms: LatencyStats,
    pub total_fpga_mj: f64,
    pub host_throughput_rps: f64,
    pub per_worker: Vec<usize>,
    /// Samples recorded with an out-of-range worker index (see
    /// [`MetricsRegistry::record`]). Non-zero means a worker-count
    /// mismatch between the registry and its callers.
    pub misattributed: usize,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_and_rollup() {
        let reg = MetricsRegistry::new(2);
        for i in 0..100 {
            reg.record(i % 2, (i + 1) as f64, 1.0, 0.5, 0.4);
        }
        let s = reg.summary();
        assert_eq!(s.requests, 100);
        assert_eq!(s.per_worker, vec![50, 50]);
        assert!((s.host_us.mean - 50.5).abs() < 1e-9);
        assert!(s.host_us.p99 >= s.host_us.p50);
        assert!((s.total_fpga_mj - 40.0).abs() < 1e-9);
    }

    #[test]
    fn empty_registry_safe() {
        let reg = MetricsRegistry::new(1);
        let s = reg.summary();
        assert_eq!(s.requests, 0);
        assert_eq!(s.host_us.count, 0);
        assert_eq!(s.host_throughput_rps, 0.0);
        assert_eq!(s.misattributed, 0);
    }

    /// Satellite: min/max come from a fold over the samples, not a 0.0
    /// seed — an all-negative series must NOT report max = 0.0 (the old
    /// `fold(0.0, f64::max)` fabricated a sample that never happened),
    /// and min must track the smallest sample. Empty stays all-zero.
    #[test]
    fn min_max_track_samples_without_a_zero_seed() {
        let s = LatencyStats::from_samples(&[-5.0, -3.0, -9.5]);
        assert_eq!(s.max, -3.0, "max must be a real sample, not the 0.0 seed");
        assert_eq!(s.min, -9.5);

        let s = LatencyStats::from_samples(&[2.0, 7.0, 4.0]);
        assert_eq!((s.min, s.max), (2.0, 7.0));
        assert!(s.min <= s.p50 && s.p999 <= s.max);

        let empty = LatencyStats::from_samples(&[]);
        assert_eq!((empty.min, empty.max), (0.0, 0.0));
    }

    /// Satellite: samples reported with an out-of-range worker index
    /// are counted, not silently dropped — the rollup surfaces the
    /// mismatch while the latency series still includes the sample.
    #[test]
    fn out_of_range_worker_is_counted_as_misattributed() {
        let reg = MetricsRegistry::new(2);
        reg.record(0, 1.0, 0.1, 0.5, 0.4);
        reg.record(7, 2.0, 0.1, 0.5, 0.4); // no worker 7 in a 2-worker registry
        reg.record(2, 3.0, 0.1, 0.5, 0.4); // one past the end
        let s = reg.summary();
        assert_eq!(s.requests, 3, "latency samples are kept either way");
        assert_eq!(s.per_worker, vec![1, 0]);
        assert_eq!(s.misattributed, 2);
    }

    /// Independent nearest-rank reference: sort a copy (total order) and
    /// index at round(p/100 · (n−1)). This is the documented spec of
    /// `util::percentile`, restated here so a regression in either the
    /// sort or the rank arithmetic shows up as a divergence.
    fn ref_percentile(xs: &[f64], p: f64) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        let mut v = xs.to_vec();
        v.sort_by(f64::total_cmp);
        let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
        v[rank.min(v.len() - 1)]
    }

    fn assert_pinned(xs: &[f64], ctx: &str) {
        let s = LatencyStats::from_samples(xs);
        for (name, got, p) in [
            ("p50", s.p50, 50.0),
            ("p95", s.p95, 95.0),
            ("p99", s.p99, 99.0),
            ("p999", s.p999, 99.9),
        ] {
            let want = ref_percentile(xs, p);
            assert_eq!(got, want, "{ctx}: {name} diverged from sorted-vector reference");
        }
        // Percentiles are monotone in p and drawn from the inputs.
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.p999, "{ctx}: not monotone");
        if !xs.is_empty() {
            for (name, got) in [("p50", s.p50), ("p99", s.p99), ("p999", s.p999)] {
                assert!(
                    xs.contains(&got),
                    "{ctx}: {name}={got} is not an input sample (nearest-rank must not interpolate)"
                );
            }
            assert!(s.p999 <= s.max, "{ctx}: p999 above max");
        }
    }

    /// Satellite: percentile computation pinned against a sorted-vector
    /// reference on adversarial inputs — empty, single sample,
    /// duplicate-heavy, out-of-order arrival.
    #[test]
    fn percentiles_pinned_on_adversarial_inputs() {
        // Empty: all stats are 0 by convention.
        let empty = LatencyStats::from_samples(&[]);
        assert_eq!((empty.count, empty.p50, empty.p999, empty.max), (0, 0.0, 0.0, 0.0));
        assert_pinned(&[], "empty");

        // Single sample: every percentile IS the sample.
        let one = LatencyStats::from_samples(&[42.5]);
        assert_eq!((one.p50, one.p95, one.p99, one.p999, one.max), (42.5, 42.5, 42.5, 42.5, 42.5));
        assert_pinned(&[42.5], "single");

        // Duplicate-heavy: 980 copies of 1.0 and twenty outliers of
        // 100.0. p50/p95 sit in the duplicate mass; p99/p999 must climb
        // into the outlier tail (ranks 989 and 998 of 0..=999) rather
        // than being flattened by the duplicates.
        let mut dup = vec![1.0; 980];
        dup.extend(std::iter::repeat(100.0).take(20));
        assert_pinned(&dup, "duplicate-heavy");
        let s = LatencyStats::from_samples(&dup);
        assert_eq!((s.p50, s.p95), (1.0, 1.0));
        assert_eq!((s.p99, s.p999), (100.0, 100.0));

        // Out-of-order arrival: reversed and interleaved permutations of
        // the same multiset must produce identical stats (percentiles are
        // order-free).
        let sorted: Vec<f64> = (0..1000).map(|i| i as f64 / 7.0).collect();
        let baseline = LatencyStats::from_samples(&sorted);
        let reversed: Vec<f64> = sorted.iter().rev().cloned().collect();
        let interleaved: Vec<f64> = (0..500)
            .flat_map(|i| [sorted[i], sorted[999 - i]])
            .collect();
        for (perm, name) in [(&reversed, "reversed"), (&interleaved, "interleaved")] {
            assert_pinned(perm, name);
            assert_eq!(LatencyStats::from_samples(perm), baseline, "{name}: order leaked into stats");
        }

        // Tail separation: one 1-in-500 outlier. Nearest-rank p999 over
        // 500 samples rounds to the top rank (0.999·499 ≈ 498.5 → 499)
        // while p99 (rank 494) stays in the bulk.
        let mut tail: Vec<f64> = vec![1.0; 499];
        tail.push(1000.0);
        let s = LatencyStats::from_samples(&tail);
        assert_eq!(s.p99, 1.0, "p99 must not see a 1-in-500 outlier");
        assert_eq!(s.p999, 1000.0, "p999 must see a 1-in-500 outlier");
        assert_pinned(&tail, "tail-separation");
    }
}
