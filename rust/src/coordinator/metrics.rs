//! Serving metrics: latency distributions (host / queue / simulated
//! FPGA), throughput and energy accounting, aggregated across workers.

use std::sync::Mutex;
use std::time::Instant;

use crate::util::{mean, percentile, stddev};

/// Summary statistics over a latency series.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencyStats {
    pub count: usize,
    pub mean: f64,
    pub std: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl LatencyStats {
    pub fn from_samples(xs: &[f64]) -> Self {
        Self {
            count: xs.len(),
            mean: mean(xs),
            std: stddev(xs),
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            p99: percentile(xs, 99.0),
            max: xs.iter().cloned().fold(0.0, f64::max),
        }
    }
}

#[derive(Debug, Default)]
struct MetricsInner {
    host_us: Vec<f64>,
    queue_us: Vec<f64>,
    fpga_ms: Vec<f64>,
    fpga_mj: Vec<f64>,
    per_worker: Vec<usize>,
    started: Option<Instant>,
    finished: Option<Instant>,
}

/// Thread-safe metrics registry shared by all workers.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    inner: Mutex<MetricsInner>,
}

impl MetricsRegistry {
    pub fn new(workers: usize) -> Self {
        Self {
            inner: Mutex::new(MetricsInner {
                per_worker: vec![0; workers],
                ..Default::default()
            }),
        }
    }

    pub fn record(&self, worker: usize, host_us: f64, queue_us: f64, fpga_ms: f64, fpga_mj: f64) {
        let mut m = self.inner.lock().unwrap();
        let now = Instant::now();
        m.started.get_or_insert(now);
        m.finished = Some(now);
        m.host_us.push(host_us);
        m.queue_us.push(queue_us);
        m.fpga_ms.push(fpga_ms);
        m.fpga_mj.push(fpga_mj);
        if worker < m.per_worker.len() {
            m.per_worker[worker] += 1;
        }
    }

    pub fn summary(&self) -> MetricsSummary {
        let m = self.inner.lock().unwrap();
        let wall_s = match (m.started, m.finished) {
            (Some(a), Some(b)) => (b - a).as_secs_f64(),
            _ => 0.0,
        };
        MetricsSummary {
            requests: m.host_us.len(),
            host_us: LatencyStats::from_samples(&m.host_us),
            queue_us: LatencyStats::from_samples(&m.queue_us),
            fpga_ms: LatencyStats::from_samples(&m.fpga_ms),
            total_fpga_mj: m.fpga_mj.iter().sum(),
            host_throughput_rps: if wall_s > 0.0 {
                m.host_us.len() as f64 / wall_s
            } else {
                0.0
            },
            per_worker: m.per_worker.clone(),
        }
    }
}

/// A point-in-time rollup.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSummary {
    pub requests: usize,
    pub host_us: LatencyStats,
    pub queue_us: LatencyStats,
    pub fpga_ms: LatencyStats,
    pub total_fpga_mj: f64,
    pub host_throughput_rps: f64,
    pub per_worker: Vec<usize>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_and_rollup() {
        let reg = MetricsRegistry::new(2);
        for i in 0..100 {
            reg.record(i % 2, (i + 1) as f64, 1.0, 0.5, 0.4);
        }
        let s = reg.summary();
        assert_eq!(s.requests, 100);
        assert_eq!(s.per_worker, vec![50, 50]);
        assert!((s.host_us.mean - 50.5).abs() < 1e-9);
        assert!(s.host_us.p99 >= s.host_us.p50);
        assert!((s.total_fpga_mj - 40.0).abs() < 1e-9);
    }

    #[test]
    fn empty_registry_safe() {
        let reg = MetricsRegistry::new(1);
        let s = reg.summary();
        assert_eq!(s.requests, 0);
        assert_eq!(s.host_us.count, 0);
        assert_eq!(s.host_throughput_rps, 0.0);
    }
}
