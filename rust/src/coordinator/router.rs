//! Request router: assigns incoming requests to per-worker queues.
//! Policies: round-robin, least-loaded (queue depth), and size-aware
//! (estimated work = nnz(A), so a DD-sized graph doesn't head-of-line
//! block a MUTAG-sized one).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use super::batcher::{BatchQueue, PushError, PushManyError};
use super::Request;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    RoundRobin,
    LeastLoaded,
    /// Least accumulated estimated work (Σ nnz of queued graphs).
    SizeAware,
}

/// Router over a fixed set of worker queues.
pub struct Router {
    queues: Vec<Arc<BatchQueue>>,
    policy: RoutingPolicy,
    rr_next: AtomicU64,
    /// Outstanding estimated work per worker (SizeAware).
    work: Vec<AtomicU64>,
}

impl Router {
    pub fn new(queues: Vec<Arc<BatchQueue>>, policy: RoutingPolicy) -> Self {
        let n = queues.len();
        assert!(n > 0, "router needs at least one queue");
        Self {
            queues,
            policy,
            rr_next: AtomicU64::new(0),
            work: (0..n).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    pub fn num_workers(&self) -> usize {
        self.queues.len()
    }

    /// Estimated work units of a request (graph nnz + node count).
    fn estimate(req: &Request) -> u64 {
        (req.graph.adj.nnz() + req.graph.num_nodes()) as u64
    }

    /// Pick a worker index for a request.
    pub fn pick(&self, _req: &Request) -> usize {
        match self.policy {
            RoutingPolicy::RoundRobin => {
                (self.rr_next.fetch_add(1, Ordering::Relaxed) as usize) % self.queues.len()
            }
            // `new` asserts at least one queue, so min_by_key is Some;
            // 0 is a correct (never-taken) fallback rather than a panic.
            RoutingPolicy::LeastLoaded => (0..self.queues.len())
                .min_by_key(|&i| self.queues[i].len())
                .unwrap_or(0),
            RoutingPolicy::SizeAware => (0..self.queues.len())
                .min_by_key(|&i| self.work[i].load(Ordering::Relaxed))
                .unwrap_or(0),
        }
    }

    /// Route: returns the chosen worker, or hands the request back inside
    /// a [`PushError`] — `Full` is retryable backpressure (caller decides:
    /// retry, shed, or block), `Closed` means the stack is shutting down.
    // The Err variant hands the Request back by design (no clone on the
    // backpressure path).
    #[allow(clippy::result_large_err)]
    pub fn route(&self, req: Request) -> Result<usize, PushError> {
        let idx = self.pick(&req);
        let est = Self::estimate(&req);
        match self.queues[idx].push(req) {
            Ok(()) => {
                self.work[idx].fetch_add(est, Ordering::Relaxed);
                Ok(idx)
            }
            Err(e) => Err(e),
        }
    }

    /// Route a whole batch to ONE worker queue as a unit (the
    /// batch-major submit path): the worker is picked once — by the
    /// first request under the configured policy — and the batch
    /// enqueues atomically so a single `pop_batch` can dispatch it as
    /// one blocked C×W pass. Returns the chosen worker, or hands the
    /// whole batch back.
    pub fn route_batch(&self, reqs: Vec<Request>) -> Result<usize, PushManyError> {
        let Some(first) = reqs.first() else {
            return Ok(0); // empty batch: nothing enqueued, any index valid
        };
        let idx = self.pick(first);
        let est: u64 = reqs.iter().map(Self::estimate).sum();
        match self.queues[idx].push_many(reqs) {
            Ok(()) => {
                self.work[idx].fetch_add(est, Ordering::Relaxed);
                Ok(idx)
            }
            Err(e) => Err(e),
        }
    }

    /// Worker `idx` reports `est` work completed (SizeAware accounting).
    pub fn complete(&self, idx: usize, req: &Request) {
        let est = Self::estimate(req);
        let _ =
            self.work[idx].fetch_update(Ordering::Relaxed, Ordering::Relaxed, |w| {
                Some(w.saturating_sub(est))
            });
    }

    pub fn close_all(&self) {
        for q in &self.queues {
            q.close();
        }
    }

    pub fn queue(&self, idx: usize) -> &Arc<BatchQueue> {
        &self.queues[idx]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::batcher::BatcherConfig;
    use crate::graph::generators::labeled_graph;
    use crate::testing::{forall, PropConfig};
    use crate::util::rng::Xoshiro256;
    use std::time::Instant;

    fn mk_router(n: usize, policy: RoutingPolicy) -> Router {
        let queues = (0..n)
            .map(|_| {
                Arc::new(BatchQueue::new(BatcherConfig {
                    capacity: 100_000,
                    ..Default::default()
                }))
            })
            .collect();
        Router::new(queues, policy)
    }

    fn mk_req(id: u64, rng: &mut Xoshiro256) -> super::Request {
        let n = 4 + rng.gen_range(30);
        super::super::Request {
            id,
            graph: labeled_graph(n, rng.gen_range(n), 0.2, &[0.6, 0.4], rng),
            submitted: Instant::now(),
        }
    }

    /// Property: every routed request lands in exactly one queue and none
    /// are lost, under every policy.
    #[test]
    fn routing_conserves_requests() {
        for policy in [
            RoutingPolicy::RoundRobin,
            RoutingPolicy::LeastLoaded,
            RoutingPolicy::SizeAware,
        ] {
            forall("routing-conserves", PropConfig::default(), |rng, size| {
                let workers = 1 + rng.gen_range(6);
                let router = mk_router(workers, policy);
                let count = size * 3;
                for id in 0..count as u64 {
                    let req = mk_req(id, rng);
                    crate::prop_assert!(router.route(req).is_ok(), "route rejected");
                }
                router.close_all();
                let mut ids = Vec::new();
                for i in 0..workers {
                    while let Some(batch) = router.queue(i).pop_batch() {
                        ids.extend(batch.into_iter().map(|r| r.id));
                    }
                }
                ids.sort_unstable();
                let want: Vec<u64> = (0..count as u64).collect();
                crate::prop_assert!(ids == want, "lost/duplicated: got {} want {}", ids.len(), count);
                Ok(())
            });
        }
    }

    /// Property: round-robin spreads requests within ±1.
    #[test]
    fn round_robin_balances_exactly() {
        forall("rr-balance", PropConfig::default(), |rng, size| {
            let workers = 1 + rng.gen_range(5);
            let router = mk_router(workers, RoutingPolicy::RoundRobin);
            let count = size * workers;
            let mut per = vec![0usize; workers];
            for id in 0..count as u64 {
                let req = mk_req(id, rng);
                per[router.route(req).unwrap()] += 1;
            }
            let max = *per.iter().max().unwrap();
            let min = *per.iter().min().unwrap();
            crate::prop_assert!(max - min <= 1, "imbalance {per:?}");
            Ok(())
        });
    }

    /// Property: size-aware routing bounds the work skew well below a
    /// single max-size request times worker count.
    #[test]
    fn size_aware_bounds_work_skew() {
        forall("size-aware-skew", PropConfig::default(), |rng, size| {
            let workers = 2 + rng.gen_range(4);
            let router = mk_router(workers, RoutingPolicy::SizeAware);
            let mut per_work = vec![0u64; workers];
            let mut max_est = 0u64;
            for id in 0..(size * 8) as u64 {
                let req = mk_req(id, rng);
                let est = Router::estimate(&req);
                max_est = max_est.max(est);
                let idx = router.route(req).unwrap();
                per_work[idx] += est;
            }
            let max = *per_work.iter().max().unwrap();
            let min = *per_work.iter().min().unwrap();
            crate::prop_assert!(
                max - min <= max_est + 1,
                "work skew {max}-{min} > max item {max_est}"
            );
            Ok(())
        });
    }
}
