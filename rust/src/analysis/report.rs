//! The `LINT_REPORT.json` artifact (schema `nysx-lint/v1`) and its text
//! rendering. Follows the repo's benchmark-artifact convention
//! (`BENCH_*.json`): a `schema` tag, deterministic key order via the
//! in-tree [`Json`] emitter, and a parse-back round trip **plus schema
//! validation** before any bytes land on disk — an ill-formed or
//! self-inconsistent report is a typed error, never an artifact.

use std::collections::BTreeMap;

use crate::api::NysxError;
use crate::util::json::Json;

use super::rules::RULES;

/// Schema tag carried by every emitted report.
pub const SCHEMA: &str = "nysx-lint/v1";

/// One rule violation at a source location.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: String,
    /// Crate-root-relative path with `/` separators.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub message: String,
}

/// One justified suppression pragma — the report inventories every site
/// where an invariant is consciously waived, with its written reason.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PragmaSite {
    pub rule: String,
    pub file: String,
    pub line: usize,
    pub justification: String,
}

/// The full analyzer result over one crate root.
#[derive(Debug)]
pub struct LintReport {
    /// The scanned crate root, as given (display only).
    pub root: String,
    pub files_scanned: usize,
    /// Sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Sorted by (file, line, rule).
    pub pragmas: Vec<PragmaSite>,
}

impl LintReport {
    /// Emit the `nysx-lint/v1` document. Every known rule always appears
    /// under `rules` (with zero counts if silent), so consumers can
    /// index unconditionally.
    pub fn to_json(&self) -> Json {
        // Every known rule gets an entry (zero counts if silent) so
        // consumers can index unconditionally; unknown rule names from
        // pragmas are added too so the counts always sum up.
        let mut per_rule: BTreeMap<&str, (usize, usize)> =
            RULES.iter().map(|r| (*r, (0, 0))).collect();
        for f in &self.findings {
            per_rule.entry(f.rule.as_str()).or_insert((0, 0)).0 += 1;
        }
        for p in &self.pragmas {
            per_rule.entry(p.rule.as_str()).or_insert((0, 0)).1 += 1;
        }
        Json::obj(vec![
            ("schema", Json::str(SCHEMA)),
            ("root", Json::str(self.root.as_str())),
            ("files_scanned", Json::num(self.files_scanned as f64)),
            ("total_findings", Json::num(self.findings.len() as f64)),
            (
                "rules",
                Json::Obj(
                    per_rule
                        .into_iter()
                        .map(|(rule, (nf, np))| {
                            (
                                rule.to_string(),
                                Json::obj(vec![
                                    ("findings", Json::num(nf as f64)),
                                    ("pragmas", Json::num(np as f64)),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
            (
                "findings",
                Json::arr(self.findings.iter().map(|f| {
                    Json::obj(vec![
                        ("rule", Json::str(f.rule.as_str())),
                        ("file", Json::str(f.file.as_str())),
                        ("line", Json::num(f.line as f64)),
                        ("message", Json::str(f.message.as_str())),
                    ])
                })),
            ),
            (
                "pragmas",
                Json::arr(self.pragmas.iter().map(|p| {
                    Json::obj(vec![
                        ("rule", Json::str(p.rule.as_str())),
                        ("file", Json::str(p.file.as_str())),
                        ("line", Json::num(p.line as f64)),
                        ("justification", Json::str(p.justification.as_str())),
                    ])
                })),
            ),
        ])
    }

    /// Human-readable rendering: one `file:line: [rule] message` per
    /// finding, then the pragma inventory and a summary line.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!(
                "{}:{}: [{}] {}\n",
                f.file, f.line, f.rule, f.message
            ));
        }
        if !self.pragmas.is_empty() {
            out.push_str(&format!(
                "{} suppression pragma(s) in force:\n",
                self.pragmas.len()
            ));
            for p in &self.pragmas {
                out.push_str(&format!(
                    "  {}:{}: allow({}) — {}\n",
                    p.file, p.line, p.rule, p.justification
                ));
            }
        }
        out.push_str(&format!(
            "nysx lint: {} finding(s) over {} file(s)\n",
            self.findings.len(),
            self.files_scanned
        ));
        out
    }

    /// Validate an emitted document against its own schema: tag, count
    /// consistency (`total_findings` == findings array length == sum of
    /// per-rule counts, and likewise for pragmas), and the presence of
    /// every rule key. Returns the re-parsed document on success.
    fn validate(&self, text: &str) -> Result<Json, NysxError> {
        let doc = Json::parse(text).map_err(|e| {
            NysxError::Config(format!("emitted LINT_REPORT.json does not parse: {e}"))
        })?;
        let schema_err = |what: &str| NysxError::Config(format!("LINT_REPORT.json: {what}"));
        if doc.get("schema").and_then(Json::as_str) != Some(SCHEMA) {
            return Err(schema_err("wrong or missing schema tag"));
        }
        let total = doc
            .get("total_findings")
            .and_then(Json::as_usize)
            .ok_or_else(|| schema_err("missing total_findings"))?;
        let listed = doc
            .get("findings")
            .and_then(Json::as_arr)
            .ok_or_else(|| schema_err("missing findings array"))?
            .len();
        let pragmas_listed = doc
            .get("pragmas")
            .and_then(Json::as_arr)
            .ok_or_else(|| schema_err("missing pragmas array"))?
            .len();
        let rules_obj = match doc.get("rules") {
            Some(Json::Obj(m)) => m,
            _ => return Err(schema_err("missing rules object")),
        };
        for rule in RULES {
            if !rules_obj.contains_key(rule) {
                return Err(schema_err("missing per-rule entry"));
            }
        }
        let mut rule_findings = 0usize;
        let mut rule_pragmas = 0usize;
        for entry in rules_obj.values() {
            rule_findings += entry
                .get("findings")
                .and_then(Json::as_usize)
                .ok_or_else(|| schema_err("per-rule entry missing findings count"))?;
            rule_pragmas += entry
                .get("pragmas")
                .and_then(Json::as_usize)
                .ok_or_else(|| schema_err("per-rule entry missing pragmas count"))?;
        }
        if total != listed || total != rule_findings || total != self.findings.len() {
            return Err(schema_err("finding counts disagree"));
        }
        if pragmas_listed != rule_pragmas || pragmas_listed != self.pragmas.len() {
            return Err(schema_err("pragma counts disagree"));
        }
        Ok(doc)
    }

    /// Emit, round-trip-validate against the schema, and write the
    /// artifact. No ill-formed report ever lands on disk.
    pub fn write(&self, path: &std::path::Path) -> Result<(), NysxError> {
        let doc = self.to_json();
        let text = doc.to_string();
        let back = self.validate(&text)?;
        if back != doc {
            return Err(NysxError::config(
                "LINT_REPORT.json round-trip drift: parsed document != emitted document",
            ));
        }
        std::fs::write(path, text + "\n").map_err(NysxError::Io)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> LintReport {
        LintReport {
            root: "rust".to_string(),
            files_scanned: 3,
            findings: vec![Finding {
                rule: "determinism".to_string(),
                file: "src/kernel/lsh.rs".to_string(),
                line: 12,
                message: "`HashMap` in an output-affecting kernel module".to_string(),
            }],
            pragmas: vec![PragmaSite {
                rule: "raw-spawn".to_string(),
                file: "src/bench/serving.rs".to_string(),
                line: 40,
                justification: "load-harness clients".to_string(),
            }],
        }
    }

    #[test]
    fn document_shape_and_counts() {
        let report = sample();
        let doc = report.to_json();
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(SCHEMA));
        assert_eq!(doc.get("total_findings").and_then(Json::as_usize), Some(1));
        assert_eq!(doc.get("files_scanned").and_then(Json::as_usize), Some(3));
        // Every rule key is present, including silent ones.
        for rule in RULES {
            let entry = doc.get("rules").and_then(|r| r.get(rule));
            assert!(entry.is_some(), "missing rules.{rule}");
        }
        let det = doc.get("rules").and_then(|r| r.get("determinism")).unwrap();
        assert_eq!(det.get("findings").and_then(Json::as_usize), Some(1));
        assert_eq!(det.get("pragmas").and_then(Json::as_usize), Some(0));
        let spawn = doc.get("rules").and_then(|r| r.get("raw-spawn")).unwrap();
        assert_eq!(spawn.get("pragmas").and_then(Json::as_usize), Some(1));
        // Round trip through the parser is exact.
        let text = doc.to_string();
        assert_eq!(Json::parse(&text).unwrap(), doc);
        // And the self-validation accepts its own emission.
        report.validate(&text).expect("validates");
    }

    #[test]
    fn validation_rejects_inconsistent_documents() {
        let report = sample();
        let good = report.to_json().to_string();
        // A tampered total must be caught.
        let bad = good.replace("\"total_findings\":1", "\"total_findings\":7");
        assert!(matches!(report.validate(&bad), Err(NysxError::Config(_))));
        // A wrong schema tag must be caught.
        let bad = good.replace(SCHEMA, "nysx-lint/v0");
        assert!(matches!(report.validate(&bad), Err(NysxError::Config(_))));
    }

    #[test]
    fn write_lands_validated_artifact() {
        let report = sample();
        let dir = std::env::temp_dir().join(format!("nysx-lint-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("LINT_REPORT.json");
        report.write(&path).expect("write");
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(&text).expect("file parses");
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(SCHEMA));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn text_rendering_lists_findings_and_summary() {
        let text = sample().render_text();
        assert!(text.contains("src/kernel/lsh.rs:12: [determinism]"), "{text}");
        assert!(text.contains("1 suppression pragma(s) in force:"), "{text}");
        assert!(text.contains("nysx lint: 1 finding(s) over 3 file(s)"), "{text}");
        let clean = LintReport {
            root: ".".to_string(),
            files_scanned: 2,
            findings: vec![],
            pragmas: vec![],
        };
        assert!(clean.render_text().contains("0 finding(s) over 2 file(s)"));
    }
}
