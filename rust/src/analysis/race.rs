//! `nysx race` — the concurrency-safety rule tier (DESIGN.md §9) and
//! its `CONCURRENCY_REPORT.json` artifact (schema `nysx-race/v1`).
//!
//! Where `nysx lint` (§8) checks surface hygiene, these rules check the
//! *partition invariants* the exec runtime's soundness rests on: raw
//! parallel dispatch stays confined to `exec/parallel.rs`, every raw use
//! there sits behind `validate_disjoint`, no constant-evaluable range
//! list overlaps, and the coordinator tier acquires its locks in one
//! declared order. They ride the same [`super::scanner`] model and
//! suppression-pragma mechanism as the lint rules, and the dynamic half
//! of the story — the shadow claim table — lives in
//! `crate::exec::check`.

use std::collections::BTreeMap;
use std::path::Path;

use crate::api::NysxError;
use crate::util::json::Json;

use super::report::{Finding, PragmaSite};
use super::rules::{has_word, in_set};
use super::scanner::SourceModel;

/// Rule: `SendPtr` / `from_raw_parts_mut` only inside `exec/parallel.rs`
/// — raw-pointer parallel dispatch is confined to the one audited file.
pub const RULE_RAW_CONFINEMENT: &str = "race-raw-confinement";
/// Rule: inside `exec/parallel.rs`, every function using the raw tokens
/// also calls `validate_disjoint` (the partition precondition check).
pub const RULE_UNVALIDATED_DISPATCH: &str = "race-unvalidated-dispatch";
/// Rule: a constant-evaluable range list (`[a..b, c..d, …]` with integer
/// literals) must be sorted and pairwise disjoint.
pub const RULE_CONST_OVERLAP: &str = "race-const-overlap";
/// Rule: coordinator files acquire locks in the declared global order,
/// and only acquire locks that appear in the declaration.
pub const RULE_LOCK_ORDER: &str = "race-lock-order";

/// All race rules, in report order.
pub const RACE_RULES: [&str; 4] = [
    RULE_RAW_CONFINEMENT,
    RULE_UNVALIDATED_DISPATCH,
    RULE_CONST_OVERLAP,
    RULE_LOCK_ORDER,
];

/// Schema tag carried by every emitted concurrency report.
pub const SCHEMA: &str = "nysx-race/v1";

/// The one file allowed to hold raw-pointer parallel dispatch.
const RAW_OK: &str = "src/exec/parallel.rs";

/// The coordinator files under the lock-order rule.
const LOCK_SCOPE: [&str; 5] = [
    "src/coordinator/batcher.rs",
    "src/coordinator/metrics.rs",
    "src/coordinator/router.rs",
    "src/coordinator/server.rs",
    "src/coordinator/sharded.rs",
];

/// The declared global lock-acquisition order (DESIGN.md §9): a lock may
/// only be acquired while holding locks of strictly *lower* rank. Every
/// lock acquired in [`LOCK_SCOPE`] must appear here.
const LOCK_ORDER: [(&str, &str); 2] = [
    ("&self.state", "batcher queue state"),
    ("&self.inner", "metrics registry"),
];

/// Tokens that mark a line as a lock acquisition in [`LOCK_SCOPE`].
const LOCK_ACQUIRE: [&str; 2] = ["lock_or_poison(", ".lock("];

/// Does this code line *use* raw dispatch power? A `SendPtr(` call that
/// is not the tuple-struct declaration itself, or any
/// `from_raw_parts_mut`.
fn uses_raw(code: &str) -> bool {
    if has_word(code, "from_raw_parts_mut") {
        return true;
    }
    code.contains("SendPtr(") && !code.trim_start().starts_with("struct ")
}

/// Extract the integer-literal ranges (`12..34`, not `..=`) inside the
/// first complete `[...]` group starting at or after `from`, as
/// `(start, end)` pairs in textual order. Returns the scan position past
/// the group, or `None` when no group opens.
fn literal_ranges_in_group(code: &str, from: usize) -> Option<(Vec<(u64, u64)>, usize)> {
    let bytes = code.as_bytes();
    let open = bytes[from..].iter().position(|&b| b == b'[')? + from;
    let mut depth = 0i32;
    let mut close = open;
    for (k, &b) in bytes.iter().enumerate().skip(open) {
        match b {
            b'[' => depth += 1,
            b']' => {
                depth -= 1;
                if depth == 0 {
                    close = k;
                    break;
                }
            }
            _ => {}
        }
    }
    if depth != 0 {
        return None; // group continues past this line — out of scope
    }
    let group = &code[open + 1..close];
    let gb = group.as_bytes();
    let mut ranges = Vec::new();
    let mut i = 0usize;
    while i < gb.len() {
        // A digit glued to an identifier char or a dot (`x1..`, `1.2..`)
        // is not the start of an integer-literal range.
        let glued = i > 0 && {
            let p = gb[i - 1];
            p.is_ascii_alphanumeric() || p == b'_' || p == b'.'
        };
        if !gb[i].is_ascii_digit() || glued {
            i += 1;
            continue;
        }
        let ns = i;
        while i < gb.len() && gb[i].is_ascii_digit() {
            i += 1;
        }
        if !group[i..].starts_with("..") || group[i + 2..].starts_with('=') {
            continue;
        }
        let es = i + 2;
        let mut j = es;
        while j < gb.len() && gb[j].is_ascii_digit() {
            j += 1;
        }
        if j == es {
            i = es;
            continue; // `a..` open range or `a..name` — not constant
        }
        let (Ok(a), Ok(b)) = (group[ns..i].parse::<u64>(), group[es..j].parse::<u64>()) else {
            i = j;
            continue;
        };
        ranges.push((a, b));
        i = j;
    }
    Some((ranges, close + 1))
}

/// Run every race rule over one file. Same contract as
/// [`super::rules::check_file`]: `rel` is crate-root-relative, the
/// returned pragma inventory holds only justified `allow(race-*)` sites.
pub fn check_race_file(rel: &str, text: &str) -> (Vec<Finding>, Vec<PragmaSite>) {
    let model = SourceModel::of(text);
    let mut findings = Vec::new();
    let mut pragmas = Vec::new();

    for (ln, p) in &model.pragmas {
        if !RACE_RULES.contains(&p.rule.as_str()) {
            continue; // lint-tier pragmas belong to the lint report
        }
        if let Some(j) = &p.justification {
            pragmas.push(PragmaSite {
                rule: p.rule.clone(),
                file: rel.to_string(),
                line: ln + 1,
                justification: j.clone(),
            });
        }
        // An unjustified pragma is already a lint finding
        // (pragma-missing-justification) and suppresses nothing here.
    }

    let mut emit = |rule: &str, ln: usize, msg: String| {
        if !model.suppressed(rule, ln) {
            findings.push(Finding {
                rule: rule.to_string(),
                file: rel.to_string(),
                line: ln + 1,
                message: msg,
            });
        }
    };

    let in_parallel = rel == RAW_OK;
    let in_lock_scope = in_set(rel, &LOCK_SCOPE);

    // Per-fn-segment state for the unvalidated-dispatch and lock-order
    // rules. A "segment" runs from one line whose code holds the `fn`
    // keyword to the next — coarse, but every fn in scope is short and
    // the approximation only ever errs toward flagging.
    let mut seg_raw_line: Option<usize> = None;
    let mut seg_validated = false;
    let mut seg_max_rank: Option<usize> = None;

    let mut close_segment = |seg_raw_line: &mut Option<usize>,
                             seg_validated: &mut bool,
                             emit: &mut dyn FnMut(&str, usize, String)| {
        if let (Some(raw_ln), false) = (*seg_raw_line, *seg_validated) {
            emit(
                RULE_UNVALIDATED_DISPATCH,
                raw_ln,
                "raw-pointer dispatch in a function that never calls validate_disjoint"
                    .to_string(),
            );
        }
        *seg_raw_line = None;
        *seg_validated = false;
    };

    for (ln, line) in model.lines.iter().enumerate() {
        let code = line.code.as_str();

        if has_word(code, "fn") {
            close_segment(&mut seg_raw_line, &mut seg_validated, &mut emit);
            seg_max_rank = None;
        }

        if !in_parallel && (has_word(code, "SendPtr") || has_word(code, "from_raw_parts_mut")) {
            emit(
                RULE_RAW_CONFINEMENT,
                ln,
                "raw-pointer parallel dispatch outside exec/parallel.rs".to_string(),
            );
        }

        if in_parallel {
            if uses_raw(code) && seg_raw_line.is_none() {
                seg_raw_line = Some(ln);
            }
            if code.contains("validate_disjoint(") {
                seg_validated = true;
            }
        }

        if !model.in_test[ln] {
            let mut from = 0usize;
            while let Some((ranges, next)) = literal_ranges_in_group(code, from) {
                from = next;
                if ranges.len() >= 2 {
                    for w in ranges.windows(2) {
                        let ((_, prev_end), (start, _)) = (w[0], w[1]);
                        if start < prev_end {
                            emit(
                                RULE_CONST_OVERLAP,
                                ln,
                                format!(
                                    "constant range list is not sorted+disjoint \
                                     ({}..{} then {}..{})",
                                    w[0].0, w[0].1, w[1].0, w[1].1
                                ),
                            );
                            break;
                        }
                    }
                }
            }
        }

        if in_lock_scope && !model.in_test[ln] && LOCK_ACQUIRE.iter().any(|t| code.contains(t)) {
            // Position-ordered lock tokens on this line.
            let mut hits: Vec<(usize, usize)> = LOCK_ORDER
                .iter()
                .enumerate()
                .filter_map(|(rank, (tok, _))| code.find(tok).map(|pos| (pos, rank)))
                .collect();
            hits.sort_unstable();
            if hits.is_empty() {
                emit(
                    RULE_LOCK_ORDER,
                    ln,
                    "lock acquisition not in the declared lock-order table (DESIGN.md §9)"
                        .to_string(),
                );
            }
            for (_, rank) in hits {
                if let Some(max) = seg_max_rank {
                    if rank < max {
                        let (tok, what) = LOCK_ORDER[rank];
                        let (held_tok, held_what) = LOCK_ORDER[max];
                        emit(
                            RULE_LOCK_ORDER,
                            ln,
                            format!(
                                "lock-order inversion: {tok} ({what}) acquired after \
                                 {held_tok} ({held_what})"
                            ),
                        );
                    }
                }
                seg_max_rank = Some(seg_max_rank.map_or(rank, |m| m.max(rank)));
            }
        }
    }
    close_segment(&mut seg_raw_line, &mut seg_validated, &mut emit);

    (findings, pragmas)
}

/// The full race-analyzer result over one crate root — the same shape as
/// `LintReport`, but over [`RACE_RULES`] and landing as
/// `CONCURRENCY_REPORT.json`.
#[derive(Debug)]
pub struct RaceReport {
    pub root: String,
    pub files_scanned: usize,
    /// Sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Sorted by (file, line, rule).
    pub pragmas: Vec<PragmaSite>,
}

impl RaceReport {
    /// Emit the `nysx-race/v1` document; every rule always appears under
    /// `rules` (zero counts when silent).
    pub fn to_json(&self) -> Json {
        let mut per_rule: BTreeMap<&str, (usize, usize)> =
            RACE_RULES.iter().map(|r| (*r, (0, 0))).collect();
        for f in &self.findings {
            per_rule.entry(f.rule.as_str()).or_insert((0, 0)).0 += 1;
        }
        for p in &self.pragmas {
            per_rule.entry(p.rule.as_str()).or_insert((0, 0)).1 += 1;
        }
        Json::obj(vec![
            ("schema", Json::str(SCHEMA)),
            ("root", Json::str(self.root.as_str())),
            ("files_scanned", Json::num(self.files_scanned as f64)),
            ("total_findings", Json::num(self.findings.len() as f64)),
            (
                "rules",
                Json::Obj(
                    per_rule
                        .into_iter()
                        .map(|(rule, (nf, np))| {
                            (
                                rule.to_string(),
                                Json::obj(vec![
                                    ("findings", Json::num(nf as f64)),
                                    ("pragmas", Json::num(np as f64)),
                                ]),
                            )
                        })
                        .collect(),
                ),
            ),
            (
                "findings",
                Json::arr(self.findings.iter().map(|f| {
                    Json::obj(vec![
                        ("rule", Json::str(f.rule.as_str())),
                        ("file", Json::str(f.file.as_str())),
                        ("line", Json::num(f.line as f64)),
                        ("message", Json::str(f.message.as_str())),
                    ])
                })),
            ),
            (
                "pragmas",
                Json::arr(self.pragmas.iter().map(|p| {
                    Json::obj(vec![
                        ("rule", Json::str(p.rule.as_str())),
                        ("file", Json::str(p.file.as_str())),
                        ("line", Json::num(p.line as f64)),
                        ("justification", Json::str(p.justification.as_str())),
                    ])
                })),
            ),
        ])
    }

    /// Human-readable rendering, mirroring `nysx lint`'s.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!(
                "{}:{}: [{}] {}\n",
                f.file, f.line, f.rule, f.message
            ));
        }
        if !self.pragmas.is_empty() {
            out.push_str(&format!(
                "{} suppression pragma(s) in force:\n",
                self.pragmas.len()
            ));
            for p in &self.pragmas {
                out.push_str(&format!(
                    "  {}:{}: allow({}) — {}\n",
                    p.file, p.line, p.rule, p.justification
                ));
            }
        }
        out.push_str(&format!(
            "nysx race: {} finding(s) over {} file(s)\n",
            self.findings.len(),
            self.files_scanned
        ));
        out
    }

    /// Validate an emitted document against its own schema (same checks
    /// as the lint report: tag, count consistency, rule-key presence).
    fn validate(&self, text: &str) -> Result<Json, NysxError> {
        let doc = Json::parse(text).map_err(|e| {
            NysxError::Config(format!("emitted CONCURRENCY_REPORT.json does not parse: {e}"))
        })?;
        let schema_err = |what: &str| NysxError::Config(format!("CONCURRENCY_REPORT.json: {what}"));
        if doc.get("schema").and_then(Json::as_str) != Some(SCHEMA) {
            return Err(schema_err("wrong or missing schema tag"));
        }
        let total = doc
            .get("total_findings")
            .and_then(Json::as_usize)
            .ok_or_else(|| schema_err("missing total_findings"))?;
        let listed = doc
            .get("findings")
            .and_then(Json::as_arr)
            .ok_or_else(|| schema_err("missing findings array"))?
            .len();
        let pragmas_listed = doc
            .get("pragmas")
            .and_then(Json::as_arr)
            .ok_or_else(|| schema_err("missing pragmas array"))?
            .len();
        let rules_obj = match doc.get("rules") {
            Some(Json::Obj(m)) => m,
            _ => return Err(schema_err("missing rules object")),
        };
        for rule in RACE_RULES {
            if !rules_obj.contains_key(rule) {
                return Err(schema_err("missing per-rule entry"));
            }
        }
        let mut rule_findings = 0usize;
        let mut rule_pragmas = 0usize;
        for entry in rules_obj.values() {
            rule_findings += entry
                .get("findings")
                .and_then(Json::as_usize)
                .ok_or_else(|| schema_err("per-rule entry missing findings count"))?;
            rule_pragmas += entry
                .get("pragmas")
                .and_then(Json::as_usize)
                .ok_or_else(|| schema_err("per-rule entry missing pragmas count"))?;
        }
        if total != listed || total != rule_findings || total != self.findings.len() {
            return Err(schema_err("finding counts disagree"));
        }
        if pragmas_listed != rule_pragmas || pragmas_listed != self.pragmas.len() {
            return Err(schema_err("pragma counts disagree"));
        }
        Ok(doc)
    }

    /// Emit, round-trip-validate, and write `CONCURRENCY_REPORT.json` —
    /// an ill-formed report never lands on disk.
    pub fn write(&self, path: &Path) -> Result<(), NysxError> {
        let doc = self.to_json();
        let text = doc.to_string();
        let back = self.validate(&text)?;
        if back != doc {
            return Err(NysxError::config(
                "CONCURRENCY_REPORT.json round-trip drift: parsed document != emitted document",
            ));
        }
        std::fs::write(path, text + "\n").map_err(NysxError::Io)
    }
}

/// Run every race rule over `<root>/src` and `<root>/tests` and return
/// the sorted report — the `nysx race` analogue of
/// [`super::lint_crate`].
pub fn race_crate(root: &Path) -> Result<RaceReport, NysxError> {
    let src = root.join("src");
    if !src.is_dir() {
        return Err(NysxError::Config(format!(
            "race-check root {} has no src/ directory (pass the crate root via --root)",
            root.display()
        )));
    }
    let mut files = Vec::new();
    super::collect_rs(&src, &mut files)?;
    let tests = root.join("tests");
    if tests.is_dir() {
        super::collect_rs(&tests, &mut files)?;
    }
    let mut findings = Vec::new();
    let mut pragmas = Vec::new();
    let files_scanned = files.len();
    for path in files {
        let text = std::fs::read_to_string(&path).map_err(NysxError::Io)?;
        let rel = super::rel_path(root, &path);
        let (f, p) = check_race_file(&rel, &text);
        findings.extend(f);
        pragmas.extend(p);
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule.as_str()).cmp(&(b.file.as_str(), b.line, b.rule.as_str()))
    });
    pragmas.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule.as_str()).cmp(&(b.file.as_str(), b.line, b.rule.as_str()))
    });
    Ok(RaceReport {
        root: root.display().to_string(),
        files_scanned,
        findings,
        pragmas,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_fired(rel: &str, text: &str) -> Vec<String> {
        check_race_file(rel, text).0.into_iter().map(|f| f.rule).collect()
    }

    // ------- race-raw-confinement -------

    #[test]
    fn raw_tokens_confined_to_parallel_rs() {
        for src in [
            "let base = SendPtr(data.as_mut_ptr());\n",
            "let s = unsafe { std::slice::from_raw_parts_mut(p, n) }; // SAFETY: disjoint\n",
        ] {
            assert_eq!(
                rules_fired("src/hdc/packed.rs", src),
                vec![RULE_RAW_CONFINEMENT],
                "{src}"
            );
            assert_eq!(
                rules_fired("tests/exec_differential.rs", src),
                vec![RULE_RAW_CONFINEMENT],
                "tests are not exempt: {src}"
            );
        }
        let validated = "fn f() { validate_disjoint(r, n); let b = SendPtr(p); }\n";
        assert!(rules_fired("src/exec/parallel.rs", validated).is_empty());
    }

    #[test]
    fn raw_confinement_ignores_strings_and_comments() {
        let src = "// mentions SendPtr and from_raw_parts_mut\nlet s = \"SendPtr( from_raw_parts_mut\";\n";
        assert!(rules_fired("src/hdc/packed.rs", src).is_empty());
    }

    // ------- race-unvalidated-dispatch -------

    #[test]
    fn unvalidated_dispatch_planted_and_clean() {
        let planted = concat!(
            "fn bad(p: *mut u8, n: usize) {\n",
            "    let s = unsafe { std::slice::from_raw_parts_mut(p, n) }; // SAFETY: no\n",
            "    drop(s);\n",
            "}\n",
        );
        assert_eq!(
            rules_fired("src/exec/parallel.rs", planted),
            vec![RULE_UNVALIDATED_DISPATCH]
        );
        let clean = concat!(
            "fn good(data: &mut [u8], ranges: &[Range<usize>]) {\n",
            "    validate_disjoint(ranges, data.len());\n",
            "    let base = SendPtr(data.as_mut_ptr());\n",
            "    let s = unsafe { std::slice::from_raw_parts_mut(base.0, 1) }; // SAFETY: ok\n",
            "}\n",
        );
        assert!(rules_fired("src/exec/parallel.rs", clean).is_empty());
        // The tuple-struct declaration itself is not a "use".
        let decl = "struct SendPtr<T>(*mut T);\n";
        assert!(rules_fired("src/exec/parallel.rs", decl).is_empty());
    }

    #[test]
    fn unvalidated_dispatch_is_per_function() {
        let src = concat!(
            "fn good(r: &[Range<usize>], n: usize, p: *mut u8) {\n",
            "    validate_disjoint(r, n);\n",
            "}\n",
            "fn bad(p: *mut u8) {\n",
            "    let b = SendPtr(p);\n",
            "}\n",
        );
        let (findings, _) = check_race_file("src/exec/parallel.rs", src);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].rule, RULE_UNVALIDATED_DISPATCH);
        assert_eq!(findings[0].line, 5, "anchored at the raw use in `bad`");
    }

    // ------- race-const-overlap -------

    #[test]
    fn const_overlap_planted_fixture_detected() {
        let src = "for_each_range_mut(&pool, &mut data, &[0..6, 5..10], |_, _| {});\n";
        assert_eq!(rules_fired("src/sparse/schedule.rs", src), vec![RULE_CONST_OVERLAP]);
        // Unsorted lists break validate_disjoint the same way.
        let unsorted = "let r = [5..10, 0..5];\n";
        assert_eq!(rules_fired("src/sparse/schedule.rs", unsorted), vec![RULE_CONST_OVERLAP]);
    }

    #[test]
    fn const_overlap_allows_sorted_disjoint_and_non_constant() {
        for src in [
            "let r = [0..5, 5..10, 12..20];\n",
            "let r = [0..n, n..len];\n",     // not constant-evaluable
            "let one = v[3..10].to_vec();\n", // single range
            "let r = [0..=5, 5..=10];\n",     // inclusive — out of scope
            "let pair = (0..6, 5..10);\n",    // no bracket group
        ] {
            assert!(rules_fired("src/sparse/schedule.rs", src).is_empty(), "{src}");
        }
    }

    #[test]
    fn const_overlap_exempts_test_regions_and_respects_pragmas() {
        let in_test = concat!(
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    #[test]\n",
            "    fn t() { probe(&[0..6, 5..10]); }\n",
            "}\n",
        );
        assert!(rules_fired("src/exec/parallel.rs", in_test).is_empty());
        let pragma =
            "// nysx-race note below\n// nysx-lint: allow(race-const-overlap): doc example of a rejected input\nlet r = [0..6, 5..10];\n";
        assert!(rules_fired("src/sparse/schedule.rs", pragma).is_empty());
    }

    #[test]
    fn literal_range_parsing() {
        let (r, _) = literal_ranges_in_group("&[0..6, 5..10]", 0).unwrap();
        assert_eq!(r, vec![(0, 6), (5, 10)]);
        let (r, _) = literal_ranges_in_group("[10..20]", 0).unwrap();
        assert_eq!(r, vec![(10, 20)]);
        let (r, _) = literal_ranges_in_group("[a..4, 4..b, 1..=3]", 0).unwrap();
        assert!(r.is_empty(), "{r:?}");
        assert!(literal_ranges_in_group("no group here", 0).is_none());
        // Version-like dotted numbers are not ranges.
        let (r, _) = literal_ranges_in_group("[1.2..3.4]", 0).unwrap();
        assert!(r.is_empty(), "{r:?}");
    }

    // ------- race-lock-order -------

    #[test]
    fn lock_order_inversion_detected() {
        let src = concat!(
            "fn snapshot(&self) {\n",
            "    let inner = lock_or_poison(&self.inner);\n",
            "    let state = lock_or_poison(&self.state);\n",
            "    drop((inner, state));\n",
            "}\n",
        );
        assert_eq!(rules_fired("src/coordinator/metrics.rs", src), vec![RULE_LOCK_ORDER]);
    }

    #[test]
    fn lock_order_declared_order_is_clean() {
        let src = concat!(
            "fn flush(&self) {\n",
            "    let state = lock_or_poison(&self.state);\n",
            "    let inner = lock_or_poison(&self.inner);\n",
            "    drop((state, inner));\n",
            "}\n",
            "fn other(&self) {\n",
            "    let inner = lock_or_poison(&self.inner);\n",
            "    drop(inner);\n",
            "}\n",
        );
        assert!(rules_fired("src/coordinator/batcher.rs", src).is_empty());
    }

    #[test]
    fn lock_order_resets_per_function() {
        // inner in one fn, state in the next — no inversion across fns.
        let src = concat!(
            "fn a(&self) { let g = lock_or_poison(&self.inner); drop(g); }\n",
            "fn b(&self) { let g = lock_or_poison(&self.state); drop(g); }\n",
        );
        assert!(rules_fired("src/coordinator/server.rs", src).is_empty());
    }

    #[test]
    fn undeclared_lock_is_flagged_in_scope_only() {
        let src = "fn f(&self) { let g = self.queue.lock(); drop(g); }\n";
        assert_eq!(rules_fired("src/coordinator/router.rs", src), vec![RULE_LOCK_ORDER]);
        assert!(
            rules_fired("src/exec/pool.rs", src).is_empty(),
            "exec latches are out of the coordinator lock-order scope"
        );
    }

    #[test]
    fn lock_order_pragma_suppression_and_inventory() {
        let src = "// nysx-lint: allow(race-lock-order): startup-only path, no other lock held\nfn f(&self) { let g = self.boot.lock(); drop(g); }\n";
        let (findings, pragmas) = check_race_file("src/coordinator/server.rs", src);
        assert!(findings.is_empty(), "{findings:?}");
        assert_eq!(pragmas.len(), 1);
        assert_eq!(pragmas[0].rule, RULE_LOCK_ORDER);
        // Lint-tier pragmas never leak into the race inventory.
        let lint_pragma = "// nysx-lint: allow(determinism): oracle map\nlet x = 1;\n";
        let (_, p) = check_race_file("src/kernel/h.rs", lint_pragma);
        assert!(p.is_empty());
    }

    // ------- report -------

    fn sample() -> RaceReport {
        RaceReport {
            root: "rust".to_string(),
            files_scanned: 4,
            findings: vec![Finding {
                rule: RULE_CONST_OVERLAP.to_string(),
                file: "src/sparse/schedule.rs".to_string(),
                line: 9,
                message: "constant range list is not sorted+disjoint".to_string(),
            }],
            pragmas: vec![PragmaSite {
                rule: RULE_LOCK_ORDER.to_string(),
                file: "src/coordinator/server.rs".to_string(),
                line: 3,
                justification: "startup-only".to_string(),
            }],
        }
    }

    #[test]
    fn report_shape_counts_and_roundtrip() {
        let report = sample();
        let doc = report.to_json();
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(SCHEMA));
        assert_eq!(doc.get("total_findings").and_then(Json::as_usize), Some(1));
        for rule in RACE_RULES {
            assert!(doc.get("rules").and_then(|r| r.get(rule)).is_some(), "rules.{rule}");
        }
        let text = doc.to_string();
        assert_eq!(Json::parse(&text).unwrap(), doc);
        report.validate(&text).expect("validates");
        let rendered = report.render_text();
        assert!(rendered.contains("nysx race: 1 finding(s) over 4 file(s)"), "{rendered}");
    }

    #[test]
    fn report_validation_rejects_tampering() {
        let report = sample();
        let good = report.to_json().to_string();
        let bad = good.replace("\"total_findings\":1", "\"total_findings\":3");
        assert!(matches!(report.validate(&bad), Err(NysxError::Config(_))));
        let bad = good.replace(SCHEMA, "nysx-race/v0");
        assert!(matches!(report.validate(&bad), Err(NysxError::Config(_))));
    }

    #[test]
    fn report_write_lands_validated_artifact() {
        let report = sample();
        let dir = std::env::temp_dir().join(format!("nysx-race-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("CONCURRENCY_REPORT.json");
        report.write(&path).expect("write");
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            Json::parse(&text).unwrap().get("schema").and_then(Json::as_str),
            Some(SCHEMA)
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn race_crate_scans_a_synthetic_tree() {
        let dir = std::env::temp_dir().join(format!("nysx-race-tree-{}", std::process::id()));
        let src = dir.join("src").join("coordinator");
        std::fs::create_dir_all(&src).unwrap();
        std::fs::write(
            src.join("router.rs"),
            concat!(
                "fn f(&self) {\n",
                "    let inner = lock_or_poison(&self.inner);\n",
                "    let state = lock_or_poison(&self.state);\n",
                "    drop((inner, state));\n",
                "}\n",
            ),
        )
        .unwrap();
        std::fs::write(dir.join("src").join("lib.rs"), "pub fn ok() {}\n").unwrap();
        let report = race_crate(&dir).expect("race runs");
        assert_eq!(report.files_scanned, 2);
        assert_eq!(report.findings.len(), 1);
        assert_eq!(report.findings[0].rule, RULE_LOCK_ORDER);
        assert_eq!(report.findings[0].file, "src/coordinator/router.rs");
        assert_eq!(report.findings[0].line, 3);
        std::fs::remove_dir_all(&dir).ok();
    }
}
