//! `nysx lint` / `nysx race` — dependency-free invariant analyzers over
//! the crate's own sources (DESIGN.md §8 and §9).
//!
//! The crate's core guarantees — bit-identical kernel outputs at any
//! thread count, a serving tier that degrades instead of panicking,
//! `unsafe` that always carries its proof — are invariants the type
//! system cannot express. This module checks them mechanically: a
//! comment/string-aware line scanner ([`scanner`]) feeds a small rule
//! engine ([`rules`]), and the result is both a human rendering and the
//! `LINT_REPORT.json` artifact ([`report`]). `tests/lint_gate.rs` pins
//! the tree to zero findings, and the analyzer scans its own sources
//! with the same rules (no self-exemption).
//!
//! Exceptions are per-site pragmas with a mandatory written reason:
//!
//! ```text
//! // nysx-lint: allow(<rule>): <justification>
//! ```
//!
//! on the offending line or the line directly above. A pragma without a
//! justification suppresses nothing and is itself reported.

pub mod race;
pub mod report;
pub mod rules;
pub mod scanner;

use std::path::{Path, PathBuf};

pub use race::{race_crate, RaceReport, RACE_RULES};
pub use report::{Finding, LintReport, PragmaSite, SCHEMA};

use crate::api::NysxError;

/// Recursively collect `.rs` files under `dir`, sorted by path, so the
/// scan order (and therefore the report) is deterministic across
/// filesystems.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), NysxError> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(NysxError::Io)?
        .collect::<Result<Vec<_>, _>>()
        .map_err(NysxError::Io)?
        .into_iter()
        .map(|e| e.path())
        .collect();
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Crate-root-relative display path with `/` separators, whatever the
/// platform separator is.
fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

/// Run every rule over `<root>/src` and `<root>/tests` and return the
/// sorted report. `root` is the crate root (the directory holding
/// `Cargo.toml`); a missing `tests/` directory is fine, a missing
/// `src/` is an error.
pub fn lint_crate(root: &Path) -> Result<LintReport, NysxError> {
    let src = root.join("src");
    if !src.is_dir() {
        return Err(NysxError::Config(format!(
            "lint root {} has no src/ directory (pass the crate root via --root)",
            root.display()
        )));
    }
    let mut files = Vec::new();
    collect_rs(&src, &mut files)?;
    let tests = root.join("tests");
    if tests.is_dir() {
        collect_rs(&tests, &mut files)?;
    }
    let mut findings = Vec::new();
    let mut pragmas = Vec::new();
    let files_scanned = files.len();
    for path in files {
        let text = std::fs::read_to_string(&path).map_err(NysxError::Io)?;
        let rel = rel_path(root, &path);
        let (f, p) = rules::check_file(&rel, &text);
        findings.extend(f);
        pragmas.extend(p);
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule.as_str()).cmp(&(b.file.as_str(), b.line, b.rule.as_str()))
    });
    pragmas.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule.as_str()).cmp(&(b.file.as_str(), b.line, b.rule.as_str()))
    });
    Ok(LintReport {
        root: root.display().to_string(),
        files_scanned,
        findings,
        pragmas,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Locate the crate root from the test binary's environment.
    fn crate_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
    }

    /// The analyzer scans its own crate — including this very module —
    /// and the walk is deterministic.
    #[test]
    fn self_scan_is_deterministic_and_covers_analysis() {
        let root = crate_root();
        let a = lint_crate(&root).expect("lint runs");
        let b = lint_crate(&root).expect("lint runs twice");
        assert_eq!(a.findings, b.findings);
        assert_eq!(a.pragmas, b.pragmas);
        assert_eq!(a.files_scanned, b.files_scanned);
        assert!(a.files_scanned > 50, "walk found {} files", a.files_scanned);
        // The artifact pipeline works end to end on the real tree.
        let doc = a.to_json();
        let text = doc.to_string();
        assert_eq!(crate::util::json::Json::parse(&text).unwrap(), doc);
    }

    #[test]
    fn missing_src_is_a_typed_error() {
        let dir = std::env::temp_dir().join(format!("nysx-lint-nosrc-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let err = lint_crate(&dir).err().expect("no src/ must be rejected");
        assert!(matches!(err, NysxError::Config(_)), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn lint_crate_scans_a_synthetic_tree() {
        let dir = std::env::temp_dir().join(format!("nysx-lint-tree-{}", std::process::id()));
        let src = dir.join("src").join("kernel");
        std::fs::create_dir_all(&src).unwrap();
        std::fs::write(
            src.join("bad.rs"),
            "fn f() { let t = Instant::now(); drop(t); }\n",
        )
        .unwrap();
        std::fs::write(src.join("good.rs"), "pub fn g() -> u32 { 7 }\n").unwrap();
        let report = lint_crate(&dir).expect("lint runs");
        assert_eq!(report.files_scanned, 2);
        // A kernel-module clock read violates two invariants at once:
        // determinism (kernel outputs must not depend on wall time) and
        // timing-confinement (raw clock reads live in obs/coordinator/
        // bench only). Findings on one line sort by rule name.
        assert_eq!(report.findings.len(), 2);
        assert_eq!(report.findings[0].rule, rules::RULE_DETERMINISM);
        assert_eq!(report.findings[1].rule, rules::RULE_TIMING);
        for f in &report.findings {
            assert_eq!(f.file, "src/kernel/bad.rs");
            assert_eq!(f.line, 1);
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
