//! The source model under every lint rule: a comment/string-aware split
//! of a Rust file into per-line *code* and *comment* parts, plus
//! `#[cfg(test)]` region tracking and suppression-pragma extraction.
//!
//! The splitter is a small character-level state machine, not a parser:
//! it understands line and (nested) block comments, ordinary and raw
//! string literals, char literals vs lifetimes — enough that a token
//! like `.unwrap()` inside a string literal or a doc comment never
//! reaches a rule, while everything that *is* code does. String and
//! char-literal *contents* are blanked from the code part (the quotes
//! remain as placeholders), so brace counting for `#[cfg(test)]` regions
//! cannot be derailed by a `'{'` literal.

/// One source line: the code text (string/char contents blanked) and the
/// comment text (`//`, `///`, `//!` and block-comment bodies).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Line {
    pub code: String,
    pub comment: String,
}

/// A suppression pragma parsed from a comment:
/// `// nysx-lint: allow(<rule>): <justification>`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Pragma {
    pub rule: String,
    /// `None` when the mandatory justification is missing — the pragma
    /// then suppresses nothing and is itself reported.
    pub justification: Option<String>,
}

/// The fully analyzed model of one source file.
#[derive(Debug)]
pub struct SourceModel {
    pub lines: Vec<Line>,
    /// `in_test[i]` — line `i` sits inside a `#[cfg(test)]` item.
    pub in_test: Vec<bool>,
    /// Pragmas per line index (0-based), in textual order.
    pub pragmas: Vec<(usize, Pragma)>,
}

enum State {
    Normal,
    LineComment,
    /// Nested block comment depth.
    Block(u32),
    Str,
    /// Raw string terminator hash count (`r##"…"##` → 2).
    RawStr(usize),
}

/// Split a file into per-line code/comment parts.
pub fn split_lines(text: &str) -> Vec<Line> {
    let chars: Vec<char> = text.chars().collect();
    let n = chars.len();
    let mut out = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut state = State::Normal;
    let mut i = 0usize;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            if matches!(state, State::LineComment) {
                state = State::Normal;
            }
            out.push(Line {
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
            });
            i += 1;
            continue;
        }
        match state {
            State::LineComment => {
                comment.push(c);
                i += 1;
            }
            State::Block(depth) => {
                if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::Block(depth + 1);
                    comment.push_str("/*");
                    i += 2;
                } else if c == '*' && chars.get(i + 1) == Some(&'/') {
                    comment.push_str("*/");
                    i += 2;
                    state = if depth <= 1 {
                        State::Normal
                    } else {
                        State::Block(depth - 1)
                    };
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    // Skip the escaped char — except an escaped newline
                    // (the line-continuation form), whose '\n' must
                    // still reach the line splitter above or every
                    // later line of the file shifts by one.
                    if chars.get(i + 1) == Some(&'\n') {
                        i += 1;
                    } else {
                        i += 2;
                    }
                } else if c == '"' {
                    code.push('"');
                    state = State::Normal;
                    i += 1;
                } else {
                    i += 1; // blank the contents
                }
            }
            State::RawStr(hashes) => {
                if c == '"' && (1..=hashes).all(|k| chars.get(i + k) == Some(&'#')) {
                    code.push('"');
                    for _ in 0..hashes {
                        code.push('#');
                    }
                    state = State::Normal;
                    i += 1 + hashes;
                } else {
                    i += 1;
                }
            }
            State::Normal => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    state = State::LineComment;
                    comment.push_str("//");
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    state = State::Block(1);
                    comment.push_str("/*");
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    state = State::Str;
                    i += 1;
                } else if let Some((consumed, hashes)) = raw_string_start(&chars, i) {
                    code.push('r');
                    for _ in 0..hashes {
                        code.push('#');
                    }
                    code.push('"');
                    state = State::RawStr(hashes);
                    i += consumed;
                } else if c == '\'' {
                    i = consume_quote(&chars, i, &mut code);
                } else {
                    code.push(c);
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        out.push(Line { code, comment });
    }
    out
}

/// At a `'`: distinguish a char literal (blank its contents) from a
/// lifetime (keep scanning). Returns the next index to process.
fn consume_quote(chars: &[char], i: usize, code: &mut String) -> usize {
    let n = chars.len();
    if chars.get(i + 1) == Some(&'\\') {
        // Escaped char literal: skip the escape body to the closing
        // quote ('\n', '\'', '\u{1f600}', …).
        let mut j = i + 2;
        if chars.get(j) != Some(&'u') {
            j += 1; // the escaped character itself (may be a quote)
        }
        while j < n && chars[j] != '\'' {
            j += 1;
        }
        code.push_str("''");
        j + 1
    } else if chars.get(i + 2) == Some(&'\'') {
        // Plain one-char literal 'x'.
        code.push_str("''");
        i + 3
    } else {
        // A lifetime ('a) — keep the tick as code and move on.
        code.push('\'');
        i + 1
    }
}

/// Detect `r"…"` / `r#"…"#` / `br##"…"##` at position `i`; returns
/// (chars consumed through the opening quote, hash count).
fn raw_string_start(chars: &[char], i: usize) -> Option<(usize, usize)> {
    if i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_') {
        return None; // mid-identifier 'r' (e.g. `for r in …` is safe anyway)
    }
    let mut k = i;
    if chars.get(k) == Some(&'b') {
        k += 1;
    }
    if chars.get(k) != Some(&'r') {
        return None;
    }
    k += 1;
    let mut hashes = 0usize;
    while chars.get(k) == Some(&'#') {
        hashes += 1;
        k += 1;
    }
    if chars.get(k) == Some(&'"') {
        Some((k + 1 - i, hashes))
    } else {
        None
    }
}

/// Mark every line inside a `#[cfg(test)]` item. Brace depth is tracked
/// over the blanked code text; the attribute arms a pending flag that the
/// item's opening `{` converts into a region (popped when depth returns),
/// and a bare `;` (statement items like `#[cfg(test)] use …;`) discharges.
fn test_regions(lines: &[Line]) -> Vec<bool> {
    let mut in_test = vec![false; lines.len()];
    let mut depth = 0i64;
    let mut stack: Vec<i64> = Vec::new();
    let mut pending = false;
    for (ln, line) in lines.iter().enumerate() {
        if pending || !stack.is_empty() {
            in_test[ln] = true;
        }
        if line.code.contains("#[cfg(test)]") {
            pending = true;
            in_test[ln] = true;
        }
        for ch in line.code.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if pending {
                        stack.push(depth);
                        pending = false;
                    }
                }
                '}' => {
                    if stack.last() == Some(&depth) {
                        stack.pop();
                    }
                    depth -= 1;
                }
                ';' if pending && stack.is_empty() => {
                    pending = false;
                }
                _ => {}
            }
        }
    }
    in_test
}

/// Extract every `nysx-lint: allow(<rule>)[: justification]` pragma from
/// one comment. The rule name must be `[a-z0-9-]+` — anything else (like
/// prose *describing* the syntax with `<rule>` placeholders) is not a
/// pragma and is skipped.
fn pragmas_in(comment: &str) -> Vec<Pragma> {
    const MARKER: &str = "nysx-lint:";
    let mut out = Vec::new();
    let mut rest = comment;
    while let Some(pos) = rest.find(MARKER) {
        rest = &rest[pos + MARKER.len()..];
        let Some(after) = rest.trim_start().strip_prefix("allow(") else {
            continue;
        };
        let Some(close) = after.find(')') else {
            break;
        };
        let rule = &after[..close];
        rest = &after[close + 1..];
        if rule.is_empty()
            || !rule
                .chars()
                .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-')
        {
            continue;
        }
        let justification = rest
            .trim_start()
            .strip_prefix(':')
            .map(str::trim)
            .filter(|j| !j.is_empty())
            .map(str::to_string);
        out.push(Pragma {
            rule: rule.to_string(),
            justification,
        });
    }
    out
}

impl SourceModel {
    pub fn of(text: &str) -> Self {
        let lines = split_lines(text);
        let in_test = test_regions(&lines);
        let mut pragmas = Vec::new();
        for (ln, line) in lines.iter().enumerate() {
            for p in pragmas_in(&line.comment) {
                pragmas.push((ln, p));
            }
        }
        Self {
            lines,
            in_test,
            pragmas,
        }
    }

    /// Is there a justified `allow(rule)` pragma on this line or the
    /// line directly above? (The two sanctioned placements: trailing
    /// comment, or a dedicated comment line above the finding.)
    pub fn suppressed(&self, rule: &str, ln: usize) -> bool {
        self.pragmas.iter().any(|(at, p)| {
            (*at == ln || *at + 1 == ln) && p.rule == rule && p.justification.is_some()
        })
    }

    /// Does the comment context of `ln` carry a SAFETY marker? Checks
    /// the line's own comment, then up to 3 lines above; a pure comment
    /// line inside that window extends the search through its whole
    /// contiguous comment block (multi-line SAFETY arguments count via
    /// their last line).
    pub fn has_safety_comment(&self, ln: usize) -> bool {
        let is_safety = |c: &str| c.to_uppercase().contains("SAFETY");
        if is_safety(&self.lines[ln].comment) {
            return true;
        }
        for k in 1..=3usize {
            let Some(j) = ln.checked_sub(k) else { break };
            let line = &self.lines[j];
            if is_safety(&line.comment) {
                return true;
            }
            if !line.comment.is_empty() && line.code.trim().is_empty() {
                // Pure comment line: walk the contiguous block upward.
                let mut j2 = j;
                loop {
                    let l2 = &self.lines[j2];
                    if l2.comment.is_empty() || !l2.code.trim().is_empty() {
                        break;
                    }
                    if is_safety(&l2.comment) {
                        return true;
                    }
                    let Some(next) = j2.checked_sub(1) else { break };
                    j2 = next;
                }
            }
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_separated_from_code() {
        let m = SourceModel::of(concat!(
            "let x = \"unsafe .unwrap() HashMap\"; // trailing unsafe note\n",
            "/* block .unwrap() */ let y = 1;\n",
        ));
        assert!(!m.lines[0].code.contains("unwrap"), "{:?}", m.lines[0]);
        assert!(m.lines[0].comment.contains("trailing unsafe note"));
        assert!(!m.lines[1].code.contains("unwrap"));
        assert!(m.lines[1].code.contains("let y = 1;"));
        assert!(m.lines[1].comment.contains(".unwrap()"));
    }

    #[test]
    fn nested_block_comments_and_multiline_strings() {
        let m = SourceModel::of(concat!(
            "/* outer /* inner */ still comment */ code();\n",
            "let s = \"line one\n",
            "line two with } brace\";\n",
            "after();\n",
        ));
        assert!(m.lines[0].code.contains("code();"));
        assert!(m.lines[0].comment.contains("inner"));
        // The multi-line string body is blanked, including its brace.
        assert!(!m.lines[1].code.contains("line one"));
        assert!(!m.lines[2].code.contains('}'));
        assert!(m.lines[3].code.contains("after();"));
    }

    #[test]
    fn char_literals_blanked_lifetimes_kept() {
        let m = SourceModel::of("match c { '{' => a, '\\'' => b, _ => f::<'static>() }\n");
        let code = &m.lines[0].code;
        assert!(!code.contains('{') || code.matches('{').count() == 1, "{code}");
        assert!(code.contains("'static"), "{code}");
        // Exactly the one structural brace pair survives.
        assert_eq!(code.matches('{').count(), 1, "{code}");
        assert_eq!(code.matches('}').count(), 1, "{code}");
    }

    #[test]
    fn raw_strings_blanked() {
        let m = SourceModel::of("let p = r#\"contains .unwrap() and \"quotes\"\"#;\nnext();\n");
        assert!(!m.lines[0].code.contains("unwrap"), "{:?}", m.lines[0]);
        assert!(m.lines[1].code.contains("next();"));
    }

    #[test]
    fn hash_guarded_raw_strings_hide_comment_markers_and_unsafe() {
        let m = SourceModel::of(concat!(
            "let q = r#\"// not a comment, unsafe not code\"#; live();\n",
            "let r2 = r##\"has \"# inside\"##; tail();\n",
        ));
        assert!(!m.lines[0].code.contains("unsafe"), "{:?}", m.lines[0]);
        assert!(
            m.lines[0].comment.is_empty(),
            "// inside a raw string is not a comment: {:?}",
            m.lines[0]
        );
        assert!(m.lines[0].code.contains("live();"));
        // A lone `"#` inside an `r##` string does not terminate it.
        assert!(!m.lines[1].code.contains("inside"), "{:?}", m.lines[1]);
        assert!(m.lines[1].code.contains("tail();"));
    }

    #[test]
    fn multiline_raw_string_keeps_line_alignment() {
        let m = SourceModel::of(concat!(
            "let s = r#\"first // line\n",
            "unsafe second\n",
            "\"#; after();\n",
        ));
        assert_eq!(m.lines.len(), 3, "{:?}", m.lines);
        assert!(!m.lines[1].code.contains("unsafe"), "{:?}", m.lines[1]);
        assert!(m.lines[2].code.contains("after();"));
    }

    #[test]
    fn block_comments_nested_three_deep() {
        let m = SourceModel::of(concat!(
            "/* 1 /* 2 /* 3 unsafe */ still2 */ still1 */ code();\n",
            "/* a /* b /* c */\n",
            "*/ */ tail();\n",
        ));
        assert_eq!(m.lines[0].code.trim(), "code();", "{:?}", m.lines[0]);
        assert!(m.lines[0].comment.contains("unsafe"));
        assert!(m.lines[1].code.trim().is_empty(), "{:?}", m.lines[1]);
        assert!(m.lines[2].code.contains("tail();"), "{:?}", m.lines[2]);
    }

    #[test]
    fn escaped_newline_in_string_does_not_lose_a_line() {
        let m = SourceModel::of(concat!(
            "let s = \"one \\\n",
            "two\"; done();\n",
            "after();\n",
        ));
        assert_eq!(m.lines.len(), 3, "{:?}", m.lines);
        assert!(m.lines[1].code.contains("done();"), "{:?}", m.lines[1]);
        assert!(m.lines[2].code.contains("after();"), "{:?}", m.lines[2]);
    }

    #[test]
    fn cfg_test_regions_cover_mod_and_statement_forms() {
        let src = concat!(
            "fn live() { body(); }\n",        // 0
            "#[cfg(test)]\n",                 // 1
            "use super::Request;\n",          // 2: statement form ends region
            "fn also_live() {}\n",            // 3
            "#[cfg(test)]\n",                 // 4
            "mod tests {\n",                  // 5
            "    fn helper() { x(); }\n",     // 6
            "    #[test]\n",                  // 7
            "    fn t() { y(); }\n",          // 8
            "}\n",                            // 9
            "fn after() {}\n",                // 10
        );
        let m = SourceModel::of(src);
        let want = [
            false, true, true, false, true, true, true, true, true, true, false,
        ];
        for (ln, &w) in want.iter().enumerate() {
            assert_eq!(m.in_test[ln], w, "line {ln}");
        }
    }

    #[test]
    fn pragma_parsing_rule_and_justification() {
        let m = SourceModel::of(concat!(
            "let a = 1; // nysx-lint: allow(determinism): lookup-only map\n",
            "let b = 2; // nysx-lint: allow(raw-spawn)\n",
            "let c = 3; // nysx-lint: allow(raw-spawn):   \n",
        ));
        assert_eq!(m.pragmas.len(), 3);
        assert_eq!(m.pragmas[0].1.rule, "determinism");
        assert_eq!(
            m.pragmas[0].1.justification.as_deref(),
            Some("lookup-only map")
        );
        // Missing and whitespace-only justifications are both None.
        assert_eq!(m.pragmas[1].1.justification, None);
        assert_eq!(m.pragmas[2].1.justification, None);
        // Prose describing the syntax is not a pragma.
        let doc = SourceModel::of("//! `// nysx-lint: allow(<rule>): <justification>`\n");
        assert!(doc.pragmas.is_empty(), "{:?}", doc.pragmas);
        assert!(m.suppressed("determinism", 0));
        assert!(m.suppressed("determinism", 1), "pragma covers the next line");
        assert!(!m.suppressed("determinism", 2));
        assert!(!m.suppressed("raw-spawn", 1), "no justification, no effect");
    }

    #[test]
    fn safety_comment_window_and_block_extension() {
        let src = concat!(
            "// SAFETY: a long argument that starts here\n", // 0
            "// and continues across several lines\n",       // 1
            "// before the block ends\n",                    // 2
            "// with this fourth line\n",                    // 3
            "let x = unsafe { f() };\n",                     // 4
            "let a = 1;\n",                                  // 5
            "let b = 2;\n",                                  // 6
            "let c = 3;\n",                                  // 7
            "let y = unsafe { g() };\n",                     // 8
        );
        let m = SourceModel::of(src);
        // Line 4: the block's last line is 1 above; SAFETY sits 4 above
        // but the contiguous block extension reaches it.
        assert!(m.has_safety_comment(4));
        // Line 8: nothing within 3 lines is a comment, so the block is
        // out of reach.
        assert!(!m.has_safety_comment(8));
    }

    #[test]
    fn safety_comment_same_line_and_doc_form() {
        let m = SourceModel::of(concat!(
            "unsafe { h() } // SAFETY: single-threaded here\n",
            "/// # Safety\n",
            "/// caller upholds disjointness\n",
            "#[inline]\n",
            "pub unsafe fn w() {}\n",
        ));
        assert!(m.has_safety_comment(0));
        assert!(m.has_safety_comment(4), "doc # Safety within window");
    }
}
