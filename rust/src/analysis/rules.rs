//! The invariant rules (DESIGN.md §8) and the per-file check driver.
//!
//! Each rule is a line-level predicate over the blanked code text from
//! [`super::scanner`], scoped to the path set whose invariant it guards.
//! A finding is suppressed only by a *justified* pragma on the same line
//! or the line directly above; a pragma without a justification is
//! itself a finding (`pragma-missing-justification`) and suppresses
//! nothing — silence always costs a written sentence.

use super::report::{Finding, PragmaSite};
use super::scanner::SourceModel;

/// Rule: every `unsafe` keyword carries a SAFETY comment within 3 lines.
pub const RULE_UNSAFE: &str = "unsafe-needs-safety";
/// Rule: no `unwrap`/`expect`/`panic!`-family in the serving set.
pub const RULE_NO_PANIC: &str = "no-panic-in-serving";
/// Rule: no hash-order / wall-clock / ambient-RNG sources in kernels.
pub const RULE_DETERMINISM: &str = "determinism";
/// Rule: no bare `partial_cmp().unwrap()` orderings.
pub const RULE_FLOAT_ORDERING: &str = "float-ordering";
/// Rule: raw `std::thread` spawns only in `exec/` and `coordinator/`.
pub const RULE_RAW_SPAWN: &str = "raw-spawn";
/// Rule: no panicking channel endpoints (`.send(..)`/`.recv(..)` chained
/// into `.unwrap()`/`.expect(..)`) in the exec + coordinator tier.
pub const RULE_CHANNEL_PANIC: &str = "channel-panic";
/// Rule: an `allow(...)` pragma must state its justification.
pub const RULE_PRAGMA_JUSTIFICATION: &str = "pragma-missing-justification";
/// Rule: raw wall-clock reads (`Instant::now` / `SystemTime`) only in
/// the timing-confined set (`obs/`, `coordinator/`, `bench/`) —
/// everything else times through the `obs::clock` seam, so the
/// determinism story has ONE clock to audit.
pub const RULE_TIMING: &str = "timing-confinement";

/// All rules, in report order.
pub const RULES: [&str; 8] = [
    RULE_UNSAFE,
    RULE_NO_PANIC,
    RULE_DETERMINISM,
    RULE_FLOAT_ORDERING,
    RULE_RAW_SPAWN,
    RULE_CHANNEL_PANIC,
    RULE_PRAGMA_JUSTIFICATION,
    RULE_TIMING,
];

/// The panic-free serving set: paths where a worker panic would take the
/// serving tier down (or poison shared state) instead of degrading.
const PANIC_SET: [&str; 4] = ["src/api/", "src/coordinator/", "src/model/io.rs", "src/main.rs"];

/// The deterministic kernel set: modules whose outputs must be
/// bit-identical across runs and thread counts.
const KERNEL_SET: [&str; 6] = [
    "src/hdc/",
    "src/nystrom/",
    "src/sparse/",
    "src/exec/partition.rs",
    "src/kernel/",
    "src/succinct/",
];

/// Paths allowed to spawn OS threads directly.
const SPAWN_OK: [&str; 2] = ["src/exec/", "src/coordinator/"];

/// Paths where a panicking channel endpoint takes a worker or serving
/// lane down instead of degrading: the exec runtime and the coordinator.
const CHANNEL_SET: [&str; 2] = ["src/coordinator/", "src/exec/"];

/// Paths allowed to read the wall clock directly: the obs layer (the
/// clock seam itself), the coordinator (per-request latency bookkeeping)
/// and the bench harnesses. Everywhere else, raw `Instant::now` /
/// `SystemTime` reads must route through `obs::clock` instead.
const TIMING_OK: [&str; 3] = ["src/obs/", "src/coordinator/", "src/bench/"];

pub(super) fn in_set(rel: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| rel == *p || rel.starts_with(p))
}

fn is_word_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Word-bounded token search: `tok` occurs in `code` with no identifier
/// character hugging either end (so `spawn` never matches `respawned`,
/// and `HashMap` never matches `NoHashMapHere`).
pub(super) fn has_word(code: &str, tok: &str) -> bool {
    let mut from = 0;
    while let Some(off) = code[from..].find(tok) {
        let start = from + off;
        let end = start + tok.len();
        let pre_ok = code[..start].chars().next_back().is_none_or(|c| !is_word_char(c));
        let post_ok = code[end..].chars().next().is_none_or(|c| !is_word_char(c));
        if pre_ok && post_ok {
            return true;
        }
        from = end;
    }
    false
}

/// Find `channel-panic` sites: a `.send(` / `.recv(` / `.recv_timeout(`
/// call whose matching `)` is followed — possibly across lines — by
/// `.unwrap()` or `.expect(`. The per-line code parts are concatenated
/// first so a multi-line builder chain (`.send(Job { … })⏎.expect(…)`)
/// is seen whole. Returns 0-based line indices of the panicking
/// continuation (where a suppression pragma must sit).
fn channel_panic_sites(model: &SourceModel) -> Vec<usize> {
    let mut flat = String::new();
    let mut line_of: Vec<usize> = Vec::new(); // flat byte index -> line
    for (ln, line) in model.lines.iter().enumerate() {
        for c in line.code.chars() {
            flat.push(c);
            for _ in 0..c.len_utf8() {
                line_of.push(ln);
            }
        }
        flat.push('\n');
        line_of.push(ln);
    }
    let bytes = flat.as_bytes();
    let mut sites = Vec::new();
    for tok in [".send(", ".recv(", ".recv_timeout("] {
        let mut from = 0usize;
        while let Some(off) = flat[from..].find(tok) {
            let open = from + off + tok.len() - 1; // index of the '('
            from = open + 1;
            let mut depth = 1i32;
            let mut j = open + 1;
            while j < bytes.len() && depth > 0 {
                match bytes[j] {
                    b'(' => depth += 1,
                    b')' => depth -= 1,
                    _ => {}
                }
                j += 1;
            }
            if depth != 0 {
                continue; // unbalanced (truncated file) — nothing to chain onto
            }
            let mut k = j;
            while k < bytes.len() && bytes[k].is_ascii_whitespace() {
                k += 1;
            }
            if flat[k..].starts_with(".unwrap()") || flat[k..].starts_with(".expect(") {
                sites.push(line_of[k]);
            }
        }
    }
    sites.sort_unstable();
    sites.dedup();
    sites
}

/// Run every rule over one file. `rel` is the crate-root-relative path
/// with `/` separators (e.g. `src/hdc/encode.rs`, `tests/lint_gate.rs`).
/// Returns the findings plus the file's justified-pragma inventory.
pub fn check_file(rel: &str, text: &str) -> (Vec<Finding>, Vec<PragmaSite>) {
    let model = SourceModel::of(text);
    let mut findings = Vec::new();
    let mut pragmas = Vec::new();

    for (ln, p) in &model.pragmas {
        match &p.justification {
            Some(j) => pragmas.push(PragmaSite {
                rule: p.rule.clone(),
                file: rel.to_string(),
                line: ln + 1,
                justification: j.clone(),
            }),
            None => findings.push(Finding {
                rule: RULE_PRAGMA_JUSTIFICATION.to_string(),
                file: rel.to_string(),
                line: ln + 1,
                message: format!("allow({}) pragma has no justification", p.rule),
            }),
        }
    }

    let mut emit = |rule: &str, ln: usize, msg: String| {
        if !model.suppressed(rule, ln) {
            findings.push(Finding {
                rule: rule.to_string(),
                file: rel.to_string(),
                line: ln + 1,
                message: msg,
            });
        }
    };

    let panic_tokens = [".unwrap()", ".expect(", "panic!", "todo!", "unimplemented!"];
    let det_tokens = ["HashMap", "HashSet", "Instant::now", "SystemTime", "thread_rng"];

    for (ln, line) in model.lines.iter().enumerate() {
        let code = line.code.as_str();
        if has_word(code, "unsafe") && !model.has_safety_comment(ln) {
            emit(
                RULE_UNSAFE,
                ln,
                "`unsafe` without a SAFETY comment within 3 lines above".to_string(),
            );
        }
        if in_set(rel, &PANIC_SET) && !model.in_test[ln] {
            for tok in panic_tokens {
                // `.unwrap()`/`.expect(` match literally (the leading dot
                // is the boundary); the macros are word-bounded.
                let hit = if tok.starts_with('.') {
                    code.contains(tok)
                } else {
                    has_word(code, tok)
                };
                if hit {
                    emit(
                        RULE_NO_PANIC,
                        ln,
                        format!("`{tok}` in the panic-free serving set"),
                    );
                    break;
                }
            }
        }
        if in_set(rel, &KERNEL_SET) && !model.in_test[ln] && !code.trim_start().starts_with("use ")
        {
            for tok in det_tokens {
                if has_word(code, tok) {
                    emit(
                        RULE_DETERMINISM,
                        ln,
                        format!("`{tok}` in an output-affecting kernel module"),
                    );
                    break;
                }
            }
        }
        if code.contains("partial_cmp") && (code.contains(".unwrap()") || code.contains(".expect("))
        {
            emit(
                RULE_FLOAT_ORDERING,
                ln,
                "bare partial_cmp().unwrap() ordering; use total_cmp/argmax_first_max".to_string(),
            );
        }
        if !in_set(rel, &SPAWN_OK)
            && (code.contains("thread::spawn") || code.contains("thread::Builder"))
        {
            emit(
                RULE_RAW_SPAWN,
                ln,
                "raw std::thread spawn outside exec/ and coordinator/".to_string(),
            );
        }
        // Timing confinement: tests (both #[cfg(test)] regions and the
        // tests/ tree) may time freely, and `use` lines only name the
        // type. A kernel-set violation also trips `determinism` — the
        // two rules guard different invariants (one clock seam vs
        // bit-identical outputs), so both fire.
        if !in_set(rel, &TIMING_OK)
            && !rel.starts_with("tests/")
            && !model.in_test[ln]
            && !code.trim_start().starts_with("use ")
        {
            for tok in ["Instant::now", "SystemTime"] {
                if has_word(code, tok) {
                    emit(
                        RULE_TIMING,
                        ln,
                        format!(
                            "`{tok}` outside the timing-confined set (obs/, coordinator/, \
                             bench/); route through obs::clock"
                        ),
                    );
                    break;
                }
            }
        }
    }

    if in_set(rel, &CHANNEL_SET) {
        for ln in channel_panic_sites(&model) {
            if model.in_test[ln] {
                continue;
            }
            emit(
                RULE_CHANNEL_PANIC,
                ln,
                "panicking channel endpoint (send/recv chained into unwrap/expect); \
                 handle the Err"
                    .to_string(),
            );
        }
    }

    (findings, pragmas)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_fired(rel: &str, text: &str) -> Vec<String> {
        check_file(rel, text).0.into_iter().map(|f| f.rule).collect()
    }

    #[test]
    fn word_boundaries() {
        assert!(has_word("let x = unsafe { y };", "unsafe"));
        assert!(!has_word("let unsafer = 1;", "unsafe"));
        assert!(!has_word("let not_unsafe = 1;", "unsafe"));
        assert!(has_word("h: HashMap<K, V>", "HashMap"));
        assert!(!has_word("h: MyHashMapLike", "HashMap"));
        assert!(has_word("Instant::now()", "Instant::now"));
        assert!(!has_word("Instant::nowish()", "Instant::now"));
    }

    // ------- unsafe-needs-safety -------

    #[test]
    fn unsafe_rule_fires_without_safety_comment() {
        let src = "pub fn f(p: *mut u8) {\n    unsafe { *p = 0 };\n}\n";
        assert_eq!(rules_fired("src/exec/x.rs", src), vec![RULE_UNSAFE]);
    }

    #[test]
    fn unsafe_rule_satisfied_by_nearby_safety_comment() {
        let src = "pub fn f(p: *mut u8) {\n    // SAFETY: caller guarantees p is valid\n    unsafe { *p = 0 };\n}\n";
        assert!(rules_fired("src/exec/x.rs", src).is_empty());
    }

    #[test]
    fn unsafe_rule_applies_in_tests_too() {
        let src = "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { unsafe { core::hint::unreachable_unchecked() } }\n}\n";
        assert_eq!(rules_fired("src/exec/x.rs", src), vec![RULE_UNSAFE]);
    }

    #[test]
    fn unsafe_rule_pragma_suppression() {
        // (A pragma naming this rule contains the word "safety" and so
        // also satisfies the SAFETY-comment check — suppression via a
        // trailing pragma on the unsafe line itself is the clean probe.)
        let with_just = "unsafe { f() }; // nysx-lint: allow(unsafe-needs-safety): ffi shim documented in DESIGN.md\n";
        assert!(rules_fired("src/exec/x.rs", with_just).is_empty());
    }

    #[test]
    fn unjustified_pragma_reports_itself_and_suppresses_nothing() {
        let src = "fn k() {\n    // nysx-lint: allow(determinism)\n    let t = Instant::now(); drop(t);\n}\n";
        assert_eq!(
            rules_fired("src/kernel/x.rs", src),
            vec![RULE_PRAGMA_JUSTIFICATION, RULE_DETERMINISM, RULE_TIMING],
            "unjustified pragma reports itself and suppresses nothing"
        );
    }

    // ------- no-panic-in-serving -------

    #[test]
    fn no_panic_fires_only_in_serving_set() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        assert_eq!(rules_fired("src/api/mod.rs", src), vec![RULE_NO_PANIC]);
        assert_eq!(rules_fired("src/coordinator/batcher.rs", src), vec![RULE_NO_PANIC]);
        assert_eq!(rules_fired("src/model/io.rs", src), vec![RULE_NO_PANIC]);
        assert_eq!(rules_fired("src/main.rs", src), vec![RULE_NO_PANIC]);
        assert!(rules_fired("src/hdc/encode.rs", src).is_empty(), "outside the set");
    }

    #[test]
    fn no_panic_covers_every_token() {
        for src in [
            "let v = m.lock().expect(\"poisoned\");\n",
            "panic!(\"boom\");\n",
            "todo!()\n",
            "unimplemented!()\n",
        ] {
            assert_eq!(rules_fired("src/api/mod.rs", src), vec![RULE_NO_PANIC], "{src}");
        }
        // `expect` as an identifier is not the method token.
        assert!(rules_fired("src/api/mod.rs", "fn expect_byte() {}\n").is_empty());
    }

    #[test]
    fn no_panic_skips_cfg_test_regions() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { Some(1).unwrap(); }\n}\n";
        assert!(rules_fired("src/api/mod.rs", src).is_empty());
    }

    #[test]
    fn no_panic_ignores_tokens_in_strings_and_comments() {
        let src = "// explains .unwrap() history\nlet s = \"never .unwrap() here\";\n";
        assert!(rules_fired("src/api/mod.rs", src).is_empty());
    }

    #[test]
    fn no_panic_pragma_suppression() {
        let src = "// nysx-lint: allow(no-panic-in-serving): documented panicking convenience wrapper\nlet v = x.unwrap();\n";
        assert!(rules_fired("src/coordinator/server.rs", src).is_empty());
        let trailing = "let v = x.unwrap(); // nysx-lint: allow(no-panic-in-serving): init-time only\n";
        assert!(rules_fired("src/coordinator/server.rs", trailing).is_empty());
    }

    // ------- determinism -------

    #[test]
    fn determinism_fires_in_kernel_set_only() {
        let src = "fn f() { let m: HashMap<u32, u32> = Default::default(); drop(m); }\n";
        for rel in [
            "src/hdc/encode.rs",
            "src/nystrom/landmarks.rs",
            "src/sparse/csr.rs",
            "src/exec/partition.rs",
            "src/kernel/histogram.rs",
            "src/succinct/phast.rs",
        ] {
            assert_eq!(rules_fired(rel, src), vec![RULE_DETERMINISM], "{rel}");
        }
        assert!(rules_fired("src/coordinator/metrics.rs", src).is_empty());
        assert!(rules_fired("src/exec/pool.rs", src).is_empty(), "only partition.rs in exec/");
    }

    #[test]
    fn determinism_covers_clock_and_rng_tokens() {
        // The clock tokens also violate timing-confinement (kernel
        // modules are outside the timing-confined set), so both fire.
        for src in [
            "let t0 = Instant::now();\n",
            "let t = SystemTime::now();\n",
        ] {
            assert_eq!(
                rules_fired("src/kernel/lsh.rs", src),
                vec![RULE_DETERMINISM, RULE_TIMING],
                "{src}"
            );
        }
        for src in [
            "let r = thread_rng();\n",
            "let s: HashSet<u32> = Default::default();\n",
        ] {
            assert_eq!(rules_fired("src/kernel/lsh.rs", src), vec![RULE_DETERMINISM], "{src}");
        }
    }

    #[test]
    fn determinism_skips_use_lines_and_tests() {
        let src = "use std::collections::HashMap;\nfn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { let m: HashMap<u8, u8> = Default::default(); drop(m); }\n}\n";
        assert!(rules_fired("src/kernel/histogram.rs", src).is_empty());
    }

    #[test]
    fn determinism_pragma_suppression() {
        let src = "struct C {\n    // nysx-lint: allow(determinism): lookup-only map, never iterated\n    index: HashMap<u64, u32>,\n}\n";
        assert!(rules_fired("src/kernel/histogram.rs", src).is_empty());
    }

    // ------- float-ordering -------

    #[test]
    fn float_ordering_fires_anywhere_including_tests() {
        let src = "v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n";
        assert_eq!(rules_fired("src/util/mod.rs", src), vec![RULE_FLOAT_ORDERING]);
        let in_test = "#[cfg(test)]\nmod tests {\n    fn t() { v.sort_by(|a, b| b.partial_cmp(a).expect(\"nan\")); }\n}\n";
        assert_eq!(rules_fired("src/linalg/eigen.rs", in_test), vec![RULE_FLOAT_ORDERING]);
    }

    #[test]
    fn float_ordering_allows_handled_partial_cmp() {
        let src = "v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));\n";
        assert!(rules_fired("src/util/mod.rs", src).is_empty(), "unwrap_or is not .unwrap()");
        assert!(rules_fired("src/util/mod.rs", "v.sort_by(f64::total_cmp);\n").is_empty());
    }

    #[test]
    fn float_ordering_pragma_suppression() {
        let src = "// nysx-lint: allow(float-ordering): inputs proven finite two lines up\nv.sort_by(|a, b| a.partial_cmp(b).unwrap());\n";
        assert!(rules_fired("src/util/mod.rs", src).is_empty());
    }

    // ------- raw-spawn -------

    #[test]
    fn raw_spawn_fires_outside_exec_and_coordinator() {
        for src in [
            "let h = std::thread::spawn(move || work());\n",
            "let h = thread::Builder::new().spawn(move || work());\n",
        ] {
            assert_eq!(rules_fired("src/bench/serving.rs", src), vec![RULE_RAW_SPAWN], "{src}");
            assert_eq!(rules_fired("tests/exec_differential.rs", src), vec![RULE_RAW_SPAWN]);
            assert!(rules_fired("src/exec/pool.rs", src).is_empty());
            assert!(rules_fired("src/coordinator/server.rs", src).is_empty());
        }
    }

    #[test]
    fn raw_spawn_pragma_suppression() {
        let src = "// nysx-lint: allow(raw-spawn): load-harness client threads, not serving lanes\nlet h = std::thread::spawn(f);\n";
        assert!(rules_fired("src/bench/serving.rs", src).is_empty());
    }

    // ------- channel-panic -------

    #[test]
    fn channel_panic_fires_in_exec_and_coordinator_only() {
        let src = "fn f(tx: &Sender<u32>) { tx.send(1).unwrap(); }\n";
        assert_eq!(rules_fired("src/exec/pool.rs", src), vec![RULE_CHANNEL_PANIC]);
        // coordinator/ is also in the panic-free serving set, so the
        // same line trips both rules there.
        let fired = rules_fired("src/coordinator/worker.rs", src);
        assert!(fired.contains(&RULE_CHANNEL_PANIC.to_string()), "{fired:?}");
        assert!(rules_fired("src/bench/serving.rs", src).is_empty(), "outside the set");
    }

    #[test]
    fn channel_panic_recv_variants_fire() {
        for src in [
            "fn f(rx: &Receiver<u32>) -> u32 { rx.recv().unwrap() }\n",
            "fn f(rx: &Receiver<u32>) -> u32 { rx.recv().expect(\"closed\") }\n",
            "fn f(rx: &Receiver<u32>) -> u32 { rx.recv_timeout(d).unwrap() }\n",
        ] {
            assert_eq!(rules_fired("src/exec/mod.rs", src), vec![RULE_CHANNEL_PANIC], "{src}");
        }
    }

    #[test]
    fn channel_panic_sees_multiline_chains() {
        let src = concat!(
            "fn f(tx: &Sender<Job>) {\n",
            "    tx.send(Job {\n",
            "        lane,\n",
            "        latch: latch.clone(),\n",
            "    })\n",
            "    .expect(\"worker gone\");\n",
            "}\n",
        );
        let (findings, _) = check_file("src/exec/pool.rs", src);
        assert_eq!(findings.len(), 1, "{findings:?}");
        assert_eq!(findings[0].rule, RULE_CHANNEL_PANIC);
        assert_eq!(findings[0].line, 6, "anchored at the panicking continuation");
    }

    #[test]
    fn channel_panic_allows_handled_endpoints() {
        let src = concat!(
            "fn f(tx: &Sender<u32>) {\n",
            "    if tx.send(1).is_err() { return; }\n",
            "    while let Ok(v) = rx.recv() { drop(v); }\n",
            "    match rx.recv_timeout(d) { Ok(v) => use_it(v), Err(_) => {} }\n",
            "    let _ = tx.send(2);\n",
            "}\n",
        );
        assert!(rules_fired("src/exec/pool.rs", src).is_empty());
    }

    #[test]
    fn channel_panic_skips_tests_and_respects_pragmas() {
        let in_test =
            "#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() { tx.send(1).unwrap(); }\n}\n";
        assert!(rules_fired("src/exec/pool.rs", in_test).is_empty());
        let pragma = "// nysx-lint: allow(channel-panic): init-time only, receiver proven alive\ntx.send(1).unwrap();\n";
        assert!(rules_fired("src/exec/pool.rs", pragma).is_empty());
    }

    // ------- timing-confinement -------

    #[test]
    fn timing_fires_outside_the_confined_set() {
        for src in [
            "let t0 = std::time::Instant::now();\n",
            "let stamp = SystemTime::now();\n",
        ] {
            assert_eq!(rules_fired("src/infer/optimized.rs", src), vec![RULE_TIMING], "{src}");
            assert_eq!(rules_fired("src/main.rs", src), vec![RULE_TIMING], "{src}");
        }
    }

    #[test]
    fn timing_allowed_inside_the_confined_set() {
        let src = "let t0 = std::time::Instant::now();\n";
        for rel in [
            "src/obs/clock.rs",
            "src/coordinator/worker.rs",
            "src/bench/harness.rs",
        ] {
            assert!(rules_fired(rel, src).is_empty(), "{rel}");
        }
    }

    #[test]
    fn timing_skips_use_lines_tests_and_tests_dir() {
        let use_line = "use std::time::{Instant, SystemTime};\n";
        assert!(rules_fired("src/infer/optimized.rs", use_line).is_empty());
        let in_test = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() { let t0 = Instant::now(); drop(t0); }\n}\n";
        assert!(rules_fired("src/infer/optimized.rs", in_test).is_empty());
        let live = "let t0 = Instant::now();\n";
        assert!(rules_fired("tests/serving_integration.rs", live).is_empty());
    }

    #[test]
    fn timing_pragma_suppression() {
        let src = "// nysx-lint: allow(timing-confinement): one-shot startup stamp, never in outputs\nlet t0 = Instant::now();\n";
        assert!(rules_fired("src/infer/optimized.rs", src).is_empty());
    }

    // ------- pragma inventory -------

    #[test]
    fn justified_pragmas_are_inventoried_not_findings() {
        let src = "// nysx-lint: allow(determinism): oracle map\nlet m: HashMap<u8, u8> = Default::default();\n";
        let (findings, pragmas) = check_file("src/kernel/histogram.rs", src);
        assert!(findings.is_empty());
        assert_eq!(pragmas.len(), 1);
        assert_eq!(pragmas[0].rule, RULE_DETERMINISM);
        assert_eq!(pragmas[0].line, 1);
        assert_eq!(pragmas[0].justification, "oracle map");
    }
}
