//! PHast/CHD-style bucketed minimal perfect hashing (DESIGN.md §10) —
//! the compact replacement for the BBHash cascade in [`crate::mph`].
//!
//! Keys hash into `⌈n/λ⌉` buckets; each bucket searches for the smallest
//! seed that lands its keys on distinct, unoccupied slots of a
//! `⌈β·n⌉`-slot table. The structure then stores only (a) one
//! Rice-coded seed per bucket and (b) an `assigned` bit per slot whose
//! [`BitVec::rank1`] compresses the slot space back onto `[0, n)` —
//! landing at ≈2.7 bits/key on large key sets (vs ≈4+ for the cascade).
//!
//! Construction is two-phase so the parallel fan-out can never leak into
//! the result:
//!
//! 1. **Parallel lower bounds** (`exec::map_parts` over `even_ranges`):
//!    each bucket's minimal *self*-collision-free seed — a pure function
//!    of the bucket, so lane count and completion order are irrelevant.
//! 2. **Sequential placement**: buckets in (size desc, id asc) order
//!    continue their seed search against the global occupancy table,
//!    starting from the phase-1 bound. No parallel state mutates here.
//!
//! The result is bit-identical at any thread count — the same contract
//! every `nysx::exec` kernel carries.

use super::bits::{BitBuf, BitVec};
use crate::exec::{self, even_ranges, map_parts, Pool};
use crate::mph::wang_hash64;

/// Expected keys per bucket (λ). Larger buckets amortize the per-bucket
/// seed better but search exponentially harder; 5 is the sweet spot the
/// sizing sweep settled on.
const LAMBDA: usize = 5;
/// Slot-table load numerator/denominator: m = ⌈n·β⌉ with β = 1.2.
/// Looser tables shrink seeds faster than the extra `assigned` bits
/// cost (the sweep's minimum across codebook-scale n).
const BETA_NUM: usize = 6;
const BETA_DEN: usize = 5;
/// Per-bucket seed search cap; a bucket that exhausts it aborts the
/// attempt and the whole build retries under a new global seed.
const MAX_SEED: u64 = 1 << 20;
/// Global rebuild attempts before declaring the key set unbuildable
/// (never observed past attempt 0 at these λ/β).
const MAX_RETRIES: u64 = 8;

/// Multiply-shift range reduction: uniform `h` to `[0, n)` without `%`.
#[inline]
fn mult_shift(h: u64, n: usize) -> usize {
    ((h as u128 * n as u128) >> 64) as usize
}

/// Slot of a key (pre-hashed to `h`) under bucket seed `s` and global
/// retry seed `g`.
#[inline]
fn slot(h: u64, s: u64, g: u64, m: usize) -> usize {
    mult_shift(wang_hash64(h ^ s.wrapping_mul(0x9E3779B97F4A7C15) ^ g), m)
}

/// The bucketed MPH: seeds + assigned-slot bitmap, both succinct.
#[derive(Debug, Clone, PartialEq)]
pub struct PhastMph {
    num_keys: usize,
    num_buckets: usize,
    num_slots: usize,
    /// Nonzero only when an earlier attempt hit `MAX_SEED`.
    global_seed: u64,
    /// Rice remainder width for the per-bucket seeds.
    rice_k: u32,
    /// Unary seed quotients: bucket b's quotient is the run of zeros
    /// before the b-th one, recovered with two selects.
    quotients: BitVec,
    /// Fixed-width seed remainders, `rice_k` bits per bucket.
    remainders: BitBuf,
    /// One bit per slot; `rank1` over it is the slot→index compression.
    assigned: BitVec,
}

/// `true` iff the bucket's keys land on pairwise-distinct slots that are
/// also all free in `occupied` (pass the all-zeros table for phase 1).
/// Buckets are O(λ) so the quadratic distinctness check is cheap.
fn placeable(hashes: &[u64], s: u64, g: u64, m: usize, occupied: &[u64]) -> bool {
    for (i, &h) in hashes.iter().enumerate() {
        let p = slot(h, s, g, m);
        if occupied[p / 64] >> (p % 64) & 1 == 1 {
            return false;
        }
        for &earlier in &hashes[..i] {
            if slot(earlier, s, g, m) == p {
                return false;
            }
        }
    }
    true
}

impl PhastMph {
    /// Build over a distinct key set on the process-wide pool. Panics on
    /// duplicate keys (same contract as the legacy cascade).
    pub fn build(keys: &[u64]) -> Self {
        Self::build_with_pool(keys, &exec::global())
    }

    /// [`Self::build`] on an explicit pool. Thread count never changes
    /// the structure (see the module docs for why).
    pub fn build_with_pool(keys: &[u64], pool: &Pool) -> Self {
        let n = keys.len();
        {
            // Duplicate rejection without hash sets (determinism lint
            // covers this module): sort a copy, scan adjacent.
            let mut sorted = keys.to_vec();
            sorted.sort_unstable();
            for w in sorted.windows(2) {
                assert!(w[0] != w[1], "duplicate key {} in MPH key set", w[0]);
            }
        }
        if n == 0 {
            return Self {
                num_keys: 0,
                num_buckets: 0,
                num_slots: 0,
                global_seed: 0,
                rice_k: 0,
                quotients: BitVec::from_words(Vec::new(), 0),
                remainders: BitBuf::new(),
                assigned: BitVec::from_words(Vec::new(), 0),
            };
        }
        let m = (n * BETA_NUM).div_ceil(BETA_DEN).max(n);
        let nb = n.div_ceil(LAMBDA);

        // Group key hashes by bucket with a counting sort — stable,
        // allocation-flat, and independent of input order beyond the
        // (deterministic) key order itself. wang_hash64 is a bijection,
        // so distinct keys keep distinct hashes.
        let hashes: Vec<u64> = keys.iter().map(|&k| wang_hash64(k)).collect();
        let mut counts = vec![0usize; nb + 1];
        for &h in &hashes {
            counts[mult_shift(h, nb) + 1] += 1;
        }
        for b in 0..nb {
            counts[b + 1] += counts[b];
        }
        let mut grouped = vec![0u64; n];
        let mut cursor = counts.clone();
        for &h in &hashes {
            let b = mult_shift(h, nb);
            grouped[cursor[b]] = h;
            cursor[b] += 1;
        }
        let bucket = |b: usize| &grouped[counts[b]..counts[b + 1]];

        let mut retry = 0u64;
        loop {
            let g = if retry == 0 { 0 } else { wang_hash64(retry) };

            // Phase 1 — parallel: per-bucket minimal self-collision-free
            // seed, a pure lower bound on the final seed.
            let ranges = even_ranges(nb, pool.threads());
            let no_occupancy = vec![0u64; m.div_ceil(64)];
            let starts: Vec<u64> = map_parts(pool, ranges.len(), |part| {
                let mut out = Vec::with_capacity(ranges[part].len());
                for b in ranges[part].clone() {
                    let keys = bucket(b);
                    let mut s = 0u64;
                    while !placeable(keys, s, g, m, &no_occupancy) {
                        s += 1;
                    }
                    out.push(s);
                }
                out
            })
            .into_iter()
            .flatten()
            .collect();

            // Phase 2 — sequential: place buckets largest-first against
            // the shared table, resuming each search at its bound.
            let mut order: Vec<usize> = (0..nb).collect();
            order.sort_by_key(|&b| (usize::MAX - bucket(b).len(), b));
            let mut occupied = vec![0u64; m.div_ceil(64)];
            let mut seeds = vec![0u64; nb];
            let mut failed = false;
            'place: for &b in &order {
                let keys = bucket(b);
                let mut s = starts[b];
                while !placeable(keys, s, g, m, &occupied) {
                    s += 1;
                    if s >= MAX_SEED {
                        failed = true;
                        break 'place;
                    }
                }
                for &h in keys {
                    let p = slot(h, s, g, m);
                    occupied[p / 64] |= 1 << (p % 64);
                }
                seeds[b] = s;
            }
            if failed {
                retry += 1;
                assert!(retry < MAX_RETRIES, "MPH build exhausted global retries");
                continue;
            }

            // Rice-code the seeds: scan the remainder width minimizing
            // total bits (unary quotients + terminators + remainders).
            let rice_k = (0..=16u32)
                .min_by_key(|&k| {
                    nb as u64
                        + seeds.iter().map(|&s| s >> k).sum::<u64>()
                        + nb as u64 * k as u64
                })
                .unwrap_or(0);
            let mut quotients = BitBuf::new();
            let mut remainders = BitBuf::with_capacity(nb * rice_k as usize);
            for &s in &seeds {
                quotients.push_zeros((s >> rice_k) as usize);
                quotients.push_bit(true);
                if rice_k > 0 {
                    remainders.push_bits(s & ((1u64 << rice_k) - 1), rice_k);
                }
            }
            return Self {
                num_keys: n,
                num_buckets: nb,
                num_slots: m,
                global_seed: g,
                rice_k,
                quotients: BitVec::from_buf(&quotients),
                remainders,
                assigned: BitVec::from_words(occupied, m),
            };
        }
    }

    /// Decode bucket `b`'s seed: quotient from two selects on the unary
    /// stream, remainder from the fixed-width buffer.
    #[inline]
    fn seed(&self, b: usize) -> u64 {
        let end = self.quotients.select1(b);
        let start = if b == 0 { 0 } else { self.quotients.select1(b - 1) + 1 };
        let q = (end - start) as u64;
        if self.rice_k == 0 {
            q
        } else {
            (q << self.rice_k)
                | self.remainders.get_bits(b * self.rice_k as usize, self.rice_k)
        }
    }

    /// O(1) lookup: the MPH index in `[0, num_keys)` for keys in the
    /// build set. A key *outside* the set either hits an unassigned slot
    /// (`None`) or aliases an assigned one — returning an in-range index
    /// the caller's verification store rejects, exactly like the legacy
    /// cascade's contract.
    #[inline]
    pub fn index(&self, key: u64) -> Option<u32> {
        if self.num_keys == 0 {
            return None;
        }
        let h = wang_hash64(key);
        let s = self.seed(mult_shift(h, self.num_buckets));
        let pos = slot(h, s, self.global_seed, self.num_slots);
        if self.assigned.get(pos) {
            Some(self.assigned.rank1(pos) as u32)
        } else {
            None
        }
    }

    pub fn num_keys(&self) -> usize {
        self.num_keys
    }

    pub fn num_buckets(&self) -> usize {
        self.num_buckets
    }

    /// Structure bytes: seed streams + assigned bitmap (the same
    /// payload-only convention as the legacy `Mph::bytes`).
    pub fn bytes(&self) -> usize {
        self.quotients.bytes() + self.remainders.bytes() + self.assigned.bytes()
    }

    pub fn bits_per_key(&self) -> f64 {
        if self.num_keys == 0 {
            0.0
        } else {
            self.bytes() as f64 * 8.0 / self.num_keys as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mph::code_key;
    use crate::testing::{forall, PropConfig};
    use crate::util::rng::Xoshiro256;

    fn random_keys(n: usize, rng: &mut Xoshiro256) -> Vec<u64> {
        let mut set = std::collections::HashSet::new();
        while set.len() < n {
            set.insert(rng.next_u64());
        }
        let mut keys: Vec<u64> = set.into_iter().collect();
        keys.sort_unstable();
        keys
    }

    #[test]
    fn perfect_minimal_bijection() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for &n in &[1usize, 2, 5, 64, 100, 1000, 5000] {
            let keys = random_keys(n, &mut rng);
            let mph = PhastMph::build(&keys);
            let mut seen = vec![false; n];
            for &k in &keys {
                let idx = mph.index(k).expect("present key must resolve") as usize;
                assert!(idx < n, "index {idx} out of range for n={n}");
                assert!(!seen[idx], "collision at index {idx} (n={n})");
                seen[idx] = true;
            }
            assert!(seen.iter().all(|&s| s), "not minimal for n={n}");
        }
    }

    #[test]
    fn sequential_code_keys_stay_perfect() {
        // The production key distribution: dense sequential LSH codes.
        let keys: Vec<u64> = (-1500i64..1500).map(code_key).collect();
        let mph = PhastMph::build(&keys);
        let mut seen = std::collections::HashSet::new();
        for &k in &keys {
            assert!(seen.insert(mph.index(k).unwrap()));
        }
    }

    #[test]
    fn absent_keys_in_range_or_none() {
        forall("phast-absent-keys", PropConfig::default(), |rng, size| {
            let n = 1 + rng.gen_range(96 * size.max(1));
            let keys = random_keys(n, rng);
            let mph = PhastMph::build(&keys);
            let key_set: std::collections::HashSet<u64> = keys.iter().copied().collect();
            let mut checked = 0;
            while checked < 64 {
                let k = rng.next_u64();
                if key_set.contains(&k) {
                    continue;
                }
                if let Some(idx) = mph.index(k) {
                    crate::prop_assert!(
                        (idx as usize) < n,
                        "absent key {k} indexed out of range ({idx} >= {n})"
                    );
                }
                checked += 1;
            }
            Ok(())
        });
    }

    #[test]
    fn thread_count_never_changes_the_structure() {
        let keys: Vec<u64> = (0..4000i64).map(code_key).collect();
        let baseline = PhastMph::build_with_pool(&keys, &Pool::new(1));
        for threads in [2usize, 7] {
            let pool = Pool::new(threads);
            assert_eq!(
                PhastMph::build_with_pool(&keys, &pool),
                baseline,
                "structure differs at {threads} threads"
            );
        }
        assert_eq!(baseline.global_seed, 0, "retries should not trigger");
    }

    #[test]
    fn under_three_bits_per_key_at_scale() {
        let keys: Vec<u64> = (0..20_000i64).map(code_key).collect();
        let mph = PhastMph::build(&keys);
        let bpk = mph.bits_per_key();
        assert!(bpk < 3.0, "bits/key too high: {bpk:.3}");
        assert!(bpk > 1.44, "below the information-theoretic floor: {bpk:.3}");
    }

    #[test]
    fn empty_key_set() {
        let mph = PhastMph::build(&[]);
        assert_eq!(mph.index(123), None);
        assert_eq!(mph.num_keys(), 0);
        assert_eq!(mph.bytes(), 0);
    }

    #[test]
    #[should_panic(expected = "duplicate key")]
    fn rejects_duplicates() {
        PhastMph::build(&[7, 8, 7]);
    }
}
