//! Elias–Fano encoding of monotone (non-decreasing) u64 sequences
//! (DESIGN.md §10): n values over universe `[0, u]` in
//! `n·(2 + ⌈log₂(u/n)⌉)` bits plus the rank/select directory, with O(1)
//! random access through [`BitVec::select1`] on the unary upper half.
//!
//! Each value splits into `low_width` low bits (packed fixed-width) and
//! a high part stored in unary: value `i` contributes a one at position
//! `high(i) + i` of the high bit vector. `get(i)` is then
//! `((select1(i) − i) << low_width) | low(i)`.
//!
//! Used for CSR row offsets ([`crate::sparse::csr::RowOffsets`]) and the
//! model-v3 artifact sections (`model/io.rs`): both are sorted integer
//! sequences whose plain encodings burn 4–8 bytes per entry.

use super::bits::{BitBuf, BitVec};

/// An Elias–Fano-coded monotone sequence.
#[derive(Debug, Clone, PartialEq)]
pub struct EliasFano {
    n: usize,
    /// The largest encoded value (0 for the empty sequence).
    universe: u64,
    low_width: u32,
    low: BitBuf,
    high: BitVec,
}

/// The canonical split: `⌊log₂(u/n)⌋` low bits for n values over
/// universe size u (= max value + 1), zero when the sequence is dense.
fn split_width(n: usize, universe: u64) -> u32 {
    if n == 0 {
        return 0;
    }
    // u128 dodges the +1 overflow at universe == u64::MAX.
    let ratio = (universe as u128 + 1) / n as u128;
    if ratio >= 2 {
        ratio.ilog2()
    } else {
        0
    }
}

impl EliasFano {
    /// Encode a non-decreasing sequence. Panics on decreasing input —
    /// monotonicity is the codec's precondition, not a runtime case.
    pub fn from_sorted(values: &[u64]) -> Self {
        let n = values.len();
        let universe = values.last().copied().unwrap_or(0);
        let low_width = split_width(n, universe);
        let mut low = BitBuf::with_capacity(n * low_width as usize);
        let high_len = n + (universe >> low_width) as usize + 1;
        let mut high_buf = BitBuf::with_capacity(high_len);
        high_buf.push_zeros(high_len);
        let mut high_words = high_buf.words().to_vec();
        let mut prev = 0u64;
        for (i, &v) in values.iter().enumerate() {
            assert!(v >= prev, "EliasFano input must be non-decreasing");
            prev = v;
            if low_width > 0 {
                low.push_bits(v & ((1u64 << low_width) - 1), low_width);
            }
            let pos = (v >> low_width) as usize + i;
            high_words[pos / 64] |= 1u64 << (pos % 64);
        }
        let high = BitVec::from_words(high_words, high_len);
        Self {
            n,
            universe,
            low_width,
            low,
            high,
        }
    }

    /// Reconstruct from serialized parts (artifact load path). The low
    /// width is derived from `(n, universe)`, every length is
    /// cross-checked, and the ones count and last value must be
    /// consistent — a corrupt section comes back as `Err`, never a panic
    /// or an oversized allocation beyond the provided words.
    pub fn from_parts(
        n: usize,
        universe: u64,
        low_words: Vec<u64>,
        high_words: Vec<u64>,
    ) -> Result<Self, String> {
        if n == 0 {
            if universe != 0 || !low_words.is_empty() {
                return Err("empty Elias-Fano section with nonzero universe/low".into());
            }
            let high_len = 1;
            if high_words.len() != 1 || high_words[0] != 0 {
                return Err("empty Elias-Fano section with malformed high bits".into());
            }
            let high = BitVec::from_words(high_words, high_len);
            return Ok(Self {
                n,
                universe,
                low_width: 0,
                low: BitBuf::new(),
                high,
            });
        }
        let low_width = split_width(n, universe);
        let low_len = n * low_width as usize;
        let low = BitBuf::from_words(low_words, low_len)
            .ok_or_else(|| "Elias-Fano low-bits length mismatch".to_string())?;
        let high_len = n + (universe >> low_width) as usize + 1;
        if high_words.len() != high_len.div_ceil(64) {
            return Err("Elias-Fano high-bits length mismatch".into());
        }
        if let Some(&last) = high_words.last() {
            let tail = high_len % 64;
            if tail != 0 && last >> tail != 0 {
                return Err("Elias-Fano high-bits tail padding nonzero".into());
            }
        }
        let high = BitVec::from_words(high_words, high_len);
        if high.ones() != n {
            return Err(format!(
                "Elias-Fano ones count {} != n {n}",
                high.ones()
            ));
        }
        let ef = Self {
            n,
            universe,
            low_width,
            low,
            high,
        };
        if ef.get(n - 1) != universe {
            return Err("Elias-Fano last value != universe".into());
        }
        Ok(ef)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// The largest encoded value (0 for the empty sequence).
    #[inline]
    pub fn universe(&self) -> u64 {
        self.universe
    }

    /// O(1) random access to the i-th value.
    #[inline]
    pub fn get(&self, i: usize) -> u64 {
        debug_assert!(i < self.n, "EliasFano index out of range");
        let high = (self.high.select1(i) - i) as u64;
        if self.low_width == 0 {
            high
        } else {
            (high << self.low_width) | self.low.get_bits(i * self.low_width as usize, self.low_width)
        }
    }

    /// First `(index, value)` with `value >= x` (binary search over the
    /// O(1) `get`, so O(log n)); `None` when every value is below `x`.
    pub fn successor(&self, x: u64) -> Option<(usize, u64)> {
        if self.n == 0 || self.universe < x {
            return None;
        }
        let (mut lo, mut hi) = (0usize, self.n - 1);
        // Invariant: get(hi) >= x (checked above via universe).
        while lo < hi {
            let mid = lo + (hi - lo) / 2;
            if self.get(mid) >= x {
                hi = mid;
            } else {
                lo = mid + 1;
            }
        }
        Some((lo, self.get(lo)))
    }

    /// Sequential decode (faster than n `get` calls: one pass over the
    /// high words, no selects).
    pub fn iter(&self) -> EliasFanoIter<'_> {
        EliasFanoIter {
            ef: self,
            i: 0,
            high_pos: 0,
        }
    }

    /// Heap payload bytes (both halves including rank/select directory).
    pub fn bytes(&self) -> usize {
        self.low.bytes() + self.high.bytes()
    }

    /// Serialization accessors (the v3 artifact writes these verbatim).
    pub fn low_words(&self) -> &[u64] {
        self.low.words()
    }

    pub fn high_words(&self) -> &[u64] {
        self.high.words()
    }
}

/// Sequential decoder returned by [`EliasFano::iter`].
pub struct EliasFanoIter<'a> {
    ef: &'a EliasFano,
    i: usize,
    high_pos: usize,
}

impl Iterator for EliasFanoIter<'_> {
    type Item = u64;

    fn next(&mut self) -> Option<u64> {
        if self.i >= self.ef.n {
            return None;
        }
        // Scan the unary half for the next one; amortized O(1) per item.
        while !self.ef.high.get(self.high_pos) {
            self.high_pos += 1;
        }
        let high = (self.high_pos - self.i) as u64;
        let w = self.ef.low_width;
        let v = if w == 0 {
            high
        } else {
            (high << w) | self.ef.low.get_bits(self.i * w as usize, w)
        };
        self.high_pos += 1;
        self.i += 1;
        Some(v)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let rest = self.ef.n - self.i;
        (rest, Some(rest))
    }
}

impl ExactSizeIterator for EliasFanoIter<'_> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, PropConfig};
    use crate::util::rng::Xoshiro256;

    /// The naive sorted-vector oracle every property pins against.
    fn check_against_oracle(values: &[u64]) {
        let ef = EliasFano::from_sorted(values);
        assert_eq!(ef.len(), values.len());
        for (i, &v) in values.iter().enumerate() {
            assert_eq!(ef.get(i), v, "get({i}) of {} values", values.len());
        }
        let decoded: Vec<u64> = ef.iter().collect();
        assert_eq!(decoded, values, "iter mismatch");
        // Successor against a linear-scan oracle, probed at every value,
        // every value±1, and past the end.
        let mut probes: Vec<u64> = vec![0, u64::MAX];
        for &v in values {
            probes.push(v);
            probes.push(v.saturating_sub(1));
            probes.push(v.saturating_add(1));
        }
        for x in probes {
            let want = values
                .iter()
                .enumerate()
                .find(|&(_, &v)| v >= x)
                .map(|(i, &v)| (i, v));
            assert_eq!(ef.successor(x), want, "successor({x})");
        }
    }

    #[test]
    fn empty_single_and_constant() {
        check_against_oracle(&[]);
        check_against_oracle(&[0]);
        check_against_oracle(&[7]);
        check_against_oracle(&[u64::MAX]);
        check_against_oracle(&vec![0; 100]);
        check_against_oracle(&vec![42; 257]);
    }

    #[test]
    fn dense_vs_sparse() {
        // Dense: consecutive integers (low_width 0).
        check_against_oracle(&(0..300).collect::<Vec<u64>>());
        // All-ones gaps (strictly increasing by 1 from an offset).
        check_against_oracle(&(1000..1300).collect::<Vec<u64>>());
        // Sparse: huge gaps.
        let sparse: Vec<u64> = (0..50).map(|i| i * 1_000_000_007).collect();
        check_against_oracle(&sparse);
    }

    #[test]
    fn boundary_dims_63_64_65() {
        for n in [63usize, 64, 65] {
            let vals: Vec<u64> = (0..n as u64).map(|i| i * 13 + 5).collect();
            check_against_oracle(&vals);
        }
        // Values at word-boundary magnitudes.
        check_against_oracle(&[(1 << 63) - 1, 1 << 63, (1 << 63) + 1]);
    }

    #[test]
    fn u32_overflow_adjacent_universes() {
        let base = u32::MAX as u64;
        let vals = vec![base - 2, base - 1, base, base + 1, base + 2, base + 700];
        check_against_oracle(&vals);
        // A whole sequence straddling 2^32 with mixed gaps.
        let mut rng = Xoshiro256::seed_from_u64(21);
        let mut v = base - 5000;
        let mut vals = Vec::new();
        for _ in 0..2000 {
            v += rng.gen_range(17) as u64;
            vals.push(v);
        }
        check_against_oracle(&vals);
    }

    #[test]
    fn successor_on_gaps() {
        let vals = vec![10, 10, 20, 50, 51, 1000];
        let ef = EliasFano::from_sorted(&vals);
        assert_eq!(ef.successor(0), Some((0, 10)));
        assert_eq!(ef.successor(10), Some((0, 10)), "hits first duplicate");
        assert_eq!(ef.successor(11), Some((2, 20)));
        assert_eq!(ef.successor(21), Some((3, 50)));
        assert_eq!(ef.successor(52), Some((5, 1000)));
        assert_eq!(ef.successor(1000), Some((5, 1000)));
        assert_eq!(ef.successor(1001), None);
    }

    #[test]
    fn random_monotone_property() {
        forall("elias-fano-vs-oracle", PropConfig::default(), |rng, size| {
            let n = size * 9 + 1;
            // Geometric-ish universes so both dense and sparse splits run.
            let max_gap = 1u64 << (rng.gen_range(24) + 1);
            let mut v = 0u64;
            let mut vals = Vec::with_capacity(n);
            for _ in 0..n {
                v += rng.next_u64() % max_gap;
                vals.push(v);
            }
            let ef = EliasFano::from_sorted(&vals);
            for (i, &want) in vals.iter().enumerate() {
                crate::prop_assert!(ef.get(i) == want, "get({i}) at n={n} gap={max_gap}");
            }
            let probe = rng.next_u64() % vals.last().map_or(1, |&l| l.max(1));
            let want = vals
                .iter()
                .enumerate()
                .find(|&(_, &x)| x >= probe)
                .map(|(i, &x)| (i, x));
            crate::prop_assert!(ef.successor(probe) == want, "successor({probe})");
            Ok(())
        });
    }

    #[test]
    fn parts_roundtrip_and_validation() {
        let vals: Vec<u64> = (0..500u64).map(|i| i * 37 + (i % 3)).collect();
        let ef = EliasFano::from_sorted(&vals);
        let again = EliasFano::from_parts(
            ef.len(),
            ef.universe(),
            ef.low_words().to_vec(),
            ef.high_words().to_vec(),
        )
        .expect("round trip");
        assert_eq!(again, ef);

        // Wrong lengths and corrupt padding are typed errors.
        assert!(EliasFano::from_parts(ef.len() + 1, ef.universe(), ef.low_words().to_vec(), ef.high_words().to_vec()).is_err());
        assert!(EliasFano::from_parts(ef.len(), ef.universe() + 64, ef.low_words().to_vec(), ef.high_words().to_vec()).is_err());
        let mut short_low = ef.low_words().to_vec();
        short_low.pop();
        assert!(EliasFano::from_parts(ef.len(), ef.universe(), short_low, ef.high_words().to_vec()).is_err());
        let mut bad_high = ef.high_words().to_vec();
        if let Some(last) = bad_high.last_mut() {
            *last |= 1 << 63; // tail padding must stay zero
        }
        assert!(EliasFano::from_parts(ef.len(), ef.universe(), ef.low_words().to_vec(), bad_high).is_err());

        // Empty-sequence parts.
        let empty = EliasFano::from_sorted(&[]);
        let again = EliasFano::from_parts(0, 0, Vec::new(), empty.high_words().to_vec())
            .expect("empty round trip");
        assert_eq!(again, empty);
        assert!(EliasFano::from_parts(0, 9, Vec::new(), vec![0]).is_err());
    }

    #[test]
    fn compresses_row_ptr_style_sequences() {
        // A CSR offset array: 100k rows, ~6 nnz per row. Plain usize
        // storage is 8 bytes/entry; EF should land well under 2.
        let mut rng = Xoshiro256::seed_from_u64(4);
        let mut offs = vec![0u64];
        for _ in 0..100_000 {
            offs.push(offs.last().unwrap() + rng.gen_range(12) as u64);
        }
        let ef = EliasFano::from_sorted(&offs);
        let plain = offs.len() * 8;
        assert!(
            ef.bytes() * 4 < plain,
            "EF {} bytes vs plain {plain} — expected >4x win",
            ef.bytes()
        );
        for i in (0..offs.len()).step_by(997) {
            assert_eq!(ef.get(i), offs[i]);
        }
    }
}
