//! `nysx::succinct` — dependency-free succinct data structures
//! (DESIGN.md §10): the memory layer under the paper's on-chip budget
//! claims, built in three tiers:
//!
//! * [`bits`] — [`BitBuf`] (append/extract bit packing) and [`BitVec`]
//!   with O(1) `rank1`/`select1` over an interleaved poppy-style
//!   directory (~3.2% overhead) plus broadword select-in-word.
//! * [`elias_fano`] — [`EliasFano`], the monotone-sequence codec behind
//!   compressed CSR row offsets ([`crate::sparse::RowOffsets`]) and the
//!   model-v3 artifact sections.
//! * [`phast`] — [`PhastMph`], the bucketed seeded MPH (≈2.7 bits/key
//!   at codebook scale) serving as the default engine behind
//!   [`crate::mph::MphLookup`], with the BBHash cascade retained as its
//!   differential oracle.
//!
//! Everything here is in the deterministic kernel set: no hash-order
//! containers, no clocks, no ambient RNG — structures are pure
//! functions of their inputs at any thread count.

pub mod bits;
pub mod elias_fano;
pub mod phast;

pub use bits::{select_in_word, BitBuf, BitVec};
pub use elias_fano::EliasFano;
pub use phast::PhastMph;
