//! Bit-level foundation of the succinct layer (DESIGN.md §10): an
//! append-only bit buffer ([`BitBuf`], the raw storage every codec in
//! this module writes into) and an immutable bit vector with O(1)
//! `rank1`/`select1` ([`BitVec`]).
//!
//! The rank directory is the interleaved superblock/block layout
//! (poppy-style): one u64 per 2048-bit block holding a 32-bit absolute
//! count (ones before the block) and three 10-bit counts for the first
//! three 512-bit sub-blocks — 3.1% space overhead, and a rank touches
//! exactly one directory word plus at most eight payload words.
//! `select1` narrows to a block via sampled hints + binary search on the
//! absolute counts, walks the sub-block counts, then finishes with a
//! branch-free broadword select-in-word (SWAR byte prefix sums + a
//! 2048-entry select-in-byte table).

/// Append-only bit buffer: fixed-width little-endian-in-word bit codes.
///
/// The write side of every succinct structure: Elias–Fano low bits and
/// Rice remainders are `push_bits` calls, unary codes are built a bit at
/// a time. Reads are random-access (`get_bits` crosses word boundaries).
#[derive(Debug, Clone, PartialEq)]
pub struct BitBuf {
    words: Vec<u64>,
    len: usize,
}

impl Default for BitBuf {
    fn default() -> Self {
        Self::new()
    }
}

impl BitBuf {
    pub fn new() -> Self {
        Self {
            words: Vec::new(),
            len: 0,
        }
    }

    pub fn with_capacity(bits: usize) -> Self {
        Self {
            words: Vec::with_capacity(bits.div_ceil(64)),
            len: 0,
        }
    }

    /// Reconstruct from raw words (artifact load path). Bits at and past
    /// `len` must be zero so serialization round-trips bit-identically;
    /// returns `None` when the shape or the tail padding is wrong.
    pub fn from_words(words: Vec<u64>, len: usize) -> Option<Self> {
        if words.len() != len.div_ceil(64) {
            return None;
        }
        if let Some(&last) = words.last() {
            let tail = len % 64;
            if tail != 0 && (last >> tail) != 0 {
                return None;
            }
        }
        Some(Self { words, len })
    }

    /// Number of bits written.
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Append the low `width` bits of `value` (width <= 64).
    #[inline]
    pub fn push_bits(&mut self, value: u64, width: u32) {
        debug_assert!(width <= 64);
        debug_assert!(width == 64 || value >> width == 0, "value wider than width");
        if width == 0 {
            return;
        }
        let bit = self.len % 64;
        if bit == 0 {
            self.words.push(value);
        } else {
            let last = self.words.len() - 1;
            self.words[last] |= value << bit;
            if bit + width as usize > 64 {
                self.words.push(value >> (64 - bit));
            }
        }
        self.len += width as usize;
        // Clear any garbage above len in the last word (value << bit can
        // only have set bits below bit+width, so nothing to do — the
        // invariant holds by construction).
    }

    /// Append a single bit.
    #[inline]
    pub fn push_bit(&mut self, bit: bool) {
        self.push_bits(u64::from(bit), 1);
    }

    /// Append `count` zero bits.
    pub fn push_zeros(&mut self, count: usize) {
        let new_len = self.len + count;
        self.words.resize(new_len.div_ceil(64), 0);
        self.len = new_len;
    }

    /// Read `width` bits starting at bit `pos` (width <= 64).
    #[inline]
    pub fn get_bits(&self, pos: usize, width: u32) -> u64 {
        debug_assert!(width <= 64);
        debug_assert!(pos + width as usize <= self.len, "bit read out of range");
        if width == 0 {
            return 0;
        }
        let word = pos / 64;
        let bit = pos % 64;
        let lo = self.words[word] >> bit;
        let got = 64 - bit as u32;
        let v = if got >= width {
            lo
        } else {
            lo | (self.words[word + 1] << got)
        };
        if width == 64 {
            v
        } else {
            v & ((1u64 << width) - 1)
        }
    }

    /// Heap payload bytes.
    pub fn bytes(&self) -> usize {
        self.words.len() * 8
    }
}

// --- broadword select-in-word -------------------------------------------

const ONES_STEP_4: u64 = 0x1111_1111_1111_1111;
const ONES_STEP_8: u64 = 0x0101_0101_0101_0101;
const MSBS_STEP_8: u64 = 0x8080_8080_8080_8080;

/// Per-byte x <= y comparison for byte values < 128: MSB of byte i of
/// the result is set iff byte i of `x` is <= byte i of `y`. Borrow-free
/// because each byte of `(y | 0x80) - x` stays non-negative when both
/// operand bytes are below 128 — true here (cumulative popcounts <= 64,
/// ranks <= 63).
#[inline]
fn leq_bytes_lt128(x: u64, y: u64) -> u64 {
    ((y | MSBS_STEP_8) - x) & MSBS_STEP_8
}

/// Position of the r-th (0-based) set bit within one byte, for all 256
/// byte values × 8 ranks. Built at compile time; 2 KiB.
const SELECT_IN_BYTE: [u8; 2048] = {
    let mut table = [0u8; 2048];
    let mut rank = 0usize;
    while rank < 8 {
        let mut byte = 0usize;
        while byte < 256 {
            let mut seen = 0usize;
            let mut bit = 0usize;
            let mut found = 8u8; // out-of-range marker for infeasible ranks
            while bit < 8 {
                if byte & (1 << bit) != 0 {
                    if seen == rank {
                        found = bit as u8;
                        break;
                    }
                    seen += 1;
                }
                bit += 1;
            }
            table[(rank << 8) | byte] = found;
            byte += 1;
        }
        rank += 1;
    }
    table
};

/// Position of the r-th (0-based) set bit of `x`. Branch-free broadword:
/// SWAR popcount folded into cumulative byte sums, a parallel byte
/// comparison locating the byte, then the select-in-byte table.
/// `r < x.count_ones()` is the caller's contract.
#[inline]
pub fn select_in_word(x: u64, r: u32) -> u32 {
    debug_assert!(r < x.count_ones(), "select_in_word rank out of range");
    // Cumulative popcounts: byte i of byte_sums = ones in bytes 0..=i.
    let mut byte_sums = x - ((x & (0xA * ONES_STEP_4)) >> 1);
    byte_sums = (byte_sums & (0x3 * ONES_STEP_4)) + ((byte_sums >> 2) & (0x3 * ONES_STEP_4));
    byte_sums = (byte_sums + (byte_sums >> 4)) & (0xF * ONES_STEP_8);
    byte_sums = byte_sums.wrapping_mul(ONES_STEP_8);
    // Count the bytes whose cumulative sum is <= r: that count × 8 is the
    // bit offset of the byte holding the r-th one.
    let k_step_8 = (r as u64) * ONES_STEP_8;
    let leq = leq_bytes_lt128(byte_sums, k_step_8);
    let place = (((leq >> 7).wrapping_mul(ONES_STEP_8) >> 56) * 8) as u32;
    let byte_rank = (r as u64) - (((byte_sums << 8) >> place) & 0xFF);
    place + SELECT_IN_BYTE[(((x >> place) & 0xFF) as usize) | ((byte_rank as usize) << 8)] as u32
}

// --- BitVec with O(1) rank/select ----------------------------------------

/// Payload words per directory block (2048 bits).
const BLOCK_WORDS: usize = 32;
/// Payload words per sub-block (512 bits).
const SUB_WORDS: usize = 8;
/// One select hint (block index) per this many ones.
const SELECT_SAMPLE: usize = 4096;

/// Immutable bit vector with O(1) `rank1` and `select1`.
///
/// Space: payload + 64 bits per 2048 (the interleaved directory) + a u32
/// hint per 4096 ones — ~3.2% overhead over the raw bits.
#[derive(Debug, Clone, PartialEq)]
pub struct BitVec {
    words: Vec<u64>,
    len: usize,
    ones: usize,
    /// One u64 per 2048-bit block: bits [0,32) = ones before the block;
    /// bits [32+10j, 42+10j) for j in 0..3 = ones in the block's j-th
    /// 512-bit sub-block (the fourth count is implied).
    dir: Vec<u64>,
    /// Block index of every `SELECT_SAMPLE`-th one.
    hints: Vec<u32>,
}

impl BitVec {
    /// Build from raw words; bits at and past `len` must be zero (the
    /// constructor asserts it — rank over the tail depends on it).
    pub fn from_words(words: Vec<u64>, len: usize) -> Self {
        assert_eq!(words.len(), len.div_ceil(64), "word count / len mismatch");
        assert!(len <= u32::MAX as usize, "BitVec capped at 2^32 bits");
        if let Some(&last) = words.last() {
            let tail = len % 64;
            assert!(
                tail == 0 || last >> tail == 0,
                "bits past len must be zero"
            );
        }
        let num_blocks = len.div_ceil(BLOCK_WORDS * 64).max(1);
        let mut dir = Vec::with_capacity(num_blocks);
        let mut hints = Vec::new();
        let mut abs = 0usize;
        for b in 0..num_blocks {
            let mut entry = abs as u64;
            let mut block_ones = 0usize;
            for sub in 0..4 {
                let start = b * BLOCK_WORDS + sub * SUB_WORDS;
                let end = (start + SUB_WORDS).min(words.len());
                let sub_ones: u32 = words
                    .get(start.min(words.len())..end)
                    .unwrap_or(&[])
                    .iter()
                    .map(|w| w.count_ones())
                    .sum();
                if sub < 3 {
                    entry |= (sub_ones as u64) << (32 + 10 * sub);
                }
                block_ones += sub_ones as usize;
            }
            // Sampled select hints: record the block of every
            // SELECT_SAMPLE-th one as the counts pass it.
            while hints.len() * SELECT_SAMPLE < abs + block_ones
                && hints.len() * SELECT_SAMPLE >= abs
            {
                hints.push(b as u32);
            }
            dir.push(entry);
            abs += block_ones;
        }
        Self {
            words,
            len,
            ones: abs,
            dir,
            hints,
        }
    }

    /// Build from an iterator of bools.
    pub fn from_bools(bits: impl IntoIterator<Item = bool>) -> Self {
        let mut buf = BitBuf::new();
        for b in bits {
            buf.push_bit(b);
        }
        Self::from_buf(&buf)
    }

    /// Build from a finished [`BitBuf`].
    pub fn from_buf(buf: &BitBuf) -> Self {
        Self::from_words(buf.words().to_vec(), buf.len())
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Total number of set bits.
    #[inline]
    pub fn ones(&self) -> usize {
        self.ones
    }

    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len, "bit index out of range");
        self.words[i / 64] >> (i % 64) & 1 != 0
    }

    /// Ones in `[0, i)`; `i` may equal `len`.
    #[inline]
    pub fn rank1(&self, i: usize) -> usize {
        debug_assert!(i <= self.len, "rank index out of range");
        if i == 0 {
            return 0;
        }
        if i == self.len {
            // Also keeps block/word indexing in range when len is an
            // exact block or word multiple.
            return self.ones;
        }
        let block = i / (BLOCK_WORDS * 64);
        let entry = self.dir[block];
        let mut r = (entry & 0xFFFF_FFFF) as usize;
        let sub = (i / (SUB_WORDS * 64)) % 4;
        for j in 0..sub {
            r += ((entry >> (32 + 10 * j)) & 0x3FF) as usize;
        }
        let word = i / 64;
        for w in (block * BLOCK_WORDS + sub * SUB_WORDS)..word {
            r += self.words[w].count_ones() as usize;
        }
        let bit = i % 64;
        if bit != 0 {
            r += (self.words[word] & ((1u64 << bit) - 1)).count_ones() as usize;
        }
        r
    }

    /// Position of the k-th (0-based) set bit. `k < ones()` is the
    /// caller's contract (asserted).
    pub fn select1(&self, k: usize) -> usize {
        assert!(k < self.ones, "select1 rank {k} >= ones {}", self.ones);
        // Hint window: the k/SAMPLE-th sampled one lives in hints[k/S],
        // the next sample bounds the search from above.
        let sample = k / SELECT_SAMPLE;
        let mut lo = self.hints[sample] as usize;
        let mut hi = self
            .hints
            .get(sample + 1)
            .map_or(self.dir.len(), |&b| b as usize + 1);
        // Binary search the last block whose absolute count is <= k.
        while hi - lo > 1 {
            let mid = lo + (hi - lo) / 2;
            if (self.dir[mid] & 0xFFFF_FFFF) as usize <= k {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let entry = self.dir[lo];
        let mut rem = k - (entry & 0xFFFF_FFFF) as usize;
        // Walk the three explicit sub-block counts.
        let mut sub = 0usize;
        while sub < 3 {
            let c = ((entry >> (32 + 10 * sub)) & 0x3FF) as usize;
            if rem < c {
                break;
            }
            rem -= c;
            sub += 1;
        }
        // At most eight payload words, then broadword select-in-word.
        let mut word = lo * BLOCK_WORDS + sub * SUB_WORDS;
        loop {
            let ones = self.words[word].count_ones() as usize;
            if rem < ones {
                return word * 64 + select_in_word(self.words[word], rem as u32) as usize;
            }
            rem -= ones;
            word += 1;
        }
    }

    /// Heap payload bytes (words + directory + hints).
    pub fn bytes(&self) -> usize {
        self.words.len() * 8 + self.dir.len() * 8 + self.hints.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{forall, PropConfig};
    use crate::util::rng::Xoshiro256;

    /// Naive oracle over a plain bool vector.
    struct Naive(Vec<bool>);

    impl Naive {
        fn rank1(&self, i: usize) -> usize {
            self.0[..i].iter().filter(|&&b| b).count()
        }
        fn select1(&self, k: usize) -> usize {
            self.0
                .iter()
                .enumerate()
                .filter(|(_, &b)| b)
                .nth(k)
                .map(|(i, _)| i)
                .expect("select oracle rank in range")
        }
    }

    fn check_all(bits: &[bool]) {
        let bv = BitVec::from_bools(bits.iter().copied());
        let oracle = Naive(bits.to_vec());
        assert_eq!(bv.len(), bits.len());
        let total = oracle.rank1(bits.len());
        assert_eq!(bv.ones(), total);
        for i in 0..=bits.len() {
            assert_eq!(bv.rank1(i), oracle.rank1(i), "rank1({i}) on len {}", bits.len());
        }
        for k in 0..total {
            assert_eq!(bv.select1(k), oracle.select1(k), "select1({k})");
        }
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!(bv.get(i), b);
        }
    }

    #[test]
    fn select_in_word_matches_naive_all_ranks() {
        let mut rng = Xoshiro256::seed_from_u64(11);
        let mut words: Vec<u64> = vec![
            1,
            u64::MAX,
            0x8000_0000_0000_0000,
            0x5555_5555_5555_5555,
            0xAAAA_AAAA_AAAA_AAAA,
            0x0100_0000_0000_0080,
        ];
        for _ in 0..200 {
            words.push(rng.next_u64());
        }
        for &w in &words {
            let mut seen = 0u32;
            for bit in 0..64 {
                if w >> bit & 1 != 0 {
                    assert_eq!(select_in_word(w, seen), bit, "word {w:#x} rank {seen}");
                    seen += 1;
                }
            }
        }
    }

    #[test]
    fn empty_and_degenerate() {
        check_all(&[]);
        check_all(&[false]);
        check_all(&[true]);
        let bv = BitVec::from_bools(std::iter::empty());
        assert_eq!(bv.ones(), 0);
        assert_eq!(bv.rank1(0), 0);
    }

    #[test]
    fn boundary_dims_63_64_65() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        for len in [63usize, 64, 65, 127, 128, 129, 511, 512, 513, 2047, 2048, 2049] {
            // Random, all-ones and all-zeros at every boundary length.
            let random: Vec<bool> = (0..len).map(|_| rng.bernoulli(0.4)).collect();
            check_all(&random);
            check_all(&vec![true; len]);
            check_all(&vec![false; len]);
        }
    }

    #[test]
    fn dense_vs_sparse_property() {
        forall("bitvec-vs-naive", PropConfig::default(), |rng, size| {
            let len = size * 67 + rng.gen_range(64);
            // Alternate sparse and dense fills across cases.
            let p = if size % 2 == 0 { 0.02 } else { 0.85 };
            let bits: Vec<bool> = (0..len).map(|_| rng.bernoulli(p)).collect();
            let bv = BitVec::from_bools(bits.iter().copied());
            let oracle = Naive(bits.clone());
            // Spot-check a deterministic sample of positions + all selects.
            for step in 1..4 {
                let i = (len * step) / 4;
                crate::prop_assert!(
                    bv.rank1(i) == oracle.rank1(i),
                    "rank1({i}) mismatch at len {len}"
                );
            }
            for k in 0..bv.ones() {
                crate::prop_assert!(
                    bv.select1(k) == oracle.select1(k),
                    "select1({k}) mismatch at len {len}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn rank_select_inverse() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let bits: Vec<bool> = (0..10_000).map(|_| rng.bernoulli(0.3)).collect();
        let bv = BitVec::from_bools(bits.iter().copied());
        for k in 0..bv.ones() {
            let pos = bv.select1(k);
            assert!(bv.get(pos));
            assert_eq!(bv.rank1(pos), k);
            assert_eq!(bv.rank1(pos + 1), k + 1);
        }
    }

    #[test]
    fn bitbuf_roundtrip_mixed_widths() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut buf = BitBuf::new();
        let mut expect: Vec<(usize, u64, u32)> = Vec::new();
        let mut pos = 0usize;
        for _ in 0..500 {
            let width = 1 + rng.gen_range(64) as u32;
            let value = if width == 64 {
                rng.next_u64()
            } else {
                rng.next_u64() & ((1u64 << width) - 1)
            };
            buf.push_bits(value, width);
            expect.push((pos, value, width));
            pos += width as usize;
        }
        assert_eq!(buf.len(), pos);
        for (p, v, w) in expect {
            assert_eq!(buf.get_bits(p, w), v, "at bit {p} width {w}");
        }
        // Word-level round trip preserves everything.
        let again = BitBuf::from_words(buf.words().to_vec(), buf.len()).expect("valid words");
        assert_eq!(again, buf);
    }

    #[test]
    fn bitbuf_from_words_rejects_bad_shapes() {
        assert!(BitBuf::from_words(vec![0, 0], 65).is_some());
        assert!(BitBuf::from_words(vec![0], 65).is_none(), "too few words");
        assert!(BitBuf::from_words(vec![0, 0, 0], 65).is_none(), "too many");
        // Garbage above len in the tail word breaks round-tripping.
        assert!(BitBuf::from_words(vec![0, 0b10], 65).is_none());
        assert!(BitBuf::from_words(vec![0, 0b1], 65).is_some());
    }

    #[test]
    fn bytes_overhead_is_small() {
        let bits = vec![true; 1 << 20];
        let bv = BitVec::from_bools(bits);
        let payload = (1usize << 20) / 8;
        assert!(
            bv.bytes() < payload + payload / 16,
            "rank/select overhead too large: {} over {payload}",
            bv.bytes()
        );
    }
}
