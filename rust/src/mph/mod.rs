//! Minimal perfect hashing (paper §5.2.2) — a BBHash-style [36] cascade of
//! level bit-arrays with a rank vector, giving O(1) code→index lookups at
//! ≈3 bits/key.
//!
//! Construction: at level `d`, every still-unresolved key hashes into bit
//! array `A_d` (sized `γ × remaining`). Positions hit by exactly one key
//! get a 1 and resolve that key; colliding keys advance to level `d+1`.
//! The final structure concatenates all bit arrays; the **rank vector**
//! stores the cumulative popcount at the start of each 64-bit word, so the
//! MPH index of a key resolved at global bit position `p` is
//! `rank[word(p)] + popcount(bits within word up to p) - 1` — exactly the
//! paper's step (3).
//!
//! Queries use Wang's 64-bit integer hash [57] seeded per level via an
//! xorshift-based rehash sequence [51]. A queried key absent from the
//! original key set either falls through every level (no 1 hit) or lands
//! on some 1 bit — which the **codebook verification** step (paper step 4)
//! catches by comparing the stored code at the returned index.
//!
//! Since the succinct layer landed, this cascade is no longer the
//! default engine: [`MphLookup::build`] routes through the bucketed
//! [`PhastMph`] (≈2.7 bits/key, DESIGN.md §10) behind the [`MphEngine`]
//! enum, and the cascade stays on as the *differential oracle* — the
//! property suite pins both engines to the same bijection contract on
//! every key set, and [`MphLookup::build_capped`] still constructs it
//! directly for the fallback-path tests and sizing ablations.

use crate::succinct::PhastMph;

/// Thomas Wang's 64-bit mix — the paper's seeded integer hash function.
#[inline]
pub fn wang_hash64(mut key: u64) -> u64 {
    key = (!key).wrapping_add(key << 21);
    key ^= key >> 24;
    key = key.wrapping_add(key << 3).wrapping_add(key << 8);
    key ^= key >> 14;
    key = key.wrapping_add(key << 2).wrapping_add(key << 4);
    key ^= key >> 28;
    key = key.wrapping_add(key << 31);
    key
}

/// xorshift64* step — generates the per-level seed sequence (the paper's
/// "xorshift-based rehash generator").
#[inline]
fn xorshift_next(mut seed: u64) -> u64 {
    seed ^= seed << 13;
    seed ^= seed >> 7;
    seed ^= seed << 17;
    seed.wrapping_mul(0x2545F4914F6CDD1D)
}

/// Level-d hash of a key.
#[inline]
fn level_hash(key: u64, level_seed: u64) -> u64 {
    wang_hash64(key ^ level_seed)
}

/// One level's bit array (64-bit words, as banked in BRAM).
#[derive(Debug, Clone)]
struct Level {
    /// Bit capacity |A_d|.
    bits: u64,
    /// Offset (in bits) of this level within the concatenated structure.
    bit_offset: u64,
    seed: u64,
}

/// The minimal perfect hash function over a fixed key set.
#[derive(Debug, Clone)]
pub struct Mph {
    levels: Vec<Level>,
    /// Concatenated bit arrays of all levels.
    words: Vec<u64>,
    /// rank[w] = number of 1s in words[0..w].
    rank: Vec<u32>,
    /// Number of keys (= number of set bits).
    num_keys: usize,
    /// Keys that failed to resolve within `max_levels` (kept for
    /// completeness; γ=1.5 makes this virtually empty).
    fallback: std::collections::HashMap<u64, u32>,
    /// Load factor γ used at construction.
    gamma: f64,
}

/// Construction/lookup statistics (drives the §5.2.2 "≈3 bits/key" claim
/// and the MPHE cycle model).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MphStats {
    pub num_keys: usize,
    pub levels: usize,
    pub total_bits: u64,
    pub bits_per_key: f64,
    /// Expected number of level probes for a present key.
    pub expected_probes: f64,
    pub fallback_keys: usize,
}

/// Default maximum number of cascade levels: deep enough that fallback
/// stays virtually empty at any sane γ.
const DEFAULT_MAX_LEVELS: usize = 48;

impl Mph {
    /// Build over a distinct key set with load factor `gamma` (paper-style
    /// default 1.5; larger = fewer levels, more bits).
    pub fn build(keys: &[u64], gamma: f64) -> Self {
        Self::build_capped(keys, gamma, DEFAULT_MAX_LEVELS)
    }

    /// Build with an explicit cascade-depth cap. Keys unresolved after
    /// `max_levels` land in the exact-match `fallback` store. Production
    /// goes through [`Self::build`] (deep cascade, fallback virtually
    /// empty); a small cap deterministically forces fallback population,
    /// which the absent-key property tests and sizing ablations rely on.
    pub fn build_capped(keys: &[u64], gamma: f64, max_levels: usize) -> Self {
        assert!(gamma >= 1.0);
        let mut remaining: Vec<u64> = keys.to_vec();
        {
            let mut seen = std::collections::HashSet::with_capacity(keys.len());
            for &k in keys {
                assert!(seen.insert(k), "duplicate key {k} in MPH key set");
            }
        }
        let mut levels = Vec::new();
        let mut all_bits: Vec<u64> = Vec::new(); // words
        let mut bit_offset = 0u64;
        let mut seed = 0x9E3779B97F4A7C15u64;

        while !remaining.is_empty() && levels.len() < max_levels {
            seed = xorshift_next(seed);
            let bits = ((remaining.len() as f64 * gamma).ceil() as u64).max(64);
            let nwords = bits.div_ceil(64) as usize;
            let word_base = all_bits.len();
            all_bits.resize(word_base + nwords, 0);

            // Count collisions: 0 = empty, 1 = unique, 2 = collision.
            let mut occupancy = vec![0u8; bits as usize];
            for &k in &remaining {
                let pos = (level_hash(k, seed) % bits) as usize;
                occupancy[pos] = occupancy[pos].saturating_add(1);
            }
            let mut next = Vec::new();
            for &k in &remaining {
                let pos = (level_hash(k, seed) % bits) as usize;
                if occupancy[pos] == 1 {
                    all_bits[word_base + pos / 64] |= 1u64 << (pos % 64);
                } else {
                    next.push(k);
                }
            }
            levels.push(Level {
                bits,
                bit_offset,
                seed,
            });
            bit_offset += nwords as u64 * 64;
            remaining = next;
        }

        // Rank vector over the concatenated words.
        let mut rank = Vec::with_capacity(all_bits.len() + 1);
        let mut acc = 0u32;
        for &w in &all_bits {
            rank.push(acc);
            acc += w.count_ones();
        }
        rank.push(acc);

        let resolved = acc as usize;
        let mut mph = Self {
            levels,
            words: all_bits,
            rank,
            num_keys: keys.len(),
            fallback: std::collections::HashMap::new(),
            gamma,
        };
        // Any stragglers (astronomically rare at γ≥1.5 with 48 levels) get
        // indices after the rank-addressable range.
        if resolved < keys.len() {
            let mut next_idx = resolved as u32;
            for &k in keys {
                if mph.rank_index(k).is_none() {
                    mph.fallback.insert(k, next_idx);
                    next_idx += 1;
                }
            }
        }
        mph
    }

    /// Probe the level cascade; `Some((index, probes))` when a set bit is
    /// hit. NOTE: for keys outside the construction set this may return a
    /// bogus index — callers verify via their codebook store (paper step 4).
    #[inline]
    fn rank_index_probes(&self, key: u64) -> Option<(u32, u32)> {
        for (d, level) in self.levels.iter().enumerate() {
            let pos = level_hash(key, level.seed) % level.bits;
            let global = level.bit_offset + pos;
            let w = (global / 64) as usize;
            let b = global % 64;
            let word = self.words[w];
            if (word >> b) & 1 == 1 {
                let within = (word & ((1u64 << b) | ((1u64 << b) - 1))).count_ones();
                return Some((self.rank[w] + within - 1, d as u32 + 1));
            }
        }
        None
    }

    fn rank_index(&self, key: u64) -> Option<u32> {
        self.rank_index_probes(key).map(|(i, _)| i)
    }

    /// O(1) lookup: MPH index in [0, num_keys) for keys in the key set;
    /// arbitrary-or-None for other keys (must be verified downstream).
    #[inline]
    pub fn index(&self, key: u64) -> Option<u32> {
        if let Some(&i) = self.fallback.get(&key) {
            return Some(i);
        }
        self.rank_index(key)
    }

    /// Lookup returning the number of level probes performed (feeds the
    /// MPHE cycle model).
    #[inline]
    pub fn index_with_probes(&self, key: u64) -> (Option<u32>, u32) {
        if let Some(&i) = self.fallback.get(&key) {
            return (Some(i), 1);
        }
        match self.rank_index_probes(key) {
            Some((i, p)) => (Some(i), p),
            None => (None, self.levels.len() as u32),
        }
    }

    pub fn num_keys(&self) -> usize {
        self.num_keys
    }

    pub fn num_levels(&self) -> usize {
        self.levels.len()
    }

    /// On-chip bytes: level bit arrays + rank vector (+ fallback).
    pub fn bytes(&self) -> usize {
        self.words.len() * 8 + self.rank.len() * 4 + self.fallback.len() * 12
    }

    pub fn stats(&self, sample_keys: &[u64]) -> MphStats {
        let total_bits = self.words.len() as u64 * 64;
        let probes: u64 = sample_keys
            .iter()
            .map(|&k| self.index_with_probes(k).1 as u64)
            .sum();
        MphStats {
            num_keys: self.num_keys,
            levels: self.levels.len(),
            total_bits,
            bits_per_key: if self.num_keys > 0 {
                total_bits as f64 / self.num_keys as f64
            } else {
                0.0
            },
            expected_probes: if sample_keys.is_empty() {
                0.0
            } else {
                probes as f64 / sample_keys.len() as f64
            },
            fallback_keys: self.fallback.len(),
        }
    }

    pub fn gamma(&self) -> f64 {
        self.gamma
    }
}

/// The pluggable MPH engine behind [`MphLookup`]: the succinct bucketed
/// hash is the production default; the BBHash cascade remains available
/// as the differential oracle and for fallback-path coverage.
#[derive(Debug, Clone)]
pub enum MphEngine {
    /// Bucketed seeded MPH ([`crate::succinct::phast`], ≈2.7 bits/key).
    Phast(PhastMph),
    /// The original level cascade (≈4+ bits/key, kept as oracle).
    Legacy(Mph),
}

impl MphEngine {
    /// O(1) lookup; both engines share the contract that an absent key
    /// resolves to `None` or an in-range index the store rejects.
    #[inline]
    pub fn index(&self, key: u64) -> Option<u32> {
        match self {
            MphEngine::Phast(p) => p.index(key),
            MphEngine::Legacy(m) => m.index(key),
        }
    }

    /// Lookup with probe count (MPHE cycle-model hook). The bucketed
    /// engine always probes exactly one slot; the cascade reports its
    /// level walk.
    #[inline]
    pub fn index_with_probes(&self, key: u64) -> (Option<u32>, u32) {
        match self {
            MphEngine::Phast(p) => (p.index(key), 1),
            MphEngine::Legacy(m) => m.index_with_probes(key),
        }
    }

    pub fn num_keys(&self) -> usize {
        match self {
            MphEngine::Phast(p) => p.num_keys(),
            MphEngine::Legacy(m) => m.num_keys(),
        }
    }

    /// Structure bytes (both engines count payload only).
    pub fn bytes(&self) -> usize {
        match self {
            MphEngine::Phast(p) => p.bytes(),
            MphEngine::Legacy(m) => m.bytes(),
        }
    }

    pub fn bits_per_key(&self) -> f64 {
        if self.num_keys() == 0 {
            0.0
        } else {
            self.bytes() as f64 * 8.0 / self.num_keys() as f64
        }
    }

    /// The cascade, when this engine is one (fallback/sizing tests).
    pub fn legacy(&self) -> Option<&Mph> {
        match self {
            MphEngine::Legacy(m) => Some(m),
            MphEngine::Phast(_) => None,
        }
    }
}

/// The full MPHE lookup structure: MPH + the compact codebook store of
/// `(code, hist_idx)` pairs addressed by MPH index (paper step 4).
#[derive(Debug, Clone)]
pub struct MphLookup {
    pub mph: MphEngine,
    /// store[mph_index] = (code, hist_idx)
    store: Vec<(u64, u32)>,
}

impl MphLookup {
    /// Build from parallel arrays: key i maps to value `values[i]`.
    /// Routes to the succinct bucketed engine (`gamma` only shapes the
    /// legacy cascade and is ignored here; kept so callers configure one
    /// build surface). Uses the process-wide pool for the seed search.
    pub fn build(keys: &[u64], values: &[u32], gamma: f64) -> Self {
        let _ = gamma;
        Self::build_with_pool(keys, values, &crate::exec::global())
    }

    /// [`Self::build`] on an explicit pool (thread count never changes
    /// the structure).
    pub fn build_with_pool(keys: &[u64], values: &[u32], pool: &crate::exec::Pool) -> Self {
        assert_eq!(keys.len(), values.len());
        let engine = MphEngine::Phast(PhastMph::build_with_pool(keys, pool));
        Self::with_store(engine, keys, values)
    }

    /// Build on the *legacy cascade* with an explicit depth cap (see
    /// [`Mph::build_capped`]): small caps force keys into the fallback
    /// store, exercising the verification path the deep cascade almost
    /// never reaches. Also the constructor the differential suite uses
    /// to pit the oracle engine against the default one.
    pub fn build_capped(keys: &[u64], values: &[u32], gamma: f64, max_levels: usize) -> Self {
        assert_eq!(keys.len(), values.len());
        let engine = MphEngine::Legacy(Mph::build_capped(keys, gamma, max_levels));
        Self::with_store(engine, keys, values)
    }

    fn with_store(mph: MphEngine, keys: &[u64], values: &[u32]) -> Self {
        let mut store = vec![(0u64, 0u32); keys.len()];
        for (i, &k) in keys.iter().enumerate() {
            let idx = mph.index(k).expect("constructed key must resolve") as usize;
            store[idx] = (k, values[i]);
        }
        Self { mph, store }
    }

    /// Verified O(1) lookup: returns the stored value only when the code
    /// matches (paper's codebook-verification step).
    #[inline]
    pub fn get(&self, key: u64) -> Option<u32> {
        match self.mph.index(key) {
            Some(idx) => {
                let (stored_key, value) = self.store[idx as usize];
                (stored_key == key).then_some(value)
            }
            None => None,
        }
    }

    /// Lookup with probe count (cycle model hook).
    #[inline]
    pub fn get_with_probes(&self, key: u64) -> (Option<u32>, u32) {
        let (idx, probes) = self.mph.index_with_probes(key);
        match idx {
            Some(idx) => {
                let (stored_key, value) = self.store[idx as usize];
                ((stored_key == key).then_some(value), probes)
            }
            None => (None, probes),
        }
    }

    /// Total on-chip bytes: MPH structure + (code, hist_idx) store.
    pub fn bytes(&self) -> usize {
        self.mph.bytes() + self.store.len() * 12
    }
}

/// Map an i64 LSH code to the u64 key domain (order-preserving offset).
#[inline]
pub fn code_key(code: i64) -> u64 {
    (code as u64) ^ (1u64 << 63)
}

/// Inverse of [`code_key`]: recover the i64 LSH code from its key image
/// (the model loader decodes Elias–Fano'd key sections through this).
#[inline]
pub fn code_from_key(key: u64) -> i64 {
    (key ^ (1u64 << 63)) as i64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn random_keys(n: usize, rng: &mut Xoshiro256) -> Vec<u64> {
        let mut set = std::collections::HashSet::new();
        while set.len() < n {
            set.insert(rng.next_u64());
        }
        set.into_iter().collect()
    }

    /// Property: the function is *perfect* (injective) and *minimal*
    /// (image is exactly [0, n)).
    #[test]
    fn perfect_and_minimal() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for &n in &[1usize, 2, 10, 100, 1000, 5000] {
            let keys = random_keys(n, &mut rng);
            let mph = Mph::build(&keys, 1.5);
            let mut seen = vec![false; n];
            for &k in &keys {
                let idx = mph.index(k).expect("present key must resolve") as usize;
                assert!(idx < n, "index {idx} out of range for n={n}");
                assert!(!seen[idx], "collision at index {idx}");
                seen[idx] = true;
            }
            assert!(seen.iter().all(|&s| s), "not minimal for n={n}");
        }
    }

    #[test]
    fn compact_bits_per_key() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let keys = random_keys(10_000, &mut rng);
        let mph = Mph::build(&keys, 1.5);
        let stats = mph.stats(&keys);
        assert!(
            stats.bits_per_key < 4.5,
            "bits/key too high: {}",
            stats.bits_per_key
        );
        assert_eq!(stats.fallback_keys, 0);
        // Expected probes should be small (geometric-ish decay).
        assert!(stats.expected_probes < 3.0, "probes {}", stats.expected_probes);
    }

    #[test]
    fn verified_lookup_rejects_absent_keys() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let keys = random_keys(2000, &mut rng);
        let values: Vec<u32> = (0..2000u32).collect();
        let lookup = MphLookup::build(&keys, &values, 1.5);
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(lookup.get(k), Some(values[i]));
        }
        let key_set: std::collections::HashSet<u64> = keys.iter().copied().collect();
        let mut absent_checked = 0;
        while absent_checked < 2000 {
            let k = rng.next_u64();
            if !key_set.contains(&k) {
                assert_eq!(lookup.get(k), None, "absent key {k} returned a value");
                absent_checked += 1;
            }
        }
    }

    /// Property (paper step 4): a key OUTSIDE the build set either falls
    /// through every cascade level (`None`) or lands on some set bit —
    /// in which case the rank index stays in `[0, n)` and the codebook
    /// verification rejects it. Never a silent wrong value. Half the
    /// cases cap the cascade depth so the structure carries fallback
    /// keys, covering collisions around the fallback range too.
    #[test]
    fn absent_keys_never_silently_resolve() {
        use crate::testing::{forall, PropConfig};
        forall("mph-absent-keys", PropConfig::default(), |rng, size| {
            let n = 1 + rng.gen_range(96 * size.max(1));
            let keys = random_keys(n, rng);
            let values: Vec<u32> = (0..n as u32).collect();
            let gamma = [1.0, 1.1, 1.5][rng.gen_range(3)];
            let max_levels = if rng.bernoulli(0.5) {
                1 + rng.gen_range(2) // forces fallback population
            } else {
                48
            };
            let lookup = MphLookup::build_capped(&keys, &values, gamma, max_levels);
            // Every built key resolves to its own value — including the
            // ones that collided into the fallback store.
            for (i, &k) in keys.iter().enumerate() {
                crate::prop_assert!(
                    lookup.get(k) == Some(values[i]),
                    "present key {k} lost (n={n}, gamma={gamma}, levels<={max_levels})"
                );
            }
            let key_set: std::collections::HashSet<u64> = keys.iter().copied().collect();
            let mut checked = 0;
            while checked < 100 {
                let k = rng.next_u64();
                if key_set.contains(&k) {
                    continue;
                }
                // The raw MPH may hand back a bogus index, but it must be
                // in range (so the codebook probe is well-defined)...
                let (idx, probes) = lookup.mph.index_with_probes(k);
                if let Some(idx) = idx {
                    crate::prop_assert!(
                        (idx as usize) < n,
                        "absent key {k} indexed out of range ({idx} >= {n})"
                    );
                    crate::prop_assert!(probes >= 1, "a hit needs at least one probe");
                }
                // ...and the verified lookup must reject it outright.
                crate::prop_assert!(
                    lookup.get(k).is_none(),
                    "absent key {k} silently resolved (n={n}, gamma={gamma})"
                );
                let (verified, _) = lookup.get_with_probes(k);
                crate::prop_assert!(verified.is_none(), "get_with_probes leaked a value");
                checked += 1;
            }
            Ok(())
        });
    }

    /// Differential property across *both engines*: on the same key
    /// set, the bucketed default and the legacy cascade are each
    /// bijections onto [0, n), and through the verified lookup an
    /// absent key never aliases a present one's value on either.
    #[test]
    fn engines_agree_on_the_bijection_contract() {
        use crate::testing::{forall, PropConfig};
        forall("mph-engine-differential", PropConfig::default(), |rng, size| {
            let n = 1 + rng.gen_range(120 * size.max(1));
            let keys = if rng.bernoulli(0.5) {
                // Sequential LSH-style codes (the production shape).
                let base = rng.gen_range(1000) as i64 - 500;
                (base..base + n as i64).map(code_key).collect::<Vec<u64>>()
            } else {
                random_keys(n, rng)
            };
            let values: Vec<u32> = (0..n as u32).collect();
            let phast = MphLookup::build(&keys, &values, 1.5);
            let legacy = MphLookup::build_capped(&keys, &values, 1.5, 48);
            for engine in [&phast, &legacy] {
                let mut seen = vec![false; n];
                for &k in &keys {
                    let idx = engine.mph.index(k);
                    let idx = match idx {
                        Some(i) if (i as usize) < n => i as usize,
                        other => {
                            return Err(format!("present key {k} resolved to {other:?} (n={n})"))
                        }
                    };
                    crate::prop_assert!(!seen[idx], "index {idx} hit twice (n={n})");
                    seen[idx] = true;
                }
                crate::prop_assert!(seen.iter().all(|&s| s), "not minimal (n={n})");
            }
            // Verified lookups agree everywhere: identical values on
            // present keys, identical rejections on absent ones.
            for (i, &k) in keys.iter().enumerate() {
                crate::prop_assert!(phast.get(k) == Some(values[i]), "phast lost key {k}");
                crate::prop_assert!(legacy.get(k) == Some(values[i]), "legacy lost key {k}");
            }
            let key_set: std::collections::HashSet<u64> = keys.iter().copied().collect();
            let mut checked = 0;
            while checked < 64 {
                let k = rng.next_u64();
                if key_set.contains(&k) {
                    continue;
                }
                crate::prop_assert!(phast.get(k).is_none(), "phast aliased absent {k}");
                crate::prop_assert!(legacy.get(k).is_none(), "legacy aliased absent {k}");
                checked += 1;
            }
            Ok(())
        });
    }

    /// A capped cascade deterministically lands keys in `fallback`; the
    /// lookup must stay perfect for them and still reject absent keys.
    #[test]
    fn capped_cascade_populates_fallback_and_stays_verified() {
        let keys: Vec<u64> = (0..512i64).map(code_key).collect();
        let values: Vec<u32> = (0..512u32).collect();
        let lookup = MphLookup::build_capped(&keys, &values, 1.0, 1);
        let st = lookup
            .mph
            .legacy()
            .expect("capped build uses the cascade")
            .stats(&keys);
        assert!(
            st.fallback_keys > 0,
            "a 1-level cascade at gamma=1 must overflow into fallback"
        );
        assert_eq!(st.levels, 1);
        for (i, &k) in keys.iter().enumerate() {
            assert_eq!(lookup.get(k), Some(values[i]));
            // Fallback hits report exactly one probe (exact-match store).
            let (v, probes) = lookup.get_with_probes(k);
            assert_eq!(v, Some(values[i]));
            assert!(probes >= 1);
        }
        // Keys adjacent to (but outside) the build range must be rejected.
        for code in 512i64..1024 {
            assert_eq!(lookup.get(code_key(code)), None);
        }
        assert_eq!(lookup.get(code_key(-1)), None);
    }

    #[test]
    fn adversarial_sequential_keys() {
        // LSH codes are small sequential integers — the actual key
        // distribution in NysX.
        let keys: Vec<u64> = (0..3000i64).map(code_key).collect();
        let mph = Mph::build(&keys, 1.5);
        let mut seen = std::collections::HashSet::new();
        for &k in &keys {
            let idx = mph.index(k).unwrap();
            assert!(seen.insert(idx));
            assert!((idx as usize) < keys.len());
        }
    }

    #[test]
    fn code_key_order_preserving() {
        assert!(code_key(-5) < code_key(-4));
        assert!(code_key(-1) < code_key(0));
        assert!(code_key(0) < code_key(1));
        assert!(code_key(i64::MIN) < code_key(i64::MAX));
    }

    #[test]
    fn gamma_tradeoff() {
        // Larger gamma => fewer levels (fewer probes), more bits/key.
        let mut rng = Xoshiro256::seed_from_u64(5);
        let keys = random_keys(5000, &mut rng);
        let tight = Mph::build(&keys, 1.1);
        let loose = Mph::build(&keys, 3.0);
        let st = tight.stats(&keys);
        let sl = loose.stats(&keys);
        assert!(sl.bits_per_key > st.bits_per_key);
        assert!(sl.expected_probes <= st.expected_probes);
    }

    #[test]
    #[should_panic(expected = "duplicate key")]
    fn rejects_duplicates() {
        Mph::build(&[1, 2, 1], 1.5);
    }

    #[test]
    fn empty_key_set() {
        let mph = Mph::build(&[], 1.5);
        assert_eq!(mph.index(123), None);
        assert_eq!(mph.num_keys(), 0);
    }
}
