//! Symmetric eigendecomposition via the cyclic Jacobi method, plus the
//! derived operations the Nyström pipeline needs: pseudo-inverse and the
//! `Λ^{-1/2} Q^T` whitening map (§2.1.2 of the paper), and log-determinants
//! for DPP likelihoods.
//!
//! Jacobi is a good fit here: landmark kernels are small (s ≤ a few
//! hundred), symmetric PSD, and Jacobi is simple, numerically robust and
//! gives orthonormal eigenvectors to machine precision.

use super::dense::Mat;

/// Eigendecomposition `A = Q diag(λ) Q^T` of a symmetric matrix.
/// Eigenvalues are sorted descending; `q` holds eigenvectors as columns.
#[derive(Debug, Clone)]
pub struct SymEigen {
    pub values: Vec<f64>,
    /// n×n orthonormal matrix, column j = eigenvector for values[j].
    pub q: Mat,
}

/// Cyclic Jacobi eigensolver for symmetric matrices.
///
/// Sweeps all off-diagonal (p,q) pairs, rotating each to zero, until the
/// off-diagonal Frobenius mass falls below `tol * ||A||_F` or `max_sweeps`
/// is reached (30 sweeps is far more than ever needed; convergence is
/// quadratic).
pub fn sym_eigen(a: &Mat) -> SymEigen {
    assert_eq!(a.rows, a.cols, "sym_eigen: matrix must be square");
    let n = a.rows;
    let mut m = a.clone();
    // Symmetrize defensively (callers pass kernels that should already be
    // symmetric up to roundoff).
    for i in 0..n {
        for j in (i + 1)..n {
            let avg = 0.5 * (m[(i, j)] + m[(j, i)]);
            m[(i, j)] = avg;
            m[(j, i)] = avg;
        }
    }
    let mut q = Mat::identity(n);
    let fro = m.fro_norm().max(f64::MIN_POSITIVE);
    let tol = 1e-14 * fro;

    for _sweep in 0..30 {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += m[(i, j)] * m[(i, j)];
            }
        }
        if (2.0 * off).sqrt() < tol {
            break;
        }
        for p in 0..n {
            for r in (p + 1)..n {
                let apq = m[(p, r)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(r, r)];
                // Stable rotation computation (Golub & Van Loan 8.4).
                let theta = (aqq - app) / (2.0 * apq);
                let t = if theta >= 0.0 {
                    1.0 / (theta + (1.0 + theta * theta).sqrt())
                } else {
                    1.0 / (theta - (1.0 + theta * theta).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // Apply rotation to rows/cols p and r of m.
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, r)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, r)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(r, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(r, k)] = s * mpk + c * mqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let qkp = q[(k, p)];
                    let qkq = q[(k, r)];
                    q[(k, p)] = c * qkp - s * qkq;
                    q[(k, r)] = s * qkp + c * qkq;
                }
            }
        }
    }

    let mut values: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    // Sort descending, permuting eigenvector columns accordingly.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| values[j].total_cmp(&values[i]));
    let sorted_values: Vec<f64> = order.iter().map(|&i| values[i]).collect();
    let mut sorted_q = Mat::zeros(n, n);
    for (new_col, &old_col) in order.iter().enumerate() {
        for row in 0..n {
            sorted_q[(row, new_col)] = q[(row, old_col)];
        }
    }
    values = sorted_values;
    SymEigen { values, q: sorted_q }
}

impl SymEigen {
    /// Reconstruct `Q diag(values) Q^T`.
    pub fn reconstruct(&self) -> Mat {
        let n = self.values.len();
        let mut scaled = self.q.clone(); // columns scaled by eigenvalue
        for j in 0..n {
            for i in 0..n {
                scaled[(i, j)] *= self.values[j];
            }
        }
        scaled.matmul(&self.q.transpose())
    }

    /// Moore-Penrose pseudo-inverse (eigenvalues below `rcond * λ_max`
    /// treated as zero).
    pub fn pseudo_inverse(&self, rcond: f64) -> Mat {
        let n = self.values.len();
        let lmax = self.values.iter().cloned().fold(0.0, f64::max).max(0.0);
        let cutoff = rcond * lmax;
        let mut scaled = self.q.clone();
        for j in 0..n {
            let inv = if self.values[j] > cutoff {
                1.0 / self.values[j]
            } else {
                0.0
            };
            for i in 0..n {
                scaled[(i, j)] *= inv;
            }
        }
        scaled.matmul(&self.q.transpose())
    }

    /// The Nyström whitening map `W = Λ^{-1/2} Q^T` (rank-truncated at
    /// `rcond * λ_max`), so that `W^T W = H_Z^+`. Shape: n×n (rows for
    /// zeroed eigenvalues are zero).
    pub fn whitening(&self, rcond: f64) -> Mat {
        let n = self.values.len();
        let lmax = self.values.iter().cloned().fold(0.0, f64::max).max(0.0);
        let cutoff = rcond * lmax;
        let qt = self.q.transpose();
        let mut w = Mat::zeros(n, n);
        for i in 0..n {
            let scale = if self.values[i] > cutoff {
                1.0 / self.values[i].sqrt()
            } else {
                0.0
            };
            for j in 0..n {
                w[(i, j)] = scale * qt[(i, j)];
            }
        }
        w
    }

    /// log det(A + eps I) — used by greedy DPP MAP selection.
    pub fn log_det(&self, eps: f64) -> f64 {
        self.values.iter().map(|&l| (l + eps).max(1e-300).ln()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn random_symmetric(n: usize, rng: &mut Xoshiro256) -> Mat {
        let a = Mat::randn(n, n, rng);
        let mut s = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                s[(i, j)] = 0.5 * (a[(i, j)] + a[(j, i)]);
            }
        }
        s
    }

    fn random_psd(n: usize, rng: &mut Xoshiro256) -> Mat {
        let a = Mat::randn(n, n.max(2), rng);
        a.matmul(&a.transpose())
    }

    #[test]
    fn eigen_reconstructs() {
        let mut rng = Xoshiro256::seed_from_u64(100);
        for n in [1usize, 2, 5, 12, 30] {
            let a = random_symmetric(n, &mut rng);
            let e = sym_eigen(&a);
            let r = e.reconstruct();
            assert!(
                r.max_abs_diff(&a) < 1e-8 * (1.0 + a.fro_norm()),
                "n={n} err={}",
                r.max_abs_diff(&a)
            );
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let mut rng = Xoshiro256::seed_from_u64(101);
        let a = random_symmetric(10, &mut rng);
        let e = sym_eigen(&a);
        let qtq = e.q.transpose().matmul(&e.q);
        assert!(qtq.max_abs_diff(&Mat::identity(10)) < 1e-10);
    }

    #[test]
    fn values_sorted_descending() {
        let mut rng = Xoshiro256::seed_from_u64(102);
        let a = random_symmetric(15, &mut rng);
        let e = sym_eigen(&a);
        for w in e.values.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Mat::from_rows(vec![vec![2.0, 1.0], vec![1.0, 2.0]]);
        let e = sym_eigen(&a);
        assert!((e.values[0] - 3.0).abs() < 1e-12);
        assert!((e.values[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pseudo_inverse_property() {
        // For PSD A: A A+ A == A.
        let mut rng = Xoshiro256::seed_from_u64(103);
        let a = random_psd(8, &mut rng);
        let e = sym_eigen(&a);
        let pinv = e.pseudo_inverse(1e-12);
        let back = a.matmul(&pinv).matmul(&a);
        assert!(back.max_abs_diff(&a) < 1e-6 * (1.0 + a.fro_norm()));
    }

    #[test]
    fn whitening_squares_to_pinv() {
        // W^T W == A+ for PSD A.
        let mut rng = Xoshiro256::seed_from_u64(104);
        let a = random_psd(6, &mut rng);
        let e = sym_eigen(&a);
        let w = e.whitening(1e-12);
        let wtw = w.transpose().matmul(&w);
        let pinv = e.pseudo_inverse(1e-12);
        assert!(wtw.max_abs_diff(&pinv) < 1e-8 * (1.0 + pinv.fro_norm()));
    }

    #[test]
    fn rank_deficient_handled() {
        // Rank-1 PSD matrix: vv^T.
        let v = Mat::from_vec(4, 1, vec![1.0, 2.0, -1.0, 0.5]);
        let a = v.matmul(&v.transpose());
        let e = sym_eigen(&a);
        assert!(e.values[0] > 1.0);
        for &l in &e.values[1..] {
            assert!(l.abs() < 1e-10);
        }
        let pinv = e.pseudo_inverse(1e-10);
        // A+ A A+ == A+
        let back = pinv.matmul(&a).matmul(&pinv);
        assert!(back.max_abs_diff(&pinv) < 1e-8);
    }

    #[test]
    fn log_det_identity_zero() {
        let e = sym_eigen(&Mat::identity(5));
        assert!(e.log_det(0.0).abs() < 1e-10);
    }
}
