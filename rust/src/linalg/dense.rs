//! Dense row-major matrices over `f64` with the operations the
//! Nyström/DPP/HDC pipeline needs: matmul, matvec, transpose, norms.
//!
//! We deliberately keep a single scalar type (f64) for the *math* path;
//! the deployed accelerator/functional model quantizes where the paper
//! does (bipolar HVs, integer histograms, f32 streaming of `P_nys`).

use crate::util::rng::Xoshiro256;

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Self {
            rows: r,
            cols: c,
            data: rows.into_iter().flatten().collect(),
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// I.i.d. standard-normal entries (used for random hyperplane
    /// projections P_rp and LSH vectors u).
    pub fn randn(rows: usize, cols: usize, rng: &mut Xoshiro256) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal()).collect();
        Self { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// self (r×k) @ other (k×c) -> (r×c). Cache-friendly ikj loop.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            let a_row = self.row(i);
            let out_row = out.row_mut(i);
            for (k, &a) in a_row.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let b_row = other.row(k);
                for (o, &b) in out_row.iter_mut().zip(b_row.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// self (r×c) @ x (c) -> (r).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len(), "matvec shape mismatch");
        (0..self.rows)
            .map(|i| dot(self.row(i), x))
            .collect()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn scale(&mut self, a: f64) {
        for x in &mut self.data {
            *x *= a;
        }
    }

    /// Max |a - b| over entries.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

/// Dot product.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0;
    for (x, y) in a.iter().zip(b.iter()) {
        acc += x * y;
    }
    acc
}

/// Euclidean norm.
pub fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// a += alpha * b
pub fn axpy(a: &mut [f64], alpha: f64, b: &[f64]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b.iter()) {
        *x += alpha * y;
    }
}

/// Cosine similarity; 0 when either vector is zero.
pub fn cosine(a: &[f64], b: &[f64]) -> f64 {
    let na = norm(a);
    let nb = norm(b);
    if na == 0.0 || nb == 0.0 {
        return 0.0;
    }
    dot(a, b) / (na * nb)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        let a = Mat::randn(4, 4, &mut rng);
        let i = Mat::identity(4);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-12);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(vec![vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let a = Mat::randn(5, 3, &mut rng);
        let x: Vec<f64> = (0..3).map(|_| rng.normal()).collect();
        let xm = Mat::from_vec(3, 1, x.clone());
        let via_mm = a.matmul(&xm);
        let via_mv = a.matvec(&x);
        for i in 0..5 {
            assert!((via_mm[(i, 0)] - via_mv[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Xoshiro256::seed_from_u64(3);
        let a = Mat::randn(3, 7, &mut rng);
        assert!(a.transpose().transpose().max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn transpose_respects_matmul() {
        // (AB)^T == B^T A^T
        let mut rng = Xoshiro256::seed_from_u64(4);
        let a = Mat::randn(4, 6, &mut rng);
        let b = Mat::randn(6, 3, &mut rng);
        let lhs = a.matmul(&b).transpose();
        let rhs = b.transpose().matmul(&a.transpose());
        assert!(lhs.max_abs_diff(&rhs) < 1e-10);
    }

    #[test]
    fn cosine_bounds() {
        let a = [1.0, 0.0];
        let b = [0.0, 2.0];
        assert!((cosine(&a, &a) - 1.0).abs() < 1e-12);
        assert!(cosine(&a, &b).abs() < 1e-12);
        assert_eq!(cosine(&a, &[0.0, 0.0]), 0.0);
    }
}
