//! Dense linear algebra substrate: row-major matrices, cyclic-Jacobi
//! symmetric eigendecomposition, pseudo-inverse and Nyström whitening.

pub mod dense;
pub mod eigen;

pub use dense::{axpy, cosine, dot, norm, Mat};
pub use eigen::{sym_eigen, SymEigen};
