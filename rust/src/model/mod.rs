//! The trained Nyström-HDC model: everything Algorithm 1 needs at
//! inference time, plus the Table-2 memory accounting that drives the
//! paper's Table 8 (memory ± DPP).

pub mod io;
pub mod train;

use crate::hdc::{ClassPrototypes, PackedPrototypes};
use crate::kernel::{Codebook, LshParams};
use crate::mph::MphLookup;
use crate::nystrom::{LandmarkStrategy, NystromProjection};
use crate::sparse::{Csr, SchedulePolicy, ScheduleTable};

/// Hyper-parameters of a training run.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    /// Propagation hops H.
    pub hops: usize,
    /// HV dimensionality d (paper uses 10^4).
    pub hv_dim: usize,
    /// LSH quantization width w (shared across hops).
    pub lsh_width: f64,
    /// Landmark count s.
    pub num_landmarks: usize,
    /// Landmark selection strategy (uniform = NysHD, hybrid DPP = NysX).
    pub strategy: LandmarkStrategy,
    /// MPH load factor γ.
    pub mph_gamma: f64,
    /// PEs in the SpMV engines (schedule-table width).
    pub pes: usize,
    /// Master seed.
    pub seed: u64,
}

impl Default for ModelConfig {
    fn default() -> Self {
        Self {
            hops: 4,
            hv_dim: 10_000,
            lsh_width: 1.0,
            num_landmarks: 64,
            strategy: LandmarkStrategy::HybridDpp { pool_factor: 2 },
            mph_gamma: 1.5,
            pes: 4,
            seed: 0x4e79_7358, // "NysX"
        }
    }
}

impl ModelConfig {
    /// Check every field for values that would make training or
    /// inference meaningless (or panic deep inside the pipeline).
    /// The [`crate::api::Pipeline`] builder and the model-file loader
    /// call this before any heavy work, turning bad user input into a
    /// typed [`crate::api::NysxError::Config`] instead of an assert.
    pub fn validate(&self) -> Result<(), crate::api::NysxError> {
        use crate::api::NysxError;
        // The upper bounds are plausibility caps, not tuning advice: the
        // derived structures (schedule tables sized `iterations × pes`,
        // MPH bit arrays sized `γ·n`) allocate proportionally to these
        // fields, so a corrupt value must be rejected before it reaches
        // the builders.
        if self.hops == 0 || self.hops > 64 {
            return Err(NysxError::Config(format!(
                "hops must be in 1..=64, got {}",
                self.hops
            )));
        }
        if self.hv_dim == 0 || self.hv_dim > 1 << 26 {
            return Err(NysxError::Config(format!(
                "hv_dim must be in 1..=2^26, got {}",
                self.hv_dim
            )));
        }
        if self.num_landmarks == 0 || self.num_landmarks > 1 << 24 {
            return Err(NysxError::Config(format!(
                "num_landmarks must be in 1..=2^24, got {}",
                self.num_landmarks
            )));
        }
        if !(self.lsh_width.is_finite() && self.lsh_width > 0.0) {
            return Err(NysxError::Config(format!(
                "lsh_width must be finite and > 0, got {}",
                self.lsh_width
            )));
        }
        if !(self.mph_gamma.is_finite() && (1.0..=64.0).contains(&self.mph_gamma)) {
            return Err(NysxError::Config(format!(
                "mph_gamma must be a load factor in [1, 64], got {}",
                self.mph_gamma
            )));
        }
        if self.pes == 0 || self.pes > 1 << 16 {
            return Err(NysxError::Config(format!(
                "pes must be in 1..=65536, got {}",
                self.pes
            )));
        }
        if let LandmarkStrategy::HybridDpp { pool_factor } = self.strategy {
            if pool_factor == 0 {
                return Err(NysxError::config("HybridDpp pool_factor must be >= 1"));
            }
        }
        Ok(())
    }
}

/// The trained model — the full parameter set of Algorithm 1.
#[derive(Debug, Clone)]
pub struct NysHdcModel {
    pub config: ModelConfig,
    pub dataset_name: String,
    pub num_classes: usize,
    pub feature_dim: usize,
    /// LSH parameters {(u^(t), b^(t))}, width w.
    pub lsh: LshParams,
    /// Hop-specific codebooks B^(t).
    pub codebooks: Vec<Codebook>,
    /// MPH lookup engines (code→histogram index), one per hop.
    pub lookups: Vec<MphLookup>,
    /// Landmark histogram matrices H^(t) ∈ R^{s×|B^(t)|} in CSR.
    pub landmark_hists: Vec<Csr>,
    /// Static load-balance schedules for each H^(t) (built offline per
    /// §4.2 — these operands never change after training).
    pub kse_schedules: Vec<ScheduleTable>,
    /// Nyström projection P_nys ∈ R^{d×s} (f32 streaming layout).
    pub projection: NystromProjection,
    /// Class prototypes G ∈ {-1,+1}^{C×d} at one sign bit per element —
    /// the operand the SCE hot path matches against, and the only stored
    /// representation. Side computations that need the i8 oracle view
    /// unpack it on demand via [`Self::reference_prototypes`].
    pub packed_prototypes: PackedPrototypes,
    /// Indices of the selected landmark graphs in the training set.
    pub landmark_indices: Vec<usize>,
}

impl NysHdcModel {
    pub fn s(&self) -> usize {
        self.config.num_landmarks
    }

    pub fn d(&self) -> usize {
        self.config.hv_dim
    }

    pub fn hops(&self) -> usize {
        self.config.hops
    }

    /// The i8 oracle view of the prototypes, unpacked on demand. The
    /// model stores only the packed representation; the reference
    /// inference path and differential tests rebuild this view (lossless
    /// — packing is sign-exact on ±1 data).
    pub fn reference_prototypes(&self) -> ClassPrototypes {
        self.packed_prototypes.to_reference()
    }

    /// Rebuild the KSE schedule tables (used after deserialization).
    pub fn build_kse_schedules(hists: &[Csr], pes: usize) -> Vec<ScheduleTable> {
        hists
            .iter()
            .map(|h| ScheduleTable::build(h, pes, SchedulePolicy::NnzGrouped))
            .collect()
    }

    /// Table 2 memory accounting at the deployed bit-widths.
    pub fn memory_report(&self) -> MemoryReport {
        let codebooks: usize = self.codebooks.iter().map(|c| c.bytes()).sum();
        // Paper Table 2 accounts H^(t) densely (s×|B|×b_H); the
        // accelerator stores CSR. Report both.
        let hists_dense: usize = self
            .landmark_hists
            .iter()
            .map(|h| h.rows * h.cols * 4)
            .sum();
        let hists_csr: usize = self.landmark_hists.iter().map(|h| h.csr_bytes(32)).sum();
        let p_nys = self.projection.bytes();
        // Table 2 accounts G at b_G = 8 bits per element (the i8 oracle
        // width), derived from the packed dims without materializing it.
        let prototypes = self.packed_prototypes.num_classes() * self.packed_prototypes.dim();
        let mph: usize = self.lookups.iter().map(|l| l.bytes()).sum();
        let schedules: usize = self.kse_schedules.iter().map(|s| s.table_bytes()).sum();
        MemoryReport {
            codebooks,
            hists_dense,
            hists_csr,
            p_nys,
            prototypes,
            mph,
            schedules,
        }
    }
}

/// Byte counts per component (Table 2 / Table 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemoryReport {
    pub codebooks: usize,
    /// Dense s×|B| accounting (what the paper's Table 2 counts).
    pub hists_dense: usize,
    /// CSR accounting (what the accelerator actually stores).
    pub hists_csr: usize,
    pub p_nys: usize,
    pub prototypes: usize,
    pub mph: usize,
    pub schedules: usize,
}

impl MemoryReport {
    /// Total with dense histogram accounting (paper's Table 2 convention).
    pub fn total_dense(&self) -> usize {
        self.codebooks + self.hists_dense + self.p_nys + self.prototypes
    }

    /// Total as deployed on the accelerator (CSR + MPH + schedules).
    pub fn total_deployed(&self) -> usize {
        self.codebooks + self.hists_csr + self.p_nys + self.prototypes + self.mph + self.schedules
    }

    /// Fraction of total taken by P_nys (the paper's ">90%" claim).
    pub fn p_nys_fraction(&self) -> f64 {
        self.p_nys as f64 / self.total_dense().max(1) as f64
    }
}
