//! The training pipeline (offline; paper §2.2 + §4.1):
//!
//! 1. sample LSH parameters;
//! 2. select `s` landmark graphs (uniform or hybrid Uniform+DPP);
//! 3. build hop-specific codebooks `B^(t)` from the landmark codes;
//! 4. assemble landmark histogram matrices `H^(t)` (CSR) and their §4.2
//!    schedule tables;
//! 5. compute the landmark kernel `H_Z`, eigendecompose, build `P_nys`;
//! 6. single-pass encode all training graphs into class prototypes.

use super::{ModelConfig, NysHdcModel};
use crate::exec::{self, Pool};
use crate::graph::{Graph, GraphDataset};
use crate::hdc::{Hypervector, PackedAccumulator, PackedHypervector};
use crate::kernel::{
    gram_from_signatures_with_pool, node_codes, signatures_with_pool, Codebook, LshParams,
};
use crate::linalg::Mat;
use crate::mph::{code_key, MphLookup};
use crate::nystrom::{select_landmarks_with_pool, NystromProjection};
use crate::sparse::Csr;
use crate::util::rng::Xoshiro256;

/// Train a Nyström-HDC model on a dataset (on the process-wide exec
/// pool; see [`train_with_pool`]).
pub fn train(dataset: &GraphDataset, config: &ModelConfig) -> NysHdcModel {
    train_with_pool(dataset, config, &exec::global())
}

/// Train a Nyström-HDC model on a dataset across an explicit exec pool.
///
/// Parallelism never changes the model: every RNG draw happens in the
/// same sequential order as a single-threaded run (LSH sampling,
/// landmark pool draws, `P_rp`), the heavy stages — DPP pool kernel,
/// landmark signatures/codes, `H_Z`, the d×s² `P_nys` multiply, and the
/// per-graph prototype bundling — are statically partitioned with
/// disjoint writes, and the per-lane bundle counters merge in fixed
/// lane order ([`PackedAccumulator::merge`]). Trained models are
/// bit-identical at any thread count, which the test suite pins.
pub fn train_with_pool(dataset: &GraphDataset, config: &ModelConfig, pool: &Pool) -> NysHdcModel {
    let mut rng = Xoshiro256::seed_from_u64(config.seed);
    let graphs: Vec<&Graph> = dataset.train.iter().map(|(g, _)| g).collect();
    assert!(
        config.num_landmarks <= graphs.len(),
        "s={} exceeds training set size {}",
        config.num_landmarks,
        graphs.len()
    );

    // (1) LSH parameters (shared by training and inference).
    let lsh = LshParams::sample(config.hops, dataset.feature_dim, config.lsh_width, &mut rng);

    // (2) Landmark selection (kernel matrix across the pool's lanes).
    let landmark_indices = select_landmarks_with_pool(
        pool,
        &graphs,
        config.num_landmarks,
        config.strategy,
        &lsh,
        &mut rng,
    );
    let s = landmark_indices.len();

    // (3) Codebooks from landmark codes, hop by hop (codes per landmark
    // graph are independent — one exec part per landmark block).
    let landmark_codes: Vec<Vec<Vec<i64>>> = {
        let ranges = exec::even_ranges(landmark_indices.len(), pool.threads());
        exec::map_parts(pool, ranges.len(), |block| {
            ranges[block]
                .clone()
                .map(|li| node_codes(graphs[landmark_indices[li]], &lsh))
                .collect::<Vec<_>>()
        })
        .into_iter()
        .flatten()
        .collect()
    };
    let codebooks: Vec<Codebook> = (0..config.hops)
        .map(|t| {
            Codebook::build(
                landmark_codes
                    .iter()
                    .flat_map(|codes| codes[t].iter().copied()),
            )
        })
        .collect();

    // (4) Landmark histogram matrices H^(t) ∈ s×|B^(t)| (CSR) and their
    // static schedules.
    let landmark_hists: Vec<Csr> = (0..config.hops)
        .map(|t| {
            let mut triplets = Vec::new();
            for (row, codes) in landmark_codes.iter().enumerate() {
                let mut counts: std::collections::HashMap<u32, f64> = std::collections::HashMap::new();
                for &c in &codes[t] {
                    // Landmark codes are by construction in-vocabulary.
                    let j = codebooks[t].index_of(c).expect("landmark code in B");
                    *counts.entry(j).or_insert(0.0) += 1.0;
                }
                for (j, v) in counts {
                    triplets.push((row, j as usize, v));
                }
            }
            Csr::from_triplets(s, codebooks[t].len(), triplets)
        })
        .collect();
    let kse_schedules = NysHdcModel::build_kse_schedules(&landmark_hists, config.pes);

    // MPH lookup engines over each codebook.
    let lookups: Vec<MphLookup> = codebooks
        .iter()
        .map(|cb| {
            let keys: Vec<u64> = cb.codes.iter().map(|&c| code_key(c)).collect();
            let values: Vec<u32> = (0..cb.len() as u32).collect();
            MphLookup::build_with_pool(&keys, &values, pool)
        })
        .collect();

    // (5) Landmark kernel H_Z from signatures (Σ_t h_i^(t)·h_j^(t)) and the
    // Nyström projection — signatures, the s×s kernel walk and the d×s²
    // P_nys multiply all run across the pool's lanes.
    let landmark_graphs: Vec<&Graph> = landmark_indices.iter().map(|&i| graphs[i]).collect();
    let landmark_sigs = signatures_with_pool(pool, &landmark_graphs, &lsh);
    let h_z: Mat = gram_from_signatures_with_pool(pool, &landmark_sigs);
    debug_assert_eq!(h_z.rows, s);
    let projection = NystromProjection::build_with_pool(pool, &h_z, config.hv_dim, &mut rng);

    let mut model = NysHdcModel {
        config: config.clone(),
        dataset_name: dataset.name.clone(),
        num_classes: dataset.num_classes,
        feature_dim: dataset.feature_dim,
        lsh,
        codebooks,
        lookups,
        landmark_hists,
        kse_schedules,
        projection,
        packed_prototypes: PackedAccumulator::new(dataset.num_classes, config.hv_dim).finalize(),
        landmark_indices,
    };

    // (6) Single-pass prototype training through the fused
    // project-bipolarize-pack path: no i8 (or even f64 y) HV is ever
    // materialized, and the per-bit minus-counters reproduce the i64-sum
    // accumulator bit-for-bit (see `hdc::packed::PackedAccumulator`).
    // The counter updates ripple plane-major through the runtime-
    // dispatched SIMD backend (`hdc::simd::active`), which is
    // bit-identical to scalar by construction, so trained models do not
    // depend on the host's vector ISA.
    //
    // The training split is partitioned into contiguous even blocks,
    // one bundle accumulator per lane, merged afterwards in fixed lane
    // order. Counters are pure per-coordinate counts, so the merged
    // state — and therefore the prototypes — equals the sequential
    // single-accumulator pass exactly, at any thread count.
    let _stage = crate::obs::span(&crate::obs::metrics::STAGE_TRAIN_FINALIZE);
    let ranges = exec::even_ranges(dataset.train.len(), pool.threads());
    let lane_accs: Vec<PackedAccumulator> = exec::map_parts(pool, ranges.len(), |block| {
        let mut acc = PackedAccumulator::new(dataset.num_classes, config.hv_dim);
        let mut c_buf = vec![0.0f64; s];
        let mut hv_buf = PackedHypervector::zeros(config.hv_dim);
        for (g, y) in &dataset.train[ranges[block].clone()] {
            encode_kernel_vector(&model, g, &mut c_buf);
            model.projection.project_pack_into(&c_buf, &mut hv_buf);
            acc.add(*y, &hv_buf);
        }
        acc
    });
    let mut acc = PackedAccumulator::new(dataset.num_classes, config.hv_dim);
    for lane_acc in &lane_accs {
        acc.merge(lane_acc);
    }
    model.packed_prototypes = acc.finalize_with_pool(pool);
    model
}

/// Compute the kernel-similarity vector C(x) ∈ R^s for a graph (Alg. 1
/// lines 1-12) using hashmap codebook lookups — the shared training-side
/// encoder. (The optimized inference engine in `infer::optimized` has its
/// own MPH/scheduled implementation; both are property-tested equal.)
pub fn encode_kernel_vector(model: &NysHdcModel, graph: &Graph, c_out: &mut [f64]) {
    assert_eq!(c_out.len(), model.s());
    c_out.iter_mut().for_each(|v| *v = 0.0);
    let codes = node_codes(graph, &model.lsh);
    for t in 0..model.hops() {
        let cb = &model.codebooks[t];
        let mut hist = vec![0.0f64; cb.len()];
        for &c in &codes[t] {
            if let Some(j) = cb.index_of(c) {
                hist[j as usize] += 1.0;
            }
        }
        // v^(t) = H^(t) h^(t); C += v^(t)
        let h = &model.landmark_hists[t];
        for r in 0..h.rows {
            let mut acc = 0.0;
            for k in h.row_range(r) {
                acc += h.val[k] * hist[h.col_idx[k] as usize];
            }
            c_out[r] += acc;
        }
    }
}

/// Encode a graph all the way to its query HV (training-side path).
pub fn encode_hv(model: &NysHdcModel, graph: &Graph) -> Hypervector {
    let mut c = vec![0.0; model.s()];
    encode_kernel_vector(model, graph, &mut c);
    Hypervector::from_real(&model.projection.project(&c))
}

/// Classification accuracy of a model over a labeled split, or `None`
/// for an empty split (accuracy over nothing is undefined — the old
/// `0.0` was indistinguishable from "every prediction wrong").
///
/// Delegates to [`crate::api::accuracy`] over a fresh batched packed
/// engine: one scratch set, one blocked C×W SCE dispatch per chunk.
/// Bit-identical to the per-graph i8 path — [`evaluate_reference`]
/// stays as the oracle and the `evaluate_matches_i8_reference_path`
/// test pins the two equal.
pub fn evaluate(model: &NysHdcModel, split: &[(Graph, usize)]) -> Option<f64> {
    // The in-process engine has no fallible transport; collapse Result.
    crate::api::accuracy(&mut crate::infer::NysxEngine::new(model), split).unwrap_or(None)
}

/// The pre-batching evaluation path: per-graph hashmap-codebook
/// [`encode_hv`] + i8 prototype matching. Kept as the oracle for
/// [`evaluate`]; not for production use.
pub fn evaluate_reference(model: &NysHdcModel, split: &[(Graph, usize)]) -> Option<f64> {
    if split.is_empty() {
        return None;
    }
    let protos = model.reference_prototypes();
    let correct = split
        .iter()
        .filter(|(g, y)| protos.classify(&encode_hv(model, g)) == *y)
        .count();
    Some(correct as f64 / split.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::tudataset::spec_by_name;
    use crate::kernel::GraphSignature;
    use crate::nystrom::LandmarkStrategy;

    fn small_config(s: usize) -> ModelConfig {
        ModelConfig {
            hops: 3,
            hv_dim: 2048,
            num_landmarks: s,
            ..ModelConfig::default()
        }
    }

    #[test]
    fn trains_and_beats_chance_on_mutag_scaled() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, s_uni, _) = spec.generate_scaled(1, 0.5);
        let model = train(&ds, &small_config(s_uni));
        assert_eq!(model.s(), s_uni);
        assert_eq!(model.codebooks.len(), 3);
        assert_eq!(model.landmark_hists.len(), 3);
        for t in 0..3 {
            assert_eq!(model.landmark_hists[t].rows, s_uni);
            assert_eq!(model.landmark_hists[t].cols, model.codebooks[t].len());
        }
        let train_acc = evaluate(&model, &ds.train).expect("non-empty train split");
        let test_acc = evaluate(&model, &ds.test).expect("non-empty test split");
        let chance = 1.0 / ds.num_classes as f64;
        assert!(train_acc > chance + 0.1, "train acc {train_acc} ~ chance");
        assert!(test_acc > chance, "test acc {test_acc} below chance");
    }

    /// Satellite equivalence pin: the batched packed [`evaluate`] must be
    /// bit-identical in accuracy to the old per-graph i8 path (now
    /// [`evaluate_reference`]) on every split, and both must agree that
    /// an empty split has no accuracy.
    #[test]
    fn evaluate_matches_i8_reference_path() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(77, 0.5);
        // hv_dim off a word boundary AND a train split larger than one
        // accuracy() batch chunk (64): tail words and chunk seams live.
        let mut cfg = small_config(10);
        cfg.hv_dim = 1000;
        let model = train(&ds, &cfg);
        assert_eq!(
            evaluate(&model, &ds.train),
            evaluate_reference(&model, &ds.train),
            "train-split accuracy drifted from the i8 oracle"
        );
        assert_eq!(
            evaluate(&model, &ds.test),
            evaluate_reference(&model, &ds.test),
            "test-split accuracy drifted from the i8 oracle"
        );
        assert_eq!(evaluate(&model, &[]), None);
        assert_eq!(evaluate_reference(&model, &[]), None);
    }

    #[test]
    fn landmark_rows_consistent_with_kernel() {
        // H_Z reconstructed from stored CSR hists must equal the kernel of
        // the landmark signatures: row dot products over hops.
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(2, 0.2);
        let mut cfg = small_config(8);
        cfg.strategy = LandmarkStrategy::Uniform;
        let model = train(&ds, &cfg);
        // For landmark i, encode_kernel_vector over its own graph must
        // reproduce K(z_i, z_j) = Σ_t h_i·h_j for all j.
        let mut c = vec![0.0; model.s()];
        let li = model.landmark_indices[3];
        let g = &ds.train[li].0;
        encode_kernel_vector(&model, g, &mut c);
        let lsh = &model.lsh;
        let sig_i = GraphSignature::compute(g, lsh);
        for (j, &lj) in model.landmark_indices.iter().enumerate() {
            let sig_j = GraphSignature::compute(&ds.train[lj].0, lsh);
            let want = sig_i.kernel(&sig_j);
            assert!(
                (c[j] - want).abs() < 1e-9,
                "C[{j}]={} vs kernel {want}",
                c[j]
            );
        }
    }

    #[test]
    fn packed_prototypes_consistent_with_reference() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(6, 0.2);
        // hv_dim off a word boundary to exercise the tail-masked path.
        let mut cfg = small_config(8);
        cfg.hv_dim = 1000;
        let model = train(&ds, &cfg);
        // The unpack→repack roundtrip is lossless on ±1 data, so the
        // on-demand i8 view is a faithful oracle for the stored packing.
        let reference = model.reference_prototypes();
        assert_eq!(reference.num_classes(), ds.num_classes);
        assert_eq!(reference.dim(), 1000);
        assert_eq!(
            model.packed_prototypes,
            crate::hdc::PackedPrototypes::from_reference(&reference)
        );
    }

    #[test]
    fn deterministic_training() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(3, 0.2);
        let m1 = train(&ds, &small_config(10));
        let m2 = train(&ds, &small_config(10));
        assert_eq!(m1.landmark_indices, m2.landmark_indices);
        assert_eq!(m1.packed_prototypes, m2.packed_prototypes);
    }

    /// The exec contract on training: the whole trained model — landmark
    /// selection, projection matrix, packed prototypes — is bit-identical
    /// at thread counts {1, 2, 7}. This is the acceptance pin for the
    /// per-lane-accumulator + fixed-order-merge bundling path.
    #[test]
    fn training_bit_identical_across_thread_counts() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(13, 0.15);
        // DPP strategy + off-boundary hv_dim: the parallel kernel matrix,
        // the parallel P_nys build and the tail word are all live.
        let mut cfg = small_config(8);
        cfg.hv_dim = 500;
        let want = train_with_pool(&ds, &cfg, &crate::exec::Pool::new(1));
        for threads in [2usize, 7] {
            let got = train_with_pool(&ds, &cfg, &crate::exec::Pool::new(threads));
            assert_eq!(
                got.landmark_indices, want.landmark_indices,
                "landmark drift at {threads} threads"
            );
            assert_eq!(
                got.projection.data, want.projection.data,
                "P_nys drift at {threads} threads"
            );
            assert_eq!(
                got.packed_prototypes, want.packed_prototypes,
                "prototype drift at {threads} threads"
            );
        }
        // The plain entry point (global pool, whatever its size) agrees.
        let plain = train(&ds, &cfg);
        assert_eq!(plain.packed_prototypes, want.packed_prototypes);
        assert_eq!(plain.landmark_indices, want.landmark_indices);
    }

    #[test]
    fn memory_report_dominated_by_projection() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, s_uni, _) = spec.generate_scaled(4, 0.4);
        let mut cfg = small_config(s_uni);
        cfg.hv_dim = 10_000;
        let model = train(&ds, &cfg);
        let mem = model.memory_report();
        assert!(
            mem.p_nys_fraction() > 0.8,
            "P_nys fraction {} (paper: >90%)",
            mem.p_nys_fraction()
        );
        assert_eq!(mem.p_nys, 10_000 * s_uni * 4);
        assert!(mem.total_deployed() > mem.p_nys);
    }
}
