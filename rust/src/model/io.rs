//! Binary model serialization (no `serde` available — a small
//! length-prefixed little-endian format with magic/version header).
//!
//! Derived structures (MPH lookups, KSE schedule tables) are *rebuilt*
//! on load: they are deterministic functions of the stored codebooks /
//! histogram matrices / packed prototypes, which keeps the artifact
//! compact and guarantees the offline tables always match the deployed
//! parameters.
//!
//! ## Format versions
//!
//! * v1 (`NYSXMDL\x01`): prototypes stored as i8 bytes (d bytes each).
//!   Still read transparently.
//! * v2 (`NYSXMDL\x02`): prototypes stored bit-packed (one sign bit per
//!   element, `⌈d/64⌉` u64 words each — 8× smaller), with tail-bit
//!   validation on load. Still read transparently.
//! * v3 (`NYSXMDL\x03`, current): the monotone integer sections —
//!   codebook codes (strictly increasing, mapped through the
//!   order-preserving [`code_key`] image) and CSR row offsets — are
//!   stored Elias–Fano-coded (`n, universe, low words, high words`;
//!   see `succinct::EliasFano`), cutting them from 8 bytes per entry to
//!   roughly `2 + log2(universe/n)` bits per entry.
//!
//! ## Robustness contract
//!
//! [`load`] never panics on malformed bytes and never allocates
//! proportionally to a corrupt length prefix: every failure — wrong
//! magic, truncation, an implausible section length, an internal
//! inconsistency between sections (including Elias–Fano sections whose
//! declared `n`/`universe` disagree with their bit content) — comes back
//! as a typed [`NysxError::ModelFormat`] carrying the byte offset at
//! which decoding stopped. Vector reads grow incrementally (bounded by
//! bytes actually present in the stream), so a bit-flipped length prefix
//! produces an error, not an OOM-sized preallocation.

use std::io::{self, Read, Write};

use super::{ModelConfig, NysHdcModel};
use crate::api::NysxError;
use crate::hdc::{Hypervector, PackedHypervector, PackedPrototypes};
use crate::kernel::{Codebook, LshParams};
use crate::mph::{code_from_key, code_key, MphLookup};
use crate::nystrom::{LandmarkStrategy, NystromProjection};
use crate::sparse::Csr;
use crate::succinct::EliasFano;

const MAGIC_V1: &[u8; 8] = b"NYSXMDL\x01";
const MAGIC_V2: &[u8; 8] = b"NYSXMDL\x02";
const MAGIC: &[u8; 8] = b"NYSXMDL\x03";

struct Writer<W: Write> {
    w: W,
}

impl<W: Write> Writer<W> {
    fn u64(&mut self, v: u64) -> io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }
    fn i64(&mut self, v: i64) -> io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }
    fn f64(&mut self, v: f64) -> io::Result<()> {
        self.w.write_all(&v.to_le_bytes())
    }
    fn bytes(&mut self, v: &[u8]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        self.w.write_all(v)
    }
    fn str(&mut self, s: &str) -> io::Result<()> {
        self.bytes(s.as_bytes())
    }
    fn f64s(&mut self, v: &[f64]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.f64(x)?;
        }
        Ok(())
    }
    fn f32s(&mut self, v: &[f32]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
    fn i64s(&mut self, v: &[i64]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.i64(x)?;
        }
        Ok(())
    }
    fn usizes(&mut self, v: &[usize]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.u64(x as u64)?;
        }
        Ok(())
    }
    fn u32s(&mut self, v: &[u32]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.w.write_all(&x.to_le_bytes())?;
        }
        Ok(())
    }
    fn u64s(&mut self, v: &[u64]) -> io::Result<()> {
        self.u64(v.len() as u64)?;
        for &x in v {
            self.u64(x)?;
        }
        Ok(())
    }
    /// An Elias–Fano section: `n, universe, low words, high words`. The
    /// word vectors carry their own length prefixes so the reader can
    /// bound allocation before trusting `n`.
    fn elias_fano(&mut self, ef: &EliasFano) -> io::Result<()> {
        self.u64(ef.len() as u64)?;
        self.u64(ef.universe())?;
        self.u64s(ef.low_words())?;
        self.u64s(ef.high_words())
    }
}

/// Upper bound on any single serialized section, in bytes. A full-size
/// model (d = 10^4, s ≈ 400 at FP32) is ~16 MB total; 1 GiB per section
/// rejects corrupt length prefixes early without constraining any
/// plausible deployment.
const MAX_SECTION_BYTES: u64 = 1 << 30;

/// Initial allocation granularity for incremental vector reads: memory
/// growth is driven by bytes actually read, never by the length prefix.
const ALLOC_CHUNK: usize = 1 << 16;

fn invalid(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

struct Reader<R: Read> {
    r: R,
    /// Bytes consumed so far — reported as the error offset.
    offset: u64,
}

impl<R: Read> Reader<R> {
    fn fill(&mut self, buf: &mut [u8]) -> io::Result<()> {
        self.r.read_exact(buf)?;
        self.offset += buf.len() as u64;
        Ok(())
    }
    /// Read a vector length prefix for elements of `elem_bytes` each,
    /// rejecting sizes no real model section can reach.
    fn len_prefix(&mut self, elem_bytes: u64, what: &str) -> io::Result<usize> {
        let n = self.u64()?;
        if n.saturating_mul(elem_bytes) > MAX_SECTION_BYTES {
            return Err(invalid(format!("implausible {what} length {n}")));
        }
        Ok(n as usize)
    }
    fn u64(&mut self) -> io::Result<u64> {
        let mut b = [0u8; 8];
        self.fill(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }
    fn i64(&mut self) -> io::Result<i64> {
        let mut b = [0u8; 8];
        self.fill(&mut b)?;
        Ok(i64::from_le_bytes(b))
    }
    fn f64(&mut self) -> io::Result<f64> {
        let mut b = [0u8; 8];
        self.fill(&mut b)?;
        Ok(f64::from_le_bytes(b))
    }
    fn bytes(&mut self) -> io::Result<Vec<u8>> {
        let n = self.len_prefix(1, "byte string")?;
        let mut v = Vec::with_capacity(n.min(ALLOC_CHUNK));
        let mut chunk = [0u8; 4096];
        let mut remaining = n;
        while remaining > 0 {
            let take = remaining.min(chunk.len());
            self.fill(&mut chunk[..take])?;
            v.extend_from_slice(&chunk[..take]);
            remaining -= take;
        }
        Ok(v)
    }
    fn str(&mut self) -> io::Result<String> {
        String::from_utf8(self.bytes()?).map_err(|e| invalid(e.to_string()))
    }
    fn f64s(&mut self) -> io::Result<Vec<f64>> {
        let n = self.len_prefix(8, "f64 vector")?;
        let mut out = Vec::with_capacity(n.min(ALLOC_CHUNK));
        for _ in 0..n {
            out.push(self.f64()?);
        }
        Ok(out)
    }
    fn f32s(&mut self) -> io::Result<Vec<f32>> {
        let n = self.len_prefix(4, "f32 vector")?;
        let mut out = Vec::with_capacity(n.min(ALLOC_CHUNK));
        for _ in 0..n {
            let mut b = [0u8; 4];
            self.fill(&mut b)?;
            out.push(f32::from_le_bytes(b));
        }
        Ok(out)
    }
    fn i64s(&mut self) -> io::Result<Vec<i64>> {
        let n = self.len_prefix(8, "i64 vector")?;
        let mut out = Vec::with_capacity(n.min(ALLOC_CHUNK));
        for _ in 0..n {
            out.push(self.i64()?);
        }
        Ok(out)
    }
    fn usizes(&mut self) -> io::Result<Vec<usize>> {
        let n = self.len_prefix(8, "index vector")?;
        let mut out = Vec::with_capacity(n.min(ALLOC_CHUNK));
        for _ in 0..n {
            out.push(self.u64()? as usize);
        }
        Ok(out)
    }
    fn u32s(&mut self) -> io::Result<Vec<u32>> {
        let n = self.len_prefix(4, "u32 vector")?;
        let mut out = Vec::with_capacity(n.min(ALLOC_CHUNK));
        for _ in 0..n {
            let mut b = [0u8; 4];
            self.fill(&mut b)?;
            out.push(u32::from_le_bytes(b));
        }
        Ok(out)
    }
    fn i8s(&mut self) -> io::Result<Vec<i8>> {
        let bytes = self.bytes()?;
        Ok(bytes.into_iter().map(|b| b as i8).collect())
    }
    fn u64s(&mut self) -> io::Result<Vec<u64>> {
        let n = self.len_prefix(8, "u64 vector")?;
        let mut out = Vec::with_capacity(n.min(ALLOC_CHUNK));
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(out)
    }
    /// Decode an Elias–Fano section. `EliasFano::from_parts` cross-checks
    /// every length against `(n, universe)` and re-counts the high ones,
    /// so a lying `n` or a corrupt word vector is a typed error; the word
    /// vectors themselves go through the capped incremental readers, so
    /// allocation stays bounded by bytes actually present.
    fn elias_fano(&mut self, what: &str) -> io::Result<EliasFano> {
        let n = self.len_prefix(8, &format!("{what} element count"))?;
        let universe = self.u64()?;
        let low_words = self.u64s()?;
        let high_words = self.u64s()?;
        EliasFano::from_parts(n, universe, low_words, high_words)
            .map_err(|e| invalid(format!("{what}: {e}")))
    }
}

fn strategy_tag(s: LandmarkStrategy) -> (u64, u64) {
    match s {
        LandmarkStrategy::Uniform => (0, 0),
        LandmarkStrategy::HybridDpp { pool_factor } => (1, pool_factor as u64),
        LandmarkStrategy::FullDpp => (2, 0),
    }
}

fn strategy_from_tag(tag: u64, arg: u64) -> io::Result<LandmarkStrategy> {
    match tag {
        0 => Ok(LandmarkStrategy::Uniform),
        1 => Ok(LandmarkStrategy::HybridDpp {
            pool_factor: arg as usize,
        }),
        2 => Ok(LandmarkStrategy::FullDpp),
        _ => Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("bad strategy tag {tag}"),
        )),
    }
}

/// Serialize a model to a writer (current format, v3).
pub fn save<W: Write>(model: &NysHdcModel, w: W) -> io::Result<()> {
    let mut w = Writer { w };
    w.w.write_all(MAGIC)?;
    // Config
    let c = &model.config;
    w.u64(c.hops as u64)?;
    w.u64(c.hv_dim as u64)?;
    w.f64(c.lsh_width)?;
    w.u64(c.num_landmarks as u64)?;
    let (tag, arg) = strategy_tag(c.strategy);
    w.u64(tag)?;
    w.u64(arg)?;
    w.f64(c.mph_gamma)?;
    w.u64(c.pes as u64)?;
    w.u64(c.seed)?;
    // Meta
    w.str(&model.dataset_name)?;
    w.u64(model.num_classes as u64)?;
    w.u64(model.feature_dim as u64)?;
    // LSH
    w.u64(model.lsh.u.len() as u64)?;
    for u in &model.lsh.u {
        w.f64s(u)?;
    }
    w.f64s(&model.lsh.b)?;
    w.f64(model.lsh.w)?;
    // Codebooks (v3: Elias–Fano over the order-preserving u64 key image —
    // the code list is strictly increasing by construction).
    w.u64(model.codebooks.len() as u64)?;
    for cb in &model.codebooks {
        let keys: Vec<u64> = cb.codes.iter().map(|&c| code_key(c)).collect();
        w.elias_fano(&EliasFano::from_sorted(&keys))?;
    }
    // Landmark hists (CSR; v3: Elias–Fano row offsets)
    w.u64(model.landmark_hists.len() as u64)?;
    for h in &model.landmark_hists {
        w.u64(h.rows as u64)?;
        w.u64(h.cols as u64)?;
        let offs: Vec<u64> = h.offsets().iter().map(|p| p as u64).collect();
        w.elias_fano(&EliasFano::from_sorted(&offs))?;
        w.u32s(&h.col_idx)?;
        w.f64s(&h.val)?;
    }
    // Projection
    w.u64(model.projection.d as u64)?;
    w.u64(model.projection.s as u64)?;
    w.u64(model.projection.rank as u64)?;
    w.f32s(&model.projection.data)?;
    // Prototypes (bit-packed, one sign bit per element; unchanged from v2)
    w.u64(model.packed_prototypes.prototypes.len() as u64)?;
    for p in &model.packed_prototypes.prototypes {
        w.u64(p.dim() as u64)?;
        w.u64s(p.words())?;
    }
    w.usizes(&model.packed_prototypes.counts)?;
    // Landmark indices
    w.usizes(&model.landmark_indices)?;
    Ok(())
}

/// Deserialize a model from a reader, rebuilding MPH lookups and KSE
/// schedule tables. Reads the current Elias–Fano-sectioned format (v3)
/// plus the legacy packed (v2) and i8 (v1) formats.
///
/// Malformed input of any kind — wrong magic, truncation, corrupt length
/// prefixes, cross-section inconsistencies — yields a
/// [`NysxError::ModelFormat`] with the byte offset where decoding
/// stopped. No input can make this panic or preallocate beyond the bytes
/// actually present.
pub fn load<R: Read>(r: R) -> Result<NysHdcModel, NysxError> {
    let mut r = Reader { r, offset: 0 };
    match load_inner(&mut r) {
        Ok(model) => Ok(model),
        // Decode-shaped failures (malformed bytes, truncation) become
        // ModelFormat with the stop offset; environmental read failures
        // (disk faults, interrupted reads) stay Io so callers never
        // mistake a flaky filesystem for a corrupt artifact.
        Err(e) => match e.kind() {
            io::ErrorKind::InvalidData | io::ErrorKind::UnexpectedEof => {
                Err(NysxError::ModelFormat {
                    offset: r.offset,
                    detail: e.to_string(),
                })
            }
            _ => Err(NysxError::Io(e)),
        },
    }
}

/// Cross-field consistency for a deserialized CSR operand, validated on
/// the raw arrays *before* [`Csr::from_parts`] assembles them: everything
/// the SpMV kernels index into unchecked must be proven here.
fn check_csr_parts(
    rows: usize,
    cols: usize,
    row_ptr: &[usize],
    col_idx: &[u32],
    val: &[f64],
    what: &str,
) -> io::Result<()> {
    let want_ptrs = rows
        .checked_add(1)
        .ok_or_else(|| invalid(format!("{what}: row count overflow")))?;
    if row_ptr.len() != want_ptrs {
        return Err(invalid(format!(
            "{what}: row_ptr length {} != rows+1 = {want_ptrs}",
            row_ptr.len()
        )));
    }
    if row_ptr.first() != Some(&0) {
        return Err(invalid(format!("{what}: row_ptr must start at 0")));
    }
    if row_ptr.windows(2).any(|w| w[0] > w[1]) {
        return Err(invalid(format!("{what}: row_ptr not monotone")));
    }
    let nnz = *row_ptr.last().unwrap_or(&0);
    if nnz != col_idx.len() || nnz != val.len() {
        return Err(invalid(format!(
            "{what}: nnz {} disagrees with col_idx/val lengths {}/{}",
            nnz,
            col_idx.len(),
            val.len()
        )));
    }
    if col_idx.iter().any(|&c| c as usize >= cols) {
        return Err(invalid(format!("{what}: column index out of range")));
    }
    Ok(())
}

fn load_inner<R: Read>(r: &mut Reader<R>) -> io::Result<NysHdcModel> {
    let mut magic = [0u8; 8];
    r.fill(&mut magic)?;
    let version = if &magic == MAGIC {
        3u8
    } else if &magic == MAGIC_V2 {
        2u8
    } else if &magic == MAGIC_V1 {
        1u8
    } else {
        return Err(invalid("not a NysX model file (bad magic)"));
    };
    let hops = r.u64()? as usize;
    let hv_dim = r.u64()? as usize;
    let lsh_width = r.f64()?;
    let num_landmarks = r.u64()? as usize;
    let tag = r.u64()?;
    let arg = r.u64()?;
    let strategy = strategy_from_tag(tag, arg)?;
    let mph_gamma = r.f64()?;
    let pes = r.u64()? as usize;
    let seed = r.u64()?;
    let config = ModelConfig {
        hops,
        hv_dim,
        lsh_width,
        num_landmarks,
        strategy,
        mph_gamma,
        pes,
        seed,
    };
    // A corrupt header must not reach the derived-structure builders
    // (zero PEs, NaN gamma, ... all panic or loop deep inside them).
    config
        .validate()
        .map_err(|e| invalid(format!("stored config rejected: {e}")))?;
    let dataset_name = r.str()?;
    let num_classes = r.u64()? as usize;
    if num_classes == 0 || num_classes > 1 << 20 {
        return Err(invalid(format!("implausible class count {num_classes}")));
    }
    let feature_dim = r.u64()? as usize;
    let n_u = r.u64()? as usize;
    if n_u != hops {
        return Err(invalid(format!("{n_u} LSH projections for {hops} hops")));
    }
    let mut u = Vec::with_capacity(n_u);
    for t in 0..n_u {
        let ut = r.f64s()?;
        // kernel_vector zips features against u^(t) — a silently short
        // row would truncate projections instead of erroring.
        if ut.len() != feature_dim {
            return Err(invalid(format!(
                "LSH projection u^({t}) has {} entries for feature_dim {feature_dim}",
                ut.len()
            )));
        }
        u.push(ut);
    }
    let b = r.f64s()?;
    if b.len() != hops {
        return Err(invalid(format!("{} LSH offsets for {hops} hops", b.len())));
    }
    let w_width = r.f64()?;
    let lsh = LshParams { u, b, w: w_width };
    let n_cb = r.u64()? as usize;
    if n_cb != hops {
        return Err(invalid(format!("{n_cb} codebooks for {hops} hops")));
    }
    let codebooks: Vec<Codebook> = (0..n_cb)
        .map(|t| -> io::Result<Codebook> {
            if version >= 3 {
                let ef = r.elias_fano(&format!("B^({t}) codes"))?;
                // The Elias–Fano contract is non-decreasing; codebook
                // codes must be *strictly* increasing (they index the
                // histogram columns one-to-one).
                let mut codes = Vec::with_capacity(ef.len().min(ALLOC_CHUNK));
                let mut prev: Option<u64> = None;
                for k in ef.iter() {
                    if prev.is_some_and(|p| p >= k) {
                        return Err(invalid(format!(
                            "B^({t}) codes not strictly increasing"
                        )));
                    }
                    prev = Some(k);
                    codes.push(code_from_key(k));
                }
                Ok(Codebook::build(codes))
            } else {
                r.i64s().map(Codebook::build)
            }
        })
        .collect::<io::Result<_>>()?;
    let n_h = r.u64()? as usize;
    if n_h != hops {
        return Err(invalid(format!("{n_h} histogram matrices for {hops} hops")));
    }
    let mut landmark_hists = Vec::with_capacity(n_h);
    for t in 0..n_h {
        let rows = r.u64()? as usize;
        let cols = r.u64()? as usize;
        let row_ptr: Vec<usize> = if version >= 3 {
            let ef = r.elias_fano(&format!("H^({t}) row offsets"))?;
            ef.iter().map(|p| p as usize).collect()
        } else {
            r.usizes()?
        };
        let col_idx = r.u32s()?;
        let val = r.f64s()?;
        check_csr_parts(rows, cols, &row_ptr, &col_idx, &val, &format!("H^({t})"))?;
        if rows != num_landmarks {
            return Err(invalid(format!(
                "H^({t}) has {rows} rows for s = {num_landmarks} landmarks"
            )));
        }
        if cols != codebooks[t].len() {
            return Err(invalid(format!(
                "H^({t}) has {cols} cols for |B^({t})| = {}",
                codebooks[t].len()
            )));
        }
        // from_parts re-chooses the offset representation, so every
        // format version lands on the same canonical in-memory Csr.
        landmark_hists.push(Csr::from_parts(rows, cols, row_ptr, col_idx, val));
    }
    let d = r.u64()? as usize;
    let s = r.u64()? as usize;
    let rank = r.u64()? as usize;
    if d != hv_dim || s != num_landmarks {
        return Err(invalid(format!(
            "projection is {d}x{s}, model wants {hv_dim}x{num_landmarks}"
        )));
    }
    if rank > s {
        return Err(invalid(format!("projection rank {rank} exceeds s = {s}")));
    }
    let data = r.f32s()?;
    if d.checked_mul(s) != Some(data.len()) {
        return Err(invalid("projection size mismatch"));
    }
    let projection = NystromProjection { d, s, data, rank };
    let n_proto = r.u64()? as usize;
    if n_proto != num_classes {
        return Err(invalid(format!(
            "{n_proto} prototypes for {num_classes} classes"
        )));
    }
    let mut packed_protos = Vec::with_capacity(n_proto);
    for _ in 0..n_proto {
        match version {
            1 => {
                let hv = Hypervector { data: r.i8s()? };
                if hv.dim() != hv_dim {
                    return Err(invalid(format!(
                        "prototype dim {} != model hv_dim {hv_dim}",
                        hv.dim()
                    )));
                }
                packed_protos.push(PackedHypervector::pack(&hv));
            }
            _ => {
                let p_dim = r.u64()? as usize;
                if p_dim != hv_dim {
                    return Err(invalid(format!(
                        "prototype dim {p_dim} != model hv_dim {hv_dim}"
                    )));
                }
                let words = r.u64s()?;
                packed_protos.push(
                    PackedHypervector::from_words(p_dim, words)
                        .map_err(|e| invalid(format!("prototype: {e}")))?,
                );
            }
        }
    }
    let counts = r.usizes()?;
    if counts.len() != num_classes {
        return Err(invalid(format!(
            "{} prototype counts for {num_classes} classes",
            counts.len()
        )));
    }
    let landmark_indices = r.usizes()?;
    if landmark_indices.len() != num_landmarks {
        return Err(invalid(format!(
            "{} landmark indices for s = {num_landmarks}",
            landmark_indices.len()
        )));
    }

    // Rebuild derived structures.
    let lookups: Vec<MphLookup> = codebooks
        .iter()
        .map(|cb| {
            let keys: Vec<u64> = cb.codes.iter().map(|&c| code_key(c)).collect();
            let values: Vec<u32> = (0..cb.len() as u32).collect();
            MphLookup::build(&keys, &values, mph_gamma)
        })
        .collect();
    let kse_schedules = NysHdcModel::build_kse_schedules(&landmark_hists, pes);
    let packed_prototypes = PackedPrototypes {
        prototypes: packed_protos,
        counts,
    };

    Ok(NysHdcModel {
        config,
        dataset_name,
        num_classes,
        feature_dim,
        lsh,
        codebooks,
        lookups,
        landmark_hists,
        kse_schedules,
        projection,
        packed_prototypes,
        landmark_indices,
    })
}

/// The legacy v2 writer (packed prototypes, plain integer sections).
/// Not the default save path: kept for the reader's backwards-compat
/// tests and for the memory benchmark, which measures the v3 Elias–Fano
/// savings against real v2 artifacts rather than estimating them.
pub(crate) fn save_v2<W: Write>(model: &NysHdcModel, w: W) -> io::Result<()> {
    let mut w = Writer { w };
    w.w.write_all(MAGIC_V2)?;
    let c = &model.config;
    w.u64(c.hops as u64)?;
    w.u64(c.hv_dim as u64)?;
    w.f64(c.lsh_width)?;
    w.u64(c.num_landmarks as u64)?;
    let (tag, arg) = strategy_tag(c.strategy);
    w.u64(tag)?;
    w.u64(arg)?;
    w.f64(c.mph_gamma)?;
    w.u64(c.pes as u64)?;
    w.u64(c.seed)?;
    w.str(&model.dataset_name)?;
    w.u64(model.num_classes as u64)?;
    w.u64(model.feature_dim as u64)?;
    w.u64(model.lsh.u.len() as u64)?;
    for u in &model.lsh.u {
        w.f64s(u)?;
    }
    w.f64s(&model.lsh.b)?;
    w.f64(model.lsh.w)?;
    w.u64(model.codebooks.len() as u64)?;
    for cb in &model.codebooks {
        w.i64s(&cb.codes)?;
    }
    w.u64(model.landmark_hists.len() as u64)?;
    for h in &model.landmark_hists {
        w.u64(h.rows as u64)?;
        w.u64(h.cols as u64)?;
        w.usizes(&h.offsets().to_vec())?;
        w.u32s(&h.col_idx)?;
        w.f64s(&h.val)?;
    }
    w.u64(model.projection.d as u64)?;
    w.u64(model.projection.s as u64)?;
    w.u64(model.projection.rank as u64)?;
    w.f32s(&model.projection.data)?;
    w.u64(model.packed_prototypes.prototypes.len() as u64)?;
    for p in &model.packed_prototypes.prototypes {
        w.u64(p.dim() as u64)?;
        w.u64s(p.words())?;
    }
    w.usizes(&model.packed_prototypes.counts)?;
    w.usizes(&model.landmark_indices)?;
    Ok(())
}

/// Save to a file path.
pub fn save_file(model: &NysHdcModel, path: &std::path::Path) -> io::Result<()> {
    let f = std::fs::File::create(path)?;
    save(model, std::io::BufWriter::new(f))
}

/// Load from a file path. Open failures come back as [`NysxError::Io`],
/// decode failures as [`NysxError::ModelFormat`] with the byte offset.
pub fn load_file(path: &std::path::Path) -> Result<NysHdcModel, NysxError> {
    let f = std::fs::File::open(path)?;
    load(std::io::BufReader::new(f))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::tudataset::spec_by_name;
    use crate::model::train::{encode_hv, train};
    use crate::model::ModelConfig;

    /// The legacy v1 writer (i8 prototypes), kept test-only to prove the
    /// reader's backwards compatibility.
    fn save_v1<W: Write>(model: &NysHdcModel, w: W) -> io::Result<()> {
        let mut w = Writer { w };
        w.w.write_all(MAGIC_V1)?;
        let c = &model.config;
        w.u64(c.hops as u64)?;
        w.u64(c.hv_dim as u64)?;
        w.f64(c.lsh_width)?;
        w.u64(c.num_landmarks as u64)?;
        let (tag, arg) = strategy_tag(c.strategy);
        w.u64(tag)?;
        w.u64(arg)?;
        w.f64(c.mph_gamma)?;
        w.u64(c.pes as u64)?;
        w.u64(c.seed)?;
        w.str(&model.dataset_name)?;
        w.u64(model.num_classes as u64)?;
        w.u64(model.feature_dim as u64)?;
        w.u64(model.lsh.u.len() as u64)?;
        for u in &model.lsh.u {
            w.f64s(u)?;
        }
        w.f64s(&model.lsh.b)?;
        w.f64(model.lsh.w)?;
        w.u64(model.codebooks.len() as u64)?;
        for cb in &model.codebooks {
            w.i64s(&cb.codes)?;
        }
        w.u64(model.landmark_hists.len() as u64)?;
        for h in &model.landmark_hists {
            w.u64(h.rows as u64)?;
            w.u64(h.cols as u64)?;
            w.usizes(&h.offsets().to_vec())?;
            w.u32s(&h.col_idx)?;
            w.f64s(&h.val)?;
        }
        w.u64(model.projection.d as u64)?;
        w.u64(model.projection.s as u64)?;
        w.u64(model.projection.rank as u64)?;
        w.f32s(&model.projection.data)?;
        let protos = model.reference_prototypes();
        w.u64(protos.prototypes.len() as u64)?;
        for p in &protos.prototypes {
            let bytes: Vec<u8> = p.data.iter().map(|&x| x as u8).collect();
            w.bytes(&bytes)?;
        }
        w.usizes(&protos.counts)?;
        w.usizes(&model.landmark_indices)?;
        Ok(())
    }

    #[test]
    fn roundtrip_preserves_behaviour() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(5, 0.2);
        let cfg = ModelConfig {
            hops: 2,
            hv_dim: 512,
            num_landmarks: 8,
            ..ModelConfig::default()
        };
        let model = train(&ds, &cfg);
        let mut buf = Vec::new();
        save(&model, &mut buf).unwrap();
        let back = load(&buf[..]).unwrap();
        assert_eq!(back.dataset_name, model.dataset_name);
        assert_eq!(back.landmark_indices, model.landmark_indices);
        assert_eq!(back.projection.data, model.projection.data);
        assert_eq!(back.packed_prototypes, model.packed_prototypes);
        assert_eq!(back.landmark_hists, model.landmark_hists);
        for t in 0..2 {
            assert_eq!(back.codebooks[t].codes, model.codebooks[t].codes);
        }
        // Behavioural equality: same HV for the same query.
        for (g, _) in ds.test.iter().take(5) {
            assert_eq!(encode_hv(&model, g), encode_hv(&back, g));
        }
        // Rebuilt MPH agrees with stored codebooks.
        for t in 0..2 {
            for &c in &back.codebooks[t].codes {
                assert_eq!(
                    back.lookups[t].get(crate::mph::code_key(c)),
                    back.codebooks[t].index_of(c)
                );
            }
        }
    }

    #[test]
    fn v1_files_still_load() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(7, 0.2);
        let cfg = ModelConfig {
            hops: 2,
            // Off a word boundary so the packed conversion's tail path is
            // exercised by the version shim too.
            hv_dim: 500,
            num_landmarks: 8,
            ..ModelConfig::default()
        };
        let model = train(&ds, &cfg);
        let mut v1 = Vec::new();
        save_v1(&model, &mut v1).unwrap();
        let back = load(&v1[..]).unwrap();
        assert_eq!(back.packed_prototypes, model.packed_prototypes);
        for (g, _) in ds.test.iter().take(3) {
            assert_eq!(encode_hv(&model, g), encode_hv(&back, g));
        }
    }

    #[test]
    fn v2_files_still_load() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(9, 0.2);
        let cfg = ModelConfig {
            hops: 2,
            hv_dim: 500,
            num_landmarks: 8,
            ..ModelConfig::default()
        };
        let model = train(&ds, &cfg);
        let mut v2 = Vec::new();
        save_v2(&model, &mut v2).unwrap();
        let back = load(&v2[..]).unwrap();
        assert_eq!(back.packed_prototypes, model.packed_prototypes);
        assert_eq!(back.landmark_hists, model.landmark_hists);
        for (g, _) in ds.test.iter().take(3) {
            assert_eq!(encode_hv(&model, g), encode_hv(&back, g));
        }
    }

    #[test]
    fn v2_prototype_section_is_packed_smaller() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(8, 0.15);
        let cfg = ModelConfig {
            hops: 2,
            hv_dim: 4096,
            num_landmarks: 6,
            ..ModelConfig::default()
        };
        let model = train(&ds, &cfg);
        let (mut v1, mut v2) = (Vec::new(), Vec::new());
        save_v1(&model, &mut v1).unwrap();
        save_v2(&model, &mut v2).unwrap();
        // i8 protos: C*d bytes; packed: C*d/8 (+ small headers).
        let c = model.num_classes;
        let d = model.d();
        let saved = v1.len() - v2.len();
        let expect = c * d - c * (d / 8 + 8); // minus per-proto dim header
        assert!(
            saved >= expect - 64 && v2.len() < v1.len(),
            "saved {saved} bytes, expected ≈{expect}"
        );
    }

    /// The v3 acceptance pin: Elias–Fano sections must shrink the
    /// artifact relative to v2 on TUDataset-shaped models, not just on
    /// synthetic extremes.
    #[test]
    fn v3_smaller_than_v2_on_tudataset_configs() {
        for name in ["MUTAG", "BZR", "PROTEINS"] {
            let spec = spec_by_name(name).unwrap();
            let (ds, _, s_dpp) = spec.generate_scaled(15, 0.15);
            let cfg = ModelConfig {
                hops: 3,
                hv_dim: 1024,
                num_landmarks: s_dpp.min(ds.train.len()),
                ..ModelConfig::default()
            };
            let model = train(&ds, &cfg);
            let (mut v2, mut v3) = (Vec::new(), Vec::new());
            save_v2(&model, &mut v2).unwrap();
            save(&model, &mut v3).unwrap();
            assert!(
                v3.len() < v2.len(),
                "{name}: v3 {} bytes not smaller than v2 {}",
                v3.len(),
                v2.len()
            );
        }
    }

    /// Differential pin for the format migration: a model loaded from v2
    /// bytes and one loaded from v3 bytes are the same model — same
    /// parameters, and bit-identical inference at thread counts {1,2,7}.
    #[test]
    fn v3_and_v2_loads_infer_identically_across_pools() {
        use crate::exec::Pool;
        use crate::infer::NysxEngine;
        use std::sync::Arc;

        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(16, 0.2);
        let cfg = ModelConfig {
            hops: 3,
            hv_dim: 1000,
            num_landmarks: 10,
            ..ModelConfig::default()
        };
        let model = train(&ds, &cfg);
        let (mut v2, mut v3) = (Vec::new(), Vec::new());
        save_v2(&model, &mut v2).unwrap();
        save(&model, &mut v3).unwrap();
        let m2 = load(&v2[..]).unwrap();
        let m3 = load(&v3[..]).unwrap();
        assert_eq!(m2.packed_prototypes, m3.packed_prototypes);
        assert_eq!(m2.projection.data, m3.projection.data);
        assert_eq!(m2.landmark_hists, m3.landmark_hists);
        for t in 0..3 {
            assert_eq!(m2.codebooks[t].codes, m3.codebooks[t].codes);
        }
        for threads in [1usize, 2, 7] {
            let mut e2 = NysxEngine::with_pool(&m2, Arc::new(Pool::new(threads)));
            let mut e3 = NysxEngine::with_pool(&m3, Arc::new(Pool::new(threads)));
            for (g, _) in ds.test.iter().take(5) {
                let (r2, r3) = (e2.infer(g), e3.infer(g));
                assert_eq!(r2.predicted, r3.predicted, "at {threads} threads");
                assert_eq!(r2.hv, r3.hv, "HV drift at {threads} threads");
            }
        }
    }

    #[test]
    fn rejects_bad_magic() {
        let buf = b"NOTAMODELxxxxxxxxxxxxxxx".to_vec();
        match load(&buf[..]) {
            Err(NysxError::ModelFormat { offset, detail }) => {
                assert_eq!(offset, 8, "magic is the first 8 bytes");
                assert!(detail.contains("magic"), "{detail}");
            }
            other => panic!("want ModelFormat, got {other:?}"),
        }
    }

    #[test]
    fn rejects_truncated() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(6, 0.15);
        let cfg = ModelConfig {
            hops: 2,
            hv_dim: 128,
            num_landmarks: 5,
            ..ModelConfig::default()
        };
        let model = train(&ds, &cfg);
        let mut buf = Vec::new();
        save(&model, &mut buf).unwrap();
        buf.truncate(buf.len() / 2);
        match load(&buf[..]) {
            Err(NysxError::ModelFormat { offset, .. }) => {
                assert!(offset <= buf.len() as u64, "offset past the stream end");
            }
            other => panic!("want ModelFormat, got {other:?}"),
        }
    }

    /// Tiny model serialized in all three on-disk formats, for the corpus
    /// tests below.
    fn tiny_model_bytes() -> Vec<(&'static str, Vec<u8>)> {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(13, 0.15);
        let cfg = ModelConfig {
            hops: 2,
            // Off a word boundary: the packed tail-bit validation path is
            // live in the v2/v3 decode.
            hv_dim: 200,
            num_landmarks: 5,
            ..ModelConfig::default()
        };
        let model = train(&ds, &cfg);
        let (mut v1, mut v2, mut v3) = (Vec::new(), Vec::new(), Vec::new());
        save_v1(&model, &mut v1).unwrap();
        save_v2(&model, &mut v2).unwrap();
        save(&model, &mut v3).unwrap();
        vec![("v1", v1), ("v2", v2), ("v3", v3)]
    }

    /// THE robustness property: truncation at any point, in any format
    /// version, is a typed [`NysxError::ModelFormat`] — never a panic.
    #[test]
    fn truncation_at_every_offset_yields_model_format() {
        for (tag, buf) in tiny_model_bytes() {
            for cut in (0..buf.len()).step_by(7) {
                match load(&buf[..cut]) {
                    Err(NysxError::ModelFormat { offset, .. }) => {
                        assert!(
                            offset <= cut as u64,
                            "{tag}: error offset {offset} past truncation point {cut}"
                        );
                    }
                    Ok(_) => panic!("{tag}: truncated at {cut} still loaded"),
                    Err(other) => panic!("{tag}: want ModelFormat at {cut}, got {other:?}"),
                }
            }
        }
    }

    /// Bit flips anywhere in the artifact either still decode (a flip in
    /// value payload changes numbers, not structure) or fail with a typed
    /// [`NysxError::ModelFormat`]. A panic or abort fails this test —
    /// which is exactly what a corrupt length prefix used to cause via
    /// `Vec::with_capacity` on the raw count.
    #[test]
    fn bit_flips_never_panic() {
        for (tag, buf) in tiny_model_bytes() {
            for pos in (0..buf.len()).step_by(11) {
                for bit in [0u8, 3, 7] {
                    let mut bad = buf.clone();
                    bad[pos] ^= 1 << bit;
                    match load(&bad[..]) {
                        Ok(_) | Err(NysxError::ModelFormat { .. }) => {}
                        Err(other) => {
                            panic!("{tag}: flip {pos}.{bit} gave wrong error class {other:?}")
                        }
                    }
                }
            }
        }
    }

    /// A corrupt length prefix announcing an absurd element count must be
    /// rejected by the plausibility cap — BEFORE any proportional
    /// allocation — and a merely-large lie must die on EOF with memory
    /// bounded by the actual stream length.
    #[test]
    fn corrupt_length_prefix_rejected_without_huge_allocation() {
        let (_, buf) = tiny_model_bytes().pop().unwrap();
        // The dataset-name length prefix sits right after the 8-byte
        // magic and the 9-field (72-byte) config block.
        let name_len_at = 8 + 72;
        for lie in [u64::MAX, 1 << 40, 1 << 25] {
            let mut bad = buf.clone();
            bad[name_len_at..name_len_at + 8].copy_from_slice(&lie.to_le_bytes());
            match load(&bad[..]) {
                Err(NysxError::ModelFormat { offset, .. }) => {
                    // Decoding stops inside or right after the lying
                    // section; it must never "succeed".
                    assert!(offset <= bad.len() as u64 + 8);
                }
                other => panic!("lying length {lie:#x}: want ModelFormat, got {other:?}"),
            }
        }
    }

    /// Byte offset of the first codebook's Elias–Fano section in a v3
    /// artifact — a mirror of the writer's layout, verified in the test
    /// against the actual bytes before it is trusted.
    fn first_codebook_section_offset(model: &NysHdcModel) -> usize {
        let mut off = 8 + 72; // magic + 9-field config
        off += 8 + model.dataset_name.len(); // dataset name
        off += 16; // num_classes, feature_dim
        off += 8; // LSH u count
        for u in &model.lsh.u {
            off += 8 + u.len() * 8;
        }
        off += 8 + model.lsh.b.len() * 8; // LSH b
        off += 8; // LSH w
        off += 8; // codebook count
        off
    }

    /// Satellite pin: corrupt Elias–Fano section headers — a lying `n`,
    /// a lying word-vector length — are typed [`NysxError::ModelFormat`],
    /// never a panic or an allocation proportional to the lie.
    #[test]
    fn corrupt_elias_fano_section_lengths_are_typed() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(13, 0.15);
        let cfg = ModelConfig {
            hops: 2,
            hv_dim: 200,
            num_landmarks: 5,
            ..ModelConfig::default()
        };
        let model = train(&ds, &cfg);
        let mut buf = Vec::new();
        save(&model, &mut buf).unwrap();
        let at = first_codebook_section_offset(&model);
        // Guard the offset mirror against layout drift: the u64 here must
        // be the first codebook's element count.
        let n0 = u64::from_le_bytes(buf[at..at + 8].try_into().unwrap());
        assert_eq!(
            n0 as usize,
            model.codebooks[0].codes.len(),
            "layout mirror drifted — update first_codebook_section_offset"
        );
        // Lie about n: absurd (caught by the plausibility cap), large
        // (dies on cross-checked word lengths), and off-by-one in either
        // direction (dies on the ones-count / last-value checks).
        for lie in [u64::MAX, 1 << 40, n0 + 1, n0.saturating_sub(1)] {
            let mut bad = buf.clone();
            bad[at..at + 8].copy_from_slice(&lie.to_le_bytes());
            match load(&bad[..]) {
                Err(NysxError::ModelFormat { .. }) => {}
                other => panic!("EF n lie {lie:#x}: want ModelFormat, got {other:?}"),
            }
        }
        // Lie about the low-words vector length (n and universe intact).
        let low_len_at = at + 16;
        for lie in [u64::MAX, 1 << 40, 3u64] {
            let mut bad = buf.clone();
            bad[low_len_at..low_len_at + 8].copy_from_slice(&lie.to_le_bytes());
            match load(&bad[..]) {
                Err(NysxError::ModelFormat { .. }) => {}
                other => panic!("EF low-words lie {lie:#x}: want ModelFormat, got {other:?}"),
            }
        }
    }

    /// Cross-section inconsistencies (not just truncation) are caught:
    /// a prototype section claiming a different dimensionality.
    #[test]
    fn prototype_dim_mismatch_is_typed() {
        let spec = spec_by_name("MUTAG").unwrap();
        let (ds, _, _) = spec.generate_scaled(14, 0.15);
        let cfg = ModelConfig {
            hops: 2,
            hv_dim: 128,
            num_landmarks: 5,
            ..ModelConfig::default()
        };
        let mut model = train(&ds, &cfg);
        // Desynchronize: claim hv_dim 256 while every stored section is
        // still sized for 128.
        model.config.hv_dim = 256;
        let mut buf = Vec::new();
        save(&model, &mut buf).unwrap();
        match load(&buf[..]) {
            Err(NysxError::ModelFormat { detail, .. }) => {
                assert!(
                    detail.contains("256") || detail.contains("128"),
                    "detail should name the mismatching dims: {detail}"
                );
            }
            other => panic!("want ModelFormat, got {other:?}"),
        }
    }
}
